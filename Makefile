# SMURF repo targets. The rust crate is dependency-free by default; the
# optional `xla` feature (PJRT runtime) needs deps uncommented in
# rust/Cargo.toml — see that file.
#
# FEATURES selects optional crate features for build/test/clippy/bench,
# e.g. `make tier1 FEATURES=wide512` runs the suite with 512-lane bit
# planes (CI exercises both feature sets).

CARGO ?= cargo
MANIFEST := rust/Cargo.toml
FEATURES ?=
FEATFLAGS := $(if $(FEATURES),--features $(FEATURES),)

.PHONY: build test tier1 chaos clippy bench-json bench bench-build fault-sweep ci \
	lint-invariants loom miri tsan careful verify-all fuzz-smoke soak

build:
	$(CARGO) build --release --manifest-path $(MANIFEST) $(FEATFLAGS)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST) $(FEATFLAGS)

# Tier-1 verification gate (see ROADMAP.md): must stay green per PR.
tier1: build test

# Chaos suite: fault-injected serving-core tests (worker panics, stalls,
# overload shedding, deadline expiry, shutdown drains) plus the resilient
# client's full recovery ladder (deadline-carved retries under budgets,
# hedged requests with bit-identity audits, per-function circuit
# breakers). Run in release — the tests drive real worker pools under
# timing assertions.
chaos:
	$(CARGO) test --test chaos --release --manifest-path $(MANIFEST) $(FEATFLAGS)

# Lint gate (CI `lint` job): warnings are errors across every target, so
# an uncompilable or warning-ridden state cannot land again.
clippy:
	$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) $(FEATFLAGS) -- -D warnings

# Compile every bench target without running it (CI): bench-only code
# cannot silently rot between perf sessions.
bench-build:
	$(CARGO) bench --no-run --manifest-path $(MANIFEST) $(FEATFLAGS)

# Machine-readable perf record: runs the wide-vs-scalar simulation bench
# (which writes BENCH_perf.json in the repo root; override with BENCH_OUT)
# and the serving-stack bench (human-readable log). perf_wide equality-
# gates every wide/scalar pair before timing and panics on divergence, so
# a tripped assertion fails this target with a non-zero exit instead of
# committing numbers from a wrong engine.
bench-json:
	$(CARGO) bench --bench perf_wide --manifest-path $(MANIFEST) $(FEATFLAGS)
	$(CARGO) bench --bench perf_serve --manifest-path $(MANIFEST) $(FEATFLAGS)

bench: bench-json

# Fault-injection sweep (ISSUE 7): zero-rate equality gates (armed
# all-zero fault plans and rate-0 TMR must be bit-identical to the clean
# engine at every compiled plane width — a divergence aborts with a
# non-zero exit before anything is recorded), then per-site MAE-vs-flip-
# rate curves raw vs TMR and hook-overhead timings, written to
# BENCH_fault_sweep.json (override with BENCH_FAULT_OUT). The TMR-gain
# and overhead floors are deferred and skippable with BENCH_NO_ENFORCE=1;
# the equality gates never are.
fault-sweep:
	$(CARGO) bench --bench fault_sweep --manifest-path $(MANIFEST) $(FEATFLAGS)

# Differential-oracle fuzz smoke (ISSUE 10): FUZZ_CASES seeded cases
# through the exact-equality lattice (scalar == every plane width ==
# TMR-at-0 == armed-zero faults, bit for bit) plus the bounded analytic
# relation, with shrinking to a minimized seed+config repro on failure.
# Sized for tier-1 time; override FUZZ_SEED to replay a reported case.
FUZZ_CASES ?=
FUZZ_SEED ?=
fuzz-smoke:
	FUZZ_CASES=$(FUZZ_CASES) FUZZ_SEED=$(FUZZ_SEED) \
		$(CARGO) test --test soak --release --manifest-path $(MANIFEST) $(FEATFLAGS) \
		-- --nocapture differential_oracle_fuzz_smoke

# Chaos soak (ISSUE 10): SOAK_ROUNDS randomized server/client/fault
# rounds with global invariant audits (answered-exactly-once metrics
# conservation, depth drain, pool respawn, payload bit-fidelity,
# sentinel/breaker legality) and an identical-seed byte-identical replay
# per round. `#[ignore]`d from plain `cargo test`; a failure prints the
# round seed — rerun with SOAK_SEED=<seed> SOAK_ROUNDS=1 to reproduce.
SOAK_ROUNDS ?=
SOAK_SEED ?=
soak:
	SOAK_ROUNDS=$(SOAK_ROUNDS) SOAK_SEED=$(SOAK_SEED) \
		$(CARGO) test --test soak --release --manifest-path $(MANIFEST) $(FEATFLAGS) \
		-- --ignored --nocapture chaos_soak

# Repo-invariant static analysis (docs/INVARIANTS.md): zero-dep lint
# pass over rust/src — coordinator no-panic, hot-loop alloc bans, seed
# hygiene, plane-width genericity, doc'd failure modes, justified allows.
lint-invariants:
	$(CARGO) run -p xtask -- verify

# Loom model checking of the serving-core concurrency kernels (depth
# tokens, shed latch, supervisor wakeup, sentinel transitions). The loom
# dependency cannot be vendored in the offline container, so it ships
# commented out in rust/Cargo.toml: uncomment `loom = "0.7"` there on a
# networked machine, then run this. The grep guard turns the missing-dep
# compile error into a clear message.
loom:
	@grep -Eq '^loom *=' rust/Cargo.toml || { \
		echo 'make loom: uncomment `loom = "0.7"` under [dependencies] in rust/Cargo.toml first'; \
		echo '(regular dependency, not dev — util/sync.rs re-exports its types under --cfg loom)'; \
		exit 1; }
	LOOM_MAX_PREEMPTIONS=3 RUSTFLAGS="--cfg loom" \
		$(CARGO) test --release --manifest-path $(MANIFEST) --features loom --test loom_models

# Miri on the deterministic kernels (bit planes, FSM chains, decode):
# UB detection under the interpreter. The serving-core thread-pool tests
# are excluded — Miri's scheduler makes real-time chaos assertions
# meaningless; loom + TSan cover that side.
miri:
	$(CARGO) +nightly miri test --manifest-path $(MANIFEST) $(FEATFLAGS) \
		--lib -- sc:: fsm:: smurf::sim

# ThreadSanitizer over the chaos suite (nightly + rust-src). Advisory in
# CI (continue-on-error): TSan needs -Zbuild-std and can false-positive
# on std internals, but a clean run is strong evidence against data races
# the loom models don't reach.
tsan:
	RUSTFLAGS="-Zsanitizer=thread" \
		$(CARGO) +nightly test --test chaos --release --manifest-path $(MANIFEST) \
		-Zbuild-std --target x86_64-unknown-linux-gnu

# Careful-style run: debug assertions + overflow checks on in release
# mode, so the release-only chaos/bench timings also execute every
# debug_assert! in the kernels.
careful:
	RUSTFLAGS="-C debug-assertions=on -C overflow-checks=on" \
		$(CARGO) test --release --manifest-path $(MANIFEST) $(FEATFLAGS)

# Everything a first session on a networked/toolchain machine should
# run, in dependency order: static analysis, the tier-1 gate, lints,
# chaos, the randomized robustness harness, assertion-heavy release
# tests, and bench compilation. (loom / miri / tsan stay manual: they
# need the uncommented dep or nightly.)
verify-all: lint-invariants tier1 clippy chaos fuzz-smoke soak careful bench-build

ci: tier1 clippy lint-invariants fuzz-smoke

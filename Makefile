# SMURF repo targets. The rust crate is dependency-free by default; the
# optional `xla` feature (PJRT runtime) needs deps uncommented in
# rust/Cargo.toml — see that file.

CARGO ?= cargo
MANIFEST := rust/Cargo.toml

.PHONY: build test tier1 clippy bench-json bench ci

build:
	$(CARGO) build --release --manifest-path $(MANIFEST)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST)

# Tier-1 verification gate (see ROADMAP.md): must stay green per PR.
tier1: build test

# Lint gate (CI `lint` job): warnings are errors across every target, so
# an uncompilable or warning-ridden state cannot land again.
clippy:
	$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) -- -D warnings

# Machine-readable perf record: runs the wide-vs-scalar simulation bench
# (which writes BENCH_perf.json in the repo root; override with BENCH_OUT)
# and the serving-stack bench (human-readable log). perf_wide equality-
# gates every wide/scalar pair before timing and panics on divergence, so
# a tripped assertion fails this target with a non-zero exit instead of
# committing numbers from a wrong engine.
bench-json:
	$(CARGO) bench --bench perf_wide --manifest-path $(MANIFEST)
	$(CARGO) bench --bench perf_serve --manifest-path $(MANIFEST)

bench: bench-json

ci: tier1 clippy

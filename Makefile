# SMURF repo targets. The rust crate is dependency-free by default; the
# optional `xla` feature (PJRT runtime) needs deps uncommented in
# rust/Cargo.toml — see that file.
#
# FEATURES selects optional crate features for build/test/clippy/bench,
# e.g. `make tier1 FEATURES=wide512` runs the suite with 512-lane bit
# planes (CI exercises both feature sets).

CARGO ?= cargo
MANIFEST := rust/Cargo.toml
FEATURES ?=
FEATFLAGS := $(if $(FEATURES),--features $(FEATURES),)

.PHONY: build test tier1 chaos clippy bench-json bench bench-build fault-sweep ci

build:
	$(CARGO) build --release --manifest-path $(MANIFEST) $(FEATFLAGS)

test:
	$(CARGO) test -q --manifest-path $(MANIFEST) $(FEATFLAGS)

# Tier-1 verification gate (see ROADMAP.md): must stay green per PR.
tier1: build test

# Chaos suite: fault-injected serving-core tests (worker panics, stalls,
# overload shedding, deadline expiry, shutdown drains). Run in release —
# the tests drive real worker pools under timing assertions.
chaos:
	$(CARGO) test --test chaos --release --manifest-path $(MANIFEST) $(FEATFLAGS)

# Lint gate (CI `lint` job): warnings are errors across every target, so
# an uncompilable or warning-ridden state cannot land again.
clippy:
	$(CARGO) clippy --all-targets --manifest-path $(MANIFEST) $(FEATFLAGS) -- -D warnings

# Compile every bench target without running it (CI): bench-only code
# cannot silently rot between perf sessions.
bench-build:
	$(CARGO) bench --no-run --manifest-path $(MANIFEST) $(FEATFLAGS)

# Machine-readable perf record: runs the wide-vs-scalar simulation bench
# (which writes BENCH_perf.json in the repo root; override with BENCH_OUT)
# and the serving-stack bench (human-readable log). perf_wide equality-
# gates every wide/scalar pair before timing and panics on divergence, so
# a tripped assertion fails this target with a non-zero exit instead of
# committing numbers from a wrong engine.
bench-json:
	$(CARGO) bench --bench perf_wide --manifest-path $(MANIFEST) $(FEATFLAGS)
	$(CARGO) bench --bench perf_serve --manifest-path $(MANIFEST) $(FEATFLAGS)

bench: bench-json

# Fault-injection sweep (ISSUE 7): zero-rate equality gates (armed
# all-zero fault plans and rate-0 TMR must be bit-identical to the clean
# engine at every compiled plane width — a divergence aborts with a
# non-zero exit before anything is recorded), then per-site MAE-vs-flip-
# rate curves raw vs TMR and hook-overhead timings, written to
# BENCH_fault_sweep.json (override with BENCH_FAULT_OUT). The TMR-gain
# and overhead floors are deferred and skippable with BENCH_NO_ENFORCE=1;
# the equality gates never are.
fault-sweep:
	$(CARGO) bench --bench fault_sweep --manifest-path $(MANIFEST) $(FEATFLAGS)

ci: tier1 clippy

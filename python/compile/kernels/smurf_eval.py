"""L1 Pallas kernels: batched SMURF evaluation.

The hot compute of the serving path: for a batch of input probability
vectors, evaluate the closed-form steady-state readout (paper Eq. 21)

    y_b = sum_s P_s(x_b) * w_s
        = pi(x2_b) @ W @ pi(x1_b)          (M = 2, factored joint)

expressed as two small matmuls per block so the contraction maps onto the
MXU systolic array on a real TPU. BlockSpec tiles the batch dimension
into VMEM-sized blocks (BLOCK_B × (N + N + N²) f32 ≪ 16 MiB).

Pallas runs with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO with
identical arithmetic (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_STATES = 4
BLOCK_B = 256


def _steady4(p):
    """Chain steady state for N=4, stable form (matches ref.steady_state)."""
    q = 1.0 - p
    # Unrolled powers (cheaper than pow for N=4; fuses into FMAs).
    p2 = p * p
    q2 = q * q
    w0 = q2 * q
    w1 = p * q2
    w2 = p2 * q
    w3 = p2 * p
    z = w0 + w1 + w2 + w3
    inv = 1.0 / z
    return jnp.stack([w0 * inv, w1 * inv, w2 * inv, w3 * inv], axis=-1)


def _smurf_eval_kernel(x_ref, w_ref, y_ref):
    """One batch block: y = (pi(x2) @ W) · pi(x1), summed over states."""
    x = x_ref[...]  # (BLOCK_B, 2)
    w = w_ref[...]  # (4, 4), w[i2, i1]
    m1 = _steady4(x[:, 0])  # (BLOCK_B, 4)
    m2 = _steady4(x[:, 1])  # (BLOCK_B, 4)
    # Two-matmul contraction: (B,4)@(4,4) -> (B,4), then row-dot.
    t = jnp.dot(m2, w, preferred_element_type=jnp.float32)
    y_ref[...] = jnp.sum(t * m1, axis=-1)


@functools.partial(jax.jit, static_argnames=())
def smurf_eval(x, w):
    """Batched bivariate SMURF evaluation.

    Args:
      x: (B, 2) f32 probabilities, B divisible by BLOCK_B (pad upstream).
      w: (4, 4) f32 coefficient table.

    Returns:
      (B,) f32 outputs.
    """
    b = x.shape[0]
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    grid = (b // BLOCK_B,)
    return pl.pallas_call(
        _smurf_eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, 2), lambda i: (i, 0)),
            pl.BlockSpec((N_STATES, N_STATES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(x, w)


def _smurf_act_kernel(v_ref, w_ref, y_ref, *, r):
    """Bipolar SMURF activation block: y = 2·(pi(P) · w) − 1."""
    v = v_ref[...]
    w = w_ref[...]  # (4,)
    p = (jnp.clip(v / r, -1.0, 1.0) + 1.0) * 0.5
    pi = _steady4(p)  # (..., 4)
    y_ref[...] = 2.0 * jnp.sum(pi * w, axis=-1) - 1.0


def _smurf_act_pallas(v, w, r):
    b, f = v.shape
    kernel = functools.partial(_smurf_act_kernel, r=r)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, f), lambda i: (0, 0)),
            pl.BlockSpec((N_STATES,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b, f), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.float32),
        interpret=True,
    )(v, w)


def _smurf_act_ref(v, w, r):
    """Pure-jnp twin of the kernel (used for the VJP)."""
    p = (jnp.clip(v / r, -1.0, 1.0) + 1.0) * 0.5
    pi = _steady4(p)
    return 2.0 * jnp.sum(pi * w, axis=-1) - 1.0


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def smurf_act(v, w, r=2.0):
    """Batched univariate SMURF activation (used inside the LeNet model).

    Forward runs the Pallas kernel; the backward pass (pallas_call has no
    reverse-mode rule) differentiates the mathematically-identical pure
    jnp expression — the L2 trainer trains *through* the SMURF
    nonlinearity this way.

    Args:
      v: (B, F) f32 pre-activations.
      w: (4,) f32 coefficient table of the univariate tanh SMURF.
      r: clamp half-range (= N/2 for the Brown–Card-consistent config).

    Returns:
      (B, F) f32 activations in [-1, 1].
    """
    return _smurf_act_pallas(v, w, r)


def _smurf_act_fwd(v, w, r):
    return _smurf_act_pallas(v, w, r), (v, w)


def _smurf_act_bwd(r, res, g):
    v, w = res
    _, vjp = jax.vjp(lambda vv, ww: _smurf_act_ref(vv, ww, r), v, w)
    return vjp(g)


smurf_act.defvjp(_smurf_act_fwd, _smurf_act_bwd)

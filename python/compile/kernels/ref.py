"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Everything here mirrors the rust analytic evaluator
(rust/src/smurf/analytic.rs): the chain-FSM steady state of paper Eq. 4
in its numerically-stable form, the joint factorization, and the Eq. 21
readout.
"""

import jax.numpy as jnp


def steady_state(n: int, p):
    """Steady-state distribution of an n-state chain FSM at Bernoulli(p).

    pi_i = p^i (1-p)^(n-1-i) / sum_k p^k (1-p)^(n-1-k)  — stable on [0,1].

    Args:
      n: number of states.
      p: array of shape (...,) of probabilities in [0, 1].

    Returns:
      array of shape (..., n).
    """
    p = jnp.asarray(p)
    q = 1.0 - p
    i = jnp.arange(n)
    w = p[..., None] ** i * q[..., None] ** (n - 1 - i)
    return w / jnp.sum(w, axis=-1, keepdims=True)


def smurf_eval_ref(x, w):
    """Batched bivariate SMURF analytic evaluation (Eq. 21), M=2, N=4.

    Args:
      x: (B, 2) input probabilities.
      w: (4, 4) coefficient table, w[i2, i1].

    Returns:
      (B,) outputs  y_b = sum_{i2,i1} pi(x2)[i2] pi(x1)[i1] w[i2,i1].
    """
    m1 = steady_state(4, x[:, 0])  # (B, 4) marginal of variable 1 (i1)
    m2 = steady_state(4, x[:, 1])  # (B, 4) marginal of variable 2 (i2)
    return jnp.einsum("bi,ij,bj->b", m2, w, m1)


def smurf_act_ref(v, w, r):
    """Batched univariate SMURF activation in the bipolar convention.

    v in [-inf, inf] clamps to [-r, r]; P = (v/r + 1)/2; the N=4 SMURF
    with coefficients w (4,) produces P_y; decode y = 2 P_y - 1.

    Mirrors rust/src/nn/sc_ops.rs::SmurfActivation::eval_analytic.
    """
    p = (jnp.clip(v / r, -1.0, 1.0) + 1.0) / 2.0
    pi = steady_state(4, p)  # (..., 4)
    p_y = jnp.sum(pi * w, axis=-1)
    return 2.0 * p_y - 1.0

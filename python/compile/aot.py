"""AOT export: lower the L2/L1 computations to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_proto().serialize()``)
is the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction
ids which the rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (written to ../artifacts/ by default):

- ``smurf_eval.hlo.txt``        — L1 Pallas batched SMURF evaluator,
  (1024, 2) probabilities + (4, 4) table → (1024,) outputs.
- ``lenet_infer.hlo.txt``       — vanilla LeNet-5 inference, trained
  weights baked in, (32, 1, 28, 28) → (32, 10) logits.
- ``lenet_smurf_infer.hlo.txt`` — LeNet-5 with the Pallas SMURF
  activation (CNN/SMURF inference path).
- ``lenet_weights.json``        — trained weights for the rust SC-CNN.
- ``train_log.json``            — loss curves + test accuracy for
  EXPERIMENTS.md.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train
from .kernels.smurf_eval import smurf_eval


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path).

    print_large_constants=True matters: the default print elides big
    literals as ``constant({...})``, which the rust-side text parser
    cannot reconstruct — baked model weights must round-trip.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def export_smurf_eval(out_dir, batch=1024):
    spec_x = jax.ShapeDtypeStruct((batch, 2), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda x, w: (smurf_eval(x, w),)).lower(spec_x, spec_w)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, "smurf_eval.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def export_lenet(out_dir, params, activation, name, batch=32):
    spec = jax.ShapeDtypeStruct((batch, 1, 28, 28), jnp.float32)
    fwd = functools.partial(model.forward, activation=activation)
    # Bake trained weights as constants: the serving binary only feeds
    # images (closure over params).
    lowered = jax.jit(lambda x: (fwd(params, x),)).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--train-samples", type=int, default=4000)
    ap.add_argument("--skip-train", action="store_true",
                    help="only export the smurf_eval kernel")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    export_smurf_eval(args.out_dir)
    if args.skip_train:
        return

    # Vanilla training (Table IV column 1) …
    params_v, hist_v = train.train(
        n_train=args.train_samples, epochs=args.epochs, activation="tanh"
    )
    # … and SMURF-surrogate training (Table IV column 3): same data/seed.
    params_s, hist_s = train.train(
        n_train=args.train_samples, epochs=args.epochs, activation="smurf"
    )

    export_lenet(args.out_dir, params_v, "tanh", "lenet_infer.hlo.txt")
    export_lenet(args.out_dir, params_s, "smurf", "lenet_smurf_infer.hlo.txt")

    wpath = os.path.join(args.out_dir, "lenet_weights.json")
    with open(wpath, "w") as f:
        f.write(train.params_to_json(params_s))
    print(f"wrote {wpath}")

    lpath = os.path.join(args.out_dir, "train_log.json")
    with open(lpath, "w") as f:
        json.dump({"vanilla": hist_v, "smurf": hist_s}, f, indent=1)
    print(f"wrote {lpath}")


if __name__ == "__main__":
    main()

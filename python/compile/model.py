"""L2: LeNet-5 in JAX with SMURF-surrogate activations.

The network of paper §IV-B (Table V): conv1 6@5×5 pad2 → act → avgpool2 →
conv2 16@5×5 → act → avgpool2 → fc 400→120 → act → fc 120→84 → act →
fc 84→10. The activation is pluggable:

- ``"tanh"``   — vanilla CNN.
- ``"smurf"``  — the L1 Pallas SMURF activation kernel
  (kernels.smurf_eval.smurf_act): the closed-form Eq. 21 expectation of
  the 4-state bipolar tanh SMURF. It is exactly what the SC hardware
  computes in expectation, and it is differentiable, so training through
  it produces weights adapted to the SMURF nonlinearity (the paper's
  CNN/SMURF training setup).

Layout is NCHW throughout, matching the rust inference engine.
"""

import jax
import jax.numpy as jnp

from .kernels.smurf_eval import smurf_act

# The 4-state bipolar tanh SMURF coefficient table. Synthesis (rust
# synth/ or the QP below) recovers the Brown–Card labelling; the exact
# QP optimum at k = N/2 = 2 deviates from binary labels by < 0.03.
SMURF_TANH_W4 = jnp.array([0.02741, 0.0, 1.0, 0.97259], dtype=jnp.float32)
SMURF_ACT_RANGE = 2.0


def init_params(key):
    """Kaiming-uniform LeNet-5 parameters (NCHW conv layout)."""
    shapes = {
        "conv1_w": (6, 1, 5, 5),
        "conv2_w": (16, 6, 5, 5),
        "fc1_w": (120, 400),
        "fc2_w": (84, 120),
        "fc3_w": (10, 84),
    }
    biases = {"conv1_b": 6, "conv2_b": 16, "fc1_b": 120, "fc2_b": 84, "fc3_b": 10}
    params = {}
    for name, shape in shapes.items():
        key, sub = jax.random.split(key)
        fan_in = int(jnp.prod(jnp.array(shape[1:])))
        bound = (6.0 / fan_in) ** 0.5
        params[name] = jax.random.uniform(sub, shape, jnp.float32, -bound, bound)
    for name, n in biases.items():
        params[name] = jnp.zeros((n,), jnp.float32)
    return params


def _conv(x, w, b, pad):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b[None, :, None, None]


def _avgpool2(x):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    ) * 0.25


def _activate(v, kind):
    if kind == "tanh":
        return jnp.tanh(v)
    if kind == "smurf":
        # The Pallas kernel is rank-2 (B, F): flatten feature dims.
        shape = v.shape
        flat = v.reshape(shape[0], -1)
        y = smurf_act(flat, SMURF_TANH_W4, r=SMURF_ACT_RANGE)
        return y.reshape(shape)
    raise ValueError(f"unknown activation {kind}")


def forward(params, x, activation="tanh"):
    """LeNet-5 forward pass.

    Args:
      params: dict from init_params.
      x: (B, 1, 28, 28) f32 images in [0, 1].
      activation: "tanh" | "smurf".

    Returns:
      (B, 10) logits.
    """
    h = _activate(_conv(x, params["conv1_w"], params["conv1_b"], 2), activation)
    h = _avgpool2(h)
    h = _activate(_conv(h, params["conv2_w"], params["conv2_b"], 0), activation)
    h = _avgpool2(h)
    h = h.reshape(h.shape[0], -1)  # (B, 400)
    h = _activate(h @ params["fc1_w"].T + params["fc1_b"], activation)
    h = _activate(h @ params["fc2_w"].T + params["fc2_b"], activation)
    return h @ params["fc3_w"].T + params["fc3_b"]


def loss_fn(params, x, labels, activation="tanh"):
    """Mean softmax cross-entropy."""
    logits = forward(params, x, activation)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def accuracy(params, x, labels, activation="tanh", batch=200):
    """Full-dataset accuracy in minibatches."""
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = forward(params, x[i : i + batch], activation)
        correct += int(jnp.sum(jnp.argmax(logits, axis=1) == labels[i : i + batch]))
    return correct / x.shape[0]

"""L2 training: LeNet-5 on the synthetic corpus, vanilla and SMURF-activated.

Run by aot.py (or standalone: ``python -m compile.train``). Produces the
weight sets the AOT exports and the rust SC-CNN consume, plus a training
log for EXPERIMENTS.md.
"""

import json
import time

import jax
import jax.numpy as jnp

from . import data, model


def sgd_momentum(params, grads, vel, lr, mu):
    new_vel = {}
    new_params = {}
    for k in params:
        v = mu * vel[k] - lr * grads[k]
        new_vel[k] = v
        new_params[k] = params[k] + v
    return new_params, new_vel


def train(
    n_train=4000,
    n_test=1000,
    epochs=6,
    batch=64,
    lr=0.05,
    momentum=0.9,
    activation="tanh",
    seed=0,
    log=print,
):
    """Train and return (params, history dict)."""
    x_train, y_train = data.generate(n_train, seed=42)
    x_test, y_test = data.generate(n_test, seed=43)
    params = model.init_params(jax.random.PRNGKey(seed))
    vel = {k: jnp.zeros_like(v) for k, v in params.items()}

    @jax.jit
    def step(params, vel, xb, yb):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, xb, yb, activation)
        params, vel = sgd_momentum(params, grads, vel, lr, momentum)
        return params, vel, loss

    rng = jax.random.PRNGKey(seed + 1)
    history = {"activation": activation, "epoch_loss": [], "epoch_time_s": []}
    n = x_train.shape[0]
    for epoch in range(epochs):
        t0 = time.time()
        rng, sub = jax.random.split(rng)
        order = jax.random.permutation(sub, n)
        total = 0.0
        batches = 0
        for i in range(0, n - batch + 1, batch):
            idx = order[i : i + batch]
            params, vel, loss = step(params, vel, x_train[idx], y_train[idx])
            total += float(loss)
            batches += 1
        dt = time.time() - t0
        history["epoch_loss"].append(total / batches)
        history["epoch_time_s"].append(dt)
        log(f"[{activation}] epoch {epoch}: loss {total / batches:.4f} ({dt:.1f}s)")
    history["test_accuracy"] = model.accuracy(params, x_test, y_test, activation)
    log(f"[{activation}] test accuracy: {history['test_accuracy'] * 100:.2f}%")
    return params, history


def params_to_json(params):
    """Serialize weights in the rust LeNet::from_json format."""
    return json.dumps({k: [float(x) for x in jnp.ravel(v)] for k, v in params.items()})

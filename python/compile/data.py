"""Synthetic MNIST-shaped digit corpus (numpy port of rust/src/data/synth_mnist.rs).

Same design: stroke-glyph polylines per class, per-sample affine +
stroke-width jitter, pixel noise. Not bit-identical to the rust
generator (different PRNG), but statistically equivalent — both sides
train to the same accuracy regime, which is what Table IV compares.
"""

import numpy as np

GLYPHS = {
    0: [[(0.5, 0.15), (0.75, 0.3), (0.75, 0.7), (0.5, 0.85), (0.25, 0.7), (0.25, 0.3), (0.5, 0.15)]],
    1: [[(0.35, 0.3), (0.55, 0.15), (0.55, 0.85)]],
    2: [[(0.27, 0.3), (0.45, 0.15), (0.7, 0.25), (0.68, 0.45), (0.3, 0.8), (0.3, 0.85), (0.75, 0.85)]],
    3: [[(0.3, 0.2), (0.6, 0.15), (0.72, 0.3), (0.5, 0.48), (0.72, 0.65), (0.6, 0.85), (0.28, 0.8)]],
    4: [[(0.62, 0.85), (0.62, 0.15), (0.25, 0.6), (0.78, 0.6)]],
    5: [[(0.7, 0.15), (0.32, 0.15), (0.3, 0.45), (0.6, 0.42), (0.73, 0.6), (0.6, 0.85), (0.28, 0.8)]],
    6: [[(0.65, 0.15), (0.35, 0.4), (0.27, 0.65), (0.45, 0.85), (0.7, 0.72), (0.62, 0.52), (0.3, 0.58)]],
    7: [[(0.25, 0.15), (0.75, 0.15), (0.45, 0.85)]],
    8: [[(0.5, 0.48), (0.3, 0.32), (0.5, 0.15), (0.7, 0.32), (0.5, 0.48), (0.28, 0.68), (0.5, 0.85), (0.72, 0.68), (0.5, 0.48)]],
    9: [[(0.68, 0.42), (0.4, 0.48), (0.3, 0.28), (0.5, 0.15), (0.7, 0.25), (0.68, 0.42), (0.6, 0.85)]],
}


def _draw_segment(img, a, b, width):
    ax, ay = a[0] * 28.0, a[1] * 28.0
    bx, by = b[0] * 28.0, b[1] * 28.0
    w = width * 28.0
    dx, dy = bx - ax, by - ay
    len2 = max(dx * dx + dy * dy, 1e-12)
    lo_x = int(max(min(ax, bx) - w - 1, 0))
    hi_x = int(min(max(ax, bx) + w + 1, 27))
    lo_y = int(max(min(ay, by) - w - 1, 0))
    hi_y = int(min(max(ay, by) + w + 1, 27))
    if hi_x < lo_x or hi_y < lo_y:
        return
    ys, xs = np.mgrid[lo_y : hi_y + 1, lo_x : hi_x + 1]
    cx, cy = xs + 0.5, ys + 0.5
    t = np.clip(((cx - ax) * dx + (cy - ay) * dy) / len2, 0.0, 1.0)
    qx, qy = ax + t * dx, ay + t * dy
    dist = np.sqrt((cx - qx) ** 2 + (cy - qy) ** 2)
    v = np.clip(1.0 - np.maximum(dist - w, 0.0) / 1.2, 0.0, 1.0)
    region = img[lo_y : hi_y + 1, lo_x : hi_x + 1]
    np.maximum(region, v, out=region)


def render(digit, rng):
    """Render one jittered 28x28 sample of `digit` in [0,1]."""
    img = np.zeros((28, 28), dtype=np.float64)
    angle = rng.uniform(-0.25, 0.25)
    scale = rng.uniform(0.82, 1.05)
    dx = rng.uniform(-0.08, 0.08)
    dy = rng.uniform(-0.08, 0.08)
    shear = rng.uniform(-0.12, 0.12)
    width = rng.uniform(0.035, 0.055)
    sin, cos = np.sin(angle), np.cos(angle)

    def xform(p):
        x0, y0 = p[0] - 0.5, p[1] - 0.5
        x1 = x0 + shear * y0
        x2 = cos * x1 - sin * y0
        y2 = sin * x1 + cos * y0
        return (scale * x2 + 0.5 + dx, scale * y2 + 0.5 + dy)

    for stroke in GLYPHS[digit]:
        pts = [xform(p) for p in stroke]
        for a, b in zip(pts, pts[1:]):
            _draw_segment(img, a, b, width)
    img = np.clip(img + rng.normal(0.0, 0.04, img.shape), 0.0, 1.0)
    return img.astype(np.float32)


def generate(n, seed):
    """Balanced dataset: images (n, 1, 28, 28) f32, labels (n,) int32."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, 1, 28, 28), dtype=np.float32)
    labels = np.zeros((n,), dtype=np.int32)
    for i in range(n):
        d = i % 10
        images[i, 0] = render(d, rng)
        labels[i] = d
    order = rng.permutation(n)
    return images[order], labels[order]

"""AOT lowering tests: the compile path produces loadable HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import to_hlo_text
from compile.kernels.smurf_eval import BLOCK_B, smurf_eval


def test_smurf_eval_lowers_to_hlo_text():
    spec_x = jax.ShapeDtypeStruct((BLOCK_B, 2), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda x, w: (smurf_eval(x, w),)).lower(spec_x, spec_w)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    # interpret=True must not leave Mosaic custom-calls behind.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_hlo_text_parses_back():
    # The text must parse back into an HLO module with the expected entry
    # signature. (Full execute-from-text round-trip is exercised on the
    # rust side: rust/src/runtime/mod.rs::loads_and_runs_artifact_if_present
    # and examples/quickstart.rs — the consumer of these artifacts.)
    from jax._src.lib import xla_client as xc

    spec_x = jax.ShapeDtypeStruct((BLOCK_B, 2), jnp.float32)
    spec_w = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda x, w: (smurf_eval(x, w),)).lower(spec_x, spec_w)
    text = to_hlo_text(lowered)
    mod = xc._xla.hlo_module_from_text(text)
    sig = mod.to_string()
    assert f"f32[{BLOCK_B},2]" in sig
    assert "f32[4,4]" in sig
    assert "ENTRY" in sig


def test_kernel_output_values_match_through_lowering():
    # jit-compiled (the exported computation) vs eager both equal the ref.
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.uniform(0, 1, (BLOCK_B, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (4, 4)), jnp.float32)
    jitted = jax.jit(lambda x, w: smurf_eval(x, w))
    np.testing.assert_allclose(
        np.asarray(jitted(x, w)), np.asarray(smurf_eval(x, w)), atol=1e-6
    )

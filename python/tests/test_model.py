"""L2 model tests: shapes, loss, gradients, quick training smoke, data."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import data, model, train


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((4, 1, 28, 28), jnp.float32)
    for act in ("tanh", "smurf"):
        logits = model.forward(params, x, act)
        assert logits.shape == (4, 10), act


def test_loss_finite_and_grads_flow():
    params = model.init_params(jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(0).uniform(0, 1, (8, 1, 28, 28)), jnp.float32)
    y = jnp.asarray(np.arange(8) % 10, jnp.int32)
    for act in ("tanh", "smurf"):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, x, y, act)
        assert np.isfinite(float(loss)), act
        for k, g in grads.items():
            assert np.all(np.isfinite(np.asarray(g))), (act, k)
        # conv1 must receive gradient through 4 activation layers.
        assert float(jnp.max(jnp.abs(grads["conv1_w"]))) > 0, act


def test_smurf_and_tanh_forward_agree_closely():
    # The SMURF surrogate is a tanh approximation (MAE < 0.01 per unit);
    # logits should be close for moderate weights.
    params = model.init_params(jax.random.PRNGKey(2))
    x = jnp.asarray(np.random.default_rng(1).uniform(0, 1, (4, 1, 28, 28)), jnp.float32)
    lt = np.asarray(model.forward(params, x, "tanh"))
    ls = np.asarray(model.forward(params, x, "smurf"))
    assert np.max(np.abs(lt - ls)) < 0.5, np.max(np.abs(lt - ls))
    # And the argmax rarely moves on random nets.
    assert (np.argmax(lt, 1) == np.argmax(ls, 1)).mean() >= 0.75


def test_data_generator_balanced_and_bounded():
    x, y = data.generate(50, seed=5)
    assert x.shape == (50, 1, 28, 28)
    assert x.min() >= 0.0 and x.max() <= 1.0
    counts = np.bincount(y, minlength=10)
    assert counts.min() == 5 and counts.max() == 5


def test_one_epoch_reduces_loss():
    _, hist = train.train(
        n_train=300, n_test=100, epochs=2, batch=32, activation="tanh", log=lambda *_: None
    )
    assert hist["epoch_loss"][-1] < hist["epoch_loss"][0]
    assert 0.0 <= hist["test_accuracy"] <= 1.0


def test_params_json_roundtrip_format():
    params = model.init_params(jax.random.PRNGKey(3))
    import json

    j = json.loads(train.params_to_json(params))
    assert set(j) == {
        "conv1_w", "conv1_b", "conv2_w", "conv2_b",
        "fc1_w", "fc1_b", "fc2_w", "fc2_b", "fc3_w", "fc3_b",
    }
    assert len(j["conv1_w"]) == 6 * 1 * 5 * 5
    assert len(j["fc3_b"]) == 10

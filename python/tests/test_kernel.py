"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

The core correctness signal of the compile path — hypothesis sweeps
shapes and input distributions, assert_allclose against ref.py.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.smurf_eval import BLOCK_B, smurf_act, smurf_eval

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# steady_state oracle sanity
# ---------------------------------------------------------------------------


def test_steady_state_sums_to_one():
    p = jnp.linspace(0.0, 1.0, 33)
    pi = ref.steady_state(4, p)
    np.testing.assert_allclose(np.asarray(jnp.sum(pi, axis=-1)), 1.0, atol=1e-6)


def test_steady_state_endpoints_degenerate():
    pi = np.asarray(ref.steady_state(4, jnp.array([0.0, 1.0])))
    np.testing.assert_allclose(pi[0], [1, 0, 0, 0], atol=1e-7)
    np.testing.assert_allclose(pi[1], [0, 0, 0, 1], atol=1e-7)


@given(st.integers(2, 8), st.floats(0.0, 1.0))
@settings(max_examples=50, deadline=None)
def test_steady_state_detailed_balance(n, p):
    pi = np.asarray(ref.steady_state(n, jnp.float32(p)), dtype=np.float64)
    # pi_{i+1} (1-p) == pi_i p  (Eq. 2)
    for i in range(n - 1):
        lhs = pi[i + 1] * (1.0 - p)
        rhs = pi[i] * p
        assert abs(lhs - rhs) < 1e-5, (n, p, i)


# ---------------------------------------------------------------------------
# smurf_eval (bivariate) vs oracle
# ---------------------------------------------------------------------------


def test_smurf_eval_matches_ref_fixed_batch():
    x = jnp.asarray(RNG.uniform(0, 1, (BLOCK_B * 4, 2)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0, 1, (4, 4)), jnp.float32)
    got = smurf_eval(x, w)
    want = ref.smurf_eval_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@given(
    st.integers(1, 4),  # batch blocks
    st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_smurf_eval_matches_ref_hypothesis(blocks, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.uniform(0, 1, (BLOCK_B * blocks, 2)), jnp.float32)
    w = jnp.asarray(rng.uniform(0, 1, (4, 4)), jnp.float32)
    got = np.asarray(smurf_eval(x, w))
    want = np.asarray(ref.smurf_eval_ref(x, w))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_smurf_eval_output_is_convex_combination():
    x = jnp.asarray(RNG.uniform(0, 1, (BLOCK_B, 2)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.2, 0.8, (4, 4)), jnp.float32)
    y = np.asarray(smurf_eval(x, w))
    assert y.min() >= float(jnp.min(w)) - 1e-5
    assert y.max() <= float(jnp.max(w)) + 1e-5


def test_smurf_eval_corner_readout():
    # At (1,1) both chains saturate: y = w[3,3].
    x = jnp.tile(jnp.array([[1.0, 1.0]], jnp.float32), (BLOCK_B, 1))
    w = jnp.asarray(RNG.uniform(0, 1, (4, 4)), jnp.float32)
    y = np.asarray(smurf_eval(x, w))
    np.testing.assert_allclose(y, float(w[3, 3]), atol=1e-6)


def test_smurf_eval_rejects_ragged_batch():
    x = jnp.zeros((BLOCK_B + 1, 2), jnp.float32)
    w = jnp.zeros((4, 4), jnp.float32)
    with pytest.raises(AssertionError):
        smurf_eval(x, w)


# ---------------------------------------------------------------------------
# smurf_act (univariate activation) vs oracle and tanh
# ---------------------------------------------------------------------------

# QP-optimal 4-state bipolar tanh table (max pointwise error < 0.019 on
# the clamp region; the binary Brown–Card labels are the nearby vertex).
W4 = jnp.array([0.02741, 0.0, 1.0, 0.97259], jnp.float32)


def test_smurf_act_matches_ref():
    v = jnp.asarray(RNG.normal(0, 2, (8, 50)), jnp.float32)
    got = np.asarray(smurf_act(v, W4, r=2.0))
    want = np.asarray(ref.smurf_act_ref(v, W4, 2.0))
    np.testing.assert_allclose(got, want, atol=1e-5)


@given(st.floats(-1.9, 1.9), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_smurf_act_tracks_tanh(v, salt):
    vv = jnp.full((1, 8), jnp.float32(v + salt * 0.0))
    y = float(np.asarray(smurf_act(vv, W4, r=2.0))[0, 0])
    assert abs(y - np.tanh(v)) < 0.025, (v, y, np.tanh(v))


def test_smurf_act_odd_symmetry():
    v = jnp.asarray([[0.5, 1.0, 1.5]], jnp.float32)
    y_pos = np.asarray(smurf_act(v, W4, r=2.0))
    y_neg = np.asarray(smurf_act(-v, W4, r=2.0))
    np.testing.assert_allclose(y_pos, -y_neg, atol=1e-6)


def test_smurf_act_differentiable():
    # The L2 trainer differentiates through the kernel.
    def scalar(v):
        return jnp.sum(smurf_act(v, W4, r=2.0))

    g = jax.grad(scalar)(jnp.full((2, 3), 0.5, jnp.float32))
    assert np.all(np.isfinite(np.asarray(g)))
    assert float(jnp.min(g)) > 0.0, "tanh-like slope must be positive at 0.5"

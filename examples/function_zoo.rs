//! Function zoo: synthesize every registered target (univariate through
//! trivariate), print accuracy at the paper's stream lengths, and compare
//! against the Bernstein, Taylor, LUT and CORDIC baselines on a shared
//! accuracy budget — the §IV-A experiment generalized to the whole
//! function library.
//!
//! Run: `cargo run --release --example function_zoo`

use smurf::baselines::bernstein::BernsteinSc;
use smurf::baselines::lut::Lut;
use smurf::baselines::taylor::TaylorPoly;
use smurf::prelude::*;
use smurf::util::prng::Pcg;

fn bitlevel_mae(approx: &SmurfApproximator, len: usize, trials: usize) -> f64 {
    // MAE over a uniform grid with Monte-Carlo trials per point.
    let m = approx.config().num_vars();
    let grid = match m {
        1 => 33,
        2 => 9,
        _ => 5,
    };
    let mut idx = vec![0usize; m];
    let mut total = 0.0;
    let mut count = 0;
    let sim = approx.simulator();
    loop {
        let p: Vec<f64> = idx.iter().map(|&i| i as f64 / (grid - 1) as f64).collect();
        let target = approx.eval_analytic(&p);
        total += sim.abs_error(&p, target, len, trials, 42);
        count += 1;
        let mut j = 0;
        loop {
            idx[j] += 1;
            if idx[j] < grid {
                break;
            }
            idx[j] = 0;
            j += 1;
            if j == m {
                let _ = count;
                return total / count as f64;
            }
        }
    }
}

fn main() {
    println!("=== SMURF function zoo (N=4 per variable) ===\n");
    println!(
        "{:<12} {:>5} {:>10} {:>10} {:>10}",
        "function", "M", "analytic", "hw@64", "hw@256"
    );
    for f in functions::registry() {
        let cfg = SmurfConfig::uniform(f.arity(), 4);
        let approx = SmurfApproximator::synthesize(&cfg, &f, 64);
        let e64 = bitlevel_mae(&approx, 64, 8);
        let e256 = bitlevel_mae(&approx, 256, 8);
        println!(
            "{:<12} {:>5} {:>10.4} {:>10.4} {:>10.4}",
            f.name(),
            f.arity(),
            approx.synth_mae,
            e64,
            e256
        );
    }

    // Baseline shoot-out on the Euclidean distance at equalized accuracy.
    println!("\n=== baselines on euclidean2 (accuracy-equalized, §IV-C) ===\n");
    let f = functions::euclidean2();
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &f, 256);
    println!("SMURF      : analytic MAE {:.4} with 16 coefficients", approx.synth_mae);

    let taylor = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
    println!(
        "Taylor-3   : float MAE {:.4}, 16-bit fixed MAE {:.4}, {} muls/{} adds",
        taylor.mae_vs(&f, 33, None),
        taylor.mae_vs(&f, 33, Some(14)),
        taylor.mul_count(),
        taylor.add_count()
    );

    let lut = Lut::size_for_accuracy(&f, 0.015, 16).expect("LUT sizing");
    println!(
        "LUT        : MAE {:.4} with {} entries ({} bits of storage)",
        lut.mae_vs(&f, 65),
        lut.entries(),
        lut.storage_bits()
    );

    // Bernstein handles univariate only — use the tanh target.
    let tanh = functions::tanh_bipolar(2.0);
    let bern = BernsteinSc::synthesize(&tanh, 6);
    println!(
        "Bernstein-6: tanh MAE {:.4} with {} coefficients (univariate only)",
        bern.mae_vs(&tanh, 101),
        bern.coeffs.len()
    );

    // CORDIC: iterative, exact-ish — show iteration/accuracy trade.
    let mut rng = Pcg::new(1);
    let mut worst: f64 = 0.0;
    for _ in 0..1000 {
        let (x1, x2) = (rng.uniform(), rng.uniform());
        let (r, _) = smurf::baselines::cordic::vectoring(x1.max(1e-9), x2, 16);
        worst = worst.max((r - (x1 * x1 + x2 * x2).sqrt()).abs());
    }
    println!("CORDIC-16  : worst-case |err| {worst:.2e} (16 iterations, vectoring mode)");
    println!("\nfunction_zoo OK");
}

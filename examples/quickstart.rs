//! Quickstart: synthesize a SMURF, evaluate it three ways, and (if
//! `make artifacts` has run) execute the AOT-compiled XLA kernel — the
//! full L3→L1 stack in one file.
//!
//! Run: `cargo run --release --example quickstart`

use smurf::prelude::*;
use smurf::runtime::{default_artifacts_dir, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. Synthesize the paper's flagship example: the bivariate Euclidean
    //    distance on a 2-variable, 4-state-per-variable SMURF (§III-B).
    let cfg = SmurfConfig::uniform(2, 4);
    let f = functions::euclidean2();
    let approx = SmurfApproximator::synthesize(&cfg, &f, 64);
    println!("synthesized {} on {}", approx.name(), approx.config());
    println!("analytic MAE from synthesis: {:.5}\n", approx.synth_mae);

    // 2. Print the coefficient table (compare with paper Table I — see
    //    EXPERIMENTS.md for why the published table differs).
    println!("coefficient table w_t (t = i1 + 4*i2):");
    for (t, w) in approx.coefficients().iter().enumerate() {
        print!("  w_{t:<2} = {w:.4}");
        if (t + 1) % 4 == 0 {
            println!();
        }
    }

    // 3. Evaluate a few points: exact target, analytic (Eq. 21), and the
    //    cycle-accurate bit-level hardware simulation at 64/256 bits.
    println!("\n{:>12} {:>9} {:>9} {:>9} {:>9}", "input", "target", "analytic", "hw@64", "hw@256");
    for (x1, x2) in [(0.3, 0.4), (0.6, 0.8), (0.1, 0.9), (0.5, 0.5)] {
        let p = [x1, x2];
        println!(
            "{:>12} {:>9.4} {:>9.4} {:>9.4} {:>9.4}",
            format!("({x1},{x2})"),
            f.eval(&p),
            approx.eval_analytic(&p),
            approx.eval_bitstream(&p, 64, 1),
            approx.eval_bitstream(&p, 256, 1),
        );
    }

    // 4. AOT path: run the Pallas-lowered XLA kernel through PJRT.
    let rt = Runtime::cpu(default_artifacts_dir())?;
    if rt.has_artifact("smurf_eval.hlo.txt") {
        let exe = rt.load("smurf_eval.hlo.txt")?;
        let batch = 1024;
        let mut xs = vec![0.0f32; batch * 2];
        for i in 0..batch {
            xs[i * 2] = (i % 32) as f32 / 31.0;
            xs[i * 2 + 1] = (i / 32) as f32 / 31.0;
        }
        let w: Vec<f32> = approx.coefficients().iter().map(|&v| v as f32).collect();
        let out = exe.run_f32(&[(&[batch, 2], &xs), (&[4, 4], &w)])?;
        // Cross-check the kernel against the rust analytic evaluator.
        let mut max_err = 0.0f64;
        for i in 0..batch {
            let y_rust = approx.eval_analytic(&[xs[i * 2] as f64, xs[i * 2 + 1] as f64]);
            max_err = max_err.max((out[0][i] as f64 - y_rust).abs());
        }
        println!("\nXLA kernel vs rust analytic: max |Δ| = {max_err:.2e} over {batch} points");
        assert!(max_err < 1e-4, "AOT kernel must agree with the analytic evaluator");
        println!("quickstart OK (all three layers agree)");
    } else {
        println!("\n(artifacts missing — run `make artifacts` to exercise the XLA path)");
    }
    Ok(())
}

//! Serving demo: stand up the evaluation service with a function registry,
//! drive concurrent clients against all three engines (bit-level sim,
//! analytic, AOT XLA kernel), and print the latency/throughput report —
//! the L3 coordinator under load.
//!
//! Run: `make artifacts && cargo run --release --example serve`

use smurf::coordinator::{Engine, EvalServer, ServerConfig};
use smurf::prelude::*;
use smurf::runtime::default_artifacts_dir;
use std::sync::Arc;

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let funcs = vec![
        SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::sincos(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::softmax2(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::product2(), 64),
    ];
    let server = Arc::new(EvalServer::start(
        funcs,
        Some(default_artifacts_dir()),
        ServerConfig::default(),
    ));
    println!("registered functions: {:?}", server.functions());

    // Concurrent client load: 8 threads × 500 requests, mixed engines.
    let clients = 8;
    let per_client = 500;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            let names = ["euclidean2", "sincos", "softmax2", "product2"];
            let mut xla_ok = 0usize;
            let mut xla_err = 0usize;
            for i in 0..per_client {
                let x = ((c * per_client + i) % 101) as f64 / 100.0;
                let y = ((c * per_client + i * 37) % 101) as f64 / 100.0;
                let fname = names[i % names.len()];
                let engine = match i % 5 {
                    0 => Engine::BitLevel,
                    1 | 2 => Engine::Analytic,
                    _ => Engine::Xla,
                };
                let r = s.eval_sync(fname, vec![vec![x, y]], engine, 64);
                match engine {
                    Engine::Xla => {
                        if r.is_ok() {
                            xla_ok += 1;
                        } else {
                            xla_err += 1;
                        }
                    }
                    _ => assert!(r.is_ok(), "{:?}", r.error),
                }
                if r.is_ok() {
                    assert!(!r.outputs.is_empty());
                    // f32 round-off on the XLA path can graze the unit
                    // interval boundary.
                    assert!(
                        (-1e-5..=1.0 + 1e-5).contains(&r.outputs[0]),
                        "{fname} out of range: {}",
                        r.outputs[0]
                    );
                }
            }
            (xla_ok, xla_err)
        }));
    }
    let mut xla_ok = 0;
    let mut xla_err = 0;
    for h in handles {
        let (ok, err) = h.join().unwrap();
        xla_ok += ok;
        xla_err += err;
    }
    let dt = t0.elapsed();
    println!(
        "\ndrove {} requests from {clients} clients in {dt:?}",
        clients * per_client
    );
    if xla_err > 0 {
        println!("XLA engine: {xla_ok} ok, {xla_err} failed (run `make artifacts`)");
    } else {
        println!("XLA engine: {xla_ok} requests served from the AOT kernel");
    }
    println!("\n=== service metrics ===\n{}", server.metrics().report());
    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    println!("serve OK");
}

//! End-to-end CNN driver (the paper's §IV-B experiment, Table IV):
//! load the L2-trained LeNet-5 weights, classify the test corpus with all
//! three operator sets (vanilla / CNN-HSC / CNN-SMURF), and — when the
//! AOT artifacts exist — serve batched inference through the XLA
//! executable, reporting latency and throughput.
//!
//! This is the end-to-end validation required by DESIGN.md: it proves the
//! L1 Pallas kernel, the L2 trained model and the L3 rust engine compose
//! on a real (small) workload.
//!
//! Run: `make artifacts && cargo run --release --example cnn_inference`

use smurf::data;
use smurf::nn::lenet::ScRuntime;
use smurf::nn::{LeNet, OpSet};
use smurf::runtime::{default_artifacts_dir, Runtime};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let artifacts = default_artifacts_dir();
    let n_test = 400;
    let (_, test) = data::load_corpus(0, n_test, 42);
    println!("test corpus: {} images (28x28, 10 classes)\n", test.n);

    // --- Load trained weights (L2 output) or fall back to rust training.
    let weights_path = artifacts.join("lenet_weights.json");
    let net = match LeNet::load(weights_path.to_str().unwrap()) {
        Ok(net) => {
            println!("loaded L2-trained weights from {}", weights_path.display());
            net
        }
        Err(e) => {
            println!("({e}) — training in-process with the rust trainer instead");
            let (train_set, _) = data::load_corpus(2000, 0, 42);
            let mut net = LeNet::random(7);
            smurf::nn::train::train(
                &mut net,
                &train_set,
                &smurf::nn::train::TrainConfig::default(),
                1,
            );
            net
        }
    };

    // --- Table IV: three operator sets on the same weights.
    let t0 = Instant::now();
    let acc_vanilla = net.accuracy(&test.images, &test.labels, OpSet::Vanilla, None);
    let dt_vanilla = t0.elapsed();

    let mut rt_hsc = ScRuntime::paper_config(11);
    let t0 = Instant::now();
    let acc_hsc = net.accuracy(&test.images, &test.labels, OpSet::Hsc, Some(&mut rt_hsc));
    let dt_hsc = t0.elapsed();

    let mut rt_smurf = ScRuntime::paper_config(13);
    let t0 = Instant::now();
    let acc_smurf = net.accuracy(&test.images, &test.labels, OpSet::Smurf, Some(&mut rt_smurf));
    let dt_smurf = t0.elapsed();

    println!("\n=== Table IV (reproduced on the synthetic corpus) ===");
    println!("{:<14} {:>10} {:>12}", "operator set", "accuracy", "wall time");
    println!("{:<14} {:>9.2}% {:>12?}", "vanilla CNN", acc_vanilla * 100.0, dt_vanilla);
    println!("{:<14} {:>9.2}% {:>12?}", "CNN/HSC", acc_hsc * 100.0, dt_hsc);
    println!("{:<14} {:>9.2}% {:>12?}", "CNN/SMURF", acc_smurf * 100.0, dt_smurf);
    println!("(paper: 99.67 / 98.04 / 98.42 on MNIST — the shape to match is");
    println!(" vanilla ≥ both SC variants, with a small SC gap)");

    // --- Serve batched inference through the AOT XLA executables.
    let rt = Runtime::cpu(&artifacts)?;
    for artifact in ["lenet_infer.hlo.txt", "lenet_smurf_infer.hlo.txt"] {
        if !rt.has_artifact(artifact) {
            println!("\n({artifact} missing — run `make artifacts`)");
            continue;
        }
        let exe = rt.load(artifact)?;
        const BATCH: usize = 32;
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut latencies = Vec::new();
        for chunk_start in (0..test.n).step_by(BATCH) {
            let n = BATCH.min(test.n - chunk_start);
            let mut xs = vec![0.0f32; BATCH * 784];
            for i in 0..n {
                xs[i * 784..(i + 1) * 784].copy_from_slice(test.image(chunk_start + i));
            }
            let t0 = Instant::now();
            let out = exe.run_f32(&[(&[BATCH, 1, 28, 28], &xs)])?;
            latencies.push(t0.elapsed().as_secs_f64() * 1e3);
            for i in 0..n {
                let logits = &out[0][i * 10..(i + 1) * 10];
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += (pred == test.labels[chunk_start + i] as usize) as usize;
                total += 1;
            }
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = latencies[latencies.len() / 2];
        let p99_idx = ((latencies.len() as f64 * 0.99) as usize).min(latencies.len() - 1);
        let p99 = latencies[p99_idx];
        let throughput = total as f64 / latencies.iter().sum::<f64>() * 1e3;
        println!(
            "\nXLA {artifact}: accuracy {:.2}% | batch-32 latency p50 {p50:.2} ms, p99 {p99:.2} ms | {throughput:.0} img/s",
            correct as f64 / total as f64 * 100.0
        );
    }
    println!("\ncnn_inference OK");
    Ok(())
}

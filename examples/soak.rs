//! Standalone chaos-soak driver: the same round engine the `#[ignore]`d
//! integration test runs (`smurf::testutil::soak`), packaged as a
//! long-running binary with environment-variable knobs and a per-round
//! progress line. Exits non-zero on the first invariant violation,
//! printing the violating round's seed — the one-line repro is
//! `SOAK_SEED=<seed> SOAK_ROUNDS=1 cargo run --release --example soak`.
//!
//! Knobs (all optional; decimal or 0x-hex):
//!   SOAK_SEED      base seed           (default: SoakOptions::default)
//!   SOAK_ROUNDS    independent rounds  (default: 8)
//!   SOAK_CLIENTS   client threads      (default: 3)
//!   SOAK_REQUESTS  calls per client    (default: 24)
//!   SOAK_REPLAY    0 disables the identical-seed replay audit
//!
//! Run: `cargo run --release --example soak`, or `make soak`.

use smurf::testutil::{run_round, SoakOptions};
use smurf::util::prng::GOLDEN_GAMMA;

fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        // Absent and empty (a Makefile-passed undefined knob) both fall
        // back to the default.
        Ok(v) if !v.trim().is_empty() => {
            let v = v.trim().to_string();
            let parsed = if let Some(hex) = v.strip_prefix("0x") {
                u64::from_str_radix(hex, 16)
            } else {
                v.parse::<u64>()
            };
            match parsed {
                Ok(n) => n,
                Err(_) => {
                    eprintln!("soak: {name}={v:?} is not a u64");
                    std::process::exit(2);
                }
            }
        }
        _ => default,
    }
}

fn main() {
    let d = SoakOptions::default();
    let opts = SoakOptions {
        seed: env_u64("SOAK_SEED", d.seed),
        rounds: env_u64("SOAK_ROUNDS", d.rounds as u64) as usize,
        clients: env_u64("SOAK_CLIENTS", d.clients as u64) as usize,
        requests_per_client: env_u64("SOAK_REQUESTS", d.requests_per_client as u64) as usize,
        replay: env_u64("SOAK_REPLAY", 1) != 0,
    };
    println!(
        "soak: {} rounds × {} clients × {} calls, seed={:#x}, replay={}",
        opts.rounds, opts.clients, opts.requests_per_client, opts.seed, opts.replay
    );
    let mut compared = 0usize;
    for r in 0..opts.rounds {
        let seed = opts.seed.wrapping_add((r as u64).wrapping_mul(GOLDEN_GAMMA));
        match run_round(seed, &opts) {
            Ok(report) => {
                compared += report.replay_compared;
                println!("[{}/{}] {}", r + 1, opts.rounds, report.render());
            }
            Err(violation) => {
                eprintln!("[{}/{}] INVARIANT VIOLATION\n{violation}", r + 1, opts.rounds);
                eprintln!(
                    "repro: SOAK_SEED={seed:#x} SOAK_ROUNDS=1 cargo run --release --example soak"
                );
                std::process::exit(1);
            }
        }
    }
    if opts.replay && opts.rounds > 0 && compared == 0 {
        eprintln!(
            "soak: replay enabled but zero payload pairs were comparable — \
             the replay invariant was never exercised"
        );
        std::process::exit(1);
    }
    println!(
        "soak OK: {} rounds green, {} replay pairs byte-identical",
        opts.rounds, compared
    );
}

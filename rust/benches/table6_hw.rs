//! Table VI: hardware metrics (area, power, area·power) of SMURF vs the
//! Taylor-series pipeline and the LUT, from the shared SMIC-65nm-like
//! cell library, accuracy-equalized per §IV-C (MAE ≈ 0.015).

use smurf::baselines::lut::Lut;
use smurf::baselines::taylor::TaylorPoly;
use smurf::hw::{lut_design, smurf_design, taylor_design};
use smurf::prelude::*;

fn main() {
    let f = functions::euclidean2();

    // Accuracy equalization (§IV-C): all three schemes near MAE 0.015.
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &f, 256);
    let taylor = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
    let lut = Lut::build(&f, 8, 16);
    println!("accuracy equalization (target ≈ 0.015):");
    println!("  SMURF analytic MAE {:.4} (+ bitstream noise @256b ≈ 0.02)", approx.synth_mae);
    println!("  Taylor cubic 16-bit MAE {:.4}", taylor.mae_vs(&f, 33, Some(14)));
    println!("  LUT 2×8b→16b MAE {:.4}\n", lut.mae_vs(&f, 65));

    let s = smurf_design(&cfg);
    let t = taylor_design(&taylor);
    let l = lut_design(&lut);
    print!("{}", s.table());
    print!("{}", t.table());
    print!("{}", l.table());

    let (st, tt, lt) = (s.total(), t.total(), l.total());
    println!("\n=== Table VI ===");
    println!(
        "{:<8} {:>14} {:>10} {:>18}",
        "method", "area/um^2", "power/mW", "area*power"
    );
    for (name, c, paper_area, paper_pow) in [
        ("SMURF", st, 5294.72, 0.51),
        ("Taylor", tt, 32941.44, 3.53),
        ("LUT", lt, 238176.38, 0.10),
    ] {
        println!(
            "{:<8} {:>14.2} {:>10.3} {:>18.2}   (paper: {:.2} um², {:.2} mW)",
            name,
            c.area_um2,
            c.power_mw,
            c.area_power(),
            paper_area,
            paper_pow
        );
    }

    println!("\nheadline ratios:");
    println!(
        "  SMURF/Taylor area  = {:>6.2}%   (paper 16.07%)",
        100.0 * st.area_um2 / tt.area_um2
    );
    println!(
        "  SMURF/Taylor power = {:>6.2}%   (paper 14.45%)",
        100.0 * st.power_mw / tt.power_mw
    );
    println!(
        "  SMURF/LUT area     = {:>6.2}%   (paper 2.22%)",
        100.0 * st.area_um2 / lt.area_um2
    );
    println!(
        "  SMURF/Taylor AP    = {:>6.2}%   (paper 2.32%)",
        100.0 * st.area_power() / tt.area_power()
    );
    println!(
        "  SMURF/LUT AP       = {:>6.2}%   (paper 11.34%)",
        100.0 * st.area_power() / lt.area_power()
    );

    // Ablation: how SMURF hardware scales with radix and arity.
    println!("\n--- ablation: SMURF cost vs configuration ---");
    println!("{:<16} {:>12} {:>10}", "config", "area/um^2", "power/mW");
    for (m, n) in [(1, 4), (2, 3), (2, 4), (2, 8), (3, 4), (4, 4)] {
        let d = smurf_design(&SmurfConfig::uniform(m, n)).total();
        println!(
            "{:<16} {:>12.2} {:>10.3}",
            format!("M={m}, N={n}"),
            d.area_um2,
            d.power_mw
        );
    }
}

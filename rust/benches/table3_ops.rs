//! Table III: operational comparison of SMURF vs CORDIC for three
//! multivariate functions, regenerated from the symbolic decompositions,
//! plus a numeric validation that each CORDIC pipeline actually computes
//! its function (so the op counts refer to real, working engines).

use smurf::baselines::cordic;
use smurf::prelude::*;
use std::time::Instant;

fn fmt_ops(ops: &[(&str, usize)]) -> String {
    ops.iter()
        .map(|(name, n)| format!("{n}×{name}"))
        .collect::<Vec<_>>()
        .join(" + ")
}

fn main() {
    println!("=== Table III: operational comparison (SMURF vs CORDIC) ===\n");
    println!("{:<28} {:<42} {:>6}", "function", "operations", "units");
    for row in cordic::table3_cordic() {
        println!("{:<28} {:<42} {:>6}", row.function, fmt_ops(&row.ops), row.total_units());
    }
    for row in cordic::table3_smurf() {
        println!("{:<28} {:<42} {:>6}", row.function, fmt_ops(&row.ops), row.total_units());
    }

    // Numeric validation: both engines actually compute each function.
    println!("\n--- validation: CORDIC pipelines vs SMURF generators ---");
    let iters = 24;
    let points = [(0.3, 0.4), (0.7, 0.2), (0.5, 0.9)];

    // f1 = sqrt(x1²+x2²): paper decomposition 2 squarings + 1 sqrt.
    for &(x1, x2) in &points {
        let via_ops = cordic::sqrt(x1 * x1 + x2 * x2, iters);
        let exact = f64::sqrt(x1 * x1 + x2 * x2);
        assert!((via_ops - exact).abs() < 1e-4);
    }
    println!("CORDIC sqrt(x1²+x2²): OK (2×square + 1×sqrt, {iters} iters each)");

    // f2 = sin(x1)cos(x2): 2 sin + 1 cos + add + multiply per the paper's
    // count (sin(a)cos(b) = [sin(a+b) + sin(a-b)]/2).
    for &(x1, x2) in &points {
        let (_, s_sum) = cordic::sin_cos(x1 + x2, iters);
        let (_, s_diff) = cordic::sin_cos(x1 - x2, iters);
        let via_ops = 0.5 * (s_sum + s_diff);
        assert!((via_ops - x1.sin() * x2.cos()).abs() < 1e-4);
    }
    println!("CORDIC sin(x1)cos(x2): OK (2×sin + 1×cos + add + multiply)");

    // f3 = softmax2: 2 exp + add + divide.
    for &(x1, x2) in &points {
        let e1 = cordic::exp(x1, iters);
        let e2 = cordic::exp(x2, iters);
        let via_ops = cordic::divide(e1, e1 + e2, 30);
        let exact = x1.exp() / (x1.exp() + x2.exp());
        assert!((via_ops - exact).abs() < 1e-4, "{via_ops} vs {exact}");
    }
    println!("CORDIC softmax2: OK (2×exp + add + divide)");

    // SMURF: one generator per function, same architecture.
    let cfg = SmurfConfig::uniform(2, 4);
    for f in [functions::euclidean2(), functions::sincos(), functions::softmax2()] {
        let t0 = Instant::now();
        let a = SmurfApproximator::synthesize(&cfg, &f, 64);
        println!(
            "SMURF {:<12}: 1 generator (16 θ-gates), analytic MAE {:.4}, synth {:?}",
            f.name(),
            a.synth_mae,
            t0.elapsed()
        );
    }
    println!("\nHeadline: every function is ONE SMURF instance (same hardware,");
    println!("different θ-gate thresholds) vs 3–5 distinct CORDIC engines.");
}

//! §Robustness: bit-level fault-injection sweep and TMR mitigation record.
//!
//! Three stages, on the paper's Euclid M=2/N=4 configuration:
//!
//! 1. **Zero-rate equality gates** (never skippable, run before anything
//!    is timed or written): an *armed* fault plan whose every rate is 0
//!    must be bit-identical to the clean path — scalar engine across all
//!    three entropy modes, the wide engine at every compiled plane width,
//!    and `eval_avg_tmr` against `eval_avg` (TMR at rate 0 votes three
//!    identical replicas, so the vote is the identity). A divergence here
//!    means the fault hooks perturb the datapath even when disarmed, and
//!    the record is aborted with a non-zero exit.
//! 2. **Accuracy-vs-fault-rate sweep**: for each [`FaultSite`] and a
//!    ladder of transient-flip rates, the MAE of the Monte-Carlo
//!    estimate against the analytic closed form (Eq. 21 — the fault-free
//!    reference; it never touches the stochastic pipeline), raw vs
//!    lane-redundancy TMR. Accuracy rows carry `us_per_iter: 0` and the
//!    MAE as `throughput` with unit `"mae"`.
//! 3. **Overhead timing**: clean vs armed-zero-rate `eval_avg` (the cost
//!    of the per-cycle hook when every site is disarmed) and the TMR
//!    route (3x lane redundancy, so ~3x fewer trials per pass).
//!
//! Acceptance floors (ISSUE 7), deferred until after the record is
//! written and skippable with `BENCH_NO_ENFORCE=1` (the equality gates
//! are not): TMR must cut the output-bit-flip MAE at the harshest swept
//! rate by ≥ 2x, and the armed-zero-rate hook overhead must stay ≤ 1.5x
//! clean. Neither has been measured on a cargo-equipped runner yet.
//!
//! Wall-clock methodology as in perf_wide (criterion is not vendored).
//! The record is written to `BENCH_fault_sweep.json` in the repo root
//! (override with `BENCH_FAULT_OUT`), schema `smurf-bench-v1`.

use smurf::prelude::*;
use smurf::sc::fault::{BitFaultPlan, FaultRates, FaultSite};
use smurf::smurf::sim::EntropyMode;
use smurf::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

fn timed<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<52} {:>12.3} us/iter", per * 1e6);
    per
}

fn row(bench: &str, us_per_iter: f64, throughput: f64, unit: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str(bench.into()));
    m.insert("us_per_iter".into(), Json::Num(us_per_iter));
    m.insert("throughput".into(), Json::Num(throughput));
    m.insert("unit".into(), Json::Str(unit.into()));
    Json::Obj(m)
}

fn mode_name(mode: EntropyMode) -> &'static str {
    match mode {
        EntropyMode::SharedLfsr => "shared_lfsr",
        EntropyMode::IndependentXorshift => "xorshift",
        EntropyMode::SobolCpt => "sobol_cpt",
    }
}

fn site_name(site: FaultSite) -> &'static str {
    match site {
        FaultSite::EntropyWord => "entropy_word",
        FaultSite::ThetaOutput => "theta_output",
        FaultSite::FsmState => "fsm_state",
        FaultSite::OutputBit => "output_bit",
    }
}

/// Zero-rate equality gates for one plane width: an armed all-zero-rate
/// plan must be bit-identical to the clean engine, and the TMR route at
/// rate 0 must be bit-identical to `eval_avg` (clean and armed alike).
/// Any trip aborts the record before a single number is written.
fn gate_zero_rate<P: BitPlane>(label: &str, scalar: &BitLevelSmurf, p: &[f64]) {
    let clean = WideBitLevelSmurf::<P>::from_scalar(scalar);
    let armed = clean.clone().with_fault_plan(BitFaultPlan::new(0xFA11));
    let mut st_c = clean.make_run_state();
    let mut st_a = armed.make_run_state();
    let len = 256usize;
    // Off-multiple trial count: exercises partial final passes too.
    let trials = P::LANES + 5;
    let want = clean.eval_avg(p, len, trials, 42, &mut st_c);
    let got = armed.eval_avg(p, len, trials, 42, &mut st_a);
    assert_eq!(
        want, got,
        "FATAL: {label} armed zero-rate plan diverges from clean — record aborted"
    );
    // TMR chunk cap is LANES/3; go past one chunk to cover the remainder
    // path as well.
    let tmr_trials = P::LANES / 3 + 3;
    let want = clean.eval_avg(p, len, tmr_trials, 42, &mut st_c);
    let got_clean_tmr = clean.eval_avg_tmr(p, len, tmr_trials, 42, &mut st_c);
    assert_eq!(
        want, got_clean_tmr,
        "FATAL: {label} clean TMR diverges from eval_avg at rate 0 — record aborted"
    );
    let got_armed_tmr = armed.eval_avg_tmr(p, len, tmr_trials, 42, &mut st_a);
    assert_eq!(
        want, got_armed_tmr,
        "FATAL: {label} armed zero-rate TMR diverges from eval_avg — record aborted"
    );
    println!("gate   {label:<8} armed-zero == clean, tmr(0) == eval_avg  ok");
}

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let res = synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
    let w = res.smurf.coefficients().to_vec();
    let approx =
        SmurfApproximator::from_coefficients("euclidean2", cfg.clone(), w.clone(), 64);
    let mut rows: Vec<Json> = Vec::new();

    // ---- Stage 1: zero-rate equality gates ----------------------------
    println!("=== Fault sweep stage 1: zero-rate equality gates ===\n");
    let p0 = [0.3f64, 0.4];
    for mode in [
        EntropyMode::SharedLfsr,
        EntropyMode::IndependentXorshift,
        EntropyMode::SobolCpt,
    ] {
        let clean = BitLevelSmurf::new(cfg.clone(), &w, mode);
        let armed = clean.clone().with_fault_plan(BitFaultPlan::new(0xFA11));
        let name = mode_name(mode);
        for seed in [0u64, 3, 0x5EED] {
            assert_eq!(
                clean.eval(&p0, 128, seed),
                armed.eval(&p0, 128, seed),
                "FATAL: scalar {name} armed zero-rate eval diverges — record aborted"
            );
        }
        assert_eq!(
            clean.eval_avg_scalar(&p0, 128, 16, 5),
            armed.eval_avg_scalar(&p0, 128, 16, 5),
            "FATAL: scalar {name} armed zero-rate eval_avg diverges — record aborted"
        );
        println!("gate   scalar {name:<12} armed-zero == clean  ok");

        gate_zero_rate::<u64>(&format!("u64/{name}"), &clean, &p0);
        gate_zero_rate::<[u64; 4]>(&format!("u64x4/{name}"), &clean, &p0);
        #[cfg(feature = "wide512")]
        gate_zero_rate::<[u64; 8]>(&format!("u64x8/{name}"), &clean, &p0);
    }

    // ---- Stage 2: accuracy vs fault rate, raw vs TMR ------------------
    // Transient flips at each datapath site, widest compiled plane. MAE
    // over an 8-point grid against the analytic closed form; the rate-0
    // column doubles as one more equality check (it must match the clean
    // engine's MAE exactly).
    println!("\n=== Fault sweep stage 2: MAE vs flip rate, raw vs TMR (MaxPlane) ===\n");
    let scalar = BitLevelSmurf::new(cfg.clone(), &w, EntropyMode::SharedLfsr);
    let clean = WideBitLevelSmurf::<MaxPlane>::from_scalar(&scalar);
    let mut st = clean.make_run_state();
    let points: Vec<[f64; 2]> = (0..8)
        .map(|i| [(i % 4) as f64 / 3.0 * 0.9 + 0.05, (i / 4) as f64 * 0.6 + 0.2])
        .collect();
    let (len, trials) = (256usize, 60usize);
    let mae = |eng: &WideBitLevelSmurf<MaxPlane>,
               st: &mut WideRunState<MaxPlane>,
               tmr: bool| {
        let mut acc = 0.0f64;
        for p in &points {
            let y = if tmr {
                eng.eval_avg_tmr(p, len, trials, 42, st)
            } else {
                eng.eval_avg(p, len, trials, 42, st)
            };
            acc += (y - approx.eval_analytic(p)).abs();
        }
        acc / points.len() as f64
    };
    let mae_clean = mae(&clean, &mut st, false);
    let mae_clean_tmr = mae(&clean, &mut st, true);
    rows.push(row("fault_sweep/mae/clean/raw", 0.0, mae_clean, "mae"));
    rows.push(row("fault_sweep/mae/clean/tmr", 0.0, mae_clean_tmr, "mae"));
    println!(
        "{:<52} raw {:.5}  tmr {:.5}",
        "clean baseline (sampling error only)", mae_clean, mae_clean_tmr
    );

    const RATES: [(f64, &str); 4] =
        [(0.0, "0"), (1e-3, "1e-3"), (1e-2, "1e-2"), (5e-2, "5e-2")];
    let mut tmr_gain_at_worst = 0.0f64;
    for site in FaultSite::ALL {
        let sname = site_name(site);
        for (rate, rlabel) in RATES {
            let plan =
                BitFaultPlan::new(0xFA11).with_site(site, FaultRates::flips(rate));
            let eng = clean.clone().with_fault_plan(plan);
            let mut est = eng.make_run_state();
            let mae_raw = mae(&eng, &mut est, false);
            let mae_tmr = mae(&eng, &mut est, true);
            if rate == 0.0 {
                // One more disarmed-site identity: a zero-rate site must
                // not move the MAE by even one ULP.
                assert_eq!(
                    mae_raw, mae_clean,
                    "FATAL: {sname} zero-rate raw MAE diverges from clean — record aborted"
                );
                assert_eq!(
                    mae_tmr, mae_clean_tmr,
                    "FATAL: {sname} zero-rate TMR MAE diverges from clean — record aborted"
                );
            }
            rows.push(row(
                &format!("fault_sweep/mae/{sname}/flip_{rlabel}/raw"),
                0.0,
                mae_raw,
                "mae",
            ));
            rows.push(row(
                &format!("fault_sweep/mae/{sname}/flip_{rlabel}/tmr"),
                0.0,
                mae_tmr,
                "mae",
            ));
            println!(
                "{:<52} raw {:.5}  tmr {:.5}",
                format!("{sname} flip={rlabel}"),
                mae_raw,
                mae_tmr
            );
            if site == FaultSite::OutputBit && rate == 5e-2 {
                tmr_gain_at_worst = mae_raw / mae_tmr.max(f64::MIN_POSITIVE);
            }
        }
    }
    println!(
        "\n{:<52} {:>11.2}x  (acceptance floor: 2x)",
        "  → TMR MAE reduction (output_bit flip=5e-2)", tmr_gain_at_worst
    );
    rows.push(row(
        "fault_sweep/tmr_gain/output_bit/flip_5e-2",
        0.0,
        tmr_gain_at_worst,
        "x",
    ));

    // ---- Stage 3: hook overhead timing --------------------------------
    println!("\n=== Fault sweep stage 3: hook overhead (MaxPlane, L=256 T=60) ===\n");
    let armed0 = clean.clone().with_fault_plan(BitFaultPlan::new(0xFA11));
    let mut st_a = armed0.make_run_state();
    let per_clean = timed("clean  eval_avg L=256 T=60 (MaxPlane)", 200, || {
        std::hint::black_box(clean.eval_avg(&p0, len, trials, 42, &mut st));
    });
    let per_armed0 = timed("armed0 eval_avg L=256 T=60 (MaxPlane)", 200, || {
        std::hint::black_box(armed0.eval_avg(&p0, len, trials, 42, &mut st_a));
    });
    let per_tmr = timed("tmr    eval_avg L=256 T=60 (MaxPlane)", 200, || {
        std::hint::black_box(clean.eval_avg_tmr(&p0, len, trials, 42, &mut st));
    });
    let hook_overhead = per_armed0 / per_clean;
    rows.push(row(
        "fault_sweep/overhead/clean_eval_avg/L256/T60",
        per_clean * 1e6,
        trials as f64 / per_clean,
        "trials/s",
    ));
    rows.push(row(
        "fault_sweep/overhead/armed_zero_eval_avg/L256/T60",
        per_armed0 * 1e6,
        trials as f64 / per_armed0,
        "trials/s",
    ));
    rows.push(row(
        "fault_sweep/overhead/tmr_eval_avg/L256/T60",
        per_tmr * 1e6,
        trials as f64 / per_tmr,
        "trials/s",
    ));
    rows.push(row("fault_sweep/overhead/armed_zero_vs_clean", 0.0, hook_overhead, "x"));
    rows.push(row("fault_sweep/overhead/tmr_vs_clean", 0.0, per_tmr / per_clean, "x"));
    println!(
        "\n{:<52} {:>11.2}x  (acceptance ceiling: 1.5x)",
        "  → armed-zero hook overhead", hook_overhead
    );
    println!(
        "{:<52} {:>11.2}x  (3x lanes spent on redundancy)",
        "  → TMR cost", per_tmr / per_clean
    );

    // Emit the machine-readable record. Cargo runs bench binaries with
    // cwd = the package root (rust/), so default to the repo root
    // explicitly; BENCH_FAULT_OUT overrides.
    let out_path = std::env::var("BENCH_FAULT_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_fault_sweep.json", env!("CARGO_MANIFEST_DIR"))
    });
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Json::Str("smurf-bench-v1".into()));
    doc.insert(
        "config".into(),
        Json::Str(
            "euclidean2 M=2 N=4 (QP-synthesized), flip-rate sweep raw vs TMR".into(),
        ),
    );
    doc.insert("rows".into(), Json::Arr(rows));
    match std::fs::write(&out_path, Json::Obj(doc).dump()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }

    // Acceptance floors fire only now, AFTER the record is written: the
    // measured rows are never discarded, but an under-floor run still
    // exits non-zero unless the runner opted out with BENCH_NO_ENFORCE=1.
    // The equality gates above are never skippable.
    let mut floor_failures: Vec<String> = Vec::new();
    if tmr_gain_at_worst < 2.0 {
        floor_failures.push(format!(
            "TMR MAE reduction {tmr_gain_at_worst:.2}x below the 2x floor \
             (output_bit flip=5e-2)"
        ));
    }
    if hook_overhead > 1.5 {
        floor_failures.push(format!(
            "armed-zero hook overhead {hook_overhead:.2}x above the 1.5x ceiling"
        ));
    }
    if std::env::var("BENCH_NO_ENFORCE").is_err() && !floor_failures.is_empty() {
        panic!(
            "FATAL: acceptance floor(s) missed (record written; set BENCH_NO_ENFORCE=1 \
             on noisy runners): {}",
            floor_failures.join("; ")
        );
    }
    println!("\nfault_sweep done");
}

//! Fig. 7: average absolute error of a 3-variate softmax SMURF vs
//! bitstream length, for 3-, 4- and 8-state FSMs per variable.
//!
//! Paper's series: error ≈ 0.15 at very short streams, ≈ 0.04 at 64 bits,
//! ≈ 0.02 at 256 bits; extra states buy ≤ 0.01. The bench reproduces the
//! decay curve and checks those three anchors.

use smurf::prelude::*;
use smurf::smurf::sim::{BitLevelSmurf, EntropyMode};
use std::time::Instant;

fn mae_at(sim: &BitLevelSmurf, approx: &smurf::smurf::analytic::AnalyticSmurf, len: usize) -> f64 {
    // Grid over the 3-cube + MC trials per point; error vs the TARGET
    // (the paper measures against the true softmax, so analytic fit error
    // is included).
    let f = functions::softmax3();
    let grid = 4;
    let trials = 12;
    let mut total = 0.0;
    let mut count = 0;
    for i in 0..grid {
        for j in 0..grid {
            for k in 0..grid {
                let p = [
                    i as f64 / (grid - 1) as f64,
                    j as f64 / (grid - 1) as f64,
                    k as f64 / (grid - 1) as f64,
                ];
                let target = f.eval(&p);
                total += sim.abs_error(&p, target, len, trials, 97);
                count += 1;
            }
        }
    }
    let _ = approx;
    total / count as f64
}

fn main() {
    let f = functions::softmax3();
    let lengths = [4usize, 8, 16, 32, 64, 128, 256, 512, 1024];

    println!("=== Fig. 7: softmax-3 average absolute error vs bitstream length ===\n");
    print!("{:>6}", "L");
    for n in [3usize, 4, 8] {
        print!(" {:>10}", format!("N={n}"));
    }
    println!();

    let mut series = Vec::new();
    for n in [3usize, 4, 8] {
        let cfg = SmurfConfig::uniform(3, n);
        let t0 = Instant::now();
        let res = synthesize(&cfg, &f, &SynthOptions::default());
        let sim = BitLevelSmurf::new(cfg, res.smurf.coefficients(), EntropyMode::IndependentXorshift);
        eprintln!("synth N={n}: {:?} (analytic MAE {:.4})", t0.elapsed(), res.mae);
        let errs: Vec<f64> = lengths.iter().map(|&l| mae_at(&sim, &res.smurf, l)).collect();
        series.push(errs);
    }
    for (li, &l) in lengths.iter().enumerate() {
        print!("{:>6}", l);
        for s in &series {
            print!(" {:>10.4}", s[li]);
        }
        println!();
    }

    // Anchors from the paper.
    let n4 = &series[1];
    let e64 = n4[lengths.iter().position(|&l| l == 64).unwrap()];
    let e256 = n4[lengths.iter().position(|&l| l == 256).unwrap()];
    println!("\nanchors (N=4): error@64 = {e64:.4} (paper ≈ 0.04), error@256 = {e256:.4} (paper ≈ 0.02)");
    assert!(e64 < 0.08, "error@64 too high: {e64}");
    assert!(e256 < e64, "error must decay with stream length");
    // Extra states: ≤ 0.01-ish gain at 256 bits (paper's observation).
    let n3 = series[0][lengths.iter().position(|&l| l == 256).unwrap()];
    let n8 = series[2][lengths.iter().position(|&l| l == 256).unwrap()];
    println!("state-count gain @256: N=3 {n3:.4} → N=8 {n8:.4} (paper: ≤ 0.01)");
    println!("fig7 OK");
}

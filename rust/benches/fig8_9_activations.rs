//! Figs. 8 & 9: SMURF approximation of tanh and swish at bitstream
//! lengths 64 and 256, in the bipolar convention.
//!
//! Paper anchors: tanh MAE 0.037 @64 / 0.011 @256; swish 0.033 @64 /
//! 0.010 @256. tanh uses the 4-state chain (whose QP optimum is the
//! Brown–Card labelling); swish is asymmetric and uses the dual-FSM
//! configuration (both FSMs fed the same variable — the bivariate SMURF
//! at x₁ = x₂), which is what reaches the paper's accuracy regime.

use smurf::prelude::*;
use smurf::smurf::sim::{BitLevelSmurf, EntropyMode};
use smurf::synth::synthesize::synthesize_univariate_dual;

/// MC-averaged bit-level MAE of a univariate generator over the curve.
fn curve_mae(
    sim: &BitLevelSmurf,
    target: &TargetFn,
    dual: bool,
    len: usize,
    trials: usize,
) -> f64 {
    let grid = 33;
    let mut total = 0.0;
    for i in 0..grid {
        let x = i as f64 / (grid - 1) as f64;
        let t = target.eval(&[x]);
        let p: Vec<f64> = if dual { vec![x, x] } else { vec![x] };
        total += sim.abs_error(&p, t, len, trials, 1234 + i as u64);
    }
    total / grid as f64
}

fn print_curve(analytic: &smurf::smurf::analytic::AnalyticSmurf, target: &TargetFn, dual: bool) {
    println!("{:>6} {:>10} {:>10}", "x", "target", "analytic");
    for i in 0..=16 {
        let x = i as f64 / 16.0;
        let p: Vec<f64> = if dual { vec![x, x] } else { vec![x] };
        println!("{:>6.3} {:>10.4} {:>10.4}", x, target.eval(&[x]), analytic.eval(&p));
    }
}

fn main() {
    // --- Fig. 8: tanh, 4-state chain (Brown–Card-consistent config).
    let tanh = functions::tanh_bipolar(2.0);
    let res_t = synthesize(&SmurfConfig::uniform(1, 4), &tanh, &SynthOptions::default());
    let sim_t = BitLevelSmurf::new(
        SmurfConfig::uniform(1, 4),
        res_t.smurf.coefficients(),
        EntropyMode::IndependentXorshift,
    );
    println!("=== Fig. 8: tanh (bipolar, N=4 chain) ===");
    print_curve(&res_t.smurf, &tanh, false);
    let t64 = curve_mae(&sim_t, &tanh, false, 64, 24);
    let t256 = curve_mae(&sim_t, &tanh, false, 256, 24);
    println!("\ntanh  MAE @64  = {t64:.4}  (paper 0.037)");
    println!("tanh  MAE @256 = {t256:.4}  (paper 0.011)");
    assert!(t64 < 0.08 && t256 < t64);

    // --- Fig. 9: swish, dual-FSM (bivariate SMURF at x1 = x2).
    let swish = functions::swish_bipolar(2.0);
    let res_s = synthesize_univariate_dual(4, &swish, &SynthOptions::default());
    let sim_s = BitLevelSmurf::new(
        SmurfConfig::uniform(2, 4),
        res_s.smurf.coefficients(),
        EntropyMode::IndependentXorshift,
    );
    println!("\n=== Fig. 9: swish (bipolar, dual-FSM 4×4) ===");
    print_curve(&res_s.smurf, &swish, true);
    let s64 = curve_mae(&sim_s, &swish, true, 64, 24);
    let s256 = curve_mae(&sim_s, &swish, true, 256, 24);
    println!("\nswish MAE @64  = {s64:.4}  (paper 0.033)");
    println!("swish MAE @256 = {s256:.4}  (paper 0.010)");
    assert!(s64 < 0.08 && s256 < s64);

    // Ablation: the single-chain swish the dual config improves on.
    let res_single = synthesize(&SmurfConfig::uniform(1, 4), &swish, &SynthOptions::default());
    println!(
        "\nablation: swish analytic MAE — single chain {:.4} vs dual-FSM {:.4}",
        res_single.mae, res_s.mae
    );
    println!("fig8_9 OK");
}

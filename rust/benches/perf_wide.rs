//! §Perf: scalar vs bit-sliced wide SMURF simulation.
//!
//! Measures trial throughput of the Monte-Carlo estimator (`eval_avg`) on
//! the paper's Euclid M=2/N=4 configuration — the
//! `euclid_paper_accuracy_at_64_bits` workload shape (L=64, 32 trials per
//! point) — comparing the scalar one-bit-per-cycle simulator against the
//! 64-lane bit-sliced engine, for every entropy mode. Also measures the
//! coordinator-shaped batch (64 distinct points per pass), the NN
//! activation shape (a 120-neuron layer of SMURF tanh at L=4096,
//! per-neuron scalar vs `SmurfActivation::eval_bitlevel_batch`), and the
//! **plane-width sweep**: the same tanh workload on the `u64` (64-lane),
//! `[u64; 4]` (256-lane) and — under `--features wide512` — `[u64; 8]`
//! (512-lane) `BitPlane` engines, both the L=4096 `eval_avg` row and the
//! activation-batch row. The `u64x4` plane must reach ≥ 2× the `u64`
//! plane's trials/s on the L=4096 `eval_avg` row (the ISSUE 4 acceptance
//! floor; `BENCH_NO_ENFORCE=1` opts a noisy runner out of the ratio,
//! never out of the equality gates).
//!
//! Also measures the **SC-PwMM sweep** (`pwmm_sweep/*` rows): the CNN
//! conv/dense multiply workload (B=1024 bipolar products, L=128 — the
//! paper's SC-PwMM stream length) as one scalar-`Exact` `mul_bipolar`
//! per product vs the plane-form engine (`sc::pwmm_wide`) at every
//! compiled plane width, equality-gated product-for-product before
//! timing. Acceptance floor: wide-u64 ≥ 4× scalar MAC/s (never measured
//! on real hardware yet — like the other floors it is deferred until
//! after the record is written and `BENCH_NO_ENFORCE=1` skips it; the
//! equality gates are never skippable).
//!
//! Also measures **degraded-mode serving** (`degraded_mode/*` rows): the
//! same BitLevel request through the full serving stack healthy vs under
//! forced load shedding (analytic fallback, response flagged
//! `degraded`), with a deferred ≥ 2× capacity-gain floor.
//!
//! Every scalar/wide pair is equality-gated before timing: any bit-level
//! divergence panics (non-zero exit from `make bench-json`) instead of
//! silently recording numbers from a wrong engine.
//!
//! Wall-clock methodology as in perf_serve (criterion is not vendored):
//! warmup + N timed iterations. Results are printed and written as
//! machine-readable rows to `BENCH_perf.json` (override with `BENCH_OUT`)
//! so the perf trajectory is tracked per-PR:
//! `{"bench", "us_per_iter", "throughput", "unit"}`.

use smurf::coordinator::batcher::BatchPolicy;
use smurf::coordinator::{Engine, EvalServer, ServerConfig};
use smurf::nn::sc_ops::{ScContext, ScMode, SmurfActivation};
use smurf::prelude::*;
use smurf::sc::pwmm_wide::{self, PwmmScratch};
use smurf::smurf::sim::EntropyMode;
use smurf::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

/// One plane width of the SC-PwMM sweep: equality-gate the wide batch
/// against the scalar-`Exact` reference products (a divergence aborts
/// the perf record), then time it. Returns the per-iteration time.
fn sweep_pwmm<P: BitPlane>(
    label: &str,
    xs: &[f32],
    ws: &[f32],
    len: usize,
    seed0: u64,
    want: &[f32],
    rows: &mut Vec<Json>,
) -> f64 {
    let b = xs.len();
    let mut st = PwmmScratch::<P>::new();
    let mut out = vec![0.0f32; b];
    pwmm_wide::mul_bipolar_exact_batch(xs, ws, len, seed0, &mut st, &mut out);
    assert_eq!(
        want, &out[..],
        "FATAL: {label} PwMM diverges from scalar Exact — perf record aborted"
    );
    let per = timed(&format!("wide   PwMM L={len} B={b} ({label})"), 50, || {
        std::hint::black_box(pwmm_wide::mul_bipolar_exact_batch(
            xs, ws, len, seed0, &mut st, &mut out,
        ));
    });
    rows.push(row(
        &format!("pwmm_sweep/wide/L{len}/B{b}/{label}"),
        per * 1e6,
        b as f64 / per,
        "MAC/s",
    ));
    per
}

/// One plane width of the sweep: equality-gate the width against the
/// scalar reference (a divergence aborts the perf record), then time the
/// tanh L=4096 `eval_avg` row (T=256 trials, chunked by `P::LANES`) and
/// the activation-batch row (B=120 distinct points, one trial each).
/// Returns the two per-iteration times.
fn sweep_plane<P: BitPlane>(
    label: &str,
    scalar: &BitLevelSmurf,
    rows: &mut Vec<Json>,
) -> (f64, f64) {
    let wide = WideBitLevelSmurf::<P>::from_scalar(scalar);
    let mut st = wide.make_run_state();
    let p = [0.62f64];
    let (len, trials) = (4096usize, 256usize);
    let want = scalar.eval_avg_scalar(&p, len, trials, 42);
    let got = wide.eval_avg(&p, len, trials, 42, &mut st);
    assert_eq!(
        want, got,
        "FATAL: {label} plane eval_avg diverges from scalar — perf record aborted"
    );
    let per_avg = timed(&format!("plane  eval_avg tanh L={len} T={trials} ({label})"), 30, || {
        std::hint::black_box(wide.eval_avg(&p, len, trials, 42, &mut st));
    });
    rows.push(row(
        &format!("plane_sweep/eval_avg/tanh_n4/L4096/T256/{label}"),
        per_avg * 1e6,
        trials as f64 / per_avg,
        "trials/s",
    ));

    // Activation-batch shape: 120 distinct univariate points, one trial
    // each, chunked by this width's lane count.
    let pts: Vec<Vec<f64>> = (0..120).map(|i| vec![i as f64 / 119.0]).collect();
    let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
    let seeds: Vec<u64> = (0..120).map(|i| 1 + i as u64).collect();
    let mut out = vec![0.0f64; 120];
    let mut run_batch = |st: &mut WideRunState<P>, out: &mut [f64]| {
        for (ci, chunk) in refs.chunks(P::LANES).enumerate() {
            let base = ci * P::LANES;
            wide.eval_points(chunk, len, &seeds[base..base + chunk.len()], st, &mut out[base..]);
        }
    };
    run_batch(&mut st, &mut out);
    for (i, pt) in refs.iter().enumerate() {
        assert_eq!(
            out[i],
            scalar.eval(pt, len, seeds[i]),
            "FATAL: {label} plane batch point {i} diverges — perf record aborted"
        );
    }
    let per_batch = timed(&format!("plane  batch B=120 tanh L={len} ({label})"), 30, || {
        run_batch(&mut st, &mut out);
        std::hint::black_box(out[119]);
    });
    rows.push(row(
        &format!("plane_sweep/activation_batch/tanh_n4/L4096/B120/{label}"),
        per_batch * 1e6,
        120.0 / per_batch,
        "points/s",
    ));
    (per_avg, per_batch)
}

fn timed<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<52} {:>12.3} us/iter", per * 1e6);
    per
}

fn row(bench: &str, us_per_iter: f64, throughput: f64, unit: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str(bench.into()));
    m.insert("us_per_iter".into(), Json::Num(us_per_iter));
    m.insert("throughput".into(), Json::Num(throughput));
    m.insert("unit".into(), Json::Str(unit.into()));
    Json::Obj(m)
}

fn mode_name(mode: EntropyMode) -> &'static str {
    match mode {
        EntropyMode::SharedLfsr => "shared_lfsr",
        EntropyMode::IndependentXorshift => "xorshift",
        EntropyMode::SobolCpt => "sobol_cpt",
    }
}

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let res = synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
    let w = res.smurf.coefficients().to_vec();
    let p = [0.3, 0.4];
    let (len, trials) = (64usize, 32usize);
    let mut rows: Vec<Json> = Vec::new();

    println!("=== §Perf: scalar vs wide (bit-sliced) SMURF, Euclid M=2 N=4 ===\n");
    for mode in [
        EntropyMode::SharedLfsr,
        EntropyMode::IndependentXorshift,
        EntropyMode::SobolCpt,
    ] {
        let scalar = BitLevelSmurf::new(cfg.clone(), &w, mode);
        let wide = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
        let mut st = wide.make_run_state();

        // Equality gate: the two engines must agree bit-exactly before we
        // compare their speed. A trip here aborts `make bench-json` with a
        // non-zero exit — the perf record is never written from a
        // diverged engine pair.
        let a = scalar.eval_avg_scalar(&p, len, trials, 42);
        let b = wide.eval_avg(&p, len, trials, 42, &mut st);
        assert_eq!(a, b, "FATAL: wide/scalar divergence in {mode:?} — perf record aborted");

        let name = mode_name(mode);
        let per_s = timed(
            &format!("scalar eval_avg L={len} T={trials} ({name})"),
            2_000,
            || {
                std::hint::black_box(scalar.eval_avg_scalar(&p, len, trials, 42));
            },
        );
        let per_w = timed(
            &format!("wide   eval_avg L={len} T={trials} ({name})"),
            2_000,
            || {
                std::hint::black_box(wide.eval_avg(&p, len, trials, 42, &mut st));
            },
        );
        let tput_s = trials as f64 / per_s;
        let tput_w = trials as f64 / per_w;
        println!(
            "{:<52} {:>11.2}x  ({:.2} → {:.2} Mtrials/s)\n",
            format!("  → wide speedup ({name})"),
            per_s / per_w,
            tput_s / 1e6,
            tput_w / 1e6
        );
        rows.push(row(
            &format!("eval_avg_scalar/{name}/L{len}/T{trials}"),
            per_s * 1e6,
            tput_s,
            "trials/s",
        ));
        rows.push(row(
            &format!("eval_avg_wide/{name}/L{len}/T{trials}"),
            per_w * 1e6,
            tput_w,
            "trials/s",
        ));
        rows.push(row(
            &format!("speedup/{name}/L{len}/T{trials}"),
            0.0,
            per_s / per_w,
            "x",
        ));
    }

    // Full-word shape: 64 trials per pass (no idle lanes), hardware mode.
    let scalar = BitLevelSmurf::new(cfg.clone(), &w, EntropyMode::SharedLfsr);
    let wide = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
    let mut st = wide.make_run_state();
    let per_s64 = timed("scalar eval_avg L=64 T=64 (shared_lfsr)", 1_000, || {
        std::hint::black_box(scalar.eval_avg_scalar(&p, 64, 64, 7));
    });
    let per_w64 = timed("wide   eval_avg L=64 T=64 (shared_lfsr)", 1_000, || {
        std::hint::black_box(wide.eval_avg(&p, 64, 64, 7, &mut st));
    });
    rows.push(row("eval_avg_scalar/shared_lfsr/L64/T64", per_s64 * 1e6, 64.0 / per_s64, "trials/s"));
    rows.push(row("eval_avg_wide/shared_lfsr/L64/T64", per_w64 * 1e6, 64.0 / per_w64, "trials/s"));
    rows.push(row("speedup/shared_lfsr/L64/T64", 0.0, per_s64 / per_w64, "x"));
    println!("{:<52} {:>11.2}x\n", "  → wide speedup (T=64, no idle lanes)", per_s64 / per_w64);

    // Simulated clock rate of the wide engine (64 lanes × L cycles/iter).
    let mcycles = 64.0 * 64.0 / per_w64 / 1e6;
    println!("{:<52} {:>12.1} Mcycles/s (lane-cycles)", "  → wide simulated clock rate", mcycles);
    rows.push(row("wide_lane_cycle_rate/shared_lfsr", 0.0, mcycles * 1e6, "lane-cycles/s"));

    // Coordinator batch shape: 64 distinct points, one trial each.
    let pts: Vec<Vec<f64>> = (0..64)
        .map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 7.0])
        .collect();
    let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
    let seeds: Vec<u64> = (0..64).map(|i| 0x5EED ^ i as u64).collect();
    let mut out = [0.0f64; 64];
    let per_batch_s = timed("scalar 64-point batch L=64 (shared_lfsr)", 1_000, || {
        for (i, pt) in refs.iter().enumerate() {
            out[i] = scalar.eval(pt, 64, seeds[i]);
        }
        std::hint::black_box(out[63]);
    });
    let per_batch_w = timed("wide   64-point batch L=64 (shared_lfsr)", 1_000, || {
        wide.eval_points(&refs, 64, &seeds, &mut st, &mut out);
        std::hint::black_box(out[63]);
    });
    rows.push(row("batch64_scalar/shared_lfsr/L64", per_batch_s * 1e6, 64.0 / per_batch_s, "points/s"));
    rows.push(row("batch64_wide/shared_lfsr/L64", per_batch_w * 1e6, 64.0 / per_batch_w, "points/s"));
    rows.push(row("speedup/batch64/shared_lfsr/L64", 0.0, per_batch_s / per_batch_w, "x"));
    println!(
        "{:<52} {:>11.2}x\n",
        "  → wide speedup (coordinator batch shape)",
        per_batch_s / per_batch_w
    );

    // NN activation shape: a whole layer of SMURF tanh activations at
    // L=4096 — per-neuron scalar simulation vs the batched wide path the
    // SC forward passes now use. Two identically-synthesized instances
    // keep the per-instance seed counters in lockstep for the equality
    // gate.
    let act_scalar = SmurfActivation::tanh(4096, 4);
    let act_batched = SmurfActivation::tanh(4096, 4);
    let layer: Vec<f32> = (0..120).map(|i| (i as f32 / 119.0) * 4.0 - 2.0).collect();
    let want: Vec<f32> = layer.iter().map(|&x| act_scalar.eval_bitlevel(x)).collect();
    let got = act_batched.eval_bitlevel_batch(&layer);
    assert_eq!(
        want, got,
        "FATAL: batched/scalar activation divergence — perf record aborted"
    );
    let per_act_s = timed("scalar per-neuron activation L=4096 B=120", 20, || {
        for &x in &layer {
            std::hint::black_box(act_scalar.eval_bitlevel(x));
        }
    });
    let per_act_w = timed("batched wide   activation L=4096 B=120", 20, || {
        std::hint::black_box(act_batched.eval_bitlevel_batch(&layer));
    });
    rows.push(row(
        "activation_scalar/tanh_n4/L4096/B120",
        per_act_s * 1e6,
        120.0 / per_act_s,
        "activations/s",
    ));
    rows.push(row(
        "activation_batched/tanh_n4/L4096/B120",
        per_act_w * 1e6,
        120.0 / per_act_w,
        "activations/s",
    ));
    rows.push(row("speedup/activation/L4096", 0.0, per_act_s / per_act_w, "x"));
    println!(
        "{:<52} {:>11.2}x  (acceptance floor: 4x)\n",
        "  → batched activation speedup (L=4096)",
        per_act_s / per_act_w
    );
    // Enforced acceptance criterion (ISSUE 3): the batched path must show
    // ≥ 4x throughput over per-neuron scalar at L=4096. Throughput floors
    // are DEFERRED until after the perf record is written (a slow runner
    // still exits non-zero but keeps its measured rows); a noisy or
    // underpowered runner (e.g. CI perf-smoke) opts out entirely with
    // BENCH_NO_ENFORCE=1. The bit-equality gates above are never
    // skippable and always abort before the record exists.
    let mut floor_failures: Vec<String> = Vec::new();
    if per_act_s / per_act_w < 4.0 {
        floor_failures.push(format!(
            "batched activation speedup {:.2}x below the 4x acceptance floor",
            per_act_s / per_act_w
        ));
    }

    // Plane-width sweep: the identical bit-slicing scheme at 64, 256 and
    // (with `wide512`) 512 lanes per plane word, on the tanh activation
    // workload. Every width is equality-gated against the scalar
    // reference before timing.
    println!(
        "=== Plane-width sweep: u64 vs u64x4{} (tanh N=4) ===\n",
        if cfg!(feature = "wide512") { " vs u64x8" } else { "" }
    );
    let tanh_cfg = SmurfConfig::uniform(1, 4);
    let tanh_res =
        synthesize(&tanh_cfg, &functions::tanh_bipolar(2.0), &SynthOptions::default());
    let tanh_scalar = BitLevelSmurf::new(
        tanh_cfg,
        tanh_res.smurf.coefficients(),
        EntropyMode::SharedLfsr,
    );
    let (avg_u64, batch_u64) = sweep_plane::<u64>("u64", &tanh_scalar, &mut rows);
    let (avg_u64x4, batch_u64x4) = sweep_plane::<[u64; 4]>("u64x4", &tanh_scalar, &mut rows);
    #[cfg(feature = "wide512")]
    sweep_plane::<[u64; 8]>("u64x8", &tanh_scalar, &mut rows);
    let plane_ratio = avg_u64 / avg_u64x4;
    rows.push(row("speedup/plane/u64x4_vs_u64/eval_avg_L4096", 0.0, plane_ratio, "x"));
    rows.push(row(
        "speedup/plane/u64x4_vs_u64/batch_L4096",
        0.0,
        batch_u64 / batch_u64x4,
        "x",
    ));
    println!(
        "{:<52} {:>11.2}x  (acceptance floor: 2x)\n",
        "  → u64x4 plane speedup (eval_avg L=4096)", plane_ratio
    );
    // Enforced acceptance criterion (ISSUE 4): the 256-lane plane must
    // reach ≥ 2x the 64-lane plane's trials/s on the L=4096 eval_avg row
    // (relies on AVX2/NEON autovectorization of the [u64; 4] ops).
    // Deferred like the activation floor so the record survives a slow
    // runner.
    if plane_ratio < 2.0 {
        floor_failures.push(format!(
            "u64x4 plane speedup {plane_ratio:.2}x below the 2x acceptance floor"
        ));
    }

    // SC-PwMM sweep: the CNN conv/dense multiply workload — B bipolar
    // products on L=128 streams (the paper's SC-PwMM length), scalar
    // `Exact` (one `mul_bipolar` per product, allocation-free scratch
    // pair) vs the plane-form engine at every compiled width. Every
    // width is equality-gated product-for-product before timing; the
    // `ScContext` batched route is additionally gated so the NN layers'
    // actual entry point is covered, not just the raw kernel.
    println!(
        "=== SC-PwMM sweep: scalar Exact vs plane-form wide (L=128) ===\n"
    );
    let b_prod = 1024usize;
    let l_stream = 128usize;
    let pxs: Vec<f32> = (0..b_prod).map(|i| ((i * 37) % 199) as f32 / 99.0 - 1.0).collect();
    let pws: Vec<f32> = (0..b_prod).map(|i| 1.0 - ((i * 53) % 193) as f32 / 96.0).collect();
    let mut scalar_ctx = ScContext::new(l_stream, ScMode::Exact, 2024);
    let pwmm_seed0 = scalar_ctx.stream_seed();
    let mut pwmm_want = vec![0.0f32; b_prod];
    for (o, (&x, &w)) in pwmm_want.iter_mut().zip(pxs.iter().zip(&pws)) {
        *o = scalar_ctx.mul_bipolar(x, w);
    }
    let mut batch_ctx = ScContext::new(l_stream, ScMode::Exact, 2024);
    let mut pwmm_got = vec![0.0f32; b_prod];
    batch_ctx.mul_bipolar_batch(&pxs, &pws, &mut pwmm_got);
    assert_eq!(
        pwmm_want, pwmm_got,
        "FATAL: ScContext batched PwMM diverges from scalar Exact — perf record aborted"
    );
    let per_pwmm_s = timed(&format!("scalar Exact mul_bipolar L={l_stream} B={b_prod}"), 50, || {
        for (&x, &w) in pxs.iter().zip(&pws) {
            std::hint::black_box(scalar_ctx.mul_bipolar(x, w));
        }
    });
    rows.push(row(
        &format!("pwmm_sweep/scalar_exact/L{l_stream}/B{b_prod}"),
        per_pwmm_s * 1e6,
        b_prod as f64 / per_pwmm_s,
        "MAC/s",
    ));
    let per_pwmm_u64 =
        sweep_pwmm::<u64>("u64", &pxs, &pws, l_stream, pwmm_seed0, &pwmm_want, &mut rows);
    let per_pwmm_u64x4 =
        sweep_pwmm::<[u64; 4]>("u64x4", &pxs, &pws, l_stream, pwmm_seed0, &pwmm_want, &mut rows);
    #[cfg(feature = "wide512")]
    sweep_pwmm::<[u64; 8]>("u64x8", &pxs, &pws, l_stream, pwmm_seed0, &pwmm_want, &mut rows);
    let pwmm_ratio = per_pwmm_s / per_pwmm_u64;
    rows.push(row("speedup/pwmm/u64_vs_scalar/L128", 0.0, pwmm_ratio, "x"));
    rows.push(row(
        "speedup/pwmm/u64x4_vs_scalar/L128",
        0.0,
        per_pwmm_s / per_pwmm_u64x4,
        "x",
    ));
    println!(
        "{:<52} {:>11.2}x  (acceptance floor: 4x)\n",
        "  → wide PwMM speedup (u64, L=128)", pwmm_ratio
    );
    println!(
        "{:<52} {:>8.2} → {:.2} MMAC/s\n",
        "  → SC-PwMM throughput (scalar → wide u64x4)",
        b_prod as f64 / per_pwmm_s / 1e6,
        b_prod as f64 / per_pwmm_u64x4 / 1e6
    );
    // Enforced acceptance criterion (ISSUE 5): the 64-lane plane-form
    // PwMM must reach ≥ 4x the scalar Exact path's MAC/s at L=128.
    // Deferred like the other floors (the record survives a slow runner;
    // BENCH_NO_ENFORCE=1 opts out); the equality gates above are not
    // skippable. NOTE: the xorshift64* entropy does not bit-slice (lanes
    // step scalarly), so this floor leans on the batch eliminating
    // per-product stream materialization and amortizing decode — it has
    // never been measured on real hardware and may need recalibrating on
    // the first cargo-equipped runner.
    if pwmm_ratio < 4.0 {
        floor_failures.push(format!(
            "wide-u64 PwMM speedup {pwmm_ratio:.2}x below the 4x acceptance floor"
        ));
    }

    // Degraded-mode serving (ISSUE 6): the same BitLevel request served
    // healthy (bit-level engine, L=4096) vs under load shedding (forced
    // via the admission hook), where it is rewritten to the analytic
    // closed form and flagged `degraded`. Both routes run through the
    // full serving stack (submit → admission → batcher → worker), so the
    // ratio is the real capacity a shedding server buys per request.
    // Equality gates before timing, as everywhere: the healthy route must
    // reproduce the direct `eval_bitstream(p, L, 0x5EED ^ i)` streams
    // bit-exactly, the degraded route must equal `eval_analytic` exactly
    // and carry the flag.
    println!("=== Degraded-mode serving: BitLevel vs forced Analytic fallback ===\n");
    let serve_func =
        SmurfApproximator::from_coefficients("euclidean2", cfg.clone(), w.clone(), 64);
    let serve_ref =
        SmurfApproximator::from_coefficients("euclidean2", cfg.clone(), w.clone(), 64);
    let server = EvalServer::start(
        vec![serve_func],
        None,
        ServerConfig {
            workers: 2,
            // A single closed-loop client: flush each request immediately
            // so both routes pay the same (minimal) batching overhead.
            policy: BatchPolicy {
                max_batch: 1,
                max_wait: std::time::Duration::from_micros(1),
            },
            ..ServerConfig::default()
        },
    );
    let (serve_b, serve_l) = (64usize, 4096usize);
    let serve_pts: Vec<Vec<f64>> = (0..serve_b)
        .map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 7.0])
        .collect();
    let healthy = server.eval_sync("euclidean2", serve_pts.clone(), Engine::BitLevel, serve_l);
    assert!(healthy.is_ok() && !healthy.degraded, "healthy BitLevel route must serve");
    for (i, p) in serve_pts.iter().enumerate() {
        assert_eq!(
            healthy.outputs[i],
            serve_ref.eval_bitstream(p, serve_l, 0x5EED ^ i as u64),
            "FATAL: served BitLevel diverges from direct simulation — perf record aborted"
        );
    }
    server.admission().force_shed(true);
    let degraded = server.eval_sync("euclidean2", serve_pts.clone(), Engine::BitLevel, serve_l);
    assert!(degraded.is_ok(), "{:?}", degraded.error);
    assert!(degraded.degraded, "FATAL: shedding route must flag the response");
    for (i, p) in serve_pts.iter().enumerate() {
        assert_eq!(
            degraded.outputs[i],
            serve_ref.eval_analytic(p),
            "FATAL: degraded output diverges from the analytic closed form — record aborted"
        );
    }
    server.admission().force_shed(false);
    let per_serve_bl = timed(
        &format!("served BitLevel L={serve_l} B={serve_b} (healthy)"),
        100,
        || {
            let r = server.eval_sync("euclidean2", serve_pts.clone(), Engine::BitLevel, serve_l);
            assert!(r.is_ok() && !r.degraded);
            std::hint::black_box(r.outputs[serve_b - 1]);
        },
    );
    server.admission().force_shed(true);
    let per_serve_an = timed(
        &format!("served fallback L={serve_l} B={serve_b} (shedding)"),
        100,
        || {
            let r = server.eval_sync("euclidean2", serve_pts.clone(), Engine::BitLevel, serve_l);
            assert!(r.is_ok() && r.degraded);
            std::hint::black_box(r.outputs[serve_b - 1]);
        },
    );
    server.admission().force_shed(false);
    rows.push(row(
        &format!("degraded_mode/bitlevel/L{serve_l}/B{serve_b}"),
        per_serve_bl * 1e6,
        serve_b as f64 / per_serve_bl,
        "points/s",
    ));
    rows.push(row(
        &format!("degraded_mode/analytic_fallback/B{serve_b}"),
        per_serve_an * 1e6,
        serve_b as f64 / per_serve_an,
        "points/s",
    ));
    let shed_ratio = per_serve_bl / per_serve_an;
    rows.push(row("speedup/degraded_mode/fallback_vs_bitlevel", 0.0, shed_ratio, "x"));
    println!(
        "{:<52} {:>11.2}x  (acceptance floor: 2x)\n",
        "  → shed-mode capacity gain (fallback vs BitLevel)", shed_ratio
    );
    // Enforced acceptance criterion (ISSUE 6): shedding only makes sense
    // if the fallback buys real capacity — ≥ 2x served points/s over the
    // healthy BitLevel route at L=4096. Deferred like the other floors
    // (never measured on real hardware; BENCH_NO_ENFORCE=1 opts out); the
    // equality/flag gates above are not skippable.
    if shed_ratio < 2.0 {
        floor_failures.push(format!(
            "degraded-mode capacity gain {shed_ratio:.2}x below the 2x acceptance floor"
        ));
    }
    server.shutdown();

    // Emit the machine-readable perf record. Cargo runs bench binaries
    // with cwd = the package root (rust/), so default to the repo root
    // explicitly; BENCH_OUT overrides.
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_perf.json", env!("CARGO_MANIFEST_DIR"))
    });
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Json::Str("smurf-bench-v1".into()));
    doc.insert(
        "config".into(),
        Json::Str("euclidean2 M=2 N=4 (QP-synthesized), eval_avg shapes".into()),
    );
    doc.insert("rows".into(), Json::Arr(rows));
    match std::fs::write(&out_path, Json::Obj(doc).dump()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    // Throughput floors fire only now, AFTER the record is written: the
    // measured rows are never discarded, but an under-floor run still
    // exits non-zero unless the runner opted out with BENCH_NO_ENFORCE=1.
    if std::env::var("BENCH_NO_ENFORCE").is_err() && !floor_failures.is_empty() {
        panic!(
            "FATAL: acceptance floor(s) missed (record written; set BENCH_NO_ENFORCE=1 \
             on noisy runners): {}",
            floor_failures.join("; ")
        );
    }
    println!("\nperf_wide done");
}

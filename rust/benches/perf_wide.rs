//! §Perf: scalar vs bit-sliced wide SMURF simulation.
//!
//! Measures trial throughput of the Monte-Carlo estimator (`eval_avg`) on
//! the paper's Euclid M=2/N=4 configuration — the
//! `euclid_paper_accuracy_at_64_bits` workload shape (L=64, 32 trials per
//! point) — comparing the scalar one-bit-per-cycle simulator against the
//! 64-lane bit-sliced engine, for every entropy mode. Also measures the
//! coordinator-shaped batch (64 distinct points per pass).
//!
//! Wall-clock methodology as in perf_serve (criterion is not vendored):
//! warmup + N timed iterations. Results are printed and written as
//! machine-readable rows to `BENCH_perf.json` (override with `BENCH_OUT`)
//! so the perf trajectory is tracked per-PR:
//! `{"bench", "us_per_iter", "throughput", "unit"}`.

use smurf::prelude::*;
use smurf::smurf::sim::EntropyMode;
use smurf::util::json::Json;
use std::collections::BTreeMap;
use std::time::Instant;

fn timed<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<52} {:>12.3} us/iter", per * 1e6);
    per
}

fn row(bench: &str, us_per_iter: f64, throughput: f64, unit: &str) -> Json {
    let mut m = BTreeMap::new();
    m.insert("bench".into(), Json::Str(bench.into()));
    m.insert("us_per_iter".into(), Json::Num(us_per_iter));
    m.insert("throughput".into(), Json::Num(throughput));
    m.insert("unit".into(), Json::Str(unit.into()));
    Json::Obj(m)
}

fn mode_name(mode: EntropyMode) -> &'static str {
    match mode {
        EntropyMode::SharedLfsr => "shared_lfsr",
        EntropyMode::IndependentXorshift => "xorshift",
        EntropyMode::SobolCpt => "sobol_cpt",
    }
}

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let res = synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
    let w = res.smurf.coefficients().to_vec();
    let p = [0.3, 0.4];
    let (len, trials) = (64usize, 32usize);
    let mut rows: Vec<Json> = Vec::new();

    println!("=== §Perf: scalar vs wide (bit-sliced) SMURF, Euclid M=2 N=4 ===\n");
    for mode in [
        EntropyMode::SharedLfsr,
        EntropyMode::IndependentXorshift,
        EntropyMode::SobolCpt,
    ] {
        let scalar = BitLevelSmurf::new(cfg.clone(), &w, mode);
        let wide = WideBitLevelSmurf::from_scalar(&scalar);
        let mut st = wide.make_run_state();

        // Sanity: the two engines must agree bit-exactly before we
        // compare their speed.
        let a = scalar.eval_avg_scalar(&p, len, trials, 42);
        let b = wide.eval_avg(&p, len, trials, 42, &mut st);
        assert_eq!(a, b, "wide/scalar divergence in {mode:?}");

        let name = mode_name(mode);
        let per_s = timed(
            &format!("scalar eval_avg L={len} T={trials} ({name})"),
            2_000,
            || {
                std::hint::black_box(scalar.eval_avg_scalar(&p, len, trials, 42));
            },
        );
        let per_w = timed(
            &format!("wide   eval_avg L={len} T={trials} ({name})"),
            2_000,
            || {
                std::hint::black_box(wide.eval_avg(&p, len, trials, 42, &mut st));
            },
        );
        let tput_s = trials as f64 / per_s;
        let tput_w = trials as f64 / per_w;
        println!(
            "{:<52} {:>11.2}x  ({:.2} → {:.2} Mtrials/s)\n",
            format!("  → wide speedup ({name})"),
            per_s / per_w,
            tput_s / 1e6,
            tput_w / 1e6
        );
        rows.push(row(
            &format!("eval_avg_scalar/{name}/L{len}/T{trials}"),
            per_s * 1e6,
            tput_s,
            "trials/s",
        ));
        rows.push(row(
            &format!("eval_avg_wide/{name}/L{len}/T{trials}"),
            per_w * 1e6,
            tput_w,
            "trials/s",
        ));
        rows.push(row(
            &format!("speedup/{name}/L{len}/T{trials}"),
            0.0,
            per_s / per_w,
            "x",
        ));
    }

    // Full-word shape: 64 trials per pass (no idle lanes), hardware mode.
    let scalar = BitLevelSmurf::new(cfg.clone(), &w, EntropyMode::SharedLfsr);
    let wide = WideBitLevelSmurf::from_scalar(&scalar);
    let mut st = wide.make_run_state();
    let per_s64 = timed("scalar eval_avg L=64 T=64 (shared_lfsr)", 1_000, || {
        std::hint::black_box(scalar.eval_avg_scalar(&p, 64, 64, 7));
    });
    let per_w64 = timed("wide   eval_avg L=64 T=64 (shared_lfsr)", 1_000, || {
        std::hint::black_box(wide.eval_avg(&p, 64, 64, 7, &mut st));
    });
    rows.push(row("eval_avg_scalar/shared_lfsr/L64/T64", per_s64 * 1e6, 64.0 / per_s64, "trials/s"));
    rows.push(row("eval_avg_wide/shared_lfsr/L64/T64", per_w64 * 1e6, 64.0 / per_w64, "trials/s"));
    rows.push(row("speedup/shared_lfsr/L64/T64", 0.0, per_s64 / per_w64, "x"));
    println!("{:<52} {:>11.2}x\n", "  → wide speedup (T=64, no idle lanes)", per_s64 / per_w64);

    // Simulated clock rate of the wide engine (64 lanes × L cycles/iter).
    let mcycles = 64.0 * 64.0 / per_w64 / 1e6;
    println!("{:<52} {:>12.1} Mcycles/s (lane-cycles)", "  → wide simulated clock rate", mcycles);
    rows.push(row("wide_lane_cycle_rate/shared_lfsr", 0.0, mcycles * 1e6, "lane-cycles/s"));

    // Coordinator batch shape: 64 distinct points, one trial each.
    let pts: Vec<Vec<f64>> = (0..64)
        .map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 7.0])
        .collect();
    let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
    let seeds: Vec<u64> = (0..64).map(|i| 0x5EED ^ i as u64).collect();
    let mut out = [0.0f64; 64];
    let per_batch_s = timed("scalar 64-point batch L=64 (shared_lfsr)", 1_000, || {
        for (i, pt) in refs.iter().enumerate() {
            out[i] = scalar.eval(pt, 64, seeds[i]);
        }
        std::hint::black_box(out[63]);
    });
    let per_batch_w = timed("wide   64-point batch L=64 (shared_lfsr)", 1_000, || {
        wide.eval_points(&refs, 64, &seeds, &mut st, &mut out);
        std::hint::black_box(out[63]);
    });
    rows.push(row("batch64_scalar/shared_lfsr/L64", per_batch_s * 1e6, 64.0 / per_batch_s, "points/s"));
    rows.push(row("batch64_wide/shared_lfsr/L64", per_batch_w * 1e6, 64.0 / per_batch_w, "points/s"));
    rows.push(row("speedup/batch64/shared_lfsr/L64", 0.0, per_batch_s / per_batch_w, "x"));
    println!(
        "{:<52} {:>11.2}x\n",
        "  → wide speedup (coordinator batch shape)",
        per_batch_s / per_batch_w
    );

    // Emit the machine-readable perf record. Cargo runs bench binaries
    // with cwd = the package root (rust/), so default to the repo root
    // explicitly; BENCH_OUT overrides.
    let out_path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_perf.json", env!("CARGO_MANIFEST_DIR"))
    });
    let mut doc = BTreeMap::new();
    doc.insert("schema".into(), Json::Str("smurf-bench-v1".into()));
    doc.insert(
        "config".into(),
        Json::Str("euclidean2 M=2 N=4 (QP-synthesized), eval_avg shapes".into()),
    );
    doc.insert("rows".into(), Json::Arr(rows));
    match std::fs::write(&out_path, Json::Obj(doc).dump()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    println!("\nperf_wide done");
}

//! Table IV + Table V: MNIST-style test accuracy of vanilla CNN, CNN/HSC
//! and CNN/SMURF on shared LeNet-5 weights (paper §IV-B).
//!
//! Uses the L2-trained weights from `make artifacts` when present;
//! otherwise trains in-process with the rust trainer (same architecture,
//! same corpus generator). Paper reference: 99.67 / 98.04 / 98.42 %.
//! Absolute numbers differ (synthetic corpus, not MNIST); the reproduced
//! *shape* is vanilla ≥ SC variants with a small SC gap.

use smurf::data;
use smurf::nn::lenet::ScRuntime;
use smurf::nn::{train, LeNet, OpSet};
use smurf::runtime::default_artifacts_dir;
use std::time::Instant;

fn main() {
    let n_test = 300;
    let (_, test) = data::load_corpus(0, n_test, 42);

    let weights = default_artifacts_dir().join("lenet_weights.json");
    let net = LeNet::load(weights.to_str().unwrap()).unwrap_or_else(|e| {
        eprintln!("({e}) — training in-process");
        let (train_set, _) = data::load_corpus(2000, 0, 42);
        let mut net = LeNet::random(7);
        train::train(&mut net, &train_set, &train::TrainConfig::default(), 1);
        net
    });

    println!("=== Table V: implementation matrix ===");
    println!("{:<14} {:<34} {:<28}", "scheme", "convolution", "activations");
    println!("{:<14} {:<34} {:<28}", "vanilla CNN", "standard f32 convolution", "exact tanh + softmax");
    println!("{:<14} {:<34} {:<28}", "CNN/HSC", "SC-PwMM (128-bit XNOR streams)", "exact tanh + softmax");
    println!("{:<14} {:<34} {:<28}", "CNN/SMURF", "SC-PwMM (128-bit XNOR streams)", "SMURF tanh (64-bit streams)");

    println!("\n=== Table IV: test accuracy over {n_test} images ===");
    println!("{:<14} {:>10} {:>10} {:>14}", "scheme", "ours", "paper", "eval time");

    let t0 = Instant::now();
    let acc_v = net.accuracy(&test.images, &test.labels, OpSet::Vanilla, None);
    println!(
        "{:<14} {:>9.2}% {:>9.2}% {:>14?}",
        "vanilla CNN",
        acc_v * 100.0,
        99.67,
        t0.elapsed()
    );

    let mut rt = ScRuntime::paper_config(11);
    let t0 = Instant::now();
    let acc_h = net.accuracy(&test.images, &test.labels, OpSet::Hsc, Some(&mut rt));
    println!(
        "{:<14} {:>9.2}% {:>9.2}% {:>14?}",
        "CNN/HSC",
        acc_h * 100.0,
        98.04,
        t0.elapsed()
    );

    let mut rt = ScRuntime::paper_config(13);
    let t0 = Instant::now();
    let acc_s = net.accuracy(&test.images, &test.labels, OpSet::Smurf, Some(&mut rt));
    println!(
        "{:<14} {:>9.2}% {:>9.2}% {:>14?}",
        "CNN/SMURF",
        acc_s * 100.0,
        98.42,
        t0.elapsed()
    );

    // The reproducible claim: SC costs ≲ 2% accuracy.
    assert!(acc_v >= acc_s - 0.005, "vanilla should not trail CNN/SMURF");
    assert!(acc_s > acc_v - 0.03, "SC gap should stay small (paper: ~1.2%)");
    assert!(acc_h > acc_v - 0.03, "SC gap should stay small (paper: ~1.6%)");
    println!("\nshape check OK: vanilla ≥ SC variants, gap < 3%");
}

//! §Perf: end-to-end performance of the serving stack.
//!
//! Measures, with wall-clock timing (criterion is not vendored in this
//! offline environment — methodology: warmup + N timed iterations,
//! median-of-runs):
//!
//! 1. bit-level simulator cycle rate (the L3 hot loop),
//! 2. analytic evaluator throughput (scalar and batched),
//! 3. XLA kernel throughput (AOT Pallas path, batch 1024),
//! 4. coordinator end-to-end request throughput + latency percentiles,
//! 5. SC-PwMM MAC rate (the CNN hot path),
//! 6. resilient-client overhead (passthrough + retry-armed, both
//!    equality-gated against the direct server path) and hedged tail
//!    latency against a deterministically stalled worker.

use smurf::coordinator::batcher::BatchPolicy;
use smurf::coordinator::{
    ClientConfig, Engine, EvalServer, FaultInjector, HedgeConfig, HedgeDelay, ResilientClient,
    RetryPolicy, ServerConfig,
};
use smurf::nn::sc_ops::{ScContext, ScMode};
use smurf::prelude::*;
use smurf::runtime::default_artifacts_dir;
use smurf::util::stats::percentile_sorted;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn timed<F: FnMut()>(label: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<44} {:>12.3} us/iter", per * 1e6);
    per
}

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
    println!("=== §Perf: serving-stack microbenchmarks ===\n");

    // 1. Bit-level simulator.
    let p = [0.3, 0.4];
    let per64 = timed("bitlevel eval L=64 (SharedLfsr)", 20_000, || {
        std::hint::black_box(approx.eval_bitstream(&p, 64, 42));
    });
    println!("{:<44} {:>12.1} Mcycles/s", "  → simulated clock rate", 64.0 / per64 / 1e6);
    timed("bitlevel eval L=1024", 2_000, || {
        std::hint::black_box(approx.eval_bitstream(&p, 1024, 42));
    });

    // 2. Analytic evaluator.
    let per_a = timed("analytic eval (Eq. 21, M=2 N=4)", 200_000, || {
        std::hint::black_box(approx.eval_analytic(&p));
    });
    println!("{:<44} {:>12.2} Meval/s", "  → analytic throughput", 1.0 / per_a / 1e6);
    let batch: Vec<Vec<f64>> = (0..1024)
        .map(|i| vec![(i % 32) as f64 / 31.0, (i / 32) as f64 / 31.0])
        .collect();
    timed("analytic eval_batch (1024 points)", 500, || {
        std::hint::black_box(approx.analytic().eval_batch(&batch));
    });

    // 3. XLA kernel (AOT Pallas) — measured through the coordinator's
    //    dedicated owner thread, as served in production.
    let funcs = vec![SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64)];
    let server = Arc::new(EvalServer::start(
        funcs,
        Some(default_artifacts_dir()),
        ServerConfig::default(),
    ));
    let points: Vec<Vec<f64>> = batch.clone();
    let r = server.eval_sync("euclidean2", points.clone(), Engine::Xla, 64);
    if r.is_ok() {
        timed("XLA smurf_eval batch-1024 (via coordinator)", 200, || {
            let r = server.eval_sync("euclidean2", points.clone(), Engine::Xla, 64);
            assert!(r.is_ok());
        });
    } else {
        println!("XLA path skipped: {:?}", r.error);
    }

    // 4. Coordinator end-to-end under concurrent load.
    let n_clients = 8;
    let per_client = 400;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let x = ((c * 37 + i) % 101) as f64 / 100.0;
                let r = s.eval_sync("euclidean2", vec![vec![x, 1.0 - x]], Engine::Analytic, 64);
                assert!(r.is_ok());
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let total = (n_clients * per_client) as f64;
    println!(
        "{:<44} {:>12.0} req/s",
        "coordinator e2e (8 clients, sync)",
        total / dt
    );
    println!("\n{}", server.metrics().report());

    // 5. SC-PwMM MAC rate (CNN hot path).
    let mut ctx = ScContext::new(128, ScMode::Binomial, 5);
    let xs: Vec<f32> = (0..400).map(|i| ((i % 13) as f32 / 13.0) * 2.0 - 1.0).collect();
    let ws: Vec<f32> = (0..400).map(|i| ((i % 7) as f32 / 7.0) * 2.0 - 1.0).collect();
    let per_dot = timed("SC-PwMM dot-400 (binomial, L=128)", 2_000, || {
        std::hint::black_box(ctx.dot_bipolar(&xs, &ws));
    });
    println!(
        "{:<44} {:>12.2} MMAC/s",
        "  → SC MAC rate",
        400.0 / per_dot / 1e6
    );

    // 6. Resilient client: ladder overhead and hedged tail latency.
    //    Every row is equality-gated — the client must serve the exact
    //    bits the direct path serves, or the measurement is meaningless.
    println!();
    let p1 = vec![vec![0.3, 0.4]];
    let direct_ref = server.eval_sync("euclidean2", p1.clone(), Engine::Analytic, 64);
    assert!(direct_ref.is_ok());
    let gate = |r: &smurf::coordinator::EvalResponse| {
        assert!(r.is_ok(), "{:?}", r.error);
        assert_eq!(r.outputs.len(), direct_ref.outputs.len());
        for (a, b) in r.outputs.iter().zip(&direct_ref.outputs) {
            assert_eq!(a.to_bits(), b.to_bits(), "client row diverged from direct path");
        }
    };
    timed("direct eval_sync (analytic, baseline)", 5_000, || {
        let r = server.eval_sync("euclidean2", p1.clone(), Engine::Analytic, 64);
        std::hint::black_box(gate(&r));
    });
    let passthrough = ResilientClient::new(server.as_ref(), ClientConfig::default());
    timed("resilient client, passthrough (default)", 5_000, || {
        let r = passthrough.eval("euclidean2", p1.clone(), Engine::Analytic, 64);
        std::hint::black_box(gate(&r));
    });
    drop(passthrough);
    let armed = ResilientClient::new(
        server.as_ref(),
        ClientConfig { retry: Some(RetryPolicy::default()), ..ClientConfig::default() },
    );
    timed("resilient client, retry-armed (no faults)", 5_000, || {
        let r = armed.eval("euclidean2", p1.clone(), Engine::Analytic, 64);
        std::hint::black_box(gate(&r));
    });
    drop(armed);

    // Hedged tail: a dedicated 2-worker server whose injector stalls the
    // primary attempt of each measured request; the hedge must cut the
    // tail far below the stall.
    let stall = Duration::from_millis(30);
    let faults = Arc::new(FaultInjector::new());
    let hedge_server = EvalServer::start(
        vec![SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64)],
        None,
        ServerConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_micros(200) },
            faults: faults.clone(),
            ..ServerConfig::default()
        },
    );
    let hedged = ResilientClient::new(
        &hedge_server,
        ClientConfig {
            hedge: Some(HedgeConfig { delay: HedgeDelay::Fixed(Duration::from_millis(2)) }),
            ..ClientConfig::default()
        },
    );
    let bits_ref = hedge_server.eval_sync("euclidean2", p1.clone(), Engine::BitLevel, 256);
    assert!(bits_ref.is_ok());
    let mut lat_ms: Vec<f64> = Vec::new();
    for _ in 0..40 {
        faults.arm_stall_on_batch(1, stall); // the primary stalls; the hedge races past
        let t = Instant::now();
        let r = hedged.eval_with_timeout(
            "euclidean2",
            p1.clone(),
            Engine::BitLevel,
            256,
            Duration::from_secs(5),
        );
        lat_ms.push(t.elapsed().as_secs_f64() * 1e3);
        assert!(r.is_ok(), "{:?}", r.error);
        assert_eq!(r.outputs[0].to_bits(), bits_ref.outputs[0].to_bits());
        // Let the stalled loser finish so the next arm targets a fresh batch.
        let audit = hedged.drain_hedge_audits(Duration::from_secs(2));
        assert_eq!(audit.mismatched, 0, "hedge losers must stay bit-identical");
    }
    lat_ms.sort_by(|a, b| a.total_cmp(b));
    println!(
        "{:<44} {:>8.2} / {:>8.2} ms (stall {} ms)",
        "hedged tail p50/p99 vs stalled primary",
        percentile_sorted(&lat_ms, 50.0),
        percentile_sorted(&lat_ms, 99.0),
        stall.as_millis()
    );
    let hsnap = hedge_server.metrics();
    println!(
        "{:<44} {:>6} hedges, {:>4} wins, {:>4} verified, {} mismatches",
        "  → hedge accounting",
        hsnap.client_hedges,
        hsnap.client_hedge_wins,
        hsnap.client_hedge_verified,
        hsnap.client_hedge_mismatches
    );
    assert_eq!(hsnap.client_hedge_mismatches, 0);
    drop(hedged);
    hedge_server.shutdown();

    if let Ok(s) = Arc::try_unwrap(server) {
        s.shutdown();
    }
    println!("\nperf_serve done");
}

//! Fig. 10: SMURF approximating the three bivariate targets at 64-bit
//! streams — (a) Euclidean distance, (b) the HT kernel sin(x₁)cos(x₂),
//! (c) bivariate softmax.
//!
//! Paper anchors: MAE ≈ 0.032, 0.032 and 0.014 respectively (softmax is
//! smoother, hence smaller error).

use smurf::prelude::*;
use smurf::smurf::sim::{BitLevelSmurf, EntropyMode};

fn surface_mae(sim: &BitLevelSmurf, f: &TargetFn, len: usize, grid: usize, trials: usize) -> f64 {
    let mut total = 0.0;
    let mut count = 0;
    for i in 0..grid {
        for j in 0..grid {
            let p = [i as f64 / (grid - 1) as f64, j as f64 / (grid - 1) as f64];
            total += sim.abs_error(&p, f.eval(&p), len, trials, 777 + (i * grid + j) as u64);
            count += 1;
        }
    }
    total / count as f64
}

fn main() {
    let cfg = SmurfConfig::uniform(2, 4);
    let cases = [
        (functions::euclidean2(), 0.032),
        (functions::sincos(), 0.032),
        (functions::softmax2(), 0.014),
    ];
    println!("=== Fig. 10: bivariate surfaces at L=64 ===\n");
    println!(
        "{:<12} {:>10} {:>10} {:>10} {:>8}",
        "function", "analytic", "MAE@64", "paper", "shape"
    );
    let mut results = Vec::new();
    for (f, paper) in &cases {
        let res = synthesize(&cfg, f, &SynthOptions::default());
        // Sobol (low-discrepancy) CPT sampling — the configuration that
        // reaches the paper's 64-bit accuracy (§II-B mentions Sobol
        // θ-gates explicitly; see EXPERIMENTS.md for the noise-floor
        // analysis that makes it necessary).
        let sim = BitLevelSmurf::new(
            cfg.clone(),
            res.smurf.coefficients(),
            EntropyMode::SobolCpt,
        );
        let mae = surface_mae(&sim, f, 64, 9, 16);
        let ok = mae < paper * 2.5;
        println!(
            "{:<12} {:>10.4} {:>10.4} {:>10.3} {:>8}",
            f.name(),
            res.mae,
            mae,
            paper,
            if ok { "OK" } else { "OFF" }
        );
        results.push((f.name().to_string(), mae, ok));
    }
    // The paper's qualitative finding: softmax2 (smoothest) is the most
    // accurate of the three.
    let softmax_mae = results[2].1;
    assert!(
        softmax_mae <= results[0].1 + 0.01 && softmax_mae <= results[1].1 + 0.01,
        "softmax should be the smoothest/most accurate surface"
    );
    assert!(results.iter().all(|r| r.2), "some surface error is out of regime");

    // Ablation: entropy wiring (the LFSR vs LDS trade, §II-B).
    println!("\n--- ablation: entropy mode vs MAE@64 ---");
    println!("{:<12} {:>12} {:>12} {:>12}", "function", "SharedLfsr", "Xorshift", "SobolCpt");
    for (f, _) in &cases {
        let res = synthesize(&cfg, f, &SynthOptions::default());
        let mut row = format!("{:<12}", f.name());
        for mode in [
            EntropyMode::SharedLfsr,
            EntropyMode::IndependentXorshift,
            EntropyMode::SobolCpt,
        ] {
            let sim = BitLevelSmurf::new(cfg.clone(), res.smurf.coefficients(), mode);
            row += &format!(" {:>12.4}", surface_mae(&sim, f, 64, 9, 8));
        }
        println!("{row}");
    }

    // Sample surface print (euclidean2) for plotting.
    println!("\n--- euclidean2 surface (analytic), 9×9 ---");
    let res = synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
    for i in 0..9 {
        for j in 0..9 {
            let p = [i as f64 / 8.0, j as f64 / 8.0];
            print!("{:6.3} ", res.smurf.eval(&p));
        }
        println!();
    }
    println!("\nfig10 OK");
}

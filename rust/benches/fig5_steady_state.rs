//! Fig. 5: steady-state probabilities of 2-, 3-, 4- and 5-state chain
//! FSMs as a function of the input probability — the analytic curves
//! (Eq. 4) cross-validated against long-run empirical occupancy of the
//! bit-level chain.

use smurf::fsm::chain::ChainFsm;
use smurf::fsm::steady::steady_state;
use smurf::util::prng::Pcg;

fn main() {
    // Analytic curves, printed as plot-ready series.
    for n in [2usize, 3, 4, 5] {
        println!("=== Fig. 5: N={n} — steady-state probabilities π_i(P_x) ===");
        print!("{:>6}", "P_x");
        for i in 0..n {
            print!(" {:>9}", format!("pi_{i}"));
        }
        println!();
        for k in 0..=20 {
            let p = k as f64 / 20.0;
            let pi = steady_state(n, p);
            print!("{:>6.2}", p);
            for v in &pi {
                print!(" {:>9.5}", v);
            }
            println!();
        }
        println!();
    }

    // Empirical cross-validation at a few interior points.
    println!("--- empirical occupancy vs analytic (2M cycles) ---");
    println!("{:>3} {:>6} {:>12} {:>12}", "N", "P_x", "max |Δ|", "verdict");
    for n in [2usize, 3, 4, 5] {
        for &p in &[0.25, 0.5, 0.75] {
            let mut fsm = ChainFsm::centered(n);
            let mut rng = Pcg::new((n * 1000) as u64 + (p * 100.0) as u64);
            let cycles = 2_000_000u64;
            let mut occ = vec![0u64; n];
            for _ in 0..1000 {
                fsm.step(rng.uniform() < p);
            }
            for _ in 0..cycles {
                occ[fsm.step(rng.uniform() < p)] += 1;
            }
            let pi = steady_state(n, p);
            let max_d = occ
                .iter()
                .zip(&pi)
                .map(|(&c, &a)| (c as f64 / cycles as f64 - a).abs())
                .fold(0.0f64, f64::max);
            println!(
                "{:>3} {:>6.2} {:>12.5} {:>12}",
                n,
                p,
                max_d,
                if max_d < 0.005 { "OK" } else { "DEVIATES" }
            );
            assert!(max_d < 0.005, "N={n} p={p}: empirical deviates by {max_d}");
        }
    }
    println!("\nFig. 5 shape checks: 2-state is linear; middle states are humps.");
    let pi2 = steady_state(2, 0.3);
    assert!((pi2[1] - 0.3).abs() < 1e-12);
    for n in [3, 4, 5] {
        for mid in 1..n - 1 {
            assert_eq!(steady_state(n, 0.0)[mid], 0.0);
            assert_eq!(steady_state(n, 1.0)[mid], 0.0);
            assert!(steady_state(n, 0.5)[mid] > 0.0);
        }
    }
    println!("fig5 OK");
}

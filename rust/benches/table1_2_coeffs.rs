//! Tables I & II: synthesized coefficient tables for √(x₁²+x₂²) and
//! sin(x₁)cos(x₂) (N=4, M=2), printed side by side with the paper's
//! published values, plus both tables' objectives under the paper's own
//! Eq. 5 quadratic and their grid MAE under Eq. 21.
//!
//! Reproduction finding (EXPERIMENTS.md): the published tables are not
//! minimizers of the paper's own optimization problem — our QP solution
//! dominates them by a wide margin and matches the accuracy the paper
//! *reports* (≈0.032 bit-level MAE at 64-bit streams).

use smurf::prelude::*;
use smurf::synth::paper_tables::{TABLE1_EUCLID, TABLE2_SINCOS};
use smurf::synth::qp::objective;
use smurf::synth::quadrature::{c_vector, h_matrix};
use std::time::Instant;

fn grid_mae(s: &smurf::smurf::analytic::AnalyticSmurf, f: &TargetFn, grid: usize) -> f64 {
    let mut total = 0.0;
    for i in 0..grid {
        for j in 0..grid {
            let p = [i as f64 / (grid - 1) as f64, j as f64 / (grid - 1) as f64];
            total += (s.eval(&p) - f.eval(&p)).abs();
        }
    }
    total / (grid * grid) as f64
}

fn run(f: &TargetFn, paper: &[f64; 16], label: &str) {
    let cfg = SmurfConfig::uniform(2, 4);
    let t0 = Instant::now();
    let res = synthesize(&cfg, f, &SynthOptions::default());
    let dt = t0.elapsed();
    let ours = res.smurf.coefficients();

    println!("=== {label}: w_t (t = i1 + 4·i2), synthesized in {dt:?} ===");
    println!("{:>4} {:>12} {:>12}", "t", "ours", "paper");
    for t in 0..16 {
        println!("{:>4} {:>12.4} {:>12.4}", t, ours[t], paper[t]);
    }

    let h = h_matrix(&cfg, 32);
    let g = f.as_fn();
    let c = c_vector(&cfg, &g, 32);
    let paper_analytic =
        smurf::smurf::analytic::AnalyticSmurf::new(cfg.clone(), paper.to_vec());
    println!(
        "\nEq. 5 objective (lower = better):  ours {:.6}   paper {:.6}",
        objective(&h, &c, ours),
        objective(&h, &c, paper)
    );
    println!(
        "Eq. 21 grid MAE (41×41):           ours {:.4}   paper {:.4}",
        grid_mae(&res.smurf, f, 41),
        grid_mae(&paper_analytic, f, 41)
    );
    println!(
        "QP: {} iterations, KKT residual {:.1e}\n",
        res.qp.iterations, res.qp.kkt_residual
    );
}

fn main() {
    run(&functions::euclidean2(), &TABLE1_EUCLID, "Table I  (euclidean2)");
    run(&functions::sincos(), &TABLE2_SINCOS, "Table II (sincos)");
}

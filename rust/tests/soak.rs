//! Randomized robustness entry points (see `smurf::testutil` and
//! docs/INVARIANTS.md § Randomized robustness harness).
//!
//! Two tiers:
//!
//! - `differential_oracle_fuzz_smoke` runs in tier-1 time and is always
//!   on: N seeded cases through the differential oracle (`make
//!   fuzz-smoke`, or plain `cargo test --test soak`). Any failure prints
//!   a minimized seed + config repro produced by the shrinker.
//! - `chaos_soak` is `#[ignore]`d by default and driven by
//!   `make soak SOAK_ROUNDS=… SOAK_SEED=…`: full randomized
//!   server/client/fault rounds with global invariant audits and an
//!   identical-seed replay check per round.
//!
//! Every knob comes from the environment so a failing seed pasted from
//! a report reproduces the exact run:
//!
//! ```text
//! FUZZ_SEED=0x1234 FUZZ_CASES=64   cargo test --test soak differential
//! SOAK_SEED=0x1234 SOAK_ROUNDS=25  cargo test --test soak -- --ignored
//! ```

use smurf::testutil::{run_seeded, run_soak, SoakOptions};

/// Parse an env var as u64 (decimal or 0x-hex); absent or empty (as the
/// Makefile passes undefined knobs) falls back to the default.
fn env_u64(name: &str, default: u64) -> u64 {
    match std::env::var(name) {
        Ok(v) if !v.trim().is_empty() => {
            let v = v.trim();
            let parsed = if let Some(hex) = v.strip_prefix("0x") {
                u64::from_str_radix(hex, 16)
            } else {
                v.parse::<u64>()
            };
            parsed.unwrap_or_else(|_| panic!("{name}={v:?} is not a u64"))
        }
        _ => default,
    }
}

/// Differential oracle over seeded structured cases: scalar == every
/// plane width == TMR-at-rate-0 == armed-zero faults, bit for bit, plus
/// the bounded analytic relation — with shrinking on failure. Case
/// count defaults are sized for tier-1 time (debug builds are ~20×
/// slower than release, so they run fewer cases).
#[test]
fn differential_oracle_fuzz_smoke() {
    let default_cases = if cfg!(debug_assertions) { 12 } else { 64 };
    let cases = env_u64("FUZZ_CASES", default_cases) as usize;
    let seed = env_u64("FUZZ_SEED", 0xF0_5EED);
    match run_seeded(seed, cases) {
        Ok(n) => println!("fuzz smoke: {n} cases checked (seed={seed:#x})"),
        Err(report) => panic!("{report}"),
    }
}

/// Chaos soak: randomized serving stacks under randomized fault
/// schedules, audited for answered-exactly-once conservation, depth
/// drain, pool respawn, payload fidelity, sentinel/breaker legality,
/// and byte-identical identical-seed replay. Long-running; `#[ignore]`d
/// so plain `cargo test` stays fast. Drive with
/// `make soak SOAK_ROUNDS=25`.
#[test]
#[ignore = "long-running; drive with `make soak SOAK_ROUNDS=... SOAK_SEED=...`"]
fn chaos_soak() {
    let opts = SoakOptions {
        seed: env_u64("SOAK_SEED", SoakOptions::default().seed),
        rounds: env_u64("SOAK_ROUNDS", SoakOptions::default().rounds as u64) as usize,
        clients: env_u64("SOAK_CLIENTS", SoakOptions::default().clients as u64) as usize,
        requests_per_client: env_u64(
            "SOAK_REQUESTS",
            SoakOptions::default().requests_per_client as u64,
        ) as usize,
        replay: env_u64("SOAK_REPLAY", 1) != 0,
    };
    println!(
        "chaos soak: {} rounds × {} clients × {} calls (seed={:#x}, replay={})",
        opts.rounds, opts.clients, opts.requests_per_client, opts.seed, opts.replay
    );
    match run_soak(&opts) {
        Ok(reports) => {
            for r in &reports {
                println!("{}", r.render());
            }
            let compared: usize = reports.iter().map(|r| r.replay_compared).sum();
            println!(
                "chaos soak: {} rounds green, {} replay pairs byte-identical",
                reports.len(),
                compared
            );
        }
        Err(violation) => panic!("chaos soak failed:\n{violation}"),
    }
}

//! Deterministic boundary-input regressions (ISSUE 10 satellite):
//! the hostile corners the fuzzer *can* reach by luck, pinned here as
//! named tests so they are exercised on every tier-1 run regardless of
//! fuzz seeds — θ gate rows 0/65535, degenerate L=1 streams, signed
//! zero and subnormal inputs, and maximum-radix/maximum-state shapes —
//! across the scalar simulator, every compiled plane width, and the
//! analytic closed form (the full lattice runs through
//! `testutil::oracle::check_case`).
//!
//! One deliberate non-claim, documented because it is the classic trap:
//! a θ row of 1.0 quantizes to gate threshold 65535, which fires on
//! `rand16 < 65535` — an effective probability of 65535/65536, *not* a
//! constant-1 stream. Only the 0 row yields an exact constant stream,
//! so only the all-zero table gets exact-equality assertions on the
//! bit-level output.

use smurf::prelude::*;
use smurf::sc::sng::quantize_threshold;
use smurf::smurf::sim::EntropyMode;
use smurf::testutil::{check_case, FuzzCase};

const MODES: [EntropyMode; 3] =
    [EntropyMode::SharedLfsr, EntropyMode::IndependentXorshift, EntropyMode::SobolCpt];

/// A hand-built case over explicit radices/table/point; the lattice
/// (scalar == wide == TMR-0 == armed-zero) is then asserted by the
/// oracle exactly as for generated cases.
fn case(radices: Vec<usize>, w: Vec<f64>, point: Vec<f64>, len: usize, mode: EntropyMode) -> FuzzCase {
    FuzzCase {
        seed: 0xB0D4_0001,
        radices,
        w,
        mode,
        point,
        len,
        trials: 4,
        lattice_seeds: 4,
        plan: None,
    }
}

/// The quantization contract the gate-row tests stand on.
#[test]
fn theta_quantization_boundary_pins() {
    assert_eq!(quantize_threshold(0.0), 0);
    assert_eq!(quantize_threshold(-0.0), 0);
    assert_eq!(quantize_threshold(5e-324), 0, "subnormals round to the 0 row");
    assert_eq!(quantize_threshold(1.0), 65535, "w=1.0 is NOT an always-fire gate");
    assert_eq!(quantize_threshold(65535.0 / 65536.0), 65535);
    assert_eq!(quantize_threshold(0.5), 32768);
}

/// An all-zero θ table is the one exactly-constant stream: the gate
/// threshold is 0, `rand16 < 0` never fires, and the output is exactly
/// +0.0 at every L, every seed, every entropy mode, every engine.
#[test]
fn all_zero_table_is_exactly_zero_everywhere() {
    let cfg = SmurfConfig::uniform(2, 4);
    let states = cfg.num_aggregate_states();
    let w = vec![0.0; states];
    let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
    for mode in MODES {
        let sim = BitLevelSmurf::new(cfg.clone(), &w, mode);
        for len in [1usize, 63, 64, 65, 4096] {
            for seed in [0u64, 1, 0x5EED, u64::MAX] {
                for p in [[0.0, 0.0], [0.5, 0.5], [1.0, 1.0], [0.25, 0.75]] {
                    let y = sim.eval(&p, len, seed);
                    assert_eq!(y.to_bits(), 0.0f64.to_bits(), "mode={mode:?} L={len} seed={seed:#x} p={p:?}");
                }
            }
        }
        // Full lattice (wide planes, TMR, armed-zero) via the oracle.
        let c = case(vec![4, 4], w.clone(), vec![0.5, 0.5], 65, mode);
        if let Err(f) = check_case(&c) {
            panic!("all-zero table broke the lattice: {}", f.render());
        }
    }
    assert_eq!(analytic.eval(&[0.5, 0.5]).to_bits(), 0.0f64.to_bits());
}

/// The all-one table: the analytic form is 1.0 (within float summation
/// of the state distribution), the bit-level output sits within the
/// 65535/65536 quantization gap of 1.0, and the full lattice still
/// agrees bit-for-bit across engines.
#[test]
fn all_one_table_is_one_minus_quantization_gap() {
    let cfg = SmurfConfig::uniform(2, 4);
    let w = vec![1.0; cfg.num_aggregate_states()];
    let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
    let truth = analytic.eval(&[0.5, 0.5]);
    assert!((truth - 1.0).abs() < 1e-9, "analytic all-one table: {truth}");
    for mode in MODES {
        let sim = BitLevelSmurf::new(cfg.clone(), &w, mode);
        // Effective per-cycle fire probability is 65535/65536; over
        // L=4096 the deterministic outputs at these pinned seeds stay
        // within a generous multiple of the expected zero count.
        for seed in [0u64, 1, 0x5EED, 42] {
            let y = sim.eval(&[0.5, 0.5], 4096, seed);
            assert!(y > 0.99 && y <= 1.0, "mode={mode:?} seed={seed}: {y}");
        }
        let c = case(vec![4, 4], w.clone(), vec![0.5, 0.5], 64, mode);
        if let Err(f) = check_case(&c) {
            panic!("all-one table broke the lattice: {}", f.render());
        }
    }
}

/// Mixed boundary rows (0.0 and 1.0 in the same table) through the full
/// lattice at the lane-boundary lengths.
#[test]
fn mixed_boundary_rows_hold_the_lattice_at_lane_edges() {
    let cfg = SmurfConfig::uniform(2, 4);
    let states = cfg.num_aggregate_states();
    let mut w = vec![0.5; states];
    w[0] = 0.0;
    w[states - 1] = 1.0;
    for len in [1usize, 63, 64, 65] {
        let c = case(vec![4, 4], w.clone(), vec![0.25, 0.75], len, EntropyMode::SharedLfsr);
        if let Err(f) = check_case(&c) {
            panic!("boundary rows broke the lattice at L={len}: {}", f.render());
        }
    }
}

/// A one-cycle stream can only ever average to 0.0 or 1.0 — and the
/// whole lattice must agree on which, bit for bit.
#[test]
fn single_cycle_streams_are_zero_or_one() {
    let cfg = SmurfConfig::uniform(2, 4);
    let w: Vec<f64> = (0..cfg.num_aggregate_states())
        .map(|s| s as f64 / 15.0)
        .collect();
    for mode in MODES {
        let sim = BitLevelSmurf::new(cfg.clone(), &w, mode);
        for seed in 0..16u64 {
            let y = sim.eval(&[0.3, 0.9], 1, seed);
            assert!(
                y.to_bits() == 0.0f64.to_bits() || y.to_bits() == 1.0f64.to_bits(),
                "mode={mode:?} seed={seed}: L=1 output {y} is not a single bit"
            );
        }
        let c = case(vec![4, 4], w.clone(), vec![0.3, 0.9], 1, mode);
        if let Err(f) = check_case(&c) {
            panic!("L=1 broke the lattice: {}", f.render());
        }
    }
}

/// −0.0 and +0.0 inputs quantize to the same SNG threshold, so the
/// entire evaluation — not just the first bit — must be bit-identical.
/// Same for the smallest subnormal vs zero.
#[test]
fn signed_zero_and_subnormal_inputs_are_stream_identical() {
    let cfg = SmurfConfig::uniform(2, 4);
    let w: Vec<f64> = (0..cfg.num_aggregate_states())
        .map(|s| (s % 5) as f64 / 4.0)
        .collect();
    for mode in MODES {
        let sim = BitLevelSmurf::new(cfg.clone(), &w, mode);
        for len in [1usize, 64, 257] {
            for seed in [0u64, 7, 0x5EED] {
                let plus = sim.eval(&[0.0, 0.6], len, seed);
                let minus = sim.eval(&[-0.0, 0.6], len, seed);
                assert_eq!(plus.to_bits(), minus.to_bits(), "±0.0 diverged: mode={mode:?} L={len}");
                let sub = sim.eval(&[5e-324, 0.6], len, seed);
                assert_eq!(plus.to_bits(), sub.to_bits(), "5e-324 vs 0.0 diverged: mode={mode:?} L={len}");
            }
        }
    }
    // And the analytic closed form agrees with itself on signed zero.
    let analytic = AnalyticSmurf::new(cfg, w);
    assert_eq!(
        analytic.eval(&[0.0, 0.6]).to_bits(),
        analytic.eval(&[-0.0, 0.6]).to_bits()
    );
}

/// Maximum-radix digits (16) and the maximum aggregate-state shape the
/// fuzzer can generate (512 states) hold the full lattice.
#[test]
fn max_radix_and_max_state_shapes_hold_the_lattice() {
    // Radix-16 × radix-16: 256 states, digits 0..=15 on both variables.
    let w256: Vec<f64> = (0..256).map(|s| (s % 17) as f64 / 16.0).collect();
    let c = case(vec![16, 16], w256, vec![15.5 / 16.0, 1.0 / 16.0], 96, EntropyMode::SobolCpt);
    if let Err(f) = check_case(&c) {
        panic!("radix-16 shape broke the lattice: {}", f.render());
    }
    // 2 × 16 × 16 = 512 states — the generator's MAX_AGGREGATE_STATES.
    let w512: Vec<f64> = (0..512).map(|s| (s % 33) as f64 / 32.0).collect();
    let c = case(
        vec![2, 16, 16],
        w512,
        vec![1.0, 0.0, 0.5],
        64,
        EntropyMode::IndependentXorshift,
    );
    if let Err(f) = check_case(&c) {
        panic!("512-state shape broke the lattice: {}", f.render());
    }
}

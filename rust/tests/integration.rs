//! Cross-module integration tests: synthesis → simulation → serving,
//! weights interchange, runtime artifacts, and failure injection.

use smurf::coordinator::batcher::BatchPolicy;
use smurf::coordinator::{Engine, EvalServer, ServerConfig};
use smurf::data;
use smurf::nn::lenet::ScRuntime;
use smurf::nn::{train, LeNet, OpSet};
use smurf::prelude::*;
#[cfg(feature = "xla")]
use smurf::runtime::{default_artifacts_dir, Runtime};
use smurf::smurf::multi_output::softmax3_vector;
use smurf::smurf::sim::{BitLevelSmurf, EntropyMode};
use std::time::Duration;

/// Synthesis → analytic → bit-level: the three views agree within the
/// expected stochastic envelope for every paper function.
#[test]
fn synthesis_to_silicon_pipeline_agrees() {
    for f in [functions::euclidean2(), functions::softmax2(), functions::product2()] {
        let cfg = SmurfConfig::uniform(f.arity(), 4);
        let res = synthesize(&cfg, &f, &SynthOptions::default());
        let sim = BitLevelSmurf::new(
            cfg.clone(),
            res.smurf.coefficients(),
            EntropyMode::IndependentXorshift,
        );
        for &(a, b) in &[(0.2, 0.8), (0.5, 0.5), (0.9, 0.1)] {
            let p = [a, b];
            let target = f.eval(&p);
            let analytic = res.smurf.eval(&p);
            let hw = sim.eval_avg(&p, 4096, 8, 5);
            assert!(
                (analytic - target).abs() < 0.05,
                "{}: analytic {analytic} vs target {target}",
                f.name()
            );
            assert!(
                (hw - analytic).abs() < 0.02,
                "{}: hw {hw} vs analytic {analytic}",
                f.name()
            );
        }
    }
}

/// The serving layer returns the same numbers as direct evaluation.
#[test]
fn server_matches_direct_evaluation() {
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &functions::sincos(), 64);
    let direct: Vec<f64> = (0..10)
        .map(|i| approx.eval_analytic(&[i as f64 / 9.0, 0.4]))
        .collect();
    let server = EvalServer::start(
        vec![approx],
        None,
        ServerConfig {
            workers: 2,
            policy: BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200) },
            ..ServerConfig::default()
        },
    );
    let points: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 / 9.0, 0.4]).collect();
    let resp = server.eval_sync("sincos", points, Engine::Analytic, 64);
    assert!(resp.is_ok());
    for (got, want) in resp.outputs.iter().zip(&direct) {
        assert_eq!(got, want, "server must be bit-identical to direct eval");
    }
    server.shutdown();
}

/// Weights trained by the rust trainer survive the JSON round-trip and
/// give identical accuracy.
#[test]
fn weights_roundtrip_preserves_behaviour() {
    let (train_set, test_set) = data::load_corpus(120, 40, 7);
    let mut net = LeNet::random(3);
    train::train(
        &mut net,
        &train_set,
        &train::TrainConfig { epochs: 1, lr: 0.05, momentum: 0.9, log_every: 0 },
        1,
    );
    let json = net.to_json().dump();
    let net2 = LeNet::from_json(&smurf::util::json::Json::parse(&json).unwrap()).unwrap();
    let a1 = net.accuracy(&test_set.images, &test_set.labels, OpSet::Vanilla, None);
    let a2 = net2.accuracy(&test_set.images, &test_set.labels, OpSet::Vanilla, None);
    assert_eq!(a1, a2);
}

/// SC inference: longer streams monotonically approach vanilla accuracy
/// (statistically — checked with generous envelopes).
#[test]
fn sc_accuracy_improves_with_stream_length() {
    let (train_set, test_set) = data::load_corpus(300, 60, 11);
    let mut net = LeNet::random(5);
    train::train(
        &mut net,
        &train_set,
        &train::TrainConfig { epochs: 2, lr: 0.05, momentum: 0.9, log_every: 0 },
        2,
    );
    let vanilla = net.accuracy(&test_set.images, &test_set.labels, OpSet::Vanilla, None);
    let mut rt_short = ScRuntime::paper_config(1);
    rt_short.ctx.len = 8; // starve the streams
    let short = net.accuracy(&test_set.images, &test_set.labels, OpSet::Hsc, Some(&mut rt_short));
    let mut rt_long = ScRuntime::paper_config(1);
    rt_long.ctx.len = 1024;
    let long = net.accuracy(&test_set.images, &test_set.labels, OpSet::Hsc, Some(&mut rt_long));
    assert!(
        long + 0.05 >= short,
        "1024-bit streams ({long}) should not lose to 8-bit ({short})"
    );
    assert!(
        (long - vanilla).abs() < 0.15,
        "long streams ({long}) should approach vanilla ({vanilla})"
    );
}

/// Multi-output SMURF (paper §V extension): the vector generator serves
/// the full softmax and stays consistent with its scalar components.
#[test]
fn multi_output_vector_softmax() {
    let ms = softmax3_vector(4);
    let p = [0.2, 0.9, 0.5];
    let y = ms.eval_analytic(&p);
    let s: f64 = y.iter().sum();
    assert!((s - 1.0).abs() < 0.02, "vector softmax sum {s}");
    // argmax preserved vs the true softmax.
    let e: Vec<f64> = p.iter().map(|v| v.exp()).collect();
    let true_arg = e
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let got_arg = y
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(true_arg, got_arg);
}

/// AOT artifact integration: when `make artifacts` has run, the XLA
/// engine serves numbers matching the rust analytic evaluator.
/// (Needs the real PJRT runtime — the default build ships the stub.)
#[cfg(feature = "xla")]
#[test]
fn xla_engine_matches_analytic_when_artifacts_present() {
    let rt = Runtime::cpu(default_artifacts_dir()).expect("PJRT CPU client");
    if !rt.has_artifact("smurf_eval.hlo.txt") {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
    let direct: Vec<f64> = (0..16)
        .map(|i| approx.eval_analytic(&[i as f64 / 15.0, 0.3]))
        .collect();
    let server = EvalServer::start(
        vec![approx],
        Some(default_artifacts_dir()),
        ServerConfig::default(),
    );
    let points: Vec<Vec<f64>> = (0..16).map(|i| vec![i as f64 / 15.0, 0.3]).collect();
    let resp = server.eval_sync("euclidean2", points, Engine::Xla, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    for (got, want) in resp.outputs.iter().zip(&direct) {
        assert!((got - want).abs() < 1e-4, "xla {got} vs analytic {want}");
    }
    server.shutdown();
}

/// Failure injection: dropping reply receivers must not wedge the
/// server; subsequent requests still succeed.
#[test]
fn server_survives_dropped_clients() {
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
    let server = EvalServer::start(vec![approx], None, ServerConfig::default());
    // Fire-and-forget requests whose receivers die immediately.
    for i in 0..50 {
        let (rtx, rrx) = std::sync::mpsc::channel();
        drop(rrx);
        let _ = server.submit(smurf::coordinator::EvalRequest::new(
            "product2",
            vec![vec![i as f64 / 50.0, 0.5]],
            Engine::Analytic,
            64,
            rtx,
        ));
    }
    // A healthy request afterwards still completes.
    let r = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::Analytic, 64);
    assert!(r.is_ok());
    assert!((r.outputs[0] - 0.25).abs() < 0.01);
    server.shutdown();
}

/// Unknown engines/functions degrade to clean typed errors, and metrics
/// reflect them: unknown functions are rejected at the admission edge
/// (never queued), while engine failures surface as `Engine` errors.
#[test]
fn error_paths_are_observable() {
    use smurf::coordinator::{EvalError, RejectReason};
    let cfg = SmurfConfig::uniform(2, 4);
    let approx = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
    let server = EvalServer::start(vec![approx], None, ServerConfig::default());
    let r = server.eval_sync("missing_fn", vec![vec![0.1, 0.2]], Engine::Analytic, 64);
    assert!(!r.is_ok());
    assert!(
        matches!(r.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))),
        "{:?}",
        r.error
    );
    let r = server.eval_sync("product2", vec![vec![0.1, 0.2]], Engine::Xla, 64);
    assert!(!r.is_ok(), "XLA without runtime must fail cleanly");
    assert!(matches!(r.error, Some(EvalError::Engine(_))), "{:?}", r.error);
    let snap = server.metrics();
    assert_eq!(snap.rejected_bad_request, 1);
    assert!(snap.errors >= 1);
    server.shutdown();
}

//! Loom model checking of the serving core's concurrency kernels.
//!
//! These tests compile only under `RUSTFLAGS="--cfg loom"` with the
//! `loom` feature enabled (`make loom`), because the `loom` crate is not
//! vendored in the default offline build — see the commented-out
//! dependency line in `rust/Cargo.toml`. Everything here exercises the
//! *shipping* code paths: the [`smurf::util::sync`] facade re-exports
//! loom's `Arc`/`Mutex`/atomics under `cfg(loom)`, so `Admission`,
//! `DriftSentinel` and `WakeSignal` below are the exact production types,
//! model-checked across every interleaving loom can reach (bounded by
//! `LOOM_MAX_PREEMPTIONS`).
//!
//! The four kernels and what each model proves:
//!
//! 1. [`depth_tokens_never_leak_or_overshoot`] — the admission CAS loop
//!    admits at most `limit` requests concurrently, and every token
//!    release (including drop-without-reply, the panic-unwind path)
//!    returns the counter to zero: depth can neither leak nor go
//!    negative (underflow would wrap the `AtomicUsize` and trip the
//!    overshoot assertion on the next admit).
//! 2. [`shed_latch_hysteresis_converges`] — however concurrent submits
//!    and drains interleave around the watermarks, the shed latch always
//!    disengages once the backlog drains: a post-drain submit is never
//!    degraded.
//! 3. [`wake_signal_never_loses_a_death_and_publishes_event`] — the
//!    supervisor wakeup flag cannot lose a worker-death notification
//!    (the PR-7 `OnceLock` registration-window bug, fixed by the
//!    level-triggered flag), and its Release/Acquire pairing publishes
//!    the notifier's prior writes to the woken waiter.
//! 4. [`sentinel_transitions_stay_monotone`] — concurrent route/observe
//!    traffic can only move a function along the documented
//!    `Healthy → Quarantined → Probing → Healthy` cycle, raises exactly
//!    one alarm per trip, and the full lifecycle still terminates in
//!    `Healthy`.

#![cfg(all(loom, feature = "loom"))]

use smurf::coordinator::admission::{Admission, AdmissionConfig};
use smurf::coordinator::metrics::Metrics;
use smurf::coordinator::request::{Engine, EvalRequest, EvalResponse};
use smurf::coordinator::sentinel::{
    DriftSentinel, EngineHealth, Observation, Route, SentinelConfig,
};
use smurf::util::sync::{Arc, AtomicU64, Ordering, WakeSignal};

/// A minimal admissible BitLevel request (the reply channel is a plain
/// std mpsc sender: loom does not model it, and no model races on it).
fn mk_req(engine: Engine) -> EvalRequest {
    let (tx, _rx) = std::sync::mpsc::channel::<EvalResponse>();
    EvalRequest::new("f", vec![vec![0.5, 0.5]], engine, 16, tx)
}

fn arity2(name: &str) -> Option<usize> {
    (name == "f").then_some(2)
}

fn mk_admission(cfg: AdmissionConfig) -> Arc<Admission> {
    Arc::new(Admission::new(cfg, Arc::new(Metrics::new())))
}

/// Model 1: the depth-token CAS protocol. Two threads race one
/// `bitlevel_limit = 1` slot; one winner drops its request *without*
/// replying (exactly what a panicking worker's unwind does to the batch
/// it held). Across every interleaving: the limit is never overshot, and
/// after all tokens die the depth is exactly zero — no leak, no
/// underflow.
#[test]
fn depth_tokens_never_leak_or_overshoot() {
    loom::model(|| {
        let adm = mk_admission(AdmissionConfig {
            bitlevel_limit: 1,
            ..AdmissionConfig::default()
        });
        let t1 = {
            let adm = Arc::clone(&adm);
            loom::thread::spawn(move || {
                let mut req = mk_req(Engine::BitLevel);
                let admitted = Admission::admit(&adm, &mut req, arity2).is_ok();
                assert!(adm.depth(Engine::BitLevel) <= 1, "depth limit overshot");
                // Panic-unwind path: the request (and its token) drops
                // without ever being answered.
                drop(req);
                admitted
            })
        };
        let t2 = {
            let adm = Arc::clone(&adm);
            loom::thread::spawn(move || {
                let mut req = mk_req(Engine::BitLevel);
                let admitted = Admission::admit(&adm, &mut req, arity2).is_ok();
                assert!(adm.depth(Engine::BitLevel) <= 1, "depth limit overshot");
                drop(req);
                admitted
            })
        };
        let a = t1.join().unwrap();
        let b = t2.join().unwrap();
        // At least one submit must have won the slot (the CAS loop cannot
        // livelock both into QueueFull from an empty pool).
        assert!(a || b, "an empty pool rejected every submit");
        // Every token released: the counter is back to zero, not negative
        // (underflow would wrap and the next admit's overshoot assert
        // would fire), not leaked.
        assert_eq!(adm.depth(Engine::BitLevel), 0, "depth leaked or wrapped");
        // The freed pool admits again.
        let mut req = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&adm, &mut req, arity2).is_ok());
        assert_eq!(adm.depth(Engine::BitLevel), 1);
    });
}

/// Model 2: the hysteresis shed latch. Start at the `shed_high = 2`
/// watermark, then race a drain (token drop) against a fresh submit —
/// the submit may or may not observe the latch engage, both are valid.
/// The invariant is convergence: once the backlog fully drains, the next
/// submit must serve at full fidelity (latch disengaged at
/// `shed_low = 1`), in every interleaving.
#[test]
fn shed_latch_hysteresis_converges() {
    loom::model(|| {
        let adm = mk_admission(AdmissionConfig {
            shed_high: 2,
            shed_low: 1,
            ..AdmissionConfig::default()
        });
        // Fill BitLevel to the high watermark (not degraded: the latch
        // trips on the *next* submit that observes depth >= shed_high).
        let mut r1 = mk_req(Engine::BitLevel);
        let mut r2 = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&adm, &mut r1, arity2).is_ok());
        assert!(Admission::admit(&adm, &mut r2, arity2).is_ok());
        let drainer = loom::thread::spawn(move || drop(r1));
        let submitter = {
            let adm = Arc::clone(&adm);
            loom::thread::spawn(move || {
                let mut req = mk_req(Engine::BitLevel);
                assert!(
                    Admission::admit(&adm, &mut req, arity2).is_ok(),
                    "BitLevel pool is nowhere near its limit"
                );
                // Raced against the drain, both verdicts are legal:
                // degraded (saw depth 2, latched) or served (saw 1).
                let degraded = req.degraded;
                drop(req);
                degraded
            })
        };
        drainer.join().unwrap();
        let _ = submitter.join().unwrap();
        drop(r2);
        // Backlog fully drained: whatever the race did to the latch, the
        // next submit must observe depth 0 <= shed_low and serve at full
        // fidelity. A latch stuck engaged here is the flap/starvation bug
        // the hysteresis exists to prevent.
        let mut req = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&adm, &mut req, arity2).is_ok());
        assert!(!req.degraded, "shed latch failed to disengage after drain");
        assert!(!adm.is_shedding());
    });
}

/// Model 3: the supervisor wakeup flag. A worker dies (writes its death
/// record, then notifies) concurrently with the supervisor entering its
/// wait. Loom explores the orderings the PR-7 `OnceLock` wiring lost —
/// notify before the waiter ever waits — and verifies both liveness (the
/// yield-spin wait always observes the flag) and publication (the
/// Release store / Acquire swap pairing makes the death record visible
/// after the wait returns, even though the record itself is Relaxed).
#[test]
fn wake_signal_never_loses_a_death_and_publishes_event() {
    loom::model(|| {
        let signal = Arc::new(WakeSignal::new());
        // The "worker death record" the supervisor must observe; Relaxed
        // on purpose — the signal's Release/Acquire edge is what orders it.
        let record = Arc::new(AtomicU64::new(0));
        let worker = {
            let signal = Arc::clone(&signal);
            let record = Arc::clone(&record);
            loom::thread::spawn(move || {
                record.store(42, Ordering::Relaxed);
                signal.notify();
            })
        };
        signal.register_current();
        // Liveness: the notify is never lost, whichever side runs first.
        assert!(signal.wait(), "worker-death wakeup lost");
        // Publication: the waiter sees everything the notifier wrote
        // before notify().
        assert_eq!(
            record.load(Ordering::Relaxed),
            42,
            "notify() failed to publish the death record"
        );
        worker.join().unwrap();
        // Level-triggered, consume-once: the flag was swapped down, so a
        // second notify is a fresh event, not a stale one.
        signal.notify();
        assert!(signal.wait());
    });
}

/// Model 4: the quarantine state machine. A tripping observation races a
/// concurrent route; the sentinel's mutex serializes them, so loom
/// explores both lock orders. In each: the route verdict is one the
/// machine may legally emit in its pre- or post-trip state, exactly one
/// alarm is raised per trip, and health lands in a post-trip state. The
/// tail then drives the full monotone cycle
/// `Quarantined → Probing → Healthy` to completion.
#[test]
fn sentinel_transitions_stay_monotone() {
    loom::model(|| {
        // Hair-trigger policy: one sample trips, one probe recovers.
        let s = Arc::new(DriftSentinel::new(SentinelConfig {
            canary_fraction: 1.0,
            ewma_alpha: 1.0,
            min_samples: 1,
            probe_interval: 1,
            probe_successes: 1,
            ..SentinelConfig::default()
        }));
        let observer = {
            let s = Arc::clone(&s);
            loom::thread::spawn(move || match s.observe("f", 0.5) {
                Observation::Alarm(a) => {
                    assert_eq!(a.function, "f");
                    assert!(a.ewma > a.threshold);
                }
                other => panic!("tripping observation must alarm, got {other:?}"),
            })
        };
        let router = {
            let s = Arc::clone(&s);
            loom::thread::spawn(move || {
                match s.route("f") {
                    // Before the trip: healthy serve (full-fraction canary).
                    Route::Serve { canary } => assert!(canary),
                    // After the trip: probe_interval = 1 schedules a probe
                    // on the first quarantined arrival; a later arrival
                    // while that probe is in flight degrades.
                    Route::Probe | Route::Degrade => {}
                }
            })
        };
        observer.join().unwrap();
        router.join().unwrap();
        // Post-trip: the machine sits in the quarantine half of the cycle
        // (never back in Healthy without a recovery), with exactly one
        // queued alarm.
        let h = s.health("f");
        assert!(
            h == EngineHealth::Quarantined || h == EngineHealth::Probing,
            "trip must leave the function quarantined, got {h:?}"
        );
        assert_eq!(s.take_alarms().len(), 1, "exactly one alarm per trip");
        // Drive the rest of the cycle sequentially. If the racing route
        // already took the probe (health = Probing), the probe result is
        // owed directly; otherwise schedule one first (probe_interval = 1:
        // the next quarantined arrival probes).
        if h == EngineHealth::Quarantined {
            assert_eq!(s.route("f"), Route::Probe, "cadence must schedule a probe");
        }
        assert_eq!(s.observe("f", 0.0), Observation::Recovered);
        assert_eq!(s.health("f"), EngineHealth::Healthy);
        assert!(s.take_alarms().is_empty(), "recovery must not re-alarm");
    });
}

//! Chaos suite: the fault-tolerance contract of the serving core under
//! injected failures (see the failure model in `smurf::coordinator`).
//!
//! The invariant every test enforces: **no client ever hangs**. Every
//! submit resolves to a success, a degraded success, or a typed
//! rejection/failure within its deadline — under worker panics, stalls,
//! queue overload, dropped clients, and shutdown — and the worker pool
//! returns to full strength afterwards.

use smurf::coordinator::batcher::BatchPolicy;
use smurf::coordinator::{
    AdmissionConfig, BreakerConfig, BreakerState, BudgetConfig, ClientConfig, Engine,
    EngineHealth, EvalError, EvalRequest, EvalServer, FaultInjector, FlakyWindow, HedgeConfig,
    HedgeDelay, RejectReason, ResilientClient, RetryPolicy, SentinelConfig, ServerConfig,
};
use smurf::prelude::*;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn chaos_server(
    workers: usize,
    policy: BatchPolicy,
    admission: AdmissionConfig,
) -> (EvalServer, Arc<FaultInjector>) {
    let cfg = SmurfConfig::uniform(2, 4);
    let funcs = vec![
        SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::product2(), 64),
    ];
    let faults = Arc::new(FaultInjector::new());
    let server = EvalServer::start(
        funcs,
        None,
        ServerConfig { workers, policy, admission, faults: faults.clone(), ..ServerConfig::default() },
    );
    (server, faults)
}

fn default_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) }
}

/// Wait (bounded) until the supervisor has the pool back at `n` workers.
fn await_pool(server: &EvalServer, n: usize) {
    for _ in 0..2000 {
        if server.live_workers() == n {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("pool did not recover to {n} workers (live={})", server.live_workers());
}

/// Teardown used by every test (ISSUE 10 satellite): wait for the
/// in-flight depth to drain, shut down, and require the final metrics
/// snapshot's conservation ledger to balance — every submit accounted
/// for by exactly one answer bucket.
fn shutdown_conserved(server: EvalServer) {
    await_drain(&server);
    let last = server.shutdown();
    last.check_conservation().expect("conservation ledger must balance at teardown");
}

/// A worker panicking mid-batch must answer every in-flight client with a
/// typed `WorkerPanic`, the supervisor must respawn the thread, and the
/// server must keep serving.
#[test]
fn worker_panic_answers_clients_and_pool_recovers() {
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) };
    let (server, faults) = chaos_server(2, policy, AdmissionConfig::default());
    faults.arm_panic_on_batch(1); // the very next batch dies mid-execution

    let mut receivers = Vec::new();
    for i in 0..4 {
        let (rtx, rrx) = channel();
        let req = EvalRequest::new(
            "euclidean2",
            vec![vec![i as f64 / 4.0, 0.5]],
            Engine::Analytic,
            64,
            rtx,
        );
        server.submit(req).expect("healthy traffic admits");
        receivers.push(rrx);
    }
    // Every client is answered — none hang, and the panicking batch's
    // members carry the typed error.
    let mut panics = 0;
    for rrx in receivers {
        let resp = rrx
            .recv_timeout(Duration::from_secs(10))
            .expect("client must be answered despite the panic");
        if let Some(EvalError::WorkerPanic(msg)) = &resp.error {
            assert!(msg.contains("fault injection"), "panic payload preserved: {msg}");
            panics += 1;
        }
    }
    assert!(panics >= 1, "at least the injected batch must report WorkerPanic");

    let snap = server.metrics();
    assert!(snap.panics >= 1, "panic must be counted");
    await_pool(&server, 2);
    for _ in 0..200 {
        if server.metrics().respawns >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(server.metrics().respawns >= 1, "supervisor must record the respawn");

    // The recovered pool serves correctly (deterministically, even).
    let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::Analytic, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert!((resp.outputs[0] - 0.25).abs() < 0.01);
    shutdown_conserved(server);
}

/// A stalled worker must not wedge synchronous clients: the deadline
/// fires, the client gets a typed `Timeout`, and once the stall clears
/// the server recovers.
#[test]
fn slow_worker_times_out_typed_then_recovers() {
    let (server, faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    faults.set_slow_batch(Duration::from_millis(300));

    let t0 = Instant::now();
    let resp = server.eval_sync_with_timeout(
        "euclidean2",
        vec![vec![0.3, 0.4]],
        Engine::Analytic,
        64,
        Duration::from_millis(40),
    );
    assert_eq!(resp.error, Some(EvalError::Timeout), "typed timeout, not a hang");
    assert!(
        t0.elapsed() < Duration::from_millis(250),
        "timeout must fire at the client deadline, got {:?}",
        t0.elapsed()
    );
    assert!(server.metrics().client_timeouts >= 1);

    faults.set_slow_batch(Duration::ZERO);
    // The worker finishes the stalled batch, then serves normally.
    let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::Analytic, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    shutdown_conserved(server);
}

/// A queued request whose deadline expires behind a stalled worker is
/// answered with `Rejected(Deadline)` — expired work is never executed.
#[test]
fn queued_deadline_expires_behind_stalled_worker() {
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    let (server, faults) = chaos_server(1, policy, AdmissionConfig::default());
    faults.set_slow_batch(Duration::from_millis(100));

    // Occupy the single worker.
    let (busy_tx, busy_rx) = channel();
    server
        .submit(EvalRequest::new(
            "euclidean2",
            vec![vec![0.5, 0.5]],
            Engine::Analytic,
            64,
            busy_tx,
        ))
        .unwrap();
    std::thread::sleep(Duration::from_millis(20)); // let the worker pick it up

    // This request's 5 ms deadline will expire while it waits in line.
    let (rtx, rrx) = channel();
    let req = EvalRequest::new("euclidean2", vec![vec![0.2, 0.8]], Engine::BitLevel, 256, rtx)
        .with_deadline(Instant::now() + Duration::from_millis(5));
    server.submit(req).expect("deadline still live at submit");

    let resp = rrx.recv_timeout(Duration::from_secs(5)).expect("expired request is answered");
    assert_eq!(resp.error, Some(EvalError::Rejected(RejectReason::Deadline)));
    assert!(server.metrics().rejected_deadline >= 1);

    // The stalled request itself still completes.
    let busy = busy_rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert!(busy.is_ok());
    faults.set_slow_batch(Duration::ZERO);
    shutdown_conserved(server);
}

/// Overload: past the shed watermark BitLevel traffic degrades to the
/// analytic closed form (flagged), past the hard limits it is rejected
/// with `QueueFull`, every admitted request still resolves, and once the
/// backlog drains the hysteresis latch releases (no more degradation).
#[test]
fn overload_sheds_then_rejects_then_recovers() {
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) };
    let admission = AdmissionConfig {
        bitlevel_limit: 4,
        analytic_limit: 4,
        shed_high: 2,
        shed_low: 1,
        ..AdmissionConfig::default()
    };
    let (server, faults) = chaos_server(1, policy, admission);
    faults.set_slow_batch(Duration::from_millis(50));

    let mut receivers = Vec::new();
    let mut queue_full = 0;
    for i in 0..12 {
        let (rtx, rrx) = channel();
        let req = EvalRequest::new(
            "euclidean2",
            vec![vec![i as f64 / 12.0, 0.5]],
            Engine::BitLevel,
            64,
            rtx,
        );
        match server.submit(req) {
            Ok(()) => receivers.push(rrx),
            Err(EvalError::Rejected(RejectReason::QueueFull)) => queue_full += 1,
            Err(e) => panic!("unexpected rejection: {e}"),
        }
    }
    assert!(queue_full >= 1, "hard limits must eventually reject");
    assert!(server.metrics().rejected_queue_full >= 1);

    // Every admitted request resolves — none hang behind the slow worker.
    let mut degraded = 0;
    for rrx in receivers {
        let resp = rrx
            .recv_timeout(Duration::from_secs(10))
            .expect("admitted requests must resolve under overload");
        assert!(resp.is_ok(), "{:?}", resp.error);
        if resp.degraded {
            degraded += 1;
        }
    }
    assert!(degraded >= 1, "shedding must have served BitLevel traffic analytically");
    assert!(server.metrics().degraded >= 1);

    // Backlog drained (tokens released on reply) → latch disengages →
    // fresh BitLevel traffic is served at full fidelity again.
    faults.set_slow_batch(Duration::ZERO);
    for _ in 0..500 {
        if server.admission().total_depth() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    let resp = server.eval_sync("euclidean2", vec![vec![0.4, 0.6]], Engine::BitLevel, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert!(!resp.degraded, "hysteresis latch must release once the backlog drains");
    assert!(!server.admission().is_shedding());
    shutdown_conserved(server);
}

/// Malformed traffic is refused at the submit edge with typed reasons and
/// never reaches an engine.
#[test]
fn bad_requests_rejected_at_the_edge() {
    let (server, _faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    let reject = |req: EvalRequest| -> RejectReason {
        match server.submit(req) {
            Err(EvalError::Rejected(r)) => r,
            other => panic!("expected rejection, got {other:?}"),
        }
    };
    let (rtx, _rrx) = channel();
    // Unknown function.
    let r = reject(EvalRequest::new("nope", vec![vec![0.1, 0.2]], Engine::Analytic, 64, rtx.clone()));
    assert!(matches!(r, RejectReason::BadRequest(_)));
    // Arity mismatch.
    let r = reject(EvalRequest::new("euclidean2", vec![vec![0.1]], Engine::Analytic, 64, rtx.clone()));
    assert!(matches!(r, RejectReason::BadRequest(_)));
    // Non-finite input.
    let r = reject(EvalRequest::new(
        "euclidean2",
        vec![vec![0.1, f64::NAN]],
        Engine::Analytic,
        64,
        rtx.clone(),
    ));
    assert!(matches!(r, RejectReason::BadRequest(_)));
    // Zero-length stream on the bit-level engine.
    let r = reject(EvalRequest::new("euclidean2", vec![vec![0.1, 0.2]], Engine::BitLevel, 0, rtx.clone()));
    assert!(matches!(r, RejectReason::BadRequest(_)));
    // Dead on arrival.
    let expired = EvalRequest::new("euclidean2", vec![vec![0.1, 0.2]], Engine::Analytic, 64, rtx)
        .with_deadline(Instant::now() - Duration::from_millis(1));
    assert_eq!(reject(expired), RejectReason::Deadline);

    let snap = server.metrics();
    assert_eq!(snap.rejected_bad_request, 4);
    assert_eq!(snap.rejected_deadline, 1);
    assert_eq!(snap.requests, 0, "nothing malformed may reach an engine");
    shutdown_conserved(server);
}

/// Shutdown answers queued requests instead of dropping them: every
/// receiver held across `shutdown()` resolves.
#[test]
fn shutdown_answers_queued_requests() {
    let (server, faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    faults.set_slow_batch(Duration::from_millis(50));
    let mut receivers = Vec::new();
    for i in 0..6 {
        let (rtx, rrx) = channel();
        server
            .submit(EvalRequest::new(
                "product2",
                vec![vec![i as f64 / 6.0, 0.5]],
                Engine::Analytic,
                64,
                rtx,
            ))
            .unwrap();
        receivers.push(rrx);
    }
    // Shut down with requests still queued behind the stalled worker:
    // the drain must answer every one of them, and the final snapshot's
    // conservation ledger must balance (ISSUE 10 satellite) even though
    // nothing drained *before* the close.
    let last = server.shutdown();
    for rrx in receivers {
        let resp = rrx
            .recv_timeout(Duration::from_secs(1))
            .expect("queued request must be answered at shutdown, not dropped");
        // Either evaluated by the draining workers or typed-failed —
        // never silently discarded.
        assert!(resp.is_ok() || resp.error == Some(EvalError::Shutdown), "{:?}", resp.error);
    }
    last.check_conservation().expect("ledger must balance across a mid-flight shutdown");
}

/// The full drift-quarantine lifecycle: a biased engine trips the canary
/// EWMA (typed `DriftAlarm`), quarantine degrades traffic to
/// analytic-exact responses, recovery probes notice the heal, and full
/// bit-level fidelity returns — with every request answered exactly once
/// and depth draining to zero.
#[test]
fn drift_quarantine_lifecycle_detects_degrades_and_recovers() {
    let cfg = SmurfConfig::uniform(2, 4);
    let funcs = vec![SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64)];
    let reference = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
    let faults = Arc::new(FaultInjector::new());
    let server = EvalServer::start(
        funcs,
        None,
        ServerConfig {
            workers: 1,
            policy: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) },
            faults: faults.clone(),
            sentinel: SentinelConfig {
                canary_fraction: 1.0, // cross-check every BitLevel response
                min_samples: 2,
                probe_interval: 2,
                probe_successes: 2,
                ..SentinelConfig::default()
            },
            ..ServerConfig::default()
        },
    );
    let point = vec![vec![0.3, 0.4]];

    // Phase 1 — healthy full-fidelity service.
    let resp = server.eval_sync("euclidean2", point.clone(), Engine::BitLevel, 256);
    assert!(resp.is_ok() && !resp.degraded, "{:?}", resp.error);
    assert_eq!(server.sentinel().health("euclidean2"), EngineHealth::Healthy);

    // Phase 2 — the engine drifts (constant output bias, far past the
    // quarantine threshold). Canaries notice within a few requests.
    faults.set_output_bias(0.75);
    for _ in 0..20 {
        let resp = server.eval_sync("euclidean2", point.clone(), Engine::BitLevel, 256);
        assert!(resp.is_ok(), "{:?}", resp.error);
        if server.sentinel().health("euclidean2") != EngineHealth::Healthy {
            break;
        }
    }
    assert_ne!(
        server.sentinel().health("euclidean2"),
        EngineHealth::Healthy,
        "sustained drift must quarantine the function"
    );
    let alarms = server.sentinel().take_alarms();
    assert_eq!(alarms.len(), 1, "exactly one typed alarm for one trip");
    assert_eq!(alarms[0].function, "euclidean2");
    assert!(alarms[0].ewma > alarms[0].threshold);
    assert!(server.metrics().drift_alarms >= 1);

    // Phase 3 — quarantined traffic degrades to the analytic closed
    // form: flagged, and exactly the unbiased reference value (the bias
    // only corrupts the BitLevel engine).
    let mut degraded_seen = 0;
    for _ in 0..4 {
        let resp = server.eval_sync("euclidean2", point.clone(), Engine::BitLevel, 256);
        assert!(resp.is_ok(), "{:?}", resp.error);
        if resp.degraded {
            degraded_seen += 1;
            assert_eq!(
                resp.outputs[0],
                reference.eval_analytic(&point[0]),
                "degraded response must be the analytic closed form, not biased"
            );
        }
    }
    assert!(degraded_seen >= 1, "quarantine must degrade traffic");
    assert!(server.metrics().drift_degraded >= 1);

    // Phase 4 — the fault heals; recovery probes (served on the real
    // engine) succeed and restore the function to Healthy.
    faults.set_output_bias(0.0);
    let mut recovered = false;
    for _ in 0..40 {
        let resp = server.eval_sync("euclidean2", point.clone(), Engine::BitLevel, 256);
        assert!(resp.is_ok(), "{:?}", resp.error);
        if server.sentinel().health("euclidean2") == EngineHealth::Healthy {
            recovered = true;
            break;
        }
    }
    assert!(recovered, "successful probes must end the quarantine");
    assert!(server.metrics().drift_probes >= 2, "recovery takes probe_successes probes");
    assert!(server.metrics().drift_recoveries >= 1);

    // Phase 5 — full fidelity again: non-degraded and bit-identical to
    // the clean engine (seeds derive from request content only).
    let resp = server.eval_sync("euclidean2", point.clone(), Engine::BitLevel, 256);
    assert!(resp.is_ok() && !resp.degraded, "{:?}", resp.error);
    assert_eq!(resp.outputs[0], reference.eval_bitstream(&point[0], 256, 0x5EED));

    // Every eval_sync above was answered exactly once (each call consumes
    // its own reply channel); depth fully drains.
    for _ in 0..2000 {
        if server.admission().total_depth() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(server.admission().total_depth(), 0, "in-flight accounting must drain");
    shutdown_conserved(server);
}

/// NaN-poisoned engine outputs must reach clients as typed engine errors
/// (never as poisoned floats), be counted, and clear when the fault does.
#[test]
fn nan_poisoning_yields_typed_errors_not_poisoned_floats() {
    let (server, faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    faults.set_poison_nan(true);
    for _ in 0..3 {
        let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 64);
        assert!(!resp.is_ok());
        assert!(
            matches!(resp.error, Some(EvalError::Engine(ref m)) if m.contains("non-finite")),
            "{:?}",
            resp.error
        );
        assert!(resp.outputs.is_empty());
    }
    assert!(server.metrics().nonfinite_outputs >= 3);
    faults.set_poison_nan(false);
    let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert!(resp.outputs[0].is_finite());
    shutdown_conserved(server);
}

/// Clients that drop their reply receivers — even while panics are being
/// injected — must not wedge the server or leak queue depth.
#[test]
fn dropped_clients_under_panics_leak_nothing() {
    let (server, faults) = chaos_server(2, default_policy(), AdmissionConfig::default());
    faults.arm_panic_on_batch(2);
    for i in 0..30 {
        let (rtx, rrx) = channel();
        drop(rrx); // client walks away immediately
        let _ = server.submit(EvalRequest::new(
            "euclidean2",
            vec![vec![i as f64 / 30.0, 0.5]],
            Engine::Analytic,
            64,
            rtx,
        ));
    }
    // Depth drains fully: tokens release whether the reply was sent,
    // unsendable, or the batch died in a panic.
    for _ in 0..2000 {
        if server.admission().total_depth() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(server.admission().total_depth(), 0, "in-flight accounting must drain to zero");
    await_pool(&server, 2);
    let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::Analytic, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    shutdown_conserved(server);
}

/// Wait (bounded) until in-flight depth accounting drains to zero.
fn await_drain(server: &EvalServer) {
    for _ in 0..2000 {
        if server.admission().total_depth() == 0 {
            return;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("in-flight depth did not drain (depth={})", server.admission().total_depth());
}

/// The invariant the whole resilient-client ladder stands on (ISSUE 9
/// satellite): submitting the *same* request twice through the full
/// server yields bitwise-identical outputs on both engines — including
/// after a worker panic and respawn, because stream seeds derive from
/// `DEFAULT_STREAM_SEED ^ point_index`, never from batch composition or
/// worker identity.
#[test]
fn resubmission_is_bit_identical_across_respawns() {
    let (server, faults) = chaos_server(2, default_policy(), AdmissionConfig::default());
    let reference =
        SmurfApproximator::synthesize(&SmurfConfig::uniform(2, 4), &functions::euclidean2(), 64);
    let points = vec![vec![0.2, 0.7], vec![0.5, 0.5], vec![0.9, 0.1]];

    let run = |engine: Engine| -> Vec<f64> {
        let resp = server.eval_sync("euclidean2", points.clone(), engine, 256);
        assert!(resp.is_ok() && !resp.degraded, "{:?}", resp.error);
        resp.outputs
    };
    let bit_a = run(Engine::BitLevel);
    let bit_b = run(Engine::BitLevel);
    for (a, b) in bit_a.iter().zip(&bit_b) {
        assert_eq!(a.to_bits(), b.to_bits(), "BitLevel resubmission must be bit-identical");
    }
    // Pinned to the seed-discipline contract, not just self-consistent.
    for (i, (p, out)) in points.iter().zip(&bit_a).enumerate() {
        assert_eq!(
            out.to_bits(),
            reference.eval_bitstream(p, 256, 0x5EED ^ i as u64).to_bits(),
            "point {i} must be served at seed DEFAULT_STREAM_SEED ^ {i}"
        );
    }
    let an_a = run(Engine::Analytic);
    let an_b = run(Engine::Analytic);
    for (a, b) in an_a.iter().zip(&an_b) {
        assert_eq!(a.to_bits(), b.to_bits(), "Analytic resubmission must be bit-identical");
    }

    // Kill a worker mid-stream; the respawned pool must serve the same bits.
    faults.arm_panic_on_batch(1);
    let (rtx, rrx) = channel();
    server
        .submit(EvalRequest::new("euclidean2", points.clone(), Engine::Analytic, 64, rtx))
        .expect("sacrificial traffic admits");
    let _ = rrx.recv_timeout(Duration::from_secs(10)).expect("sacrificial request answered");
    await_pool(&server, 2);
    let bit_c = run(Engine::BitLevel);
    for (a, c) in bit_a.iter().zip(&bit_c) {
        assert_eq!(a.to_bits(), c.to_bits(), "respawned worker must serve identical bits");
    }
    await_drain(&server);
    shutdown_conserved(server);
}

/// Ladder rung 1+2: a deterministically flaky worker (seeded Bernoulli
/// panic window) is survived by deadline-carved retries, the answer is
/// bit-identical to a clean run, and the retry count is exactly the
/// number of injected failures — no storm.
#[test]
fn flaky_worker_survived_by_retries_within_budget() {
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    let (server, faults) = chaos_server(1, policy, AdmissionConfig::default());
    let reference =
        SmurfApproximator::synthesize(&SmurfConfig::uniform(2, 4), &functions::euclidean2(), 64);
    // The first two batches panic (p = 1 over a 2-batch window), then heal.
    faults.arm_flaky_window(FlakyWindow {
        seed: 1,
        panic_prob: 1.0,
        stall_prob: 0.0,
        stall: Duration::ZERO,
        batches: 2,
    });
    let client = ResilientClient::new(
        &server,
        ClientConfig {
            retry: Some(RetryPolicy {
                max_retries: 3,
                backoff_base: Duration::ZERO, // keep the test fast; jitter is moot at 0
                ..RetryPolicy::default()
            }),
            budget: Some(BudgetConfig { initial: 5.0, max: 5.0, earn_per_success: 0.1 }),
            ..ClientConfig::default()
        },
    );

    let resp = client.eval("euclidean2", vec![vec![0.3, 0.4]], Engine::BitLevel, 256);
    assert!(resp.is_ok(), "retries must survive the flaky window: {:?}", resp.error);
    assert_eq!(
        resp.outputs[0].to_bits(),
        reference.eval_bitstream(&[0.3, 0.4], 256, 0x5EED).to_bits(),
        "the surviving attempt serves the exact same bits as a clean run"
    );
    let snap = server.metrics();
    assert_eq!(snap.client_retries, 2, "exactly one retry per injected panic");
    assert_eq!(snap.client_retry_budget_exhausted, 0);
    assert!(snap.panics >= 2, "both injected panics were real worker deaths");
    // 5 tokens - 2 retries + 0.1 earned by the success.
    let tokens = client.retry_budget_tokens().expect("budget configured");
    assert!((tokens - 3.1).abs() < 1e-9, "tokens={tokens}");
    await_drain(&server);
    await_pool(&server, 1);
    drop(client);
    shutdown_conserved(server);
}

/// Ladder rung 2 under a *persistent* fault: the token-bucket budget
/// caps total retry amplification across calls. 5 failing evals against
/// a 3-token budget spend exactly 3 retries ever, every call still
/// resolves with the typed underlying error, and depth drains to zero.
#[test]
fn retry_storm_is_contained_by_the_budget() {
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    let (server, faults) = chaos_server(1, policy, AdmissionConfig::default());
    faults.arm_flaky_window(FlakyWindow {
        seed: 2,
        panic_prob: 1.0, // every batch dies for the whole window
        stall_prob: 0.0,
        stall: Duration::ZERO,
        batches: 100,
    });
    let client = ResilientClient::new(
        &server,
        ClientConfig {
            retry: Some(RetryPolicy {
                max_retries: 10,
                backoff_base: Duration::ZERO,
                ..RetryPolicy::default()
            }),
            budget: Some(BudgetConfig { initial: 3.0, max: 3.0, earn_per_success: 0.1 }),
            ..ClientConfig::default()
        },
    );

    for _ in 0..5 {
        let resp = client.eval("euclidean2", vec![vec![0.3, 0.4]], Engine::BitLevel, 64);
        assert!(
            matches!(resp.error, Some(EvalError::WorkerPanic(_))),
            "the underlying typed error must surface when retries stop: {:?}",
            resp.error
        );
    }
    let snap = server.metrics();
    assert_eq!(snap.client_retries, 3, "the 3-token budget caps total retries at 3");
    assert_eq!(
        snap.client_retry_budget_exhausted, 5,
        "every eval eventually hit the empty bucket (once each)"
    );
    assert_eq!(client.retry_budget_tokens(), Some(0.0));
    // Storm arithmetic: 5 calls + 3 retries = 8 server attempts total,
    // not 5 * (1 + max_retries) = 55.
    assert_eq!(snap.panics, 8, "no amplification beyond the budget cap");
    faults.clear_flaky_window();
    await_drain(&server);
    await_pool(&server, 1);
    drop(client);
    shutdown_conserved(server);
}

/// Ladder rung 3: a hedged request beats a stalled worker well inside
/// the deadline, and the losing (stalled) attempt is audited
/// bit-identical to the winner when it finally lands — the idempotency
/// dividend, checked on live traffic.
#[test]
fn hedged_request_beats_a_stalled_worker_within_deadline() {
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    let (server, faults) = chaos_server(2, policy, AdmissionConfig::default());
    let reference =
        SmurfApproximator::synthesize(&SmurfConfig::uniform(2, 4), &functions::euclidean2(), 64);
    // The very first batch (the primary attempt) stalls 400 ms; the
    // hedge lands on the second, healthy worker.
    faults.arm_stall_on_batch(1, Duration::from_millis(400));
    let client = ResilientClient::new(
        &server,
        ClientConfig {
            hedge: Some(HedgeConfig { delay: HedgeDelay::Fixed(Duration::from_millis(20)) }),
            ..ClientConfig::default()
        },
    );

    let t0 = Instant::now();
    let resp = client.eval_with_timeout(
        "euclidean2",
        vec![vec![0.3, 0.4]],
        Engine::BitLevel,
        256,
        Duration::from_secs(5),
    );
    assert!(resp.is_ok(), "{:?}", resp.error);
    assert!(
        t0.elapsed() < Duration::from_millis(300),
        "the hedge must beat the 400 ms stall, got {:?}",
        t0.elapsed()
    );
    assert_eq!(
        resp.outputs[0].to_bits(),
        reference.eval_bitstream(&[0.3, 0.4], 256, 0x5EED).to_bits(),
        "hedged answer is the same deterministic bits"
    );
    let snap = server.metrics();
    assert!(snap.client_hedges >= 1, "a hedge must have launched");
    assert!(snap.client_hedge_wins >= 1, "the hedge must have won");

    // The stalled loser completes eventually; audit it against the winner.
    let audit = client.drain_hedge_audits(Duration::from_secs(5));
    assert!(audit.verified >= 1, "the loser must resolve and verify: {audit:?}");
    assert_eq!(audit.mismatched, 0, "bit-identity must hold: {audit:?}");
    assert_eq!(server.metrics().client_hedge_mismatches, 0);
    await_drain(&server);
    drop(client);
    shutdown_conserved(server);
}

/// Ladder rung 4: a persistent engine fault trips the per-function
/// breaker (fail-fast `CircuitOpen` without touching the server), probes
/// keep sampling the function, and once the fault clears the probe
/// streak recloses the breaker and full service resumes bit-exact.
#[test]
fn breaker_opens_probes_and_recloses_after_the_fault_clears() {
    let (server, faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    let reference =
        SmurfApproximator::synthesize(&SmurfConfig::uniform(2, 4), &functions::product2(), 64);
    faults.set_poison_nan(true); // every BitLevel eval → typed Engine error
    let client = ResilientClient::new(
        &server,
        ClientConfig {
            breaker: Some(BreakerConfig {
                failure_threshold: 3,
                probe_interval: 2,
                probe_successes: 2,
            }),
            ..ClientConfig::default()
        },
    );
    let eval = |client: &ResilientClient| {
        client.eval("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 64)
    };

    // 3 engine failures trip the breaker.
    for _ in 0..3 {
        let resp = eval(&client);
        assert!(matches!(resp.error, Some(EvalError::Engine(_))), "{:?}", resp.error);
    }
    assert_eq!(client.breaker_state("product2"), BreakerState::Open);
    assert_eq!(server.metrics().breaker_opens, 1);

    // While open: fail-fast rejections, with every probe_interval-th
    // arrival probing the (still broken) engine.
    let requests_before = server.metrics().requests;
    let mut circuit_open_seen = 0;
    for _ in 0..4 {
        let resp = eval(&client);
        if resp.error == Some(EvalError::CircuitOpen) {
            circuit_open_seen += 1;
        }
    }
    assert_eq!(circuit_open_seen, 2, "interval-2 probing: half the arrivals fail fast");
    assert!(server.metrics().breaker_rejections >= 2);
    assert_eq!(
        server.metrics().requests,
        requests_before,
        "fail-fast rejections and failed probes never produce served requests"
    );
    assert_eq!(client.breaker_state("product2"), BreakerState::Open, "failed probes reopen");

    // Fault clears → two successful probes reclose the breaker.
    faults.set_poison_nan(false);
    let mut reclosed = false;
    for _ in 0..16 {
        let _ = eval(&client);
        if client.breaker_state("product2") == BreakerState::Closed {
            reclosed = true;
            break;
        }
    }
    assert!(reclosed, "good probes must reclose the breaker");
    assert_eq!(server.metrics().breaker_recloses, 1);

    // Full service, bit-exact, and other functions were never affected.
    let resp = eval(&client);
    assert!(resp.is_ok() && !resp.degraded, "{:?}", resp.error);
    assert_eq!(
        resp.outputs[0].to_bits(),
        reference.eval_bitstream(&[0.5, 0.5], 64, 0x5EED).to_bits()
    );
    assert_eq!(client.breaker_state("euclidean2"), BreakerState::Closed);
    await_drain(&server);
    drop(client);
    shutdown_conserved(server);
}

/// Acceptance pin: with every ladder rung disabled (the default config)
/// the client is byte-for-byte behavior-identical to calling the server
/// directly — same bits on success, same typed errors on refusal, and
/// zero client-side counters.
#[test]
fn default_client_config_is_passthrough_identical() {
    let (server, _faults) = chaos_server(1, default_policy(), AdmissionConfig::default());
    let client = ResilientClient::new(&server, ClientConfig::default());
    let timeout = Duration::from_secs(5);

    for engine in [Engine::BitLevel, Engine::Analytic] {
        let via_client = client.eval_with_timeout(
            "euclidean2",
            vec![vec![0.3, 0.4], vec![0.8, 0.2]],
            engine,
            256,
            timeout,
        );
        let direct = server.eval_sync_with_timeout(
            "euclidean2",
            vec![vec![0.3, 0.4], vec![0.8, 0.2]],
            engine,
            256,
            timeout,
        );
        assert!(via_client.is_ok() && direct.is_ok());
        assert_eq!(via_client.outputs.len(), direct.outputs.len());
        for (a, b) in via_client.outputs.iter().zip(&direct.outputs) {
            assert_eq!(a.to_bits(), b.to_bits(), "passthrough must serve identical bits");
        }
        assert_eq!(via_client.degraded, direct.degraded);
    }

    // Same typed refusals as the direct path.
    let via_client =
        client.eval_with_timeout("nope", vec![vec![0.1, 0.2]], Engine::Analytic, 64, timeout);
    let direct =
        server.eval_sync_with_timeout("nope", vec![vec![0.1, 0.2]], Engine::Analytic, 64, timeout);
    assert!(matches!(via_client.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))));
    assert_eq!(via_client.error, direct.error);

    // The ladder never engaged: all client-side counters stay zero.
    let snap = server.metrics();
    assert_eq!(snap.client_retries, 0);
    assert_eq!(snap.client_retry_budget_exhausted, 0);
    assert_eq!(snap.client_hedges, 0);
    assert_eq!(snap.client_hedge_wins, 0);
    assert_eq!(snap.breaker_rejections, 0);
    assert_eq!(snap.breaker_opens, 0);
    assert_eq!(client.breaker_state("euclidean2"), BreakerState::Closed);
    assert_eq!(client.retry_budget_tokens(), None);
    await_drain(&server);
    drop(client);
    shutdown_conserved(server);
}

/// Regression for the supervisor registration window (found by the loom
/// wakeup model, fixed by `util::sync::WakeSignal`): a worker that
/// panics on the *very first* batch — potentially before the supervisor
/// thread has ever parked or been registered — must still wake the
/// supervisor. Under the old `OnceLock<Thread>` + raw `unpark` wiring,
/// a death in that window was a silent no-op and the respawn waited for
/// the next supervisor poll tick; the level-triggered signal makes the
/// wakeup unlosable. The client still gets its typed `WorkerPanic`, and
/// the pool returns to full strength promptly.
#[test]
fn first_batch_panic_at_startup_cannot_lose_the_respawn_wakeup() {
    let policy = BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) };
    let (server, faults) = chaos_server(1, policy, AdmissionConfig::default());
    faults.arm_panic_on_batch(1);

    // Submit immediately — no settling sleep — so the panic races server
    // startup as closely as this test can arrange.
    let (rtx, rrx) = channel();
    server
        .submit(EvalRequest::new(
            "euclidean2",
            vec![vec![0.25, 0.75]],
            Engine::Analytic,
            64,
            rtx,
        ))
        .expect("startup traffic admits");
    let resp = rrx
        .recv_timeout(Duration::from_secs(10))
        .expect("the startup-window panic must still answer the client");
    assert!(
        matches!(resp.error, Some(EvalError::WorkerPanic(_))),
        "typed WorkerPanic expected, got {:?}",
        resp.error
    );

    // The respawn wakeup must not be lost: the pool recovers and serves.
    await_pool(&server, 1);
    for _ in 0..200 {
        if server.metrics().respawns >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(server.metrics().respawns >= 1, "supervisor must record the respawn");
    let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::Analytic, 64);
    assert!(resp.is_ok(), "{:?}", resp.error);
    shutdown_conserved(server);
}

//! θ-gates — stochastic number generators (paper Fig. 1, §II-B).
//!
//! A θ-gate is a binary comparator between a prescribed threshold and a
//! random entropy source: per clock cycle it emits `1` iff
//! `rand < threshold`. The SNG of Fig. 1 *is* a θ-gate; the CPT-gate is a
//! bank of them behind a MUX ([`crate::sc::cpt`]).

use super::plane::BitPlane;
use super::rng::StreamRng;

/// Fixed-point threshold width used by the datapath (16 bits — the paper's
/// "standard fixed-point representation ... whose quantization error is
/// negligible", §IV-A).
pub const THRESHOLD_BITS: u32 = 16;

/// Quantize a probability into the 16-bit threshold register: the one
/// rounding rule every stream generator in the crate shares
/// ([`ThetaGate::new`], [`crate::sc::bitstream::Bitstream::generate`],
/// the wide SC-PwMM banks in [`crate::sc::pwmm_wide`]). The scalar and
/// wide paths being bit-identical *starts* with them agreeing on this
/// quantization, so it is defined exactly once.
#[inline]
pub fn quantize_threshold(p: f64) -> u16 {
    (p.clamp(0.0, 1.0) * 65536.0).round().min(65535.0) as u16
}

/// A θ-gate: comparator + threshold register.
#[derive(Clone, Debug)]
pub struct ThetaGate {
    /// 16-bit threshold; the gate fires when `rand16 < threshold`.
    threshold: u16,
}

impl ThetaGate {
    /// Quantize a probability into the 16-bit threshold register (see
    /// [`quantize_threshold`]).
    pub fn new(p: f64) -> Self {
        Self { threshold: quantize_threshold(p) }
    }

    /// Construct from the raw register value.
    pub fn from_raw(threshold: u16) -> Self {
        Self { threshold }
    }

    /// The exact probability this gate realizes after quantization.
    pub fn effective_p(&self) -> f64 {
        self.threshold as f64 / 65536.0
    }

    /// Raw register value.
    pub fn raw(&self) -> u16 {
        self.threshold
    }

    /// One clock cycle: compare against the entropy word.
    #[inline(always)]
    pub fn sample(&self, rand16: u16) -> bool {
        rand16 < self.threshold
    }

    /// Convenience: run `len` cycles against `rng` and return the mean.
    pub fn run_mean(&self, len: usize, rng: &mut impl StreamRng) -> f64 {
        let mut ones = 0u64;
        for _ in 0..len {
            ones += self.sample(rng.next_u16()) as u64;
        }
        ones as f64 / len as f64
    }

    /// `P::LANES` comparisons per call: one clock of this θ-gate across
    /// every lane whose entropy words are given as bit planes (see
    /// [`crate::sc::rng::planes_from_lanes`]). Lane `l` of the result is
    /// `rand_l < threshold`.
    #[inline]
    pub fn sample_wide<P: BitPlane>(&self, rand_planes: &[P; 16]) -> P {
        wide_lt_const(rand_planes, self.threshold)
    }
}

// ---------------------------------------------------------------------------
// Wide (bit-sliced) comparators: the θ-gate datapath over P::LANES
// lanes per plane word (64 for the default `u64`, 256/512 for the SIMD
// planes — see `crate::sc::plane`).
//
// A 16-bit unsigned compare `rand < t` is evaluated MSB-first: the first
// bit position where the operands differ decides. Keeping `eq` = "lanes
// still tied" and folding one plane at a time gives every lane's verdict
// in ≤ 2–5 plane ops per bit — this is the Fig. 6 comparator bank run
// P::LANES trials at a time.
// ---------------------------------------------------------------------------

/// Lane-wise `rand < threshold` with the rand planes supplied by an
/// accessor (lets ring-buffered plane stores avoid a copy).
#[inline]
pub fn wide_lt_const_with<P: BitPlane>(plane: impl Fn(usize) -> P, threshold: u16) -> P {
    let mut lt = P::zero();
    let mut eq = P::ones();
    for b in (0..16).rev() {
        let p = plane(b);
        if (threshold >> b) & 1 == 1 {
            lt = lt.or(eq.and_not(p));
            eq = eq.and(p);
        } else {
            eq = eq.and_not(p);
        }
        if eq.is_zero() {
            break;
        }
    }
    lt
}

/// Lane-wise `rand < threshold` over materialized planes.
#[inline]
pub fn wide_lt_const<P: BitPlane>(rand_planes: &[P; 16], threshold: u16) -> P {
    wide_lt_const_with(|b| rand_planes[b], threshold)
}

/// Lane-wise `rand_l < threshold_l` where *both* sides vary per lane —
/// the CPT-gate case, where each lane's codeword selects its own
/// coefficient threshold (threshold planes built by
/// [`crate::sc::cpt::CptGate::threshold_planes`]).
#[inline]
pub fn wide_lt_planes<P: BitPlane>(rand_planes: &[P; 16], threshold_planes: &[P; 16]) -> P {
    let mut lt = P::zero();
    let mut eq = P::ones();
    for b in (0..16).rev() {
        let r = rand_planes[b];
        let t = threshold_planes[b];
        lt = lt.or(eq.and_not(r).and(t));
        eq = eq.and(r.xor(t).not());
        if eq.is_zero() {
            break;
        }
    }
    lt
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::{Lfsr16, Sobol};
    use crate::testing::{check, UnitF64};

    #[test]
    fn threshold_quantization() {
        assert_eq!(ThetaGate::new(0.0).raw(), 0);
        assert_eq!(ThetaGate::new(1.0).raw(), 65535);
        assert_eq!(ThetaGate::new(0.5).raw(), 32768);
        // The shared rule saturates out-of-range inputs instead of
        // wrapping (the bipolar encode feeds it raw clamp results).
        assert_eq!(quantize_threshold(-0.5), 0);
        assert_eq!(quantize_threshold(2.0), 65535);
        assert_eq!(quantize_threshold(0.99999), 65535);
    }

    #[test]
    fn zero_threshold_never_fires() {
        let g = ThetaGate::new(0.0);
        let mut rng = Lfsr16::new(1);
        assert_eq!(g.run_mean(10_000, &mut rng), 0.0);
    }

    #[test]
    fn effective_p_roundtrip() {
        let g = ThetaGate::new(0.7);
        assert!((g.effective_p() - 0.7).abs() < 1.0 / 65536.0);
    }

    #[test]
    fn lfsr_driven_mean_converges() {
        // Over a full LFSR period the mean is exact to 1/65536 (each
        // non-zero comparator word appears exactly once).
        let g = ThetaGate::new(0.7);
        let mut rng = Lfsr16::new(0x1357);
        let mean = g.run_mean(65535, &mut rng);
        assert!((mean - 0.7).abs() < 2.0 / 65536.0 + 1e-9, "mean={mean}");
    }

    #[test]
    fn prop_sobol_mean_error_is_o_one_over_l() {
        check(21, 64, &UnitF64::unit(), |&p| {
            let g = ThetaGate::new(p);
            let mut rng = Sobol::new(0);
            let l = 1024;
            let mean = g.run_mean(l, &mut rng);
            (mean - g.effective_p()).abs() <= 1.0 / l as f64 + 1e-12
        });
    }

    fn wide_lt_const_matches_generic<P: BitPlane>() {
        use crate::sc::rng::planes_from_lanes;
        use crate::util::prng::Pcg;
        check(23 + P::LANES as u64, 32, &UnitF64::unit(), |&p| {
            let t = ThetaGate::new(p).raw();
            let mut rng = Pcg::new(p.to_bits());
            let lanes: Vec<u16> = (0..P::LANES).map(|_| rng.next_u64() as u16).collect();
            let planes: [P; 16] = planes_from_lanes(&lanes);
            let mask = wide_lt_const(&planes, t);
            lanes.iter().enumerate().all(|(l, &r)| mask.lane(l) == (r < t))
        });
    }

    #[test]
    fn prop_wide_lt_const_matches_scalar_compare() {
        crate::for_each_plane_width!(wide_lt_const_matches_generic);
    }

    fn wide_lt_planes_matches_generic<P: BitPlane>() {
        use crate::sc::rng::planes_from_lanes;
        use crate::util::prng::Pcg;
        check(24 + P::LANES as u64, 32, &UnitF64::unit(), |&p| {
            let mut rng = Pcg::new(p.to_bits() ^ 0xABCD);
            let rs: Vec<u16> = (0..P::LANES).map(|_| rng.next_u64() as u16).collect();
            let ts: Vec<u16> = (0..P::LANES).map(|_| rng.next_u64() as u16).collect();
            let mask: P = wide_lt_planes(&planes_from_lanes(&rs), &planes_from_lanes(&ts));
            (0..P::LANES).all(|l| mask.lane(l) == (rs[l] < ts[l]))
        });
    }

    #[test]
    fn prop_wide_lt_planes_matches_scalar_compare() {
        crate::for_each_plane_width!(wide_lt_planes_matches_generic);
    }

    fn wide_lt_boundary_generic<P: BitPlane>() {
        use crate::sc::rng::planes_from_lanes;
        let lanes: Vec<u16> = (0..P::LANES).map(|l| (l as u16).wrapping_mul(1031)).collect();
        let planes: [P; 16] = planes_from_lanes(&lanes);
        assert!(wide_lt_const(&planes, 0).is_zero(), "t=0 never fires");
        let all = wide_lt_const(&planes, 0xFFFF);
        for (l, &v) in lanes.iter().enumerate() {
            assert_eq!(all.lane(l), v < 0xFFFF);
        }
    }

    #[test]
    fn wide_lt_boundary_thresholds() {
        crate::for_each_plane_width!(wide_lt_boundary_generic);
    }

    #[test]
    fn prop_monotone_in_threshold() {
        // A higher threshold can never fire less often on the same entropy.
        check(22, 64, &UnitF64::unit(), |&p| {
            let g1 = ThetaGate::new(p * 0.5);
            let g2 = ThetaGate::new(p);
            let mut r1 = Lfsr16::new(42);
            let mut r2 = Lfsr16::new(42);
            g1.run_mean(2048, &mut r1) <= g2.run_mean(2048, &mut r2) + 1e-12
        });
    }
}

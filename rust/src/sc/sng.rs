//! θ-gates — stochastic number generators (paper Fig. 1, §II-B).
//!
//! A θ-gate is a binary comparator between a prescribed threshold and a
//! random entropy source: per clock cycle it emits `1` iff
//! `rand < threshold`. The SNG of Fig. 1 *is* a θ-gate; the CPT-gate is a
//! bank of them behind a MUX ([`crate::sc::cpt`]).

use super::rng::StreamRng;

/// Fixed-point threshold width used by the datapath (16 bits — the paper's
/// "standard fixed-point representation ... whose quantization error is
/// negligible", §IV-A).
pub const THRESHOLD_BITS: u32 = 16;

/// A θ-gate: comparator + threshold register.
#[derive(Clone, Debug)]
pub struct ThetaGate {
    /// 16-bit threshold; the gate fires when `rand16 < threshold`.
    threshold: u16,
}

impl ThetaGate {
    /// Quantize a probability into the 16-bit threshold register.
    pub fn new(p: f64) -> Self {
        let t = (p.clamp(0.0, 1.0) * 65536.0).round().min(65535.0) as u16;
        Self { threshold: t }
    }

    /// Construct from the raw register value.
    pub fn from_raw(threshold: u16) -> Self {
        Self { threshold }
    }

    /// The exact probability this gate realizes after quantization.
    pub fn effective_p(&self) -> f64 {
        self.threshold as f64 / 65536.0
    }

    /// Raw register value.
    pub fn raw(&self) -> u16 {
        self.threshold
    }

    /// One clock cycle: compare against the entropy word.
    #[inline(always)]
    pub fn sample(&self, rand16: u16) -> bool {
        rand16 < self.threshold
    }

    /// Convenience: run `len` cycles against `rng` and return the mean.
    pub fn run_mean(&self, len: usize, rng: &mut impl StreamRng) -> f64 {
        let mut ones = 0u64;
        for _ in 0..len {
            ones += self.sample(rng.next_u16()) as u64;
        }
        ones as f64 / len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::{Lfsr16, Sobol};
    use crate::testing::{check, UnitF64};

    #[test]
    fn threshold_quantization() {
        assert_eq!(ThetaGate::new(0.0).raw(), 0);
        assert_eq!(ThetaGate::new(1.0).raw(), 65535);
        assert_eq!(ThetaGate::new(0.5).raw(), 32768);
    }

    #[test]
    fn zero_threshold_never_fires() {
        let g = ThetaGate::new(0.0);
        let mut rng = Lfsr16::new(1);
        assert_eq!(g.run_mean(10_000, &mut rng), 0.0);
    }

    #[test]
    fn effective_p_roundtrip() {
        let g = ThetaGate::new(0.7);
        assert!((g.effective_p() - 0.7).abs() < 1.0 / 65536.0);
    }

    #[test]
    fn lfsr_driven_mean_converges() {
        // Over a full LFSR period the mean is exact to 1/65536 (each
        // non-zero comparator word appears exactly once).
        let g = ThetaGate::new(0.7);
        let mut rng = Lfsr16::new(0x1357);
        let mean = g.run_mean(65535, &mut rng);
        assert!((mean - 0.7).abs() < 2.0 / 65536.0 + 1e-9, "mean={mean}");
    }

    #[test]
    fn prop_sobol_mean_error_is_o_one_over_l() {
        check(21, 64, &UnitF64::unit(), |&p| {
            let g = ThetaGate::new(p);
            let mut rng = Sobol::new(0);
            let l = 1024;
            let mean = g.run_mean(l, &mut rng);
            (mean - g.effective_p()).abs() <= 1.0 / l as f64 + 1e-12
        });
    }

    #[test]
    fn prop_monotone_in_threshold() {
        // A higher threshold can never fire less often on the same entropy.
        check(22, 64, &UnitF64::unit(), |&p| {
            let g1 = ThetaGate::new(p * 0.5);
            let g2 = ThetaGate::new(p);
            let mut r1 = Lfsr16::new(42);
            let mut r2 = Lfsr16::new(42);
            g1.run_mean(2048, &mut r1) <= g2.run_mean(2048, &mut r2) + 1e-12
        });
    }
}

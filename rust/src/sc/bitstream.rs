//! Packed stochastic bitstreams and the classic SC arithmetic (paper §II-A,
//! Fig. 2).
//!
//! A stochastic number (SN) is a random bitstream whose mean encodes a
//! value in `[0,1]`. We pack 64 stream bits per `u64` word so the hot path
//! (SC-PwMM in the CNN, §IV-B) is a handful of word ops per multiply.

use super::rng::StreamRng;

/// A packed stochastic bitstream of `len` bits (LSB of word 0 is cycle 0).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bitstream {
    words: Vec<u64>,
    len: usize,
}

impl Bitstream {
    /// All-zeros stream of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0; len.div_ceil(64)], len }
    }

    /// Generate a stream encoding probability `p` using `rng` as the
    /// comparator entropy source (this is a θ-gate run for `len` cycles).
    ///
    /// Comparator bits are accumulated 64 at a time into a register and
    /// written one whole word per 64 cycles — no per-bit div/mod/bounds
    /// path (this generator sits on the SC-PwMM and wide-engine setup hot
    /// paths). Bit order matches the per-bit reference exactly (LSB of
    /// word 0 is cycle 0).
    pub fn generate(p: f64, len: usize, rng: &mut impl StreamRng) -> Self {
        let mut s = Self { words: Vec::with_capacity(len.div_ceil(64)), len: 0 };
        s.generate_into(p, len, rng);
        s
    }

    /// [`Self::generate`] into an existing stream, reusing its word
    /// buffer: the allocation-free regeneration path of the scalar
    /// `Exact`-mode SC multiply, which re-fills the same scratch pair
    /// once per product. Bit-for-bit identical to a fresh `generate`
    /// (property-tested there).
    pub fn generate_into(&mut self, p: f64, len: usize, rng: &mut impl StreamRng) {
        let threshold = crate::sc::sng::quantize_threshold(p);
        self.words.clear();
        self.len = len;
        let mut remaining = len;
        while remaining > 0 {
            let take = remaining.min(64);
            let mut w = 0u64;
            for b in 0..take {
                w |= ((rng.next_u16() < threshold) as u64) << b;
            }
            self.words.push(w);
            remaining -= take;
        }
    }

    /// Exact-length bit count.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.len);
        let w = &mut self.words[i / 64];
        let m = 1u64 << (i % 64);
        if v {
            *w |= m;
        } else {
            *w &= !m;
        }
    }

    /// Number of '1' bits.
    pub fn popcount(&self) -> u64 {
        // Tail bits beyond `len` are maintained zero by construction.
        self.words.iter().map(|w| w.count_ones() as u64).sum()
    }

    /// Decode the stream back into a value: mean of the bits (the binary
    /// counter + average of Fig. 1's decode path).
    pub fn mean(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        self.popcount() as f64 / self.len as f64
    }

    /// Stochastic multiplication: bitwise AND (Fig. 2 top). Requires
    /// *independent* input streams for `E[z] = Px·Py` to hold.
    pub fn and(&self, other: &Bitstream) -> Bitstream {
        assert_eq!(self.len, other.len, "stream length mismatch");
        Bitstream {
            words: self.words.iter().zip(&other.words).map(|(a, b)| a & b).collect(),
            len: self.len,
        }
    }

    /// Scaled stochastic addition via MUX (Fig. 2 bottom): `sel` picks
    /// `self` where its bit is 1, `other` where 0. With `P_sel = 1/2` the
    /// output mean is `(Px + Py)/2`.
    pub fn mux(&self, other: &Bitstream, sel: &Bitstream) -> Bitstream {
        assert_eq!(self.len, other.len);
        assert_eq!(self.len, sel.len);
        Bitstream {
            words: self
                .words
                .iter()
                .zip(&other.words)
                .zip(&sel.words)
                .map(|((a, b), s)| (a & s) | (b & !s))
                .collect(),
            len: self.len,
        }
    }

    /// Bitwise NOT: encodes `1 - p` (unipolar complement).
    // justification: named for the SC operation, not the `std::ops::Not`
    // trait (which would consume or re-borrow awkwardly at call sites).
    #[allow(clippy::should_implement_trait)]
    pub fn not(&self) -> Bitstream {
        let mut out = Bitstream {
            words: self.words.iter().map(|w| !w).collect(),
            len: self.len,
        };
        out.mask_tail();
        out
    }

    /// XNOR: bipolar-format multiplication (means map [0,1]→[-1,1]).
    pub fn xnor(&self, other: &Bitstream) -> Bitstream {
        assert_eq!(self.len, other.len);
        let mut out = Bitstream {
            words: self.words.iter().zip(&other.words).map(|(a, b)| !(a ^ b)).collect(),
            len: self.len,
        };
        out.mask_tail();
        out
    }

    /// Number of positions where the two streams agree — the popcount of
    /// [`Self::xnor`] without materializing the XNOR stream (the bipolar
    /// multiply only ever decodes that stream's popcount, so the scalar
    /// `Exact` SC-PwMM path stays allocation-free through here). The tail
    /// of the last word is masked exactly as `xnor` would.
    pub fn xnor_match_count(&self, other: &Bitstream) -> u64 {
        assert_eq!(self.len, other.len, "stream length mismatch");
        let mut ones = 0u64;
        let last = self.words.len().wrapping_sub(1);
        let rem = self.len % 64;
        for (i, (a, b)) in self.words.iter().zip(&other.words).enumerate() {
            let mut m = !(a ^ b);
            if i == last && rem != 0 {
                m &= (1u64 << rem) - 1;
            }
            ones += m.count_ones() as u64;
        }
        ones
    }

    /// Zero any bits at positions >= len (after whole-word inversions).
    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Raw packed words (read-only) — used by the SC-PwMM hot path.
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

/// Correlation (overlap) coefficient between two streams: the SCC metric.
/// 0 for independent streams; +1 for maximally-overlapped; -1 for
/// maximally-disjoint. Used in tests to verify decorrelation machinery.
pub fn scc(a: &Bitstream, b: &Bitstream) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let p1 = a.mean();
    let p2 = b.mean();
    let p12 = a.and(b).popcount() as f64 / n;
    let delta = p12 - p1 * p2;
    if delta > 0.0 {
        let d = p1.min(p2) - p1 * p2;
        if d == 0.0 {
            0.0
        } else {
            delta / d
        }
    } else {
        let d = p1 * p2 - (p1 + p2 - 1.0).max(0.0);
        if d == 0.0 {
            0.0
        } else {
            delta / d
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::{Lfsr16, Sobol, XorShift64};
    use crate::testing::{check, UnitF64};

    #[test]
    fn generate_encodes_probability() {
        let mut rng = XorShift64::new(5);
        let s = Bitstream::generate(0.7, 4096, &mut rng);
        assert!((s.mean() - 0.7).abs() < 0.03, "mean={}", s.mean());
    }

    #[test]
    fn sobol_generate_is_tight() {
        let mut rng = Sobol::new(0);
        let s = Bitstream::generate(0.7, 256, &mut rng);
        assert!((s.mean() - 0.7).abs() <= 1.0 / 256.0 + 1e-12);
    }

    #[test]
    fn generate_word_built_equals_per_bit_reference() {
        // The word-accumulating generator must emit bit-for-bit the same
        // stream as the naive per-bit set() construction on the same rng.
        fn per_bit_reference(p: f64, len: usize, rng: &mut impl StreamRng) -> Bitstream {
            let threshold = (p.clamp(0.0, 1.0) * 65536.0).round().min(65535.0) as u16;
            let mut s = Bitstream::zeros(len);
            for i in 0..len {
                if rng.next_u16() < threshold {
                    s.set(i, true);
                }
            }
            s
        }
        for (p, len, seed) in [
            (0.7, 4096, 5u64),
            (0.3, 1, 6),
            (0.5, 63, 7),
            (0.5, 64, 8),
            (0.9, 65, 9),
            (0.0, 130, 10),
            (1.0, 130, 11),
        ] {
            let mut r1 = XorShift64::new(seed);
            let mut r2 = XorShift64::new(seed);
            let fast = Bitstream::generate(p, len, &mut r1);
            let slow = per_bit_reference(p, len, &mut r2);
            assert_eq!(fast, slow, "p={p} len={len}");
        }
        // LFSR entropy too (different word widths exercised).
        let mut r1 = Lfsr16::new(0x1357);
        let mut r2 = Lfsr16::new(0x1357);
        assert_eq!(
            Bitstream::generate(0.42, 1000, &mut r1),
            per_bit_reference(0.42, 1000, &mut r2)
        );
    }

    #[test]
    fn generate_into_reuse_equals_fresh_generate() {
        // One scratch stream regenerated across lengths/probabilities must
        // match a fresh construction every time (the Exact-mode multiply
        // reuses a scratch pair like this once per product).
        let mut scratch = Bitstream::zeros(0);
        for (p, len, seed) in
            [(0.7, 4096, 21u64), (0.3, 63, 22), (0.5, 64, 23), (0.0, 1, 24), (1.0, 130, 25)]
        {
            let mut r1 = XorShift64::new(seed);
            let mut r2 = XorShift64::new(seed);
            scratch.generate_into(p, len, &mut r1);
            assert_eq!(scratch, Bitstream::generate(p, len, &mut r2), "p={p} len={len}");
        }
        // Shrinking reuse: a long stream followed by a short one must not
        // leave stale words behind.
        let mut r = XorShift64::new(9);
        scratch.generate_into(0.4, 10, &mut r);
        assert_eq!(scratch.len(), 10);
        assert_eq!(scratch.words().len(), 1);
    }

    #[test]
    fn xnor_match_count_equals_materialized_xnor() {
        for (pa, pb, len) in
            [(0.7, 0.2, 1000), (0.5, 0.5, 64), (0.9, 0.1, 63), (0.3, 0.8, 129), (0.0, 1.0, 1)]
        {
            let mut r1 = XorShift64::new(31);
            let mut r2 = XorShift64::new(32);
            let a = Bitstream::generate(pa, len, &mut r1);
            let b = Bitstream::generate(pb, len, &mut r2);
            assert_eq!(
                a.xnor_match_count(&b),
                a.xnor(&b).popcount(),
                "pa={pa} pb={pb} len={len}"
            );
        }
        let empty = Bitstream::zeros(0);
        assert_eq!(empty.xnor_match_count(&Bitstream::zeros(0)), 0);
    }

    #[test]
    fn and_multiplies() {
        let mut r1 = XorShift64::new(1);
        let mut r2 = XorShift64::new(2);
        let a = Bitstream::generate(0.6, 8192, &mut r1);
        let b = Bitstream::generate(0.5, 8192, &mut r2);
        let z = a.and(&b);
        assert!((z.mean() - 0.3).abs() < 0.03, "mean={}", z.mean());
    }

    #[test]
    fn mux_adds_scaled() {
        let mut r1 = XorShift64::new(3);
        let mut r2 = XorShift64::new(4);
        let mut r3 = XorShift64::new(5);
        let a = Bitstream::generate(0.8, 8192, &mut r1);
        let b = Bitstream::generate(0.2, 8192, &mut r2);
        let s = Bitstream::generate(0.5, 8192, &mut r3);
        let z = a.mux(&b, &s);
        assert!((z.mean() - 0.5).abs() < 0.03, "mean={}", z.mean());
    }

    #[test]
    fn not_complements_exactly() {
        let mut rng = Lfsr16::new(77);
        let s = Bitstream::generate(0.3, 1000, &mut rng);
        let ns = s.not();
        assert_eq!(ns.popcount(), 1000 - s.popcount());
        // Tail bits must stay masked.
        assert!((s.mean() + ns.mean() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn xnor_bipolar_multiplies() {
        // bipolar value v = 2p-1. xnor: v_out = v1*v2.
        let mut r1 = XorShift64::new(6);
        let mut r2 = XorShift64::new(7);
        let p1 = 0.9; // v=0.8
        let p2 = 0.25; // v=-0.5
        let a = Bitstream::generate(p1, 16384, &mut r1);
        let b = Bitstream::generate(p2, 16384, &mut r2);
        let z = a.xnor(&b);
        let v = 2.0 * z.mean() - 1.0;
        assert!((v - (0.8 * -0.5)).abs() < 0.03, "v={v}");
    }

    #[test]
    fn get_set_roundtrip() {
        let mut s = Bitstream::zeros(130);
        s.set(0, true);
        s.set(64, true);
        s.set(129, true);
        assert!(s.get(0) && s.get(64) && s.get(129));
        assert!(!s.get(1) && !s.get(128));
        assert_eq!(s.popcount(), 3);
        s.set(64, false);
        assert_eq!(s.popcount(), 2);
    }

    #[test]
    fn empty_stream() {
        let s = Bitstream::zeros(0);
        assert_eq!(s.mean(), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn scc_of_identical_is_one() {
        let mut rng = XorShift64::new(8);
        let s = Bitstream::generate(0.5, 2048, &mut rng);
        assert!((scc(&s, &s) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn scc_of_independent_near_zero() {
        let mut r1 = XorShift64::new(9);
        let mut r2 = XorShift64::new(10);
        let a = Bitstream::generate(0.5, 65536, &mut r1);
        let b = Bitstream::generate(0.5, 65536, &mut r2);
        assert!(scc(&a, &b).abs() < 0.05, "scc={}", scc(&a, &b));
    }

    #[test]
    fn prop_and_mean_bounded_by_min() {
        // For ANY pair of streams, P(a AND b) <= min(Pa, Pb).
        check(11, 64, &UnitF64::unit(), |&p| {
            let mut r1 = XorShift64::new((p * 1e9) as u64 + 1);
            let mut r2 = XorShift64::new((p * 1e9) as u64 + 2);
            let a = Bitstream::generate(p, 2048, &mut r1);
            let b = Bitstream::generate(1.0 - p, 2048, &mut r2);
            a.and(&b).mean() <= a.mean().min(b.mean()) + 1e-12
        });
    }

    #[test]
    fn prop_generate_mean_within_clt_bound() {
        // 6-sigma CLT bound on the empirical mean of a 4096-bit stream.
        check(12, 64, &UnitF64::unit(), |&p| {
            let mut rng = XorShift64::new((p.to_bits()).wrapping_mul(2654435761));
            let s = Bitstream::generate(p, 4096, &mut rng);
            let sigma = (p * (1.0 - p) / 4096.0).sqrt();
            (s.mean() - p).abs() <= 6.0 * sigma + 1.0 / 65536.0 + 1e-12
        });
    }
}

//! Bit-plane words: the SIMD lane substrate of the wide SMURF engine.
//!
//! The bit-sliced pipeline ([`crate::smurf::sim_wide`]) stores every
//! 16-bit datapath word as 16 *bit planes*, where plane `b` holds bit `b`
//! of every lane's word. PR 1 hardwired the plane type to `u64` (64
//! lanes); everything the engine does to a plane is plain boolean algebra
//! plus a handful of carry-chain steps, so the plane type is really a
//! trait — and widening it multiplies lane count with the identical
//! slicing scheme.
//!
//! [`BitPlane`] is that trait. Three implementations ship:
//!
//! - `u64` — 64 lanes, one machine word. The default type parameter of
//!   every wide type, so existing code and streams are unchanged.
//! - `[u64; 4]` — 256 lanes. Written as straight-line per-word array ops
//!   with no cross-word data flow, which LLVM autovectorizes to AVX2
//!   (4 × u64 per ymm) or 2 × NEON; on scalar-only targets it degrades to
//!   4 independent word ops, never worse per lane than `u64`.
//! - `[u64; 8]` — 512 lanes, behind the `wide512` cargo feature (profits
//!   on AVX-512 hardware; elsewhere it just splits into 2 × 256-bit or
//!   8 × 64-bit ops).
//!
//! Lanes are numbered `0 .. LANES`; lane `l` of an `[u64; W]` plane is bit
//! `l & 63` of word `l >> 6`, so `u64` lane numbering embeds unchanged.
//!
//! # Adding a width
//!
//! Implement [`BitPlane`] (the `impl_bitplane_words!` macro does it for
//! any `[u64; W]`), give it a thread-local scratch with the
//! `impl_thread_scratch!` line in `smurf::sim_wide`, and register it in
//! [`for_each_plane_width!`](crate::for_each_plane_width) so every
//! width-parametric test suite fans out over it. Every wide type — RNG
//! lanes, comparators, chain FSMs, the full simulator — is generic over
//! the plane and inherits the new width; the lane-equivalence property
//! suite in `sim_wide::tests` is width-parametric (add per-width `#[test]`
//! wrappers there), so the bit-exactness contract is tested, not assumed.

/// One plane: a word holding one bit for each of `LANES` independent
/// lanes. All operations are lane-wise boolean algebra — no arithmetic
/// carries ever cross a lane boundary, which is what makes N-lane
/// simulation of N independent machines exact.
///
/// Everything here must stay branch-free and `#[inline(always)]`-cheap:
/// these ops run a few dozen times per simulated clock inside the
/// hottest loop in the crate.
pub trait BitPlane: Copy + Eq + std::fmt::Debug + Send + Sync + 'static {
    /// Number of lanes carried per plane word.
    const LANES: usize;

    /// All-zeros plane.
    fn zero() -> Self;

    /// All-ones plane.
    fn ones() -> Self;

    /// Broadcast one bit to every lane.
    #[inline(always)]
    fn splat(bit: bool) -> Self {
        if bit {
            Self::ones()
        } else {
            Self::zero()
        }
    }

    fn and(self, other: Self) -> Self;
    fn or(self, other: Self) -> Self;
    fn xor(self, other: Self) -> Self;
    fn not(self) -> Self;

    /// `self & !other` — the masked-clear idiom of the MSB-first
    /// comparators (`lt |= eq & !p`).
    #[inline(always)]
    fn and_not(self, other: Self) -> Self {
        self.and(other.not())
    }

    /// True iff no lane has its bit set — every carry/borrow ripple and
    /// comparator fold early-exits on this.
    fn is_zero(self) -> bool;

    /// Population count across lanes (bitstream decode / debug).
    fn count_ones(self) -> u32;

    /// Extract lane `l`'s bit.
    fn lane(self, l: usize) -> bool;

    /// Set lane `l`'s bit (the transpose-insert used by
    /// [`crate::sc::rng::planes_from_lanes`] and the scalar-stepped
    /// xorshift lanes).
    fn set_lane(&mut self, l: usize);

    /// Set lane `l`'s bit iff `bit`, branch-free: the comparator pack
    /// loops (`WideXorShift64::next_lt_lanes` and friends) fold one
    /// data-dependent compare per lane, and a conditional store would put
    /// a ~50% mispredicted branch in the hottest loop of the PwMM engine.
    fn set_lane_if(&mut self, l: usize, bit: bool);

    /// Half-adder: `(sum, carry) = (a ^ b, a & b)`. One step of the
    /// carry-save ripple used by the Sobol counter, the chain-FSM masked
    /// increment and the vertical output counter.
    #[inline(always)]
    fn half_add(self, other: Self) -> (Self, Self) {
        (self.xor(other), self.and(other))
    }

    /// Half-subtractor: `(diff, borrow') = (a ^ borrow, !a & borrow)` —
    /// the chain-FSM masked decrement step.
    #[inline(always)]
    fn half_sub(self, borrow: Self) -> (Self, Self) {
        (self.xor(borrow), self.not().and(borrow))
    }

    /// Shift every lane's bit down by `lanes` positions: output lane `l`
    /// is input lane `l + lanes` (vacated high lanes read 0). This is the
    /// lane-group alignment step of the TMR majority vote
    /// ([`crate::sc::fault::vote3`]): with three redundant groups of `k`
    /// lanes, `vote3(p, p.shift_lanes_down(k), p.shift_lanes_down(2*k))`
    /// puts each logical lane's majority verdict back in group 0.
    /// Requires `lanes < LANES`.
    fn shift_lanes_down(self, lanes: usize) -> Self;
}

impl BitPlane for u64 {
    const LANES: usize = 64;

    #[inline(always)]
    fn zero() -> Self {
        0
    }

    #[inline(always)]
    fn ones() -> Self {
        !0
    }

    #[inline(always)]
    fn and(self, other: Self) -> Self {
        self & other
    }

    #[inline(always)]
    fn or(self, other: Self) -> Self {
        self | other
    }

    #[inline(always)]
    fn xor(self, other: Self) -> Self {
        self ^ other
    }

    #[inline(always)]
    fn not(self) -> Self {
        !self
    }

    #[inline(always)]
    fn is_zero(self) -> bool {
        self == 0
    }

    #[inline(always)]
    fn count_ones(self) -> u32 {
        u64::count_ones(self)
    }

    #[inline(always)]
    fn lane(self, l: usize) -> bool {
        debug_assert!(l < 64);
        (self >> l) & 1 == 1
    }

    #[inline(always)]
    fn set_lane(&mut self, l: usize) {
        debug_assert!(l < 64);
        *self |= 1u64 << l;
    }

    #[inline(always)]
    fn set_lane_if(&mut self, l: usize, bit: bool) {
        debug_assert!(l < 64);
        *self |= (bit as u64) << l;
    }

    #[inline(always)]
    fn shift_lanes_down(self, lanes: usize) -> Self {
        debug_assert!(lanes < 64);
        self >> lanes
    }
}

/// Implement [`BitPlane`] for `[u64; W]` as straight-line per-word array
/// ops. The fixed-trip-count loops have no cross-iteration dependence, so
/// LLVM unrolls and autovectorizes them (AVX2/NEON for W=4, AVX-512 for
/// W=8) on stable Rust with no intrinsics.
macro_rules! impl_bitplane_words {
    ($($w:literal),+ $(,)?) => {$(
        impl BitPlane for [u64; $w] {
            const LANES: usize = 64 * $w;

            #[inline(always)]
            fn zero() -> Self {
                [0; $w]
            }

            #[inline(always)]
            fn ones() -> Self {
                [!0; $w]
            }

            #[inline(always)]
            fn and(self, other: Self) -> Self {
                let mut r = self;
                for (a, b) in r.iter_mut().zip(other.iter()) {
                    *a &= b;
                }
                r
            }

            #[inline(always)]
            fn or(self, other: Self) -> Self {
                let mut r = self;
                for (a, b) in r.iter_mut().zip(other.iter()) {
                    *a |= b;
                }
                r
            }

            #[inline(always)]
            fn xor(self, other: Self) -> Self {
                let mut r = self;
                for (a, b) in r.iter_mut().zip(other.iter()) {
                    *a ^= b;
                }
                r
            }

            #[inline(always)]
            fn not(self) -> Self {
                let mut r = self;
                for a in r.iter_mut() {
                    *a = !*a;
                }
                r
            }

            #[inline(always)]
            fn is_zero(self) -> bool {
                let mut acc = 0u64;
                for &a in self.iter() {
                    acc |= a;
                }
                acc == 0
            }

            #[inline(always)]
            fn count_ones(self) -> u32 {
                let mut n = 0u32;
                for &a in self.iter() {
                    n += a.count_ones();
                }
                n
            }

            #[inline(always)]
            fn lane(self, l: usize) -> bool {
                debug_assert!(l < Self::LANES);
                (self[l >> 6] >> (l & 63)) & 1 == 1
            }

            #[inline(always)]
            fn set_lane(&mut self, l: usize) {
                debug_assert!(l < Self::LANES);
                self[l >> 6] |= 1u64 << (l & 63);
            }

            #[inline(always)]
            fn set_lane_if(&mut self, l: usize, bit: bool) {
                debug_assert!(l < Self::LANES);
                self[l >> 6] |= (bit as u64) << (l & 63);
            }

            #[inline(always)]
            fn shift_lanes_down(self, lanes: usize) -> Self {
                debug_assert!(lanes < Self::LANES);
                // Multi-word funnel shift: word i takes the high bits of
                // word i+q shifted down by r, topped up from word i+q+1.
                let q = lanes >> 6;
                let r = lanes & 63;
                let mut out = [0u64; $w];
                for i in 0..($w - q) {
                    let lo = self[i + q] >> r;
                    let hi = if r != 0 && i + q + 1 < $w {
                        self[i + q + 1] << (64 - r)
                    } else {
                        0
                    };
                    out[i] = lo | hi;
                }
                out
            }
        }
    )+};
}

impl_bitplane_words!(4);
#[cfg(feature = "wide512")]
impl_bitplane_words!(8);

/// The widest [`BitPlane`] compiled into this build: `[u64; 8]`
/// (512 lanes) with the `wide512` cargo feature, `[u64; 4]` (256 lanes)
/// otherwise. The auto-width batch entry points across the crate (the
/// SMURF estimators and activation batches via
/// [`crate::smurf::sim_wide`], the SC-PwMM multiply batches via
/// [`crate::sc::pwmm_wide`], the coordinator's `BitLevel` chunking) pick
/// this plane automatically; narrower planes remain available to callers
/// that name them. Lives here (not in `smurf::sim_wide`, which re-exports
/// it) because the plane substrate is below every engine that chunks by
/// it.
#[cfg(feature = "wide512")]
pub type MaxPlane = [u64; 8];
/// The widest [`BitPlane`] compiled into this build: `[u64; 8]`
/// (512 lanes) with the `wide512` cargo feature, `[u64; 4]` (256 lanes)
/// otherwise. The auto-width batch entry points across the crate (the
/// SMURF estimators and activation batches via
/// [`crate::smurf::sim_wide`], the SC-PwMM multiply batches via
/// [`crate::sc::pwmm_wide`], the coordinator's `BitLevel` chunking) pick
/// this plane automatically; narrower planes remain available to callers
/// that name them. Lives here (not in `smurf::sim_wide`, which re-exports
/// it) because the plane substrate is below every engine that chunks by
/// it.
#[cfg(not(feature = "wide512"))]
pub type MaxPlane = [u64; 4];

/// Lane count of [`MaxPlane`] — the chunk size of every auto-width batch
/// entry point.
pub const MAX_LANES: usize = <MaxPlane as BitPlane>::LANES;

/// Invoke `$f::<P>()` once per compiled plane width — `u64`, `[u64; 4]`,
/// and (under the `wide512` feature) `[u64; 8]`. The width-parametric
/// test helpers across the crate fan out through this, so registering a
/// new width in those suites is one edit here; only the per-width named
/// `#[test]` wrappers in `smurf::sim_wide` (kept explicit for test
/// granularity) list widths by hand.
#[macro_export]
macro_rules! for_each_plane_width {
    ($f:ident) => {{
        // xtask: allow(plane-default) justification: for_each_plane_width
        // is the single width-registration fan-out — the one place a
        // concrete u64 turbofish belongs in a generic module.
        $f::<u64>();
        $f::<[u64; 4]>();
        #[cfg(feature = "wide512")]
        $f::<[u64; 8]>();
    }};
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg;

    /// Reference model: a plane is just `LANES` independent booleans.
    fn random_plane<P: BitPlane>(rng: &mut Pcg) -> (P, Vec<bool>) {
        let mut p = P::zero();
        let mut bits = Vec::with_capacity(P::LANES);
        for l in 0..P::LANES {
            let b = rng.next_u64() & 1 == 1;
            if b {
                p.set_lane(l);
            }
            bits.push(b);
        }
        (p, bits)
    }

    fn check_lanewise_ops<P: BitPlane>() {
        let mut rng = Pcg::new(0xBEEF ^ P::LANES as u64);
        for _ in 0..20 {
            let (a, av) = random_plane::<P>(&mut rng);
            let (b, bv) = random_plane::<P>(&mut rng);
            let mut ones = 0u32;
            for l in 0..P::LANES {
                assert_eq!(a.lane(l), av[l]);
                assert_eq!(a.and(b).lane(l), av[l] & bv[l]);
                assert_eq!(a.or(b).lane(l), av[l] | bv[l]);
                assert_eq!(a.xor(b).lane(l), av[l] ^ bv[l]);
                assert_eq!(a.not().lane(l), !av[l]);
                assert_eq!(a.and_not(b).lane(l), av[l] & !bv[l]);
                let (s, c) = a.half_add(b);
                assert_eq!(s.lane(l), av[l] ^ bv[l]);
                assert_eq!(c.lane(l), av[l] & bv[l]);
                let (d, w) = a.half_sub(b);
                assert_eq!(d.lane(l), av[l] ^ bv[l]);
                assert_eq!(w.lane(l), !av[l] & bv[l]);
                ones += av[l] as u32;
            }
            assert_eq!(a.count_ones(), ones);
            assert_eq!(a.is_zero(), ones == 0);
        }
        assert!(P::zero().is_zero());
        assert!(!P::ones().is_zero());
        assert_eq!(P::ones().count_ones() as usize, P::LANES);
        assert_eq!(P::splat(true), P::ones());
        assert_eq!(P::splat(false), P::zero());
        for l in [0, 1, P::LANES / 2, P::LANES - 1] {
            let mut p = P::zero();
            p.set_lane(l);
            assert_eq!(p.count_ones(), 1);
            assert!(p.lane(l));
            let mut q = P::zero();
            q.set_lane_if(l, false);
            assert!(q.is_zero(), "set_lane_if(false) must be a no-op");
            q.set_lane_if(l, true);
            assert_eq!(q, p, "set_lane_if(true) must equal set_lane");
        }
    }

    #[test]
    fn plane_lanewise_ops_all_widths() {
        crate::for_each_plane_width!(check_lanewise_ops);
    }

    fn check_shift_lanes_down<P: BitPlane>() {
        let mut rng = Pcg::new(0x5417 ^ P::LANES as u64);
        let shifts = [0usize, 1, 7, 21, 63, 64, 85, 170, P::LANES - 1];
        for _ in 0..10 {
            let (p, bits) = random_plane::<P>(&mut rng);
            for &k in shifts.iter().filter(|&&k| k < P::LANES) {
                let s = p.shift_lanes_down(k);
                for l in 0..P::LANES {
                    let want = l + k < P::LANES && bits[l + k];
                    assert_eq!(s.lane(l), want, "shift={k} lane={l}");
                }
            }
        }
    }

    #[test]
    fn shift_lanes_down_matches_lane_model() {
        crate::for_each_plane_width!(check_shift_lanes_down);
    }

    #[test]
    fn array_lane_numbering_embeds_u64() {
        // Lane l of [u64; W] is bit (l & 63) of word (l >> 6): the first
        // 64 lanes are word 0, exactly the u64 plane.
        let mut p = <[u64; 4]>::zero();
        p.set_lane(5);
        p.set_lane(64);
        p.set_lane(255);
        assert_eq!(p[0], 1u64 << 5);
        assert_eq!(p[1], 1u64 << 0);
        assert_eq!(p[3], 1u64 << 63);
    }
}

//! Bit-level fault injection for the SC engines: deterministic stuck-at
//! and transient-flip faults, plus the TMR majority vote that mitigates
//! them.
//!
//! SMURF's hardware story (and the SC literature it cites — e.g. the
//! SC-DCNN line of work) leans on stochastic computing's inherent
//! soft-error tolerance: a flipped bit in a 2^10-cycle bitstream perturbs
//! the decoded value by 2^-10, not 2^-1. This module makes that claim
//! *measurable* in the simulators instead of folklore. It models the
//! three classic gate-level fault kinds at four datapath sites of the
//! Fig. 6 pipeline:
//!
//! | [`FaultSite`]      | hardware signal                                  |
//! |--------------------|--------------------------------------------------|
//! | `EntropyWord`      | the 16-bit RNG branch words feeding every θ-gate |
//! | `ThetaOutput`      | the input θ-gate comparator output bits          |
//! | `FsmState`         | the chain-FSM state register bits                |
//! | `OutputBit`        | the CPT-gate output bit entering the counter     |
//!
//! and the three kinds per site, each with an independent per-bit,
//! per-cycle probability ([`FaultRates`]): stuck-at-0 (AND with the
//! complement of a Bernoulli mask), stuck-at-1 (OR), transient flip
//! (XOR). Applying an armed site therefore costs **one AND/OR/XOR per
//! plane word per armed kind** in the wide engine — the masks are
//! ordinary [`BitPlane`] words — and nothing at all when the engine has
//! no plan: the simulators are generic over a hook trait
//! ([`ScalarFaultHook`] / [`WideFaultHook`]) whose inert implementation
//! ([`NoFaults`]) is a zero-sized type with identity methods, so the
//! clean instantiation monomorphizes to exactly the pre-fault code with
//! zero added branches.
//!
//! # Determinism
//!
//! A [`BitFaultPlan`] is pure configuration: a seed plus per-site rates.
//! Fault mask entropy comes from dedicated xorshift64* streams — one per
//! site, seeded by splitmix from `(plan seed, site, lane)` — that are
//! (re)seeded at the start of every simulator run, so a given
//! `(plan, input, stream length, run seeds)` always reproduces the same
//! faulted bitstream, at every plane width. Two deliberate consequences:
//! wide lanes draw *independent* fault streams (lane `l`'s faults differ
//! from lane `m`'s, and from the scalar simulator's — fault injection is
//! a statistical experiment, not part of the lane-equivalence contract),
//! and repeated runs on one engine see the same fault pattern per run
//! seed (reproducibility beats pattern diversity here; sweep the plan
//! seed for diversity).
//!
//! Rates are quantized to the same 16-bit θ-gate grid as every other
//! probability in the engine ([`quantize_threshold`]); a rate that
//! quantizes to 0 (anything below ~2^-17) never fires and never draws
//! entropy, which is what makes the **zero-rate identity** hold exactly:
//! an armed plan whose rates are all zero is bit-identical to the clean
//! path (property-tested in `smurf::sim`/`sim_wide` across widths and
//! entropy modes).
//!
//! # Mitigation: lane-level TMR
//!
//! The classic SC hardening is triple modular redundancy on the stream:
//! run three copies, majority-vote each output bit. The wide engine gets
//! this almost for free — lanes are already independent replicas — so
//! `WideBitLevelSmurf::eval_trials_tmr` seeds three lane *groups* with
//! the same trial seeds, lets faults hit each group independently, and
//! votes the output plane per cycle with [`vote3`] after aligning the
//! groups with [`BitPlane::shift_lanes_down`]. A corrupted bit must
//! appear in two of three groups in the same cycle to survive.

use crate::sc::plane::BitPlane;
use crate::sc::rng::{StreamRng, WideXorShift64, XorShift64};
use crate::sc::sng::quantize_threshold;

/// Datapath sites a [`BitFaultPlan`] can target (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// The 16-bit entropy words of every RNG branch (M input θ-gate
    /// branches and the CPT branch), per bit.
    EntropyWord,
    /// The input θ-gate comparator output bits (the FSM `up` inputs).
    ThetaOutput,
    /// The chain-FSM state register bits (after the clock edge; injected
    /// patterns outside `0..N` saturate to `N-1` — see
    /// `ChainFsm::inject` / `WideChainFsm::inject`).
    FsmState,
    /// The CPT-gate output bit entering the output counter.
    OutputBit,
}

impl FaultSite {
    /// Number of distinct sites.
    pub const COUNT: usize = 4;

    /// All sites, in pipeline order.
    pub const ALL: [FaultSite; FaultSite::COUNT] = [
        FaultSite::EntropyWord,
        FaultSite::ThetaOutput,
        FaultSite::FsmState,
        FaultSite::OutputBit,
    ];

    /// Dense index (array key into per-site tables).
    #[inline(always)]
    pub fn index(self) -> usize {
        self as usize
    }
}

/// Per-bit, per-cycle fault probabilities of one site. All three kinds
/// are independent; within a cycle they apply in the fixed order
/// stuck-at-0 → stuck-at-1 → flip.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultRates {
    /// P(bit forced to 0) per bit per cycle.
    pub stuck_at_zero: f64,
    /// P(bit forced to 1) per bit per cycle.
    pub stuck_at_one: f64,
    /// P(bit inverted) per bit per cycle.
    pub flip: f64,
}

impl FaultRates {
    /// No faults.
    pub const NONE: FaultRates =
        FaultRates { stuck_at_zero: 0.0, stuck_at_one: 0.0, flip: 0.0 };

    /// Transient flips only.
    pub fn flips(rate: f64) -> Self {
        Self { flip: rate, ..Self::NONE }
    }

    /// Stuck-at-0 only.
    pub fn stuck0(rate: f64) -> Self {
        Self { stuck_at_zero: rate, ..Self::NONE }
    }

    /// Stuck-at-1 only.
    pub fn stuck1(rate: f64) -> Self {
        Self { stuck_at_one: rate, ..Self::NONE }
    }

    /// 16-bit θ-grid thresholds (the runtime form).
    fn quantized(&self) -> SiteThresholds {
        let s0 = quantize_threshold(self.stuck_at_zero);
        let s1 = quantize_threshold(self.stuck_at_one);
        let flip = quantize_threshold(self.flip);
        SiteThresholds { s0, s1, flip, armed: s0 | s1 | flip != 0 }
    }
}

/// Quantized per-site thresholds; `armed` is false iff every kind
/// quantized to zero (such a site never draws fault entropy).
#[derive(Clone, Copy, Debug, Default)]
struct SiteThresholds {
    s0: u16,
    s1: u16,
    flip: u16,
    armed: bool,
}

/// A deterministic, seed-driven bit-fault configuration: per-site
/// [`FaultRates`] plus the seed of the fault-entropy streams. Inert by
/// default ([`BitFaultPlan::new`] sets every rate to zero); arm sites
/// with [`BitFaultPlan::with_site`] or all at once with
/// [`BitFaultPlan::uniform`]. Attach to an engine with
/// `BitLevelSmurf::with_fault_plan` / `WideBitLevelSmurf::with_fault_plan`.
#[derive(Clone, Debug, PartialEq)]
pub struct BitFaultPlan {
    seed: u64,
    rates: [FaultRates; FaultSite::COUNT],
}

impl BitFaultPlan {
    /// An inert plan (all rates zero) with the given fault-entropy seed.
    pub fn new(seed: u64) -> Self {
        Self { seed, rates: [FaultRates::NONE; FaultSite::COUNT] }
    }

    /// The same rates at every site.
    pub fn uniform(seed: u64, rates: FaultRates) -> Self {
        Self { seed, rates: [rates; FaultSite::COUNT] }
    }

    /// Builder: set one site's rates.
    pub fn with_site(mut self, site: FaultSite, rates: FaultRates) -> Self {
        self.rates[site.index()] = rates;
        self
    }

    /// The fault-entropy seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// One site's configured rates.
    pub fn rates(&self, site: FaultSite) -> FaultRates {
        self.rates[site.index()]
    }

    /// True iff no site can ever fire (every rate quantizes to zero on
    /// the 16-bit θ grid). An inert plan attached to an engine is
    /// bit-identical to no plan at all.
    pub fn is_inert(&self) -> bool {
        self.rates.iter().all(|r| !r.quantized().armed)
    }

    /// Fresh scalar fault streams for one simulator run.
    pub fn scalar_state(&self) -> ScalarFaultState {
        ScalarFaultState {
            sites: std::array::from_fn(|i| ScalarSite {
                t: self.rates[i].quantized(),
                rng: XorShift64::new(lane_seed(self.seed, i, 0)),
            }),
        }
    }
}

/// splitmix64 finalizer — decorrelates the per-(site, lane) fault
/// streams from the plan seed and from each other.
fn splitmix(x: u64) -> u64 {
    let mut z = x.wrapping_add(crate::util::prng::GOLDEN_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Seed of site `site`, lane `l`'s fault stream. The scalar simulator
/// uses lane 0's streams.
fn lane_seed(seed: u64, site: usize, lane: usize) -> u64 {
    splitmix(
        seed ^ (site as u64).wrapping_mul(0xA24BAED4963EE407)
            ^ (lane as u64).wrapping_mul(0xD1B54A32D192ED03),
    )
}

// ---------------------------------------------------------------------
// Hook traits: the simulators' run loops are generic over these, so the
// clean path ([`NoFaults`], a ZST with inline identity methods)
// monomorphizes to exactly the pre-fault code.
// ---------------------------------------------------------------------

/// Fault hook of the scalar simulator (`BitLevelSmurf::run`). Every
/// method defaults to identity; [`ScalarFaultState`] overrides.
pub trait ScalarFaultHook {
    /// Corrupt one 16-bit entropy word ([`FaultSite::EntropyWord`]).
    #[inline(always)]
    fn entropy(&mut self, w: u16) -> u16 {
        w
    }

    /// Corrupt one θ-gate output bit ([`FaultSite::ThetaOutput`]).
    #[inline(always)]
    fn theta(&mut self, b: bool) -> bool {
        b
    }

    /// Whether [`FaultSite::FsmState`] is armed (gates the per-step
    /// `ChainFsm::inject` call; const-folds to `false` for [`NoFaults`]).
    #[inline(always)]
    fn state_armed(&self) -> bool {
        false
    }

    /// Corrupt an FSM state's low `nbits` bits ([`FaultSite::FsmState`]);
    /// the FSM clamps the result back into range.
    #[inline(always)]
    fn state(&mut self, s: usize, _nbits: u32) -> usize {
        s
    }

    /// Corrupt the CPT output bit ([`FaultSite::OutputBit`]).
    #[inline(always)]
    fn output(&mut self, b: bool) -> bool {
        b
    }
}

/// Fault hook of the wide simulator (`WideBitLevelSmurf`), operating on
/// whole planes. Every method defaults to identity; [`WideFaultState`]
/// overrides.
pub trait WideFaultHook<P: BitPlane> {
    /// Whether [`FaultSite::EntropyWord`] is armed. When true the Shared-
    /// threshold θ-gate path materializes its rand planes (so there is a
    /// word to corrupt) instead of folding the comparator in the RNG.
    #[inline(always)]
    fn entropy_armed(&self) -> bool {
        false
    }

    /// Whether [`FaultSite::FsmState`] is armed (gates the per-step
    /// `WideChainFsm::inject` call).
    #[inline(always)]
    fn state_armed(&self) -> bool {
        false
    }

    /// Corrupt one cycle's 16 rand planes ([`FaultSite::EntropyWord`]).
    #[inline(always)]
    fn entropy(&mut self, _planes: &mut [P; 16]) {}

    /// Corrupt a θ-gate comparator mask ([`FaultSite::ThetaOutput`]).
    #[inline(always)]
    fn theta(&mut self, p: P) -> P {
        p
    }

    /// Corrupt the live FSM state planes ([`FaultSite::FsmState`]); the
    /// FSM clamps out-of-range lanes afterwards.
    #[inline(always)]
    fn state(&mut self, _planes: &mut [P]) {}

    /// Corrupt the CPT output mask ([`FaultSite::OutputBit`]).
    #[inline(always)]
    fn output(&mut self, p: P) -> P {
        p
    }
}

/// The inert hook: a zero-sized type whose identity methods inline away,
/// so a simulator run with `NoFaults` compiles to the clean pipeline with
/// zero added branches.
pub struct NoFaults;

impl ScalarFaultHook for NoFaults {}
impl<P: BitPlane> WideFaultHook<P> for NoFaults {}

// ---------------------------------------------------------------------
// Armed implementations.
// ---------------------------------------------------------------------

/// Bernoulli mask over the low `bits` bits: bit `b` fires iff an
/// independent 16-bit draw lands under `t`.
fn mask_bits(rng: &mut XorShift64, bits: u32, t: u16) -> u32 {
    let mut m = 0u32;
    for b in 0..bits {
        m |= ((rng.next_u16() < t) as u32) << b;
    }
    m
}

struct ScalarSite {
    t: SiteThresholds,
    rng: XorShift64,
}

impl ScalarSite {
    /// Corrupt a single bit (θ-gate / CPT output sites).
    #[inline]
    fn bit(&mut self, mut b: bool) -> bool {
        let SiteThresholds { s0, s1, flip, .. } = self.t;
        if s0 != 0 && self.rng.next_u16() < s0 {
            b = false;
        }
        if s1 != 0 && self.rng.next_u16() < s1 {
            b = true;
        }
        if flip != 0 && self.rng.next_u16() < flip {
            b = !b;
        }
        b
    }

    /// Corrupt the low `bits` bits of a word (entropy / FSM-state sites).
    #[inline]
    fn word(&mut self, bits: u32, mut w: u32) -> u32 {
        let SiteThresholds { s0, s1, flip, .. } = self.t;
        if s0 != 0 {
            w &= !mask_bits(&mut self.rng, bits, s0);
        }
        if s1 != 0 {
            w |= mask_bits(&mut self.rng, bits, s1);
        }
        if flip != 0 {
            w ^= mask_bits(&mut self.rng, bits, flip);
        }
        w
    }
}

/// Armed scalar fault streams for one run (see
/// [`BitFaultPlan::scalar_state`]). At zero rates every method is an
/// exact identity that draws no entropy.
pub struct ScalarFaultState {
    sites: [ScalarSite; FaultSite::COUNT],
}

impl ScalarFaultHook for ScalarFaultState {
    #[inline]
    fn entropy(&mut self, w: u16) -> u16 {
        let s = &mut self.sites[FaultSite::EntropyWord.index()];
        if s.t.armed {
            s.word(16, w as u32) as u16
        } else {
            w
        }
    }

    #[inline]
    fn theta(&mut self, b: bool) -> bool {
        let s = &mut self.sites[FaultSite::ThetaOutput.index()];
        if s.t.armed {
            s.bit(b)
        } else {
            b
        }
    }

    #[inline(always)]
    fn state_armed(&self) -> bool {
        self.sites[FaultSite::FsmState.index()].t.armed
    }

    #[inline]
    fn state(&mut self, s: usize, nbits: u32) -> usize {
        self.sites[FaultSite::FsmState.index()].word(nbits, s as u32) as usize
    }

    #[inline]
    fn output(&mut self, b: bool) -> bool {
        let s = &mut self.sites[FaultSite::OutputBit.index()];
        if s.t.armed {
            s.bit(b)
        } else {
            b
        }
    }
}

struct WideSite<P: BitPlane> {
    t: SiteThresholds,
    rng: WideXorShift64<P>,
}

impl<P: BitPlane> WideSite<P> {
    /// Corrupt one plane: at most one AND-NOT/OR/XOR per armed kind, each
    /// against a fresh per-lane Bernoulli mask.
    #[inline]
    fn corrupt(&mut self, mut p: P) -> P {
        let SiteThresholds { s0, s1, flip, .. } = self.t;
        if s0 != 0 {
            p = p.and_not(self.rng.next_lt_const(s0));
        }
        if s1 != 0 {
            p = p.or(self.rng.next_lt_const(s1));
        }
        if flip != 0 {
            p = p.xor(self.rng.next_lt_const(flip));
        }
        p
    }
}

/// Armed wide fault streams: one [`WideXorShift64`] bank per site (every
/// lane draws independently, so TMR replicas see independent faults).
/// Lives in the `WideRunState` scratch and is re-seeded from the plan at
/// the start of each run ([`WideFaultState::reset`]), so buffers are
/// reused allocation-free. At zero rates every method is an exact
/// identity that draws no entropy.
pub struct WideFaultState<P: BitPlane> {
    sites: [WideSite<P>; FaultSite::COUNT],
    /// Reseed staging for the per-lane stream seeds.
    seed_stage: Vec<u64>,
}

impl<P: BitPlane> Default for WideFaultState<P> {
    /// Fully disarmed, no lanes; [`Self::reset`] arms it.
    fn default() -> Self {
        Self {
            sites: std::array::from_fn(|_| WideSite {
                t: SiteThresholds::default(),
                rng: WideXorShift64::from_seeds(&[]),
            }),
            seed_stage: Vec::new(),
        }
    }
}

impl<P: BitPlane> WideFaultState<P> {
    /// Armed streams for `plan` (all `P::LANES` lanes).
    pub fn new(plan: &BitFaultPlan) -> Self {
        let mut st = Self::default();
        st.reset(plan);
        st
    }

    /// Re-arm in place for a fresh run: reload the quantized thresholds
    /// and rewind every armed site's lane streams to the plan seed.
    pub fn reset(&mut self, plan: &BitFaultPlan) {
        let Self { sites, seed_stage } = self;
        for (i, site) in sites.iter_mut().enumerate() {
            site.t = plan.rates[i].quantized();
            if site.t.armed {
                seed_stage.resize(P::LANES, 0);
                for (l, s) in seed_stage.iter_mut().enumerate() {
                    *s = lane_seed(plan.seed, i, l);
                }
                site.rng.reseed(seed_stage);
            } else {
                site.rng.reseed(&[]);
            }
        }
    }
}

impl<P: BitPlane> WideFaultHook<P> for WideFaultState<P> {
    #[inline(always)]
    fn entropy_armed(&self) -> bool {
        self.sites[FaultSite::EntropyWord.index()].t.armed
    }

    #[inline(always)]
    fn state_armed(&self) -> bool {
        self.sites[FaultSite::FsmState.index()].t.armed
    }

    #[inline]
    fn entropy(&mut self, planes: &mut [P; 16]) {
        let s = &mut self.sites[FaultSite::EntropyWord.index()];
        if s.t.armed {
            for p in planes.iter_mut() {
                *p = s.corrupt(*p);
            }
        }
    }

    #[inline]
    fn theta(&mut self, p: P) -> P {
        let s = &mut self.sites[FaultSite::ThetaOutput.index()];
        if s.t.armed {
            s.corrupt(p)
        } else {
            p
        }
    }

    #[inline]
    fn state(&mut self, planes: &mut [P]) {
        let s = &mut self.sites[FaultSite::FsmState.index()];
        for p in planes.iter_mut() {
            *p = s.corrupt(*p);
        }
    }

    #[inline]
    fn output(&mut self, p: P) -> P {
        let s = &mut self.sites[FaultSite::OutputBit.index()];
        if s.t.armed {
            s.corrupt(p)
        } else {
            p
        }
    }
}

/// Per-lane 2-of-3 majority vote — the TMR reduction. One AND per pair
/// plus two ORs, all plane ops.
#[inline(always)]
pub fn vote3<P: BitPlane>(a: P, b: P, c: P) -> P {
    a.and(b).or(a.and(c)).or(b.and(c))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_is_inert_by_default_and_below_quantization() {
        assert!(BitFaultPlan::new(7).is_inert());
        // Rates below the 16-bit θ grid quantize to zero → inert.
        assert!(BitFaultPlan::uniform(7, FaultRates::flips(1e-7)).is_inert());
        assert!(!BitFaultPlan::uniform(7, FaultRates::flips(1e-3)).is_inert());
        let plan = BitFaultPlan::new(7)
            .with_site(FaultSite::OutputBit, FaultRates::stuck1(0.25));
        assert!(!plan.is_inert());
        assert_eq!(plan.rates(FaultSite::OutputBit).stuck_at_one, 0.25);
        assert_eq!(plan.rates(FaultSite::ThetaOutput), FaultRates::NONE);
    }

    #[test]
    fn zero_rate_scalar_state_is_identity_and_draws_nothing() {
        let mut f = BitFaultPlan::new(3).scalar_state();
        for i in 0..200u32 {
            let w = (i.wrapping_mul(2654435761) >> 16) as u16;
            assert_eq!(f.entropy(w), w);
            assert_eq!(f.theta(i % 2 == 0), i % 2 == 0);
            assert_eq!(f.state(i as usize % 8, 3), i as usize % 8);
            assert_eq!(f.output(i % 3 == 0), i % 3 == 0);
        }
        assert!(!f.state_armed());
    }

    fn zero_rate_wide_state_is_identity_generic<P: BitPlane>() {
        let plan = BitFaultPlan::new(11);
        let mut f = WideFaultState::<P>::new(&plan);
        assert!(!WideFaultHook::<P>::entropy_armed(&f));
        assert!(!WideFaultHook::<P>::state_armed(&f));
        let mut p = P::zero();
        p.set_lane(P::LANES / 2);
        assert_eq!(f.theta(p), p);
        assert_eq!(f.output(p), p);
        let mut planes = [p; 16];
        f.entropy(&mut planes);
        assert!(planes.iter().all(|&q| q == p));
    }

    #[test]
    fn zero_rate_wide_state_is_identity() {
        crate::for_each_plane_width!(zero_rate_wide_state_is_identity_generic);
    }

    fn wide_masks_are_deterministic_generic<P: BitPlane>() {
        let plan = BitFaultPlan::uniform(
            42,
            FaultRates { stuck_at_zero: 0.1, stuck_at_one: 0.05, flip: 0.2 },
        );
        let mut a = WideFaultState::<P>::new(&plan);
        let mut b = WideFaultState::<P>::new(&plan);
        for _ in 0..50 {
            let p = P::ones();
            assert_eq!(a.theta(p), b.theta(p));
            assert_eq!(a.output(p), b.output(p));
        }
        // reset() rewinds the streams to the plan seed.
        let first = WideFaultState::<P>::new(&plan).output(P::ones());
        a.reset(&plan);
        assert_eq!(a.output(P::ones()), first);
    }

    #[test]
    fn wide_masks_are_deterministic() {
        crate::for_each_plane_width!(wide_masks_are_deterministic_generic);
    }

    fn wide_mask_density_tracks_rate_generic<P: BitPlane>() {
        // Flip faults on an all-zeros plane expose the raw Bernoulli
        // masks; their empirical density must track the configured rate.
        let rate = 0.25;
        let plan = BitFaultPlan::uniform(9, FaultRates::flips(rate));
        let mut f = WideFaultState::<P>::new(&plan);
        let draws = 4000usize;
        let mut ones = 0u64;
        for _ in 0..draws {
            ones += f.output(P::zero()).count_ones() as u64;
        }
        let density = ones as f64 / (draws * P::LANES) as f64;
        assert!(
            (density - rate).abs() < 0.02,
            "lanes={} density={density} rate={rate}",
            P::LANES
        );
    }

    #[test]
    fn wide_mask_density_tracks_rate() {
        crate::for_each_plane_width!(wide_mask_density_tracks_rate_generic);
    }

    #[test]
    fn stuck_at_semantics() {
        // Rate 1.0 quantizes to 65535/65536 — force ~every bit and check
        // the direction of each kind.
        let s0 = BitFaultPlan::uniform(5, FaultRates::stuck0(1.0));
        let mut f = WideFaultState::<u64>::new(&s0);
        let mut zeroed = 0u32;
        for _ in 0..100 {
            zeroed += f.output(u64::ones()).not().count_ones();
        }
        assert!(zeroed > 99 * 64, "stuck-at-0 must clear almost every bit");
        let s1 = BitFaultPlan::uniform(5, FaultRates::stuck1(1.0));
        let mut f = WideFaultState::<u64>::new(&s1);
        let mut set = 0u32;
        for _ in 0..100 {
            set += f.output(u64::zero()).count_ones();
        }
        assert!(set > 99 * 64, "stuck-at-1 must set almost every bit");
    }

    #[test]
    fn scalar_word_corruption_confined_to_low_bits() {
        let plan = BitFaultPlan::uniform(13, FaultRates::stuck1(1.0));
        let mut f = plan.scalar_state();
        for _ in 0..50 {
            let s = f.state(0, 3);
            assert!(s < 8, "FSM-state corruption must stay within nbits");
        }
    }

    #[test]
    fn vote3_truth_table() {
        let t = u64::ones();
        let z = u64::zero();
        for a in [z, t] {
            for b in [z, t] {
                for c in [z, t] {
                    let want = if (a & 1) + (b & 1) + (c & 1) >= 2 { t } else { z };
                    assert_eq!(vote3(a, b, c), want);
                }
            }
        }
        // Mixed lanes: the vote is per-lane.
        assert_eq!(vote3(0b110u64, 0b011, 0b101), 0b111);
        assert_eq!(vote3(0b100u64, 0b010, 0b001), 0b000);
    }
}

//! Entropy sources for stochastic computing.
//!
//! The paper's hardware instantiates a *single* RNG whose sequence is
//! branched into differently-delayed versions feeding each θ-gate
//! (§III-A): `DelayedBranches` models exactly that. The RNG itself is a
//! Fibonacci LFSR (the area/power driver in Table VI); a xorshift64*
//! generator is provided for software-quality experiments, and a
//! van-der-Corput/Sobol sequence for low-discrepancy θ-gate sampling
//! (§II-B mentions Sobol explicitly).

/// A stream of fixed-point random values in `[0, 1)`, one per clock cycle.
///
/// `next_u16` returns the raw 16-bit comparator word (what the RTL
/// actually wires into a θ-gate); `next_f64` is its real-valued view.
pub trait StreamRng {
    /// Raw 16-bit output for the comparator datapath.
    fn next_u16(&mut self) -> u16;

    /// The same sample as a real in `[0,1)`.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        self.next_u16() as f64 / 65536.0
    }
}

/// 16-bit Fibonacci LFSR with taps (16,15,13,4) — maximal length 2^16-1.
///
/// This is the hardware RNG: 16 D-FFs and 3 XOR2 gates. The paper's RNG
/// block (~1600 µm²) is a bank of these plus output staging.
#[derive(Clone, Debug)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    /// Seed must be non-zero (the all-zeros state is the LFSR fixpoint);
    /// a zero seed is mapped to a fixed non-zero constant.
    pub fn new(seed: u16) -> Self {
        Self { state: if seed == 0 { 0xACE1 } else { seed } }
    }

    /// Advance one clock; returns the new state.
    #[inline(always)]
    pub fn step(&mut self) -> u16 {
        let s = self.state;
        // Fibonacci taps 16,15,13,4 (1-indexed from MSB side of x^16 poly).
        let bit = (s ^ (s >> 2) ^ (s >> 3) ^ (s >> 5)) & 1;
        self.state = (s >> 1) | (bit << 15);
        self.state
    }

    /// Current register state (what [`Self::step`] last returned, or the
    /// seed if never stepped).
    pub fn state(&self) -> u16 {
        self.state
    }

    /// Precompute the `steps`-clock transition as a GF(2) basis: entry `b`
    /// is the state reached from the unit state `1 << b`. The LFSR update
    /// is linear over GF(2), so any jumped state is the XOR of the basis
    /// images of its set bits — this turns the delayed-branch fast-forward
    /// (§III-A) from O(steps) into O(16) per lane, which the wide engine
    /// relies on when seeding 64 lanes at once.
    pub fn jump_basis(steps: usize) -> [u16; 16] {
        let mut basis = [0u16; 16];
        for (b, e) in basis.iter_mut().enumerate() {
            let mut l = Lfsr16 { state: 1 << b };
            for _ in 0..steps {
                l.step();
            }
            *e = l.state;
        }
        basis
    }

    /// Apply a precomputed [`Self::jump_basis`] to a state.
    #[inline]
    pub fn jump(state: u16, basis: &[u16; 16]) -> u16 {
        let mut out = 0u16;
        let mut bits = state;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            out ^= basis[b];
            bits &= bits - 1;
        }
        out
    }
}

impl StreamRng for Lfsr16 {
    #[inline(always)]
    fn next_u16(&mut self) -> u16 {
        self.step()
    }
}

/// xorshift64* — software-quality generator for long-bitstream experiments
/// where LFSR correlation artifacts would confound accuracy measurements.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { crate::util::prng::GOLDEN_GAMMA } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
}

impl StreamRng for XorShift64 {
    #[inline]
    fn next_u16(&mut self) -> u16 {
        (self.next_u64() >> 48) as u16
    }
}

/// Van der Corput base-2 sequence (= 1-D Sobol): the bit-reversed counter.
///
/// Low-discrepancy sampling makes a θ-gate's empirical mean converge as
/// O(1/L) instead of O(1/√L) — the paper's §II-B "complex probability
/// distributions such as the Sobol sequences".
#[derive(Clone, Debug)]
pub struct Sobol {
    counter: u32,
}

impl Sobol {
    pub fn new(start: u32) -> Self {
        Self { counter: start }
    }
}

impl StreamRng for Sobol {
    #[inline]
    fn next_u16(&mut self) -> u16 {
        let c = self.counter;
        self.counter = self.counter.wrapping_add(1);
        (c as u16).reverse_bits()
    }
}

/// One RNG branched into `k` differently-delayed sequences (paper §III-A:
/// "the random sequence from the RNG is branched into differently delayed
/// versions, emulating distinct pseudo-random sequences").
///
/// Hardware: a shift-register chain tapping the single LFSR at different
/// depths. Model: `k` LFSR replicas fast-forwarded by `delay*i` steps —
/// bit-identical to tapping one LFSR `delay*i` cycles apart.
#[derive(Clone, Debug)]
pub struct DelayedBranches {
    branches: Vec<Lfsr16>,
}

impl DelayedBranches {
    pub fn new(seed: u16, k: usize, delay: usize) -> Self {
        let mut branches = Vec::with_capacity(k);
        for i in 0..k {
            let mut l = Lfsr16::new(seed);
            for _ in 0..(delay * i) {
                l.step();
            }
            branches.push(l);
        }
        Self { branches }
    }

    pub fn len(&self) -> usize {
        self.branches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.branches.is_empty()
    }

    /// Advance every branch one clock and return branch `i`'s output.
    /// All branches tick together (they share the physical clock);
    /// use [`Self::tick`] to get all outputs of one cycle.
    pub fn tick(&mut self, out: &mut [u16]) {
        assert_eq!(out.len(), self.branches.len());
        for (o, b) in out.iter_mut().zip(self.branches.iter_mut()) {
            *o = b.step();
        }
    }
}

// ---------------------------------------------------------------------------
// Wide (bit-sliced) entropy: one independent lane per bit of a plane word.
//
// The wide SMURF engine ([`crate::smurf::sim_wide`]) simulates `P::LANES`
// bitstream trials per clock by keeping every 16-bit comparator word as 16
// *bit planes*: plane `b` is a [`BitPlane`] word whose lane `l` is bit `b`
// of lane `l`'s word. A θ-gate comparison against all lanes is then ~2
// plane ops per bit instead of one scalar compare per lane (see
// `crate::sc::sng::wide_lt_const`). The plane type defaults to `u64`
// (64 lanes); `[u64; 4]` / `[u64; 8]` widen to 256 / 512 lanes with the
// identical scheme (see `crate::sc::plane`).
// ---------------------------------------------------------------------------

use crate::sc::plane::BitPlane;

/// Transpose up to `P::LANES` per-lane 16-bit words into 16 bit planes
/// (plane `b`, lane `l` = bit `b` of `lanes[l]`). Missing lanes are zero.
pub fn planes_from_lanes<P: BitPlane>(lanes: &[u16]) -> [P; 16] {
    assert!(lanes.len() <= P::LANES, "at most P::LANES lanes per plane word");
    let mut planes = [P::zero(); 16];
    for (l, &v) in lanes.iter().enumerate() {
        let mut bits = v;
        while bits != 0 {
            let b = bits.trailing_zeros() as usize;
            planes[b].set_lane(l);
            bits &= bits - 1;
        }
    }
    planes
}

/// Read lane `l`'s 16-bit word back out of a plane set (test/debug path).
pub fn lane_from_planes<P: BitPlane>(planes: &[P; 16], l: usize) -> u16 {
    let mut v = 0u16;
    for (b, &p) in planes.iter().enumerate() {
        v |= (p.lane(l) as u16) << b;
    }
    v
}

/// `P::LANES` independent [`Lfsr16`] lanes stepped together in bit-sliced
/// form.
///
/// State is held as 16 planes in a ring buffer: the scalar update
/// `state' = (state >> 1) | (feedback << 15)` becomes "advance the head
/// and write one feedback plane" — ~6 plane ops per clock for all lanes
/// versus one scalar step per lane.
#[derive(Clone, Debug)]
pub struct WideLfsr16<P: BitPlane = u64> {
    buf: [P; 16],
    head: usize,
}

impl<P: BitPlane> WideLfsr16<P> {
    /// Build from per-lane register states (lane `l` behaves exactly like
    /// a scalar `Lfsr16` whose current state is `lanes[l]`). Unspecified
    /// lanes sit at the all-zeros fixpoint and emit constant zeros.
    pub fn from_lane_states(lanes: &[u16]) -> Self {
        Self { buf: planes_from_lanes(lanes), head: 0 }
    }

    /// Reset to new per-lane states in place (same semantics as
    /// [`Self::from_lane_states`]; lets run-state scratch reseed without
    /// reconstructing).
    pub fn reseed(&mut self, lanes: &[u16]) {
        self.buf = planes_from_lanes(lanes);
        self.head = 0;
    }

    /// Bit plane `b` of the current lane states.
    #[inline(always)]
    pub fn plane(&self, b: usize) -> P {
        self.buf[(self.head + b) & 15]
    }

    /// Advance all lanes one clock (each lane matches `Lfsr16::step`).
    #[inline(always)]
    pub fn step(&mut self) {
        // Taps 16,15,13,4: feedback = s0 ^ s2 ^ s3 ^ s5 per lane.
        let fb = self.plane(0).xor(self.plane(2)).xor(self.plane(3)).xor(self.plane(5));
        self.head = (self.head + 1) & 15;
        self.buf[(self.head + 15) & 15] = fb;
    }

    /// One clock for all lanes, then the θ-gate comparator mask
    /// (lane `l` set iff its fresh word `< threshold`) — the wide
    /// equivalent of `gate.sample(lfsr.next_u16())`.
    #[inline]
    pub fn next_lt_const(&mut self, threshold: u16) -> P {
        self.step();
        crate::sc::sng::wide_lt_const_with(|b| self.plane(b), threshold)
    }

    /// One clock for all lanes, then write this cycle's 16 rand planes.
    #[inline]
    pub fn next_planes_into(&mut self, out: &mut [P; 16]) {
        self.step();
        for (b, o) in out.iter_mut().enumerate() {
            *o = self.plane(b);
        }
    }
}

/// Up to `P::LANES` independent [`XorShift64`] lanes.
///
/// The 64-bit multiply in xorshift64* does not bit-slice (carries cross
/// lanes), so lanes are stepped scalarly — but the per-lane *states* live
/// in one flat `Vec<u64>` and a clock is a single straight-line loop of
/// shift/xor/multiply with no cross-lane data flow, which LLVM
/// autovectorizes (AVX2: 4 states per ymm; the `wrapping_mul` lowers to
/// the standard vpmuludq split). Lane `l` is bit-exact
/// `XorShift64::new(seeds[l])`: the state update here *is* the scalar
/// `next_u64` state update, and outputs are formed on demand as
/// `state * M » 48` exactly like `XorShift64::next_u16`. The heap buffer
/// keeps the `WideRng` variants of comparable size (the PR 2
/// `large_enum_variant` lint debt); [`Self::reseed`] rewrites it in
/// place, so steady-state resets stay allocation-free.
#[derive(Clone, Debug)]
pub struct WideXorShift64<P: BitPlane = u64> {
    /// Raw xorshift64* states, one per lane (never zero by seeding).
    states: Vec<u64>,
    _plane: std::marker::PhantomData<P>,
}

impl<P: BitPlane> WideXorShift64<P> {
    /// The xorshift64* output multiplier (`XorShift64::next_u64`).
    const MULT: u64 = 0x2545F4914F6CDD1D;

    /// One lane per seed (at most `P::LANES`), seeded exactly like
    /// `XorShift64::new` so lane `l` reproduces the scalar sequence.
    /// Unused lanes stay idle (their mask/plane bits are zero).
    pub fn from_seeds(seeds: &[u64]) -> Self {
        let mut rng = Self {
            states: Vec::with_capacity(seeds.len()),
            _plane: std::marker::PhantomData,
        };
        rng.reseed(seeds);
        rng
    }

    /// Re-seed in place (same semantics as [`Self::from_seeds`]),
    /// reusing the lane buffer's capacity.
    pub fn reseed(&mut self, seeds: &[u64]) {
        assert!(seeds.len() <= P::LANES, "at most P::LANES lanes per plane word");
        self.states.clear();
        self.states.extend(
            seeds.iter().map(|&s| if s == 0 { crate::util::prng::GOLDEN_GAMMA } else { s }),
        );
    }

    /// Advance every lane one clock (the scalar `next_u64` state update,
    /// vectorizable because the loop body is branch-free and lane-local).
    #[inline]
    fn step_all(&mut self) {
        for s in self.states.iter_mut() {
            let mut x = *s;
            x ^= x >> 12;
            x ^= x << 25;
            x ^= x >> 27;
            *s = x;
        }
    }

    /// This cycle's 16-bit comparator word of a freshly-stepped state
    /// (matches `XorShift64::next_u16`).
    #[inline(always)]
    fn out16(state: u64) -> u16 {
        (state.wrapping_mul(Self::MULT) >> 48) as u16
    }

    /// One clock for all lanes, then the θ-gate comparator mask.
    #[inline]
    pub fn next_lt_const(&mut self, threshold: u16) -> P {
        self.step_all();
        let mut mask = P::zero();
        for (l, &s) in self.states.iter().enumerate() {
            mask.set_lane_if(l, Self::out16(s) < threshold);
        }
        mask
    }

    /// One clock for all lanes, then the comparator mask with a *per-lane*
    /// threshold: lane `l` fires iff its fresh word `< thresholds[l]`.
    /// This is the SC-PwMM generation primitive — every lane is one
    /// product's θ-gate, so the whole bank of Fig. 1 SNGs emits one
    /// plane-word of stream bits per call, branch-free, with no transpose
    /// of the entropy words (per-lane compare + pack beats building 16
    /// rand planes just to run `wide_lt_planes` when the entropy is
    /// scalar-stepped anyway; the equivalence of the two routes is
    /// pinned in `sc::pwmm_wide::tests`).
    #[inline]
    pub fn next_lt_lanes(&mut self, thresholds: &[u16]) -> P {
        assert_eq!(thresholds.len(), self.states.len(), "one threshold per lane");
        self.step_all();
        let mut mask = P::zero();
        for (l, (&s, &t)) in self.states.iter().zip(thresholds).enumerate() {
            mask.set_lane_if(l, Self::out16(s) < t);
        }
        mask
    }

    /// One clock for all lanes, then write this cycle's 16 rand planes.
    pub fn next_planes_into(&mut self, out: &mut [P; 16]) {
        self.step_all();
        *out = [P::zero(); 16];
        for (l, &s) in self.states.iter().enumerate() {
            let mut bits = Self::out16(s);
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out[b].set_lane(l);
                bits &= bits - 1;
            }
        }
    }
}

/// `P::LANES` independent [`Sobol`] (van der Corput) lanes in bit-sliced
/// form.
///
/// The scalar generator emits the bit-reversed low 16 bits of a counter;
/// bit-sliced, the reversal is free (read the counter planes in reverse
/// order) and the shared increment is a ripple-carry over planes.
#[derive(Clone, Debug)]
pub struct WideSobol16<P: BitPlane = u64> {
    /// Counter planes: plane `b` holds bit `b` of each lane's counter.
    counter: [P; 16],
}

impl<P: BitPlane> WideSobol16<P> {
    /// Per-lane counter start values (low 16 bits of `Sobol::new(start)`;
    /// higher counter bits never reach the 16-bit output).
    pub fn from_lane_counters(lanes: &[u16]) -> Self {
        Self { counter: planes_from_lanes(lanes) }
    }

    /// Reset the counters in place (same semantics as
    /// [`Self::from_lane_counters`]).
    pub fn reseed(&mut self, lanes: &[u16]) {
        self.counter = planes_from_lanes(lanes);
    }

    #[inline(always)]
    fn increment_all(&mut self) {
        let mut carry = P::ones();
        for p in self.counter.iter_mut() {
            let (sum, c) = p.half_add(carry);
            *p = sum;
            carry = c;
            if carry.is_zero() {
                break;
            }
        }
    }

    /// Comparator mask for this cycle (output = bit-reversed counter,
    /// matching `Sobol::next_u16`), then advance every lane's counter.
    #[inline]
    pub fn next_lt_const(&mut self, threshold: u16) -> P {
        let mask =
            crate::sc::sng::wide_lt_const_with(|b| self.counter[15 - b], threshold);
        self.increment_all();
        mask
    }

    /// Write this cycle's 16 rand planes, then advance every counter.
    #[inline]
    pub fn next_planes_into(&mut self, out: &mut [P; 16]) {
        for (b, o) in out.iter_mut().enumerate() {
            *o = self.counter[15 - b];
        }
        self.increment_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfsr_full_period() {
        // Maximal-length 16-bit LFSR visits all 2^16-1 non-zero states.
        let mut l = Lfsr16::new(1);
        let first = l.step();
        let mut period = 1u32;
        while l.step() != first {
            period += 1;
            assert!(period <= 65536, "period exceeds 2^16");
        }
        assert_eq!(period, 65535);
    }

    #[test]
    fn lfsr_zero_seed_fixed() {
        let mut l = Lfsr16::new(0);
        assert_ne!(l.step(), 0);
    }

    #[test]
    fn lfsr_never_zero() {
        let mut l = Lfsr16::new(0xBEEF);
        for _ in 0..70_000 {
            assert_ne!(l.step(), 0);
        }
    }

    #[test]
    fn lfsr_mean_near_half() {
        let mut l = Lfsr16::new(0x1234);
        let n = 65535;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += l.next_f64();
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.001, "mean={mean}");
    }

    #[test]
    fn xorshift_mean_near_half() {
        let mut x = XorShift64::new(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| x.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn sobol_is_low_discrepancy() {
        // Empirical mean of first 256 Sobol points is exactly the threshold
        // up to 1/256 resolution for any threshold comparator.
        let mut s = Sobol::new(0);
        let p = 0.7;
        let n = 256;
        let ones = (0..n).filter(|_| s.next_f64() < p).count();
        let err = (ones as f64 / n as f64 - p).abs();
        assert!(err <= 1.0 / 256.0 + 1e-12, "err={err}");
    }

    #[test]
    fn sobol_first_points() {
        let mut s = Sobol::new(0);
        let seq: Vec<f64> = (0..4).map(|_| s.next_f64()).collect();
        assert_eq!(seq, vec![0.0, 0.5, 0.25, 0.75]);
    }

    #[test]
    fn delayed_branches_match_shifted_lfsr() {
        let k = 4;
        let delay = 7;
        let mut db = DelayedBranches::new(0x5555, k, delay);
        let mut out = vec![0u16; k];
        // Reference: independent LFSRs stepped (delay*i + t) times.
        let mut refs: Vec<Lfsr16> = (0..k)
            .map(|i| {
                let mut l = Lfsr16::new(0x5555);
                for _ in 0..(delay * i) {
                    l.step();
                }
                l
            })
            .collect();
        for _ in 0..100 {
            db.tick(&mut out);
            for (i, r) in refs.iter_mut().enumerate() {
                assert_eq!(out[i], r.step());
            }
        }
    }

    #[test]
    fn jump_basis_matches_stepping() {
        for steps in [0usize, 1, 17, 34, 51, 170] {
            let basis = Lfsr16::jump_basis(steps);
            for seed in [1u16, 0x5555, 0xBEEF, 0xFFFF] {
                let mut l = Lfsr16::new(seed);
                for _ in 0..steps {
                    l.step();
                }
                assert_eq!(
                    Lfsr16::jump(seed, &basis),
                    l.state(),
                    "seed={seed:#06x} steps={steps}"
                );
            }
        }
    }

    fn planes_roundtrip_generic<P: BitPlane>() {
        let lanes: Vec<u16> = (0..P::LANES)
            .map(|l| (l as u16).wrapping_mul(0x9E37) ^ 0x1234)
            .collect();
        let planes: [P; 16] = planes_from_lanes(&lanes);
        for (l, &v) in lanes.iter().enumerate() {
            assert_eq!(lane_from_planes(&planes, l), v);
        }
    }

    #[test]
    fn planes_roundtrip_lanes() {
        crate::for_each_plane_width!(planes_roundtrip_generic);
    }

    fn wide_lfsr_matches_scalar_generic<P: BitPlane>() {
        // A partial lane count exercises the idle-lane (all-zeros
        // fixpoint) tail alongside full planes.
        for lanes_n in [P::LANES, P::LANES - 3] {
            let lanes: Vec<u16> = (0..lanes_n).map(|l| (l as u16) * 977 + 1).collect();
            let mut wide = WideLfsr16::<P>::from_lane_states(&lanes);
            let mut scalars: Vec<Lfsr16> = lanes.iter().map(|&s| Lfsr16::new(s)).collect();
            for cycle in 0..200 {
                wide.step();
                for (l, s) in scalars.iter_mut().enumerate() {
                    let expect = s.step();
                    let got = {
                        let mut v = 0u16;
                        for b in 0..16 {
                            v |= (wide.plane(b).lane(l) as u16) << b;
                        }
                        v
                    };
                    assert_eq!(got, expect, "cycle {cycle} lane {l}");
                }
            }
        }
    }

    #[test]
    fn wide_lfsr_matches_scalar_lfsrs_all_widths() {
        crate::for_each_plane_width!(wide_lfsr_matches_scalar_generic);
    }

    fn wide_lfsr_lt_mask_generic<P: BitPlane>() {
        let lanes: Vec<u16> = (0..P::LANES).map(|l| (l as u16) * 31 + 7).collect();
        let mut wide = WideLfsr16::<P>::from_lane_states(&lanes);
        let mut scalars: Vec<Lfsr16> = lanes.iter().map(|&s| Lfsr16::new(s)).collect();
        for t in [0u16, 1, 0x8000, 0xABCD, 0xFFFF] {
            let mask = wide.next_lt_const(t);
            for (l, s) in scalars.iter_mut().enumerate() {
                let expect = s.next_u16() < t;
                assert_eq!(mask.lane(l), expect, "t={t:#06x} lane {l}");
            }
        }
    }

    #[test]
    fn wide_lfsr_lt_mask_matches_scalar_compares() {
        crate::for_each_plane_width!(wide_lfsr_lt_mask_generic);
    }

    fn wide_xorshift_matches_scalar_generic<P: BitPlane>() {
        let seeds: Vec<u64> = (0..P::LANES).map(|l| l as u64 * 0xDEAD_BEEF + 3).collect();
        let mut wide = WideXorShift64::<P>::from_seeds(&seeds);
        let mut scalars: Vec<XorShift64> = seeds.iter().map(|&s| XorShift64::new(s)).collect();
        let mut planes = [P::zero(); 16];
        for _ in 0..50 {
            wide.next_planes_into(&mut planes);
            for (l, s) in scalars.iter_mut().enumerate() {
                assert_eq!(lane_from_planes(&planes, l), s.next_u16());
            }
        }
        let t = 0x7777;
        let mask = wide.next_lt_const(t);
        for (l, s) in scalars.iter_mut().enumerate() {
            assert_eq!(mask.lane(l), s.next_u16() < t);
        }
        // Reseeding in place must reproduce a fresh construction.
        wide.reseed(&seeds[..5]);
        let mut fresh = WideXorShift64::<P>::from_seeds(&seeds[..5]);
        wide.next_planes_into(&mut planes);
        let mut fresh_planes = [P::zero(); 16];
        fresh.next_planes_into(&mut fresh_planes);
        assert_eq!(planes, fresh_planes, "in-place reseed must equal fresh seeding");
    }

    #[test]
    fn wide_xorshift_matches_scalar() {
        crate::for_each_plane_width!(wide_xorshift_matches_scalar_generic);
    }

    fn wide_xorshift_lt_lanes_generic<P: BitPlane>() {
        // Per-lane thresholds (the SC-PwMM bank shape), partial lane
        // count: every active lane must match its scalar generator's
        // compare, idle lanes must stay zero.
        let seeds: Vec<u64> = (0..P::LANES - 2).map(|l| l as u64 * 7919 + 1).collect();
        let mut wide = WideXorShift64::<P>::from_seeds(&seeds);
        let mut scalars: Vec<XorShift64> = seeds.iter().map(|&s| XorShift64::new(s)).collect();
        let thr: Vec<u16> =
            (0..seeds.len()).map(|l| (l as u16).wrapping_mul(2731).wrapping_add(9)).collect();
        for cycle in 0..40 {
            let mask = wide.next_lt_lanes(&thr);
            for (l, s) in scalars.iter_mut().enumerate() {
                assert_eq!(mask.lane(l), s.next_u16() < thr[l], "cycle {cycle} lane {l}");
            }
            for l in seeds.len()..P::LANES {
                assert!(!mask.lane(l), "idle lane {l} fired");
            }
        }
    }

    #[test]
    fn wide_xorshift_lt_lanes_matches_scalar() {
        crate::for_each_plane_width!(wide_xorshift_lt_lanes_generic);
    }

    fn wide_sobol_matches_scalar_generic<P: BitPlane>() {
        let starts: Vec<u16> = (0..P::LANES).map(|l| (l as u16).wrapping_mul(4099)).collect();
        let mut wide = WideSobol16::<P>::from_lane_counters(&starts);
        let mut scalars: Vec<Sobol> = starts.iter().map(|&s| Sobol::new(s as u32)).collect();
        let mut planes = [P::zero(); 16];
        for _ in 0..300 {
            wide.next_planes_into(&mut planes);
            for (l, s) in scalars.iter_mut().enumerate() {
                assert_eq!(lane_from_planes(&planes, l), s.next_u16());
            }
        }
    }

    #[test]
    fn wide_sobol_matches_scalar() {
        crate::for_each_plane_width!(wide_sobol_matches_scalar_generic);
    }

    #[test]
    fn wide_sobol_counter_wraps_like_scalar_low_bits() {
        // A lane sitting at 0xFFFF must wrap to 0x0000 (the scalar u32
        // counter's higher bits never reach the 16-bit output).
        let mut wide = WideSobol16::<u64>::from_lane_counters(&[0xFFFF, 3]);
        let mut a = Sobol::new(0xFFFF);
        let mut b = Sobol::new(3);
        let mut planes = [0u64; 16];
        for _ in 0..4 {
            wide.next_planes_into(&mut planes);
            assert_eq!(lane_from_planes(&planes, 0), a.next_u16());
            assert_eq!(lane_from_planes(&planes, 1), b.next_u16());
        }
    }

    #[test]
    fn branches_decorrelated() {
        // Delayed branches should have low pairwise bit correlation.
        let mut db = DelayedBranches::new(0x0BAD, 2, 31);
        let mut out = vec![0u16; 2];
        let n = 10_000;
        let mut same = 0;
        for _ in 0..n {
            db.tick(&mut out);
            if (out[0] & 1) == (out[1] & 1) {
                same += 1;
            }
        }
        let frac = same as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "agreement={frac}");
    }
}

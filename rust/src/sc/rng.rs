//! Entropy sources for stochastic computing.
//!
//! The paper's hardware instantiates a *single* RNG whose sequence is
//! branched into differently-delayed versions feeding each θ-gate
//! (§III-A): `DelayedBranches` models exactly that. The RNG itself is a
//! Fibonacci LFSR (the area/power driver in Table VI); a xorshift64*
//! generator is provided for software-quality experiments, and a
//! van-der-Corput/Sobol sequence for low-discrepancy θ-gate sampling
//! (§II-B mentions Sobol explicitly).

/// A stream of fixed-point random values in `[0, 1)`, one per clock cycle.
///
/// `next_u16` returns the raw 16-bit comparator word (what the RTL
/// actually wires into a θ-gate); `next_f64` is its real-valued view.
pub trait StreamRng {
    /// Raw 16-bit output for the comparator datapath.
    fn next_u16(&mut self) -> u16;

    /// The same sample as a real in `[0,1)`.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        self.next_u16() as f64 / 65536.0
    }
}

/// 16-bit Fibonacci LFSR with taps (16,15,13,4) — maximal length 2^16-1.
///
/// This is the hardware RNG: 16 D-FFs and 3 XOR2 gates. The paper's RNG
/// block (~1600 µm²) is a bank of these plus output staging.
#[derive(Clone, Debug)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    /// Seed must be non-zero (the all-zeros state is the LFSR fixpoint);
    /// a zero seed is mapped to a fixed non-zero constant.
    pub fn new(seed: u16) -> Self {
        Self { state: if seed == 0 { 0xACE1 } else { seed } }
    }

    /// Advance one clock; returns the new state.
    #[inline(always)]
    pub fn step(&mut self) -> u16 {
        let s = self.state;
        // Fibonacci taps 16,15,13,4 (1-indexed from MSB side of x^16 poly).
        let bit = ((s >> 0) ^ (s >> 2) ^ (s >> 3) ^ (s >> 5)) & 1;
        self.state = (s >> 1) | (bit << 15);
        self.state
    }
}

impl StreamRng for Lfsr16 {
    #[inline(always)]
    fn next_u16(&mut self) -> u16 {
        self.step()
    }
}

/// xorshift64* — software-quality generator for long-bitstream experiments
/// where LFSR correlation artifacts would confound accuracy measurements.
#[derive(Clone, Debug)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }
}

impl StreamRng for XorShift64 {
    #[inline]
    fn next_u16(&mut self) -> u16 {
        (self.next_u64() >> 48) as u16
    }
}

/// Van der Corput base-2 sequence (= 1-D Sobol): the bit-reversed counter.
///
/// Low-discrepancy sampling makes a θ-gate's empirical mean converge as
/// O(1/L) instead of O(1/√L) — the paper's §II-B "complex probability
/// distributions such as the Sobol sequences".
#[derive(Clone, Debug)]
pub struct Sobol {
    counter: u32,
}

impl Sobol {
    pub fn new(start: u32) -> Self {
        Self { counter: start }
    }
}

impl StreamRng for Sobol {
    #[inline]
    fn next_u16(&mut self) -> u16 {
        let c = self.counter;
        self.counter = self.counter.wrapping_add(1);
        (c as u16).reverse_bits()
    }
}

/// One RNG branched into `k` differently-delayed sequences (paper §III-A:
/// "the random sequence from the RNG is branched into differently delayed
/// versions, emulating distinct pseudo-random sequences").
///
/// Hardware: a shift-register chain tapping the single LFSR at different
/// depths. Model: `k` LFSR replicas fast-forwarded by `delay*i` steps —
/// bit-identical to tapping one LFSR `delay*i` cycles apart.
#[derive(Clone, Debug)]
pub struct DelayedBranches {
    branches: Vec<Lfsr16>,
}

impl DelayedBranches {
    pub fn new(seed: u16, k: usize, delay: usize) -> Self {
        let mut branches = Vec::with_capacity(k);
        for i in 0..k {
            let mut l = Lfsr16::new(seed);
            for _ in 0..(delay * i) {
                l.step();
            }
            branches.push(l);
        }
        Self { branches }
    }

    pub fn len(&self) -> usize {
        self.branches.len()
    }

    pub fn is_empty(&self) -> bool {
        self.branches.is_empty()
    }

    /// Advance every branch one clock and return branch `i`'s output.
    /// All branches tick together (they share the physical clock);
    /// use [`Self::tick`] to get all outputs of one cycle.
    pub fn tick(&mut self, out: &mut [u16]) {
        assert_eq!(out.len(), self.branches.len());
        for (o, b) in out.iter_mut().zip(self.branches.iter_mut()) {
            *o = b.step();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfsr_full_period() {
        // Maximal-length 16-bit LFSR visits all 2^16-1 non-zero states.
        let mut l = Lfsr16::new(1);
        let first = l.step();
        let mut period = 1u32;
        while l.step() != first {
            period += 1;
            assert!(period <= 65536, "period exceeds 2^16");
        }
        assert_eq!(period, 65535);
    }

    #[test]
    fn lfsr_zero_seed_fixed() {
        let mut l = Lfsr16::new(0);
        assert_ne!(l.step(), 0);
    }

    #[test]
    fn lfsr_never_zero() {
        let mut l = Lfsr16::new(0xBEEF);
        for _ in 0..70_000 {
            assert_ne!(l.step(), 0);
        }
    }

    #[test]
    fn lfsr_mean_near_half() {
        let mut l = Lfsr16::new(0x1234);
        let n = 65535;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += l.next_f64();
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.001, "mean={mean}");
    }

    #[test]
    fn xorshift_mean_near_half() {
        let mut x = XorShift64::new(99);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| x.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
    }

    #[test]
    fn sobol_is_low_discrepancy() {
        // Empirical mean of first 256 Sobol points is exactly the threshold
        // up to 1/256 resolution for any threshold comparator.
        let mut s = Sobol::new(0);
        let p = 0.7;
        let n = 256;
        let ones = (0..n).filter(|_| s.next_f64() < p).count();
        let err = (ones as f64 / n as f64 - p).abs();
        assert!(err <= 1.0 / 256.0 + 1e-12, "err={err}");
    }

    #[test]
    fn sobol_first_points() {
        let mut s = Sobol::new(0);
        let seq: Vec<f64> = (0..4).map(|_| s.next_f64()).collect();
        assert_eq!(seq, vec![0.0, 0.5, 0.25, 0.75]);
    }

    #[test]
    fn delayed_branches_match_shifted_lfsr() {
        let k = 4;
        let delay = 7;
        let mut db = DelayedBranches::new(0x5555, k, delay);
        let mut out = vec![0u16; k];
        // Reference: independent LFSRs stepped (delay*i + t) times.
        let mut refs: Vec<Lfsr16> = (0..k)
            .map(|i| {
                let mut l = Lfsr16::new(0x5555);
                for _ in 0..(delay * i) {
                    l.step();
                }
                l
            })
            .collect();
        for _ in 0..100 {
            db.tick(&mut out);
            for (i, r) in refs.iter_mut().enumerate() {
                assert_eq!(out[i], r.step());
            }
        }
    }

    #[test]
    fn branches_decorrelated() {
        // Delayed branches should have low pairwise bit correlation.
        let mut db = DelayedBranches::new(0x0BAD, 2, 31);
        let mut out = vec![0u16; 2];
        let n = 10_000;
        let mut same = 0;
        for _ in 0..n {
            db.tick(&mut out);
            if (out[0] & 1) == (out[1] & 1) {
                same += 1;
            }
        }
        let frac = same as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "agreement={frac}");
    }
}

//! Stochastic-computing substrate (paper §II).
//!
//! Everything the SMURF architecture in Fig. 6 is built from:
//!
//! - [`rng`] — hardware-faithful entropy sources: Fibonacci LFSRs (what the
//!   paper's RTL uses — the RNG dominates the 5294.72 µm² area budget),
//!   xorshift64* (software-quality), and Sobol/van-der-Corput low-
//!   discrepancy sequences (§II-B notes θ-gates may sample Sobol).
//! - [`bitstream`] — packed-`u64` stochastic numbers with the classic SC
//!   ops: AND-gate multiplication, MUX scaled addition, popcount decode.
//! - [`sng`] — the θ-gate (stochastic number generator, Fig. 1): a binary
//!   comparator against an entropy source.
//! - [`cpt`] — the CPT-gate (§II-B): a bank of θ-gates plus a MUX whose
//!   select input is, in SMURF, the universal-radix codeword.
//! - [`plane`] — the [`BitPlane`](plane::BitPlane) trait behind the
//!   bit-sliced wide engine: 64 (`u64`), 256 (`[u64; 4]`) or 512
//!   (`[u64; 8]`, feature `wide512`) SIMD lanes per plane word, plus
//!   [`MaxPlane`](plane::MaxPlane), the widest plane in the build.
//! - [`pwmm_wide`] — plane-form SC-PwMM: the bipolar XNOR multiply of the
//!   CNN column run `MaxPlane::LANES` products per pass (lane = product,
//!   plane = cycle), bit-identical to the scalar `Exact` path.
//! - [`fault`] — deterministic bit-level fault injection (stuck-at-0/1,
//!   transient flips at four datapath sites) and the lane-level TMR
//!   majority vote ([`vote3`](fault::vote3)) that mitigates them; inert
//!   by default and zero-cost when disarmed.

pub mod bitstream;
pub mod cpt;
pub mod fault;
pub mod plane;
pub mod pwmm_wide;
pub mod rng;
pub mod sng;

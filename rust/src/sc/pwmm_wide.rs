//! Plane-form SC-PwMM: bit-sliced bipolar stream multiplication,
//! `P::LANES` products per pass (paper §IV-B, ref [19]/[22]).
//!
//! # What this batches
//!
//! The CNN column runs every convolution/dense multiply as a bipolar SC
//! product: two independent `L`-bit streams (one per operand, each a
//! θ-gate over its own xorshift64* branch), XNOR'd and decoded by
//! popcount. The scalar `Exact` path (`nn::sc_ops::ScContext::mul_bipolar`)
//! materializes the two streams one product at a time; this module runs
//! the same computation transposed, like the SMURF wide engine
//! ([`crate::smurf::sim_wide`]) runs trials:
//!
//! - **lane = product.** Up to [`BitPlane::LANES`] products are packed
//!   into one pass; lane `l` carries product `l`'s streams.
//! - **plane = cycle.** Per clock cycle, the whole θ-gate bank emits one
//!   plane word per stream bank
//!   ([`crate::sc::rng::WideXorShift64::next_lt_lanes`]: every lane's
//!   16-bit comparator word against its own per-lane threshold — the
//!   Fig. 1 SNG array in one call). The xorshift64* lanes step scalarly
//!   (the 64-bit multiply does not bit-slice) but the states sit in one
//!   flat buffer whose update loop autovectorizes, and nothing is ever
//!   packed into per-product `Bitstream` buffers.
//! - **XNOR plane-against-plane.** One `xor`+`not` per cycle multiplies
//!   all lanes' bits at once (Fig. 2's bipolar XNOR across the bank).
//! - **vertical popcount.** Match masks accumulate into a ripple-carry
//!   vertical counter (one plane per count bit, as in the wide SMURF
//!   output counter); per-lane match totals are decoded once at the end.
//!
//! # Bit-exactness contract
//!
//! Product `i` of a pass is **bit-identical** to the scalar `Exact` path
//! run with stream seed `seeds[i]`: bank A is lane-for-lane
//! `XorShift64::new(seeds[i])`, bank B is
//! `XorShift64::new(seeds[i] ^ `[`B_STREAM_XOR`]`)`, thresholds use the
//! one shared quantization ([`crate::sc::sng::quantize_threshold`]), and
//! the decoded value is the same `2·matches/L − 1` double expression.
//! [`mul_bipolar_exact_batch`] additionally reproduces the `ScContext`
//! seed discipline (seed `i` = previous seed + [`STREAM_SEED_STRIDE`],
//! wrapping) so a gathered batch consumes entropy exactly as the
//! per-product loop would. Width-parametric property tests pin both
//! layers against the scalar `Bitstream` reference.
//!
//! # Tails and idle lanes
//!
//! A pass of `k < P::LANES` products follows the wide-engine convention:
//! idle lanes have no generator, both their stream bits read 0, their
//! XNOR is all-ones and the counter happily counts it — harmlessly,
//! because readout decodes only the first `k` lanes. No plane is ever
//! masked.
//!
//! All scratch lives in a caller-owned [`PwmmScratch`] (or the per-thread
//! one via [`with_thread_scratch`]), so steady-state batches are
//! allocation-free.

use super::plane::BitPlane;
use super::rng::WideXorShift64;
use super::sng::quantize_threshold;

/// XOR applied to a product's stream seed to derive the second operand's
/// generator — the scalar `Exact` path's constant, shared so the wide
/// banks reproduce it exactly.
pub const B_STREAM_XOR: u64 = 0xABCD_EF01_2345_6789;

/// Per-product stream-seed increment of the `Exact` discipline (the
/// golden-ratio constant `ScContext` has always used): product `i` of a
/// batch runs with seed `seed0 + (i+1)·STRIDE` (wrapping), exactly as
/// `i+1` sequential `mul_bipolar` calls would.
pub const STREAM_SEED_STRIDE: u64 = crate::util::prng::GOLDEN_GAMMA;

/// Count-bit planes in the vertical match counter: supports `L < 2^32`.
const COUNT_PLANES: usize = 33;

/// Caller-owned scratch for wide PwMM passes: the two θ-gate bank RNGs,
/// the vertical counter, and the staging buffers of the batch driver.
/// Every buffer is reused across passes (allocation-free steady state);
/// one scratch serves batches of any size. Construct with
/// [`PwmmScratch::new`] or borrow the per-thread one via
/// [`with_thread_scratch`].
pub struct PwmmScratch<P: BitPlane = u64> {
    rng_a: WideXorShift64<P>,
    rng_b: WideXorShift64<P>,
    counts: [P; COUNT_PLANES],
    /// Bank-B seed staging (`seeds[i] ^ B_STREAM_XOR`).
    seeds_b: Vec<u64>,
    /// Batch-driver staging: per-product thresholds and seeds of the
    /// current chunk.
    thr_a: Vec<u16>,
    thr_b: Vec<u16>,
    seeds: Vec<u64>,
    /// Batch-driver staging: per-product match counts of the chunk.
    counts_out: Vec<u64>,
}

impl<P: BitPlane> PwmmScratch<P> {
    pub fn new() -> Self {
        Self {
            rng_a: WideXorShift64::from_seeds(&[]),
            rng_b: WideXorShift64::from_seeds(&[]),
            counts: [P::zero(); COUNT_PLANES],
            seeds_b: Vec::new(),
            thr_a: Vec::new(),
            thr_b: Vec::new(),
            seeds: Vec::new(),
            counts_out: Vec::new(),
        }
    }
}

impl<P: BitPlane> Default for PwmmScratch<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// One plane pass: for each product `i` (at most `P::LANES`), the number
/// of positions where its two `len`-bit bipolar streams agree —
/// `out[i]` equals the scalar
/// `Bitstream::generate(·, len, XorShift64::new(seeds[i]))
///   .xnor_match_count(&Bitstream::generate(·, len,
///     XorShift64::new(seeds[i] ^ B_STREAM_XOR)))`
/// with thresholds `thr_a[i]` / `thr_b[i]`, bit-for-bit.
pub fn xnor_match_counts<P: BitPlane>(
    thr_a: &[u16],
    thr_b: &[u16],
    seeds: &[u64],
    len: usize,
    st: &mut PwmmScratch<P>,
    out: &mut [u64],
) {
    let k = seeds.len();
    assert!(k > 0 && k <= P::LANES, "1..=P::LANES products per pass");
    assert_eq!(thr_a.len(), k, "one A threshold per product");
    assert_eq!(thr_b.len(), k, "one B threshold per product");
    assert!(out.len() >= k);
    assert!(len > 0, "need at least one stream bit");
    assert!((len as u64) < (1u64 << (COUNT_PLANES - 1)), "stream too long for counter");
    let PwmmScratch { rng_a, rng_b, counts, seeds_b, .. } = st;
    rng_a.reseed(seeds);
    seeds_b.clear();
    seeds_b.extend(seeds.iter().map(|&s| s ^ B_STREAM_XOR));
    rng_b.reseed(seeds_b);
    *counts = [P::zero(); COUNT_PLANES];
    // xtask: hot-loop — per-clock multiply kernel (runs L times per
    // batch pass); all buffers are borrowed from the scratch above.
    for _ in 0..len {
        // One cycle of both θ-gate banks, then the bipolar multiply:
        // lane l's bit of `m` is stream-A(l) XNOR stream-B(l).
        let a = rng_a.next_lt_lanes(thr_a);
        let b = rng_b.next_lt_lanes(thr_b);
        let m = a.xor(b).not();
        // Vertical counter: one ripple-carry step per set count bit.
        let mut carry = m;
        let mut bit = 0;
        while !carry.is_zero() {
            let (sum, c) = counts[bit].half_add(carry);
            counts[bit] = sum;
            carry = c;
            bit += 1;
        }
    }
    for (l, o) in out.iter_mut().enumerate().take(k) {
        let mut count = 0u64;
        for (b, &p) in counts.iter().enumerate() {
            count |= (p.lane(l) as u64) << b;
        }
        *o = count;
    }
    // xtask: hot-loop-end
}

/// Batched bipolar SC multiply with the `Exact`-mode seed discipline:
/// `out[i]` is bit-identical to the `i`-th of `xs.len()` sequential
/// `ScContext::mul_bipolar(xs[i], ws[i])` calls in `Exact` mode starting
/// from stream seed `seed0`; returns the advanced stream seed (`seed0 +
/// xs.len()·STRIDE`, wrapping) for the caller to store back. Chunks by
/// `P::LANES`, so any batch size works; `len == 0` decodes every product
/// to `-1.0` exactly as empty scalar streams do (and still consumes one
/// seed per product).
pub fn mul_bipolar_exact_batch<P: BitPlane>(
    xs: &[f32],
    ws: &[f32],
    len: usize,
    seed0: u64,
    st: &mut PwmmScratch<P>,
    out: &mut [f32],
) -> u64 {
    assert_eq!(xs.len(), ws.len(), "operand count mismatch");
    assert!(out.len() >= xs.len());
    let mut seed = seed0;
    if len == 0 {
        for o in out.iter_mut().take(xs.len()) {
            seed = seed.wrapping_add(STREAM_SEED_STRIDE);
            *o = -1.0;
        }
        return seed;
    }
    // Move the staging buffers out so the scratch can be re-borrowed by
    // the pass kernel (capacity is preserved; no steady-state alloc).
    let mut thr_a = std::mem::take(&mut st.thr_a);
    let mut thr_b = std::mem::take(&mut st.thr_b);
    let mut seeds = std::mem::take(&mut st.seeds);
    let mut counts = std::mem::take(&mut st.counts_out);
    counts.resize(P::LANES, 0);
    // xtask: hot-loop — batch chunking path: clear/push reuse the staged
    // capacity; no fresh buffers per chunk.
    let mut start = 0;
    while start < xs.len() {
        let k = (xs.len() - start).min(P::LANES);
        thr_a.clear();
        thr_b.clear();
        seeds.clear();
        for (&x, &w) in xs[start..start + k].iter().zip(&ws[start..start + k]) {
            seed = seed.wrapping_add(STREAM_SEED_STRIDE);
            seeds.push(seed);
            // The scalar encode, operand for operand: clamp in f32, then
            // the f64 bipolar→unipolar map, then the shared quantizer.
            let a = x.clamp(-1.0, 1.0) as f64;
            let b = w.clamp(-1.0, 1.0) as f64;
            thr_a.push(quantize_threshold((a + 1.0) / 2.0));
            thr_b.push(quantize_threshold((b + 1.0) / 2.0));
        }
        xnor_match_counts(&thr_a, &thr_b, &seeds, len, st, &mut counts);
        for (o, &c) in out[start..start + k].iter_mut().zip(counts.iter()) {
            // The scalar decode expression: f64 mean, bipolar map, f32 cast.
            *o = (2.0 * (c as f64 / len as f64) - 1.0) as f32;
        }
        start += k;
    }
    // xtask: hot-loop-end
    st.thr_a = thr_a;
    st.thr_b = thr_b;
    st.seeds = seeds;
    st.counts_out = counts;
    seed
}

/// Plane widths that own a per-thread [`PwmmScratch`]. One thread-local
/// static exists per width (the scratch type is width-parametric), created
/// on first use — the same sharing scheme as
/// [`crate::smurf::sim_wide::ThreadScratch`].
pub trait PwmmThreadScratch: BitPlane {
    /// Run `f` with this thread's shared PwMM scratch for this plane
    /// width. Do not call reentrantly from inside `f` — the scratch is a
    /// `RefCell` and a nested borrow panics.
    fn with_pwmm_scratch<R>(f: impl FnOnce(&mut PwmmScratch<Self>) -> R) -> R;
}

macro_rules! impl_pwmm_thread_scratch {
    ($ty:ty) => {
        impl PwmmThreadScratch for $ty {
            fn with_pwmm_scratch<R>(f: impl FnOnce(&mut PwmmScratch<Self>) -> R) -> R {
                thread_local! {
                    static SCRATCH: std::cell::RefCell<PwmmScratch<$ty>> =
                        std::cell::RefCell::new(PwmmScratch::new());
                }
                SCRATCH.with(|s| f(&mut s.borrow_mut()))
            }
        }
    };
}

impl_pwmm_thread_scratch!(u64);
impl_pwmm_thread_scratch!([u64; 4]);
#[cfg(feature = "wide512")]
impl_pwmm_thread_scratch!([u64; 8]);

/// Run `f` with this thread's shared [`PwmmScratch`] for the inferred
/// plane width (allocation-free after the first call on a thread). Do not
/// call reentrantly from inside `f`.
pub fn with_thread_scratch<P: PwmmThreadScratch, R>(
    f: impl FnOnce(&mut PwmmScratch<P>) -> R,
) -> R {
    P::with_pwmm_scratch(f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::bitstream::Bitstream;
    use crate::sc::rng::{planes_from_lanes, XorShift64};
    use crate::sc::sng::wide_lt_planes;

    /// The scalar `Exact` path, product for product: generate both
    /// streams with the documented seed derivation and decode the XNOR
    /// popcount. This is a literal transcription of
    /// `ScContext::mul_bipolar`'s `Exact` arm.
    fn scalar_product(x: f32, w: f32, len: usize, seed: u64) -> (u64, f32) {
        let a = x.clamp(-1.0, 1.0) as f64;
        let b = w.clamp(-1.0, 1.0) as f64;
        let mut r1 = XorShift64::new(seed);
        let mut r2 = XorShift64::new(seed ^ B_STREAM_XOR);
        let sa = Bitstream::generate((a + 1.0) / 2.0, len, &mut r1);
        let sb = Bitstream::generate((b + 1.0) / 2.0, len, &mut r2);
        let matches = sa.xnor_match_count(&sb);
        let mean = if len == 0 { 0.0 } else { matches as f64 / len as f64 };
        (matches, (2.0 * mean - 1.0) as f32)
    }

    /// Mixed-sign operand ramp hitting ±1, the clamp region beyond it,
    /// zero, and irrational-ish interior points.
    fn operands(n: usize) -> (Vec<f32>, Vec<f32>) {
        let xs: Vec<f32> = (0..n)
            .map(|i| match i % 7 {
                0 => 0.0,
                1 => 1.0,
                2 => -1.0,
                3 => 1.7,
                4 => -2.3,
                _ => ((i * 37) % 101) as f32 / 50.0 - 1.0,
            })
            .collect();
        let ws: Vec<f32> = (0..n)
            .map(|i| match (i + 3) % 6 {
                0 => -1.0,
                1 => 0.5,
                2 => -3.0,
                _ => 1.0 - ((i * 53) % 97) as f32 / 48.0,
            })
            .collect();
        (xs, ws)
    }

    /// The tentpole contract at width `P`: every product of a batch is
    /// bit-identical to the scalar `Exact` reference — mixed signs,
    /// clamped operands, non-multiple-of-lane tails, L ∈ {32, 128, 256}.
    fn batch_matches_scalar_generic<P: BitPlane>() {
        let mut st = PwmmScratch::<P>::new();
        for len in [32usize, 128, 256] {
            for n in [1usize, 3, P::LANES - 1, P::LANES, P::LANES + 7] {
                let (xs, ws) = operands(n);
                let seed0 = 0xD1CE ^ (len as u64) ^ ((n as u64) << 8);
                let mut out = vec![0.0f32; n];
                let end =
                    mul_bipolar_exact_batch(&xs, &ws, len, seed0, &mut st, &mut out);
                assert_eq!(
                    end,
                    seed0.wrapping_add((n as u64).wrapping_mul(STREAM_SEED_STRIDE)),
                    "seed advance"
                );
                let mut seed = seed0;
                for i in 0..n {
                    seed = seed.wrapping_add(STREAM_SEED_STRIDE);
                    let (_, want) = scalar_product(xs[i], ws[i], len, seed);
                    assert_eq!(
                        out[i].to_bits(),
                        want.to_bits(),
                        "L={len} n={n} product {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_batch_matches_scalar_all_widths() {
        crate::for_each_plane_width!(batch_matches_scalar_generic);
    }

    /// The raw pass kernel agrees with the scalar match counts (counts,
    /// not just decoded values) including the single-product shape.
    fn kernel_counts_match_scalar_generic<P: BitPlane>() {
        let mut st = PwmmScratch::<P>::new();
        for k in [1usize, 2, P::LANES] {
            let (xs, ws) = operands(k);
            let seeds: Vec<u64> = (0..k).map(|i| 0x5EED + i as u64 * 977).collect();
            let thr_a: Vec<u16> = xs
                .iter()
                .map(|&x| quantize_threshold((x.clamp(-1.0, 1.0) as f64 + 1.0) / 2.0))
                .collect();
            let thr_b: Vec<u16> = ws
                .iter()
                .map(|&w| quantize_threshold((w.clamp(-1.0, 1.0) as f64 + 1.0) / 2.0))
                .collect();
            let mut out = vec![0u64; k];
            xnor_match_counts(&thr_a, &thr_b, &seeds, 96, &mut st, &mut out);
            for i in 0..k {
                let (want, _) = scalar_product(xs[i], ws[i], 96, seeds[i]);
                assert_eq!(out[i], want, "k={k} product {i}");
            }
        }
    }

    #[test]
    fn kernel_counts_match_scalar_all_widths() {
        crate::for_each_plane_width!(kernel_counts_match_scalar_generic);
    }

    /// The direct compare-and-pack generation route equals the
    /// transpose-then-`wide_lt_planes` route through the existing SNG
    /// comparator machinery — the two are the same θ-gate bank, one
    /// optimized for scalar-stepped entropy, one for plane-native
    /// entropy.
    fn generation_matches_plane_comparator_generic<P: BitPlane>() {
        let seeds: Vec<u64> = (0..P::LANES - 1).map(|i| i as u64 * 0xABC + 7).collect();
        let thr: Vec<u16> = (0..seeds.len())
            .map(|i| (i as u16).wrapping_mul(4099).wrapping_add(1))
            .collect();
        let mut direct = WideXorShift64::<P>::from_seeds(&seeds);
        let mut via_planes = WideXorShift64::<P>::from_seeds(&seeds);
        let thr_planes: [P; 16] = planes_from_lanes(&thr);
        let mut rand = [P::zero(); 16];
        for cycle in 0..64 {
            let a = direct.next_lt_lanes(&thr);
            via_planes.next_planes_into(&mut rand);
            let b = wide_lt_planes(&rand, &thr_planes);
            // Active lanes must agree; idle lanes are zero on both routes.
            assert_eq!(a, b, "cycle {cycle}");
        }
    }

    #[test]
    fn generation_matches_plane_comparator_route() {
        crate::for_each_plane_width!(generation_matches_plane_comparator_generic);
    }

    #[test]
    fn zero_length_streams_decode_to_minus_one_and_consume_seeds() {
        let mut st = PwmmScratch::<u64>::new();
        let mut out = [0.0f32; 3];
        let end = mul_bipolar_exact_batch(&[0.5, -0.5, 1.0], &[1.0, 1.0, 0.0], 0, 9, &mut st, &mut out);
        assert_eq!(out, [-1.0f32; 3]);
        assert_eq!(end, 9u64.wrapping_add(3u64.wrapping_mul(STREAM_SEED_STRIDE)));
        // And matches the scalar convention (empty stream mean is 0).
        let (_, v) = scalar_product(0.5, 1.0, 0, 9u64.wrapping_add(STREAM_SEED_STRIDE));
        assert_eq!(v, -1.0);
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut st = PwmmScratch::<u64>::new();
        let end = mul_bipolar_exact_batch(&[], &[], 128, 77, &mut st, &mut []);
        assert_eq!(end, 77);
    }

    #[test]
    fn thread_scratch_matches_owned() {
        let (xs, ws) = operands(70);
        let mut owned = PwmmScratch::<u64>::new();
        let mut a = vec![0.0f32; 70];
        let mut b = vec![0.0f32; 70];
        let ea = mul_bipolar_exact_batch(&xs, &ws, 64, 5, &mut owned, &mut a);
        let eb = with_thread_scratch::<u64, _>(|st| {
            mul_bipolar_exact_batch(&xs, &ws, 64, 5, st, &mut b)
        });
        assert_eq!(a, b);
        assert_eq!(ea, eb);
    }

    #[test]
    fn boundary_thresholds_saturate() {
        // p=0 (threshold 0) never fires; p=1 (threshold 65535) nearly
        // always fires: (+1)·(+1) products of saturated operands decode
        // close to +1, (−1)·(+1) close to −1, at any lane position.
        let mut st = PwmmScratch::<u64>::new();
        let xs = [1.0f32, -1.0, 1.0];
        let ws = [1.0f32, 1.0, -1.0];
        let mut out = [0.0f32; 3];
        mul_bipolar_exact_batch(&xs, &ws, 256, 3, &mut st, &mut out);
        // threshold 65535 misses only rand16 == 65535 (~1/65536 per bit).
        assert!(out[0] > 0.95, "(+1)(+1) decoded {}", out[0]);
        assert!(out[1] < -0.95, "(-1)(+1) decoded {}", out[1]);
        assert!(out[2] < -0.95, "(+1)(-1) decoded {}", out[2]);
    }

    #[test]
    #[should_panic(expected = "1..=P::LANES")]
    fn kernel_rejects_oversized_pass() {
        let mut st = PwmmScratch::<u64>::new();
        let thr = vec![1u16; 65];
        let seeds = vec![1u64; 65];
        let mut out = vec![0u64; 65];
        xnor_match_counts(&thr, &thr, &seeds, 16, &mut st, &mut out);
    }
}

//! CPT-gates (paper §II-B): a bank of θ-gates plus a MUX.
//!
//! "A CPT-gate is a collection of θ-gates, together with a MUX to select
//! one of the θ-gates as its output." In SMURF the MUX select input is the
//! universal-radix codeword `s`, and the bank holds the synthesized
//! coefficients `w_0 … w_{N^M - 1}`.

use super::plane::BitPlane;
use super::rng::StreamRng;
use super::sng::ThetaGate;

/// A conditional-probability-table gate: `bank[sel]` sampled each cycle.
#[derive(Clone, Debug)]
pub struct CptGate {
    bank: Vec<ThetaGate>,
}

impl CptGate {
    /// Build the bank from coefficient probabilities (the `w_t`'s of
    /// Tables I/II).
    pub fn new(ws: &[f64]) -> Self {
        Self { bank: ws.iter().map(|&w| ThetaGate::new(w)).collect() }
    }

    /// Number of θ-gates in the bank (`N^M` for SMURF).
    pub fn len(&self) -> usize {
        self.bank.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bank.is_empty()
    }

    /// Effective (quantized) coefficient of entry `t`.
    pub fn effective_w(&self, t: usize) -> f64 {
        self.bank[t].effective_p()
    }

    /// One clock cycle: the select codeword picks the θ-gate; that gate
    /// compares against the entropy word. (Hardware note: *all* θ-gates
    /// sample every cycle from their delayed RNG branches and the MUX picks
    /// one output — electrically equivalent to sampling only the selected
    /// gate, which is what we compute.)
    #[inline]
    pub fn sample(&self, sel: usize, rand16: u16) -> bool {
        self.bank[sel].sample(rand16)
    }

    /// Raw 16-bit threshold register of entry `t`.
    pub fn raw_threshold(&self, t: usize) -> u16 {
        self.bank[t].raw()
    }

    /// Wide (`P::LANES`-lane) MUX select in masked plane logic: `eq[t]`
    /// is the lane mask whose codeword currently selects bank entry `t`
    /// (the masks must partition the active lanes). Writes each lane's
    /// selected 16-bit threshold as bit planes into `out`, ready for
    /// [`crate::sc::sng::wide_lt_planes`] against the entropy planes.
    ///
    /// This is the bit-sliced equivalent of `bank[sel]`: instead of one
    /// indexed load per lane, every coefficient ORs its threshold bits
    /// into the planes under its select mask — exactly the AND-OR MUX
    /// tree the paper's Fig. 6 CPT block synthesizes to.
    pub fn threshold_planes<P: BitPlane>(&self, eq: &[P], out: &mut [P; 16]) {
        assert_eq!(eq.len(), self.bank.len(), "one select mask per bank entry");
        *out = [P::zero(); 16];
        for (gate, &mask) in self.bank.iter().zip(eq) {
            if mask.is_zero() {
                continue;
            }
            let mut bits = gate.raw();
            while bits != 0 {
                let b = bits.trailing_zeros() as usize;
                out[b] = out[b].or(mask);
                bits &= bits - 1;
            }
        }
    }

    /// Run the gate for `len` cycles with a constant select, returning the
    /// output mean — the conditional distribution given that state.
    pub fn run_mean_const_sel(&self, sel: usize, len: usize, rng: &mut impl StreamRng) -> f64 {
        let mut ones = 0u64;
        for _ in 0..len {
            ones += self.sample(sel, rng.next_u16()) as u64;
        }
        ones as f64 / len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::{Sobol, XorShift64};

    #[test]
    fn bank_size() {
        let g = CptGate::new(&[0.1, 0.5, 0.9, 1.0]);
        assert_eq!(g.len(), 4);
        assert!(!g.is_empty());
    }

    #[test]
    fn constant_select_recovers_coefficient() {
        let g = CptGate::new(&[0.2, 0.8]);
        let mut rng = Sobol::new(0);
        let m = g.run_mean_const_sel(1, 512, &mut rng);
        assert!((m - 0.8).abs() < 1.0 / 512.0 + 1e-12, "m={m}");
    }

    #[test]
    fn mixed_select_mixes_distributions() {
        // Alternating selects between w0=0 and w1=1 gives mean 1/2 exactly.
        let g = CptGate::new(&[0.0, 1.0]);
        let mut rng = XorShift64::new(3);
        let mut ones = 0;
        let n = 1000;
        for i in 0..n {
            ones += g.sample(i % 2, rng.next_u16()) as usize;
        }
        assert_eq!(ones, 500);
    }

    fn threshold_planes_select_generic<P: BitPlane>() {
        use crate::sc::rng::lane_from_planes;
        // 4-entry bank; all lanes cycle through the 4 selects.
        let g = CptGate::new(&[0.1, 0.35, 0.6, 0.95]);
        let mut eq = [P::zero(); 4];
        for l in 0..P::LANES {
            eq[l % 4].set_lane(l);
        }
        let mut planes = [P::zero(); 16];
        g.threshold_planes(&eq, &mut planes);
        for l in 0..P::LANES {
            assert_eq!(
                lane_from_planes(&planes, l),
                g.raw_threshold(l % 4),
                "lane {l}"
            );
        }
    }

    #[test]
    fn threshold_planes_select_per_lane() {
        crate::for_each_plane_width!(threshold_planes_select_generic);
    }

    fn threshold_planes_idle_generic<P: BitPlane>() {
        let g = CptGate::new(&[0.5, 0.5]);
        let mut eq = [P::zero(); 2]; // only lanes 0 and 1 active
        eq[0].set_lane(0);
        eq[1].set_lane(1);
        let mut planes = [P::zero(); 16];
        g.threshold_planes(&eq, &mut planes);
        for p in planes {
            for l in 2..P::LANES {
                assert!(!p.lane(l), "idle lane {l} must stay zero");
            }
        }
    }

    #[test]
    fn threshold_planes_idle_lanes_zero() {
        crate::for_each_plane_width!(threshold_planes_idle_generic);
    }

    #[test]
    fn effective_w_quantized() {
        let g = CptGate::new(&[0.6083]);
        assert!((g.effective_w(0) - 0.6083).abs() < 1.0 / 65536.0);
    }
}

//! Stochastic-computing operators for the CNN (paper §IV-B).
//!
//! **SC-PwMM** (point-wise matrix multiplication, ref [19]/[22]): each
//! scalar product runs in the bipolar SC domain on `L`-bit streams
//! (XNOR multiply), with binary-domain accumulation of the decoded
//! products (APC-style). Two fidelity modes:
//!
//! - `Exact`: run the actual gates, bit-faithfully. Batched entry points
//!   ([`ScContext::mul_bipolar_batch`], [`ScContext::dot_bipolar`])
//!   route through the plane-form engine ([`crate::sc::pwmm_wide`]):
//!   up to [`MAX_LANES`](crate::smurf::sim_wide::MAX_LANES) products per
//!   bit-plane pass (lane = product, plane = cycle), product-for-product
//!   bit-identical to the scalar fallback ([`ScContext::mul_bipolar`],
//!   which regenerates an allocation-free scratch stream pair per
//!   product). The CNN conv/dense layers gather their per-pixel products
//!   into these batches, so `Exact`-fidelity LeNet inference is a
//!   per-layer plane pipeline end to end.
//! - `Binomial`: sample the decoded product from its *exact* output
//!   distribution (`ones ~ Binomial(L, p_match)`), which is statistically
//!   identical for independent streams and ~100× faster, making full
//!   test-set evaluation practical. The equivalence is property-tested.
//!
//! **Stream-seed discipline (`Exact` mode).** Every product consumes one
//! stream seed: `stream_seed += `[`STREAM_SEED_STRIDE`] (wrapping), then
//! operand A streams from `XorShift64::new(stream_seed)` and operand B
//! from `XorShift64::new(stream_seed ^ `[`B_STREAM_XOR`]`)`. Results
//! therefore depend on *call order* — the `i`-th product of a context's
//! life always draws the same entropy, whether it arrives through the
//! scalar fallback, one big batch, or arbitrarily-chunked batches (the
//! determinism tests pin this), but inserting or reordering products
//! shifts every later stream. The batch entry points advance the seed
//! exactly as the per-product loop would, so gathering can never
//! silently reorder entropy.
//!
//! **SMURF activation**: the synthesized SMURF for tanh at `L = 64`
//! (paper §IV-A fixes 64-bit streams). Three fidelities:
//!
//! - analytic ([`SmurfActivation::eval_analytic`]) — the infinite-stream
//!   mean, used by training;
//! - stochastic ([`SmurfActivation::eval_stochastic`]) — analytic mean
//!   plus exact binomial bitstream-sampling noise;
//! - bit-level ([`SmurfActivation::eval_bitlevel`] /
//!   [`SmurfActivation::eval_bitlevel_batch`]) — the cycle-accurate FSM
//!   simulator. The batched entry point packs up to
//!   [`MAX_LANES`](crate::smurf::sim_wide::MAX_LANES) activations (the
//!   widest bit plane in the build: 256, or 512 with `wide512`) into one
//!   bit-plane pass of the wide engine
//!   ([`crate::smurf::sim_wide::WideBitLevelSmurf::eval_points`]), so a
//!   whole CNN layer is activated per-layer rather than per-neuron while
//!   staying element-for-element bit-identical to the scalar path.

use crate::sc::bitstream::Bitstream;
use crate::sc::plane::MaxPlane;
use crate::sc::pwmm_wide::{self, B_STREAM_XOR, STREAM_SEED_STRIDE};
use crate::sc::rng::XorShift64;
use crate::smurf::approximator::SmurfApproximator;
use crate::smurf::config::SmurfConfig;
use crate::synth::functions;
use crate::util::prng::Pcg;

/// SC multiplication fidelity.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScMode {
    Exact,
    Binomial,
}

/// Stateful SC execution context (stream length + entropy).
pub struct ScContext {
    pub len: usize,
    pub mode: ScMode,
    rng: Pcg,
    stream_seed: u64,
    /// `Exact`-mode scalar-fallback scratch: the two operand streams are
    /// regenerated into this pair per product, so single multiplies are
    /// allocation-free in steady state.
    scratch_a: Bitstream,
    scratch_b: Bitstream,
}

impl ScContext {
    pub fn new(len: usize, mode: ScMode, seed: u64) -> Self {
        Self {
            len,
            mode,
            rng: Pcg::new(seed),
            stream_seed: seed ^ 0xD1CE,
            scratch_a: Bitstream::zeros(0),
            scratch_b: Bitstream::zeros(0),
        }
    }

    /// Current `Exact`-mode stream seed (see the module docs on the seed
    /// discipline): advances by [`STREAM_SEED_STRIDE`] per product.
    /// Exposed so benches and tests can pin the discipline against the
    /// wide engine without replicating private state.
    pub fn stream_seed(&self) -> u64 {
        self.stream_seed
    }

    /// Bipolar SC multiply of `a, b ∈ [-1, 1]`: returns the decoded
    /// product estimate from an `len`-bit XNOR of two independent
    /// bipolar streams. This is the scalar path — the `Exact` arm
    /// regenerates the context's scratch stream pair (no allocation) and
    /// decodes the XNOR popcount directly; batches of products should
    /// prefer [`Self::mul_bipolar_batch`] / [`Self::dot_bipolar`], which
    /// run the identical computation through the plane-form engine.
    pub fn mul_bipolar(&mut self, a: f32, b: f32) -> f32 {
        let a = a.clamp(-1.0, 1.0) as f64;
        let b = b.clamp(-1.0, 1.0) as f64;
        // P(bit match) for independent bipolar streams = (1 + ab)/2.
        match self.mode {
            ScMode::Binomial => {
                let p_match = (1.0 + a * b) / 2.0;
                let ones = self.binomial(self.len, p_match);
                (2.0 * ones as f64 / self.len as f64 - 1.0) as f32
            }
            ScMode::Exact => {
                self.stream_seed = self.stream_seed.wrapping_add(STREAM_SEED_STRIDE);
                let mut r1 = XorShift64::new(self.stream_seed);
                let mut r2 = XorShift64::new(self.stream_seed ^ B_STREAM_XOR);
                let len = self.len;
                self.scratch_a.generate_into((a + 1.0) / 2.0, len, &mut r1);
                self.scratch_b.generate_into((b + 1.0) / 2.0, len, &mut r2);
                let matches = self.scratch_a.xnor_match_count(&self.scratch_b);
                let mean = if len == 0 { 0.0 } else { matches as f64 / len as f64 };
                (2.0 * mean - 1.0) as f32
            }
        }
    }

    /// Batched bipolar SC multiply: `out[i]` is bit-identical to the
    /// `i`-th of `xs.len()` sequential [`Self::mul_bipolar`] calls
    /// (`Binomial` mode literally loops them; `Exact` mode packs up to
    /// [`MAX_LANES`](crate::smurf::sim_wide::MAX_LANES) products per
    /// bit-plane pass of [`crate::sc::pwmm_wide`] on the per-thread
    /// scratch, advancing the stream seed exactly as the loop would).
    pub fn mul_bipolar_batch(&mut self, xs: &[f32], ws: &[f32], out: &mut [f32]) {
        assert_eq!(xs.len(), ws.len(), "operand count mismatch");
        assert!(out.len() >= xs.len());
        match self.mode {
            ScMode::Binomial => {
                for (o, (&x, &w)) in out.iter_mut().zip(xs.iter().zip(ws)) {
                    *o = self.mul_bipolar(x, w);
                }
            }
            ScMode::Exact => {
                let len = self.len;
                let seed0 = self.stream_seed;
                // Small batches route to the 64-lane plane: a `u64` pass
                // costs a fraction of a `MaxPlane` pass's per-cycle word
                // ops, and a batch that fits one word gains nothing from
                // the wider plane (the PR 4 `wide64` routing precedent).
                // Routing never changes results — the widths are
                // bit-identical product-for-product (property-tested).
                self.stream_seed = if xs.len() <= 64 {
                    pwmm_wide::with_thread_scratch::<u64, _>(|st| {
                        pwmm_wide::mul_bipolar_exact_batch(xs, ws, len, seed0, st, out)
                    })
                } else {
                    pwmm_wide::with_thread_scratch::<MaxPlane, _>(|st| {
                        pwmm_wide::mul_bipolar_exact_batch(xs, ws, len, seed0, st, out)
                    })
                };
            }
        }
    }

    /// SC dot product with binary-domain accumulation: each product is an
    /// independent SC multiply; the decoded values are summed exactly, in
    /// product order (APC adder tree + accumulator in hardware).
    /// Bit-identical to a per-product `mul_bipolar` loop — in `Exact`
    /// mode it runs [`Self::mul_bipolar_batch`] over
    /// [`MAX_LANES`](crate::smurf::sim_wide::MAX_LANES)-sized chunks with
    /// a stack staging buffer (no heap allocation), so the CNN layers get
    /// the plane pipeline just by handing their gathered operand pairs
    /// here.
    pub fn dot_bipolar(&mut self, xs: &[f32], ws: &[f32]) -> f32 {
        use crate::sc::plane::MAX_LANES;
        debug_assert_eq!(xs.len(), ws.len());
        let mut buf = [0.0f32; MAX_LANES];
        let mut acc = 0.0f32;
        for (xc, wc) in xs.chunks(MAX_LANES).zip(ws.chunks(MAX_LANES)) {
            self.mul_bipolar_batch(xc, wc, &mut buf[..xc.len()]);
            for &v in &buf[..xc.len()] {
                acc += v;
            }
        }
        acc
    }

    /// Sample `Binomial(n, p)` — delegates to [`binomial_bitsliced`].
    fn binomial(&mut self, n: usize, p: f64) -> u64 {
        binomial_bitsliced(&mut self.rng, n, p)
    }
}

/// Sample `Binomial(n, p)` with `p` quantized to 16-bit resolution
/// (the hardware θ-gate threshold width).
///
/// Bit-sliced: 64 lanes are drawn at once by building a 16-bit uniform
/// per lane across ≤16 random words and comparing against the threshold
/// with a bit-sliced lexicographic comparator (early exit once every
/// lane is decided). Replaces `n` scalar RNG calls with `≤16·⌈n/64⌉` —
/// the §Perf optimization that took SC-PwMM from 4.6 to 12+ MMAC/s.
pub fn binomial_bitsliced(rng: &mut Pcg, n: usize, p: f64) -> u64 {
    let p = p.clamp(0.0, 1.0);
    let k = (p * 65536.0).round() as u32; // threshold in [0, 65536]
    if k == 0 {
        return 0;
    }
    if k >= 65536 {
        return n as u64;
    }
    let mut ones = 0u64;
    let mut remaining = n;
    while remaining > 0 {
        let lanes = remaining.min(64);
        // Bit-sliced comparison uniform16 < k, MSB first.
        let mut lt = 0u64;
        let mut eq = !0u64;
        for bit in (0..16).rev() {
            let w = rng.next_u64(); // one bit-slice of all 64 uniforms
            if (k >> bit) & 1 == 1 {
                lt |= eq & !w;
            } else {
                eq &= !w;
                continue;
            }
            eq &= w;
            if eq == 0 {
                break;
            }
        }
        let mask = if lanes == 64 { !0u64 } else { (1u64 << lanes) - 1 };
        ones += (lt & mask).count_ones() as u64;
        remaining -= lanes;
    }
    ones
}

/// A SMURF-based activation: synthesized once, applied per neuron.
///
/// Bipolar convention (Fig. 3 normalization): a pre-activation
/// `v ∈ [-R, R]` maps to the SN `P = (v/R + 1)/2`, SMURF produces
/// `P_y = T(P)` with `T(P) = (tanh(k(2P−1)) + 1)/2`, and the bipolar
/// decode `y = 2·P_y − 1` realizes `tanh(k·v/R)`. With `k = R` this is
/// exactly `tanh(v)` on the clamp region — and at `k = N/2` the QP
/// recovers the Brown–Card binary labelling, so the 4-state default
/// (R = k = 2) is the paper's own configuration.
pub struct SmurfActivation {
    approx: SmurfApproximator,
    /// Input half-range R: pre-activations clamp to [-R, R].
    range: f32,
    len: usize,
    seed_ctr: std::cell::Cell<u64>,
}

impl SmurfActivation {
    /// Synthesized SMURF tanh (univariate N-state chain, bipolar,
    /// k = R = N/2).
    pub fn tanh(len: usize, n_states: usize) -> Self {
        let cfg = SmurfConfig::uniform(1, n_states);
        let r = n_states as f64 / 2.0;
        let approx = SmurfApproximator::synthesize(&cfg, &functions::tanh_bipolar(r), len);
        Self { approx, range: r as f32, len, seed_ctr: std::cell::Cell::new(1) }
    }

    fn encode(&self, x: f32) -> f64 {
        ((x / self.range).clamp(-1.0, 1.0) as f64 + 1.0) / 2.0
    }

    /// Expected-value (analytic) activation — used by training.
    pub fn eval_analytic(&self, x: f32) -> f32 {
        let p = self.encode(x);
        2.0 * self.approx.eval_analytic(&[p]) as f32 - 1.0
    }

    /// Bit-level activation: analytic mean + exact bitstream sampling
    /// noise (`ones ~ Binomial(L, P_y)`), decoded bipolar.
    pub fn eval_stochastic(&self, x: f32, rng: &mut Pcg) -> f32 {
        let p = self.encode(x);
        let p_y = self.approx.eval_analytic(&[p]).clamp(0.0, 1.0);
        let ones = binomial_bitsliced(rng, self.len, p_y);
        2.0 * (ones as f64 / self.len as f64) as f32 - 1.0
    }

    /// Full hardware-faithful evaluation through the FSM simulator, one
    /// neuron at a time. Each call consumes one seed from the per-instance
    /// counter; [`Self::eval_bitlevel_batch`] consumes the same seeds in
    /// the same order, which is what makes the two paths bit-identical.
    pub fn eval_bitlevel(&self, x: f32) -> f32 {
        let p = self.encode(x);
        let s = self.seed_ctr.get();
        self.seed_ctr.set(s + 1);
        2.0 * self.approx.eval_bitstream(&[p], self.len, s) as f32 - 1.0
    }

    /// Hardware-faithful activation of a whole layer, in place: packs up
    /// to [`MAX_LANES`](crate::smurf::sim_wide::MAX_LANES) activations
    /// (the widest bit plane compiled into the build) per bit-plane pass
    /// of the prebuilt wide engine via
    /// [`SmurfApproximator::eval_bitstream_points_into`] (thread-local
    /// scratch) and overwrites `xs` chunk by chunk — zero heap
    /// allocation, the steady-state layer path.
    ///
    /// Element-for-element bit-identical to calling
    /// [`Self::eval_bitlevel`] on each `xs[i]` in order: element `i` uses
    /// seed `ctr + i`, and the counter advances by `xs.len()` exactly as
    /// the scalar loop would — regardless of the plane width doing the
    /// chunking.
    pub fn eval_bitlevel_inplace(&self, xs: &mut [f32]) {
        use crate::smurf::sim_wide::MAX_LANES;
        if xs.is_empty() {
            return;
        }
        let s0 = self.seed_ctr.get();
        self.seed_ctr.set(s0 + xs.len() as u64);
        let mut ps = [[0.0f64; 1]; MAX_LANES];
        let mut seeds = [0u64; MAX_LANES];
        let mut lane_out = [0.0f64; MAX_LANES];
        for (c, chunk) in xs.chunks_mut(MAX_LANES).enumerate() {
            let k = chunk.len();
            for (l, &x) in chunk.iter().enumerate() {
                ps[l][0] = self.encode(x);
                seeds[l] = s0 + (c * MAX_LANES + l) as u64;
            }
            let mut refs: [&[f64]; MAX_LANES] = [&[]; MAX_LANES];
            for (l, p) in ps.iter().enumerate().take(k) {
                refs[l] = p;
            }
            self.approx
                .eval_bitstream_points_into(&refs[..k], self.len, &seeds[..k], &mut lane_out[..k]);
            for (o, &y) in chunk.iter_mut().zip(&lane_out[..k]) {
                *o = 2.0 * y as f32 - 1.0;
            }
        }
    }

    /// Allocating convenience wrapper over [`Self::eval_bitlevel_inplace`]
    /// (same seed-counter contract).
    pub fn eval_bitlevel_batch(&self, xs: &[f32]) -> Vec<f32> {
        let mut out = xs.to_vec();
        self.eval_bitlevel_inplace(&mut out);
        out
    }

    pub fn synth_mae(&self) -> f64 {
        self.approx.synth_mae
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, UnitVec};

    #[test]
    fn binomial_matches_exact_distribution() {
        // Mean and variance of the two SC modes must agree (they sample
        // the same distribution).
        let trials = 4000;
        let (a, b) = (0.6f32, -0.4f32);
        let mut mean_b = 0.0;
        let mut var_b = 0.0;
        let mut mean_e = 0.0;
        let mut var_e = 0.0;
        let mut ctx_b = ScContext::new(128, ScMode::Binomial, 1);
        let mut ctx_e = ScContext::new(128, ScMode::Exact, 2);
        for _ in 0..trials {
            let yb = ctx_b.mul_bipolar(a, b) as f64;
            let ye = ctx_e.mul_bipolar(a, b) as f64;
            mean_b += yb;
            var_b += yb * yb;
            mean_e += ye;
            var_e += ye * ye;
        }
        mean_b /= trials as f64;
        mean_e /= trials as f64;
        var_b = var_b / trials as f64 - mean_b * mean_b;
        var_e = var_e / trials as f64 - mean_e * mean_e;
        assert!((mean_b - (a * b) as f64).abs() < 0.01, "binomial mean {mean_b}");
        assert!((mean_e - (a * b) as f64).abs() < 0.01, "exact mean {mean_e}");
        assert!(
            (var_b - var_e).abs() < 0.2 * var_e.max(1e-6),
            "variance mismatch: binomial {var_b} vs exact {var_e}"
        );
    }

    #[test]
    fn prop_mul_bipolar_unbiased() {
        check(51, 32, &UnitVec { len: 2 }, |v| {
            let (a, b) = ((v[0] * 2.0 - 1.0) as f32, (v[1] * 2.0 - 1.0) as f32);
            let mut ctx = ScContext::new(128, ScMode::Binomial, v[0].to_bits());
            let n = 2000;
            let mean: f64 =
                (0..n).map(|_| ctx.mul_bipolar(a, b) as f64).sum::<f64>() / n as f64;
            (mean - (a * b) as f64).abs() < 0.03
        });
    }

    /// The legacy `Exact` implementation, verbatim: two fresh
    /// `Bitstream`s and a materialized XNOR decoded via `mean()`. The
    /// allocation-free scalar path must reproduce it bit-for-bit.
    fn legacy_exact_product(x: f32, w: f32, len: usize, sseed: u64) -> f32 {
        let a = x.clamp(-1.0, 1.0) as f64;
        let b = w.clamp(-1.0, 1.0) as f64;
        let mut r1 = XorShift64::new(sseed);
        let mut r2 = XorShift64::new(sseed ^ B_STREAM_XOR);
        let sa = Bitstream::generate((a + 1.0) / 2.0, len, &mut r1);
        let sb = Bitstream::generate((b + 1.0) / 2.0, len, &mut r2);
        (2.0 * sa.xnor(&sb).mean() - 1.0) as f32
    }

    #[test]
    fn prop_exact_mul_bipolar_unchanged_bit_for_bit() {
        // Random operands spanning the clamp region, random seeds and
        // stream lengths (incl. non-multiples of 64): the scratch-pair
        // scalar path equals the legacy allocating path exactly.
        check(61, 48, &UnitVec { len: 3 }, |v| {
            let x = (v[0] * 4.0 - 2.0) as f32;
            let w = (v[1] * 4.0 - 2.0) as f32;
            let seed = v[2].to_bits();
            let len = 32 + (seed % 97) as usize;
            let mut ctx = ScContext::new(len, ScMode::Exact, seed);
            // Two products in a row: both the first-use and the
            // scratch-reuse shapes.
            let mut sseed = seed ^ 0xD1CE;
            (0..2).all(|_| {
                sseed = sseed.wrapping_add(STREAM_SEED_STRIDE);
                let want = legacy_exact_product(x, w, len, sseed);
                ctx.mul_bipolar(x, w).to_bits() == want.to_bits()
            })
        });
    }

    #[test]
    fn exact_batching_never_reorders_entropy() {
        // Satellite: the stream-seed discipline. Same seed + same product
        // sequence ⇒ same streams, however the products are grouped:
        // per-product loop, one big batch (wide engine), uneven chunked
        // batches, or the dot-product gather.
        use crate::sc::plane::MAX_LANES;
        let n = MAX_LANES + 9;
        let xs: Vec<f32> = (0..n).map(|i| ((i * 31) % 199) as f32 / 99.0 - 1.0).collect();
        let ws: Vec<f32> = (0..n).map(|i| 1.0 - ((i * 17) % 193) as f32 / 96.0).collect();
        let mut c1 = ScContext::new(64, ScMode::Exact, 7);
        let mut c2 = ScContext::new(64, ScMode::Exact, 7);
        let mut c3 = ScContext::new(64, ScMode::Exact, 7);
        let mut c4 = ScContext::new(64, ScMode::Exact, 7);
        let v1: Vec<f32> =
            xs.iter().zip(&ws).map(|(&x, &w)| c1.mul_bipolar(x, w)).collect();
        let mut v2 = vec![0.0f32; n];
        c2.mul_bipolar_batch(&xs, &ws, &mut v2);
        let cut = 13;
        let mut v3 = vec![0.0f32; n];
        c3.mul_bipolar_batch(&xs[..cut], &ws[..cut], &mut v3[..cut]);
        c3.mul_bipolar_batch(&xs[cut..], &ws[cut..], &mut v3[cut..]);
        assert_eq!(v1, v2, "one batch must equal the per-product loop");
        assert_eq!(v1, v3, "chunked batches must equal the per-product loop");
        // Each path consumed exactly one seed per product.
        let want_seed =
            (7u64 ^ 0xD1CE).wrapping_add((n as u64).wrapping_mul(STREAM_SEED_STRIDE));
        assert_eq!(c1.stream_seed(), want_seed);
        assert_eq!(c2.stream_seed(), want_seed);
        assert_eq!(c3.stream_seed(), want_seed);
        // The dot product sums those very products, in order.
        let dot = c4.dot_bipolar(&xs, &ws);
        let mut acc = 0.0f32;
        for &v in &v1 {
            acc += v;
        }
        assert_eq!(dot.to_bits(), acc.to_bits());
        assert_eq!(c4.stream_seed(), want_seed);
        // And order is load-bearing: a context that ran one extra product
        // first sits at a different seed, so later streams shift.
        let mut c5 = ScContext::new(64, ScMode::Exact, 7);
        let _ = c5.mul_bipolar(0.5, 0.5);
        assert_ne!(c5.stream_seed(), ScContext::new(64, ScMode::Exact, 7).stream_seed());
    }

    #[test]
    fn binomial_batch_matches_loop() {
        // Binomial mode draws from the context's Pcg sequentially; the
        // batch entry must consume it identically.
        let xs = [0.5f32, -0.25, 0.0, 1.0, -1.0, 0.75];
        let ws = [0.9f32, 0.9, -0.3, -1.0, 0.2, 0.4];
        let mut c1 = ScContext::new(128, ScMode::Binomial, 3);
        let mut c2 = ScContext::new(128, ScMode::Binomial, 3);
        let v1: Vec<f32> =
            xs.iter().zip(&ws).map(|(&x, &w)| c1.mul_bipolar(x, w)).collect();
        let mut v2 = vec![0.0f32; xs.len()];
        c2.mul_bipolar_batch(&xs, &ws, &mut v2);
        assert_eq!(v1, v2);
    }

    #[test]
    fn dot_accumulates_in_binary_domain() {
        let xs = [0.5f32, -0.5, 0.25, 1.0];
        let ws = [1.0f32, 1.0, -1.0, 0.5];
        let exact: f32 = xs.iter().zip(&ws).map(|(x, w)| x * w).sum();
        let mut ctx = ScContext::new(128, ScMode::Binomial, 7);
        let n = 500;
        let mean: f32 = (0..n).map(|_| ctx.dot_bipolar(&xs, &ws)).sum::<f32>() / n as f32;
        assert!((mean - exact).abs() < 0.05, "mean={mean} exact={exact}");
    }

    #[test]
    fn smurf_tanh_activation_tracks_tanh() {
        let act = SmurfActivation::tanh(64, 4);
        assert!(act.synth_mae() < 0.01, "synth MAE {}", act.synth_mae());
        // Inside the clamp region [-2, 2] the activation is tanh(x).
        for &x in &[-1.5f32, -0.7, -0.2, 0.0, 0.5, 1.0, 1.9] {
            let y = act.eval_analytic(x);
            let t = x.tanh();
            assert!((y - t).abs() < 0.05, "x={x}: smurf={y} tanh={t}");
        }
        // Beyond the clamp it saturates to ±tanh(2) ≈ ±0.964.
        assert!((act.eval_analytic(4.0) - 2f32.tanh()).abs() < 0.05);
    }

    #[test]
    fn stochastic_activation_noisy_but_unbiased() {
        let act = SmurfActivation::tanh(64, 4);
        let mut rng = Pcg::new(3);
        let x = 1.5f32;
        let n = 3000;
        let mean: f32 =
            (0..n).map(|_| act.eval_stochastic(x, &mut rng)).sum::<f32>() / n as f32;
        assert!((mean - act.eval_analytic(x)).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn bitlevel_activation_agrees_with_analytic() {
        let act = SmurfActivation::tanh(256, 4);
        let x = 2.0f32;
        let n = 64;
        let mean: f32 = (0..n).map(|_| act.eval_bitlevel(x)).sum::<f32>() / n as f32;
        assert!(
            (mean - act.eval_analytic(x)).abs() < 0.05,
            "bitlevel mean={mean} analytic={}",
            act.eval_analytic(x)
        );
    }

    #[test]
    fn bitlevel_batch_bit_identical_to_scalar_path() {
        // MAX_LANES*2 + 2 activations = two full plane words + a 2-lane
        // tail at whichever width the build auto-selected. Two
        // identically-synthesized instances keep the seed counters in
        // lockstep between the batched and the per-neuron path.
        use crate::smurf::sim_wide::MAX_LANES;
        let n = MAX_LANES * 2 + 2;
        let batched = SmurfActivation::tanh(64, 4);
        let scalar = SmurfActivation::tanh(64, 4);
        let xs: Vec<f32> =
            (0..n).map(|i| (i as f32 / (n - 1) as f32) * 6.0 - 3.0).collect();
        let a = batched.eval_bitlevel_batch(&xs);
        let b: Vec<f32> = xs.iter().map(|&x| scalar.eval_bitlevel(x)).collect();
        assert_eq!(a, b);
        // The counters advanced identically, so a second (short) round
        // still matches — the layer-after-layer shape of a forward pass.
        let a2 = batched.eval_bitlevel_batch(&xs[..5]);
        let b2: Vec<f32> = xs[..5].iter().map(|&x| scalar.eval_bitlevel(x)).collect();
        assert_eq!(a2, b2);
        assert!(batched.eval_bitlevel_batch(&[]).is_empty());
    }

    #[test]
    fn prop_bitlevel_batch_matches_scalar_elementwise() {
        // Random batch sizes up past the auto-width chunk boundary
        // (non-multiples of the lane count included); every element must
        // be bit-identical to the scalar path.
        use crate::smurf::sim_wide::MAX_LANES;
        use crate::testing::{check, RangeUsize};
        let batched = SmurfActivation::tanh(32, 4);
        let scalar = SmurfActivation::tanh(32, 4);
        check(53, 8, &RangeUsize { lo: 1, hi: MAX_LANES + 50 }, |&n| {
            let xs: Vec<f32> =
                (0..n).map(|i| ((i * 37 % 101) as f32 / 50.0) - 1.0).collect();
            let a = batched.eval_bitlevel_batch(&xs);
            let b: Vec<f32> = xs.iter().map(|&x| scalar.eval_bitlevel(x)).collect();
            a == b
        });
    }

    #[test]
    fn odd_symmetry() {
        let act = SmurfActivation::tanh(64, 4);
        let a = act.eval_analytic(1.0);
        let b = -act.eval_analytic(-1.0);
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

//! Hartley-transform convolution path (paper Eq. 13–15, ref [22]).
//!
//! `H(k,l) = (1/Q) Σ_{m,n} f[m,n]·cas(2π(km+ln)/Q)` with
//! `cas x = cos x + sin x`. CNN/HSC computes the cas kernel from a LUT;
//! CNN/SMURF computes the factored form `sin(x₁)cos(x₂)` (Eq. 14–15) with
//! a bivariate SMURF. Both paths share the same transform plumbing so the
//! only difference is the kernel generator — exactly the paper's
//! comparison axis.

use crate::baselines::lut::Lut;
use crate::smurf::approximator::SmurfApproximator;
use crate::smurf::config::SmurfConfig;
use crate::synth::functions;

/// How the cas kernel values are produced.
pub enum CasKernel {
    /// Exact f64 (vanilla reference).
    Exact,
    /// SMURF-HT: `sin(x₁)cos(x₂)` from the synthesized bivariate SMURF
    /// (paper Table II coefficients), plus the complementary
    /// `cos(x₁)sin(x₂)` term via the identity `cas a·b` expansion.
    Smurf(Box<SmurfApproximator>),
    /// LUT-HT (CNN/HSC): cas values from an 8-bit quantized table.
    Lut(Box<Lut>),
}

impl CasKernel {
    pub fn exact() -> Self {
        CasKernel::Exact
    }

    /// Synthesize the SMURF sincos generator (N=4, M=2 — Table II).
    pub fn smurf() -> Self {
        let cfg = SmurfConfig::uniform(2, 4);
        CasKernel::Smurf(Box::new(SmurfApproximator::synthesize(
            &cfg,
            &functions::sincos(),
            256,
        )))
    }

    /// Build the HSC LUT over the product form.
    pub fn lut() -> Self {
        CasKernel::Lut(Box::new(Lut::build(&functions::sincos(), 8, 11)))
    }

    /// `sin(a)·cos(b)` for `a, b ∈ [0, 1]` (normalized angle products —
    /// the Eq. 15 target domain).
    fn sincos_unit(&self, a: f64, b: f64) -> f64 {
        match self {
            CasKernel::Exact => a.sin() * b.cos(),
            CasKernel::Smurf(s) => s.eval_analytic(&[a, b]),
            CasKernel::Lut(l) => l.eval(&[a, b]),
        }
    }

    /// `cas(θ) = cos θ + sin θ` for arbitrary θ, computed through the
    /// unit-box generator by angle reduction:
    /// `sin θ = sin(r)cos(0)`-style factored calls with r ∈ [0,1].
    pub fn cas(&self, theta: f64) -> f64 {
        // Reduce θ to [0, 2π).
        let tau = std::f64::consts::TAU;
        let mut r = theta % tau;
        if r < 0.0 {
            r += tau;
        }
        // sin/cos by quadrant reduction into [0, π/2] ⊂ radians, then the
        // generator is exercised on its [0,1] domain (π/2 < 1.5708 —
        // slightly beyond 1; fold at 1 rad via identities).
        let sin_t = self.sin_reduced(r);
        let cos_t = self.sin_reduced(r + std::f64::consts::FRAC_PI_2);
        sin_t + cos_t
    }

    /// sin of any angle via quadrant symmetry + the unit-box generator.
    fn sin_reduced(&self, theta: f64) -> f64 {
        let tau = std::f64::consts::TAU;
        let pi = std::f64::consts::PI;
        let mut r = theta % tau;
        if r < 0.0 {
            r += tau;
        }
        let (mut x, sign) = if r <= pi { (r, 1.0) } else { (r - pi, -1.0) };
        if x > pi / 2.0 {
            x = pi - x;
        }
        // x ∈ [0, π/2]; the generator domain is [0,1] rad — fold the tail
        // with sin(x) = sin(1)cos(x-1) + cos(1)sin(x-1).
        let s = if x <= 1.0 {
            // sin(x) = sin(x)·cos(0)
            self.sincos_unit(x, 0.0)
        } else {
            let d = x - 1.0; // ≤ 0.5708, in domain
            // sin(1+d) = sin(1)cos(d) + sin(d)cos(1)
            self.sincos_unit(1.0, d) + self.sincos_unit(d, 1.0)
        };
        sign * s
    }
}

/// Dense 2-D Hartley transform of a Q×Q tile (Eq. 13).
pub fn hartley2(tile: &[f64], q: usize, kernel: &CasKernel) -> Vec<f64> {
    assert_eq!(tile.len(), q * q);
    let mut out = vec![0.0; q * q];
    for k in 0..q {
        for l in 0..q {
            let mut acc = 0.0;
            for m in 0..q {
                for n in 0..q {
                    let ang = std::f64::consts::TAU * ((k * m + l * n) as f64) / q as f64;
                    acc += tile[m * q + n] * kernel.cas(ang);
                }
            }
            out[k * q + l] = acc / q as f64;
        }
    }
    out
}

/// The HT is an involution up to scale: `H(H(f)) = f`.
pub fn inverse_hartley2(spec: &[f64], q: usize, kernel: &CasKernel) -> Vec<f64> {
    hartley2(spec, q, kernel)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cas_exact_matches_closed_form() {
        let k = CasKernel::exact();
        for &t in &[0.0f64, 0.5, 1.0, 2.0, 4.0, -1.3, 7.0] {
            let want = t.cos() + t.sin();
            assert!((k.cas(t) - want).abs() < 1e-9, "cas({t})");
        }
    }

    #[test]
    fn hartley_involution_exact() {
        let q = 5;
        let tile: Vec<f64> = (0..q * q).map(|i| ((i * 7 % 11) as f64) / 11.0).collect();
        let k = CasKernel::exact();
        let spec = hartley2(&tile, q, &k);
        let back = inverse_hartley2(&spec, q, &k);
        for (a, b) in tile.iter().zip(&back) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn smurf_cas_tracks_exact() {
        let smurf = CasKernel::smurf();
        let exact = CasKernel::exact();
        let mut worst = 0.0f64;
        for i in 0..64 {
            let t = i as f64 * 0.1;
            worst = worst.max((smurf.cas(t) - exact.cas(t)).abs());
        }
        // Analytic SMURF sincos has MAE ≈ 0.01 on the unit box; the cas
        // composition roughly doubles it.
        assert!(worst < 0.1, "worst cas error {worst}");
    }

    #[test]
    fn lut_cas_tracks_exact() {
        let lut = CasKernel::lut();
        let exact = CasKernel::exact();
        let mut worst = 0.0f64;
        for i in 0..64 {
            let t = i as f64 * 0.1;
            worst = worst.max((lut.cas(t) - exact.cas(t)).abs());
        }
        assert!(worst < 0.05, "worst LUT cas error {worst}");
    }

    #[test]
    fn smurf_hartley_roundtrip_error_small() {
        let q = 5;
        let tile: Vec<f64> = (0..q * q).map(|i| (i as f64 / 25.0).sin().abs()).collect();
        let smurf = CasKernel::smurf();
        let spec = hartley2(&tile, q, &smurf);
        let back = inverse_hartley2(&spec, q, &smurf);
        let mae: f64 =
            tile.iter().zip(&back).map(|(a, b)| (a - b).abs()).sum::<f64>() / tile.len() as f64;
        assert!(mae < 0.15, "SMURF HT roundtrip MAE={mae}");
    }
}

//! LeNet-5 with pluggable operator sets (paper Tables IV/V).
//!
//! Architecture (28×28 input): conv1 6@5×5 pad2 → tanh → avgpool2 →
//! conv2 16@5×5 → tanh → avgpool2 → fc 400→120 → tanh → fc 120→84 →
//! tanh → fc 84→10 → softmax.
//!
//! Operator sets (Table V):
//! - `Vanilla`   — f32 convolution + exact tanh/softmax.
//! - `Hsc`       — SC-PwMM convolution (128-bit streams, ref [22]'s
//!   SC-PwMM; LUT-based HT front-end), exact activations.
//! - `Smurf`     — SC-PwMM convolution + SMURF tanh activations (64-bit
//!   streams) — the paper's CNN/SMURF.

use super::layers;
use super::sc_ops::{ScContext, ScMode, SmurfActivation};
use super::tensor::Tensor;
use crate::util::json::Json;
use crate::util::prng::Pcg;

/// Which operator set evaluates the network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OpSet {
    Vanilla,
    Hsc,
    Smurf,
}

/// LeNet-5 weights.
#[derive(Clone, Debug)]
pub struct LeNet {
    pub conv1_w: Tensor, // [6,1,5,5]
    pub conv1_b: Vec<f32>,
    pub conv2_w: Tensor, // [16,6,5,5]
    pub conv2_b: Vec<f32>,
    pub fc1_w: Tensor, // [120,400]
    pub fc1_b: Vec<f32>,
    pub fc2_w: Tensor, // [84,120]
    pub fc2_b: Vec<f32>,
    pub fc3_w: Tensor, // [10,84]
    pub fc3_b: Vec<f32>,
}

/// Fidelity of the SMURF activation inside the SC forward pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActFidelity {
    /// Analytic mean + exact binomial bitstream-sampling noise — fast,
    /// statistically identical to the hardware; the Table IV default.
    Stochastic,
    /// Cycle-accurate FSM simulation, batched through the wide engine at
    /// 64 activations per bit-plane pass (one
    /// [`SmurfActivation::eval_bitlevel_batch`] call per layer).
    BitLevel,
}

/// Runtime context for the SC operator sets.
pub struct ScRuntime {
    pub ctx: ScContext,
    pub act: SmurfActivation,
    pub act_rng: Pcg,
    pub act_fidelity: ActFidelity,
}

impl ScRuntime {
    /// Paper configuration: 128-bit SC-PwMM streams, 64-bit SMURF
    /// activation streams, 4-state chains, stochastic activation fidelity.
    pub fn paper_config(seed: u64) -> Self {
        Self {
            ctx: ScContext::new(128, ScMode::Binomial, seed),
            act: SmurfActivation::tanh(64, 4),
            act_rng: Pcg::new(seed ^ 0xAC70),
            act_fidelity: ActFidelity::Stochastic,
        }
    }

    /// Hardware-faithful variant of [`Self::paper_config`]: SMURF
    /// activations run through the cycle-accurate bit-sliced engine,
    /// one batched pass per layer.
    pub fn bitlevel_config(seed: u64) -> Self {
        Self { act_fidelity: ActFidelity::BitLevel, ..Self::paper_config(seed) }
    }
}

impl LeNet {
    /// Kaiming-uniform random initialization.
    pub fn random(seed: u64) -> Self {
        let mut rng = Pcg::new(seed);
        let mut init = |dims: &[usize]| -> Tensor {
            let fan_in: usize = dims[1..].iter().product();
            let bound = (6.0 / fan_in as f64).sqrt();
            let n: usize = dims.iter().product();
            Tensor::from_vec(
                dims,
                (0..n).map(|_| rng.range(-bound, bound) as f32).collect(),
            )
        };
        Self {
            conv1_w: init(&[6, 1, 5, 5]),
            conv1_b: vec![0.0; 6],
            conv2_w: init(&[16, 6, 5, 5]),
            conv2_b: vec![0.0; 16],
            fc1_w: init(&[120, 400]),
            fc1_b: vec![0.0; 120],
            fc2_w: init(&[84, 120]),
            fc2_b: vec![0.0; 84],
            fc3_w: init(&[10, 84]),
            fc3_b: vec![0.0; 10],
        }
    }

    /// Forward pass for one image (`[784]` pixels in [0,1]); returns class
    /// probabilities.
    pub fn forward(&self, image: &[f32], ops: OpSet, rt: Option<&mut ScRuntime>) -> Vec<f32> {
        match ops {
            OpSet::Vanilla => self.forward_vanilla(image),
            OpSet::Hsc | OpSet::Smurf => {
                let rt = rt.expect("SC op sets need an ScRuntime");
                self.forward_sc(image, ops, rt)
            }
        }
    }

    fn forward_vanilla(&self, image: &[f32]) -> Vec<f32> {
        let x = Tensor::from_vec(&[1, 1, 28, 28], image.to_vec());
        let mut h = layers::conv2d(&x, &self.conv1_w, &self.conv1_b, 2);
        layers::tanh_inplace(&mut h.data);
        let h = layers::avgpool2(&h);
        let mut h = layers::conv2d(&h, &self.conv2_w, &self.conv2_b, 0);
        layers::tanh_inplace(&mut h.data);
        let h = layers::avgpool2(&h);
        let mut v = layers::dense(&h.data, &self.fc1_w, &self.fc1_b);
        layers::tanh_inplace(&mut v);
        let mut v = layers::dense(&v, &self.fc2_w, &self.fc2_b);
        layers::tanh_inplace(&mut v);
        let v = layers::dense(&v, &self.fc3_w, &self.fc3_b);
        layers::softmax(&v)
    }

    /// SC forward: convolutions + dense layers via SC-PwMM; activations
    /// per the op set. Per-layer weight scaling keeps operands in the
    /// bipolar domain [-1,1].
    fn forward_sc(&self, image: &[f32], ops: OpSet, rt: &mut ScRuntime) -> Vec<f32> {
        let x = Tensor::from_vec(&[1, 1, 28, 28], image.to_vec());
        let mut h = sc_conv2d(&x, &self.conv1_w, &self.conv1_b, 2, &mut rt.ctx);
        activate(&mut h.data, ops, rt);
        let h = layers::avgpool2(&h);
        let mut h = sc_conv2d(&h, &self.conv2_w, &self.conv2_b, 0, &mut rt.ctx);
        activate(&mut h.data, ops, rt);
        let h = layers::avgpool2(&h);
        let mut v = sc_dense(&h.data, &self.fc1_w, &self.fc1_b, &mut rt.ctx);
        activate(&mut v, ops, rt);
        let mut v = sc_dense(&v, &self.fc2_w, &self.fc2_b, &mut rt.ctx);
        activate(&mut v, ops, rt);
        // Final classifier layer stays full precision in both SC schemes
        // (the paper's HSC leaves the classifier head exact; SMURF
        // replaces softmax with its own generator only for the
        // *probability readout*, which argmax makes equivalent).
        let v = layers::dense(&v, &self.fc3_w, &self.fc3_b);
        layers::softmax(&v)
    }

    /// Classification accuracy over a dataset slice.
    pub fn accuracy(
        &self,
        images: &[f32],
        labels: &[u8],
        ops: OpSet,
        mut rt: Option<&mut ScRuntime>,
    ) -> f64 {
        let n = labels.len();
        let mut correct = 0usize;
        for i in 0..n {
            let img = &images[i * 784..(i + 1) * 784];
            let probs = match &mut rt {
                Some(r) => self.forward(img, ops, Some(r)),
                None => self.forward(img, ops, None),
            };
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == labels[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / n as f64
    }

    // ---- weight (de)serialization --------------------------------------

    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        let mut put = |k: &str, t: &Tensor| {
            m.insert(k.to_string(), Json::from_f32s(&t.data));
        };
        put("conv1_w", &self.conv1_w);
        put("conv2_w", &self.conv2_w);
        put("fc1_w", &self.fc1_w);
        put("fc2_w", &self.fc2_w);
        put("fc3_w", &self.fc3_w);
        m.insert("conv1_b".into(), Json::from_f32s(&self.conv1_b));
        m.insert("conv2_b".into(), Json::from_f32s(&self.conv2_b));
        m.insert("fc1_b".into(), Json::from_f32s(&self.fc1_b));
        m.insert("fc2_b".into(), Json::from_f32s(&self.fc2_b));
        m.insert("fc3_b".into(), Json::from_f32s(&self.fc3_b));
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self, String> {
        let vecf = |k: &str| -> Result<Vec<f32>, String> {
            Ok(j.get(k)
                .and_then(Json::as_f64_vec)
                .ok_or_else(|| format!("missing {k}"))?
                .iter()
                .map(|&x| x as f32)
                .collect())
        };
        let tens = |k: &str, dims: &[usize]| -> Result<Tensor, String> {
            let v = vecf(k)?;
            if v.len() != dims.iter().product::<usize>() {
                return Err(format!("{k}: wrong size {}", v.len()));
            }
            Ok(Tensor::from_vec(dims, v))
        };
        Ok(Self {
            conv1_w: tens("conv1_w", &[6, 1, 5, 5])?,
            conv1_b: vecf("conv1_b")?,
            conv2_w: tens("conv2_w", &[16, 6, 5, 5])?,
            conv2_b: vecf("conv2_b")?,
            fc1_w: tens("fc1_w", &[120, 400])?,
            fc1_b: vecf("fc1_b")?,
            fc2_w: tens("fc2_w", &[84, 120])?,
            fc2_b: vecf("fc2_b")?,
            fc3_w: tens("fc3_w", &[10, 84])?,
            fc3_b: vecf("fc3_b")?,
        })
    }

    /// Load from `artifacts/lenet_weights.json` if present.
    pub fn load(path: &str) -> Result<Self, String> {
        let src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Self::from_json(&Json::parse(&src)?)
    }
}

/// Apply the op set's activation to one whole layer. The SMURF paths are
/// layer-granular: bit-level fidelity hands the entire slice to the wide
/// engine (64 activations per pass) instead of simulating neuron by
/// neuron.
fn activate(xs: &mut [f32], ops: OpSet, rt: &mut ScRuntime) {
    match ops {
        OpSet::Vanilla => layers::tanh_inplace(xs),
        // CNN/HSC: full-precision activation (paper §IV-B: "[22] is not
        // mentioned how the nonlinear activations are done" — they are
        // exact there).
        OpSet::Hsc => layers::tanh_inplace(xs),
        OpSet::Smurf => match rt.act_fidelity {
            ActFidelity::Stochastic => {
                for v in xs.iter_mut() {
                    *v = rt.act.eval_stochastic(*v, &mut rt.act_rng);
                }
            }
            ActFidelity::BitLevel => layers::smurf_activate_inplace(xs, &rt.act),
        },
    }
}

/// SC-PwMM convolution: every multiply runs in the bipolar SC domain;
/// accumulation is binary (APC). Weights are scaled into [-1,1] per layer
/// and rescaled after accumulation; activations from tanh are already
/// bipolar, input pixels are in [0,1] ⊂ [-1,1].
///
/// The whole output channel's `(x, w)` pairs are gathered into one flat
/// batch (pixel-major, `ic`-major within a pixel, in the tap order of
/// [`layers::for_each_valid_tap`] — exactly the order the per-product
/// loop always used) and run through [`ScContext::mul_bipolar_batch`], so
/// in `Exact` mode the plane-form PwMM engine ([`crate::sc::pwmm_wide`])
/// sees near-full lane occupancy even for small kernels (a single conv1
/// pixel is only 25 products; a channel is thousands). Decoded products
/// are then segment-summed per pixel, in product order — bit-identical to
/// per-product `mul_bipolar` accumulation, because the batch consumes
/// stream seeds positionally and the f32 adds happen in the same order.
/// The gather buffers are reused across channels.
pub fn sc_conv2d(
    x: &Tensor,
    weight: &Tensor,
    bias: &[f32],
    pad: usize,
    ctx: &mut ScContext,
) -> Tensor {
    let (n, in_c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (out_c, _, kh, kw) = (weight.dims[0], weight.dims[1], weight.dims[2], weight.dims[3]);
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    let wscale = weight.data.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
    let mut y = Tensor::zeros(&[n, out_c, oh, ow]);
    let cap = in_c * kh * kw * oh * ow;
    let mut xbuf: Vec<f32> = Vec::with_capacity(cap);
    let mut wbuf: Vec<f32> = Vec::with_capacity(cap);
    let mut prods: Vec<f32> = Vec::new();
    let mut counts: Vec<usize> = Vec::with_capacity(oh * ow);
    for b in 0..n {
        for oc in 0..out_c {
            xbuf.clear();
            wbuf.clear();
            counts.clear();
            for oy in 0..oh {
                for ox in 0..ow {
                    let before = xbuf.len();
                    for ic in 0..in_c {
                        layers::for_each_valid_tap(h, w, kh, kw, pad, oy, ox, |ky, kx, iy, ix| {
                            xbuf.push(x.at4(b, ic, iy, ix));
                            wbuf.push(weight.at4(oc, ic, ky, kx) / wscale);
                        });
                    }
                    counts.push(xbuf.len() - before);
                }
            }
            prods.resize(xbuf.len(), 0.0);
            ctx.mul_bipolar_batch(&xbuf, &wbuf, &mut prods);
            let mut off = 0;
            for (pix, &cnt) in counts.iter().enumerate() {
                let mut acc = 0.0f32;
                for &v in &prods[off..off + cnt] {
                    acc += v;
                }
                off += cnt;
                *y.at4_mut(b, oc, pix / ow, pix % ow) = acc * wscale + bias[oc];
            }
        }
    }
    y
}

/// SC-PwMM dense layer with the same scaling discipline. Like
/// [`sc_conv2d`], the whole layer's scaled operand pairs (every row
/// against the shared scaled input vector) are gathered into one flat
/// batch for [`ScContext::mul_bipolar_batch`] and segment-summed per
/// output neuron, in product order — full lane occupancy, bit-identical
/// to the per-product loop.
pub fn sc_dense(x: &[f32], w: &Tensor, b: &[f32], ctx: &mut ScContext) -> Vec<f32> {
    let (out, inn) = (w.dims[0], w.dims[1]);
    assert_eq!(x.len(), inn);
    let wscale = w.data.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
    let xscale = x.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1.0);
    let xscaled: Vec<f32> = x.iter().map(|&xi| xi / xscale).collect();
    let mut xbuf: Vec<f32> = Vec::with_capacity(out * inn);
    let mut wbuf: Vec<f32> = Vec::with_capacity(out * inn);
    for _ in 0..out {
        xbuf.extend_from_slice(&xscaled);
    }
    wbuf.extend(w.data.iter().map(|&wi| wi / wscale));
    let mut prods = vec![0.0f32; out * inn];
    ctx.mul_bipolar_batch(&xbuf, &wbuf, &mut prods);
    let mut y = vec![0.0f32; out];
    for (o, yo) in y.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for &v in &prods[o * inn..(o + 1) * inn] {
            acc += v;
        }
        *yo = acc * wscale * xscale + b[o];
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_shapes_and_probabilities() {
        let net = LeNet::random(1);
        let img = vec![0.5f32; 784];
        let p = net.forward(&img, OpSet::Vanilla, None);
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(p.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn sc_forward_close_to_vanilla_in_expectation() {
        // With long streams the SC network output must approach vanilla.
        let net = LeNet::random(2);
        let img: Vec<f32> = (0..784).map(|i| ((i % 13) as f32) / 13.0).collect();
        let p_ref = net.forward(&img, OpSet::Vanilla, None);
        let mut rt = ScRuntime {
            ctx: ScContext::new(4096, ScMode::Binomial, 7),
            act: SmurfActivation::tanh(4096, 4),
            act_rng: Pcg::new(8),
            act_fidelity: ActFidelity::Stochastic,
        };
        let p_sc = net.forward(&img, OpSet::Hsc, Some(&mut rt));
        let top_ref = p_ref
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let top_sc = p_sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(top_ref, top_sc, "argmax must survive long-stream SC");
    }

    #[test]
    fn smurf_opset_runs() {
        let net = LeNet::random(3);
        let img = vec![0.3f32; 784];
        let mut rt = ScRuntime::paper_config(5);
        let p = net.forward(&img, OpSet::Smurf, Some(&mut rt));
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn bitlevel_smurf_opset_runs() {
        // The hardware-faithful activation path (batched wide engine,
        // one pass per layer) through the whole forward pass.
        let net = LeNet::random(3);
        let img = vec![0.3f32; 784];
        let mut rt = ScRuntime::bitlevel_config(5);
        assert_eq!(rt.act_fidelity, ActFidelity::BitLevel);
        let p = net.forward(&img, OpSet::Smurf, Some(&mut rt));
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    /// The pre-gather sc_conv2d, verbatim: one scalar `mul_bipolar` per
    /// product, padding skipped inline. The gathered plane-pipeline conv
    /// must be bit-identical to this (same products, same seed order).
    fn sc_conv2d_per_product_reference(
        x: &Tensor,
        weight: &Tensor,
        bias: &[f32],
        pad: usize,
        ctx: &mut ScContext,
    ) -> Tensor {
        let (n, in_c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
        let (out_c, _, kh, kw) =
            (weight.dims[0], weight.dims[1], weight.dims[2], weight.dims[3]);
        let oh = h + 2 * pad - kh + 1;
        let ow = w + 2 * pad - kw + 1;
        let wscale = weight.data.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
        let mut y = Tensor::zeros(&[n, out_c, oh, ow]);
        for b in 0..n {
            for oc in 0..out_c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f32;
                        for ic in 0..in_c {
                            for ky in 0..kh {
                                let iy = oy + ky;
                                if iy < pad || iy - pad >= h {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = ox + kx;
                                    if ix < pad || ix - pad >= w {
                                        continue;
                                    }
                                    acc += ctx.mul_bipolar(
                                        x.at4(b, ic, iy - pad, ix - pad),
                                        weight.at4(oc, ic, ky, kx) / wscale,
                                    );
                                }
                            }
                        }
                        *y.at4_mut(b, oc, oy, ox) = acc * wscale + bias[oc];
                    }
                }
            }
        }
        y
    }

    #[test]
    fn exact_sc_conv_layer_bit_identical_on_both_paths() {
        // Table IV CNN smoke: the LeNet conv1 kernel (6@5×5, pad 2) in
        // Exact mode — once through the gathered plane-pipeline
        // sc_conv2d, once through the per-product scalar reference. The
        // layer outputs ("logits" of the conv layer) must be equal,
        // element for element. A 12×12 input keeps the smoke fast while
        // still exercising padded corners, edges and interior pixels.
        let net = LeNet::random(11);
        let img: Vec<f32> = (0..144).map(|i| ((i * 7) % 97) as f32 / 96.0).collect();
        let x = Tensor::from_vec(&[1, 1, 12, 12], img);
        let len = 32;
        let mut wide_ctx = ScContext::new(len, ScMode::Exact, 99);
        let got = sc_conv2d(&x, &net.conv1_w, &net.conv1_b, 2, &mut wide_ctx);
        let mut ref_ctx = ScContext::new(len, ScMode::Exact, 99);
        let want =
            sc_conv2d_per_product_reference(&x, &net.conv1_w, &net.conv1_b, 2, &mut ref_ctx);
        assert_eq!(got.dims, want.dims);
        assert_eq!(got.data, want.data);
        // Both contexts consumed the identical entropy.
        assert_eq!(wide_ctx.stream_seed(), ref_ctx.stream_seed());
    }

    #[test]
    fn exact_sc_dense_bit_identical_on_both_paths() {
        // Dense rows longer than one plane word (300 > MAX_LANES in the
        // default build) exercise the chunked dot against the scalar
        // per-product reference.
        let w = {
            let mut rng = Pcg::new(13);
            Tensor::from_vec(
                &[5, 300],
                (0..1500).map(|_| rng.range(-0.8, 0.8) as f32).collect(),
            )
        };
        let b: Vec<f32> = (0..5).map(|o| o as f32 / 10.0).collect();
        let x: Vec<f32> = (0..300).map(|i| ((i * 13) % 61) as f32 / 30.0 - 1.0).collect();
        let len = 48;
        let mut wide_ctx = ScContext::new(len, ScMode::Exact, 7);
        let got = sc_dense(&x, &w, &b, &mut wide_ctx);
        // Per-product reference: the pre-gather sc_dense, verbatim.
        let mut ref_ctx = ScContext::new(len, ScMode::Exact, 7);
        let wscale = w.data.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1e-6);
        let xscale = x.iter().fold(0.0f32, |a, &b| a.max(b.abs())).max(1.0);
        let mut want = vec![0.0f32; 5];
        for (o, yo) in want.iter_mut().enumerate() {
            let row = &w.data[o * 300..(o + 1) * 300];
            let mut acc = 0.0f32;
            for (&xi, &wi) in x.iter().zip(row) {
                acc += ref_ctx.mul_bipolar(xi / xscale, wi / wscale);
            }
            *yo = acc * wscale * xscale + b[o];
        }
        assert_eq!(got, want);
        assert_eq!(wide_ctx.stream_seed(), ref_ctx.stream_seed());
    }

    #[test]
    fn exact_mode_forward_runs() {
        // The Exact (bit-faithful) operator set through the whole forward
        // pass — every conv/dense product now runs in the plane pipeline.
        // Short streams keep the smoke cheap; validity, not accuracy, is
        // the assertion.
        let net = LeNet::random(3);
        let img = vec![0.3f32; 784];
        let mut rt = ScRuntime {
            ctx: ScContext::new(16, ScMode::Exact, 5),
            act: SmurfActivation::tanh(64, 4),
            act_rng: Pcg::new(6),
            act_fidelity: ActFidelity::Stochastic,
        };
        let p = net.forward(&img, OpSet::Smurf, Some(&mut rt));
        assert_eq!(p.len(), 10);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn weights_json_roundtrip() {
        let net = LeNet::random(4);
        let j = net.to_json();
        let back = LeNet::from_json(&j).unwrap();
        assert_eq!(net.conv1_w, back.conv1_w);
        assert_eq!(net.fc3_b, back.fc3_b);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let net = LeNet::random(5);
        let mut j = net.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("fc3_w".into(), Json::from_f32s(&[0.0; 3]));
        }
        assert!(LeNet::from_json(&j).is_err());
    }

    #[test]
    fn accuracy_on_tiny_random_set() {
        // Untrained network ≈ chance; just exercise the path.
        let net = LeNet::random(6);
        let d = crate::data::synth_mnist::generate(20, 9);
        let acc = net.accuracy(&d.images, &d.labels, OpSet::Vanilla, None);
        assert!((0.0..=1.0).contains(&acc));
    }
}

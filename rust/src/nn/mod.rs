//! SC-based CNN inference and training (paper §IV-B, Tables IV/V).
//!
//! - [`tensor`] — minimal NCHW f32 tensor.
//! - [`layers`] — f32 reference ops: conv2d, avg-pool, dense, activations.
//! - [`lenet`] — LeNet-5 with three operator sets (Table V): vanilla
//!   (standard conv + ReLU/softmax), CNN/HSC (SC-PwMM conv + exact
//!   activations), CNN/SMURF (SC-PwMM conv + SMURF activations).
//! - [`sc_ops`] — the stochastic operators: SC-PwMM multiplication
//!   (128-bit streams, exact bit-level or exact-distribution binomial
//!   sampling), SMURF activation evaluation. Both bit-faithful paths are
//!   layer-granular through the wide engine: `Exact`-mode conv/dense
//!   products batch up to `MAX_LANES` per bit-plane pass
//!   ([`crate::sc::pwmm_wide`], product-for-product bit-identical to the
//!   scalar path), and SMURF activations batch per layer
//!   ([`sc_ops::SmurfActivation::eval_bitlevel_batch`]).
//! - [`hartley`] — the Hartley-transform path: cas-kernel computed by
//!   SMURF (`sin(x₁)cos(x₂)` per Eq. 14–15) vs LUT (CNN/HSC).
//! - [`train`] — SGD training of the f32 reference network in rust
//!   (the L2 JAX path exports `artifacts/lenet_weights.json`; this
//!   in-repo trainer keeps Table IV reproducible without Python).

pub mod hartley;
pub mod layers;
pub mod lenet;
pub mod sc_ops;
pub mod tensor;
pub mod train;

pub use lenet::{ActFidelity, LeNet, OpSet};
pub use tensor::Tensor;

//! f32 reference layers (the "vanilla CNN" column of Table V), plus the
//! layer-granular SC entry points shared with the SC-PwMM forward passes
//! (the conv tap geometry in [`for_each_valid_tap`], the batched SMURF
//! activation in [`smurf_activate_inplace`]).

use super::tensor::Tensor;

/// Visit every in-bounds kernel tap of one output pixel `(oy, ox)` of a
/// stride-1, symmetrically-zero-padded convolution over an `h × w` input:
/// calls `f(ky, kx, iy, ix)` with the kernel coordinate and the *unpadded*
/// input coordinate, in `ky`-major order, skipping taps that fall in the
/// padding. This is the single definition of the tap geometry — the f32
/// reference conv accumulates through it and the SC-PwMM conv gathers its
/// per-pixel operand pairs through it, so the two walk products in
/// exactly the same order (which the SC `Exact` seed discipline makes
/// load-bearing).
#[inline]
// justification: conv geometry is 7 scalars + the visitor; a geometry
// struct would be built and destructured at every call site for no gain.
#[allow(clippy::too_many_arguments)]
pub fn for_each_valid_tap(
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    pad: usize,
    oy: usize,
    ox: usize,
    mut f: impl FnMut(usize, usize, usize, usize),
) {
    for ky in 0..kh {
        let iy = oy + ky;
        if iy < pad || iy - pad >= h {
            continue;
        }
        for kx in 0..kw {
            let ix = ox + kx;
            if ix < pad || ix - pad >= w {
                continue;
            }
            f(ky, kx, iy - pad, ix - pad);
        }
    }
}

/// 2-D convolution, NCHW, stride 1, symmetric zero padding.
/// `weight` is `[out_c, in_c, kh, kw]`, `bias` is `[out_c]`.
pub fn conv2d(x: &Tensor, weight: &Tensor, bias: &[f32], pad: usize) -> Tensor {
    let (n, in_c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (out_c, wc, kh, kw) = (weight.dims[0], weight.dims[1], weight.dims[2], weight.dims[3]);
    assert_eq!(in_c, wc, "channel mismatch");
    assert_eq!(bias.len(), out_c);
    let oh = h + 2 * pad - kh + 1;
    let ow = w + 2 * pad - kw + 1;
    let mut y = Tensor::zeros(&[n, out_c, oh, ow]);
    for b in 0..n {
        for oc in 0..out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = bias[oc];
                    for ic in 0..in_c {
                        for_each_valid_tap(h, w, kh, kw, pad, oy, ox, |ky, kx, iy, ix| {
                            acc += x.at4(b, ic, iy, ix) * weight.at4(oc, ic, ky, kx);
                        });
                    }
                    *y.at4_mut(b, oc, oy, ox) = acc;
                }
            }
        }
    }
    y
}

/// 2×2 average pooling, stride 2.
pub fn avgpool2(x: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut y = Tensor::zeros(&[n, c, oh, ow]);
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let s = x.at4(b, ch, 2 * oy, 2 * ox)
                        + x.at4(b, ch, 2 * oy, 2 * ox + 1)
                        + x.at4(b, ch, 2 * oy + 1, 2 * ox)
                        + x.at4(b, ch, 2 * oy + 1, 2 * ox + 1);
                    *y.at4_mut(b, ch, oy, ox) = s * 0.25;
                }
            }
        }
    }
    y
}

/// Dense layer: `y = W x + b`, `w` is `[out, in]` row-major.
pub fn dense(x: &[f32], w: &Tensor, b: &[f32]) -> Vec<f32> {
    let (out, inn) = (w.dims[0], w.dims[1]);
    assert_eq!(x.len(), inn, "dense input mismatch");
    assert_eq!(b.len(), out);
    let mut y = vec![0.0f32; out];
    for o in 0..out {
        let row = &w.data[o * inn..(o + 1) * inn];
        let mut acc = b[o];
        for (xi, wi) in x.iter().zip(row) {
            acc += xi * wi;
        }
        y[o] = acc;
    }
    y
}

/// Elementwise tanh.
pub fn tanh_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.tanh();
    }
}

/// SMURF bit-level activation of a whole layer in place: the slice goes
/// through [`super::sc_ops::SmurfActivation::eval_bitlevel_inplace`], which
/// runs 64 activations per bit-plane pass of the wide engine with zero
/// heap allocation — element-for-element bit-identical to calling
/// `eval_bitlevel` per neuron, at a fraction of the cost. This is the
/// layer-granularity entry the SC forward passes ([`super::lenet`]) use
/// instead of per-neuron simulation.
pub fn smurf_activate_inplace(xs: &mut [f32], act: &super::sc_ops::SmurfActivation) {
    act.eval_bitlevel_inplace(xs);
}

/// Elementwise ReLU.
pub fn relu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Numerically-stable softmax.
pub fn softmax(x: &[f32]) -> Vec<f32> {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = x.iter().map(|&v| (v - m).exp()).collect();
    let z: f32 = e.iter().sum();
    e.iter().map(|&v| v / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1×1 kernel of weight 1 reproduces the input.
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = conv2d(&x, &w, &[0.0], 0);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn conv_known_sum() {
        // 2×2 all-ones kernel over a 2×2 input (no pad) = sum of elements.
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0; 4]);
        let y = conv2d(&x, &w, &[0.5], 0);
        assert_eq!(y.dims, vec![1, 1, 1, 1]);
        assert_eq!(y.data[0], 10.5);
    }

    #[test]
    fn conv_padding_shape() {
        let x = Tensor::zeros(&[2, 3, 28, 28]);
        let w = Tensor::zeros(&[6, 3, 5, 5]);
        let y = conv2d(&x, &w, &[0.0; 6], 2);
        assert_eq!(y.dims, vec![2, 6, 28, 28]);
    }

    #[test]
    fn conv_multichannel_accumulates() {
        let x = Tensor::from_vec(&[1, 2, 1, 1], vec![2.0, 3.0]);
        let w = Tensor::from_vec(&[1, 2, 1, 1], vec![10.0, 100.0]);
        let y = conv2d(&x, &w, &[0.0], 0);
        assert_eq!(y.data[0], 320.0);
    }

    #[test]
    fn valid_tap_geometry() {
        // 5×5 kernel, pad 2 over 28×28: a corner output pixel sees only
        // the 3×3 in-bounds taps, an interior pixel all 25.
        let mut corner = Vec::new();
        for_each_valid_tap(28, 28, 5, 5, 2, 0, 0, |ky, kx, iy, ix| {
            corner.push((ky, kx, iy, ix));
        });
        assert_eq!(corner.len(), 9);
        assert_eq!(corner[0], (2, 2, 0, 0));
        let mut interior = 0;
        for_each_valid_tap(28, 28, 5, 5, 2, 14, 14, |_, _, _, _| interior += 1);
        assert_eq!(interior, 25);
        // No padding: every tap valid, input coords offset by the output.
        let mut plain = Vec::new();
        for_each_valid_tap(8, 8, 3, 3, 0, 2, 5, |ky, kx, iy, ix| {
            plain.push((ky, kx, iy, ix));
        });
        assert_eq!(plain.len(), 9);
        assert_eq!(plain[0], (0, 0, 2, 5));
        assert_eq!(plain[8], (2, 2, 4, 7));
    }

    #[test]
    fn avgpool_means() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 3.0, 5.0, 7.0]);
        let y = avgpool2(&x);
        assert_eq!(y.data, vec![4.0]);
    }

    #[test]
    fn dense_known() {
        let w = Tensor::from_vec(&[2, 3], vec![1.0, 0.0, 0.0, 0.0, 2.0, 0.0]);
        let y = dense(&[5.0, 6.0, 7.0], &w, &[0.1, 0.2]);
        assert!((y[0] - 5.1).abs() < 1e-6);
        assert!((y[1] - 12.2).abs() < 1e-6);
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let y = softmax(&[1000.0, 1001.0, 999.0]);
        assert!((y.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(y[1] > y[0] && y[0] > y[2]);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn smurf_activate_matches_per_neuron_bitlevel() {
        use super::super::sc_ops::SmurfActivation;
        let layer_act = SmurfActivation::tanh(64, 4);
        let neuron_act = SmurfActivation::tanh(64, 4);
        // 70 elements: one full wide word + tail.
        let mut xs: Vec<f32> = (0..70).map(|i| i as f32 / 10.0 - 3.5).collect();
        let want: Vec<f32> = xs.iter().map(|&x| neuron_act.eval_bitlevel(x)).collect();
        smurf_activate_inplace(&mut xs, &layer_act);
        assert_eq!(xs, want);
    }

    #[test]
    fn activations() {
        let mut x = vec![-1.0, 0.0, 1.0];
        relu_inplace(&mut x);
        assert_eq!(x, vec![0.0, 0.0, 1.0]);
        let mut t = vec![0.0f32];
        tanh_inplace(&mut t);
        assert_eq!(t, vec![0.0]);
    }
}

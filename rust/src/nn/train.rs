//! In-rust SGD training of the f32 LeNet-5 reference.
//!
//! Full backprop through conv/pool/dense/tanh with cross-entropy loss.
//! This keeps Table IV reproducible from the rust binary alone; the L2
//! JAX path (python/compile/train.py) is the primary trainer and exports
//! the same weight format.

use super::layers;
use super::lenet::LeNet;
use super::tensor::Tensor;
use crate::data::Dataset;
use crate::util::prng::Pcg;

/// Training hyper-parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub epochs: usize,
    pub lr: f32,
    pub momentum: f32,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { epochs: 4, lr: 0.05, momentum: 0.9, log_every: 0 }
    }
}

/// Intermediate activations kept for backprop.
struct Trace {
    x: Tensor,
    c1: Tensor,
    a1: Tensor,
    p1: Tensor,
    c2: Tensor,
    a2: Tensor,
    p2: Tensor,
    f1: Vec<f32>,
    t1: Vec<f32>,
    f2: Vec<f32>,
    t2: Vec<f32>,
    probs: Vec<f32>,
}

fn forward_trace(net: &LeNet, image: &[f32]) -> Trace {
    let x = Tensor::from_vec(&[1, 1, 28, 28], image.to_vec());
    let c1 = layers::conv2d(&x, &net.conv1_w, &net.conv1_b, 2);
    let mut a1 = c1.clone();
    layers::tanh_inplace(&mut a1.data);
    let p1 = layers::avgpool2(&a1);
    let c2 = layers::conv2d(&p1, &net.conv2_w, &net.conv2_b, 0);
    let mut a2 = c2.clone();
    layers::tanh_inplace(&mut a2.data);
    let p2 = layers::avgpool2(&a2);
    let f1 = layers::dense(&p2.data, &net.fc1_w, &net.fc1_b);
    let mut t1 = f1.clone();
    layers::tanh_inplace(&mut t1);
    let f2 = layers::dense(&t1, &net.fc2_w, &net.fc2_b);
    let mut t2 = f2.clone();
    layers::tanh_inplace(&mut t2);
    let probs = layers::softmax(&layers::dense(&t2, &net.fc3_w, &net.fc3_b));
    Trace { x, c1, a1, p1, c2, a2, p2, f1, t1, f2, t2, probs }
}

/// Gradient accumulator with the same shapes as the network.
struct Grads {
    conv1_w: Vec<f32>,
    conv1_b: Vec<f32>,
    conv2_w: Vec<f32>,
    conv2_b: Vec<f32>,
    fc1_w: Vec<f32>,
    fc1_b: Vec<f32>,
    fc2_w: Vec<f32>,
    fc2_b: Vec<f32>,
    fc3_w: Vec<f32>,
    fc3_b: Vec<f32>,
}

impl Grads {
    fn zero(net: &LeNet) -> Self {
        Self {
            conv1_w: vec![0.0; net.conv1_w.len()],
            conv1_b: vec![0.0; 6],
            conv2_w: vec![0.0; net.conv2_w.len()],
            conv2_b: vec![0.0; 16],
            fc1_w: vec![0.0; net.fc1_w.len()],
            fc1_b: vec![0.0; 120],
            fc2_w: vec![0.0; net.fc2_w.len()],
            fc2_b: vec![0.0; 84],
            fc3_w: vec![0.0; net.fc3_w.len()],
            fc3_b: vec![0.0; 10],
        }
    }
}

/// Dense backward: given dL/dy, fill dW, db and return dL/dx.
fn dense_backward(
    x: &[f32],
    w: &Tensor,
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
) -> Vec<f32> {
    let (out, inn) = (w.dims[0], w.dims[1]);
    let mut dx = vec![0.0f32; inn];
    for o in 0..out {
        db[o] += dy[o];
        let row = &w.data[o * inn..(o + 1) * inn];
        let drow = &mut dw[o * inn..(o + 1) * inn];
        for i in 0..inn {
            drow[i] += dy[o] * x[i];
            dx[i] += dy[o] * row[i];
        }
    }
    dx
}

/// tanh backward (elementwise): dL/dx = dL/dy · (1 - tanh²).
fn tanh_backward(pre: &[f32], dy: &[f32]) -> Vec<f32> {
    pre.iter().zip(dy).map(|(&p, &d)| d * (1.0 - p.tanh().powi(2))).collect()
}

/// avgpool2 backward: spread gradient equally over the 2×2 window.
fn avgpool2_backward(dy: &Tensor, in_dims: &[usize]) -> Tensor {
    let mut dx = Tensor::zeros(in_dims);
    let (n, c, oh, ow) = (dy.dims[0], dy.dims[1], dy.dims[2], dy.dims[3]);
    for b in 0..n {
        for ch in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dy.at4(b, ch, oy, ox) * 0.25;
                    for dyy in 0..2 {
                        for dxx in 0..2 {
                            *dx.at4_mut(b, ch, 2 * oy + dyy, 2 * ox + dxx) += g;
                        }
                    }
                }
            }
        }
    }
    dx
}

/// conv2d backward: returns dL/dx; accumulates dW, db.
fn conv2d_backward(
    x: &Tensor,
    weight: &Tensor,
    dy: &Tensor,
    pad: usize,
    dw: &mut [f32],
    db: &mut [f32],
) -> Tensor {
    let (n, in_c, h, w) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (out_c, _, kh, kw) = (weight.dims[0], weight.dims[1], weight.dims[2], weight.dims[3]);
    let (oh, ow) = (dy.dims[2], dy.dims[3]);
    let mut dx = Tensor::zeros(&[n, in_c, h, w]);
    for b in 0..n {
        for oc in 0..out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let g = dy.at4(b, oc, oy, ox);
                    if g == 0.0 {
                        continue;
                    }
                    db[oc] += g;
                    for ic in 0..in_c {
                        for ky in 0..kh {
                            let iy = oy + ky;
                            if iy < pad || iy - pad >= h {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = ox + kx;
                                if ix < pad || ix - pad >= w {
                                    continue;
                                }
                                let xi = x.at4(b, ic, iy - pad, ix - pad);
                                dw[((oc * in_c + ic) * kh + ky) * kw + kx] += g * xi;
                                *dx.at4_mut(b, ic, iy - pad, ix - pad) +=
                                    g * weight.at4(oc, ic, ky, kx);
                            }
                        }
                    }
                }
            }
        }
    }
    dx
}

/// One sample's backward pass; returns the cross-entropy loss.
fn backward(net: &LeNet, tr: &Trace, label: u8, g: &mut Grads) -> f32 {
    // dL/dlogits = probs - onehot.
    let mut dlogits = tr.probs.clone();
    dlogits[label as usize] -= 1.0;
    let loss = -tr.probs[label as usize].max(1e-12).ln();

    let dt2 = dense_backward(&tr.t2, &net.fc3_w, &dlogits, &mut g.fc3_w, &mut g.fc3_b);
    let df2 = tanh_backward(&tr.f2, &dt2);
    let dt1 = dense_backward(&tr.t1, &net.fc2_w, &df2, &mut g.fc2_w, &mut g.fc2_b);
    let df1 = tanh_backward(&tr.f1, &dt1);
    let dp2_flat = dense_backward(&tr.p2.data, &net.fc1_w, &df1, &mut g.fc1_w, &mut g.fc1_b);
    let dp2 = Tensor::from_vec(&tr.p2.dims, dp2_flat);
    let da2 = avgpool2_backward(&dp2, &tr.a2.dims);
    let dc2 = Tensor::from_vec(
        &tr.c2.dims,
        tanh_backward(&tr.c2.data, &da2.data),
    );
    let dp1 = conv2d_backward(&tr.p1, &net.conv2_w, &dc2, 0, &mut g.conv2_w, &mut g.conv2_b);
    let da1 = avgpool2_backward(&dp1, &tr.a1.dims);
    let dc1 = Tensor::from_vec(
        &tr.c1.dims,
        tanh_backward(&tr.c1.data, &da1.data),
    );
    let _ = conv2d_backward(&tr.x, &net.conv1_w, &dc1, 2, &mut g.conv1_w, &mut g.conv1_b);
    loss
}

/// Train with minibatch SGD + momentum; returns per-epoch mean losses.
pub fn train(net: &mut LeNet, data: &Dataset, cfg: &TrainConfig, seed: u64) -> Vec<f32> {
    let mut rng = Pcg::new(seed);
    let mut vel = Grads::zero(net);
    // zero-init velocity: reuse Grads as the velocity buffers
    for v in [
        &mut vel.conv1_w,
        &mut vel.conv1_b,
        &mut vel.conv2_w,
        &mut vel.conv2_b,
        &mut vel.fc1_w,
        &mut vel.fc1_b,
        &mut vel.fc2_w,
        &mut vel.fc2_b,
        &mut vel.fc3_w,
        &mut vel.fc3_b,
    ] {
        v.iter_mut().for_each(|x| *x = 0.0);
    }
    const BATCH: usize = 16;
    let mut losses = Vec::new();
    let mut order: Vec<usize> = (0..data.n).collect();
    for epoch in 0..cfg.epochs {
        rng.shuffle(&mut order);
        let mut epoch_loss = 0.0f32;
        let mut batches = 0;
        for chunk in order.chunks(BATCH) {
            let mut g = Grads::zero(net);
            let mut batch_loss = 0.0;
            for &i in chunk {
                let tr = forward_trace(net, data.image(i));
                batch_loss += backward(net, &tr, data.labels[i], &mut g);
            }
            let inv = 1.0 / chunk.len() as f32;
            epoch_loss += batch_loss * inv;
            batches += 1;
            // SGD + momentum update.
            let step = |w: &mut [f32], gw: &[f32], v: &mut [f32]| {
                for ((wi, &gi), vi) in w.iter_mut().zip(gw).zip(v.iter_mut()) {
                    *vi = cfg.momentum * *vi - cfg.lr * gi * inv;
                    *wi += *vi;
                }
            };
            step(&mut net.conv1_w.data, &g.conv1_w, &mut vel.conv1_w);
            step(&mut net.conv1_b, &g.conv1_b, &mut vel.conv1_b);
            step(&mut net.conv2_w.data, &g.conv2_w, &mut vel.conv2_w);
            step(&mut net.conv2_b, &g.conv2_b, &mut vel.conv2_b);
            step(&mut net.fc1_w.data, &g.fc1_w, &mut vel.fc1_w);
            step(&mut net.fc1_b, &g.fc1_b, &mut vel.fc1_b);
            step(&mut net.fc2_w.data, &g.fc2_w, &mut vel.fc2_w);
            step(&mut net.fc2_b, &g.fc2_b, &mut vel.fc2_b);
            step(&mut net.fc3_w.data, &g.fc3_w, &mut vel.fc3_w);
            step(&mut net.fc3_b, &g.fc3_b, &mut vel.fc3_b);
        }
        let mean = epoch_loss / batches as f32;
        losses.push(mean);
        if cfg.log_every > 0 && epoch % cfg.log_every == 0 {
            eprintln!("epoch {epoch}: loss {mean:.4}");
        }
    }
    losses
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;
    use crate::nn::lenet::OpSet;

    #[test]
    fn gradcheck_dense() {
        // Numerical gradient check on fc3 weights through the full loss.
        let mut net = LeNet::random(11);
        let img: Vec<f32> = (0..784).map(|i| ((i % 7) as f32) / 7.0).collect();
        let label = 3u8;
        let mut g = Grads::zero(&net);
        let tr = forward_trace(&net, &img);
        backward(&net, &tr, label, &mut g);
        // Perturb a few fc3 weights.
        let eps = 1e-3f32;
        for &k in &[0usize, 17, 100, 839] {
            let orig = net.fc3_w.data[k];
            net.fc3_w.data[k] = orig + eps;
            let lp = -forward_trace(&net, &img).probs[label as usize].max(1e-12).ln();
            net.fc3_w.data[k] = orig - eps;
            let lm = -forward_trace(&net, &img).probs[label as usize].max(1e-12).ln();
            net.fc3_w.data[k] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g.fc3_w[k]).abs() < 2e-2_f32.max(0.15 * num.abs()),
                "fc3_w[{k}]: numeric {num} vs backprop {}",
                g.fc3_w[k]
            );
        }
    }

    #[test]
    fn gradcheck_conv1() {
        let mut net = LeNet::random(12);
        let img: Vec<f32> = (0..784).map(|i| ((i % 5) as f32) / 5.0).collect();
        let label = 1u8;
        let mut g = Grads::zero(&net);
        let tr = forward_trace(&net, &img);
        backward(&net, &tr, label, &mut g);
        let eps = 1e-3f32;
        for &k in &[0usize, 31, 88] {
            let orig = net.conv1_w.data[k];
            net.conv1_w.data[k] = orig + eps;
            let lp = -forward_trace(&net, &img).probs[label as usize].max(1e-12).ln();
            net.conv1_w.data[k] = orig - eps;
            let lm = -forward_trace(&net, &img).probs[label as usize].max(1e-12).ln();
            net.conv1_w.data[k] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g.conv1_w[k]).abs() < 2e-2_f32.max(0.15 * num.abs()),
                "conv1_w[{k}]: numeric {num} vs backprop {}",
                g.conv1_w[k]
            );
        }
    }

    #[test]
    fn loss_decreases_on_tiny_corpus() {
        let mut net = LeNet::random(13);
        let data = synth_mnist::generate(60, 21);
        let cfg = TrainConfig { epochs: 3, lr: 0.05, momentum: 0.9, log_every: 0 };
        let losses = train(&mut net, &data, &cfg, 5);
        assert!(
            losses.last().unwrap() < losses.first().unwrap(),
            "losses: {losses:?}"
        );
    }

    #[test]
    #[ignore] // ~40 s: full Table IV-style training run; exercised by the bench
    fn trains_to_high_accuracy() {
        let mut net = LeNet::random(14);
        let train_set = synth_mnist::generate(2000, 31);
        let test_set = synth_mnist::generate(400, 32);
        let cfg = TrainConfig::default();
        train(&mut net, &train_set, &cfg, 6);
        let acc = net.accuracy(&test_set.images, &test_set.labels, OpSet::Vanilla, None);
        assert!(acc > 0.9, "vanilla accuracy {acc}");
    }
}

//! Minimal dense f32 tensor (row-major, NCHW for images).

/// A dense row-major tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(dims: &[usize]) -> Self {
        let n = dims.iter().product();
        Self { dims: dims.to_vec(), data: vec![0.0; n] }
    }

    pub fn from_vec(dims: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(dims.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Self { dims: dims.to_vec(), data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// 4-D accessor (NCHW).
    #[inline]
    pub fn at4(&self, n: usize, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 4);
        let (_, cc, hh, ww) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        self.data[((n * cc + c) * hh + h) * ww + w]
    }

    #[inline]
    pub fn at4_mut(&mut self, n: usize, c: usize, h: usize, w: usize) -> &mut f32 {
        debug_assert_eq!(self.dims.len(), 4);
        let (_, cc, hh, ww) = (self.dims[0], self.dims[1], self.dims[2], self.dims[3]);
        &mut self.data[((n * cc + c) * hh + h) * ww + w]
    }

    /// 2-D accessor.
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.dims.len(), 2);
        self.data[i * self.dims[1] + j]
    }

    /// Reshape (must conserve element count).
    pub fn reshape(mut self, dims: &[usize]) -> Self {
        assert_eq!(dims.iter().product::<usize>(), self.data.len());
        self.dims = dims.to_vec();
        self
    }

    /// Index of the maximum element (argmax over the flat data).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4, 5]);
        assert_eq!(t.len(), 120);
        assert_eq!(t.at4(1, 2, 3, 4), 0.0);
    }

    #[test]
    fn at4_layout_is_nchw() {
        let mut t = Tensor::zeros(&[1, 2, 2, 2]);
        *t.at4_mut(0, 1, 0, 1) = 7.0;
        // offset = ((0*2+1)*2+0)*2+1 = 5
        assert_eq!(t.data[5], 7.0);
        assert_eq!(t.at4(0, 1, 0, 1), 7.0);
    }

    #[test]
    fn reshape_conserves() {
        let t = Tensor::from_vec(&[2, 3], vec![0.0; 6]).reshape(&[3, 2]);
        assert_eq!(t.dims, vec![3, 2]);
    }

    #[test]
    #[should_panic]
    fn reshape_rejects_bad_count() {
        Tensor::zeros(&[2, 3]).reshape(&[4, 2]);
    }

    #[test]
    fn argmax_basic() {
        let t = Tensor::from_vec(&[4], vec![0.1, 0.9, 0.3, 0.2]);
        assert_eq!(t.argmax(), 1);
    }
}

//! `smurf` — CLI for the SMURF evaluation system.
//!
//! Subcommands:
//!   synth <function> [--radix N]                synthesize + print w table
//!   eval <function> <x1> <x2> …  [--len L]      bit-level evaluation
//!   serve [--requests N]                        run the evaluation service
//!   train [--epochs E] [--samples N]            train LeNet-5 (rust path)
//!   hw                                          print the Table VI cost model
//!   info                                        environment report

use smurf::baselines::{lut::Lut, taylor::TaylorPoly};
use smurf::coordinator::{Engine, EvalServer, ServerConfig};
use smurf::data;
use smurf::hw;
use smurf::nn::{lenet::ScRuntime, train, LeNet, OpSet};
use smurf::prelude::*;
use smurf::runtime::{default_artifacts_dir, Runtime};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    let code = match cmd {
        "synth" => cmd_synth(rest),
        "eval" => cmd_eval(rest),
        "serve" => cmd_serve(rest),
        "train" => cmd_train(rest),
        "hw" => cmd_hw(),
        "info" => cmd_info(),
        _ => {
            eprintln!(
                "usage: smurf <synth|eval|serve|train|hw|info> [args]\n\
                 functions: {}",
                functions::registry()
                    .iter()
                    .map(|f| f.name().to_string())
                    .collect::<Vec<_>>()
                    .join(", ")
            );
            if cmd == "help" {
                0
            } else {
                2
            }
        }
    };
    std::process::exit(code);
}

/// Tiny flag parser: `--key value` pairs after positional args.
fn flag(args: &[String], key: &str, default: usize) -> usize {
    args.windows(2)
        .find(|w| w[0] == key)
        .and_then(|w| w[1].parse().ok())
        .unwrap_or(default)
}

fn cmd_synth(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("synth: missing function name");
        return 2;
    };
    let Some(f) = functions::by_name(name) else {
        eprintln!("synth: unknown function {name}");
        return 2;
    };
    let n = flag(args, "--radix", 4);
    let cfg = SmurfConfig::uniform(f.arity(), n);
    let res = synthesize(&cfg, &f, &SynthOptions::default());
    println!("function: {name}   config: {cfg}");
    println!(
        "analytic MAE: {:.5}   L2: {:.5}   QP iters: {}",
        res.mae, res.l2_error, res.qp.iterations
    );
    for (t, w) in res.smurf.coefficients().iter().enumerate() {
        print!("w_{t} = {w:.4}  ");
        if (t + 1) % cfg.radix(0) == 0 {
            println!();
        }
    }
    0
}

fn cmd_eval(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("eval: missing function name");
        return 2;
    };
    let Some(f) = functions::by_name(name) else {
        eprintln!("eval: unknown function {name}");
        return 2;
    };
    let xs: Vec<f64> = args[1..]
        .iter()
        .take_while(|a| !a.starts_with("--"))
        .filter_map(|a| a.parse().ok())
        .collect();
    if xs.len() != f.arity() {
        eprintln!("eval: {} needs {} inputs", name, f.arity());
        return 2;
    }
    let len = flag(args, "--len", 64);
    let cfg = SmurfConfig::uniform(f.arity(), 4);
    let approx = SmurfApproximator::synthesize(&cfg, &f, len);
    let exact = f.eval(&xs);
    let analytic = approx.eval_analytic(&xs);
    let hw = approx.eval_bitstream(&xs, len, 0xC0FFEE);
    println!("target     f(x) = {exact:.5}");
    println!("analytic   P_y  = {analytic:.5}  (err {:+.5})", analytic - exact);
    println!("bit-level  P_y  = {hw:.5}  (err {:+.5}, L={len})", hw - exact);
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    let n_requests = flag(args, "--requests", 10_000);
    let cfg = SmurfConfig::uniform(2, 4);
    let funcs = vec![
        SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::sincos(), 64),
        SmurfApproximator::synthesize(&cfg, &functions::softmax2(), 64),
    ];
    let server = EvalServer::start(funcs, Some(default_artifacts_dir()), ServerConfig::default());
    println!("serving {:?}; driving {n_requests} requests…", server.functions());
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let x = (i % 100) as f64 / 99.0;
        let y = ((i * 37) % 100) as f64 / 99.0;
        let engine = if i % 3 == 0 { Engine::BitLevel } else { Engine::Analytic };
        let r = server.eval_sync("euclidean2", vec![vec![x, y]], engine, 64);
        if !r.is_ok() {
            eprintln!("request {i} failed: {:?}", r.error);
            return 1;
        }
    }
    let dt = t0.elapsed();
    println!("{}", server.metrics().report());
    println!("drove {n_requests} sync requests in {dt:?}");
    server.shutdown();
    0
}

fn cmd_train(args: &[String]) -> i32 {
    let epochs = flag(args, "--epochs", 4);
    let samples = flag(args, "--samples", 2000);
    let (train_set, test_set) = data::load_corpus(samples, samples / 5, 42);
    let mut net = LeNet::random(7);
    let cfg = train::TrainConfig { epochs, lr: 0.05, momentum: 0.9, log_every: 1 };
    let losses = train::train(&mut net, &train_set, &cfg, 1);
    println!("losses: {losses:?}");
    let acc = net.accuracy(&test_set.images, &test_set.labels, OpSet::Vanilla, None);
    println!("vanilla accuracy: {:.2}%", acc * 100.0);
    let mut rt = ScRuntime::paper_config(3);
    let acc_smurf =
        net.accuracy(&test_set.images, &test_set.labels, OpSet::Smurf, Some(&mut rt));
    println!("CNN/SMURF accuracy: {:.2}%", acc_smurf * 100.0);
    // Persist for the examples.
    let dir = default_artifacts_dir();
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join("lenet_weights.json");
    if std::fs::write(&path, net.to_json().dump()).is_ok() {
        println!("weights saved to {}", path.display());
    }
    0
}

fn cmd_hw() -> i32 {
    let f = functions::euclidean2();
    let s = hw::smurf_design(&SmurfConfig::uniform(2, 4));
    let t = hw::taylor_design(&TaylorPoly::expand(&f, &[0.5, 0.5], 3));
    let l = hw::lut_design(&Lut::build(&f, 8, 16));
    print!("{}", s.table());
    print!("{}", t.table());
    print!("{}", l.table());
    let (st, tt, lt) = (s.total(), t.total(), l.total());
    println!("\nSMURF/Taylor area  = {:.2}%  (paper 16.07%)", 100.0 * st.area_um2 / tt.area_um2);
    println!("SMURF/Taylor power = {:.2}%  (paper 14.45%)", 100.0 * st.power_mw / tt.power_mw);
    println!("SMURF/LUT area     = {:.2}%  (paper 2.22%)", 100.0 * st.area_um2 / lt.area_um2);
    0
}

fn cmd_info() -> i32 {
    println!("smurf {} — SMURF paper reproduction", env!("CARGO_PKG_VERSION"));
    println!("artifacts dir: {}", default_artifacts_dir().display());
    match Runtime::cpu(default_artifacts_dir()) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            for a in ["smurf_eval.hlo.txt", "lenet_infer.hlo.txt", "lenet_smurf_infer.hlo.txt"] {
                println!(
                    "  artifact {a}: {}",
                    if rt.has_artifact(a) { "present" } else { "MISSING (make artifacts)" }
                );
            }
        }
        Err(e) => println!("PJRT unavailable: {e}"),
    }
    0
}

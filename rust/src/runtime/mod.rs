//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them on
//! the request path (Python is build-time only).
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and python/compile/aot.py).
//!
//! The real PJRT client requires the `xla` and `anyhow` crates, which are
//! not vendored in this offline environment. The `xla` cargo feature
//! selects the real implementation; the default build gets a stub with the
//! same surface whose `cpu()` constructor reports the runtime as
//! unavailable, so the coordinator's XLA engine degrades to a clean error
//! response instead of a build failure.

use std::path::PathBuf;

#[cfg(feature = "xla")]
mod pjrt {
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    /// A compiled, ready-to-run XLA executable.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl Executable {
        /// Run on f32 buffers; returns the flattened f32 outputs of the
        /// (1-tuple) result. Inputs are (shape, data) pairs.
        pub fn run_f32(&self, inputs: &[(&[usize], &[f32])]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for (dims, data) in inputs {
                let lit = xla::Literal::vec1(data);
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                literals.push(lit.reshape(&dims_i64).context("reshape input")?);
            }
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("execute {}", self.name))?[0][0]
                .to_literal_sync()
                .context("fetch result")?;
            // aot.py lowers with return_tuple=True: unpack the tuple.
            let elems = result.to_tuple().context("untuple result")?;
            let mut out = Vec::with_capacity(elems.len());
            for e in elems {
                out.push(e.to_vec::<f32>().context("read output")?);
            }
            Ok(out)
        }

        pub fn name(&self) -> &str {
            &self.name
        }
    }

    /// PJRT CPU client + executable cache keyed by artifact path.
    pub struct Runtime {
        client: xla::PjRtClient,
        cache: Mutex<HashMap<PathBuf, std::sync::Arc<Executable>>>,
        artifacts_dir: PathBuf,
    }

    impl Runtime {
        /// Create a CPU runtime rooted at the artifacts directory.
        pub fn cpu(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
            Ok(Self {
                client,
                cache: Mutex::new(HashMap::new()),
                artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load + compile an HLO-text artifact (cached).
        pub fn load(&self, artifact: &str) -> Result<std::sync::Arc<Executable>> {
            let path = self.artifacts_dir.join(artifact);
            {
                let cache = self.cache.lock().unwrap();
                if let Some(e) = cache.get(&path) {
                    return Ok(e.clone());
                }
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))?;
            let entry = std::sync::Arc::new(Executable {
                exe,
                name: artifact.to_string(),
            });
            self.cache.lock().unwrap().insert(path, entry.clone());
            Ok(entry)
        }

        /// True if the artifact file exists (used to skip runtime-dependent
        /// paths when `make artifacts` has not run).
        pub fn has_artifact(&self, artifact: &str) -> bool {
            self.artifacts_dir.join(artifact).exists()
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    /// Stub of the PJRT executable handle; never constructed (the stub
    /// [`Runtime::cpu`] always fails), but keeps call sites type-checking.
    pub struct Executable {
        name: String,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[(&[usize], &[f32])]) -> Result<Vec<Vec<f32>>, String> {
            Err(format!(
                "{}: built without the `xla` feature; PJRT execution unavailable",
                self.name
            ))
        }

        pub fn name(&self) -> &str {
            &self.name
        }
    }

    /// Stub runtime: construction fails with an explanatory message so the
    /// coordinator's XLA engine returns a clean error response.
    pub struct Runtime {}

    impl Runtime {
        pub fn cpu(_artifacts_dir: impl AsRef<Path>) -> Result<Self, String> {
            Err("built without the `xla` feature: PJRT runtime unavailable \
                 (enable the feature and its dependencies in rust/Cargo.toml)"
                .into())
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }

        pub fn load(&self, artifact: &str) -> Result<std::sync::Arc<Executable>, String> {
            Err(format!("cannot load {artifact}: built without the `xla` feature"))
        }

        pub fn has_artifact(&self, _artifact: &str) -> bool {
            false
        }
    }
}

#[cfg(not(feature = "xla"))]
pub use stub::{Executable, Runtime};

/// Locate the artifacts directory relative to the repo root (works from
/// tests, benches and installed binaries via `SMURF_ARTIFACTS`).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SMURF_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dir_resolves() {
        let d = default_artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        let err = match Runtime::cpu(default_artifacts_dir()) {
            Ok(_) => panic!("stub Runtime::cpu must fail"),
            Err(e) => e,
        };
        assert!(err.contains("xla"), "unhelpful stub error: {err}");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn missing_artifact_detected() {
        let rt = Runtime::cpu(default_artifacts_dir());
        // PJRT CPU client creation must succeed in this environment.
        let rt = rt.expect("PJRT CPU client");
        assert!(!rt.has_artifact("definitely_not_there.hlo.txt"));
        assert!(rt.load("definitely_not_there.hlo.txt").is_err());
        assert_eq!(rt.platform(), "cpu");
    }

    #[cfg(feature = "xla")]
    #[test]
    fn loads_and_runs_artifact_if_present() {
        // Full AOT round-trip — only meaningful after `make artifacts`.
        let rt = Runtime::cpu(default_artifacts_dir()).expect("PJRT CPU client");
        if !rt.has_artifact("smurf_eval.hlo.txt") {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let exe = rt.load("smurf_eval.hlo.txt").unwrap();
        // smurf_eval: (batch=1024, 2) probabilities + (4,4) table -> (1024,).
        let batch = 1024;
        let xs: Vec<f32> = (0..batch * 2).map(|i| (i % 97) as f32 / 96.0).collect();
        let w: Vec<f32> = (0..16).map(|i| i as f32 / 15.0).collect();
        let out = exe.run_f32(&[(&[batch, 2], &xs), (&[4, 4], &w)]).unwrap();
        assert_eq!(out[0].len(), batch);
        for &y in &out[0] {
            assert!((0.0..=1.0).contains(&y), "y={y}");
        }
        // Cache hit second time.
        let exe2 = rt.load("smurf_eval.hlo.txt").unwrap();
        assert_eq!(exe.name(), exe2.name());
    }
}

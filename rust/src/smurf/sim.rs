//! Cycle-accurate bit-level SMURF simulator (paper Fig. 6).
//!
//! This is the behavioural model of the RTL the paper synthesized on SMIC
//! 65nm — every block in Fig. 6 has a direct counterpart:
//!
//! - M input θ-gates converting `P_{x_j}` to input bits `x_{b_j}`;
//! - M chained `N_j`-state FSMs clocked by those bits;
//! - the universal-radix codeword wired to the CPT MUX select;
//! - the CPT-gate's bank of `Π N_j` θ-gates holding the `w_t` thresholds;
//! - the single physical RNG branched into differently-delayed sequences
//!   feeding every θ-gate (§III-A);
//! - the output counter whose average is `P_y`.

use super::analytic::AnalyticSmurf;
use super::config::SmurfConfig;
use super::sim_wide::{
    with_thread_scratch, MaxPlane, ThreadScratch, WideBitLevelSmurf, LANES,
};
use crate::fsm::chain::ChainFsm;
use crate::sc::cpt::CptGate;
use crate::sc::fault::{BitFaultPlan, NoFaults, ScalarFaultHook};
use crate::sc::rng::{Lfsr16, Sobol, StreamRng, XorShift64};
use crate::sc::sng::ThetaGate;
use std::sync::OnceLock;

/// Entropy wiring choice for the simulator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntropyMode {
    /// Hardware-faithful: one 16-bit LFSR, delayed branches (§III-A).
    /// The delay between consecutive branches is fixed at 17 cycles
    /// (coprime with the 2^16-1 LFSR period).
    SharedLfsr,
    /// Software-quality: independent xorshift64* per θ-gate. Removes LFSR
    /// correlation artifacts; used to separate architecture error from
    /// entropy-source error in the accuracy sweeps.
    IndependentXorshift,
    /// LFSR input θ-gates + a Sobol (van der Corput) sequence at the
    /// CPT-gate. §II-B: "A θ-gate can also sample complex probability
    /// distributions such as the Sobol sequences" — low-discrepancy
    /// output sampling turns the O(1/√L) bitstream-mean error into
    /// O(1/L), which is what the paper's 64-bit accuracy figures
    /// (e.g. softmax2 MAE ≈ 0.014, Fig. 10c) require. Hardware cost: a
    /// counter with bit-reversed output instead of one LFSR branch.
    SobolCpt,
}

/// Bit-level SMURF instance.
#[derive(Clone, Debug)]
pub struct BitLevelSmurf {
    cfg: SmurfConfig,
    cpt: CptGate,
    mode: EntropyMode,
    /// Mixed-radix codeword strides, hoisted out of the per-eval hot path.
    strides: Vec<usize>,
    /// Lazily-built bit-sliced companion engine, shared by every
    /// multi-trial estimator call on this instance (previously rebuilt
    /// per `eval_avg`/`abs_error` call — the ROADMAP "amortize `eval_avg`
    /// engine construction" item). Runs at the widest plane compiled into
    /// the build ([`MaxPlane`]: 256 lanes, or 512 with `wide512`) — the
    /// result is bit-identical at every width, only throughput changes.
    wide: OnceLock<WideBitLevelSmurf<MaxPlane>>,
    /// 64-lane (`u64`-plane) companion for jobs of ≤ [`LANES`] lanes,
    /// where the widest plane's extra words would all idle (the
    /// `WIDE_*_MIN` thresholds were tuned against the 64-lane pass
    /// cost). Same streams bit-exactly — routing never changes results.
    wide64: OnceLock<WideBitLevelSmurf<u64>>,
    /// Optional bit-level fault plan ([`crate::sc::fault`]). `None` (the
    /// default) runs the clean monomorphized pipeline with zero fault
    /// branches; `Some` runs the hooked pipeline — which at all-zero
    /// rates is still bit-identical to clean (property-tested), because
    /// a zero-rate site never draws fault entropy.
    faults: Option<BitFaultPlan>,
}

/// Trial count at or above which the batch estimators route through the
/// bit-sliced wide engine ([`crate::smurf::sim_wide::WideBitLevelSmurf`]).
/// Below this the fixed 64-lane word cost is not amortized.
pub const WIDE_TRIALS_MIN: usize = 8;

/// Which estimator a routed wide job runs (see
/// [`BitLevelSmurf::eval_avg`] / [`BitLevelSmurf::abs_error`]).
#[derive(Clone, Copy)]
enum EstimatorOp {
    Avg,
    AbsError(f64),
}

/// Run one estimator op on a wide engine of any plane width, on that
/// width's thread scratch.
fn run_estimator<P: ThreadScratch>(
    wide: &WideBitLevelSmurf<P>,
    p: &[f64],
    len: usize,
    trials: usize,
    seed: u64,
    op: EstimatorOp,
) -> f64 {
    with_thread_scratch(|st| match op {
        EstimatorOp::Avg => wide.eval_avg(p, len, trials, seed, st),
        EstimatorOp::AbsError(target) => wide.abs_error(p, target, len, trials, seed, st),
    })
}

/// Devirtualized entropy source (§Perf: the simulator ticks every θ-gate
/// every cycle, so `Box<dyn StreamRng>` indirect calls were ~20% of the
/// hot loop; a small enum lets the match inline).
#[derive(Clone, Debug)]
enum RngKind {
    Lfsr(Lfsr16),
    Xor(XorShift64),
    Sobol(Sobol),
}

impl RngKind {
    #[inline(always)]
    fn next_u16(&mut self) -> u16 {
        match self {
            RngKind::Lfsr(r) => r.next_u16(),
            RngKind::Xor(r) => r.next_u16(),
            RngKind::Sobol(r) => r.next_u16(),
        }
    }
}

/// Per-run simulator state (FSMs + entropy sources), so one `BitLevelSmurf`
/// can be reused across evaluations/threads. Fixed-capacity arrays keep
/// `eval` allocation-free for every paper configuration (M ≤ 8).
struct RunState {
    fsms: Vec<ChainFsm>,
    /// Entropy for the M input θ-gates.
    input_rngs: Vec<RngKind>,
    /// Entropy for the CPT-gate output sampling.
    cpt_rng: RngKind,
}

impl BitLevelSmurf {
    pub fn new(cfg: SmurfConfig, w: &[f64], mode: EntropyMode) -> Self {
        assert_eq!(w.len(), cfg.num_aggregate_states());
        let strides = cfg.strides();
        Self {
            cfg,
            cpt: CptGate::new(w),
            mode,
            strides,
            wide: OnceLock::new(),
            wide64: OnceLock::new(),
            faults: None,
        }
    }

    /// Build from an analytic instance (same coefficients).
    pub fn from_analytic(a: &AnalyticSmurf, mode: EntropyMode) -> Self {
        Self::new(a.config().clone(), a.coefficients(), mode)
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    /// Entropy wiring of this instance.
    pub fn mode(&self) -> EntropyMode {
        self.mode
    }

    /// Builder: attach a bit-level fault plan (see [`Self::set_fault_plan`]).
    pub fn with_fault_plan(mut self, plan: BitFaultPlan) -> Self {
        self.set_fault_plan(Some(plan));
        self
    }

    /// Attach or remove a bit-level fault plan. The wide companions are
    /// rebuilt lazily so they inherit the plan — faults follow the value,
    /// not the route (the estimators keep their wide/scalar routing).
    pub fn set_fault_plan(&mut self, plan: Option<BitFaultPlan>) {
        self.faults = plan;
        self.wide = OnceLock::new();
        self.wide64 = OnceLock::new();
    }

    /// The attached fault plan, if any.
    pub fn fault_plan(&self) -> Option<&BitFaultPlan> {
        self.faults.as_ref()
    }

    /// CPT-gate (shared with the wide engine so both sample identical
    /// quantized coefficient thresholds).
    pub(crate) fn cpt(&self) -> &CptGate {
        &self.cpt
    }

    /// The cached bit-sliced companion engine (identical coefficients and
    /// entropy wiring) at the auto-selected widest plane, built on first
    /// use and reused for the life of this instance.
    pub fn wide(&self) -> &WideBitLevelSmurf<MaxPlane> {
        self.wide.get_or_init(|| WideBitLevelSmurf::from_scalar(self))
    }

    /// The cached 64-lane (`u64`-plane) companion — the right engine when
    /// a job fills at most one `u64` word of lanes, where [`Self::wide`]'s
    /// extra plane words would idle. Bit-identical streams to every other
    /// width.
    pub fn wide64(&self) -> &WideBitLevelSmurf<u64> {
        self.wide64.get_or_init(|| WideBitLevelSmurf::from_scalar(self))
    }

    fn make_state(&self, seed: u64) -> RunState {
        let mut st = RunState {
            fsms: Vec::with_capacity(self.cfg.num_vars()),
            input_rngs: Vec::with_capacity(self.cfg.num_vars()),
            cpt_rng: RngKind::Sobol(Sobol::new(0)),
        };
        self.reset_state(seed, &mut st);
        st
    }

    /// Re-seed an existing [`RunState`] in place: `eval_avg`/`abs_error`
    /// construct the buffers once and reset per trial, so the scalar
    /// estimators are allocation-free across trials.
    fn reset_state(&self, seed: u64, st: &mut RunState) {
        let m = self.cfg.num_vars();
        st.fsms.clear();
        st.fsms
            .extend((0..m).map(|j| ChainFsm::centered(self.cfg.radix(j))));
        let input_rngs = &mut st.input_rngs;
        input_rngs.clear();
        st.cpt_rng = match self.mode {
            EntropyMode::SharedLfsr => {
                // One physical LFSR seeded from `seed`; branch k is the
                // same sequence delayed by 17*k cycles.
                let base = (seed as u16) | 1;
                const DELAY: usize = 17;
                for k in 0..m {
                    let mut l = Lfsr16::new(base);
                    for _ in 0..(DELAY * k) {
                        l.step();
                    }
                    input_rngs.push(RngKind::Lfsr(l));
                }
                let mut l = Lfsr16::new(base);
                for _ in 0..(DELAY * m) {
                    l.step();
                }
                RngKind::Lfsr(l)
            }
            EntropyMode::IndependentXorshift => {
                for k in 0..m {
                    input_rngs.push(RngKind::Xor(XorShift64::new(
                        seed.wrapping_mul(crate::util::prng::GOLDEN_GAMMA)
                            .wrapping_add(k as u64 + 1),
                    )));
                }
                RngKind::Xor(XorShift64::new(
                    seed.wrapping_mul(crate::util::prng::GOLDEN_GAMMA)
                        .wrapping_add(m as u64 + 1),
                ))
            }
            EntropyMode::SobolCpt => {
                let base = (seed as u16) | 1;
                const DELAY: usize = 17;
                for k in 0..m {
                    let mut l = Lfsr16::new(base);
                    for _ in 0..(DELAY * k) {
                        l.step();
                    }
                    input_rngs.push(RngKind::Lfsr(l));
                }
                // Phase-offset the Sobol counter by the seed so trials
                // stay independent.
                RngKind::Sobol(Sobol::new(seed as u32))
            }
        };
    }

    /// One seeded bitstream run on pre-built θ-gates and scratch state —
    /// the shared core of `eval`/`eval_avg`/`abs_error`. Dispatches to
    /// the clean ([`NoFaults`], zero-cost) or fault-hooked instantiation
    /// of [`Self::run_with`]; the fault streams are re-seeded from the
    /// plan here, so every run reproduces the same fault pattern.
    fn run(&self, gates: &[ThetaGate], len: usize, st: &mut RunState) -> f64 {
        match &self.faults {
            None => self.run_with(gates, len, st, &mut NoFaults),
            Some(plan) => {
                let mut faults = plan.scalar_state();
                self.run_with(gates, len, st, &mut faults)
            }
        }
    }

    /// The run loop, generic over the fault hook (see [`crate::sc::fault`]
    /// for the site taxonomy and why `NoFaults` monomorphizes to the
    /// pre-fault code).
    fn run_with<F: ScalarFaultHook>(
        &self,
        gates: &[ThetaGate],
        len: usize,
        st: &mut RunState,
        faults: &mut F,
    ) -> f64 {
        assert!(len > 0);
        let mut ones = 0u64;
        for _ in 0..len {
            // 1. Input θ-gates sample this cycle's entropy words.
            // 2. FSMs transition on the sampled bits.
            // 3. The (updated) codeword selects the CPT θ-gate.
            let mut sel = 0;
            for j in 0..st.fsms.len() {
                let word = faults.entropy(st.input_rngs[j].next_u16());
                let bit = faults.theta(gates[j].sample(word));
                let mut s = st.fsms[j].step(bit);
                if faults.state_armed() {
                    s = st.fsms[j].inject(|cur, nbits| faults.state(cur, nbits));
                }
                sel += s * self.strides[j];
            }
            let word = faults.entropy(st.cpt_rng.next_u16());
            ones += faults.output(self.cpt.sample(sel, word)) as u64;
        }
        ones as f64 / len as f64
    }

    /// Run the machine for `len` clock cycles on input probabilities `p`
    /// and return the output-bitstream mean (the estimate of `f(x)`).
    ///
    /// `seed` determinizes the entropy sources: the same `(p, len, seed)`
    /// always reproduces the same bitstream.
    pub fn eval(&self, p: &[f64], len: usize, seed: u64) -> f64 {
        assert_eq!(p.len(), self.cfg.num_vars());
        let mut st = self.make_state(seed);
        let gates: Vec<ThetaGate> = p.iter().map(|&pj| ThetaGate::new(pj)).collect();
        self.run(&gates, len, &mut st)
    }

    /// Average of `trials` independent bitstream runs — the Monte-Carlo
    /// estimator the accuracy figures (7–10) report.
    ///
    /// At [`WIDE_TRIALS_MIN`] trials or more this routes through the
    /// bit-sliced wide engine — the 64-lane companion up to one `u64`
    /// word of trials, the widest compiled plane
    /// ([`crate::smurf::sim_wide::MAX_LANES`] trials per pass) beyond —
    /// and the result is bit-identical to the scalar loop — same
    /// per-trial seeds, same summation order — just ~an order of
    /// magnitude faster.
    pub fn eval_avg(&self, p: &[f64], len: usize, trials: usize, seed: u64) -> f64 {
        assert!(trials > 0);
        if trials >= WIDE_TRIALS_MIN {
            return self.estimate_routed(p, len, trials, seed, EstimatorOp::Avg);
        }
        self.eval_avg_scalar(p, len, trials, seed)
    }

    /// The scalar (one bit per cycle per trial) reference estimator.
    /// θ-gates and run state are built once and reset per trial, so the
    /// loop itself is allocation-free. Public for benchmarks and
    /// equivalence tests; `eval_avg` is the fast path.
    pub fn eval_avg_scalar(&self, p: &[f64], len: usize, trials: usize, seed: u64) -> f64 {
        assert!(trials > 0);
        assert_eq!(p.len(), self.cfg.num_vars());
        let gates: Vec<ThetaGate> = p.iter().map(|&pj| ThetaGate::new(pj)).collect();
        let mut st = self.make_state(seed);
        let mut sum = 0.0;
        for t in 0..trials {
            self.reset_state(seed.wrapping_add(t as u64).wrapping_mul(0x5DEECE66D), &mut st);
            sum += self.run(&gates, len, &mut st);
        }
        sum / trials as f64
    }

    /// Mean absolute error against a target over `trials` runs at one
    /// input point: E[|P_y_hat - target|] (paper's "average absolute
    /// error" is this averaged over the input grid). Routes through the
    /// wide engine at [`WIDE_TRIALS_MIN`]+ trials, bit-identically.
    pub fn abs_error(&self, p: &[f64], target: f64, len: usize, trials: usize, seed: u64) -> f64 {
        assert!(trials > 0);
        if trials >= WIDE_TRIALS_MIN {
            return self.estimate_routed(p, len, trials, seed, EstimatorOp::AbsError(target));
        }
        self.abs_error_scalar(p, target, len, trials, seed)
    }

    /// The single wide-routing policy for both estimators: jobs of at
    /// most one `u64` word of trials run on the 64-lane companion (the
    /// widest plane's extra words would idle — [`WIDE_TRIALS_MIN`] was
    /// tuned against the 64-lane pass cost), larger jobs on the widest
    /// compiled plane. Both engines produce bit-identical streams, so
    /// the route never changes the result.
    fn estimate_routed(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        op: EstimatorOp,
    ) -> f64 {
        if trials <= LANES {
            run_estimator(self.wide64(), p, len, trials, seed, op)
        } else {
            run_estimator(self.wide(), p, len, trials, seed, op)
        }
    }

    /// Scalar reference for [`Self::abs_error`] (see `eval_avg_scalar`).
    pub fn abs_error_scalar(
        &self,
        p: &[f64],
        target: f64,
        len: usize,
        trials: usize,
        seed: u64,
    ) -> f64 {
        assert!(trials > 0);
        assert_eq!(p.len(), self.cfg.num_vars());
        let gates: Vec<ThetaGate> = p.iter().map(|&pj| ThetaGate::new(pj)).collect();
        let mut st = self.make_state(seed);
        let mut sum = 0.0;
        for t in 0..trials {
            self.reset_state(seed.wrapping_add(t as u64).wrapping_mul(0x2545F4914F), &mut st);
            sum += (self.run(&gates, len, &mut st) - target).abs();
        }
        sum / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn euclid_w() -> Vec<f64> {
        // Paper Table I coefficients for sqrt(x1^2+x2^2), N=4.
        vec![
            0.0, 0.6083, 0.0474, 0.6911, //
            0.6083, 0.3749, 0.4527, 0.8372, //
            0.0474, 0.4527, 0.0159, 0.5946, //
            0.6911, 0.8372, 0.5946, 0.9846,
        ]
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = SmurfConfig::uniform(2, 4);
        let s = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let a = s.eval(&[0.3, 0.4], 256, 9);
        let b = s.eval(&[0.3, 0.4], 256, 9);
        assert_eq!(a, b);
        let c = s.eval(&[0.3, 0.4], 256, 10);
        assert_ne!(a, c, "different seeds should give different streams");
    }

    #[test]
    fn output_in_unit_interval() {
        let cfg = SmurfConfig::uniform(2, 4);
        let s = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        for seed in 0..20 {
            let y = s.eval(&[0.9, 0.1], 64, seed);
            assert!((0.0..=1.0).contains(&y));
        }
    }

    #[test]
    fn long_stream_converges_to_analytic() {
        let cfg = SmurfConfig::uniform(2, 4);
        let w = euclid_w();
        let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
        let sim = BitLevelSmurf::new(cfg, &w, EntropyMode::IndependentXorshift);
        for p in [[0.3, 0.4], [0.7, 0.2], [0.5, 0.5]] {
            let y_inf = analytic.eval(&p);
            let y_hw = sim.eval_avg(&p, 4096, 16, 1);
            assert!(
                (y_hw - y_inf).abs() < 0.02,
                "p={p:?}: hw={y_hw} analytic={y_inf}"
            );
        }
    }

    #[test]
    fn shared_lfsr_converges_too() {
        let cfg = SmurfConfig::uniform(2, 4);
        let w = euclid_w();
        let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
        let sim = BitLevelSmurf::new(cfg, &w, EntropyMode::SharedLfsr);
        let p = [0.3, 0.4];
        let y = sim.eval_avg(&p, 4096, 16, 3);
        assert!((y - analytic.eval(&p)).abs() < 0.03, "y={y}");
    }

    #[test]
    fn euclid_paper_accuracy_at_64_bits() {
        // Paper Fig. 10a: MAE ≈ 0.032 at 64-bit streams. Allow headroom
        // for grid/trial differences: assert < 0.06 over a 5×5 grid.
        // Uses the QP-synthesized table (the published Table I values are
        // inconsistent with Eq. 21 — see synth::paper_tables).
        let cfg = SmurfConfig::uniform(2, 4);
        let res = crate::synth::synthesize(
            &cfg,
            &crate::synth::functions::euclidean2(),
            &crate::synth::SynthOptions::default(),
        );
        let sim =
            BitLevelSmurf::new(cfg, res.smurf.coefficients(), EntropyMode::IndependentXorshift);
        let mut total = 0.0;
        let mut count = 0;
        for i in 0..5 {
            for j in 0..5 {
                let p = [i as f64 / 4.0, j as f64 / 4.0];
                let target = (p[0] * p[0] + p[1] * p[1]).sqrt().min(1.0);
                total += sim.abs_error(&p, target, 64, 32, 77);
                count += 1;
            }
        }
        let mae = total / count as f64;
        assert!(mae < 0.06, "64-bit Euclid MAE={mae}, paper reports ≈0.032");
    }

    #[test]
    fn error_decreases_with_stream_length() {
        // Fig. 7's qualitative shape: error at L=256 < error at L=8.
        let cfg = SmurfConfig::uniform(2, 4);
        let sim = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::IndependentXorshift);
        let p: [f64; 2] = [0.6, 0.3];
        let target = (p[0] * p[0] + p[1] * p[1]).sqrt();
        let e_short = sim.abs_error(&p, target, 8, 64, 5);
        let e_long = sim.abs_error(&p, target, 256, 64, 5);
        assert!(
            e_long < e_short,
            "short={e_short} long={e_long} — error must decay with L"
        );
    }

    #[test]
    fn wide_companion_is_cached_and_bit_identical() {
        let cfg = SmurfConfig::uniform(2, 4);
        let s = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let a: *const _ = s.wide();
        let b: *const _ = s.wide();
        assert_eq!(a, b, "OnceLock must build the wide companion once");
        let a64: *const _ = s.wide64();
        let b64: *const _ = s.wide64();
        assert_eq!(a64, b64, "OnceLock must build the 64-lane companion once");
        // The routed estimator stays bit-identical to the scalar loop on
        // both routes: T=16 (64-lane companion) and T=100 (widest plane).
        for trials in [16usize, 100] {
            assert_eq!(
                s.eval_avg(&[0.3, 0.4], 64, trials, 5),
                s.eval_avg_scalar(&[0.3, 0.4], 64, trials, 5),
                "trials={trials}"
            );
            assert_eq!(
                s.abs_error(&[0.3, 0.4], 0.5, 64, trials, 5),
                s.abs_error_scalar(&[0.3, 0.4], 0.5, 64, trials, 5),
                "trials={trials}"
            );
        }
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_arity() {
        let cfg = SmurfConfig::uniform(2, 4);
        let s = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        s.eval(&[0.5], 64, 0);
    }

    /// A zero-rate fault plan must be bit-identical to the clean path —
    /// through the public API, so the *armed* hooked loop runs (an inert
    /// plan still dispatches to `run_with::<ScalarFaultState>`; it is
    /// identical because zero-rate sites never draw fault entropy).
    #[test]
    fn zero_rate_fault_plan_is_bit_identical_all_modes() {
        use crate::sc::fault::BitFaultPlan;
        for mode in [
            EntropyMode::SharedLfsr,
            EntropyMode::IndependentXorshift,
            EntropyMode::SobolCpt,
        ] {
            let cfg = SmurfConfig::uniform(2, 4);
            let clean = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let armed = BitLevelSmurf::new(cfg, &euclid_w(), mode)
                .with_fault_plan(BitFaultPlan::new(99));
            assert!(armed.fault_plan().unwrap().is_inert());
            for seed in [0u64, 7, 81] {
                assert_eq!(
                    clean.eval(&[0.3, 0.4], 128, seed),
                    armed.eval(&[0.3, 0.4], 128, seed),
                    "mode={mode:?} seed={seed}"
                );
            }
            // Estimators too (scalar route: trials < WIDE_TRIALS_MIN).
            assert_eq!(
                clean.eval_avg(&[0.6, 0.2], 64, 4, 3),
                armed.eval_avg(&[0.6, 0.2], 64, 4, 3),
                "mode={mode:?}"
            );
        }
    }

    #[test]
    fn armed_faults_are_deterministic_and_perturb_the_stream() {
        use crate::sc::fault::{BitFaultPlan, FaultRates, FaultSite};
        let cfg = SmurfConfig::uniform(2, 4);
        let plan = BitFaultPlan::new(21)
            .with_site(FaultSite::OutputBit, FaultRates::flips(0.05));
        let clean = BitLevelSmurf::new(cfg.clone(), &euclid_w(), EntropyMode::SharedLfsr);
        let faulty = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr)
            .with_fault_plan(plan);
        let a = faulty.eval(&[0.3, 0.4], 512, 9);
        let b = faulty.eval(&[0.3, 0.4], 512, 9);
        assert_eq!(a, b, "same (plan, input, seed) must reproduce");
        let c = clean.eval(&[0.3, 0.4], 512, 9);
        assert_ne!(a, c, "a 5% output-flip rate must perturb a 512-cycle stream");
        // Flips of a Bernoulli(p) stream at rate r move the mean toward
        // 1/2 by ~r; the perturbation must stay in that ballpark.
        assert!((a - c).abs() < 0.2, "faulty={a} clean={c}");
    }

    #[test]
    fn fsm_state_faults_stay_in_range() {
        use crate::sc::fault::{BitFaultPlan, FaultRates, FaultSite};
        // Radix 5 is not a power of two: state faults can hit the
        // out-of-range patterns 5..8, which must clamp, not panic the
        // CPT bank index.
        let cfg = SmurfConfig::new(vec![5, 5]);
        let n = cfg.num_aggregate_states();
        let w: Vec<f64> = (0..n).map(|i| i as f64 / n as f64).collect();
        let plan = BitFaultPlan::new(4)
            .with_site(FaultSite::FsmState, FaultRates::flips(0.1));
        let s = BitLevelSmurf::new(cfg, &w, EntropyMode::SharedLfsr)
            .with_fault_plan(plan);
        let y = s.eval(&[0.4, 0.7], 1024, 2);
        assert!((0.0..=1.0).contains(&y));
    }
}

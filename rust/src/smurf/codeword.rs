//! The universal-radix codeword `s = [i_M, …, i_1]` (paper §III-A).
//!
//! Each digit is the current state of one variable's FSM; the mixed-radix
//! integer encoding of the codeword is the CPT MUX select. The paper
//! indexes coefficient tables (Tables I/II) with variable 1 as the
//! least-significant digit: `t = i_1 + N_1·i_2 + N_1N_2·i_3 + …` — e.g.
//! for `N=4, M=2`, `w_t` at `t = i_1 + 4·i_2`.

use super::config::SmurfConfig;

/// A decoded codeword (digit `j` = state of variable `j`'s FSM).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Codeword {
    digits: Vec<usize>,
}

impl Codeword {
    pub fn new(digits: Vec<usize>, cfg: &SmurfConfig) -> Self {
        assert_eq!(digits.len(), cfg.num_vars());
        for (j, &d) in digits.iter().enumerate() {
            assert!(d < cfg.radix(j), "digit {j} out of range");
        }
        Self { digits }
    }

    /// Decode a MUX select index into its digits.
    pub fn from_index(mut idx: usize, cfg: &SmurfConfig) -> Self {
        assert!(idx < cfg.num_aggregate_states());
        let mut digits = Vec::with_capacity(cfg.num_vars());
        for j in 0..cfg.num_vars() {
            let n = cfg.radix(j);
            digits.push(idx % n);
            idx /= n;
        }
        Self { digits }
    }

    /// Mixed-radix encode into the MUX select index.
    pub fn to_index(&self, cfg: &SmurfConfig) -> usize {
        let strides = cfg.strides();
        self.digits.iter().zip(&strides).map(|(d, s)| d * s).sum()
    }

    pub fn digits(&self) -> &[usize] {
        &self.digits
    }

    /// Iterate all codewords of a configuration in index order.
    pub fn all(cfg: &SmurfConfig) -> impl Iterator<Item = Codeword> + '_ {
        (0..cfg.num_aggregate_states()).map(move |i| Codeword::from_index(i, cfg))
    }
}

impl std::fmt::Display for Codeword {
    /// Paper notation: `[i_M, …, i_1]` (most-significant digit first).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (k, d) in self.digits.iter().rev().enumerate() {
            if k > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_uniform() {
        let cfg = SmurfConfig::uniform(2, 4);
        for i in 0..16 {
            let cw = Codeword::from_index(i, &cfg);
            assert_eq!(cw.to_index(&cfg), i);
        }
    }

    #[test]
    fn roundtrip_mixed_radix() {
        let cfg = SmurfConfig::new(vec![3, 5, 2]);
        for i in 0..30 {
            let cw = Codeword::from_index(i, &cfg);
            assert_eq!(cw.to_index(&cfg), i);
        }
    }

    #[test]
    fn paper_table1_indexing() {
        // Table I is indexed t = i_1 + 4*i_2 (N=4, M=2): w_5 ↔ [i_2,i_1]=[1,1].
        let cfg = SmurfConfig::uniform(2, 4);
        let cw = Codeword::from_index(5, &cfg);
        assert_eq!(cw.digits(), &[1, 1]);
        let cw = Codeword::from_index(7, &cfg);
        assert_eq!(cw.digits(), &[3, 1]); // i_1=3, i_2=1
        assert_eq!(cw.to_string(), "[1,3]");
    }

    #[test]
    fn all_enumerates_everything_once() {
        let cfg = SmurfConfig::new(vec![2, 3]);
        let v: Vec<usize> = Codeword::all(&cfg).map(|c| c.to_index(&cfg)).collect();
        assert_eq!(v, (0..6).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_digit() {
        let cfg = SmurfConfig::uniform(2, 4);
        Codeword::new(vec![4, 0], &cfg);
    }
}

//! SMURF configuration: variable count and per-variable radix.

/// Configuration of a SMURF instance.
///
/// `radices[j]` is the number of states `N_j` of the FSM attached to input
/// variable `j` (paper: "universal-radix ... can even be different for
/// each FSM", §III-A). The CPT bank holds `Π_j N_j` coefficients.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SmurfConfig {
    radices: Vec<usize>,
}

impl SmurfConfig {
    /// Per-variable radices. Each must be ≥ 2; ≥ 3 is required for
    /// nonlinear approximation (§II-C: two states are "completely linear"),
    /// which we allow but is worth a warning in synthesis diagnostics.
    pub fn new(radices: Vec<usize>) -> Self {
        assert!(!radices.is_empty(), "need at least one variable");
        assert!(radices.iter().all(|&n| n >= 2), "each FSM needs >= 2 states");
        Self { radices }
    }

    /// All `m` variables share radix `n` — the paper's usual setting
    /// (`N=4` works well "in all practical cases", §II-C).
    pub fn uniform(m: usize, n: usize) -> Self {
        Self::new(vec![n; m])
    }

    /// Number of input variables `M`.
    pub fn num_vars(&self) -> usize {
        self.radices.len()
    }

    /// Radix (state count) of variable `j`'s FSM.
    pub fn radix(&self, j: usize) -> usize {
        self.radices[j]
    }

    pub fn radices(&self) -> &[usize] {
        &self.radices
    }

    /// Total number of aggregate states = CPT bank size `Π N_j`.
    pub fn num_aggregate_states(&self) -> usize {
        self.radices.iter().product()
    }

    /// Mixed-radix strides: `stride[j] = Π_{k<j} N_k` so that
    /// `sel = Σ_j i_j · stride[j]` (variable 1 is the least-significant
    /// digit, matching the paper's `s = [i_M, …, i_1]` ordering).
    pub fn strides(&self) -> Vec<usize> {
        let mut s = Vec::with_capacity(self.radices.len());
        let mut acc = 1;
        for &n in &self.radices {
            s.push(acc);
            acc *= n;
        }
        s
    }
}

impl std::fmt::Display for SmurfConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "SMURF(M={}, N={:?})", self.num_vars(), self.radices)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_config() {
        let c = SmurfConfig::uniform(2, 4);
        assert_eq!(c.num_vars(), 2);
        assert_eq!(c.radix(0), 4);
        assert_eq!(c.num_aggregate_states(), 16);
        assert_eq!(c.strides(), vec![1, 4]);
    }

    #[test]
    fn mixed_radix() {
        let c = SmurfConfig::new(vec![3, 4, 5]);
        assert_eq!(c.num_aggregate_states(), 60);
        assert_eq!(c.strides(), vec![1, 3, 12]);
    }

    #[test]
    #[should_panic]
    fn rejects_empty() {
        SmurfConfig::new(vec![]);
    }

    #[test]
    #[should_panic]
    fn rejects_radix_one() {
        SmurfConfig::new(vec![4, 1]);
    }

    #[test]
    fn display() {
        assert_eq!(SmurfConfig::uniform(2, 4).to_string(), "SMURF(M=2, N=[4, 4])");
    }
}

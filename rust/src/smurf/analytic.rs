//! Closed-form SMURF evaluation (paper Eq. 21): the infinite-bitstream
//! steady-state output
//!
//! `P_y(P_x; w) = Σ_s P_s(P_x) · w_s`,   `P_s = Π_j π^{(j)}_{i_j}(P_{x_j})`
//!
//! where `π^{(j)}` is the per-variable chain steady state (Eq. 4). The
//! joint factorizes across variables because the FSMs transition
//! independently — the property that makes both evaluation and synthesis
//! tractable (the `H` matrix is a Kronecker product of 1-D Gram matrices).

use super::config::SmurfConfig;
use crate::fsm::steady::{steady_state, steady_state_into};

/// An analytic SMURF: configuration + synthesized CPT coefficients.
#[derive(Clone, Debug)]
pub struct AnalyticSmurf {
    cfg: SmurfConfig,
    /// `w[t]` for MUX select `t` (mixed-radix codeword index).
    w: Vec<f64>,
}

impl AnalyticSmurf {
    pub fn new(cfg: SmurfConfig, w: Vec<f64>) -> Self {
        assert_eq!(
            w.len(),
            cfg.num_aggregate_states(),
            "coefficient count must equal the number of aggregate states"
        );
        Self { cfg, w }
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    pub fn coefficients(&self) -> &[f64] {
        &self.w
    }

    /// Joint steady-state probability of every aggregate state at input
    /// `p` — the vector `[P_s]_s` of Eq. 21, in MUX-select order.
    ///
    /// Computed as the outer product of per-variable marginals, built up
    /// digit-by-digit (variable 0 is the least-significant digit).
    pub fn joint_steady_state(&self, p: &[f64]) -> Vec<f64> {
        assert_eq!(p.len(), self.cfg.num_vars());
        let mut joint = vec![1.0];
        for j in 0..self.cfg.num_vars() {
            let marg = steady_state(self.cfg.radix(j), p[j]);
            // New joint has marg ⊗ joint layout: digit j varies slower
            // than digits < j.
            let mut next = Vec::with_capacity(joint.len() * marg.len());
            for &mj in &marg {
                for &jv in &joint {
                    next.push(mj * jv);
                }
            }
            joint = next;
        }
        joint
    }

    /// Eq. 21: the expected output for input probabilities `p`.
    ///
    /// Allocation-free fast path for configurations up to 64 aggregate
    /// states (every paper configuration); the general case falls back to
    /// the heap (§Perf: the serving engine calls this per request point).
    pub fn eval(&self, p: &[f64]) -> f64 {
        assert_eq!(p.len(), self.cfg.num_vars());
        let states = self.w.len();
        if states <= 64 && self.cfg.radices().iter().all(|&n| n <= 16) {
            let mut joint = [0.0f64; 64];
            let mut len = 1usize;
            joint[0] = 1.0;
            let mut marg = [0.0f64; 16];
            for j in 0..self.cfg.num_vars() {
                let n = self.cfg.radix(j);
                steady_state_into(n, p[j], &mut marg[..n]);
                // In-place outer product, filling from the back so lower
                // entries are not clobbered before they are read.
                for mi in (0..n).rev() {
                    let m = marg[mi];
                    let base = mi * len;
                    for k in (0..len).rev() {
                        joint[base + k] = m * joint[k];
                    }
                }
                len *= n;
            }
            let mut acc = 0.0;
            for (a, b) in joint[..len].iter().zip(&self.w) {
                acc += a * b;
            }
            acc
        } else {
            self.joint_steady_state(p)
                .iter()
                .zip(&self.w)
                .map(|(ps, ws)| ps * ws)
                .sum()
        }
    }

    /// Batch evaluation (the L1 Pallas kernel computes exactly this shape:
    /// `(B, M) -> (B,)`).
    pub fn eval_batch(&self, ps: &[Vec<f64>]) -> Vec<f64> {
        ps.iter().map(|p| self.eval(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, UnitVec};

    fn cfg24() -> SmurfConfig {
        SmurfConfig::uniform(2, 4)
    }

    #[test]
    fn joint_sums_to_one() {
        let s = AnalyticSmurf::new(cfg24(), vec![0.0; 16]);
        for p in [[0.1, 0.9], [0.5, 0.5], [0.0, 1.0]] {
            let j = s.joint_steady_state(&p);
            assert!((j.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn joint_factorizes() {
        // P_[i2,i1] must equal π2[i2]·π1[i1] with the right index order.
        let s = AnalyticSmurf::new(cfg24(), vec![0.0; 16]);
        let p = [0.3, 0.8];
        let joint = s.joint_steady_state(&p);
        let m1 = steady_state(4, p[0]);
        let m2 = steady_state(4, p[1]);
        for i2 in 0..4 {
            for i1 in 0..4 {
                let idx = i1 + 4 * i2;
                assert!((joint[idx] - m1[i1] * m2[i2]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn constant_coefficients_give_constant_output() {
        let s = AnalyticSmurf::new(cfg24(), vec![0.37; 16]);
        for p in [[0.0, 0.0], [0.2, 0.9], [1.0, 1.0]] {
            assert!((s.eval(&p) - 0.37).abs() < 1e-12);
        }
    }

    #[test]
    fn corner_saturation_reads_corner_coefficient() {
        // At p=(1,1) both chains saturate at state 3 → w_15 is read out.
        let mut w = vec![0.0; 16];
        w[15] = 0.9846; // paper Table I corner value
        let s = AnalyticSmurf::new(cfg24(), w);
        assert!((s.eval(&[1.0, 1.0]) - 0.9846).abs() < 1e-12);
        // At p=(0,0) → w_0.
        assert!(s.eval(&[0.0, 0.0]).abs() < 1e-12);
    }

    #[test]
    fn output_bounded_by_coefficient_range() {
        // P_y is a convex combination of the w's.
        let w: Vec<f64> = (0..16).map(|i| 0.1 + 0.05 * i as f64).collect();
        let lo = w.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let s = AnalyticSmurf::new(cfg24(), w);
        check(41, 128, &UnitVec { len: 2 }, |p| {
            let y = s.eval(p);
            y >= lo - 1e-12 && y <= hi + 1e-12
        });
    }

    #[test]
    fn eval_batch_matches_eval() {
        let w: Vec<f64> = (0..16).map(|i| i as f64 / 15.0).collect();
        let s = AnalyticSmurf::new(cfg24(), w);
        let batch = vec![vec![0.1, 0.2], vec![0.7, 0.9]];
        let ys = s.eval_batch(&batch);
        assert_eq!(ys.len(), 2);
        assert_eq!(ys[0], s.eval(&batch[0]));
        assert_eq!(ys[1], s.eval(&batch[1]));
    }

    #[test]
    fn mixed_radix_joint_is_consistent() {
        let cfg = SmurfConfig::new(vec![3, 5]);
        let s = AnalyticSmurf::new(cfg, vec![0.0; 15]);
        let j = s.joint_steady_state(&[0.25, 0.75]);
        assert_eq!(j.len(), 15);
        assert!((j.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        let m1 = steady_state(3, 0.25);
        let m2 = steady_state(5, 0.75);
        assert!((j[1 + 3 * 2] - m1[1] * m2[2]).abs() < 1e-14);
    }
}

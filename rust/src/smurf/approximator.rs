//! High-level façade: synthesize once, evaluate anywhere (analytic,
//! bit-level, batch), serialize coefficient tables.

use super::analytic::AnalyticSmurf;
use super::config::SmurfConfig;
use super::sim::{BitLevelSmurf, EntropyMode};
use super::sim_wide::{with_thread_scratch, MaxPlane, WideBitLevelSmurf, LANES, MAX_LANES};
use crate::synth::functions::TargetFn;
use crate::synth::synthesize::{synthesize, SynthOptions, SynthResult};
use crate::util::json::Json;

/// A synthesized SMURF ready for evaluation.
#[derive(Clone, Debug)]
pub struct SmurfApproximator {
    name: String,
    analytic: AnalyticSmurf,
    /// Bit-level simulator; its `OnceLock`-cached wide companion
    /// ([`BitLevelSmurf::wide`]) serves the multi-trial and batch-point
    /// fast paths — one cache, one construction path.
    sim: BitLevelSmurf,
    /// Default bitstream length used by `eval` (paper fixes 64, §IV-A).
    pub default_len: usize,
    /// Analytic MAE reported by synthesis.
    pub synth_mae: f64,
}

impl SmurfApproximator {
    /// Synthesize coefficients for `target` with default options.
    pub fn synthesize(cfg: &SmurfConfig, target: &TargetFn, default_len: usize) -> Self {
        Self::synthesize_with(cfg, target, default_len, &SynthOptions::default())
    }

    pub fn synthesize_with(
        cfg: &SmurfConfig,
        target: &TargetFn,
        default_len: usize,
        opts: &SynthOptions,
    ) -> Self {
        let SynthResult { smurf, mae, .. } = synthesize(cfg, target, opts);
        Self::from_analytic(target.name().to_string(), smurf, default_len, mae)
    }

    /// Wrap pre-computed coefficients (e.g. the paper's Table I values).
    pub fn from_coefficients(
        name: impl Into<String>,
        cfg: SmurfConfig,
        w: Vec<f64>,
        default_len: usize,
    ) -> Self {
        let analytic = AnalyticSmurf::new(cfg, w);
        Self::from_analytic(name.into(), analytic, default_len, f64::NAN)
    }

    fn from_analytic(name: String, analytic: AnalyticSmurf, default_len: usize, mae: f64) -> Self {
        let sim = BitLevelSmurf::from_analytic(&analytic, EntropyMode::SharedLfsr);
        Self { name, analytic, sim, default_len, synth_mae: mae }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn config(&self) -> &SmurfConfig {
        self.analytic.config()
    }

    pub fn coefficients(&self) -> &[f64] {
        self.analytic.coefficients()
    }

    /// Infinite-bitstream (expected) output — Eq. 21.
    pub fn eval_analytic(&self, p: &[f64]) -> f64 {
        self.analytic.eval(p)
    }

    /// Hardware-faithful bit-level output with an explicit stream length.
    pub fn eval_bitstream(&self, p: &[f64], len: usize, seed: u64) -> f64 {
        self.sim.eval(p, len, seed)
    }

    /// Monte-Carlo average of `trials` bit-level runs. From
    /// [`WIDE_TRIALS_MIN`](super::sim::WIDE_TRIALS_MIN) trials upward
    /// this runs on a cached wide companion engine — the 64-lane plane
    /// up to one `u64` word of
    /// trials, the widest compiled plane ([`MAX_LANES`] trials per pass)
    /// beyond it — bit-identical to averaging [`Self::eval_bitstream`]
    /// over the same seeds. (Same routing as `BitLevelSmurf::eval_avg`,
    /// to which this delegates.)
    pub fn eval_bitstream_avg(&self, p: &[f64], len: usize, trials: usize, seed: u64) -> f64 {
        self.sim.eval_avg(p, len, trials, seed)
    }

    /// Batch of distinct points, one seeded bitstream trial each, through
    /// the wide engine at [`MAX_LANES`] points per pass (the widest plane
    /// compiled into the build — 256 lanes, or 512 with the `wide512`
    /// feature); a batch that fits in one `u64` word of lanes routes to
    /// the 64-lane companion instead, where the wide plane's extra words
    /// would idle. Allocation-free: evaluates into `out`
    /// (`out.len() == points.len()`) on the thread-local scratch.
    /// `out[i]` is bit-exact equal to
    /// `eval_bitstream(points[i], len, seeds[i])`, so callers get
    /// identical streams regardless of how a batch is chunked (or which
    /// plane width chunks it). This is the single owner of the lane
    /// chunking logic — the coordinator's `BitLevel` engine and the NN
    /// activation layers route through it.
    pub fn eval_bitstream_points_into(
        &self,
        points: &[&[f64]],
        len: usize,
        seeds: &[u64],
        out: &mut [f64],
    ) {
        assert_eq!(points.len(), seeds.len());
        assert_eq!(points.len(), out.len());
        if points.is_empty() {
            return;
        }
        let mut lane_out = [0.0f64; MAX_LANES];
        // ≤ one u64 word of points: the 64-lane companion runs the single
        // pass without the widest plane's idle words (bit-identical
        // streams, so routing never changes what a caller observes).
        if points.len() <= LANES {
            let wide = self.sim.wide64();
            with_thread_scratch(|st| {
                wide.eval_points(points, len, seeds, st, &mut lane_out);
            });
            out.copy_from_slice(&lane_out[..points.len()]);
            return;
        }
        let wide = self.sim.wide();
        with_thread_scratch(|st| {
            for (chunk_idx, chunk) in points.chunks(MAX_LANES).enumerate() {
                let base = chunk_idx * MAX_LANES;
                wide.eval_points(chunk, len, &seeds[base..base + chunk.len()], st, &mut lane_out);
                out[base..base + chunk.len()].copy_from_slice(&lane_out[..chunk.len()]);
            }
        });
    }

    /// Allocating convenience wrapper over
    /// [`Self::eval_bitstream_points_into`].
    pub fn eval_bitstream_points(&self, points: &[&[f64]], len: usize, seeds: &[u64]) -> Vec<f64> {
        let mut out = vec![0.0f64; points.len()];
        self.eval_bitstream_points_into(points, len, seeds, &mut out);
        out
    }

    /// Bit-level output at the configured default stream length.
    pub fn eval(&self, p: &[f64], seed: u64) -> f64 {
        self.sim.eval(p, self.default_len, seed)
    }

    /// Attach or remove a bit-level fault plan on the underlying
    /// simulator (and, via lazy rebuild, its wide companions) — see
    /// [`crate::sc::fault`]. The analytic path is unaffected: it is the
    /// fault-free reference the drift sentinels compare against.
    pub fn set_fault_plan(&mut self, plan: Option<crate::sc::fault::BitFaultPlan>) {
        self.sim.set_fault_plan(plan);
    }

    /// Underlying analytic instance.
    pub fn analytic(&self) -> &AnalyticSmurf {
        &self.analytic
    }

    /// Underlying bit-level simulator.
    pub fn simulator(&self) -> &BitLevelSmurf {
        &self.sim
    }

    /// Underlying wide (bit-sliced) simulator at the auto-selected widest
    /// plane — the simulator's lazily-built cached companion. Callers
    /// that want allocation-free steady state own the scratch:
    /// `let mut st = approx.wide_simulator().make_run_state();`.
    pub fn wide_simulator(&self) -> &WideBitLevelSmurf<MaxPlane> {
        self.sim.wide()
    }

    /// Serialize the coefficient table (for artifacts/ and the python
    /// compile path, which embeds the same table into the Pallas kernel).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".into(), Json::Str(self.name.clone()));
        obj.insert(
            "radices".into(),
            Json::Arr(self.config().radices().iter().map(|&n| Json::Num(n as f64)).collect()),
        );
        obj.insert("w".into(), Json::from_f64s(self.coefficients()));
        obj.insert("default_len".into(), Json::Num(self.default_len as f64));
        Json::Obj(obj)
    }

    /// Deserialize from [`Self::to_json`] output.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let name = j.get("name").and_then(Json::as_str).ok_or("missing name")?;
        let radices: Vec<usize> = j
            .get("radices")
            .and_then(Json::as_f64_vec)
            .ok_or("missing radices")?
            .iter()
            .map(|&x| x as usize)
            .collect();
        let w = j.get("w").and_then(Json::as_f64_vec).ok_or("missing w")?;
        let default_len = j
            .get("default_len")
            .and_then(Json::as_f64)
            .ok_or("missing default_len")? as usize;
        let cfg = SmurfConfig::new(radices);
        if w.len() != cfg.num_aggregate_states() {
            return Err(format!(
                "coefficient count {} does not match config {}",
                w.len(),
                cfg
            ));
        }
        Ok(Self::from_coefficients(name.to_string(), cfg, w, default_len))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::functions;

    #[test]
    fn synthesize_and_eval() {
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        let y = a.eval_analytic(&[0.3, 0.4]);
        assert!((y - 0.5).abs() < 0.05, "y={y}");
        assert!(a.synth_mae < 0.02);
        assert_eq!(a.name(), "euclidean2");
    }

    #[test]
    fn bitstream_eval_uses_default_len() {
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
        let y1 = a.eval(&[0.5, 0.5], 3);
        let y2 = a.eval_bitstream(&[0.5, 0.5], 64, 3);
        assert_eq!(y1, y2);
    }

    #[test]
    fn bitstream_avg_matches_scalar_average() {
        // 2 = scalar route, 8/40 = 64-lane companion, 300 = widest plane.
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        for trials in [2usize, 8, 40, 300] {
            let fast = a.eval_bitstream_avg(&[0.3, 0.4], 64, trials, 5);
            let slow = a.simulator().eval_avg_scalar(&[0.3, 0.4], 64, trials, 5);
            assert_eq!(fast, slow, "trials={trials}");
        }
    }

    #[test]
    fn bitstream_points_matches_per_point_eval() {
        // Batch sizes covering every route: empty (no-op), 40 (64-lane
        // companion), 70 (widest plane, single chunk) and MAX_LANES + 44
        // (auto-width chunk boundary + non-multiple tail).
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        assert!(a.eval_bitstream_points(&[], 96, &[]).is_empty());
        for n in [40usize, 70, MAX_LANES + 44] {
            let pts: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![(i % 9) as f64 / 8.0, (i % 5) as f64 / 4.0])
                .collect();
            let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
            let seeds: Vec<u64> = (0..n).map(|i| 0xFACE ^ i as u64).collect();
            let batch = a.eval_bitstream_points(&refs, 96, &seeds);
            for (i, p) in refs.iter().enumerate() {
                assert_eq!(batch[i], a.eval_bitstream(p, 96, seeds[i]), "n={n} point {i}");
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::sincos(), 128);
        let j = a.to_json();
        let b = SmurfApproximator::from_json(&j).unwrap();
        assert_eq!(a.coefficients(), b.coefficients());
        assert_eq!(b.default_len, 128);
        assert_eq!(b.name(), "sincos");
        // Same analytic output.
        assert_eq!(a.eval_analytic(&[0.2, 0.9]), b.eval_analytic(&[0.2, 0.9]));
    }

    #[test]
    fn from_json_rejects_bad_shape() {
        let cfg = SmurfConfig::uniform(2, 4);
        let a = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
        let mut j = a.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("w".into(), Json::from_f64s(&[0.5; 3]));
        }
        assert!(SmurfApproximator::from_json(&j).is_err());
    }
}

//! SMURF — the paper's contribution (§III).
//!
//! - [`config`] — number of variables `M` and per-variable radix `N_j`
//!   ("universal-radix": the radix may differ per FSM).
//! - [`codeword`] — the aggregate-state codeword `s = [i_M, …, i_1]` and
//!   its mixed-radix encoding into the CPT MUX select.
//! - [`analytic`] — the closed-form steady-state evaluator (Eq. 21):
//!   `P_y = Σ_s P_s(P_x) · w_s`. This is the infinite-bitstream limit and
//!   the differentiable surrogate the L2 JAX model trains through.
//! - [`sim`] — the cycle-accurate bit-level simulator of Fig. 6: input
//!   θ-gates, M chained FSMs, CPT-gate, output counter — gate-for-gate the
//!   paper's RTL, with the single-RNG delayed-branch entropy wiring.
//! - [`sim_wide`] — the bit-sliced wide engine: the same Fig. 6 pipeline
//!   run 64/256/512 independent trials (or batch points) per clock using
//!   bit-plane arithmetic over a generic
//!   [`BitPlane`](crate::sc::plane::BitPlane) word; lane-for-lane
//!   bit-exact with [`sim`] given matched seeds at every width.
//! - [`approximator`] — synthesis + evaluation façade.

pub mod analytic;
pub mod approximator;
pub mod codeword;
pub mod config;
pub mod multi_output;
pub mod sim;
pub mod sim_wide;

pub use analytic::AnalyticSmurf;
pub use approximator::SmurfApproximator;
pub use codeword::Codeword;
pub use config::SmurfConfig;
pub use sim::BitLevelSmurf;
pub use sim_wide::{WideBitLevelSmurf, WideRunState};

//! Multi-output SMURF — the paper's §V future-work extension,
//! implemented: "intrinsically handle multi-output nonlinear functions".
//!
//! The M input FSMs (and their θ-gates and RNG) are *shared*; each output
//! adds only one CPT-gate (a θ-gate bank + MUX) reading the same
//! universal-radix codeword. For a K-output function this amortizes the
//! dominant blocks (Table VI: the RNG is most of the area/power) across
//! outputs — the vector softmax costs one extra CPT per class instead of
//! K full generators.

use super::analytic::AnalyticSmurf;
use super::config::SmurfConfig;
use crate::fsm::chain::ChainFsm;
use crate::sc::cpt::CptGate;
use crate::sc::rng::{Lfsr16, StreamRng};
use crate::sc::sng::ThetaGate;
use crate::synth::functions::TargetFn;
use crate::synth::synthesize::{synthesize, SynthOptions};

/// A K-output SMURF sharing its FSM front-end.
#[derive(Clone, Debug)]
pub struct MultiOutputSmurf {
    cfg: SmurfConfig,
    /// One coefficient table per output.
    tables: Vec<Vec<f64>>,
    names: Vec<String>,
}

impl MultiOutputSmurf {
    /// Synthesize one CPT table per component function. All components
    /// must share the same arity (they share the FSMs).
    pub fn synthesize(cfg: &SmurfConfig, components: &[TargetFn], opts: &SynthOptions) -> Self {
        assert!(!components.is_empty());
        let mut tables = Vec::with_capacity(components.len());
        let mut names = Vec::new();
        for f in components {
            assert_eq!(f.arity(), cfg.num_vars(), "{} arity mismatch", f.name());
            let res = synthesize(cfg, f, opts);
            tables.push(res.smurf.coefficients().to_vec());
            names.push(f.name().to_string());
        }
        Self { cfg: cfg.clone(), tables, names }
    }

    pub fn num_outputs(&self) -> usize {
        self.tables.len()
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    /// Analytic vector output (Eq. 21 per table, shared joint).
    pub fn eval_analytic(&self, p: &[f64]) -> Vec<f64> {
        // Build the joint once and contract each table against it.
        let probe = AnalyticSmurf::new(self.cfg.clone(), self.tables[0].clone());
        let joint = probe.joint_steady_state(p);
        self.tables
            .iter()
            .map(|w| joint.iter().zip(w).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Bit-level vector output: ONE run of the shared FSM front-end;
    /// every CPT-gate samples the same codeword trajectory each cycle
    /// (exactly what the shared-FSM hardware does).
    pub fn eval_bitstream(&self, p: &[f64], len: usize, seed: u64) -> Vec<f64> {
        assert_eq!(p.len(), self.cfg.num_vars());
        let m = self.cfg.num_vars();
        let base = (seed as u16) | 1;
        const DELAY: usize = 17;
        // Shared front-end entropy (one LFSR, delayed branches).
        let mut input_rngs: Vec<Lfsr16> = (0..m)
            .map(|k| {
                let mut l = Lfsr16::new(base);
                for _ in 0..(DELAY * k) {
                    l.step();
                }
                l
            })
            .collect();
        // One further branch per CPT-gate.
        let mut cpt_rngs: Vec<Lfsr16> = (0..self.tables.len())
            .map(|k| {
                let mut l = Lfsr16::new(base);
                for _ in 0..(DELAY * (m + k)) {
                    l.step();
                }
                l
            })
            .collect();
        let gates: Vec<ThetaGate> = p.iter().map(|&pj| ThetaGate::new(pj)).collect();
        let cpts: Vec<CptGate> = self.tables.iter().map(|w| CptGate::new(w)).collect();
        let mut fsms: Vec<ChainFsm> =
            (0..m).map(|j| ChainFsm::centered(self.cfg.radix(j))).collect();
        let strides = self.cfg.strides();
        let mut ones = vec![0u64; self.tables.len()];
        for _ in 0..len {
            let mut sel = 0usize;
            for j in 0..m {
                let bit = gates[j].sample(input_rngs[j].next_u16());
                sel += fsms[j].step(bit) * strides[j];
            }
            for (k, cpt) in cpts.iter().enumerate() {
                ones[k] += cpt.sample(sel, cpt_rngs[k].next_u16()) as u64;
            }
        }
        ones.iter().map(|&o| o as f64 / len as f64).collect()
    }
}

/// Convenience: the full 3-class softmax vector (paper Eq. 22, all
/// components rather than just the first).
pub fn softmax3_vector(n_states: usize) -> MultiOutputSmurf {
    let comp = |idx: usize| {
        TargetFn::new(format!("softmax3_{idx}"), 3, move |x: &[f64]| {
            let e: Vec<f64> = x.iter().map(|v| v.exp()).collect();
            e[idx] / (e[0] + e[1] + e[2])
        })
    };
    MultiOutputSmurf::synthesize(
        &SmurfConfig::uniform(3, n_states),
        &[comp(0), comp(1), comp(2)],
        &SynthOptions::default(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vector_softmax_sums_to_one_analytically() {
        let ms = softmax3_vector(4);
        assert_eq!(ms.num_outputs(), 3);
        for p in [[0.2, 0.5, 0.9], [0.0, 0.0, 0.0], [1.0, 0.3, 0.6]] {
            let y = ms.eval_analytic(&p);
            let s: f64 = y.iter().sum();
            // Components are synthesized independently; the sum constraint
            // holds to synthesis accuracy, not exactly.
            assert!((s - 1.0).abs() < 0.02, "p={p:?}: sum={s}");
        }
    }

    #[test]
    fn vector_matches_componentwise_synthesis() {
        // Output 0 of the vector generator equals the standalone softmax3.
        let ms = softmax3_vector(4);
        let single = synthesize(
            &SmurfConfig::uniform(3, 4),
            &crate::synth::functions::softmax3(),
            &SynthOptions::default(),
        );
        let p = [0.3, 0.7, 0.5];
        let y = ms.eval_analytic(&p);
        assert!((y[0] - single.smurf.eval(&p)).abs() < 1e-9);
    }

    #[test]
    fn bitstream_vector_converges() {
        let ms = softmax3_vector(4);
        let p = [0.4, 0.6, 0.8];
        let want = ms.eval_analytic(&p);
        // Average several long runs.
        let trials = 16;
        let mut acc = vec![0.0; 3];
        for t in 0..trials {
            let y = ms.eval_bitstream(&p, 2048, 1000 + t);
            for k in 0..3 {
                acc[k] += y[k];
            }
        }
        for k in 0..3 {
            let mean = acc[k] / trials as f64;
            assert!(
                (mean - want[k]).abs() < 0.03,
                "output {k}: bitstream {mean} vs analytic {}",
                want[k]
            );
        }
    }

    #[test]
    fn shared_frontend_is_cheaper_than_k_generators() {
        // Hardware argument: K-output SMURF = 1 front-end + K CPTs.
        use crate::hw::gates::{comparator, mux_tree};
        use crate::hw::smurf_design;
        let cfg = SmurfConfig::uniform(3, 4);
        let one = smurf_design(&cfg).total().area_um2;
        let cpt_area = 1.35 * (mux_tree(64, 8) + comparator(8)); // logic overhead
        let coeff_area = 1.35 * (64.0 * 8.0 * crate::hw::gates::DFF);
        let three_shared = one + 2.0 * (cpt_area + coeff_area);
        let three_naive = 3.0 * one;
        // At M=3/N=4 the per-output coefficient registers (64×8 bits)
        // dominate the add-on, so the saving is ~22% — still material,
        // and it grows with the shared RNG/FSM fraction (small N^M).
        assert!(
            three_shared < 0.85 * three_naive,
            "shared {three_shared:.0} vs naive {three_naive:.0}"
        );
    }
}

//! Wide (bit-sliced) SMURF simulator: 64 independent bitstream trials per
//! clock cycle.
//!
//! # The bit-slicing scheme
//!
//! The scalar simulator ([`super::sim::BitLevelSmurf`]) walks Fig. 6 one
//! bit per cycle per trial: every θ-gate compare, FSM branch and CPT MUX
//! load is a data-dependent scalar operation, and the random comparator
//! bits make the FSM branches ~50% mispredicted. SC bitstreams are the
//! canonical bit-parallel workload, so this engine transposes the problem:
//! every 16-bit datapath word is stored as 16 *bit planes*, where plane
//! `b` is a `u64` whose bit `l` belongs to lane (= trial or batch point)
//! `l`. All 64 lanes then move through one clock of the whole
//! comparator → FSM → CPT pipeline in a few dozen branch-free word ops.
//!
//! Mapping back to the Fig. 6 blocks:
//!
//! - **RNG + delayed branches (§III-A)** — [`crate::sc::rng::WideLfsr16`]
//!   keeps the 16 LFSR register bits as planes in a ring buffer; one clock
//!   of all 64 lanes is "compute the feedback plane, rotate the head".
//!   Per-lane branch delays are applied at seed time with the GF(2) jump
//!   basis ([`crate::sc::rng::Lfsr16::jump_basis`]). Sobol output sampling
//!   is a plane ripple-carry counter read in bit-reversed plane order;
//!   xorshift64* lanes step scalarly (the 64-bit multiply does not slice)
//!   but still feed the packed pipeline.
//! - **Input θ-gates** — a 16-bit `rand < threshold` compare is folded
//!   MSB-first over the planes ([`crate::sc::sng::wide_lt_const`]): ~2 word
//!   ops per plane yield all 64 verdicts, i.e. the M comparator columns of
//!   Fig. 6 run 64 trials at a time.
//! - **Chained N-state FSMs** — [`crate::fsm::chain_wide::WideChainFsm`]
//!   holds each chain's state index as `ceil(log2 N)` planes; a clock edge
//!   is a masked ripple-carry **saturating add** (lanes whose input bit is
//!   1 and not yet at `N-1`) followed by a masked ripple-borrow
//!   **saturating sub** — plane logic, no branches.
//! - **Universal-radix codeword + CPT MUX** — each FSM exposes one-hot
//!   per-digit lane masks; ANDing one mask per variable gives `eq[t]`, the
//!   lanes whose codeword selects coefficient `w_t`. The CPT-gate ORs each
//!   coefficient's threshold bits into shared planes under its `eq[t]`
//!   mask ([`crate::sc::cpt::CptGate::threshold_planes`]) — the AND-OR MUX
//!   tree of Fig. 6 in word form — and one plane-vs-plane compare
//!   ([`crate::sc::sng::wide_lt_planes`]) samples all 64 output bits.
//! - **Output counter** — output masks accumulate into a *vertical
//!   counter* (one plane per count bit, ripple carry), so per-cycle cost
//!   is O(1) amortized; per-lane totals are read out once at the end.
//!
//! Lanes are fully independent, so the engine serves two shapes through
//! the same core: `eval_trials` (one input point, up to 64 Monte-Carlo
//! trials — the [`eval_avg`](WideBitLevelSmurf::eval_avg) estimator) and
//! `eval_points` (up to 64 distinct batch points, one trial each — the
//! coordinator's `Engine::BitLevel` path). Both are bit-exact matches of
//! the scalar simulator lane-for-lane given the same per-lane seeds: same
//! LFSR branch delays, same xorshift seeding formula, same Sobol counter
//! phase, same θ-gate quantization, same within-cycle ordering.
//!
//! All scratch state lives in a caller-owned [`WideRunState`], so repeated
//! evaluations are allocation-free end-to-end.

use super::config::SmurfConfig;
use super::sim::{BitLevelSmurf, EntropyMode};
use crate::fsm::chain_wide::WideChainFsm;
use crate::sc::cpt::CptGate;
use crate::sc::rng::{Lfsr16, WideLfsr16, WideSobol16, WideXorShift64};
use crate::sc::sng::{wide_lt_planes, ThetaGate};

/// Max count-bit planes in the output counter: supports `len < 2^40`.
const COUNT_PLANES: usize = 41;

/// Hardware lane width: one trial per bit of a `u64` word.
pub const LANES: usize = 64;

/// Devirtualized wide entropy source (mirrors the scalar `RngKind`).
// The xorshift variant inlines its 64 scalar lanes (~0.5 KiB) so reseeding
// allocates nothing; boxing it to shrink the enum would put a heap
// allocation back on the per-eval reset path.
#[allow(clippy::large_enum_variant)]
#[derive(Clone, Debug)]
enum WideRng {
    Lfsr(WideLfsr16),
    Xor(WideXorShift64),
    Sobol(WideSobol16),
}

impl WideRng {
    /// One clock for all lanes, then the comparator mask against a
    /// threshold shared by every lane.
    #[inline(always)]
    fn next_lt_const(&mut self, threshold: u16) -> u64 {
        match self {
            WideRng::Lfsr(r) => r.next_lt_const(threshold),
            WideRng::Xor(r) => r.next_lt_const(threshold),
            WideRng::Sobol(r) => r.next_lt_const(threshold),
        }
    }

    /// One clock for all lanes, materializing this cycle's rand planes.
    #[inline(always)]
    fn next_planes_into(&mut self, out: &mut [u64; 16]) {
        match self {
            WideRng::Lfsr(r) => r.next_planes_into(out),
            WideRng::Xor(r) => r.next_planes_into(out),
            WideRng::Sobol(r) => r.next_planes_into(out),
        }
    }
}

/// Per-input-gate threshold: one shared value (`eval_trials` — every lane
/// evaluates the same point) or per-lane planes (`eval_points`).
#[derive(Clone, Debug)]
enum GateThreshold {
    Shared(u16),
    PerLane([u64; 16]),
}

/// Caller-owned scratch for wide evaluations. Construct with
/// [`WideRunState::new`] (or [`WideBitLevelSmurf::make_run_state`]);
/// every buffer is reused across runs, so steady-state evaluation
/// performs no heap allocation. One scratch serves engines of *different*
/// configurations: each eval entry point resizes the per-configuration
/// buffers to fit before running (allocation-free once warmed to the
/// largest configuration seen).
pub struct WideRunState {
    fsms: Vec<WideChainFsm>,
    input_rngs: Vec<WideRng>,
    cpt_rng: WideRng,
    gate_thresholds: Vec<GateThreshold>,
    /// Per-variable one-hot digit masks, flattened (`digit_offsets`).
    digit_masks: Vec<u64>,
    /// Per-coefficient select masks (`eq[t]` = lanes selecting `w_t`).
    eq: Vec<u64>,
    rand_planes: [u64; 16],
    thresh_planes: [u64; 16],
    count_planes: [u64; COUNT_PLANES],
}

impl WideRunState {
    /// Empty scratch; buffers grow (and shrink) to fit whichever engine
    /// uses it next, so one instance can be shared across functions of
    /// different arities/radices.
    pub fn new() -> Self {
        Self {
            fsms: Vec::new(),
            input_rngs: Vec::new(),
            cpt_rng: WideRng::Sobol(WideSobol16::from_lane_counters(&[])),
            gate_thresholds: Vec::new(),
            digit_masks: Vec::new(),
            eq: Vec::new(),
            rand_planes: [0; 16],
            thresh_planes: [0; 16],
            count_planes: [0; COUNT_PLANES],
        }
    }
}

impl Default for WideRunState {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static THREAD_SCRATCH: std::cell::RefCell<WideRunState> =
        std::cell::RefCell::new(WideRunState::new());
}

/// Run `f` with this thread's shared [`WideRunState`] scratch. The
/// buffers persist for the life of the thread, so repeated evaluations
/// (the coordinator's per-worker batches, the estimator routing in
/// `BitLevelSmurf::eval_avg`, the NN activation layers) are
/// allocation-free after the first call without every caller owning its
/// own state. Do not call it reentrantly from inside `f` — the scratch is
/// a `RefCell` and a nested borrow panics.
pub fn with_thread_scratch<R>(f: impl FnOnce(&mut WideRunState) -> R) -> R {
    THREAD_SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Wide bit-sliced SMURF instance. Shares coefficients/entropy semantics
/// with a scalar [`BitLevelSmurf`]; see the module docs for the scheme.
#[derive(Clone, Debug)]
pub struct WideBitLevelSmurf {
    cfg: SmurfConfig,
    cpt: CptGate,
    mode: EntropyMode,
    /// `digits[t * M + j]` = variable `j`'s digit of codeword `t`.
    digits: Vec<u16>,
    /// Start of variable `j`'s digit-mask block in `WideRunState::digit_masks`.
    digit_offsets: Vec<usize>,
    /// LFSR fast-forward bases for branch delays `17*k`, `k in 0..=M`.
    lfsr_jumps: Vec<[u16; 16]>,
}

impl WideBitLevelSmurf {
    pub fn new(cfg: SmurfConfig, w: &[f64], mode: EntropyMode) -> Self {
        assert_eq!(w.len(), cfg.num_aggregate_states());
        Self::from_parts(cfg, CptGate::new(w), mode)
    }

    /// Build from a scalar simulator (identical coefficients, config and
    /// entropy wiring — the lane-equivalence contract).
    pub fn from_scalar(sim: &BitLevelSmurf) -> Self {
        Self::from_parts(sim.config().clone(), sim.cpt().clone(), sim.mode())
    }

    fn from_parts(cfg: SmurfConfig, cpt: CptGate, mode: EntropyMode) -> Self {
        let m = cfg.num_vars();
        let bank = cfg.num_aggregate_states();
        // Precompute each codeword's mixed-radix digits once; the hot loop
        // indexes this table instead of doing div/mod per cycle.
        let mut digits = Vec::with_capacity(bank * m);
        for t in 0..bank {
            let mut rem = t;
            for j in 0..m {
                let n = cfg.radix(j);
                digits.push((rem % n) as u16);
                rem /= n;
            }
        }
        let mut digit_offsets = Vec::with_capacity(m);
        let mut off = 0;
        for j in 0..m {
            digit_offsets.push(off);
            off += cfg.radix(j);
        }
        // §III-A branch delays: branch k lags 17*k clocks; k == M feeds
        // the CPT-gate. Precomputed as GF(2) jumps for O(16) lane seeding.
        const DELAY: usize = 17;
        let lfsr_jumps = (0..=m).map(|k| Lfsr16::jump_basis(DELAY * k)).collect();
        Self { cfg, cpt, mode, digits, digit_offsets, lfsr_jumps }
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    pub fn mode(&self) -> EntropyMode {
        self.mode
    }

    /// Allocate the reusable scratch buffers for this configuration.
    pub fn make_run_state(&self) -> WideRunState {
        let mut st = WideRunState::new();
        self.prepare(&mut st);
        st
    }

    /// Size the per-configuration buffers (idempotent). Every eval entry
    /// point calls this, so any [`WideRunState`] — including one last
    /// used by an engine of a different shape — is valid scratch.
    fn prepare(&self, st: &mut WideRunState) {
        st.digit_masks.resize(self.cfg.radices().iter().sum::<usize>(), 0);
        st.eq.resize(self.cfg.num_aggregate_states(), 0);
    }

    /// Seed the entropy lanes exactly like `BitLevelSmurf::make_state`
    /// does per trial: lane `l` reproduces the scalar run with `seeds[l]`.
    fn reset_entropy(&self, seeds: &[u64], st: &mut WideRunState) {
        let m = self.cfg.num_vars();
        let lanes = seeds.len();
        st.input_rngs.clear();
        let mut lane_states = [0u16; LANES];
        match self.mode {
            EntropyMode::SharedLfsr => {
                for k in 0..=m {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_states[l] = Lfsr16::jump(base, basis);
                    }
                    let rng = WideRng::Lfsr(WideLfsr16::from_lane_states(
                        &lane_states[..lanes],
                    ));
                    if k < m {
                        st.input_rngs.push(rng);
                    } else {
                        st.cpt_rng = rng;
                    }
                }
            }
            EntropyMode::IndependentXorshift => {
                let mut lane_seeds = [0u64; LANES];
                for k in 0..=m {
                    for (l, &s) in seeds.iter().enumerate() {
                        lane_seeds[l] = s
                            .wrapping_mul(0x9E3779B97F4A7C15)
                            .wrapping_add(k as u64 + 1);
                    }
                    let rng = WideRng::Xor(WideXorShift64::from_seeds(
                        &lane_seeds[..lanes],
                    ));
                    if k < m {
                        st.input_rngs.push(rng);
                    } else {
                        st.cpt_rng = rng;
                    }
                }
            }
            EntropyMode::SobolCpt => {
                for k in 0..m {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_states[l] = Lfsr16::jump(base, basis);
                    }
                    st.input_rngs.push(WideRng::Lfsr(WideLfsr16::from_lane_states(
                        &lane_states[..lanes],
                    )));
                }
                // Scalar: Sobol::new(seed as u32); only the low 16 counter
                // bits ever reach the bit-reversed 16-bit output.
                for (l, &s) in seeds.iter().enumerate() {
                    lane_states[l] = s as u16;
                }
                st.cpt_rng = WideRng::Sobol(WideSobol16::from_lane_counters(
                    &lane_states[..lanes],
                ));
            }
        }
        st.fsms.clear();
        for j in 0..m {
            st.fsms.push(WideChainFsm::centered(self.cfg.radix(j)));
        }
        st.count_planes = [0; COUNT_PLANES];
    }

    /// The shared 64-lane core: `len` clocks of the Fig. 6 pipeline, then
    /// per-lane bitstream means for the first `lanes` lanes into `out`.
    fn run(&self, len: usize, lanes: usize, st: &mut WideRunState, out: &mut [f64]) {
        assert!(len > 0, "need at least one clock cycle");
        assert!((len as u64) < (1u64 << (COUNT_PLANES - 1)), "stream too long for counter");
        let m = self.cfg.num_vars();
        let bank = self.cfg.num_aggregate_states();
        let WideRunState {
            fsms,
            input_rngs,
            cpt_rng,
            gate_thresholds,
            digit_masks,
            eq,
            rand_planes,
            thresh_planes,
            count_planes,
        } = st;
        for _ in 0..len {
            // 1. Input θ-gates sample this cycle's entropy; 2. FSMs
            // transition on the comparator masks (same within-cycle order
            // as the scalar simulator).
            for j in 0..m {
                let up = match &gate_thresholds[j] {
                    GateThreshold::Shared(t) => input_rngs[j].next_lt_const(*t),
                    GateThreshold::PerLane(tp) => {
                        input_rngs[j].next_planes_into(rand_planes);
                        wide_lt_planes(rand_planes, tp)
                    }
                };
                fsms[j].step(up);
            }
            // 3. Updated codeword digits → one-hot lane masks → per-
            // coefficient select masks.
            for (j, f) in fsms.iter().enumerate() {
                let off = self.digit_offsets[j];
                f.digit_masks(&mut digit_masks[off..off + f.num_states()]);
            }
            for t in 0..bank {
                let row = &self.digits[t * m..t * m + m];
                let mut mask = !0u64;
                for (j, &d) in row.iter().enumerate() {
                    mask &= digit_masks[self.digit_offsets[j] + d as usize];
                    if mask == 0 {
                        break;
                    }
                }
                eq[t] = mask;
            }
            // 4. CPT-gate: MUX the per-lane coefficient thresholds in
            // plane form, sample against the CPT entropy branch.
            self.cpt.threshold_planes(eq.as_slice(), thresh_planes);
            cpt_rng.next_planes_into(rand_planes);
            let ones = wide_lt_planes(rand_planes, thresh_planes);
            // 5. Output counter (vertical: one plane per count bit).
            let mut carry = ones;
            let mut b = 0;
            while carry != 0 {
                let t = count_planes[b];
                count_planes[b] = t ^ carry;
                carry &= t;
                b += 1;
            }
        }
        // Decode per-lane totals from the vertical counter.
        for (l, o) in out.iter_mut().enumerate().take(lanes) {
            let mut count = 0u64;
            for (b, &p) in count_planes.iter().enumerate() {
                count |= ((p >> l) & 1) << b;
            }
            *o = count as f64 / len as f64;
        }
    }

    /// Up to 64 Monte-Carlo trials of one input point in a single pass:
    /// `out[i]` is bit-exact equal to scalar `eval(p, len, seeds[i])`.
    pub fn eval_trials(
        &self,
        p: &[f64],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState,
        out: &mut [f64],
    ) {
        assert_eq!(p.len(), self.cfg.num_vars());
        assert!(!seeds.is_empty() && seeds.len() <= LANES, "1..=64 trials per pass");
        assert!(out.len() >= seeds.len());
        self.prepare(st);
        st.gate_thresholds.clear();
        for &pj in p {
            st.gate_thresholds.push(GateThreshold::Shared(ThetaGate::new(pj).raw()));
        }
        self.reset_entropy(seeds, st);
        self.run(len, seeds.len(), st, out);
    }

    /// Up to 64 distinct batch points, one bitstream trial each: `out[i]`
    /// is bit-exact equal to scalar `eval(points[i], len, seeds[i])`.
    /// This is the coordinator's `Engine::BitLevel` batch shape.
    pub fn eval_points(
        &self,
        points: &[&[f64]],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState,
        out: &mut [f64],
    ) {
        let m = self.cfg.num_vars();
        assert!(!points.is_empty() && points.len() <= LANES, "1..=64 points per pass");
        assert_eq!(points.len(), seeds.len());
        assert!(out.len() >= points.len());
        self.prepare(st);
        let mut lane_t = [0u16; LANES];
        st.gate_thresholds.clear();
        for j in 0..m {
            for (l, pt) in points.iter().enumerate() {
                assert_eq!(pt.len(), m, "point arity mismatch");
                lane_t[l] = ThetaGate::new(pt[j]).raw();
            }
            st.gate_thresholds.push(GateThreshold::PerLane(
                crate::sc::rng::planes_from_lanes(&lane_t[..points.len()]),
            ));
        }
        self.reset_entropy(seeds, st);
        self.run(len, points.len(), st, out);
    }

    /// Monte-Carlo average over `trials` runs — the same estimator (same
    /// per-trial seed derivation, same summation order, bit-identical
    /// result) as the scalar `BitLevelSmurf::eval_avg`, at 64 trials per
    /// pass.
    pub fn eval_avg(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState,
    ) -> f64 {
        assert!(trials > 0);
        let mut seeds = [0u64; LANES];
        let mut out = [0.0f64; LANES];
        let mut sum = 0.0;
        let mut done = 0;
        while done < trials {
            let k = (trials - done).min(LANES);
            for (i, s) in seeds.iter_mut().enumerate().take(k) {
                *s = seed.wrapping_add((done + i) as u64).wrapping_mul(0x5DEECE66D);
            }
            self.eval_trials(p, len, &seeds[..k], st, &mut out);
            for &y in &out[..k] {
                sum += y;
            }
            done += k;
        }
        sum / trials as f64
    }

    /// Mean absolute error against a target over `trials` runs —
    /// bit-identical to the scalar `BitLevelSmurf::abs_error`.
    pub fn abs_error(
        &self,
        p: &[f64],
        target: f64,
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState,
    ) -> f64 {
        assert!(trials > 0);
        let mut seeds = [0u64; LANES];
        let mut out = [0.0f64; LANES];
        let mut sum = 0.0;
        let mut done = 0;
        while done < trials {
            let k = (trials - done).min(LANES);
            for (i, s) in seeds.iter_mut().enumerate().take(k) {
                *s = seed.wrapping_add((done + i) as u64).wrapping_mul(0x2545F4914F);
            }
            self.eval_trials(p, len, &seeds[..k], st, &mut out);
            for &y in &out[..k] {
                sum += (y - target).abs();
            }
            done += k;
        }
        sum / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::analytic::AnalyticSmurf;
    use crate::testing::{check, UnitVec};

    fn euclid_w() -> Vec<f64> {
        vec![
            0.0, 0.6083, 0.0474, 0.6911, //
            0.6083, 0.3749, 0.4527, 0.8372, //
            0.0474, 0.4527, 0.0159, 0.5946, //
            0.6911, 0.8372, 0.5946, 0.9846,
        ]
    }

    fn modes() -> [EntropyMode; 3] {
        [
            EntropyMode::SharedLfsr,
            EntropyMode::IndependentXorshift,
            EntropyMode::SobolCpt,
        ]
    }

    /// The tentpole contract: every wide lane equals the scalar simulator
    /// run with that lane's seed, bit-exactly.
    #[test]
    fn prop_lanes_match_scalar_eval() {
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::from_scalar(&scalar);
            check(31, 8, &UnitVec { len: 2 }, |p| {
                let mut st = wide.make_run_state();
                let seeds: Vec<u64> =
                    (0..64).map(|l| (l as u64) * 0x9E37 + p[0].to_bits()).collect();
                let mut out = [0.0f64; 64];
                wide.eval_trials(p, 96, &seeds, &mut st, &mut out);
                seeds
                    .iter()
                    .enumerate()
                    .all(|(l, &s)| out[l] == scalar.eval(p, 96, s))
            });
        }
    }

    #[test]
    fn partial_lane_counts_match_scalar() {
        // 1, 7, 33 lanes — unused lanes must not disturb active ones.
        let cfg = SmurfConfig::uniform(2, 4);
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            let p = [0.3, 0.7];
            for lanes in [1usize, 7, 33] {
                let seeds: Vec<u64> = (0..lanes as u64).map(|l| l * 31 + 5).collect();
                let mut out = vec![0.0f64; lanes];
                wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
                for (l, &s) in seeds.iter().enumerate() {
                    assert_eq!(out[l], scalar.eval(&p, 64, s), "{mode:?} lanes={lanes} l={l}");
                }
            }
        }
    }

    #[test]
    fn eval_points_matches_scalar_per_point() {
        let cfg = SmurfConfig::uniform(2, 4);
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            let pts: Vec<Vec<f64>> = (0..40)
                .map(|i| vec![(i % 8) as f64 / 7.0, (i / 8) as f64 / 5.0])
                .collect();
            let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
            let seeds: Vec<u64> = (0..40).map(|i| 0x5EED ^ i as u64).collect();
            let mut out = vec![0.0f64; 40];
            wide.eval_points(&refs, 64, &seeds, &mut st, &mut out);
            for (i, p) in refs.iter().enumerate() {
                assert_eq!(out[i], scalar.eval(p, 64, seeds[i]), "{mode:?} point {i}");
            }
        }
    }

    #[test]
    fn mixed_radix_lanes_match_scalar() {
        // Non-power-of-2 radices exercise the general digit plane logic.
        let cfg = SmurfConfig::new(vec![3, 5]);
        let w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &w, mode);
            let wide = WideBitLevelSmurf::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            let p = [0.45, 0.8];
            let seeds: Vec<u64> = (0..64).map(|l| l as u64 + 100).collect();
            let mut out = [0.0f64; 64];
            wide.eval_trials(&p, 128, &seeds, &mut st, &mut out);
            for (l, &s) in seeds.iter().enumerate() {
                assert_eq!(out[l], scalar.eval(&p, 128, s), "{mode:?} lane {l}");
            }
        }
    }

    #[test]
    fn eval_avg_bit_identical_to_scalar_reference() {
        let cfg = SmurfConfig::uniform(2, 4);
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            for trials in [1usize, 8, 32, 64, 100, 130] {
                let a = wide.eval_avg(&[0.3, 0.4], 64, trials, 9, &mut st);
                let b = scalar.eval_avg_scalar(&[0.3, 0.4], 64, trials, 9);
                assert_eq!(a, b, "{mode:?} trials={trials}");
            }
            let a = wide.abs_error(&[0.6, 0.2], 0.63, 64, 48, 7, &mut st);
            let b = scalar.abs_error_scalar(&[0.6, 0.2], 0.63, 64, 48, 7);
            assert_eq!(a, b, "{mode:?} abs_error");
        }
    }

    #[test]
    fn long_stream_converges_to_analytic_wide() {
        // Mirror of the scalar `long_stream_converges_to_analytic`, driven
        // through the wide engine.
        let cfg = SmurfConfig::uniform(2, 4);
        let w = euclid_w();
        let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
        let wide = WideBitLevelSmurf::new(cfg, &w, EntropyMode::IndependentXorshift);
        let mut st = wide.make_run_state();
        for p in [[0.3, 0.4], [0.7, 0.2], [0.5, 0.5]] {
            let y_inf = analytic.eval(&p);
            let y_hw = wide.eval_avg(&p, 4096, 16, 1, &mut st);
            assert!(
                (y_hw - y_inf).abs() < 0.02,
                "p={p:?}: wide={y_hw} analytic={y_inf}"
            );
        }
    }

    #[test]
    fn run_state_reuse_across_shapes() {
        // One RunState must serve trials → points → trials without any
        // cross-contamination.
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let wide = WideBitLevelSmurf::from_scalar(&scalar);
        let mut st = wide.make_run_state();
        let p = [0.25, 0.65];
        let seeds = [3u64, 99, 1234];
        let mut out = [0.0f64; 3];
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        let first = out;
        let pts: Vec<Vec<f64>> = vec![vec![0.9, 0.1], vec![0.2, 0.2]];
        let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
        let mut pout = [0.0f64; 2];
        wide.eval_points(&refs, 32, &[1, 2], &mut st, &mut pout);
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        assert_eq!(first, out, "RunState reuse must be deterministic");
    }

    #[test]
    fn scratch_adapts_across_configs() {
        // One WideRunState (the thread-local sharing shape) must serve
        // engines of different arity/radix, bit-identically to a
        // per-engine make_run_state.
        let big_cfg = SmurfConfig::new(vec![3, 5]);
        let big_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        let big = WideBitLevelSmurf::new(big_cfg, &big_w, EntropyMode::SharedLfsr);
        let small = WideBitLevelSmurf::new(
            SmurfConfig::uniform(2, 4),
            &euclid_w(),
            EntropyMode::SharedLfsr,
        );
        let mut shared = WideRunState::new();
        let seeds = [1u64, 2, 3];
        let mut got = [0.0f64; 3];
        let mut want = [0.0f64; 3];
        for engine in [&big, &small, &big] {
            let p = vec![0.4; engine.config().num_vars()];
            engine.eval_trials(&p, 48, &seeds, &mut shared, &mut got);
            engine.eval_trials(&p, 48, &seeds, &mut engine.make_run_state(), &mut want);
            assert_eq!(got, want, "{}", engine.config());
        }
    }

    #[test]
    fn thread_scratch_matches_owned_state() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SobolCpt);
        let mut owned = wide.make_run_state();
        let a = wide.eval_avg(&[0.3, 0.4], 64, 40, 11, &mut owned);
        let b = with_thread_scratch(|st| wide.eval_avg(&[0.3, 0.4], 64, 40, 11, st));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn rejects_too_many_lanes() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let mut st = wide.make_run_state();
        let seeds = vec![0u64; 65];
        let mut out = vec![0.0f64; 65];
        wide.eval_trials(&[0.5, 0.5], 16, &seeds, &mut st, &mut out);
    }
}

//! Wide (bit-sliced) SMURF simulator: `P::LANES` independent bitstream
//! trials per clock cycle (64, 256 or 512 — see *The plane abstraction*
//! below).
//!
//! # The bit-slicing scheme
//!
//! The scalar simulator ([`super::sim::BitLevelSmurf`]) walks Fig. 6 one
//! bit per cycle per trial: every θ-gate compare, FSM branch and CPT MUX
//! load is a data-dependent scalar operation, and the random comparator
//! bits make the FSM branches ~50% mispredicted. SC bitstreams are the
//! canonical bit-parallel workload, so this engine transposes the problem:
//! every 16-bit datapath word is stored as 16 *bit planes*, where plane
//! `b` is a word whose lane `l` belongs to lane (= trial or batch point)
//! `l`. All lanes then move through one clock of the whole
//! comparator → FSM → CPT pipeline in a few dozen branch-free plane ops.
//!
//! Mapping back to the Fig. 6 blocks:
//!
//! - **RNG + delayed branches (§III-A)** — [`crate::sc::rng::WideLfsr16`]
//!   keeps the 16 LFSR register bits as planes in a ring buffer; one clock
//!   of all lanes is "compute the feedback plane, rotate the head".
//!   Per-lane branch delays are applied at seed time with the GF(2) jump
//!   basis ([`crate::sc::rng::Lfsr16::jump_basis`]). Sobol output sampling
//!   is a plane ripple-carry counter read in bit-reversed plane order;
//!   xorshift64* lanes step scalarly (the 64-bit multiply does not slice)
//!   but still feed the packed pipeline.
//! - **Input θ-gates** — a 16-bit `rand < threshold` compare is folded
//!   MSB-first over the planes ([`crate::sc::sng::wide_lt_const`]): ~2
//!   plane ops per bit yield every lane's verdict, i.e. the M comparator
//!   columns of Fig. 6 run `P::LANES` trials at a time.
//! - **Chained N-state FSMs** — [`crate::fsm::chain_wide::WideChainFsm`]
//!   holds each chain's state index as `ceil(log2 N)` planes; a clock edge
//!   is a masked ripple-carry **saturating add** (lanes whose input bit is
//!   1 and not yet at `N-1`) followed by a masked ripple-borrow
//!   **saturating sub** — plane logic, no branches.
//! - **Universal-radix codeword + CPT MUX** — each FSM exposes one-hot
//!   per-digit lane masks; ANDing one mask per variable gives `eq[t]`, the
//!   lanes whose codeword selects coefficient `w_t`. The CPT-gate ORs each
//!   coefficient's threshold bits into shared planes under its `eq[t]`
//!   mask ([`crate::sc::cpt::CptGate::threshold_planes`]) — the AND-OR MUX
//!   tree of Fig. 6 in plane form — and one plane-vs-plane compare
//!   ([`crate::sc::sng::wide_lt_planes`]) samples every lane's output bit.
//! - **Output counter** — output masks accumulate into a *vertical
//!   counter* (one plane per count bit, ripple carry), so per-cycle cost
//!   is O(1) amortized; per-lane totals are read out once at the end.
//!
//! Lanes are fully independent, so the engine serves two shapes through
//! the same core: `eval_trials` (one input point, up to `P::LANES`
//! Monte-Carlo trials — the [`eval_avg`](WideBitLevelSmurf::eval_avg)
//! estimator) and `eval_points` (up to `P::LANES` distinct batch points,
//! one trial each — the coordinator's `Engine::BitLevel` path). Both are
//! bit-exact matches of the scalar simulator lane-for-lane given the same
//! per-lane seeds: same LFSR branch delays, same xorshift seeding formula,
//! same Sobol counter phase, same θ-gate quantization, same within-cycle
//! ordering.
//!
//! # The plane abstraction
//!
//! Every operation above is lane-wise boolean algebra, so the plane type
//! is a trait — [`crate::sc::plane::BitPlane`] — and the entire pipeline
//! (entropy lanes, comparators, chain FSMs, CPT MUX, vertical counters,
//! this simulator) is generic over it. `P` defaults to `u64` (64 lanes,
//! the PR 1 engine, public behavior unchanged); `[u64; 4]` carries 256
//! lanes as straight-line array ops that LLVM autovectorizes to AVX2 /
//! NEON, and `[u64; 8]` (cargo feature `wide512`) carries 512 for
//! AVX-512 targets. [`MaxPlane`] names the widest plane compiled into
//! the build; the batch entry points
//! ([`crate::smurf::approximator::SmurfApproximator::eval_bitstream_points_into`],
//! `SmurfActivation::eval_bitlevel_batch`, the coordinator's `BitLevel`
//! chunking) pick it automatically and chunk work by
//! [`MAX_LANES`]` = MaxPlane::LANES`.
//!
//! **Adding a width** is four one-line steps: implement `BitPlane` for
//! the new word (see `impl_bitplane_words!` in [`crate::sc::plane`]),
//! give it a thread scratch with the `impl_thread_scratch!` line below,
//! register it in `for_each_plane_width!` (which fans every
//! width-parametric test suite out over it), and add per-width `#[test]`
//! wrappers to the lane-equivalence suite in this module. Nothing else
//! changes — no engine code mentions a concrete plane type.
//!
//! **Tail masking.** A run of `k < P::LANES` lanes never masks planes:
//! idle lanes are seeded to the LFSR all-zeros fixpoint (or simply have
//! no xorshift generator), their FSM/counter bits compute garbage
//! harmlessly, and the readout loop only decodes the first `k` lanes —
//! exactly the convention the 64-lane engine has used since PR 1, now at
//! every width. Callers chunk a batch by `P::LANES` and pass the
//! partially-filled tail as a short `seeds`/`points` slice.
//!
//! All scratch state lives in a caller-owned [`WideRunState`], so repeated
//! evaluations are allocation-free end-to-end.

use super::config::SmurfConfig;
use super::sim::{BitLevelSmurf, EntropyMode};
use crate::fsm::chain_wide::WideChainFsm;
use crate::sc::cpt::CptGate;
use crate::sc::plane::BitPlane;
use crate::sc::rng::{planes_from_lanes, Lfsr16, WideLfsr16, WideSobol16, WideXorShift64};
use crate::sc::sng::{wide_lt_planes, ThetaGate};

/// Max count-bit planes in the output counter: supports `len < 2^40`.
const COUNT_PLANES: usize = 41;

/// Lane count of the default (`u64`) plane. Kept for callers that reason
/// about the base word width; batch chunking should use [`MAX_LANES`].
pub const LANES: usize = 64;

/// The widest compiled plane and its lane count now live with the plane
/// substrate itself ([`crate::sc::plane`]) so that the SC-level engines
/// (e.g. the wide SC-PwMM multiply, [`crate::sc::pwmm_wide`]) can chunk
/// by them without depending on this module; re-exported here because
/// every historical consumer of the wide SMURF engine names them through
/// this path.
pub use crate::sc::plane::{MaxPlane, MAX_LANES};

/// Devirtualized wide entropy source (mirrors the scalar `RngKind`).
// The xorshift lanes are heap-backed inside `WideXorShift64` (reseeded in
// place), so the three variants are of comparable size — the PR 2
// `allow(large_enum_variant)` is gone with the inline 64-lane array.
#[derive(Clone, Debug)]
enum WideRng<P: BitPlane> {
    Lfsr(WideLfsr16<P>),
    Xor(WideXorShift64<P>),
    Sobol(WideSobol16<P>),
}

impl<P: BitPlane> WideRng<P> {
    /// One clock for all lanes, then the comparator mask against a
    /// threshold shared by every lane.
    #[inline(always)]
    fn next_lt_const(&mut self, threshold: u16) -> P {
        match self {
            WideRng::Lfsr(r) => r.next_lt_const(threshold),
            WideRng::Xor(r) => r.next_lt_const(threshold),
            WideRng::Sobol(r) => r.next_lt_const(threshold),
        }
    }

    /// One clock for all lanes, materializing this cycle's rand planes.
    #[inline(always)]
    fn next_planes_into(&mut self, out: &mut [P; 16]) {
        match self {
            WideRng::Lfsr(r) => r.next_planes_into(out),
            WideRng::Xor(r) => r.next_planes_into(out),
            WideRng::Sobol(r) => r.next_planes_into(out),
        }
    }
}

/// Reseed a scratch slot as an LFSR bank in place; the slot is only
/// reconstructed when the scratch last served a different entropy mode.
fn set_lfsr<P: BitPlane>(slot: &mut WideRng<P>, states: &[u16]) {
    if let WideRng::Lfsr(r) = slot {
        r.reseed(states);
    } else {
        *slot = WideRng::Lfsr(WideLfsr16::from_lane_states(states));
    }
}

/// Reseed a scratch slot as a xorshift bank in place (reuses the heap
/// lane buffer — the allocation-free steady-state path).
fn set_xor<P: BitPlane>(slot: &mut WideRng<P>, seeds: &[u64]) {
    if let WideRng::Xor(r) = slot {
        r.reseed(seeds);
    } else {
        *slot = WideRng::Xor(WideXorShift64::from_seeds(seeds));
    }
}

/// Reseed a scratch slot as a Sobol counter bank in place.
fn set_sobol<P: BitPlane>(slot: &mut WideRng<P>, counters: &[u16]) {
    if let WideRng::Sobol(r) = slot {
        r.reseed(counters);
    } else {
        *slot = WideRng::Sobol(WideSobol16::from_lane_counters(counters));
    }
}

/// Per-input-gate threshold: one shared value (`eval_trials` — every lane
/// evaluates the same point) or per-lane planes (`eval_points`).
#[derive(Clone, Debug)]
enum GateThreshold<P: BitPlane> {
    Shared(u16),
    PerLane([P; 16]),
}

/// Caller-owned scratch for wide evaluations. Construct with
/// [`WideRunState::new`] (or [`WideBitLevelSmurf::make_run_state`]);
/// every buffer is reused across runs, so steady-state evaluation
/// performs no heap allocation. One scratch serves engines of *different*
/// configurations: each eval entry point resizes the per-configuration
/// buffers to fit before running (allocation-free once warmed to the
/// largest configuration seen).
pub struct WideRunState<P: BitPlane = u64> {
    fsms: Vec<WideChainFsm<P>>,
    input_rngs: Vec<WideRng<P>>,
    cpt_rng: WideRng<P>,
    gate_thresholds: Vec<GateThreshold<P>>,
    /// Per-variable one-hot digit masks, flattened (`digit_offsets`).
    digit_masks: Vec<P>,
    /// Per-coefficient select masks (`eq[t]` = lanes selecting `w_t`).
    eq: Vec<P>,
    rand_planes: [P; 16],
    thresh_planes: [P; 16],
    count_planes: [P; COUNT_PLANES],
    /// Reseed staging: per-lane 16-bit LFSR states / Sobol counters.
    lane_u16: Vec<u16>,
    /// Reseed staging: per-lane xorshift seeds.
    lane_u64: Vec<u64>,
    /// Estimator staging: per-chunk trial seeds (`eval_avg`/`abs_error`).
    seed_stage: Vec<u64>,
    /// Estimator staging: per-chunk lane outputs.
    out_stage: Vec<f64>,
}

impl<P: BitPlane> WideRunState<P> {
    /// Empty scratch; buffers grow (and shrink) to fit whichever engine
    /// uses it next, so one instance can be shared across functions of
    /// different arities/radices.
    pub fn new() -> Self {
        Self {
            fsms: Vec::new(),
            input_rngs: Vec::new(),
            cpt_rng: WideRng::Sobol(WideSobol16::from_lane_counters(&[])),
            gate_thresholds: Vec::new(),
            digit_masks: Vec::new(),
            eq: Vec::new(),
            rand_planes: [P::zero(); 16],
            thresh_planes: [P::zero(); 16],
            count_planes: [P::zero(); COUNT_PLANES],
            lane_u16: Vec::new(),
            lane_u64: Vec::new(),
            seed_stage: Vec::new(),
            out_stage: Vec::new(),
        }
    }
}

impl<P: BitPlane> Default for WideRunState<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// Plane widths that own a per-thread [`WideRunState`] scratch. One
/// thread-local static exists per width (they cannot share one: the
/// scratch type is width-parametric), created on first use.
pub trait ThreadScratch: BitPlane {
    /// Run `f` with this thread's shared scratch for this plane width.
    /// Do not call reentrantly from inside `f` — the scratch is a
    /// `RefCell` and a nested borrow panics.
    fn with_scratch<R>(f: impl FnOnce(&mut WideRunState<Self>) -> R) -> R;
}

macro_rules! impl_thread_scratch {
    ($ty:ty) => {
        impl ThreadScratch for $ty {
            fn with_scratch<R>(f: impl FnOnce(&mut WideRunState<Self>) -> R) -> R {
                thread_local! {
                    static SCRATCH: std::cell::RefCell<WideRunState<$ty>> =
                        std::cell::RefCell::new(WideRunState::new());
                }
                SCRATCH.with(|s| f(&mut s.borrow_mut()))
            }
        }
    };
}

impl_thread_scratch!(u64);
impl_thread_scratch!([u64; 4]);
#[cfg(feature = "wide512")]
impl_thread_scratch!([u64; 8]);

/// Run `f` with this thread's shared [`WideRunState`] scratch for the
/// inferred plane width. The buffers persist for the life of the thread,
/// so repeated evaluations (the coordinator's per-worker batches, the
/// estimator routing in `BitLevelSmurf::eval_avg`, the NN activation
/// layers) are allocation-free after the first call without every caller
/// owning its own state. Do not call it reentrantly from inside `f` — the
/// scratch is a `RefCell` and a nested borrow panics.
pub fn with_thread_scratch<P: ThreadScratch, R>(
    f: impl FnOnce(&mut WideRunState<P>) -> R,
) -> R {
    P::with_scratch(f)
}

/// Wide bit-sliced SMURF instance over plane type `P` (default: `u64`,
/// 64 lanes). Shares coefficients/entropy semantics with a scalar
/// [`BitLevelSmurf`]; see the module docs for the scheme.
#[derive(Clone, Debug)]
pub struct WideBitLevelSmurf<P: BitPlane = u64> {
    cfg: SmurfConfig,
    cpt: CptGate,
    mode: EntropyMode,
    /// `digits[t * M + j]` = variable `j`'s digit of codeword `t`.
    digits: Vec<u16>,
    /// Start of variable `j`'s digit-mask block in `WideRunState::digit_masks`.
    digit_offsets: Vec<usize>,
    /// LFSR fast-forward bases for branch delays `17*k`, `k in 0..=M`.
    lfsr_jumps: Vec<[u16; 16]>,
    _plane: std::marker::PhantomData<P>,
}

impl<P: BitPlane> WideBitLevelSmurf<P> {
    pub fn new(cfg: SmurfConfig, w: &[f64], mode: EntropyMode) -> Self {
        assert_eq!(w.len(), cfg.num_aggregate_states());
        Self::from_parts(cfg, CptGate::new(w), mode)
    }

    /// Build from a scalar simulator (identical coefficients, config and
    /// entropy wiring — the lane-equivalence contract).
    pub fn from_scalar(sim: &BitLevelSmurf) -> Self {
        Self::from_parts(sim.config().clone(), sim.cpt().clone(), sim.mode())
    }

    fn from_parts(cfg: SmurfConfig, cpt: CptGate, mode: EntropyMode) -> Self {
        let m = cfg.num_vars();
        let bank = cfg.num_aggregate_states();
        // Precompute each codeword's mixed-radix digits once; the hot loop
        // indexes this table instead of doing div/mod per cycle.
        let mut digits = Vec::with_capacity(bank * m);
        for t in 0..bank {
            let mut rem = t;
            for j in 0..m {
                let n = cfg.radix(j);
                digits.push((rem % n) as u16);
                rem /= n;
            }
        }
        let mut digit_offsets = Vec::with_capacity(m);
        let mut off = 0;
        for j in 0..m {
            digit_offsets.push(off);
            off += cfg.radix(j);
        }
        // §III-A branch delays: branch k lags 17*k clocks; k == M feeds
        // the CPT-gate. Precomputed as GF(2) jumps for O(16) lane seeding.
        const DELAY: usize = 17;
        let lfsr_jumps = (0..=m).map(|k| Lfsr16::jump_basis(DELAY * k)).collect();
        Self {
            cfg,
            cpt,
            mode,
            digits,
            digit_offsets,
            lfsr_jumps,
            _plane: std::marker::PhantomData,
        }
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    pub fn mode(&self) -> EntropyMode {
        self.mode
    }

    /// Allocate the reusable scratch buffers for this configuration.
    pub fn make_run_state(&self) -> WideRunState<P> {
        let mut st = WideRunState::new();
        self.prepare(&mut st);
        st
    }

    /// Size the per-configuration buffers (idempotent). Every eval entry
    /// point calls this, so any [`WideRunState`] — including one last
    /// used by an engine of a different shape — is valid scratch.
    fn prepare(&self, st: &mut WideRunState<P>) {
        st.digit_masks.resize(self.cfg.radices().iter().sum::<usize>(), P::zero());
        st.eq.resize(self.cfg.num_aggregate_states(), P::zero());
    }

    /// Seed the entropy lanes exactly like `BitLevelSmurf::make_state`
    /// does per trial: lane `l` reproduces the scalar run with `seeds[l]`.
    /// Slots are reseeded in place (no allocation in steady state).
    fn reset_entropy(&self, seeds: &[u64], st: &mut WideRunState<P>) {
        let m = self.cfg.num_vars();
        let lanes = seeds.len();
        let WideRunState {
            fsms,
            input_rngs,
            cpt_rng,
            lane_u16,
            lane_u64,
            count_planes,
            ..
        } = st;
        // One persistent slot per input gate; kinds only change when the
        // scratch moves between engines of different entropy modes.
        input_rngs.resize_with(m, || WideRng::Sobol(WideSobol16::from_lane_counters(&[])));
        lane_u16.resize(lanes, 0);
        match self.mode {
            EntropyMode::SharedLfsr => {
                for k in 0..=m {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_u16[l] = Lfsr16::jump(base, basis);
                    }
                    let slot = if k < m { &mut input_rngs[k] } else { &mut *cpt_rng };
                    set_lfsr(slot, lane_u16);
                }
            }
            EntropyMode::IndependentXorshift => {
                lane_u64.resize(lanes, 0);
                for k in 0..=m {
                    for (l, &s) in seeds.iter().enumerate() {
                        lane_u64[l] = s
                            .wrapping_mul(0x9E3779B97F4A7C15)
                            .wrapping_add(k as u64 + 1);
                    }
                    let slot = if k < m { &mut input_rngs[k] } else { &mut *cpt_rng };
                    set_xor(slot, lane_u64);
                }
            }
            EntropyMode::SobolCpt => {
                for (k, slot) in input_rngs.iter_mut().enumerate() {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_u16[l] = Lfsr16::jump(base, basis);
                    }
                    set_lfsr(slot, lane_u16);
                }
                // Scalar: Sobol::new(seed as u32); only the low 16 counter
                // bits ever reach the bit-reversed 16-bit output.
                for (l, &s) in seeds.iter().enumerate() {
                    lane_u16[l] = s as u16;
                }
                set_sobol(cpt_rng, lane_u16);
            }
        }
        fsms.clear();
        for j in 0..m {
            fsms.push(WideChainFsm::centered(self.cfg.radix(j)));
        }
        *count_planes = [P::zero(); COUNT_PLANES];
    }

    /// The shared lane core: `len` clocks of the Fig. 6 pipeline, then
    /// per-lane bitstream means for the first `lanes` lanes into `out`.
    fn run(&self, len: usize, lanes: usize, st: &mut WideRunState<P>, out: &mut [f64]) {
        assert!(len > 0, "need at least one clock cycle");
        assert!((len as u64) < (1u64 << (COUNT_PLANES - 1)), "stream too long for counter");
        let m = self.cfg.num_vars();
        let bank = self.cfg.num_aggregate_states();
        let WideRunState {
            fsms,
            input_rngs,
            cpt_rng,
            gate_thresholds,
            digit_masks,
            eq,
            rand_planes,
            thresh_planes,
            count_planes,
            ..
        } = st;
        for _ in 0..len {
            // 1. Input θ-gates sample this cycle's entropy; 2. FSMs
            // transition on the comparator masks (same within-cycle order
            // as the scalar simulator).
            for j in 0..m {
                let up = match &gate_thresholds[j] {
                    GateThreshold::Shared(t) => input_rngs[j].next_lt_const(*t),
                    GateThreshold::PerLane(tp) => {
                        input_rngs[j].next_planes_into(rand_planes);
                        wide_lt_planes(rand_planes, tp)
                    }
                };
                fsms[j].step(up);
            }
            // 3. Updated codeword digits → one-hot lane masks → per-
            // coefficient select masks.
            for (j, f) in fsms.iter().enumerate() {
                let off = self.digit_offsets[j];
                f.digit_masks(&mut digit_masks[off..off + f.num_states()]);
            }
            for t in 0..bank {
                let row = &self.digits[t * m..t * m + m];
                let mut mask = P::ones();
                for (j, &d) in row.iter().enumerate() {
                    mask = mask.and(digit_masks[self.digit_offsets[j] + d as usize]);
                    if mask.is_zero() {
                        break;
                    }
                }
                eq[t] = mask;
            }
            // 4. CPT-gate: MUX the per-lane coefficient thresholds in
            // plane form, sample against the CPT entropy branch.
            self.cpt.threshold_planes(eq.as_slice(), thresh_planes);
            cpt_rng.next_planes_into(rand_planes);
            let ones = wide_lt_planes(rand_planes, thresh_planes);
            // 5. Output counter (vertical: one plane per count bit).
            let mut carry = ones;
            let mut b = 0;
            while !carry.is_zero() {
                let (sum, c) = count_planes[b].half_add(carry);
                count_planes[b] = sum;
                carry = c;
                b += 1;
            }
        }
        // Decode per-lane totals from the vertical counter.
        for (l, o) in out.iter_mut().enumerate().take(lanes) {
            let mut count = 0u64;
            for (b, &p) in count_planes.iter().enumerate() {
                count |= (p.lane(l) as u64) << b;
            }
            *o = count as f64 / len as f64;
        }
    }

    /// Up to `P::LANES` Monte-Carlo trials of one input point in a single
    /// pass: `out[i]` is bit-exact equal to scalar `eval(p, len, seeds[i])`.
    pub fn eval_trials(
        &self,
        p: &[f64],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        assert_eq!(p.len(), self.cfg.num_vars());
        assert!(
            !seeds.is_empty() && seeds.len() <= P::LANES,
            "1..=P::LANES trials per pass"
        );
        assert!(out.len() >= seeds.len());
        self.prepare(st);
        st.gate_thresholds.clear();
        for &pj in p {
            st.gate_thresholds.push(GateThreshold::Shared(ThetaGate::new(pj).raw()));
        }
        self.reset_entropy(seeds, st);
        self.run(len, seeds.len(), st, out);
    }

    /// Up to `P::LANES` distinct batch points, one bitstream trial each:
    /// `out[i]` is bit-exact equal to scalar `eval(points[i], len, seeds[i])`.
    /// This is the coordinator's `Engine::BitLevel` batch shape.
    pub fn eval_points(
        &self,
        points: &[&[f64]],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        let m = self.cfg.num_vars();
        assert!(
            !points.is_empty() && points.len() <= P::LANES,
            "1..=P::LANES points per pass"
        );
        assert_eq!(points.len(), seeds.len());
        assert!(out.len() >= points.len());
        self.prepare(st);
        st.lane_u16.resize(points.len(), 0);
        st.gate_thresholds.clear();
        for j in 0..m {
            for (l, pt) in points.iter().enumerate() {
                assert_eq!(pt.len(), m, "point arity mismatch");
                st.lane_u16[l] = ThetaGate::new(pt[j]).raw();
            }
            st.gate_thresholds
                .push(GateThreshold::PerLane(planes_from_lanes(&st.lane_u16)));
        }
        self.reset_entropy(seeds, st);
        self.run(len, points.len(), st, out);
    }

    /// Monte-Carlo average over `trials` runs — the same estimator (same
    /// per-trial seed derivation, same summation order, bit-identical
    /// result) as the scalar `BitLevelSmurf::eval_avg`, at `P::LANES`
    /// trials per pass. Chunking never changes the result: lane order is
    /// trial order, so the sum is accumulated in scalar trial order at
    /// every plane width.
    pub fn eval_avg(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState<P>,
    ) -> f64 {
        self.estimate(p, len, trials, seed, 0x5DEECE66D, st, |y, sum| *sum += y)
    }

    /// Mean absolute error against a target over `trials` runs —
    /// bit-identical to the scalar `BitLevelSmurf::abs_error`.
    pub fn abs_error(
        &self,
        p: &[f64],
        target: f64,
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState<P>,
    ) -> f64 {
        self.estimate(p, len, trials, seed, 0x2545F4914F, st, move |y, sum| {
            *sum += (y - target).abs()
        })
    }

    /// Shared chunking loop of the two estimators: derive per-trial seeds
    /// (`(seed + t) * mult`, the scalar formula), run `P::LANES` trials
    /// per pass on staging buffers owned by the scratch, fold outputs in
    /// trial order.
    #[allow(clippy::too_many_arguments)]
    fn estimate(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        mult: u64,
        st: &mut WideRunState<P>,
        mut fold: impl FnMut(f64, &mut f64),
    ) -> f64 {
        assert!(trials > 0);
        // Move the staging buffers out so the scratch can be re-borrowed
        // by eval_trials (capacity is preserved; no steady-state alloc).
        let mut seeds = std::mem::take(&mut st.seed_stage);
        let mut out = std::mem::take(&mut st.out_stage);
        seeds.resize(P::LANES, 0);
        out.resize(P::LANES, 0.0);
        let mut sum = 0.0;
        let mut done = 0;
        while done < trials {
            let k = (trials - done).min(P::LANES);
            for (i, s) in seeds.iter_mut().enumerate().take(k) {
                *s = seed.wrapping_add((done + i) as u64).wrapping_mul(mult);
            }
            self.eval_trials(p, len, &seeds[..k], st, &mut out);
            for &y in &out[..k] {
                fold(y, &mut sum);
            }
            done += k;
        }
        st.seed_stage = seeds;
        st.out_stage = out;
        sum / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::analytic::AnalyticSmurf;
    use crate::testing::{check, UnitVec};

    fn euclid_w() -> Vec<f64> {
        vec![
            0.0, 0.6083, 0.0474, 0.6911, //
            0.6083, 0.3749, 0.4527, 0.8372, //
            0.0474, 0.4527, 0.0159, 0.5946, //
            0.6911, 0.8372, 0.5946, 0.9846,
        ]
    }

    fn modes() -> [EntropyMode; 3] {
        [
            EntropyMode::SharedLfsr,
            EntropyMode::IndependentXorshift,
            EntropyMode::SobolCpt,
        ]
    }

    /// Engine pairs the width-parametric suite runs over: the paper's
    /// uniform M=2/N=4 Euclid table and a mixed-radix [3, 5] table
    /// (non-power-of-2 digit planes).
    fn test_engines(mode: EntropyMode) -> Vec<BitLevelSmurf> {
        let mixed_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        vec![
            BitLevelSmurf::new(SmurfConfig::uniform(2, 4), &euclid_w(), mode),
            BitLevelSmurf::new(SmurfConfig::new(vec![3, 5]), &mixed_w, mode),
        ]
    }

    /// The tentpole contract at width `P`: every wide lane equals the
    /// scalar simulator run with that lane's seed, bit-exactly — across
    /// all 3 entropy modes, mixed radices, and partial (non-multiple-of-
    /// P::LANES) tails.
    fn lanes_match_scalar_at_width<P: BitPlane>() {
        for mode in modes() {
            for scalar in test_engines(mode) {
                let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
                let mut st = wide.make_run_state();
                let m = scalar.config().num_vars();
                let p: Vec<f64> = (0..m).map(|j| 0.25 + 0.35 * j as f64).collect();
                // Full word, odd tails, single lane, one-past-a-u64-word.
                for lanes in [P::LANES, P::LANES - 1, 65.min(P::LANES), 7, 1] {
                    let seeds: Vec<u64> =
                        (0..lanes as u64).map(|l| l * 0x9E37 + 5).collect();
                    let mut out = vec![0.0f64; lanes];
                    wide.eval_trials(&p, 96, &seeds, &mut st, &mut out);
                    for (l, &s) in seeds.iter().enumerate() {
                        assert_eq!(
                            out[l],
                            scalar.eval(&p, 96, s),
                            "{mode:?} {} lanes={lanes} l={l}",
                            scalar.config()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lanes_match_scalar_u64() {
        lanes_match_scalar_at_width::<u64>();
    }

    #[test]
    fn lanes_match_scalar_u64x4() {
        lanes_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn lanes_match_scalar_u64x8() {
        lanes_match_scalar_at_width::<[u64; 8]>();
    }

    /// Randomized variant of the lane contract on the Euclid table (the
    /// original PR 1 property test, kept at the default width).
    #[test]
    fn prop_lanes_match_scalar_eval() {
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
            check(31, 8, &UnitVec { len: 2 }, |p| {
                let mut st = wide.make_run_state();
                let seeds: Vec<u64> =
                    (0..64).map(|l| (l as u64) * 0x9E37 + p[0].to_bits()).collect();
                let mut out = [0.0f64; 64];
                wide.eval_trials(p, 96, &seeds, &mut st, &mut out);
                seeds
                    .iter()
                    .enumerate()
                    .all(|(l, &s)| out[l] == scalar.eval(p, 96, s))
            });
        }
    }

    /// `eval_points` at width `P`: distinct inputs per lane, one trial
    /// each, including a tail chunk shape.
    fn points_match_scalar_at_width<P: BitPlane>() {
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            for n in [P::LANES, P::LANES - 3, 5] {
                let pts: Vec<Vec<f64>> = (0..n)
                    .map(|i| vec![(i % 8) as f64 / 7.0, (i % 6) as f64 / 5.0])
                    .collect();
                let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
                let seeds: Vec<u64> = (0..n).map(|i| 0x5EED ^ i as u64).collect();
                let mut out = vec![0.0f64; n];
                wide.eval_points(&refs, 64, &seeds, &mut st, &mut out);
                for (i, p) in refs.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        scalar.eval(p, 64, seeds[i]),
                        "{mode:?} n={n} point {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn eval_points_matches_scalar_u64() {
        points_match_scalar_at_width::<u64>();
    }

    #[test]
    fn eval_points_matches_scalar_u64x4() {
        points_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn eval_points_matches_scalar_u64x8() {
        points_match_scalar_at_width::<[u64; 8]>();
    }

    /// The estimators must be bit-identical to the scalar reference at
    /// every width — including trial counts that straddle the chunk
    /// boundary of the width under test.
    fn estimators_match_scalar_at_width<P: BitPlane>() {
        let cfg = SmurfConfig::uniform(2, 4);
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            for trials in [1usize, 8, P::LANES - 1, P::LANES, P::LANES + 5, 2 * P::LANES] {
                let a = wide.eval_avg(&[0.3, 0.4], 64, trials, 9, &mut st);
                let b = scalar.eval_avg_scalar(&[0.3, 0.4], 64, trials, 9);
                assert_eq!(a, b, "{mode:?} trials={trials}");
            }
            let a = wide.abs_error(&[0.6, 0.2], 0.63, 64, P::LANES + 7, 7, &mut st);
            let b = scalar.abs_error_scalar(&[0.6, 0.2], 0.63, 64, P::LANES + 7, 7);
            assert_eq!(a, b, "{mode:?} abs_error");
        }
    }

    #[test]
    fn eval_avg_bit_identical_to_scalar_reference() {
        estimators_match_scalar_at_width::<u64>();
    }

    #[test]
    fn eval_avg_bit_identical_u64x4() {
        estimators_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn eval_avg_bit_identical_u64x8() {
        estimators_match_scalar_at_width::<[u64; 8]>();
    }

    /// All compiled widths agree with each other on identical seed sets
    /// (implied by the scalar contract, but cheap to pin directly).
    #[test]
    fn widths_agree_lane_for_lane() {
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let w64 = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
        let w256 = WideBitLevelSmurf::<[u64; 4]>::from_scalar(&scalar);
        let seeds: Vec<u64> = (0..64u64).map(|l| l * 31 + 5).collect();
        let p = [0.3, 0.7];
        let mut out64 = vec![0.0f64; 64];
        let mut out256 = vec![0.0f64; 64];
        w64.eval_trials(&p, 128, &seeds, &mut w64.make_run_state(), &mut out64);
        w256.eval_trials(&p, 128, &seeds, &mut w256.make_run_state(), &mut out256);
        assert_eq!(out64, out256);
        #[cfg(feature = "wide512")]
        {
            let w512 = WideBitLevelSmurf::<[u64; 8]>::from_scalar(&scalar);
            let mut out512 = vec![0.0f64; 64];
            w512.eval_trials(&p, 128, &seeds, &mut w512.make_run_state(), &mut out512);
            assert_eq!(out64, out512);
        }
    }

    #[test]
    fn long_stream_converges_to_analytic_wide() {
        // Mirror of the scalar `long_stream_converges_to_analytic`, driven
        // through the wide engine at the auto-selected width.
        let cfg = SmurfConfig::uniform(2, 4);
        let w = euclid_w();
        let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
        let wide =
            WideBitLevelSmurf::<MaxPlane>::new(cfg, &w, EntropyMode::IndependentXorshift);
        let mut st = wide.make_run_state();
        for p in [[0.3, 0.4], [0.7, 0.2], [0.5, 0.5]] {
            let y_inf = analytic.eval(&p);
            let y_hw = wide.eval_avg(&p, 4096, 16, 1, &mut st);
            assert!(
                (y_hw - y_inf).abs() < 0.02,
                "p={p:?}: wide={y_hw} analytic={y_inf}"
            );
        }
    }

    #[test]
    fn run_state_reuse_across_shapes() {
        // One RunState must serve trials → points → trials without any
        // cross-contamination, at the widest default plane.
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let wide = WideBitLevelSmurf::<MaxPlane>::from_scalar(&scalar);
        let mut st = wide.make_run_state();
        let p = [0.25, 0.65];
        let seeds = [3u64, 99, 1234];
        let mut out = [0.0f64; 3];
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        let first = out;
        let pts: Vec<Vec<f64>> = vec![vec![0.9, 0.1], vec![0.2, 0.2]];
        let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
        let mut pout = [0.0f64; 2];
        wide.eval_points(&refs, 32, &[1, 2], &mut st, &mut pout);
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        assert_eq!(first, out, "RunState reuse must be deterministic");
    }

    #[test]
    fn scratch_adapts_across_configs_and_modes() {
        // One WideRunState (the thread-local sharing shape) must serve
        // engines of different arity/radix AND different entropy modes
        // (the in-place reseed slots change kind), bit-identically to a
        // per-engine make_run_state.
        let big_cfg = SmurfConfig::new(vec![3, 5]);
        let big_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        let big = WideBitLevelSmurf::<u64>::new(big_cfg, &big_w, EntropyMode::SharedLfsr);
        let small = WideBitLevelSmurf::<u64>::new(
            SmurfConfig::uniform(2, 4),
            &euclid_w(),
            EntropyMode::IndependentXorshift,
        );
        let sobol = WideBitLevelSmurf::<u64>::new(
            SmurfConfig::uniform(2, 4),
            &euclid_w(),
            EntropyMode::SobolCpt,
        );
        let mut shared = WideRunState::new();
        let seeds = [1u64, 2, 3];
        let mut got = [0.0f64; 3];
        let mut want = [0.0f64; 3];
        for engine in [&big, &small, &sobol, &big, &sobol, &small] {
            let p = vec![0.4; engine.config().num_vars()];
            engine.eval_trials(&p, 48, &seeds, &mut shared, &mut got);
            engine.eval_trials(&p, 48, &seeds, &mut engine.make_run_state(), &mut want);
            assert_eq!(got, want, "{}", engine.config());
        }
    }

    #[test]
    fn thread_scratch_matches_owned_state() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::<u64>::new(cfg.clone(), &euclid_w(), EntropyMode::SobolCpt);
        let mut owned = wide.make_run_state();
        let a = wide.eval_avg(&[0.3, 0.4], 64, 40, 11, &mut owned);
        let b = with_thread_scratch(|st| wide.eval_avg(&[0.3, 0.4], 64, 40, 11, st));
        assert_eq!(a, b);
        // And the per-width scratches are independent statics.
        let wide4 = WideBitLevelSmurf::<[u64; 4]>::new(cfg, &euclid_w(), EntropyMode::SobolCpt);
        let c = with_thread_scratch(|st| wide4.eval_avg(&[0.3, 0.4], 64, 40, 11, st));
        assert_eq!(a, c);
    }

    #[test]
    #[should_panic]
    fn rejects_too_many_lanes() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::<u64>::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let mut st = wide.make_run_state();
        let seeds = vec![0u64; 65];
        let mut out = vec![0.0f64; 65];
        wide.eval_trials(&[0.5, 0.5], 16, &seeds, &mut st, &mut out);
    }
}

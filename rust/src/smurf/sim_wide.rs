//! Wide (bit-sliced) SMURF simulator: `P::LANES` independent bitstream
//! trials per clock cycle (64, 256 or 512 — see *The plane abstraction*
//! below).
//!
//! # The bit-slicing scheme
//!
//! The scalar simulator ([`super::sim::BitLevelSmurf`]) walks Fig. 6 one
//! bit per cycle per trial: every θ-gate compare, FSM branch and CPT MUX
//! load is a data-dependent scalar operation, and the random comparator
//! bits make the FSM branches ~50% mispredicted. SC bitstreams are the
//! canonical bit-parallel workload, so this engine transposes the problem:
//! every 16-bit datapath word is stored as 16 *bit planes*, where plane
//! `b` is a word whose lane `l` belongs to lane (= trial or batch point)
//! `l`. All lanes then move through one clock of the whole
//! comparator → FSM → CPT pipeline in a few dozen branch-free plane ops.
//!
//! Mapping back to the Fig. 6 blocks:
//!
//! - **RNG + delayed branches (§III-A)** — [`crate::sc::rng::WideLfsr16`]
//!   keeps the 16 LFSR register bits as planes in a ring buffer; one clock
//!   of all lanes is "compute the feedback plane, rotate the head".
//!   Per-lane branch delays are applied at seed time with the GF(2) jump
//!   basis ([`crate::sc::rng::Lfsr16::jump_basis`]). Sobol output sampling
//!   is a plane ripple-carry counter read in bit-reversed plane order;
//!   xorshift64* lanes step scalarly (the 64-bit multiply does not slice)
//!   but still feed the packed pipeline.
//! - **Input θ-gates** — a 16-bit `rand < threshold` compare is folded
//!   MSB-first over the planes ([`crate::sc::sng::wide_lt_const`]): ~2
//!   plane ops per bit yield every lane's verdict, i.e. the M comparator
//!   columns of Fig. 6 run `P::LANES` trials at a time.
//! - **Chained N-state FSMs** — [`crate::fsm::chain_wide::WideChainFsm`]
//!   holds each chain's state index as `ceil(log2 N)` planes; a clock edge
//!   is a masked ripple-carry **saturating add** (lanes whose input bit is
//!   1 and not yet at `N-1`) followed by a masked ripple-borrow
//!   **saturating sub** — plane logic, no branches.
//! - **Universal-radix codeword + CPT MUX** — each FSM exposes one-hot
//!   per-digit lane masks; ANDing one mask per variable gives `eq[t]`, the
//!   lanes whose codeword selects coefficient `w_t`. The CPT-gate ORs each
//!   coefficient's threshold bits into shared planes under its `eq[t]`
//!   mask ([`crate::sc::cpt::CptGate::threshold_planes`]) — the AND-OR MUX
//!   tree of Fig. 6 in plane form — and one plane-vs-plane compare
//!   ([`crate::sc::sng::wide_lt_planes`]) samples every lane's output bit.
//! - **Output counter** — output masks accumulate into a *vertical
//!   counter* (one plane per count bit, ripple carry), so per-cycle cost
//!   is O(1) amortized; per-lane totals are read out once at the end.
//!
//! Lanes are fully independent, so the engine serves two shapes through
//! the same core: `eval_trials` (one input point, up to `P::LANES`
//! Monte-Carlo trials — the [`eval_avg`](WideBitLevelSmurf::eval_avg)
//! estimator) and `eval_points` (up to `P::LANES` distinct batch points,
//! one trial each — the coordinator's `Engine::BitLevel` path). Both are
//! bit-exact matches of the scalar simulator lane-for-lane given the same
//! per-lane seeds: same LFSR branch delays, same xorshift seeding formula,
//! same Sobol counter phase, same θ-gate quantization, same within-cycle
//! ordering.
//!
//! # The plane abstraction
//!
//! Every operation above is lane-wise boolean algebra, so the plane type
//! is a trait — [`crate::sc::plane::BitPlane`] — and the entire pipeline
//! (entropy lanes, comparators, chain FSMs, CPT MUX, vertical counters,
//! this simulator) is generic over it. `P` defaults to `u64` (64 lanes,
//! the PR 1 engine, public behavior unchanged); `[u64; 4]` carries 256
//! lanes as straight-line array ops that LLVM autovectorizes to AVX2 /
//! NEON, and `[u64; 8]` (cargo feature `wide512`) carries 512 for
//! AVX-512 targets. [`MaxPlane`] names the widest plane compiled into
//! the build; the batch entry points
//! ([`crate::smurf::approximator::SmurfApproximator::eval_bitstream_points_into`],
//! `SmurfActivation::eval_bitlevel_batch`, the coordinator's `BitLevel`
//! chunking) pick it automatically and chunk work by
//! [`MAX_LANES`]` = MaxPlane::LANES`.
//!
//! **Adding a width** is four one-line steps: implement `BitPlane` for
//! the new word (see `impl_bitplane_words!` in [`crate::sc::plane`]),
//! give it a thread scratch with the `impl_thread_scratch!` line below,
//! register it in `for_each_plane_width!` (which fans every
//! width-parametric test suite out over it), and add per-width `#[test]`
//! wrappers to the lane-equivalence suite in this module. Nothing else
//! changes — no engine code mentions a concrete plane type.
//!
//! **Tail masking.** A run of `k < P::LANES` lanes never masks planes:
//! idle lanes are seeded to the LFSR all-zeros fixpoint (or simply have
//! no xorshift generator), their FSM/counter bits compute garbage
//! harmlessly, and the readout loop only decodes the first `k` lanes —
//! exactly the convention the 64-lane engine has used since PR 1, now at
//! every width. Callers chunk a batch by `P::LANES` and pass the
//! partially-filled tail as a short `seeds`/`points` slice.
//!
//! All scratch state lives in a caller-owned [`WideRunState`], so repeated
//! evaluations are allocation-free end-to-end.

use super::config::SmurfConfig;
use super::sim::{BitLevelSmurf, EntropyMode};
use crate::fsm::chain_wide::WideChainFsm;
use crate::sc::cpt::CptGate;
use crate::sc::fault::{vote3, BitFaultPlan, NoFaults, WideFaultHook, WideFaultState};
use crate::sc::plane::BitPlane;
use crate::sc::rng::{planes_from_lanes, Lfsr16, WideLfsr16, WideSobol16, WideXorShift64};
use crate::sc::sng::{wide_lt_const, wide_lt_planes, ThetaGate};

/// Max count-bit planes in the output counter: supports `len < 2^40`.
const COUNT_PLANES: usize = 41;

/// Lane count of the default (`u64`) plane. Kept for callers that reason
/// about the base word width; batch chunking should use [`MAX_LANES`].
pub const LANES: usize = 64;

/// The widest compiled plane and its lane count now live with the plane
/// substrate itself ([`crate::sc::plane`]) so that the SC-level engines
/// (e.g. the wide SC-PwMM multiply, [`crate::sc::pwmm_wide`]) can chunk
/// by them without depending on this module; re-exported here because
/// every historical consumer of the wide SMURF engine names them through
/// this path.
pub use crate::sc::plane::{MaxPlane, MAX_LANES};

/// Devirtualized wide entropy source (mirrors the scalar `RngKind`).
// The xorshift lanes are heap-backed inside `WideXorShift64` (reseeded in
// place), so the three variants are of comparable size — the PR 2
// `allow(large_enum_variant)` is gone with the inline 64-lane array.
#[derive(Clone, Debug)]
enum WideRng<P: BitPlane> {
    Lfsr(WideLfsr16<P>),
    Xor(WideXorShift64<P>),
    Sobol(WideSobol16<P>),
}

impl<P: BitPlane> WideRng<P> {
    /// One clock for all lanes, then the comparator mask against a
    /// threshold shared by every lane.
    #[inline(always)]
    fn next_lt_const(&mut self, threshold: u16) -> P {
        match self {
            WideRng::Lfsr(r) => r.next_lt_const(threshold),
            WideRng::Xor(r) => r.next_lt_const(threshold),
            WideRng::Sobol(r) => r.next_lt_const(threshold),
        }
    }

    /// One clock for all lanes, materializing this cycle's rand planes.
    #[inline(always)]
    fn next_planes_into(&mut self, out: &mut [P; 16]) {
        match self {
            WideRng::Lfsr(r) => r.next_planes_into(out),
            WideRng::Xor(r) => r.next_planes_into(out),
            WideRng::Sobol(r) => r.next_planes_into(out),
        }
    }
}

/// Reseed a scratch slot as an LFSR bank in place; the slot is only
/// reconstructed when the scratch last served a different entropy mode.
fn set_lfsr<P: BitPlane>(slot: &mut WideRng<P>, states: &[u16]) {
    if let WideRng::Lfsr(r) = slot {
        r.reseed(states);
    } else {
        *slot = WideRng::Lfsr(WideLfsr16::from_lane_states(states));
    }
}

/// Reseed a scratch slot as a xorshift bank in place (reuses the heap
/// lane buffer — the allocation-free steady-state path).
fn set_xor<P: BitPlane>(slot: &mut WideRng<P>, seeds: &[u64]) {
    if let WideRng::Xor(r) = slot {
        r.reseed(seeds);
    } else {
        *slot = WideRng::Xor(WideXorShift64::from_seeds(seeds));
    }
}

/// Reseed a scratch slot as a Sobol counter bank in place.
fn set_sobol<P: BitPlane>(slot: &mut WideRng<P>, counters: &[u16]) {
    if let WideRng::Sobol(r) = slot {
        r.reseed(counters);
    } else {
        *slot = WideRng::Sobol(WideSobol16::from_lane_counters(counters));
    }
}

/// Per-input-gate threshold: one shared value (`eval_trials` — every lane
/// evaluates the same point) or per-lane planes (`eval_points`).
#[derive(Clone, Debug)]
enum GateThreshold<P: BitPlane> {
    Shared(u16),
    PerLane([P; 16]),
}

/// Caller-owned scratch for wide evaluations. Construct with
/// [`WideRunState::new`] (or [`WideBitLevelSmurf::make_run_state`]);
/// every buffer is reused across runs, so steady-state evaluation
/// performs no heap allocation. One scratch serves engines of *different*
/// configurations: each eval entry point resizes the per-configuration
/// buffers to fit before running (allocation-free once warmed to the
/// largest configuration seen).
pub struct WideRunState<P: BitPlane = u64> {
    fsms: Vec<WideChainFsm<P>>,
    input_rngs: Vec<WideRng<P>>,
    cpt_rng: WideRng<P>,
    gate_thresholds: Vec<GateThreshold<P>>,
    /// Per-variable one-hot digit masks, flattened (`digit_offsets`).
    digit_masks: Vec<P>,
    /// Per-coefficient select masks (`eq[t]` = lanes selecting `w_t`).
    eq: Vec<P>,
    rand_planes: [P; 16],
    thresh_planes: [P; 16],
    count_planes: [P; COUNT_PLANES],
    /// Reseed staging: per-lane 16-bit LFSR states / Sobol counters.
    lane_u16: Vec<u16>,
    /// Reseed staging: per-lane xorshift seeds.
    lane_u64: Vec<u64>,
    /// Estimator staging: per-chunk trial seeds (`eval_avg`/`abs_error`).
    seed_stage: Vec<u64>,
    /// Estimator staging: per-chunk lane outputs.
    out_stage: Vec<f64>,
    /// TMR staging: the tripled seed set of `eval_trials_tmr` (cannot
    /// reuse `lane_u64` — `reset_entropy` consumes it while the tripled
    /// seeds must stay live).
    tmr_stage: Vec<u64>,
    /// Fault-stream scratch, re-armed from the engine's plan per run;
    /// disarmed (and never touched) when the engine has no plan.
    fault: WideFaultState<P>,
}

impl<P: BitPlane> WideRunState<P> {
    /// Empty scratch; buffers grow (and shrink) to fit whichever engine
    /// uses it next, so one instance can be shared across functions of
    /// different arities/radices.
    pub fn new() -> Self {
        Self {
            fsms: Vec::new(),
            input_rngs: Vec::new(),
            cpt_rng: WideRng::Sobol(WideSobol16::from_lane_counters(&[])),
            gate_thresholds: Vec::new(),
            digit_masks: Vec::new(),
            eq: Vec::new(),
            rand_planes: [P::zero(); 16],
            thresh_planes: [P::zero(); 16],
            count_planes: [P::zero(); COUNT_PLANES],
            lane_u16: Vec::new(),
            lane_u64: Vec::new(),
            seed_stage: Vec::new(),
            out_stage: Vec::new(),
            tmr_stage: Vec::new(),
            fault: WideFaultState::default(),
        }
    }
}

impl<P: BitPlane> Default for WideRunState<P> {
    fn default() -> Self {
        Self::new()
    }
}

/// Plane widths that own a per-thread [`WideRunState`] scratch. One
/// thread-local static exists per width (they cannot share one: the
/// scratch type is width-parametric), created on first use.
pub trait ThreadScratch: BitPlane {
    /// Run `f` with this thread's shared scratch for this plane width.
    /// Do not call reentrantly from inside `f` — the scratch is a
    /// `RefCell` and a nested borrow panics.
    fn with_scratch<R>(f: impl FnOnce(&mut WideRunState<Self>) -> R) -> R;
}

macro_rules! impl_thread_scratch {
    ($ty:ty) => {
        impl ThreadScratch for $ty {
            fn with_scratch<R>(f: impl FnOnce(&mut WideRunState<Self>) -> R) -> R {
                thread_local! {
                    static SCRATCH: std::cell::RefCell<WideRunState<$ty>> =
                        std::cell::RefCell::new(WideRunState::new());
                }
                SCRATCH.with(|s| f(&mut s.borrow_mut()))
            }
        }
    };
}

impl_thread_scratch!(u64);
impl_thread_scratch!([u64; 4]);
#[cfg(feature = "wide512")]
impl_thread_scratch!([u64; 8]);

/// Run `f` with this thread's shared [`WideRunState`] scratch for the
/// inferred plane width. The buffers persist for the life of the thread,
/// so repeated evaluations (the coordinator's per-worker batches, the
/// estimator routing in `BitLevelSmurf::eval_avg`, the NN activation
/// layers) are allocation-free after the first call without every caller
/// owning its own state. Do not call it reentrantly from inside `f` — the
/// scratch is a `RefCell` and a nested borrow panics.
pub fn with_thread_scratch<P: ThreadScratch, R>(
    f: impl FnOnce(&mut WideRunState<P>) -> R,
) -> R {
    P::with_scratch(f)
}

/// Wide bit-sliced SMURF instance over plane type `P` (default: `u64`,
/// 64 lanes). Shares coefficients/entropy semantics with a scalar
/// [`BitLevelSmurf`]; see the module docs for the scheme.
#[derive(Clone, Debug)]
pub struct WideBitLevelSmurf<P: BitPlane = u64> {
    cfg: SmurfConfig,
    cpt: CptGate,
    mode: EntropyMode,
    /// `digits[t * M + j]` = variable `j`'s digit of codeword `t`.
    digits: Vec<u16>,
    /// Start of variable `j`'s digit-mask block in `WideRunState::digit_masks`.
    digit_offsets: Vec<usize>,
    /// LFSR fast-forward bases for branch delays `17*k`, `k in 0..=M`.
    lfsr_jumps: Vec<[u16; 16]>,
    /// Optional bit-level fault plan (see [`crate::sc::fault`] and the
    /// scalar twin field on [`BitLevelSmurf`]). Wide lanes draw
    /// *independent* fault streams per lane, so an armed engine is a
    /// statistical experiment, not lane-equivalent to the scalar run —
    /// but a zero-rate plan stays bit-identical to clean at every width.
    faults: Option<BitFaultPlan>,
    _plane: std::marker::PhantomData<P>,
}

impl<P: BitPlane> WideBitLevelSmurf<P> {
    pub fn new(cfg: SmurfConfig, w: &[f64], mode: EntropyMode) -> Self {
        assert_eq!(w.len(), cfg.num_aggregate_states());
        Self::from_parts(cfg, CptGate::new(w), mode)
    }

    /// Build from a scalar simulator (identical coefficients, config and
    /// entropy wiring — the lane-equivalence contract). The fault plan is
    /// inherited too, so the scalar estimators' wide routing keeps the
    /// faults armed.
    pub fn from_scalar(sim: &BitLevelSmurf) -> Self {
        let mut wide = Self::from_parts(sim.config().clone(), sim.cpt().clone(), sim.mode());
        wide.faults = sim.fault_plan().cloned();
        wide
    }

    fn from_parts(cfg: SmurfConfig, cpt: CptGate, mode: EntropyMode) -> Self {
        let m = cfg.num_vars();
        let bank = cfg.num_aggregate_states();
        // Precompute each codeword's mixed-radix digits once; the hot loop
        // indexes this table instead of doing div/mod per cycle.
        let mut digits = Vec::with_capacity(bank * m);
        for t in 0..bank {
            let mut rem = t;
            for j in 0..m {
                let n = cfg.radix(j);
                digits.push((rem % n) as u16);
                rem /= n;
            }
        }
        let mut digit_offsets = Vec::with_capacity(m);
        let mut off = 0;
        for j in 0..m {
            digit_offsets.push(off);
            off += cfg.radix(j);
        }
        // §III-A branch delays: branch k lags 17*k clocks; k == M feeds
        // the CPT-gate. Precomputed as GF(2) jumps for O(16) lane seeding.
        const DELAY: usize = 17;
        let lfsr_jumps = (0..=m).map(|k| Lfsr16::jump_basis(DELAY * k)).collect();
        Self {
            cfg,
            cpt,
            mode,
            digits,
            digit_offsets,
            lfsr_jumps,
            faults: None,
            _plane: std::marker::PhantomData,
        }
    }

    pub fn config(&self) -> &SmurfConfig {
        &self.cfg
    }

    pub fn mode(&self) -> EntropyMode {
        self.mode
    }

    /// Builder: attach a bit-level fault plan (see [`Self::set_fault_plan`]).
    pub fn with_fault_plan(mut self, plan: BitFaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Attach or remove a bit-level fault plan ([`crate::sc::fault`]).
    pub fn set_fault_plan(&mut self, plan: Option<BitFaultPlan>) {
        self.faults = plan;
    }

    /// The attached fault plan, if any.
    pub fn fault_plan(&self) -> Option<&BitFaultPlan> {
        self.faults.as_ref()
    }

    /// Allocate the reusable scratch buffers for this configuration.
    pub fn make_run_state(&self) -> WideRunState<P> {
        let mut st = WideRunState::new();
        self.prepare(&mut st);
        st
    }

    /// Size the per-configuration buffers (idempotent). Every eval entry
    /// point calls this, so any [`WideRunState`] — including one last
    /// used by an engine of a different shape — is valid scratch.
    fn prepare(&self, st: &mut WideRunState<P>) {
        st.digit_masks.resize(self.cfg.radices().iter().sum::<usize>(), P::zero());
        st.eq.resize(self.cfg.num_aggregate_states(), P::zero());
    }

    /// Seed the entropy lanes exactly like `BitLevelSmurf::make_state`
    /// does per trial: lane `l` reproduces the scalar run with `seeds[l]`.
    /// Slots are reseeded in place (no allocation in steady state).
    fn reset_entropy(&self, seeds: &[u64], st: &mut WideRunState<P>) {
        let m = self.cfg.num_vars();
        let lanes = seeds.len();
        let WideRunState {
            fsms,
            input_rngs,
            cpt_rng,
            lane_u16,
            lane_u64,
            count_planes,
            ..
        } = st;
        // One persistent slot per input gate; kinds only change when the
        // scratch moves between engines of different entropy modes.
        input_rngs.resize_with(m, || WideRng::Sobol(WideSobol16::from_lane_counters(&[])));
        lane_u16.resize(lanes, 0);
        match self.mode {
            EntropyMode::SharedLfsr => {
                for k in 0..=m {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_u16[l] = Lfsr16::jump(base, basis);
                    }
                    let slot = if k < m { &mut input_rngs[k] } else { &mut *cpt_rng };
                    set_lfsr(slot, lane_u16);
                }
            }
            EntropyMode::IndependentXorshift => {
                lane_u64.resize(lanes, 0);
                for k in 0..=m {
                    for (l, &s) in seeds.iter().enumerate() {
                        lane_u64[l] = s
                            .wrapping_mul(crate::util::prng::GOLDEN_GAMMA)
                            .wrapping_add(k as u64 + 1);
                    }
                    let slot = if k < m { &mut input_rngs[k] } else { &mut *cpt_rng };
                    set_xor(slot, lane_u64);
                }
            }
            EntropyMode::SobolCpt => {
                for (k, slot) in input_rngs.iter_mut().enumerate() {
                    let basis = &self.lfsr_jumps[k];
                    for (l, &s) in seeds.iter().enumerate() {
                        let base = (s as u16) | 1;
                        lane_u16[l] = Lfsr16::jump(base, basis);
                    }
                    set_lfsr(slot, lane_u16);
                }
                // Scalar: Sobol::new(seed as u32); only the low 16 counter
                // bits ever reach the bit-reversed 16-bit output.
                for (l, &s) in seeds.iter().enumerate() {
                    lane_u16[l] = s as u16;
                }
                set_sobol(cpt_rng, lane_u16);
            }
        }
        fsms.clear();
        for j in 0..m {
            fsms.push(WideChainFsm::centered(self.cfg.radix(j)));
        }
        *count_planes = [P::zero(); COUNT_PLANES];
    }

    /// The shared lane core: dispatch to the clean ([`NoFaults`],
    /// zero-cost — `run_core` monomorphizes to the pre-fault pipeline) or
    /// fault-hooked instantiation, re-arming the scratch fault streams
    /// from the plan so every run reproduces the same fault pattern.
    fn run(
        &self,
        len: usize,
        lanes: usize,
        vote: Option<usize>,
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        match &self.faults {
            None => self.run_core(len, lanes, vote, st, out, &mut NoFaults),
            Some(plan) => {
                // The fault streams live in the scratch (reused buffers)
                // but are borrowed out for the run so `run_core` can
                // destructure the rest of the scratch.
                let mut faults = std::mem::take(&mut st.fault);
                faults.reset(plan);
                self.run_core(len, lanes, vote, st, out, &mut faults);
                st.fault = faults;
            }
        }
    }

    /// `len` clocks of the Fig. 6 pipeline, then per-lane bitstream means
    /// for the first `lanes` lanes into `out`. Generic over the fault
    /// hook ([`crate::sc::fault`]). `vote: Some(k)` enables the TMR
    /// reduction: lanes `l`, `l+k`, `l+2k` are redundant replicas and the
    /// output plane is majority-voted group-wise before it reaches the
    /// counter — faults upstream of the vote must corrupt two replicas in
    /// the same cycle to survive.
    fn run_core<F: WideFaultHook<P>>(
        &self,
        len: usize,
        lanes: usize,
        vote: Option<usize>,
        st: &mut WideRunState<P>,
        out: &mut [f64],
        faults: &mut F,
    ) {
        assert!(len > 0, "need at least one clock cycle");
        assert!((len as u64) < (1u64 << (COUNT_PLANES - 1)), "stream too long for counter");
        let m = self.cfg.num_vars();
        let bank = self.cfg.num_aggregate_states();
        let WideRunState {
            fsms,
            input_rngs,
            cpt_rng,
            gate_thresholds,
            digit_masks,
            eq,
            rand_planes,
            thresh_planes,
            count_planes,
            ..
        } = st;
        // xtask: hot-loop — per-clock kernel: every allocation here costs
        // L× per evaluation. All plane buffers live in WideRunState and
        // are reused across cycles; nothing below may heap-allocate.
        for _ in 0..len {
            // 1. Input θ-gates sample this cycle's entropy; 2. FSMs
            // transition on the comparator masks (same within-cycle order
            // as the scalar simulator).
            for j in 0..m {
                let up = match &gate_thresholds[j] {
                    // Entropy faults need the rand planes materialized;
                    // the folded compare (`next_lt_const`) and the
                    // materialize-then-compare route produce identical
                    // masks (both are step-then-compare — the route is
                    // pinned by the eval_points suite), so the detour
                    // exists only while the site is armed.
                    GateThreshold::Shared(t) if faults.entropy_armed() => {
                        input_rngs[j].next_planes_into(rand_planes);
                        faults.entropy(rand_planes);
                        wide_lt_const(rand_planes, *t)
                    }
                    GateThreshold::Shared(t) => input_rngs[j].next_lt_const(*t),
                    GateThreshold::PerLane(tp) => {
                        input_rngs[j].next_planes_into(rand_planes);
                        faults.entropy(rand_planes);
                        wide_lt_planes(rand_planes, tp)
                    }
                };
                let up = faults.theta(up);
                fsms[j].step(up);
                if faults.state_armed() {
                    fsms[j].inject(|planes| faults.state(planes));
                }
            }
            // 3. Updated codeword digits → one-hot lane masks → per-
            // coefficient select masks.
            for (j, f) in fsms.iter().enumerate() {
                let off = self.digit_offsets[j];
                f.digit_masks(&mut digit_masks[off..off + f.num_states()]);
            }
            for t in 0..bank {
                let row = &self.digits[t * m..t * m + m];
                let mut mask = P::ones();
                for (j, &d) in row.iter().enumerate() {
                    mask = mask.and(digit_masks[self.digit_offsets[j] + d as usize]);
                    if mask.is_zero() {
                        break;
                    }
                }
                eq[t] = mask;
            }
            // 4. CPT-gate: MUX the per-lane coefficient thresholds in
            // plane form, sample against the CPT entropy branch.
            self.cpt.threshold_planes(eq.as_slice(), thresh_planes);
            cpt_rng.next_planes_into(rand_planes);
            faults.entropy(rand_planes);
            let mut ones = faults.output(wide_lt_planes(rand_planes, thresh_planes));
            // 4b. Optional TMR majority vote over the three lane groups
            // (post-fault, pre-counter — exactly where a hardware voter
            // sits). Only group 0's lanes are decoded.
            if let Some(k) = vote {
                ones = vote3(ones, ones.shift_lanes_down(k), ones.shift_lanes_down(2 * k));
            }
            // 5. Output counter (vertical: one plane per count bit).
            let mut carry = ones;
            let mut b = 0;
            while !carry.is_zero() {
                let (sum, c) = count_planes[b].half_add(carry);
                count_planes[b] = sum;
                carry = c;
                b += 1;
            }
        }
        // Decode per-lane totals from the vertical counter.
        for (l, o) in out.iter_mut().enumerate().take(lanes) {
            let mut count = 0u64;
            for (b, &p) in count_planes.iter().enumerate() {
                count |= (p.lane(l) as u64) << b;
            }
            *o = count as f64 / len as f64;
        }
        // xtask: hot-loop-end
    }

    /// Up to `P::LANES` Monte-Carlo trials of one input point in a single
    /// pass: `out[i]` is bit-exact equal to scalar `eval(p, len, seeds[i])`.
    pub fn eval_trials(
        &self,
        p: &[f64],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        assert_eq!(p.len(), self.cfg.num_vars());
        assert!(
            !seeds.is_empty() && seeds.len() <= P::LANES,
            "1..=P::LANES trials per pass"
        );
        assert!(out.len() >= seeds.len());
        self.prepare(st);
        st.gate_thresholds.clear();
        for &pj in p {
            st.gate_thresholds.push(GateThreshold::Shared(ThetaGate::new(pj).raw()));
        }
        self.reset_entropy(seeds, st);
        self.run(len, seeds.len(), None, st, out);
    }

    /// TMR (triple-modular-redundancy) variant of [`Self::eval_trials`]:
    /// up to `P::LANES / 3` trials per pass, each run as three redundant
    /// lane replicas (same trial seed, lanes `l`, `l + k`, `l + 2k`) whose
    /// output bits are majority-voted per cycle before the counter —
    /// the SC fault-hardening this module's fault model exists to
    /// measure. Fault streams are per-lane-independent, so the replicas
    /// fail independently; with no plan (or a zero-rate plan) the
    /// replicas are identical and the vote is the identity, making the
    /// result bit-equal to `eval_trials` (property-tested).
    pub fn eval_trials_tmr(
        &self,
        p: &[f64],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        let k = self.setup_tmr(p, seeds, st, out);
        self.run(len, k, Some(k), st, out);
    }

    /// Shared setup of the TMR entry points: gate thresholds, tripled
    /// seed set, entropy reset. Returns the lane-group size `k`.
    fn setup_tmr(
        &self,
        p: &[f64],
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) -> usize {
        assert_eq!(p.len(), self.cfg.num_vars());
        let k = seeds.len();
        assert!(
            k > 0 && 3 * k <= P::LANES,
            "1..=P::LANES/3 TMR trials per pass"
        );
        assert!(out.len() >= k);
        self.prepare(st);
        st.gate_thresholds.clear();
        for &pj in p {
            st.gate_thresholds.push(GateThreshold::Shared(ThetaGate::new(pj).raw()));
        }
        let mut tripled = std::mem::take(&mut st.tmr_stage);
        tripled.clear();
        for _ in 0..3 {
            tripled.extend_from_slice(seeds);
        }
        self.reset_entropy(&tripled, st);
        st.tmr_stage = tripled;
        k
    }

    /// Test seam: a TMR run with a caller-supplied fault hook, for
    /// adversarial vote tests (e.g. corrupt exactly one lane group and
    /// prove the vote removes it bit-exactly).
    #[cfg(test)]
    fn eval_trials_tmr_hooked<F: WideFaultHook<P>>(
        &self,
        p: &[f64],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
        faults: &mut F,
    ) {
        let k = self.setup_tmr(p, seeds, st, out);
        self.run_core(len, k, Some(k), st, out, faults);
    }

    /// Up to `P::LANES` distinct batch points, one bitstream trial each:
    /// `out[i]` is bit-exact equal to scalar `eval(points[i], len, seeds[i])`.
    /// This is the coordinator's `Engine::BitLevel` batch shape.
    pub fn eval_points(
        &self,
        points: &[&[f64]],
        len: usize,
        seeds: &[u64],
        st: &mut WideRunState<P>,
        out: &mut [f64],
    ) {
        let m = self.cfg.num_vars();
        assert!(
            !points.is_empty() && points.len() <= P::LANES,
            "1..=P::LANES points per pass"
        );
        assert_eq!(points.len(), seeds.len());
        assert!(out.len() >= points.len());
        self.prepare(st);
        st.lane_u16.resize(points.len(), 0);
        st.gate_thresholds.clear();
        for j in 0..m {
            for (l, pt) in points.iter().enumerate() {
                assert_eq!(pt.len(), m, "point arity mismatch");
                st.lane_u16[l] = ThetaGate::new(pt[j]).raw();
            }
            st.gate_thresholds
                .push(GateThreshold::PerLane(planes_from_lanes(&st.lane_u16)));
        }
        self.reset_entropy(seeds, st);
        self.run(len, points.len(), None, st, out);
    }

    /// Monte-Carlo average over `trials` runs — the same estimator (same
    /// per-trial seed derivation, same summation order, bit-identical
    /// result) as the scalar `BitLevelSmurf::eval_avg`, at `P::LANES`
    /// trials per pass. Chunking never changes the result: lane order is
    /// trial order, so the sum is accumulated in scalar trial order at
    /// every plane width.
    pub fn eval_avg(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState<P>,
    ) -> f64 {
        self.estimate(p, len, trials, seed, 0x5DEECE66D, st, |y, sum| *sum += y)
    }

    /// Mean absolute error against a target over `trials` runs —
    /// bit-identical to the scalar `BitLevelSmurf::abs_error`.
    pub fn abs_error(
        &self,
        p: &[f64],
        target: f64,
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState<P>,
    ) -> f64 {
        self.estimate(p, len, trials, seed, 0x2545F4914F, st, move |y, sum| {
            *sum += (y - target).abs()
        })
    }

    /// TMR variant of [`Self::eval_avg`]: same per-trial seeds, same
    /// fold order — so with no (or a zero-rate) fault plan the result is
    /// bit-identical to `eval_avg` — but every trial runs as three voted
    /// replicas ([`Self::eval_trials_tmr`]), at one third the lanes per
    /// pass. This is the mitigation curve of the `fault_sweep` bench.
    pub fn eval_avg_tmr(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        st: &mut WideRunState<P>,
    ) -> f64 {
        assert!(trials > 0);
        let cap = P::LANES / 3;
        let mut seeds = std::mem::take(&mut st.seed_stage);
        let mut out = std::mem::take(&mut st.out_stage);
        seeds.resize(cap, 0);
        out.resize(cap, 0.0);
        let mut sum = 0.0;
        let mut done = 0;
        while done < trials {
            let k = (trials - done).min(cap);
            for (i, s) in seeds.iter_mut().enumerate().take(k) {
                // The eval_avg per-trial seed formula, verbatim.
                *s = seed.wrapping_add((done + i) as u64).wrapping_mul(0x5DEECE66D);
            }
            self.eval_trials_tmr(p, len, &seeds[..k], st, &mut out);
            for &y in &out[..k] {
                sum += y;
            }
            done += k;
        }
        st.seed_stage = seeds;
        st.out_stage = out;
        sum / trials as f64
    }

    /// Shared chunking loop of the two estimators: derive per-trial seeds
    /// (`(seed + t) * mult`, the scalar formula), run `P::LANES` trials
    /// per pass on staging buffers owned by the scratch, fold outputs in
    /// trial order.
    // justification: the argument list is the full estimator contract
    // (point, stream length, trial budget, seed schedule, scratch, fold) —
    // bundling them into a struct would add a type used exactly twice.
    #[allow(clippy::too_many_arguments)]
    fn estimate(
        &self,
        p: &[f64],
        len: usize,
        trials: usize,
        seed: u64,
        mult: u64,
        st: &mut WideRunState<P>,
        mut fold: impl FnMut(f64, &mut f64),
    ) -> f64 {
        assert!(trials > 0);
        // Move the staging buffers out so the scratch can be re-borrowed
        // by eval_trials (capacity is preserved; no steady-state alloc).
        let mut seeds = std::mem::take(&mut st.seed_stage);
        let mut out = std::mem::take(&mut st.out_stage);
        seeds.resize(P::LANES, 0);
        out.resize(P::LANES, 0.0);
        let mut sum = 0.0;
        let mut done = 0;
        while done < trials {
            let k = (trials - done).min(P::LANES);
            for (i, s) in seeds.iter_mut().enumerate().take(k) {
                *s = seed.wrapping_add((done + i) as u64).wrapping_mul(mult);
            }
            self.eval_trials(p, len, &seeds[..k], st, &mut out);
            for &y in &out[..k] {
                fold(y, &mut sum);
            }
            done += k;
        }
        st.seed_stage = seeds;
        st.out_stage = out;
        sum / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::analytic::AnalyticSmurf;
    use crate::testing::{check, UnitVec};

    fn euclid_w() -> Vec<f64> {
        vec![
            0.0, 0.6083, 0.0474, 0.6911, //
            0.6083, 0.3749, 0.4527, 0.8372, //
            0.0474, 0.4527, 0.0159, 0.5946, //
            0.6911, 0.8372, 0.5946, 0.9846,
        ]
    }

    fn modes() -> [EntropyMode; 3] {
        [
            EntropyMode::SharedLfsr,
            EntropyMode::IndependentXorshift,
            EntropyMode::SobolCpt,
        ]
    }

    /// Engine pairs the width-parametric suite runs over: the paper's
    /// uniform M=2/N=4 Euclid table and a mixed-radix [3, 5] table
    /// (non-power-of-2 digit planes).
    fn test_engines(mode: EntropyMode) -> Vec<BitLevelSmurf> {
        let mixed_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        vec![
            BitLevelSmurf::new(SmurfConfig::uniform(2, 4), &euclid_w(), mode),
            BitLevelSmurf::new(SmurfConfig::new(vec![3, 5]), &mixed_w, mode),
        ]
    }

    /// The tentpole contract at width `P`: every wide lane equals the
    /// scalar simulator run with that lane's seed, bit-exactly — across
    /// all 3 entropy modes, mixed radices, and partial (non-multiple-of-
    /// P::LANES) tails.
    fn lanes_match_scalar_at_width<P: BitPlane>() {
        for mode in modes() {
            for scalar in test_engines(mode) {
                let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
                let mut st = wide.make_run_state();
                let m = scalar.config().num_vars();
                let p: Vec<f64> = (0..m).map(|j| 0.25 + 0.35 * j as f64).collect();
                // Full word, odd tails, single lane, one-past-a-u64-word.
                for lanes in [P::LANES, P::LANES - 1, 65.min(P::LANES), 7, 1] {
                    let seeds: Vec<u64> =
                        (0..lanes as u64).map(|l| l * 0x9E37 + 5).collect();
                    let mut out = vec![0.0f64; lanes];
                    wide.eval_trials(&p, 96, &seeds, &mut st, &mut out);
                    for (l, &s) in seeds.iter().enumerate() {
                        assert_eq!(
                            out[l],
                            scalar.eval(&p, 96, s),
                            "{mode:?} {} lanes={lanes} l={l}",
                            scalar.config()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lanes_match_scalar_u64() {
        lanes_match_scalar_at_width::<u64>();
    }

    #[test]
    fn lanes_match_scalar_u64x4() {
        lanes_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn lanes_match_scalar_u64x8() {
        lanes_match_scalar_at_width::<[u64; 8]>();
    }

    /// Randomized variant of the lane contract on the Euclid table (the
    /// original PR 1 property test, kept at the default width).
    #[test]
    fn prop_lanes_match_scalar_eval() {
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
            check(31, 8, &UnitVec { len: 2 }, |p| {
                let mut st = wide.make_run_state();
                let seeds: Vec<u64> =
                    (0..64).map(|l| (l as u64) * 0x9E37 + p[0].to_bits()).collect();
                let mut out = [0.0f64; 64];
                wide.eval_trials(p, 96, &seeds, &mut st, &mut out);
                seeds
                    .iter()
                    .enumerate()
                    .all(|(l, &s)| out[l] == scalar.eval(p, 96, s))
            });
        }
    }

    /// `eval_points` at width `P`: distinct inputs per lane, one trial
    /// each, including a tail chunk shape.
    fn points_match_scalar_at_width<P: BitPlane>() {
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            for n in [P::LANES, P::LANES - 3, 5] {
                let pts: Vec<Vec<f64>> = (0..n)
                    .map(|i| vec![(i % 8) as f64 / 7.0, (i % 6) as f64 / 5.0])
                    .collect();
                let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
                let seeds: Vec<u64> = (0..n).map(|i| 0x5EED ^ i as u64).collect();
                let mut out = vec![0.0f64; n];
                wide.eval_points(&refs, 64, &seeds, &mut st, &mut out);
                for (i, p) in refs.iter().enumerate() {
                    assert_eq!(
                        out[i],
                        scalar.eval(p, 64, seeds[i]),
                        "{mode:?} n={n} point {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn eval_points_matches_scalar_u64() {
        points_match_scalar_at_width::<u64>();
    }

    #[test]
    fn eval_points_matches_scalar_u64x4() {
        points_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn eval_points_matches_scalar_u64x8() {
        points_match_scalar_at_width::<[u64; 8]>();
    }

    /// The estimators must be bit-identical to the scalar reference at
    /// every width — including trial counts that straddle the chunk
    /// boundary of the width under test.
    fn estimators_match_scalar_at_width<P: BitPlane>() {
        let cfg = SmurfConfig::uniform(2, 4);
        for mode in modes() {
            let scalar = BitLevelSmurf::new(cfg.clone(), &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            for trials in [1usize, 8, P::LANES - 1, P::LANES, P::LANES + 5, 2 * P::LANES] {
                let a = wide.eval_avg(&[0.3, 0.4], 64, trials, 9, &mut st);
                let b = scalar.eval_avg_scalar(&[0.3, 0.4], 64, trials, 9);
                assert_eq!(a, b, "{mode:?} trials={trials}");
            }
            let a = wide.abs_error(&[0.6, 0.2], 0.63, 64, P::LANES + 7, 7, &mut st);
            let b = scalar.abs_error_scalar(&[0.6, 0.2], 0.63, 64, P::LANES + 7, 7);
            assert_eq!(a, b, "{mode:?} abs_error");
        }
    }

    #[test]
    fn eval_avg_bit_identical_to_scalar_reference() {
        estimators_match_scalar_at_width::<u64>();
    }

    #[test]
    fn eval_avg_bit_identical_u64x4() {
        estimators_match_scalar_at_width::<[u64; 4]>();
    }

    #[cfg(feature = "wide512")]
    #[test]
    fn eval_avg_bit_identical_u64x8() {
        estimators_match_scalar_at_width::<[u64; 8]>();
    }

    /// All compiled widths agree with each other on identical seed sets
    /// (implied by the scalar contract, but cheap to pin directly).
    #[test]
    fn widths_agree_lane_for_lane() {
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let w64 = WideBitLevelSmurf::<u64>::from_scalar(&scalar);
        let w256 = WideBitLevelSmurf::<[u64; 4]>::from_scalar(&scalar);
        let seeds: Vec<u64> = (0..64u64).map(|l| l * 31 + 5).collect();
        let p = [0.3, 0.7];
        let mut out64 = vec![0.0f64; 64];
        let mut out256 = vec![0.0f64; 64];
        w64.eval_trials(&p, 128, &seeds, &mut w64.make_run_state(), &mut out64);
        w256.eval_trials(&p, 128, &seeds, &mut w256.make_run_state(), &mut out256);
        assert_eq!(out64, out256);
        #[cfg(feature = "wide512")]
        {
            let w512 = WideBitLevelSmurf::<[u64; 8]>::from_scalar(&scalar);
            let mut out512 = vec![0.0f64; 64];
            w512.eval_trials(&p, 128, &seeds, &mut w512.make_run_state(), &mut out512);
            assert_eq!(out64, out512);
        }
    }

    #[test]
    fn long_stream_converges_to_analytic_wide() {
        // Mirror of the scalar `long_stream_converges_to_analytic`, driven
        // through the wide engine at the auto-selected width.
        let cfg = SmurfConfig::uniform(2, 4);
        let w = euclid_w();
        let analytic = AnalyticSmurf::new(cfg.clone(), w.clone());
        let wide =
            WideBitLevelSmurf::<MaxPlane>::new(cfg, &w, EntropyMode::IndependentXorshift);
        let mut st = wide.make_run_state();
        for p in [[0.3, 0.4], [0.7, 0.2], [0.5, 0.5]] {
            let y_inf = analytic.eval(&p);
            let y_hw = wide.eval_avg(&p, 4096, 16, 1, &mut st);
            assert!(
                (y_hw - y_inf).abs() < 0.02,
                "p={p:?}: wide={y_hw} analytic={y_inf}"
            );
        }
    }

    #[test]
    fn run_state_reuse_across_shapes() {
        // One RunState must serve trials → points → trials without any
        // cross-contamination, at the widest default plane.
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let wide = WideBitLevelSmurf::<MaxPlane>::from_scalar(&scalar);
        let mut st = wide.make_run_state();
        let p = [0.25, 0.65];
        let seeds = [3u64, 99, 1234];
        let mut out = [0.0f64; 3];
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        let first = out;
        let pts: Vec<Vec<f64>> = vec![vec![0.9, 0.1], vec![0.2, 0.2]];
        let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
        let mut pout = [0.0f64; 2];
        wide.eval_points(&refs, 32, &[1, 2], &mut st, &mut pout);
        wide.eval_trials(&p, 64, &seeds, &mut st, &mut out);
        assert_eq!(first, out, "RunState reuse must be deterministic");
    }

    #[test]
    fn scratch_adapts_across_configs_and_modes() {
        // One WideRunState (the thread-local sharing shape) must serve
        // engines of different arity/radix AND different entropy modes
        // (the in-place reseed slots change kind), bit-identically to a
        // per-engine make_run_state.
        let big_cfg = SmurfConfig::new(vec![3, 5]);
        let big_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        let big = WideBitLevelSmurf::<u64>::new(big_cfg, &big_w, EntropyMode::SharedLfsr);
        let small = WideBitLevelSmurf::<u64>::new(
            SmurfConfig::uniform(2, 4),
            &euclid_w(),
            EntropyMode::IndependentXorshift,
        );
        let sobol = WideBitLevelSmurf::<u64>::new(
            SmurfConfig::uniform(2, 4),
            &euclid_w(),
            EntropyMode::SobolCpt,
        );
        let mut shared = WideRunState::new();
        let seeds = [1u64, 2, 3];
        let mut got = [0.0f64; 3];
        let mut want = [0.0f64; 3];
        for engine in [&big, &small, &sobol, &big, &sobol, &small] {
            let p = vec![0.4; engine.config().num_vars()];
            engine.eval_trials(&p, 48, &seeds, &mut shared, &mut got);
            engine.eval_trials(&p, 48, &seeds, &mut engine.make_run_state(), &mut want);
            assert_eq!(got, want, "{}", engine.config());
        }
    }

    #[test]
    fn thread_scratch_matches_owned_state() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::<u64>::new(cfg.clone(), &euclid_w(), EntropyMode::SobolCpt);
        let mut owned = wide.make_run_state();
        let a = wide.eval_avg(&[0.3, 0.4], 64, 40, 11, &mut owned);
        let b = with_thread_scratch(|st| wide.eval_avg(&[0.3, 0.4], 64, 40, 11, st));
        assert_eq!(a, b);
        // And the per-width scratches are independent statics.
        let wide4 = WideBitLevelSmurf::<[u64; 4]>::new(cfg, &euclid_w(), EntropyMode::SobolCpt);
        let c = with_thread_scratch(|st| wide4.eval_avg(&[0.3, 0.4], 64, 40, 11, st));
        assert_eq!(a, c);
    }

    #[test]
    #[should_panic]
    fn rejects_too_many_lanes() {
        let cfg = SmurfConfig::uniform(2, 4);
        let wide = WideBitLevelSmurf::<u64>::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let mut st = wide.make_run_state();
        let seeds = vec![0u64; 65];
        let mut out = vec![0.0f64; 65];
        wide.eval_trials(&[0.5, 0.5], 16, &seeds, &mut st, &mut out);
    }

    use crate::sc::fault::{BitFaultPlan, FaultRates, FaultSite, WideFaultHook};

    /// A zero-rate plan runs the *armed* hooked loop (the engine
    /// dispatches on `Some(plan)`, not on `is_inert`) and must stay
    /// bit-identical to the clean path — all shapes, all entropy modes,
    /// mixed radices, at width `P`.
    fn zero_rate_plan_identity_at_width<P: BitPlane>() {
        for mode in modes() {
            for scalar in test_engines(mode) {
                let clean = WideBitLevelSmurf::<P>::from_scalar(&scalar);
                let armed = clean.clone().with_fault_plan(BitFaultPlan::new(123));
                let m = scalar.config().num_vars();
                let p: Vec<f64> = (0..m).map(|j| 0.3 + 0.3 * j as f64).collect();
                let mut st_c = clean.make_run_state();
                let mut st_a = armed.make_run_state();
                let lanes = P::LANES - 1;
                let seeds: Vec<u64> = (0..lanes as u64).map(|l| l * 0x9E37 + 5).collect();
                let mut out_c = vec![0.0f64; lanes];
                let mut out_a = vec![0.0f64; lanes];
                clean.eval_trials(&p, 96, &seeds, &mut st_c, &mut out_c);
                armed.eval_trials(&p, 96, &seeds, &mut st_a, &mut out_a);
                assert_eq!(out_c, out_a, "{mode:?} eval_trials");
                let pts: Vec<Vec<f64>> = (0..7)
                    .map(|i| (0..m).map(|j| ((i + j) % 5) as f64 / 4.0).collect())
                    .collect();
                let refs: Vec<&[f64]> = pts.iter().map(|v| v.as_slice()).collect();
                clean.eval_points(&refs, 64, &seeds[..7], &mut st_c, &mut out_c);
                armed.eval_points(&refs, 64, &seeds[..7], &mut st_a, &mut out_a);
                assert_eq!(out_c[..7], out_a[..7], "{mode:?} eval_points");
                assert_eq!(
                    clean.eval_avg(&p, 64, P::LANES + 3, 9, &mut st_c),
                    armed.eval_avg(&p, 64, P::LANES + 3, 9, &mut st_a),
                    "{mode:?} eval_avg"
                );
            }
        }
    }

    #[test]
    fn zero_rate_plan_identity() {
        crate::for_each_plane_width!(zero_rate_plan_identity_at_width);
    }

    /// With no faults the three TMR replicas are identical, the vote is
    /// the identity, and both TMR entry points are bit-equal to their
    /// plain counterparts — at width `P`, all entropy modes.
    fn tmr_zero_rate_matches_clean_at_width<P: BitPlane>() {
        for mode in modes() {
            for scalar in test_engines(mode) {
                let clean = WideBitLevelSmurf::<P>::from_scalar(&scalar);
                let armed = clean.clone().with_fault_plan(BitFaultPlan::new(5));
                let m = scalar.config().num_vars();
                let p: Vec<f64> = (0..m).map(|j| 0.45 + 0.2 * j as f64).collect();
                let mut st = clean.make_run_state();
                let k = P::LANES / 3;
                let seeds: Vec<u64> = (0..k as u64).map(|l| l * 77 + 3).collect();
                let mut plain = vec![0.0f64; k];
                let mut tmr = vec![0.0f64; k];
                clean.eval_trials(&p, 96, &seeds, &mut st, &mut plain);
                clean.eval_trials_tmr(&p, 96, &seeds, &mut st, &mut tmr);
                assert_eq!(plain, tmr, "{mode:?} no-plan TMR");
                let mut st_a = armed.make_run_state();
                armed.eval_trials_tmr(&p, 96, &seeds, &mut st_a, &mut tmr);
                assert_eq!(plain, tmr, "{mode:?} zero-rate-plan TMR");
                // Estimator: spans multiple TMR chunks.
                assert_eq!(
                    clean.eval_avg(&p, 64, P::LANES / 3 + 5, 7, &mut st),
                    clean.eval_avg_tmr(&p, 64, P::LANES / 3 + 5, 7, &mut st),
                    "{mode:?} eval_avg_tmr"
                );
            }
        }
    }

    #[test]
    fn tmr_zero_rate_matches_clean() {
        crate::for_each_plane_width!(tmr_zero_rate_matches_clean_at_width);
    }

    /// Corrupt exactly one of the three redundant lane groups (an
    /// adversarial hook flipping every output bit of lanes `k..2k`): the
    /// majority vote must remove the corruption *bit-exactly*.
    fn tmr_outvotes_single_group_corruption_at_width<P: BitPlane>() {
        struct GroupFlip<P> {
            mask: P,
        }
        impl<P: BitPlane> WideFaultHook<P> for GroupFlip<P> {
            fn output(&mut self, p: P) -> P {
                p.xor(self.mask)
            }
        }
        for mode in modes() {
            let cfg = SmurfConfig::uniform(2, 4);
            let scalar = BitLevelSmurf::new(cfg, &euclid_w(), mode);
            let wide = WideBitLevelSmurf::<P>::from_scalar(&scalar);
            let mut st = wide.make_run_state();
            let k = P::LANES / 3;
            let seeds: Vec<u64> = (0..k as u64).map(|l| l * 131 + 17).collect();
            let p = [0.35, 0.55];
            let mut clean = vec![0.0f64; k];
            let mut voted = vec![0.0f64; k];
            wide.eval_trials(&p, 128, &seeds, &mut st, &mut clean);
            let mut mask = P::zero();
            for l in k..2 * k {
                mask.set_lane(l);
            }
            wide.eval_trials_tmr_hooked(
                &p,
                128,
                &seeds,
                &mut st,
                &mut voted,
                &mut GroupFlip { mask },
            );
            assert_eq!(clean, voted, "{mode:?}: 2-of-3 must outvote one dead group");
        }
    }

    #[test]
    fn tmr_outvotes_single_group_corruption() {
        crate::for_each_plane_width!(tmr_outvotes_single_group_corruption_at_width);
    }

    /// Armed output-bit flips: deterministic per plan, and the TMR
    /// estimator must sit closer to the clean value than the unprotected
    /// one (the accuracy-vs-fault-rate claim the fault_sweep bench
    /// curves). Deterministic seeds — no statistical flake.
    #[test]
    fn tmr_reduces_output_fault_error() {
        let cfg = SmurfConfig::uniform(2, 4);
        let scalar = BitLevelSmurf::new(cfg, &euclid_w(), EntropyMode::SharedLfsr);
        let clean_engine = WideBitLevelSmurf::<MaxPlane>::from_scalar(&scalar);
        let plan = BitFaultPlan::new(77)
            .with_site(FaultSite::OutputBit, FaultRates::flips(0.05));
        let faulty = clean_engine.clone().with_fault_plan(plan);
        let mut st = clean_engine.make_run_state();
        // Euclid at [0.9, 0.8] sits near 1.0, far from the 0.5 flips pull
        // toward, so the unprotected bias is large and unambiguous.
        let p = [0.9, 0.8];
        let trials = 64;
        let clean = clean_engine.eval_avg(&p, 2048, trials, 11, &mut st);
        let unprotected = faulty.eval_avg(&p, 2048, trials, 11, &mut st);
        let protected = faulty.eval_avg_tmr(&p, 2048, trials, 11, &mut st);
        let e_raw = (unprotected - clean).abs();
        let e_tmr = (protected - clean).abs();
        assert!(
            e_tmr < e_raw,
            "TMR must shrink the fault bias: raw={e_raw} tmr={e_tmr}"
        );
        // ~5% flips toward 0.5 bias the mean by ~r(1-2y); TMR's residual
        // is O(r^2). Sanity-bound both so the test fails loudly if the
        // fault model silently stops firing.
        assert!(e_raw > 0.01, "5% output flips must visibly bias the mean");
        assert!(e_tmr < e_raw / 2.0, "vote must remove most of the bias");
        // Determinism of the armed engine.
        assert_eq!(
            faulty.eval_avg(&p, 256, 16, 3, &mut st),
            faulty.eval_avg(&p, 256, 16, 3, &mut st)
        );
    }

    /// FSM-state faults on a non-power-of-two radix exercise the wide
    /// clamp; outputs must stay means of valid bits.
    #[test]
    fn wide_fsm_faults_stay_in_range() {
        let mixed_w: Vec<f64> = (0..15).map(|i| (i as f64 + 0.5) / 15.0).collect();
        let wide = WideBitLevelSmurf::<u64>::new(
            SmurfConfig::new(vec![3, 5]),
            &mixed_w,
            EntropyMode::SharedLfsr,
        )
        .with_fault_plan(
            BitFaultPlan::new(31).with_site(FaultSite::FsmState, FaultRates::flips(0.1)),
        );
        let mut st = wide.make_run_state();
        let seeds: Vec<u64> = (0..64u64).collect();
        let mut out = vec![0.0f64; 64];
        wide.eval_trials(&[0.4, 0.7], 512, &seeds, &mut st, &mut out);
        for (l, &y) in out.iter().enumerate() {
            assert!((0.0..=1.0).contains(&y), "lane {l}: {y}");
        }
    }
}

//! Datasets for the CNN experiments (paper §IV-B, Table IV).
//!
//! MNIST is not redistributable inside this offline environment, so
//! [`synth_mnist`] procedurally renders a seeded, MNIST-shaped digit
//! corpus (28×28 grayscale, 10 classes, stroke-based glyphs with affine +
//! elastic jitter). When real MNIST IDX files are present (set
//! `MNIST_DIR`), [`idx`] loads them instead — the experiment code prefers
//! real data automatically. See DESIGN.md for why the substitution
//! preserves Table IV's comparison.

pub mod idx;
pub mod synth_mnist;

/// A labelled image dataset with MNIST geometry.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// `[n, 28*28]` row-major pixels in `[0,1]`.
    pub images: Vec<f32>,
    /// `[n]` class labels `0..=9`.
    pub labels: Vec<u8>,
    pub n: usize,
}

impl Dataset {
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * 28 * 28..(i + 1) * 28 * 28]
    }

    /// Deterministic train/test split helper.
    pub fn take(&self, start: usize, count: usize) -> Dataset {
        let end = (start + count).min(self.n);
        Dataset {
            images: self.images[start * 784..end * 784].to_vec(),
            labels: self.labels[start..end].to_vec(),
            n: end - start,
        }
    }
}

/// Load the experiment corpus: real MNIST if `MNIST_DIR` points at the
/// IDX files, else the synthetic corpus with the given seed.
pub fn load_corpus(n_train: usize, n_test: usize, seed: u64) -> (Dataset, Dataset) {
    if let Ok(dir) = std::env::var("MNIST_DIR") {
        if let Ok(pair) = idx::load_mnist_dir(&dir, n_train, n_test) {
            return pair;
        }
    }
    (
        synth_mnist::generate(n_train, seed),
        synth_mnist::generate(n_test, seed.wrapping_add(0x5EED_7E57)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_slices_consistently() {
        let d = synth_mnist::generate(20, 1);
        let s = d.take(5, 10);
        assert_eq!(s.n, 10);
        assert_eq!(s.image(0), d.image(5));
        assert_eq!(s.labels[0], d.labels[5]);
    }

    #[test]
    fn take_clamps_at_end() {
        let d = synth_mnist::generate(10, 1);
        let s = d.take(8, 10);
        assert_eq!(s.n, 2);
    }

    #[test]
    fn load_corpus_returns_requested_sizes() {
        let (tr, te) = load_corpus(30, 10, 3);
        assert_eq!(tr.n, 30);
        assert_eq!(te.n, 10);
        // train and test are disjoint draws (different seeds).
        assert_ne!(tr.image(0), te.image(0));
    }
}

//! IDX (LeCun MNIST) file parser — used automatically when real MNIST is
//! available via `MNIST_DIR`.

use super::Dataset;
use std::io::Read;
use std::path::Path;

/// Parse an IDX3 (images) file: magic 0x00000803, dims [n, rows, cols].
pub fn parse_idx3(bytes: &[u8]) -> Result<(usize, usize, usize, Vec<u8>), String> {
    if bytes.len() < 16 {
        return Err("idx3 too short".into());
    }
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    if magic != 0x0000_0803 {
        return Err(format!("bad idx3 magic {magic:#x}"));
    }
    let n = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let rows = u32::from_be_bytes(bytes[8..12].try_into().unwrap()) as usize;
    let cols = u32::from_be_bytes(bytes[12..16].try_into().unwrap()) as usize;
    let want = 16 + n * rows * cols;
    if bytes.len() < want {
        return Err(format!("idx3 truncated: {} < {want}", bytes.len()));
    }
    Ok((n, rows, cols, bytes[16..want].to_vec()))
}

/// Parse an IDX1 (labels) file: magic 0x00000801.
pub fn parse_idx1(bytes: &[u8]) -> Result<Vec<u8>, String> {
    if bytes.len() < 8 {
        return Err("idx1 too short".into());
    }
    let magic = u32::from_be_bytes(bytes[0..4].try_into().unwrap());
    if magic != 0x0000_0801 {
        return Err(format!("bad idx1 magic {magic:#x}"));
    }
    let n = u32::from_be_bytes(bytes[4..8].try_into().unwrap()) as usize;
    if bytes.len() < 8 + n {
        return Err("idx1 truncated".into());
    }
    Ok(bytes[8..8 + n].to_vec())
}

fn read_file(path: &Path) -> Result<Vec<u8>, String> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{}: {e}", path.display()))?
        .read_to_end(&mut buf)
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(buf)
}

fn to_dataset(images: &[u8], labels: &[u8], rows: usize, cols: usize, limit: usize) -> Dataset {
    assert_eq!(rows, 28);
    assert_eq!(cols, 28);
    let n = labels.len().min(limit);
    Dataset {
        images: images[..n * 784].iter().map(|&b| b as f32 / 255.0).collect(),
        labels: labels[..n].to_vec(),
        n,
    }
}

/// Load `train-images-idx3-ubyte` / `train-labels-idx1-ubyte` /
/// `t10k-…` from a directory.
pub fn load_mnist_dir(dir: &str, n_train: usize, n_test: usize) -> Result<(Dataset, Dataset), String> {
    let d = Path::new(dir);
    let (tn, tr_r, tr_c, tr_img) = parse_idx3(&read_file(&d.join("train-images-idx3-ubyte"))?)?;
    let tr_lbl = parse_idx1(&read_file(&d.join("train-labels-idx1-ubyte"))?)?;
    let (sn, te_r, te_c, te_img) = parse_idx3(&read_file(&d.join("t10k-images-idx3-ubyte"))?)?;
    let te_lbl = parse_idx1(&read_file(&d.join("t10k-labels-idx1-ubyte"))?)?;
    if tn != tr_lbl.len() || sn != te_lbl.len() {
        return Err("image/label count mismatch".into());
    }
    Ok((
        to_dataset(&tr_img, &tr_lbl, tr_r, tr_c, n_train),
        to_dataset(&te_img, &te_lbl, te_r, te_c, n_test),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx3(n: usize) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&0x0000_0803u32.to_be_bytes());
        v.extend_from_slice(&(n as u32).to_be_bytes());
        v.extend_from_slice(&28u32.to_be_bytes());
        v.extend_from_slice(&28u32.to_be_bytes());
        v.resize(v.len() + n * 784, 128u8);
        v
    }

    fn make_idx1(labels: &[u8]) -> Vec<u8> {
        let mut v = Vec::new();
        v.extend_from_slice(&0x0000_0801u32.to_be_bytes());
        v.extend_from_slice(&(labels.len() as u32).to_be_bytes());
        v.extend_from_slice(labels);
        v
    }

    #[test]
    fn roundtrip_idx3() {
        let raw = make_idx3(3);
        let (n, r, c, px) = parse_idx3(&raw).unwrap();
        assert_eq!((n, r, c), (3, 28, 28));
        assert_eq!(px.len(), 3 * 784);
    }

    #[test]
    fn roundtrip_idx1() {
        let raw = make_idx1(&[1, 2, 3]);
        assert_eq!(parse_idx1(&raw).unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut raw = make_idx3(1);
        raw[3] = 0x99;
        assert!(parse_idx3(&raw).is_err());
        let mut raw1 = make_idx1(&[1]);
        raw1[3] = 0x99;
        assert!(parse_idx1(&raw1).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let raw = make_idx3(2);
        assert!(parse_idx3(&raw[..100]).is_err());
        let raw1 = make_idx1(&[1, 2, 3]);
        assert!(parse_idx1(&raw1[..9]).is_err());
    }

    #[test]
    fn dataset_conversion_normalizes() {
        let raw = make_idx3(2);
        let (_, r, c, px) = parse_idx3(&raw).unwrap();
        let d = to_dataset(&px, &[4, 5], r, c, 10);
        assert_eq!(d.n, 2);
        assert!((d.images[0] - 128.0 / 255.0).abs() < 1e-6);
        assert_eq!(d.labels, vec![4, 5]);
    }

    #[test]
    fn load_mnist_dir_roundtrip() {
        // Write a tiny fake MNIST directory and load it back.
        let dir = std::env::temp_dir().join(format!("idx_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("train-images-idx3-ubyte"), make_idx3(5)).unwrap();
        std::fs::write(dir.join("train-labels-idx1-ubyte"), make_idx1(&[0, 1, 2, 3, 4])).unwrap();
        std::fs::write(dir.join("t10k-images-idx3-ubyte"), make_idx3(2)).unwrap();
        std::fs::write(dir.join("t10k-labels-idx1-ubyte"), make_idx1(&[5, 6])).unwrap();
        let (tr, te) = load_mnist_dir(dir.to_str().unwrap(), 3, 2).unwrap();
        assert_eq!(tr.n, 3);
        assert_eq!(te.n, 2);
        assert_eq!(te.labels, vec![5, 6]);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Procedural MNIST-like digit corpus.
//!
//! Each digit class is a polyline glyph on a 28×28 canvas, rendered with
//! anti-aliased strokes, then perturbed per-sample with a random affine
//! map (translate/rotate/scale), stroke-width jitter and pixel noise —
//! enough intra-class variance that the classification task is non-trivial
//! but learnable by LeNet-5 (~99% clean accuracy), mirroring MNIST's
//! difficulty profile at small scale.

use super::Dataset;
use crate::util::prng::Pcg;

/// Control polylines for digits 0–9 on a unit [0,1]² canvas
/// (y grows downward). Multiple strokes per glyph.
fn glyph(digit: u8) -> Vec<Vec<(f64, f64)>> {
    match digit {
        0 => vec![vec![
            (0.5, 0.15),
            (0.75, 0.3),
            (0.75, 0.7),
            (0.5, 0.85),
            (0.25, 0.7),
            (0.25, 0.3),
            (0.5, 0.15),
        ]],
        1 => vec![vec![(0.35, 0.3), (0.55, 0.15), (0.55, 0.85)]],
        2 => vec![vec![
            (0.27, 0.3),
            (0.45, 0.15),
            (0.7, 0.25),
            (0.68, 0.45),
            (0.3, 0.8),
            (0.3, 0.85),
            (0.75, 0.85),
        ]],
        3 => vec![vec![
            (0.3, 0.2),
            (0.6, 0.15),
            (0.72, 0.3),
            (0.5, 0.48),
            (0.72, 0.65),
            (0.6, 0.85),
            (0.28, 0.8),
        ]],
        4 => vec![
            vec![(0.62, 0.85), (0.62, 0.15), (0.25, 0.6), (0.78, 0.6)],
        ],
        5 => vec![vec![
            (0.7, 0.15),
            (0.32, 0.15),
            (0.3, 0.45),
            (0.6, 0.42),
            (0.73, 0.6),
            (0.6, 0.85),
            (0.28, 0.8),
        ]],
        6 => vec![vec![
            (0.65, 0.15),
            (0.35, 0.4),
            (0.27, 0.65),
            (0.45, 0.85),
            (0.7, 0.72),
            (0.62, 0.52),
            (0.3, 0.58),
        ]],
        7 => vec![vec![(0.25, 0.15), (0.75, 0.15), (0.45, 0.85)]],
        8 => vec![vec![
            (0.5, 0.48),
            (0.3, 0.32),
            (0.5, 0.15),
            (0.7, 0.32),
            (0.5, 0.48),
            (0.28, 0.68),
            (0.5, 0.85),
            (0.72, 0.68),
            (0.5, 0.48),
        ]],
        9 => vec![vec![
            (0.68, 0.42),
            (0.4, 0.48),
            (0.3, 0.28),
            (0.5, 0.15),
            (0.7, 0.25),
            (0.68, 0.42),
            (0.6, 0.85),
        ]],
        _ => panic!("digit out of range"),
    }
}

/// Render one sample of `digit` with per-sample jitter.
pub fn render(digit: u8, rng: &mut Pcg) -> Vec<f32> {
    let mut img = vec![0.0f32; 28 * 28];
    // Per-sample affine jitter.
    let angle = rng.range(-0.25, 0.25);
    let scale = rng.range(0.82, 1.05);
    let dx = rng.range(-0.08, 0.08);
    let dy = rng.range(-0.08, 0.08);
    let shear = rng.range(-0.12, 0.12);
    let width = rng.range(0.035, 0.055);
    let (sin, cos) = angle.sin_cos();
    let xform = |p: (f64, f64)| -> (f64, f64) {
        let (x0, y0) = (p.0 - 0.5, p.1 - 0.5);
        let x1 = x0 + shear * y0;
        let x2 = cos * x1 - sin * y0;
        let y2 = sin * x1 + cos * y0;
        (scale * x2 + 0.5 + dx, scale * y2 + 0.5 + dy)
    };
    for stroke in glyph(digit) {
        let pts: Vec<(f64, f64)> = stroke.into_iter().map(xform).collect();
        for seg in pts.windows(2) {
            draw_segment(&mut img, seg[0], seg[1], width);
        }
    }
    // Pixel noise + soft clipping.
    for p in img.iter_mut() {
        let noisy = *p as f64 + rng.normal() * 0.04;
        *p = noisy.clamp(0.0, 1.0) as f32;
    }
    img
}

/// Anti-aliased thick-segment rendering: per-pixel distance to segment.
fn draw_segment(img: &mut [f32], a: (f64, f64), b: (f64, f64), width: f64) {
    let (ax, ay) = (a.0 * 28.0, a.1 * 28.0);
    let (bx, by) = (b.0 * 28.0, b.1 * 28.0);
    let w = width * 28.0;
    let (lo_x, hi_x) = ((ax.min(bx) - w - 1.0).max(0.0), (ax.max(bx) + w + 1.0).min(27.0));
    let (lo_y, hi_y) = ((ay.min(by) - w - 1.0).max(0.0), (ay.max(by) + w + 1.0).min(27.0));
    let dx = bx - ax;
    let dy = by - ay;
    let len2 = (dx * dx + dy * dy).max(1e-12);
    for py in (lo_y as usize)..=(hi_y as usize) {
        for px in (lo_x as usize)..=(hi_x as usize) {
            let (cx, cy) = (px as f64 + 0.5, py as f64 + 0.5);
            let t = (((cx - ax) * dx + (cy - ay) * dy) / len2).clamp(0.0, 1.0);
            let (qx, qy) = (ax + t * dx, ay + t * dy);
            let dist = ((cx - qx).powi(2) + (cy - qy).powi(2)).sqrt();
            // Smooth falloff from the stroke core.
            let v = (1.0 - (dist - w).max(0.0) / 1.2).clamp(0.0, 1.0);
            let idx = py * 28 + px;
            img[idx] = img[idx].max(v as f32);
        }
    }
}

/// Generate a balanced dataset of `n` samples.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg::new(seed);
    let mut images = Vec::with_capacity(n * 784);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let digit = (i % 10) as u8;
        images.extend_from_slice(&render(digit, &mut rng));
        labels.push(digit);
    }
    // Shuffle sample order (images and labels together).
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let mut im2 = vec![0.0f32; n * 784];
    let mut lb2 = vec![0u8; n];
    for (dst, &src) in order.iter().enumerate() {
        im2[dst * 784..(dst + 1) * 784].copy_from_slice(&images[src * 784..(src + 1) * 784]);
        lb2[dst] = labels[src];
    }
    Dataset { images: im2, labels: lb2, n }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = generate(20, 7);
        let b = generate(20, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn pixels_in_range_and_nonempty() {
        let d = generate(30, 1);
        for i in 0..d.n {
            let img = d.image(i);
            assert!(img.iter().all(|&p| (0.0..=1.0).contains(&p)));
            let ink: f32 = img.iter().sum();
            assert!(ink > 10.0, "glyph {i} too faint: {ink}");
            assert!(ink < 500.0, "glyph {i} floods the canvas: {ink}");
        }
    }

    #[test]
    fn classes_balanced() {
        let d = generate(100, 2);
        let mut counts = [0usize; 10];
        for &l in &d.labels {
            counts[l as usize] += 1;
        }
        assert_eq!(counts, [10; 10]);
    }

    #[test]
    fn intra_class_variation_exists() {
        // Two samples of the same digit must differ (affine jitter).
        let mut rng = Pcg::new(3);
        let a = render(5, &mut rng);
        let b = render(5, &mut rng);
        let diff: f32 = a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 5.0, "no jitter? diff={diff}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean inter-class pixel distance should exceed intra-class.
        let mut rng = Pcg::new(4);
        let mut intra = 0.0;
        let mut inter = 0.0;
        let mut n = 0;
        for d in 0..10u8 {
            let a = render(d, &mut rng);
            let b = render(d, &mut rng);
            let c = render((d + 1) % 10, &mut rng);
            intra += a.iter().zip(&b).map(|(x, y)| (x - y).powi(2)).sum::<f32>();
            inter += a.iter().zip(&c).map(|(x, y)| (x - y).powi(2)).sum::<f32>();
            n += 1;
        }
        assert!(
            inter / n as f32 > intra / n as f32 * 1.3,
            "inter={inter} intra={intra}"
        );
    }

    #[test]
    #[should_panic]
    fn glyph_rejects_11() {
        glyph(11);
    }
}

//! Gate-level hardware cost model (paper §IV-C, Table VI).
//!
//! We have no SMIC-65nm synthesis flow, so area and power are estimated
//! from a standard-cell library ([`gates`]) whose per-cell numbers are
//! calibrated such that the paper's reported SMURF block totals are
//! recovered (RNG ≈ 1600 µm², SMURF core 104.4 µm², CPT-gate 293.4 µm²,
//! module total 5294.72 µm², 0.51 mW @ 400 MHz). The Taylor and LUT
//! designs ([`designs`]) are costed from the *same* library, so the
//! ratios — the paper's actual claim — are model-consistent.

pub mod cost;
pub mod designs;
pub mod gates;

pub use cost::{Cost, ModuleCost};
pub use designs::{lut_design, smurf_design, taylor_design};

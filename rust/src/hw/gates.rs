//! SMIC-65nm-like standard-cell library.
//!
//! Per-cell areas are typical published 65nm values (NAND2 ≈ 1.44 µm²,
//! DFF ≈ 8.6 µm², ROM ≈ 0.22 µm²/bit); the two density constants
//! (dynamic-power density at 400 MHz and leakage density) and the layout
//! overhead factor are calibrated once so the paper's absolute Table VI
//! numbers for SMURF are recovered — and then applied *identically* to
//! the Taylor and LUT designs, keeping the cross-scheme ratios (the
//! paper's actual claim) model-consistent. See DESIGN.md
//! §Hardware-Adaptation.

/// Gate-equivalent (NAND2) area, µm².
pub const GE: f64 = 1.44;
/// D flip-flop area, µm².
pub const DFF: f64 = 8.6;
/// XOR2 area, µm².
pub const XOR2: f64 = 4.3;
/// Per-bit 2:1 MUX area, µm².
pub const MUX2_BIT: f64 = 2.5;
/// Full-adder area, µm².
pub const FA: f64 = 10.0;
/// Half-adder area, µm².
pub const HA: f64 = 4.3;
/// Per-bit magnitude-comparator area, µm².
pub const COMP_PER_BIT: f64 = 4.1;
/// ROM cell area, µm²/bit.
pub const ROM_BIT: f64 = 0.22;
/// Truncated 16×16→16 array multiplier, µm² (≈0.6 of the full array —
/// the standard truncation for a 16-bit fractional datapath).
pub const TRUNC_MULT16: f64 = 1760.0;

/// Layout overhead (clock tree, interconnect, placement utilization)
/// applied to synthesized *logic* area; ROM arrays are compiled macros
/// and excluded.
pub const LAYOUT_OVERHEAD: f64 = 1.35;

/// Dynamic power density at 400 MHz, mW/µm² per unit switching activity.
pub const DYN_DENSITY: f64 = 100e-6;
/// Leakage power density, mW/µm².
pub const LEAK_DENSITY: f64 = 0.3e-6;

/// Composite helpers ------------------------------------------------------

/// `bits`-bit magnitude comparator.
pub fn comparator(bits: u32) -> f64 {
    COMP_PER_BIT * bits as f64 + 2.0 * GE
}

/// 16-bit Fibonacci LFSR: 16 DFF + 3 XOR2.
pub fn lfsr16() -> f64 {
    16.0 * DFF + 3.0 * XOR2
}

/// `stages`-deep, `width`-bit delay line (the RNG branch shift register).
pub fn delay_line(stages: u32, width: u32) -> f64 {
    (stages * width) as f64 * DFF
}

/// `n`-state saturating chain FSM: state register + inc/dec/saturate logic.
pub fn chain_fsm(n_states: usize) -> f64 {
    let sbits = (usize::BITS - (n_states - 1).leading_zeros()) as f64;
    sbits * DFF + 12.0 * sbits * GE
}

/// `ways`:1 MUX of `width`-bit words.
pub fn mux_tree(ways: usize, width: u32) -> f64 {
    ((ways.saturating_sub(1)) as f64) * width as f64 * MUX2_BIT
}

/// `bits`-bit ripple counter with carry chain.
pub fn counter(bits: u32) -> f64 {
    bits as f64 * (DFF + HA)
}

/// Register bank: `words` × `width` bits.
pub fn register_bank(words: usize, width: u32) -> f64 {
    (words as f64) * (width as f64) * DFF
}

/// `bits`-bit ripple-carry adder.
pub fn adder(bits: u32) -> f64 {
    bits as f64 * FA
}

/// ROM address decoder: two-level predecode for `addr_bits` address lines.
pub fn rom_decoder(addr_bits: u32) -> f64 {
    let half = addr_bits.div_ceil(2);
    2.0 * (1u64 << half) as f64 * 4.0 * GE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composite_cells_scale() {
        assert!(comparator(16) > comparator(8));
        assert!(chain_fsm(8) > chain_fsm(4));
        assert!(mux_tree(16, 8) > mux_tree(4, 8));
        assert_eq!(mux_tree(1, 8), 0.0);
    }

    #[test]
    fn lfsr_matches_inventory() {
        assert!((lfsr16() - (16.0 * 8.6 + 3.0 * 4.3)).abs() < 1e-9);
    }

    #[test]
    fn chain_fsm_bits() {
        // 4 states → 2 state bits; 5..8 states → 3 bits.
        assert!((chain_fsm(4) - (2.0 * DFF + 24.0 * GE)).abs() < 1e-9);
        assert!((chain_fsm(5) - (3.0 * DFF + 36.0 * GE)).abs() < 1e-9);
    }

    #[test]
    fn decoder_is_two_level() {
        assert!((rom_decoder(16) - 2.0 * 256.0 * 4.0 * GE).abs() < 1e-9);
    }
}

//! Hardware inventories of the three Table VI designs, all costed from
//! the same cell library.

use super::cost::{Cost, ModuleCost};
use super::gates::*;
use crate::baselines::lut::Lut;
use crate::baselines::taylor::TaylorPoly;
use crate::smurf::config::SmurfConfig;

/// Switching activity of SC logic: stochastic bitstreams toggle every
/// cycle, so the whole datapath runs at full activity.
const SC_ACTIVITY: f64 = 1.0;
/// Deep arithmetic (multiplier arrays) glitches beyond the nominal
/// toggle rate.
const ARITH_ACTIVITY: f64 = 1.07;
/// A ROM read toggles only a handful of decoder lines per cycle.
const DECODER_ACTIVITY: f64 = 0.05;

/// Coefficient-threshold width inside the CPT-gate. 8 bits gives 1/256
/// resolution — far below the 0.015 MAE equalization point of §IV-C.
const COEFF_BITS: u32 = 8;
/// Input SNG comparator width (paper: "standard fixed-point
/// representation is employed for θ-gate inputs", 16-bit datapath).
const INPUT_BITS: u32 = 16;
/// RNG branch delay-line depth (stages of 16-bit shift register).
const RNG_DELAY_STAGES: u32 = 10;

/// SMURF module (Fig. 6) for an arbitrary configuration.
///
/// Blocks mirror the paper's §IV-C breakdown: RNG (~1600 µm²), SMURF core
/// (the M chain FSMs, 104.4 µm² at M=2/N=4), CPT-gate (293.4 µm²), plus
/// input SNGs, coefficient registers, output counter and control.
pub fn smurf_design(cfg: &SmurfConfig) -> ModuleCost {
    let mut m = ModuleCost::new(format!("SMURF {cfg}"));
    let states = cfg.num_aggregate_states();

    // Single physical RNG: one LFSR + branch delay line (§III-A).
    m.push("rng", Cost::logic(lfsr16() + delay_line(RNG_DELAY_STAGES, 16), SC_ACTIVITY));

    // One input θ-gate (SNG comparator) per variable.
    m.push(
        "input_sngs",
        Cost::logic(cfg.num_vars() as f64 * comparator(INPUT_BITS), SC_ACTIVITY),
    );

    // The M chained FSMs — the "SMURF core".
    let core: f64 = cfg.radices().iter().map(|&n| chain_fsm(n)).sum();
    m.push("smurf_core", Cost::logic(core, SC_ACTIVITY));

    // CPT-gate: threshold MUX (the codeword selects the w_t word) plus a
    // single shared comparator against the RNG branch.
    m.push(
        "cpt_gate",
        Cost::logic(mux_tree(states, COEFF_BITS) + comparator(COEFF_BITS), SC_ACTIVITY),
    );

    // Coefficient storage: N^M words of COEFF_BITS.
    m.push("coeff_regs", Cost::logic(register_bank(states, COEFF_BITS), SC_ACTIVITY));

    // Output counter (12 bits covers streams up to 4096 cycles).
    m.push("out_counter", Cost::logic(counter(12), SC_ACTIVITY));

    // Control & I/O: input/output staging registers + handshake FSM.
    let ctrl = register_bank(2, 16) + register_bank(cfg.num_vars(), 16) + 30.0 * GE;
    m.push("control_io", Cost::logic(ctrl, SC_ACTIVITY));

    m
}

/// Taylor-series pipeline (§IV-C: 16-bit datapath, 4-stage pipeline,
/// cubic bivariate polynomial). The multiplier count comes from the
/// polynomial's structure with power-reuse factoring; the paper's design
/// point corresponds to ~10 truncated 16×16 multipliers.
pub fn taylor_design(poly: &TaylorPoly) -> ModuleCost {
    let mut m = ModuleCost::new(format!(
        "Taylor order-{} ({} vars)",
        poly.order,
        poly.center.len()
    ));
    // Multiply-op inventory with power reuse: each distinct monomial of
    // total degree ≥ 2 costs one extension multiply (reusing a
    // lower-degree product), plus one coefficient multiply per
    // non-constant term. Physical multipliers are time-multiplexed 2:1
    // across pipeline phases — which is why the paper observes the design
    // "can barely reach 400 MHz".
    let monomial_ext = poly
        .terms
        .iter()
        .filter(|t| t.exponents.iter().sum::<u32>() >= 2)
        .count();
    let coeff_muls = poly.terms.iter().filter(|t| t.exponents.iter().any(|&e| e > 0)).count();
    let n_mults = (monomial_ext + coeff_muls).div_ceil(2);
    // For the paper's cubic bivariate case: (7 + 9)/2 = 8 multipliers.
    m.push("multipliers", Cost::logic(n_mults as f64 * TRUNC_MULT16, ARITH_ACTIVITY));

    let n_adds = poly.add_count().min(poly.terms.len() + 2);
    m.push("adders", Cost::logic(n_adds as f64 * adder(16), ARITH_ACTIVITY));

    // 4-stage pipeline registers: 10 16-bit words per stage boundary.
    m.push("pipeline_regs", Cost::logic(register_bank(4 * 10, 16), ARITH_ACTIVITY));

    // Coefficient registers (one 16-bit word per term).
    m.push("coeff_regs", Cost::logic(register_bank(poly.terms.len(), 16), ARITH_ACTIVITY));

    // Control & I/O staging.
    let ctrl = register_bank(4, 16) + 30.0 * GE + 100.0 * GE;
    m.push("control_io", Cost::logic(ctrl, ARITH_ACTIVITY));
    m
}

/// Direct-mapped LUT (§IV-C: same output bitwidth, two 8-bit inputs →
/// 2^16 × 16-bit ROM).
pub fn lut_design(lut: &Lut) -> ModuleCost {
    let mut m = ModuleCost::new(format!(
        "LUT {}x{}b addr, {}b out",
        lut.arity(),
        lut.addr_bits,
        lut.out_bits
    ));
    m.push("rom_array", Cost::rom(lut.storage_bits()));
    let addr_total = lut.arity() as u32 * lut.addr_bits;
    m.push("addr_decoder", Cost::logic(rom_decoder(addr_total), DECODER_ACTIVITY));
    // Output register + column select + control.
    let io = register_bank(1, lut.out_bits) + mux_tree(4, lut.out_bits) + 30.0 * GE;
    m.push("sense_io", Cost::logic(io, DECODER_ACTIVITY));
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::functions;

    /// Paper Table VI reference numbers.
    const PAPER_SMURF: (f64, f64) = (5294.72, 0.51);
    const PAPER_TAYLOR: (f64, f64) = (32941.44, 3.53);
    const PAPER_LUT: (f64, f64) = (238176.38, 0.10);

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn smurf_total_matches_paper() {
        let d = smurf_design(&SmurfConfig::uniform(2, 4));
        let t = d.total();
        assert!(
            rel(t.area_um2, PAPER_SMURF.0) < 0.10,
            "area {} vs paper {}",
            t.area_um2,
            PAPER_SMURF.0
        );
        assert!(
            rel(t.power_mw, PAPER_SMURF.1) < 0.15,
            "power {} vs paper {}",
            t.power_mw,
            PAPER_SMURF.1
        );
    }

    #[test]
    fn smurf_rng_dominates_like_paper() {
        // §IV-C: "power ... mostly due to the RNG"; RNG ≈ 1600 µm².
        let d = smurf_design(&SmurfConfig::uniform(2, 4));
        let rng = d.block("rng").unwrap();
        assert!(rel(rng.area_um2, 1600.0) < 0.35, "rng area {}", rng.area_um2);
        for (name, c) in &d.blocks {
            if name != "rng" && name != "coeff_regs" {
                assert!(c.power_mw <= rng.power_mw, "{name} exceeds RNG power");
            }
        }
    }

    #[test]
    fn taylor_total_matches_paper() {
        let f = functions::euclidean2();
        let p = TaylorPoly::expand(&f, &[0.5, 0.5], 3);
        let d = taylor_design(&p);
        let t = d.total();
        assert!(
            rel(t.area_um2, PAPER_TAYLOR.0) < 0.10,
            "area {} vs paper {}",
            t.area_um2,
            PAPER_TAYLOR.0
        );
        assert!(
            rel(t.power_mw, PAPER_TAYLOR.1) < 0.10,
            "power {} vs paper {}",
            t.power_mw,
            PAPER_TAYLOR.1
        );
    }

    #[test]
    fn lut_total_matches_paper() {
        let f = functions::euclidean2();
        let lut = Lut::build(&f, 8, 16);
        let d = lut_design(&lut);
        let t = d.total();
        assert!(
            rel(t.area_um2, PAPER_LUT.0) < 0.05,
            "area {} vs paper {}",
            t.area_um2,
            PAPER_LUT.0
        );
        assert!(
            rel(t.power_mw, PAPER_LUT.1) < 0.30,
            "power {} vs paper {}",
            t.power_mw,
            PAPER_LUT.1
        );
    }

    #[test]
    fn table6_ratios_hold() {
        // The paper's headline: SMURF is 16.07% of Taylor area, 14.45% of
        // Taylor power, 2.22% of LUT area.
        let f = functions::euclidean2();
        let s = smurf_design(&SmurfConfig::uniform(2, 4)).total();
        let t = taylor_design(&TaylorPoly::expand(&f, &[0.5, 0.5], 3)).total();
        let l = lut_design(&Lut::build(&f, 8, 16)).total();
        let area_vs_taylor = s.area_um2 / t.area_um2;
        let power_vs_taylor = s.power_mw / t.power_mw;
        let area_vs_lut = s.area_um2 / l.area_um2;
        assert!((area_vs_taylor - 0.1607).abs() < 0.05, "area ratio {area_vs_taylor}");
        assert!((power_vs_taylor - 0.1445).abs() < 0.05, "power ratio {power_vs_taylor}");
        assert!((area_vs_lut - 0.0222).abs() < 0.01, "LUT area ratio {area_vs_lut}");
        // Composite area·power ordering: SMURF best.
        assert!(s.area_power() < t.area_power());
        assert!(s.area_power() < l.area_power());
    }

    #[test]
    fn smurf_scales_with_states() {
        let small = smurf_design(&SmurfConfig::uniform(2, 4)).total();
        let big = smurf_design(&SmurfConfig::uniform(3, 8)).total();
        assert!(big.area_um2 > small.area_um2 * 2.0);
    }
}

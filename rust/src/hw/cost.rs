//! Area/power cost accounting.

use super::gates::{DYN_DENSITY, LAYOUT_OVERHEAD, LEAK_DENSITY};

/// Cost of one block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    /// Placed area, µm².
    pub area_um2: f64,
    /// Total power at 400 MHz, mW.
    pub power_mw: f64,
}

impl Cost {
    /// Synthesized logic: raw cell area × layout overhead; dynamic power
    /// scaled by the block's switching activity.
    pub fn logic(cell_area_um2: f64, activity: f64) -> Self {
        let area = cell_area_um2 * LAYOUT_OVERHEAD;
        Self { area_um2: area, power_mw: area * (DYN_DENSITY * activity + LEAK_DENSITY) }
    }

    /// Compiled ROM macro: no layout overhead, leakage-dominated.
    pub fn rom(bits: u64) -> Self {
        let area = bits as f64 * super::gates::ROM_BIT;
        Self { area_um2: area, power_mw: area * LEAK_DENSITY }
    }

    pub fn add(self, other: Cost) -> Cost {
        Cost { area_um2: self.area_um2 + other.area_um2, power_mw: self.power_mw + other.power_mw }
    }

    /// Area·power product, µm²·mW (Table VI's composite metric).
    pub fn area_power(&self) -> f64 {
        self.area_um2 * self.power_mw
    }
}

/// A named breakdown of a full module.
#[derive(Clone, Debug)]
pub struct ModuleCost {
    pub name: String,
    pub blocks: Vec<(String, Cost)>,
}

impl ModuleCost {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), blocks: Vec::new() }
    }

    pub fn push(&mut self, block: impl Into<String>, cost: Cost) {
        self.blocks.push((block.into(), cost));
    }

    pub fn total(&self) -> Cost {
        self.blocks.iter().fold(Cost::default(), |acc, (_, c)| acc.add(*c))
    }

    pub fn block(&self, name: &str) -> Option<Cost> {
        self.blocks.iter().find(|(n, _)| n == name).map(|(_, c)| *c)
    }

    /// Render the breakdown as an aligned text table.
    pub fn table(&self) -> String {
        let mut s = format!("{:<28} {:>12} {:>10}\n", self.name, "area/um^2", "power/mW");
        for (n, c) in &self.blocks {
            s += &format!("  {:<26} {:>12.2} {:>10.4}\n", n, c.area_um2, c.power_mw);
        }
        let t = self.total();
        s += &format!("  {:<26} {:>12.2} {:>10.4}\n", "TOTAL", t.area_um2, t.power_mw);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logic_applies_overhead_and_activity() {
        let idle = Cost::logic(1000.0, 0.0);
        let busy = Cost::logic(1000.0, 1.0);
        assert!((idle.area_um2 - 1350.0).abs() < 1e-9);
        assert!(busy.power_mw > idle.power_mw);
        assert!(idle.power_mw > 0.0, "leakage still present");
    }

    #[test]
    fn rom_has_no_overhead() {
        let r = Cost::rom(1000);
        assert!((r.area_um2 - 220.0).abs() < 1e-9);
    }

    #[test]
    fn module_totals() {
        let mut m = ModuleCost::new("test");
        m.push("a", Cost { area_um2: 10.0, power_mw: 0.1 });
        m.push("b", Cost { area_um2: 20.0, power_mw: 0.2 });
        let t = m.total();
        assert!((t.area_um2 - 30.0).abs() < 1e-12);
        assert!((t.power_mw - 0.3).abs() < 1e-12);
        assert!(m.block("a").is_some());
        assert!(m.block("zz").is_none());
        assert!(m.table().contains("TOTAL"));
    }

    #[test]
    fn area_power_product() {
        let c = Cost { area_um2: 100.0, power_mw: 0.5 };
        assert_eq!(c.area_power(), 50.0);
    }
}

//! # SMURF — Stochastic Multivariate Universal-Radix Finite-State Machine
//!
//! Production-quality reproduction of *"Stochastic Multivariate
//! Universal-Radix Finite-State Machine: a Theoretically and Practically
//! Elegant Nonlinear Function Approximator"* (Feng et al., 2024).
//!
//! SMURF approximates arbitrary multivariate nonlinear functions
//! `f(x_1, …, x_M) : [0,1]^M → [0,1]` with stochastic-computing hardware:
//! one chained `N`-state FSM per input variable, the joint state forming a
//! *universal-radix codeword* that selects one of `N^M` θ-gates through a
//! CPT-gate (MUX). The mean of the output bitstream converges to the target
//! function value; the θ-gate thresholds `w_t` are synthesized offline by a
//! box-constrained quadratic program (paper Eq. 5–11).
//!
//! ## Crate layout
//!
//! - [`sc`] — stochastic-computing substrate: RNGs (LFSR / xorshift /
//!   Sobol), packed bitstreams, θ-gates (SNGs), CPT-gates, and the
//!   [`BitPlane`](sc::plane::BitPlane) SIMD-lane abstraction behind the
//!   wide engine (64/256/512 lanes per plane word).
//! - [`fsm`] — chained N-state Moore FSMs, steady-state analytics,
//!   Brown–Card and MM-FSM baselines.
//! - [`smurf`] — the paper's contribution: configuration, universal-radix
//!   codewords, the closed-form (analytic) evaluator and the cycle-accurate
//!   bit-level simulator.
//! - [`synth`] — coefficient synthesis: Gauss–Legendre quadrature for the
//!   `H` matrix / `c` vector and the projected-gradient QP solver.
//! - [`baselines`] — Taylor series, LUT, CORDIC and Bernstein-polynomial
//!   comparators.
//! - [`hw`] — gate-level area/power cost model (SMIC-65nm-calibrated).
//! - [`nn`] — SC-based CNN inference (LeNet-5): SC-PwMM convolution,
//!   SMURF-HT, SMURF activations.
//! - [`data`] — synthetic MNIST corpus + IDX loader.
//! - [`runtime`] — PJRT (XLA) execution of AOT-compiled artifacts.
//! - [`coordinator`] — evaluation service: request router, dynamic
//!   batcher, worker pool, metrics.
//! - [`util`] — in-repo substrates the offline environment forces us to
//!   own: JSON, deterministic PRNG for tests, statistics helpers.
//! - [`testing`] — minimal property-testing harness (proptest is not
//!   vendored in this environment; see DESIGN.md).
//! - [`testutil`] — randomized robustness harness: seed-deterministic
//!   structured generators, the differential oracle (scalar == every
//!   plane width == TMR-at-rate-0, bit for bit) with a shrinker, and the
//!   coordinator chaos-soak round engine (`make fuzz-smoke` /
//!   `make soak`; docs/INVARIANTS.md § Randomized robustness harness).
//!
//! ## Quickstart
//!
//! ```
//! use smurf::prelude::*;
//!
//! // Synthesize a bivariate Euclidean-distance SMURF (paper Table I).
//! let cfg = SmurfConfig::uniform(2, 4);
//! let approx = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
//! // Analytic (infinite-stream) evaluation:
//! let y = approx.eval_analytic(&[0.3, 0.4]);
//! assert!((y - 0.5).abs() < 0.05);
//! // Bit-level hardware simulation with 256-cycle bitstreams:
//! let y_hw = approx.eval_bitstream(&[0.3, 0.4], 256, 7);
//! assert!((y_hw - 0.5).abs() < 0.2);
//! ```
//!
//! ## Lint policy
//!
//! The crate carries **no crate-level `#![allow(...)]`s** — warnings are
//! suppressed only at the item that needs it, and every file-local
//! `#[allow(...)]` in non-test code must carry a `// justification:`
//! comment (same line or the line above). That rule is mechanically
//! enforced by `cargo run -p xtask -- verify` (see `docs/INVARIANTS.md`),
//! so an allow can't be pasted in during review without an argument for
//! it. Current inventory (all three are API-shape suppressions, not
//! correctness ones):
//!
//! - `sc::bitstream` — `clippy::should_implement_trait` on
//!   `Bitstream::not` (SC complement, deliberately not `std::ops::Not`);
//! - `nn::layers` — `clippy::too_many_arguments` on
//!   `for_each_valid_tap` (the conv tap geometry is 7 scalars);
//! - `smurf::sim_wide` — `clippy::too_many_arguments` on the shared
//!   trial-chunking estimator.
//!
//! The serving layer ([`coordinator`]) additionally bans panicking calls
//! (`unwrap`/`expect`/`panic!`…) in non-test code outright; the few
//! spawn-time exceptions carry inline `xtask: allow(no-panic)` waivers
//! with justifications.

pub mod util;
pub mod testing;
pub mod testutil;
pub mod sc;
pub mod fsm;
pub mod smurf;
pub mod synth;
pub mod baselines;
pub mod hw;
pub mod nn;
pub mod data;
pub mod runtime;
pub mod coordinator;

/// Convenience re-exports of the most common entry points.
pub mod prelude {
    pub use crate::sc::bitstream::Bitstream;
    pub use crate::sc::plane::BitPlane;
    pub use crate::sc::rng::{Lfsr16, Sobol, StreamRng, XorShift64};
    pub use crate::sc::sng::ThetaGate;
    pub use crate::smurf::analytic::AnalyticSmurf;
    pub use crate::smurf::approximator::SmurfApproximator;
    pub use crate::smurf::config::SmurfConfig;
    pub use crate::smurf::sim::BitLevelSmurf;
    pub use crate::smurf::sim_wide::{MaxPlane, WideBitLevelSmurf, WideRunState, MAX_LANES};
    pub use crate::synth::functions;
    pub use crate::synth::functions::TargetFn;
    pub use crate::synth::synthesize::{synthesize, SynthOptions, SynthResult};
}

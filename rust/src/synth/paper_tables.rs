//! The coefficient tables printed in the paper (Tables I & II), kept for
//! side-by-side comparison in the benches.
//!
//! **Reproduction note** (recorded in EXPERIMENTS.md): these published
//! values are *inconsistent with the paper's own steady-state model*
//! (Eq. 4/21). Evaluated under Eq. 21, the paper's Table I gives a grid
//! MAE of ≈ 0.196 for √(x₁²+x₂²) — e.g. its corner entry
//! `w_3 = 0.6911` is read out exactly at `(P_x₁, P_x₂) = (1, 0)` where the
//! target is `1.0`. Our QP solution of the paper's own optimization
//! problem (Eq. 5–11) achieves analytic MAE ≈ 0.027, which *matches the
//! accuracy the paper reports* for its hardware (≈ 0.032 at 64-bit
//! streams, Fig. 10a). The synthesis flow is therefore validated against
//! the paper's accuracy claims rather than its table listings.

/// Paper Table I: `w_t` for √(x₁²+x₂²), N=4, t = i₁ + 4·i₂.
pub const TABLE1_EUCLID: [f64; 16] = [
    0.0, 0.6083, 0.0474, 0.6911, //
    0.6083, 0.3749, 0.4527, 0.8372, //
    0.0474, 0.4527, 0.0159, 0.5946, //
    0.6911, 0.8372, 0.5946, 0.9846,
];

/// Paper Table II: `w_t` for sin(x₁)cos(x₂), N=4.
pub const TABLE2_SINCOS: [f64; 16] = [
    0.0, 0.4002, 0.4002, 0.3379, //
    0.3379, 0.4334, 0.4334, 0.6600, //
    0.0, 0.5407, 0.5407, 0.4564, //
    0.4564, 0.5854, 0.5854, 0.8916,
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_are_valid_probabilities() {
        for w in TABLE1_EUCLID.iter().chain(&TABLE2_SINCOS) {
            assert!((0.0..=1.0).contains(w));
        }
    }
}

//! Target-function library.
//!
//! Every nonlinearity the paper evaluates, normalized to
//! `[0,1]^M → [0,1]` (paper §II-A: any function is brought to the unit
//! box by a bijective linear map, Fig. 3), plus extras for the examples.
//!
//! A [`TargetFn`] carries its arity, a human name, and the domain/range
//! mapping metadata so callers can un-normalize outputs.

use std::sync::Arc;

/// A target function for SMURF synthesis.
#[derive(Clone)]
pub struct TargetFn {
    name: String,
    arity: usize,
    f: Arc<dyn Fn(&[f64]) -> f64 + Send + Sync>,
}

impl TargetFn {
    pub fn new(
        name: impl Into<String>,
        arity: usize,
        f: impl Fn(&[f64]) -> f64 + Send + Sync + 'static,
    ) -> Self {
        Self { name: name.into(), arity, f: Arc::new(f) }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn arity(&self) -> usize {
        self.arity
    }

    /// Evaluate at a point in the unit box.
    pub fn eval(&self, x: &[f64]) -> f64 {
        debug_assert_eq!(x.len(), self.arity);
        (self.f)(x)
    }

    /// Borrow as the `dyn Fn` the quadrature assembler expects.
    pub fn as_fn(&self) -> impl Fn(&[f64]) -> f64 + '_ {
        move |x: &[f64]| (self.f)(x)
    }
}

impl std::fmt::Debug for TargetFn {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "TargetFn({}, arity={})", self.name, self.arity)
    }
}

/// Paper §III-B Example 1: 2-D Euclidean distance `√(x₁²+x₂²)`, clipped
/// into [0,1] (the paper treats outputs as SNs, hence ≤ 1).
pub fn euclidean2() -> TargetFn {
    TargetFn::new("euclidean2", 2, |x| (x[0] * x[0] + x[1] * x[1]).sqrt().min(1.0))
}

/// Paper §III-B Example 2 (Eq. 15): the Hartley-transform kernel
/// `sin(x₁)cos(x₂)` on the unit box (already in [0,1] there).
pub fn sincos() -> TargetFn {
    TargetFn::new("sincos", 2, |x| x[0].sin() * x[1].cos())
}

/// Bivariate softmax component `exp(x₁)/(exp(x₁)+exp(x₂))` (Table III
/// column 3, Fig. 10c).
pub fn softmax2() -> TargetFn {
    TargetFn::new("softmax2", 2, |x| {
        let e1 = x[0].exp();
        let e2 = x[1].exp();
        e1 / (e1 + e2)
    })
}

/// 3-variate softmax, first component (paper Eq. 22, Fig. 7).
pub fn softmax3() -> TargetFn {
    TargetFn::new("softmax3", 3, |x| {
        let e: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        e[0] / (e[0] + e[1] + e[2])
    })
}

/// tanh in the *bipolar* SC convention (Fig. 8): the SN value `P ∈ [0,1]`
/// encodes `v = 2P−1 ∈ [-1,1]`, and the target encodes `tanh(k·v)` the
/// same way: `T(P) = (tanh(k(2P−1)) + 1)/2`. This is the convention under
/// which the Brown–Card tanh FSM (Eq. 1) is the exact binary-label
/// special case — the QP recovers labels ≈ [0,0,1,1] at k=N/2.
pub fn tanh_bipolar(k: f64) -> TargetFn {
    TargetFn::new(format!("tanh_k{k}"), 1, move |x| {
        ((k * (2.0 * x[0] - 1.0)).tanh() + 1.0) / 2.0
    })
}

/// swish = v·σ(v) over v ∈ [-R, R] in the bipolar convention, output
/// min-max normalized to [0,1] (Fig. 9). The true minimum of swish is
/// interior (≈ −0.278 at v ≈ −1.278), so normalization uses it rather
/// than the endpoint.
pub fn swish_bipolar(r: f64) -> TargetFn {
    let s = |v: f64| v / (1.0 + (-v).exp());
    // Global minimum of swish: at the root of σ(v)(1 + v(1−σ(v))) — for
    // r ≥ 1.278 it is the interior minimum, else the left endpoint.
    let vmin = if r >= 1.278 { -1.2784645427610738 } else { -r };
    let lo = s(vmin);
    let hi = s(r);
    TargetFn::new(format!("swish_r{r}"), 1, move |x| {
        let u = r * (2.0 * x[0] - 1.0);
        (s(u) - lo) / (hi - lo)
    })
}

/// GeLU over [-R, R], min-max normalized (extension beyond the paper).
/// Like swish, GeLU's minimum is interior (≈ −0.170 at v ≈ −0.751).
pub fn gelu_bipolar(r: f64) -> TargetFn {
    let g = |v: f64| 0.5 * v * (1.0 + (v / std::f64::consts::SQRT_2).erf_approx());
    let vmin = if r >= 0.7518 { -0.7517916243860019 } else { -r };
    let lo = g(vmin);
    let hi = g(r);
    TargetFn::new(format!("gelu_r{r}"), 1, move |x| {
        let u = r * (2.0 * x[0] - 1.0);
        (g(u) - lo) / (hi - lo)
    })
}

/// Sigmoid σ(k(2P−1)) — already [0,1]-valued.
pub fn sigmoid_bipolar(k: f64) -> TargetFn {
    TargetFn::new(format!("sigmoid_k{k}"), 1, move |x| {
        1.0 / (1.0 + (-(k * (2.0 * x[0] - 1.0))).exp())
    })
}

/// Product `x₁·x₂` — the stochastic-multiplication sanity target.
pub fn product2() -> TargetFn {
    TargetFn::new("product2", 2, |x| x[0] * x[1])
}

/// `log(1+x)/log 2` — univariate log example.
pub fn log1p_unit() -> TargetFn {
    TargetFn::new("log1p", 1, |x| (1.0 + x[0]).ln() / std::f64::consts::LN_2)
}

/// `exp(-x)` — decay kernel.
pub fn exp_neg() -> TargetFn {
    TargetFn::new("exp_neg", 1, |x| (-x[0]).exp())
}

/// Trivariate Euclidean norm `√(x₁²+x₂²+x₃²)/√3`.
pub fn euclidean3() -> TargetFn {
    TargetFn::new("euclidean3", 3, |x| {
        (x.iter().map(|v| v * v).sum::<f64>()).sqrt() / 3f64.sqrt()
    })
}

/// All named functions, for CLI/bench lookup.
pub fn registry() -> Vec<TargetFn> {
    vec![
        euclidean2(),
        sincos(),
        softmax2(),
        softmax3(),
        tanh_bipolar(2.0),
        swish_bipolar(2.0),
        gelu_bipolar(2.0),
        sigmoid_bipolar(4.0),
        product2(),
        log1p_unit(),
        exp_neg(),
        euclidean3(),
    ]
}

/// Find by name.
pub fn by_name(name: &str) -> Option<TargetFn> {
    registry().into_iter().find(|f| f.name() == name)
}

/// Small erf approximation (Abramowitz–Stegun 7.1.26, |err| < 1.5e-7) so
/// GeLU needs no libm beyond exp.
trait ErfApprox {
    fn erf_approx(self) -> f64;
}

impl ErfApprox for f64 {
    fn erf_approx(self) -> f64 {
        let sign = if self < 0.0 { -1.0 } else { 1.0 };
        let x = self.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        sign * y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arities() {
        assert_eq!(euclidean2().arity(), 2);
        assert_eq!(softmax3().arity(), 3);
        assert_eq!(tanh_bipolar(2.0).arity(), 1);
    }

    #[test]
    fn ranges_within_unit_interval() {
        // All registry functions map the unit box into [0,1].
        let mut rng = crate::util::prng::Pcg::new(9);
        for f in registry() {
            for _ in 0..500 {
                let x: Vec<f64> = (0..f.arity()).map(|_| rng.uniform()).collect();
                let y = f.eval(&x);
                assert!(
                    (-1e-9..=1.0 + 1e-9).contains(&y),
                    "{} out of range at {:?}: {}",
                    f.name(),
                    x,
                    y
                );
            }
        }
    }

    #[test]
    fn euclid_known_values() {
        let f = euclidean2();
        assert!((f.eval(&[0.3, 0.4]) - 0.5).abs() < 1e-12);
        assert!((f.eval(&[1.0, 1.0]) - 1.0).abs() < 1e-12, "clipped at 1");
        assert_eq!(f.eval(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn softmax_components_sum_to_one() {
        let x: [f64; 3] = [0.2, 0.5, 0.9];
        let e: Vec<f64> = x.iter().map(|v| v.exp()).collect();
        let z: f64 = e.iter().sum();
        let s1 = softmax3().eval(&x);
        assert!((s1 - e[0] / z).abs() < 1e-12);
    }

    #[test]
    fn softmax2_symmetry() {
        let f = softmax2();
        assert!((f.eval(&[0.5, 0.5]) - 0.5).abs() < 1e-12);
        assert!((f.eval(&[0.3, 0.7]) + f.eval(&[0.7, 0.3]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn tanh_bipolar_symmetry_and_endpoints() {
        let f = tanh_bipolar(2.0);
        // Odd symmetry about the bipolar origin P=0.5.
        assert!((f.eval(&[0.5]) - 0.5).abs() < 1e-12);
        assert!((f.eval(&[0.2]) + f.eval(&[0.8]) - 1.0).abs() < 1e-12);
        // Near-saturation at the endpoints.
        assert!(f.eval(&[0.0]) < 0.02);
        assert!(f.eval(&[1.0]) > 0.98);
    }

    #[test]
    fn swish_bipolar_endpoints_and_monotone_tail() {
        let f = swish_bipolar(2.0);
        // Normalized by the interior minimum: the left endpoint sits just
        // above 0, the minimum itself hits exactly 0, max is 1.
        let left = f.eval(&[0.0]);
        assert!((0.0..0.05).contains(&left), "left={left}");
        // Interior minimum at v≈-1.278 → x = (v/2+1)/2 ≈ 0.180.
        assert!(f.eval(&[0.180]).abs() < 1e-4);
        assert!((f.eval(&[1.0]) - 1.0).abs() < 1e-12);
        assert!(f.eval(&[0.75]) < f.eval(&[1.0]));
    }

    #[test]
    fn erf_approx_accuracy() {
        // Check against known values.
        assert!((1.0f64.erf_approx() - 0.8427007929).abs() < 1e-6);
        assert!((0.5f64.erf_approx() - 0.5204998778).abs() < 1e-6);
        assert!(((-1.0f64).erf_approx() + 0.8427007929).abs() < 1e-6);
    }

    #[test]
    fn registry_lookup() {
        assert!(by_name("euclidean2").is_some());
        assert!(by_name("tanh_k2").is_some());
        assert!(by_name("nope").is_none());
    }
}

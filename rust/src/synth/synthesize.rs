//! End-to-end coefficient synthesis (paper §III-B/III-C).
//!
//! Assemble `H` and `c` by quadrature, solve the box QP, report residuals
//! and the resulting L2/analytic errors.

use super::functions::TargetFn;
use super::qp::{solve_box_qp, QpReport};
use super::quadrature::{c_vector, gauss_legendre_unit, h_matrix};
use crate::smurf::analytic::AnalyticSmurf;
use crate::smurf::config::SmurfConfig;

/// Synthesis options.
#[derive(Clone, Debug)]
pub struct SynthOptions {
    /// Gauss–Legendre nodes per dimension (spectral accuracy; 32 is ample
    /// for every paper target at N ≤ 8).
    pub quad_nodes: usize,
    /// QP iteration cap.
    pub max_iters: usize,
    /// QP convergence tolerance.
    pub tol: f64,
}

impl Default for SynthOptions {
    fn default() -> Self {
        Self { quad_nodes: 32, max_iters: 50_000, tol: 1e-12 }
    }
}

/// Result of a synthesis run.
#[derive(Clone, Debug)]
pub struct SynthResult {
    pub smurf: AnalyticSmurf,
    pub qp: QpReport,
    /// √ of the mean squared analytic error over the quadrature grid
    /// (the quantity Eq. 5 minimizes, after adding the T² constant).
    pub l2_error: f64,
    /// Mean absolute analytic error over the same grid.
    pub mae: f64,
}

/// Synthesize SMURF coefficients for `target` under `cfg`.
pub fn synthesize(cfg: &SmurfConfig, target: &TargetFn, opts: &SynthOptions) -> SynthResult {
    assert_eq!(
        cfg.num_vars(),
        target.arity(),
        "configuration arity must match the target function"
    );
    let h = h_matrix(cfg, opts.quad_nodes);
    let f = target.as_fn();
    let c = c_vector(cfg, &f, opts.quad_nodes);
    let (b, qp) = solve_box_qp(&h, &c, opts.max_iters, opts.tol);
    let smurf = AnalyticSmurf::new(cfg.clone(), b);

    // Evaluate residuals on the quadrature grid.
    let (xs, ws) = gauss_legendre_unit(opts.quad_nodes);
    let m = cfg.num_vars();
    let mut idx = vec![0usize; m];
    let mut point = vec![0.0; m];
    let mut sq = 0.0;
    let mut abs = 0.0;
    loop {
        let mut wgt = 1.0;
        for j in 0..m {
            point[j] = xs[idx[j]];
            wgt *= ws[idx[j]];
        }
        let d = smurf.eval(&point) - target.eval(&point);
        sq += wgt * d * d;
        abs += wgt * d.abs();
        let mut j = 0;
        loop {
            idx[j] += 1;
            if idx[j] < xs.len() {
                break;
            }
            idx[j] = 0;
            j += 1;
            if j == m {
                return SynthResult { smurf, qp, l2_error: sq.sqrt(), mae: abs };
            }
        }
    }
}

/// Synthesize a *univariate* function on a dual-FSM SMURF: both FSMs of a
/// bivariate (N×N) SMURF are fed the same variable through independent
/// SNG branches (the paper's architecture at x₁ = x₂ = x). The joint
/// steady state on the diagonal is `π(x) ⊗ π(x)`, doubling the basis
/// richness over a single chain — this is how asymmetric activations like
/// swish reach the paper's reported accuracy (Fig. 9).
///
/// The objective integrates along the diagonal only (that is where the
/// generator operates).
pub fn synthesize_univariate_dual(
    n_states: usize,
    target: &TargetFn,
    opts: &SynthOptions,
) -> SynthResult {
    assert_eq!(target.arity(), 1);
    use crate::fsm::steady::steady_state;
    use crate::util::linalg::Mat;
    let cfg = SmurfConfig::uniform(2, n_states);
    let dim = n_states * n_states;
    let (xs, ws) = gauss_legendre_unit(opts.quad_nodes);
    let mut h = Mat::zeros(dim, dim);
    let mut c = vec![0.0; dim];
    for (&x, &w) in xs.iter().zip(&ws) {
        let pi = steady_state(n_states, x);
        // joint[s] with digit-0 fast: kron(pi, pi).
        let mut joint = vec![0.0; dim];
        for i2 in 0..n_states {
            for i1 in 0..n_states {
                joint[i1 + n_states * i2] = pi[i2] * pi[i1];
            }
        }
        let t = target.eval(&[x]);
        for a in 0..dim {
            c[a] -= w * t * joint[a];
            let wa = w * joint[a];
            for b in 0..dim {
                h.a[a * dim + b] += wa * joint[b];
            }
        }
    }
    let (b, qp) = crate::synth::qp::solve_box_qp(&h, &c, opts.max_iters, opts.tol);
    let smurf = AnalyticSmurf::new(cfg, b);
    // Diagonal residuals.
    let mut sq = 0.0;
    let mut abs = 0.0;
    for (&x, &w) in xs.iter().zip(&ws) {
        let d = smurf.eval(&[x, x]) - target.eval(&[x]);
        sq += w * d * d;
        abs += w * d.abs();
    }
    SynthResult { smurf, qp, l2_error: sq.sqrt(), mae: abs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::functions;

    #[test]
    fn euclid_table1_structure_and_accuracy() {
        // The published Table I values are inconsistent with the paper's
        // own Eq. 21 (see synth::paper_tables); the reproducible claims
        // are accuracy + structure, asserted here.
        let cfg = SmurfConfig::uniform(2, 4);
        let res = synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
        let got = res.smurf.coefficients();
        // Accuracy matches the paper's reported regime (≈0.032 at 64 bits;
        // the analytic bound must be below the bit-level number).
        assert!(res.mae < 0.03, "analytic MAE {} too large", res.mae);
        // Corners: w_0 reads out at (0,0) where f=0; w_15 at (1,1), f=1.
        assert!(got[0] < 0.05, "w_0={}", got[0]);
        assert!(got[15] > 0.95, "w_15={}", got[15]);
        // Symmetric target → symmetric table: w[i1 + 4 i2] == w[i2 + 4 i1].
        for i1 in 0..4 {
            for i2 in 0..4 {
                let a = got[i1 + 4 * i2];
                let b = got[i2 + 4 * i1];
                assert!((a - b).abs() < 1e-6, "asymmetry at ({i1},{i2})");
            }
        }
        // And the edge-corner entries track the univariate boundary:
        // at (1,0) state [0,3] dominates, so w_3 ≈ f(1,0) = 1.
        assert!(got[3] > 0.9, "w_3={} should approach f(1,0)=1", got[3]);
    }

    #[test]
    fn synthesized_tables_beat_paper_tables_under_eq21() {
        // The QP optimum must dominate the published tables in the
        // paper's own objective (Eq. 5) — the documented discrepancy.
        use crate::synth::paper_tables::{TABLE1_EUCLID, TABLE2_SINCOS};
        use crate::synth::qp::objective;
        use crate::synth::quadrature::{c_vector, h_matrix};
        let cfg = SmurfConfig::uniform(2, 4);
        for (f, table) in [
            (functions::euclidean2(), &TABLE1_EUCLID),
            (functions::sincos(), &TABLE2_SINCOS),
        ] {
            let res = synthesize(&cfg, &f, &SynthOptions::default());
            let h = h_matrix(&cfg, 32);
            let g = f.as_fn();
            let c = c_vector(&cfg, &g, 32);
            let ours = objective(&h, &c, res.smurf.coefficients());
            let theirs = objective(&h, &c, table.as_slice());
            assert!(
                ours <= theirs + 1e-9,
                "{}: QP optimum {ours} must not exceed paper-table objective {theirs}",
                f.name()
            );
        }
    }

    #[test]
    fn sincos_table2_structure_and_accuracy() {
        let cfg = SmurfConfig::uniform(2, 4);
        let res = synthesize(&cfg, &functions::sincos(), &SynthOptions::default());
        let got = res.smurf.coefficients();
        assert!(res.mae < 0.02, "analytic MAE {}", res.mae);
        // Corners: f(0,·)=0 at x1=0 edge; f(1,0)=sin(1)≈0.8415.
        assert!(got[0] < 0.05);
        assert!((got[3] - 1f64.sin()).abs() < 0.1, "w_3={}", got[3]);
        // f(1,1)=sin(1)cos(1)≈0.4546 at the (1,1) corner.
        assert!((got[15] - 1f64.sin() * 1f64.cos()).abs() < 0.1, "w_15={}", got[15]);
    }

    #[test]
    fn analytic_error_small_for_smooth_targets() {
        let cfg = SmurfConfig::uniform(2, 4);
        for f in [functions::softmax2(), functions::product2()] {
            let res = synthesize(&cfg, &f, &SynthOptions::default());
            assert!(
                res.mae < 0.01,
                "{}: analytic MAE {} too large",
                f.name(),
                res.mae
            );
        }
    }

    #[test]
    fn univariate_tanh_synthesis_recovers_brown_card() {
        // tanh(2v) bipolar with a 4-state chain: the QP optimum is the
        // Brown-Card binary labelling [0,0,1,1] (Eq. 1 with N/2 = k = 2).
        let cfg = SmurfConfig::uniform(1, 4);
        let res = synthesize(&cfg, &functions::tanh_bipolar(2.0), &SynthOptions::default());
        assert!(res.mae < 0.01, "tanh MAE={}", res.mae);
        let w = res.smurf.coefficients();
        assert!(w[0] < 0.1 && w[1] < 0.1, "left labels {w:?}");
        assert!(w[2] > 0.9 && w[3] > 0.9, "right labels {w:?}");
    }

    #[test]
    fn univariate_swish_via_dual_fsm() {
        // Univariate swish through the bivariate SMURF with both FSMs fed
        // the same variable (paper's architecture at x1 = x2) — the basis
        // doubles and the fit reaches the paper's reported regime
        // (Fig. 9: ≈0.010 analytic at 256 bits).
        let f = functions::swish_bipolar(2.0);
        let res = synthesize_univariate_dual(4, &f, &SynthOptions::default());
        assert!(res.mae < 0.012, "dual-FSM swish diagonal MAE={}", res.mae);
        // Single-chain fit is materially worse — the ablation the dual
        // basis justifies.
        let single = synthesize(
            &SmurfConfig::uniform(1, 4),
            &f,
            &SynthOptions::default(),
        );
        assert!(single.mae > res.mae * 2.0, "single {} vs dual {}", single.mae, res.mae);
    }

    #[test]
    fn trivariate_softmax_synthesis() {
        let cfg = SmurfConfig::uniform(3, 4);
        let res = synthesize(&cfg, &functions::softmax3(), &SynthOptions::default());
        assert!(res.mae < 0.01, "softmax3 MAE={}", res.mae);
        // Sanity: at equal inputs the output is 1/3.
        let y = res.smurf.eval(&[0.5, 0.5, 0.5]);
        assert!((y - 1.0 / 3.0).abs() < 0.02, "y={y}");
    }

    #[test]
    fn mixed_radix_synthesis_works() {
        let cfg = SmurfConfig::new(vec![3, 5]);
        let res = synthesize(&cfg, &functions::product2(), &SynthOptions::default());
        assert_eq!(res.smurf.coefficients().len(), 15);
        assert!(res.mae < 0.01, "MAE={}", res.mae);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let cfg = SmurfConfig::uniform(3, 4);
        synthesize(&cfg, &functions::euclidean2(), &SynthOptions::default());
    }
}

//! Gauss–Legendre quadrature and the SMURF integral assembly.
//!
//! The synthesis integrals (Eq. 8–10) are over smooth rational functions
//! on `[0,1]^M`; tensor-product Gauss–Legendre converges spectrally.
//!
//! Key structural fact: the joint steady state factorizes,
//! `P_s(x) = Π_j π^{(j)}_{s_j}(x_j)`, so
//!
//! `H_{s,s'} = Π_j ∫₀¹ π_{s_j} π_{s'_j} dx = (G^{(M)} ⊗ … ⊗ G^{(1)})_{s,s'}`
//!
//! with the 1-D Gram matrices `G^{(j)}_{a,b} = ∫ π_a π_b dx`. We therefore
//! assemble `H` from M small `N_j × N_j` quadratures instead of an
//! `(ΠN_j)²`-entry M-dimensional integral. `c` needs the target `T` and is
//! evaluated on the full tensor grid, accumulating all states per node via
//! the factored marginals.

use crate::fsm::steady::steady_state;
use crate::smurf::config::SmurfConfig;
use crate::util::linalg::Mat;

/// Gauss–Legendre nodes and weights on `[0,1]`, computed by Newton on
/// Legendre polynomials (standard Golub-free construction, adequate to
/// machine precision for n ≤ 128).
pub fn gauss_legendre_unit(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1);
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = (n + 1) / 2;
    for i in 0..m {
        // Initial guess (Chebyshev-like).
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        // Newton iterations on P_n(x).
        for _ in 0..100 {
            let (p, dp) = legendre_and_deriv(n, x);
            let dx = p / dp;
            x -= dx;
            if dx.abs() < 1e-15 {
                break;
            }
        }
        let (_, dp) = legendre_and_deriv(n, x);
        let w = 2.0 / ((1.0 - x * x) * dp * dp);
        // Map [-1,1] → [0,1].
        nodes[i] = 0.5 * (1.0 - x);
        nodes[n - 1 - i] = 0.5 * (1.0 + x);
        weights[i] = 0.5 * w;
        weights[n - 1 - i] = 0.5 * w;
    }
    (nodes, weights)
}

fn legendre_and_deriv(n: usize, x: f64) -> (f64, f64) {
    let mut p0 = 1.0;
    let mut p1 = x;
    if n == 0 {
        return (1.0, 0.0);
    }
    for k in 2..=n {
        let pk = ((2 * k - 1) as f64 * x * p1 - (k - 1) as f64 * p0) / k as f64;
        p0 = p1;
        p1 = pk;
    }
    let dp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
    (p1, dp)
}

/// 1-D Gram matrix `G_{a,b} = ∫₀¹ π_a(x) π_b(x) dx` for an `n`-state chain,
/// with `quad_nodes` GL points.
pub fn gram_1d(n_states: usize, quad_nodes: usize) -> Mat {
    let (xs, ws) = gauss_legendre_unit(quad_nodes);
    let mut g = Mat::zeros(n_states, n_states);
    for (x, w) in xs.iter().zip(&ws) {
        let pi = steady_state(n_states, *x);
        for a in 0..n_states {
            let wa = w * pi[a];
            for b in 0..n_states {
                g.a[a * n_states + b] += wa * pi[b];
            }
        }
    }
    g
}

/// Assemble the full `H` matrix (Eq. 9–10) as the Kronecker product of the
/// per-variable Gram matrices. Digit 0 (variable 1) is least significant,
/// so `H = G^{(M)} ⊗ … ⊗ G^{(1)}`.
pub fn h_matrix(cfg: &SmurfConfig, quad_nodes: usize) -> Mat {
    let mut h = Mat::from_fn(1, 1, |_, _| 1.0);
    for j in 0..cfg.num_vars() {
        let g = gram_1d(cfg.radix(j), quad_nodes);
        // Kron with the new (more significant) factor on the LEFT:
        // index = i_j * stride + rest.
        h = g.kron(&h);
    }
    h
}

/// Assemble the `c` vector (Eq. 8): `c_s = −∫ T(x) P_s(x) dx` on the
/// tensor-product GL grid.
pub fn c_vector(
    cfg: &SmurfConfig,
    target: &dyn Fn(&[f64]) -> f64,
    quad_nodes: usize,
) -> Vec<f64> {
    let m = cfg.num_vars();
    let (xs, ws) = gauss_legendre_unit(quad_nodes);
    let total_states = cfg.num_aggregate_states();
    let mut c = vec![0.0; total_states];

    // Iterate the tensor grid with an M-digit odometer.
    let mut idx = vec![0usize; m];
    let mut point = vec![0.0; m];
    // Per-variable marginals cached per node index to avoid recompute:
    // marginals[j][k] = steady_state(N_j, xs[k]).
    let marginals: Vec<Vec<Vec<f64>>> = (0..m)
        .map(|j| xs.iter().map(|&x| steady_state(cfg.radix(j), x)).collect())
        .collect();

    loop {
        let mut wgt = 1.0;
        for j in 0..m {
            point[j] = xs[idx[j]];
            wgt *= ws[idx[j]];
        }
        let t = target(&point);
        if t != 0.0 {
            // Accumulate over all aggregate states via the factored joint:
            // joint[s] = Π_j marginals[j][idx[j]][s_j], built incrementally.
            let mut joint = vec![t * wgt];
            for j in 0..m {
                let marg = &marginals[j][idx[j]];
                let mut next = Vec::with_capacity(joint.len() * marg.len());
                for &mj in marg {
                    for &jv in &joint {
                        next.push(mj * jv);
                    }
                }
                joint = next;
            }
            for (cs, jv) in c.iter_mut().zip(&joint) {
                *cs -= jv;
            }
        }
        // Odometer increment.
        let mut j = 0;
        loop {
            idx[j] += 1;
            if idx[j] < xs.len() {
                break;
            }
            idx[j] = 0;
            j += 1;
            if j == m {
                return c;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gl_integrates_polynomials_exactly() {
        // n-point GL is exact to degree 2n-1 on [0,1].
        let (xs, ws) = gauss_legendre_unit(4);
        // ∫ x^7 = 1/8
        let s: f64 = xs.iter().zip(&ws).map(|(x, w)| w * x.powi(7)).sum();
        assert!((s - 0.125).abs() < 1e-14, "s={s}");
        // weights sum to 1
        assert!((ws.iter().sum::<f64>() - 1.0).abs() < 1e-14);
    }

    #[test]
    fn gl_many_nodes_smooth_function() {
        let (xs, ws) = gauss_legendre_unit(32);
        let s: f64 = xs.iter().zip(&ws).map(|(x, w)| w * (x * 3.0).sin()).sum();
        let exact = (1.0 - (3.0f64).cos()) / 3.0;
        assert!((s - exact).abs() < 1e-12);
    }

    #[test]
    fn gram_is_symmetric_psd() {
        let g = gram_1d(4, 32);
        for a in 0..4 {
            for b in 0..4 {
                assert!((g.at(a, b) - g.at(b, a)).abs() < 1e-14);
            }
            assert!(g.at(a, a) > 0.0);
        }
        // PSD: x^T G x >= 0 for a few random x.
        let mut rng = crate::util::prng::Pcg::new(1);
        for _ in 0..10 {
            let x: Vec<f64> = (0..4).map(|_| rng.range(-1.0, 1.0)).collect();
            let gx = g.matvec(&x);
            let q: f64 = x.iter().zip(&gx).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-12);
        }
    }

    #[test]
    fn gram_rows_integrate_marginals() {
        // Σ_b G_{a,b} = ∫ π_a(x) Σ_b π_b(x) dx = ∫ π_a dx.
        let n = 4;
        let g = gram_1d(n, 48);
        let (xs, ws) = gauss_legendre_unit(48);
        for a in 0..n {
            let direct: f64 = xs
                .iter()
                .zip(&ws)
                .map(|(&x, &w)| w * steady_state(n, x)[a])
                .sum();
            let row: f64 = (0..n).map(|b| g.at(a, b)).sum();
            assert!((row - direct).abs() < 1e-12);
        }
    }

    #[test]
    fn h_is_kron_of_grams() {
        let cfg = SmurfConfig::uniform(2, 3);
        let h = h_matrix(&cfg, 24);
        let g = gram_1d(3, 24);
        // Spot-check H[(i2,i1),(k2,k1)] = G[i2,k2]*G[i1,k1].
        for i2 in 0..3 {
            for i1 in 0..3 {
                for k2 in 0..3 {
                    for k1 in 0..3 {
                        let r = i1 + 3 * i2;
                        let c = k1 + 3 * k2;
                        assert!(
                            (h.at(r, c) - g.at(i2, k2) * g.at(i1, k1)).abs() < 1e-14
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn h_entries_sum_to_one() {
        // Σ_{s,s'} H_{s,s'} = ∫ (Σ_s P_s)(Σ_{s'} P_{s'}) = ∫ 1 = 1.
        let cfg = SmurfConfig::uniform(2, 4);
        let h = h_matrix(&cfg, 32);
        let total: f64 = h.a.iter().sum();
        assert!((total - 1.0).abs() < 1e-10, "total={total}");
    }

    #[test]
    fn c_for_constant_target_sums() {
        // T ≡ 1 → Σ_s (−c_s) = ∫ Σ_s P_s = 1.
        let cfg = SmurfConfig::uniform(2, 4);
        let c = c_vector(&cfg, &|_| 1.0, 24);
        let s: f64 = c.iter().sum();
        assert!((s + 1.0).abs() < 1e-10, "sum={s}");
    }

    #[test]
    fn c_univariate_matches_direct_integral() {
        // M=1: c_a = −∫ T(x) π_a(x) dx, computable directly.
        let cfg = SmurfConfig::uniform(1, 4);
        let t = |x: &[f64]| x[0] * x[0];
        let c = c_vector(&cfg, &t, 40);
        let (xs, ws) = gauss_legendre_unit(40);
        for a in 0..4 {
            let direct: f64 = xs
                .iter()
                .zip(&ws)
                .map(|(&x, &w)| -w * x * x * steady_state(4, x)[a])
                .sum();
            assert!((c[a] - direct).abs() < 1e-12);
        }
    }
}

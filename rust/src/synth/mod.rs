//! Coefficient synthesis (paper §III-B, Eq. 5–11).
//!
//! Finds the CPT coefficients `b = [P_{w_0} … P_{w_{ΠN_j - 1}}]` minimizing
//! the L2 error `ε = ∫ (T(P_x) − P_y)² dP_x` over the unit hypercube,
//! which reduces to the box-constrained quadratic program
//!
//! `min_{0 ≤ b ≤ 1}  φ(b) = bᵀ H b + 2 cᵀ b`
//!
//! with `H_{s,s'} = ∫ P_s P_{s'}` and `c_s = −∫ T P_s` (Eq. 8–10).
//!
//! - [`quadrature`] — Gauss–Legendre nodes/weights; `H` exploits the
//!   Kronecker factorization `H = G^{(M)} ⊗ … ⊗ G^{(1)}`.
//! - [`qp`] — projected-gradient solver with Nesterov acceleration.
//! - [`functions`] — every target function the paper evaluates, plus a
//!   library of extras.
//! - [`synthesize`] — the end-to-end flow.

pub mod functions;
pub mod paper_tables;
pub mod qp;
pub mod quadrature;
pub mod synthesize;

pub use synthesize::{synthesize, SynthOptions, SynthResult};

//! Box-constrained quadratic programming (paper Eq. 11).
//!
//! Minimize `φ(b) = bᵀHb + 2cᵀb` subject to `0 ≤ b ≤ 1`, with `H`
//! symmetric PSD. Solver: FISTA (projected gradient with Nesterov
//! momentum) with the step size from the spectral radius of `H`, plus an
//! unconstrained-Cholesky fast path when the unconstrained minimizer
//! already lies in the box (common for well-conditioned targets).

use crate::util::linalg::{dot, Mat};

/// Solver diagnostics.
#[derive(Clone, Debug)]
pub struct QpReport {
    pub iterations: usize,
    pub objective: f64,
    /// Max violation of the projected-gradient optimality condition.
    pub kkt_residual: f64,
    /// Whether the unconstrained Cholesky fast path was used.
    pub used_cholesky: bool,
}

/// Solve `min_{0≤b≤1} bᵀHb + 2cᵀb`.
pub fn solve_box_qp(h: &Mat, c: &[f64], max_iters: usize, tol: f64) -> (Vec<f64>, QpReport) {
    let n = c.len();
    assert_eq!(h.rows, n);
    assert_eq!(h.cols, n);

    // Fast path: unconstrained minimizer Hb = -c, accept if inside box.
    if let Some(b) = h.solve_spd(&c.iter().map(|x| -x).collect::<Vec<_>>()) {
        if b.iter().all(|&x| (-1e-12..=1.0 + 1e-12).contains(&x)) {
            let b: Vec<f64> = b.iter().map(|&x| x.clamp(0.0, 1.0)).collect();
            let obj = objective(h, c, &b);
            let kkt = kkt_residual(h, c, &b);
            return (
                b,
                QpReport { iterations: 0, objective: obj, kkt_residual: kkt, used_cholesky: true },
            );
        }
    }

    // FISTA. Lipschitz constant of ∇φ = 2Hb + 2c is 2·λmax(H).
    let lmax = h.spectral_radius_sym(200).max(1e-30);
    let step = 1.0 / (2.0 * lmax);

    let mut b = vec![0.5; n];
    let mut y = b.clone();
    let mut t = 1.0f64;
    let mut iters = 0;
    for k in 0..max_iters {
        iters = k + 1;
        // grad at y
        let hy = h.matvec(&y);
        let mut b_next = vec![0.0; n];
        for i in 0..n {
            let g = 2.0 * (hy[i] + c[i]);
            b_next[i] = (y[i] - step * g).clamp(0.0, 1.0);
        }
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let beta = (t - 1.0) / t_next;
        let mut max_dx = 0.0f64;
        for i in 0..n {
            let dx = b_next[i] - b[i];
            max_dx = max_dx.max(dx.abs());
            y[i] = b_next[i] + beta * dx;
        }
        b = b_next;
        t = t_next;
        if max_dx < tol {
            // Confirm with the KKT residual before stopping: momentum can
            // stall briefly without being optimal.
            if kkt_residual(h, c, &b) < tol * 10.0 {
                break;
            }
        }
    }
    let obj = objective(h, c, &b);
    let kkt = kkt_residual(h, c, &b);
    (b, QpReport { iterations: iters, objective: obj, kkt_residual: kkt, used_cholesky: false })
}

/// `φ(b) = bᵀHb + 2cᵀb`.
pub fn objective(h: &Mat, c: &[f64], b: &[f64]) -> f64 {
    let hb = h.matvec(b);
    dot(b, &hb) + 2.0 * dot(c, b)
}

/// Projected-gradient KKT residual: `‖b − Π_box(b − ∇φ)‖_∞`.
pub fn kkt_residual(h: &Mat, c: &[f64], b: &[f64]) -> f64 {
    let hb = h.matvec(b);
    let mut r = 0.0f64;
    for i in 0..b.len() {
        let g = 2.0 * (hb[i] + c[i]);
        let proj = (b[i] - g).clamp(0.0, 1.0);
        r = r.max((b[i] - proj).abs());
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diag(d: &[f64]) -> Mat {
        Mat::from_fn(d.len(), d.len(), |i, j| if i == j { d[i] } else { 0.0 })
    }

    #[test]
    fn interior_solution_via_cholesky() {
        // min (b-0.5)^T D (b-0.5): H=D, c = -D·0.5.
        let h = diag(&[1.0, 2.0, 3.0]);
        let c = vec![-0.5, -1.0, -1.5];
        let (b, rep) = solve_box_qp(&h, &c, 1000, 1e-12);
        assert!(rep.used_cholesky);
        for &x in &b {
            assert!((x - 0.5).abs() < 1e-10);
        }
    }

    #[test]
    fn clipped_solution_at_box_boundary() {
        // Unconstrained minimizer at b=1.5 → clipped to 1.
        let h = diag(&[1.0]);
        let c = vec![-1.5];
        let (b, rep) = solve_box_qp(&h, &c, 5000, 1e-12);
        assert!((b[0] - 1.0).abs() < 1e-8, "b={:?} rep={rep:?}", b);
    }

    #[test]
    fn negative_direction_clips_to_zero() {
        let h = diag(&[1.0, 1.0]);
        let c = vec![0.7, -0.3]; // minimizers at -0.7 (→0) and 0.3
        let (b, _) = solve_box_qp(&h, &c, 5000, 1e-12);
        assert!(b[0].abs() < 1e-8);
        assert!((b[1] - 0.3).abs() < 1e-8);
    }

    #[test]
    fn coupled_h_kkt_satisfied() {
        // Random SPD H with known structure, generic c: verify KKT.
        let m = Mat::from_fn(6, 6, |i, j| ((i * 7 + j * 3) as f64).sin());
        let mut h = Mat::zeros(6, 6);
        for i in 0..6 {
            for j in 0..6 {
                let mut s = if i == j { 0.5 } else { 0.0 };
                for k in 0..6 {
                    s += m.at(k, i) * m.at(k, j);
                }
                *h.at_mut(i, j) = s;
            }
        }
        let c: Vec<f64> = (0..6).map(|i| ((i as f64) - 3.0) * 0.4).collect();
        let (b, rep) = solve_box_qp(&h, &c, 20_000, 1e-12);
        assert!(rep.kkt_residual < 1e-7, "kkt={}", rep.kkt_residual);
        for &x in &b {
            assert!((-1e-12..=1.0 + 1e-12).contains(&x));
        }
    }

    #[test]
    fn objective_decreases_vs_midpoint_start() {
        let h = diag(&[2.0, 2.0]);
        let c = vec![-0.2, -1.9];
        let (b, rep) = solve_box_qp(&h, &c, 5000, 1e-12);
        let mid = objective(&h, &c, &[0.5, 0.5]);
        assert!(rep.objective <= mid + 1e-12, "{} vs {mid}", rep.objective);
        assert!((b[0] - 0.1).abs() < 1e-7);
        assert!((b[1] - 0.95).abs() < 1e-7);
    }
}

//! In-repo substrates: JSON, deterministic PRNG, statistics and small
//! linear-algebra helpers.
//!
//! The build environment is fully offline and only vendors the `xla`
//! crate's dependency closure, so serde/rand/etc. are implemented here at
//! the (small) scale this project needs.

pub mod json;
pub mod linalg;
pub mod prng;
pub mod stats;
pub mod sync;

/// Relative-or-absolute closeness check used across tests.
///
/// Returns `true` when `|a-b| <= atol + rtol*|b|`.
pub fn allclose(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs()
}

/// Mean absolute error between two equally-long slices.
///
/// Panics if lengths differ or are zero.
pub fn mae(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "mae: length mismatch");
    assert!(!a.is_empty(), "mae: empty input");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Clamp a probability into the closed unit interval.
#[inline]
pub fn clamp01(p: f64) -> f64 {
    p.clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_basic() {
        assert!(allclose(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!allclose(1.0, 1.1, 1e-9, 1e-9));
    }

    #[test]
    fn mae_basic() {
        assert_eq!(mae(&[1.0, 2.0], &[1.0, 4.0]), 1.0);
    }

    #[test]
    #[should_panic]
    fn mae_len_mismatch_panics() {
        mae(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn clamp01_edges() {
        assert_eq!(clamp01(-0.5), 0.0);
        assert_eq!(clamp01(1.5), 1.0);
        assert_eq!(clamp01(0.25), 0.25);
    }
}

//! Dense column-free linear algebra at the scale the synthesis engine
//! needs: symmetric `N^M × N^M` systems with `N^M ≤ 4096`.

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub a: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, a: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.a[i * cols + j] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.a[i * self.cols + j]
    }

    /// `y = A x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            let row = &self.a[i * self.cols..(i + 1) * self.cols];
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
        y
    }

    /// Kronecker product `self ⊗ other`.
    pub fn kron(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows * other.rows, self.cols * other.cols);
        for i1 in 0..self.rows {
            for j1 in 0..self.cols {
                let s = self.at(i1, j1);
                if s == 0.0 {
                    continue;
                }
                for i2 in 0..other.rows {
                    for j2 in 0..other.cols {
                        *out.at_mut(i1 * other.rows + i2, j1 * other.cols + j2) =
                            s * other.at(i2, j2);
                    }
                }
            }
        }
        out
    }

    /// Largest eigenvalue of a symmetric PSD matrix by power iteration.
    pub fn spectral_radius_sym(&self, iters: usize) -> f64 {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut v = vec![1.0 / (n as f64).sqrt(); n];
        let mut lambda = 0.0;
        for _ in 0..iters {
            let w = self.matvec(&v);
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm == 0.0 {
                return 0.0;
            }
            lambda = norm;
            for (vi, wi) in v.iter_mut().zip(&w) {
                *vi = wi / norm;
            }
        }
        lambda
    }

    /// Solve `A x = b` for symmetric positive-definite `A` via Cholesky.
    /// Returns `None` when the matrix is not (numerically) PD.
    pub fn solve_spd(&self, b: &[f64]) -> Option<Vec<f64>> {
        assert_eq!(self.rows, self.cols);
        assert_eq!(b.len(), self.rows);
        let n = self.rows;
        // Cholesky factor L (lower), in place on a copy.
        let mut l = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let mut sum = self.at(i, j);
                for k in 0..j {
                    sum -= l[i * n + k] * l[j * n + k];
                }
                if i == j {
                    if sum <= 0.0 {
                        return None;
                    }
                    l[i * n + i] = sum.sqrt();
                } else {
                    l[i * n + j] = sum / l[j * n + j];
                }
            }
        }
        // Forward solve L y = b
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut sum = b[i];
            for k in 0..i {
                sum -= l[i * n + k] * y[k];
            }
            y[i] = sum / l[i * n + i];
        }
        // Back solve L^T x = y
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut sum = y[i];
            for k in i + 1..n {
                sum -= l[k * n + i] * x[k];
            }
            x[i] = sum / l[i * n + i];
        }
        Some(x)
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let eye = Mat::from_fn(3, 3, |i, j| if i == j { 1.0 } else { 0.0 });
        assert_eq!(eye.matvec(&[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn kron_shapes_and_values() {
        let a = Mat::from_fn(2, 2, |i, j| (i * 2 + j) as f64 + 1.0); // [[1,2],[3,4]]
        let b = Mat::from_fn(2, 2, |i, j| if i == j { 1.0 } else { 0.0 });
        let k = a.kron(&b);
        assert_eq!(k.rows, 4);
        assert_eq!(k.at(0, 0), 1.0);
        assert_eq!(k.at(0, 2), 2.0);
        assert_eq!(k.at(3, 3), 4.0);
        assert_eq!(k.at(0, 1), 0.0);
    }

    #[test]
    fn spectral_radius_of_diag() {
        let d = Mat::from_fn(3, 3, |i, j| if i == j { (i + 1) as f64 } else { 0.0 });
        let lam = d.spectral_radius_sym(100);
        assert!((lam - 3.0).abs() < 1e-9, "lam={lam}");
    }

    #[test]
    fn cholesky_solves_spd() {
        // A = M^T M + I is SPD.
        let m = Mat::from_fn(3, 3, |i, j| ((i * 3 + j) as f64).sin());
        let mut a = Mat::zeros(3, 3);
        for i in 0..3 {
            for j in 0..3 {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..3 {
                    s += m.at(k, i) * m.at(k, j);
                }
                *a.at_mut(i, j) = s;
            }
        }
        let x_true = vec![1.0, -2.0, 0.5];
        let b = a.matvec(&x_true);
        let x = a.solve_spd(&b).unwrap();
        for (xi, ti) in x.iter().zip(&x_true) {
            assert!((xi - ti).abs() < 1e-10);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_fn(2, 2, |i, j| if i == j { -1.0 } else { 0.0 });
        assert!(a.solve_spd(&[1.0, 1.0]).is_none());
    }
}

//! Streaming statistics and latency histograms used by benches and the
//! coordinator's metrics endpoint.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator); 0 for n < 2.
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Fixed-boundary log-scaled latency histogram (nanoseconds), lock-free
/// enough for our single-writer-per-worker use (merged at read time).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// Bucket k covers [2^k, 2^{k+1}) ns, k in 0..64.
    buckets: Vec<u64>,
    count: u64,
    sum_ns: u64,
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 64], count: 0, sum_ns: 0, max_ns: 0 }
    }

    pub fn record(&mut self, ns: u64) {
        let k = 63 - ns.max(1).leading_zeros() as usize;
        self.buckets[k] += 1;
        self.count += 1;
        self.sum_ns += ns;
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    /// Approximate quantile from bucket boundaries (upper edge of the
    /// bucket containing the q-th sample): within 2x of truth by design.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((self.count as f64) * q.clamp(0.0, 1.0)).ceil() as u64;
        let mut seen = 0;
        for (k, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target && n > 0 {
                return 1u64 << (k + 1);
            }
        }
        self.max_ns
    }
}

/// Percentile of a sorted slice (nearest-rank).
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((p.clamp(0.0, 100.0) / 100.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.var() - var).abs() < 1e-12);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn welford_small_n() {
        let mut w = Welford::new();
        assert_eq!(w.var(), 0.0);
        w.push(5.0);
        assert_eq!(w.mean(), 5.0);
        assert_eq!(w.var(), 0.0);
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let mut h = LatencyHistogram::new();
        for ns in [100u64, 200, 400, 800, 100_000] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert!(h.quantile_ns(0.5) <= h.quantile_ns(0.99));
        assert!(h.mean_ns() > 0.0);
        assert_eq!(h.max_ns(), 100_000);
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record(1000);
        b.record(2000);
        a.merge(&b);
        assert_eq!(a.count(), 2);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile_sorted(&v, 0.0), 1.0);
        assert_eq!(percentile_sorted(&v, 100.0), 5.0);
        assert_eq!(percentile_sorted(&v, 50.0), 3.0);
    }
}

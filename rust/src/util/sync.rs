//! Synchronization facade: std primitives by default, [loom] mock
//! primitives under `RUSTFLAGS="--cfg loom"` (`make loom`).
//!
//! Every concurrency kernel in the serving core ([`crate::coordinator`])
//! imports `Arc`, `Mutex` and the atomics from here instead of
//! `std::sync`, so the exact shipping protocols — admission CAS depth
//! tokens, the hysteresis shed latch, the supervisor wakeup flag, the
//! sentinel quarantine machine — can be compiled against loom's
//! model-checked types and exhaustively explored in
//! `rust/tests/loom_models.rs`. Default builds re-export std and stay
//! zero-dep; the `loom` crate is only resolved when its (commented-out)
//! dependency line in `rust/Cargo.toml` is enabled, which `make loom`
//! checks for.
//!
//! [loom]: https://docs.rs/loom
//!
//! Besides the re-exports, two shared helpers live here:
//!
//! - [`lock_unpoisoned`] — the repo-wide poison-tolerant lock idiom. The
//!   serving core's mutexes guard plain counters and state tables whose
//!   invariants hold between lock operations, so a panic while holding
//!   one (itself isolated by `catch_unwind` in the worker) must not
//!   cascade `PoisonError` panics through every later metrics call.
//! - [`WakeSignal`] — the supervisor wakeup primitive; see its docs for
//!   the lost-wakeup proof obligations it discharges.

#[cfg(loom)]
pub use loom::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard};

#[cfg(not(loom))]
pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard};

use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// Used for every serving-core mutex: the guarded data are counters,
/// histograms and per-function state tables that are consistent between
/// lock operations, so continuing past a poisoned flag is sound — and
/// required, because worker panics are an *expected*, injected-and-tested
/// event (`coordinator::fault`), and one of them must not convert every
/// subsequent `Metrics::record` into a second panic. Loom's `Mutex`
/// reuses std's `LockResult`, so this compiles identically under both
/// cfgs.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// A level-triggered wakeup flag for the supervisor thread.
///
/// Protocol: the supervisor calls [`register_current`](Self::register_current)
/// once at loop entry, then blocks in [`wait_timeout`](Self::wait_timeout);
/// any thread (worker panic path, `shutdown()`) calls
/// [`notify`](Self::notify) to wake it. Three properties make this
/// lose-proof where the previous `OnceLock<Thread>` + raw `unpark` wiring
/// was not:
///
/// 1. **The wakeup is level-triggered, not edge-triggered.** `notify`
///    sets `pending` (Release) *before* unparking; `wait_timeout` checks
///    `pending` (Acquire swap) both before parking and after the park
///    returns. A notify that lands between the check and the park still
///    wakes the parked thread via the park token; a notify that lands
///    before the wait starts is observed by the pre-park check.
/// 2. **A notify before registration is never lost.** The flag persists:
///    a worker that dies before the supervisor thread handle is
///    registered (the PR-7 startup race — `OnceLock::get()` returned
///    `None` and the unpark was silently skipped) now leaves `pending`
///    set, and the supervisor's first `wait_timeout` returns
///    immediately.
/// 3. **Release/Acquire on `pending` publishes the event.** Whatever the
///    notifier wrote before `notify()` (a finished worker handle, the
///    `stop` flag) is visible to the waiter after `wait_timeout` returns
///    `true` — model-checked in `loom_models::wake_signal_publishes_event`.
///
/// Under `cfg(loom)` the park/unpark half is replaced by a yield-spin on
/// the flag (loom has no `park_timeout`): the models verify the flag
/// protocol and its memory ordering, while the std-only park pairing is
/// covered by the unit tests below plus the chaos suite.
#[derive(Debug)]
pub struct WakeSignal {
    /// Level-triggered "a wakeup happened" flag; survives the window
    /// before the waiter registers or parks.
    pending: AtomicBool,
    /// The registered waiter thread, if any (std builds only — loom
    /// models the flag protocol without parking).
    #[cfg(not(loom))]
    waiter: Mutex<Option<std::thread::Thread>>,
}

impl WakeSignal {
    pub fn new() -> Self {
        Self {
            pending: AtomicBool::new(false),
            #[cfg(not(loom))]
            waiter: Mutex::new(None),
        }
    }

    /// Record the calling thread as the waiter [`notify`](Self::notify)
    /// unparks. Idempotent; call before the first
    /// [`wait_timeout`](Self::wait_timeout).
    #[cfg(not(loom))]
    pub fn register_current(&self) {
        *lock_unpoisoned(&self.waiter) = Some(std::thread::current());
    }

    /// Loom builds model the flag protocol only; there is no thread
    /// handle to register.
    #[cfg(loom)]
    pub fn register_current(&self) {}

    /// Wake the waiter: set the level-triggered flag, then unpark the
    /// registered thread (if registration already happened — if not, the
    /// flag alone guarantees delivery).
    pub fn notify(&self) {
        self.pending.store(true, Ordering::Release);
        #[cfg(not(loom))]
        if let Some(t) = lock_unpoisoned(&self.waiter).as_ref() {
            t.unpark();
        }
    }

    /// Block until notified or `timeout` elapses; returns `true` if a
    /// notify was consumed. Spurious `park_timeout` returns are absorbed
    /// by re-checking the flag; the flag is consumed (swapped to false)
    /// exactly when `true` is returned.
    #[cfg(not(loom))]
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        if self.pending.swap(false, Ordering::Acquire) {
            return true;
        }
        std::thread::park_timeout(timeout);
        self.pending.swap(false, Ordering::Acquire)
    }

    /// Loom variant: bounded waits cannot be modeled (no `park_timeout`),
    /// so this blocks until notified. Only reachable inside
    /// `loom::model`.
    #[cfg(loom)]
    pub fn wait_timeout(&self, _timeout: Duration) -> bool {
        self.wait()
    }

    /// Loom-only blocking wait: yield-spin until the flag is observed.
    #[cfg(loom)]
    pub fn wait(&self) -> bool {
        while !self.pending.swap(false, Ordering::Acquire) {
            loom::thread::yield_now();
        }
        true
    }
}

impl Default for WakeSignal {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn lock_unpoisoned_recovers_from_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert_eq!(*lock_unpoisoned(&m), 7, "guard must be recoverable after a panic");
    }

    /// Regression for the PR-7 startup race: a notify that fires before
    /// the waiter thread registers (worker panics while the server is
    /// still spawning) must not be lost.
    #[test]
    fn notify_before_register_is_not_lost() {
        let s = WakeSignal::new();
        s.notify();
        s.register_current();
        let t0 = Instant::now();
        assert!(
            s.wait_timeout(Duration::from_secs(5)),
            "pre-registration notify must be observed"
        );
        assert!(t0.elapsed() < Duration::from_secs(1), "must return immediately, not park");
    }

    #[test]
    fn notify_consumed_exactly_once() {
        let s = WakeSignal::new();
        s.register_current();
        s.notify();
        assert!(s.wait_timeout(Duration::from_millis(1)));
        // Flag consumed: the next wait times out.
        assert!(!s.wait_timeout(Duration::from_millis(1)));
    }

    #[test]
    fn cross_thread_notify_wakes_a_parked_waiter() {
        let s = Arc::new(WakeSignal::new());
        s.register_current();
        let s2 = s.clone();
        let notifier = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            s2.notify();
        });
        let t0 = Instant::now();
        assert!(s.wait_timeout(Duration::from_secs(10)), "notify must wake the park");
        assert!(t0.elapsed() < Duration::from_secs(5));
        notifier.join().unwrap();
    }

    #[test]
    fn timeout_elapses_without_notify() {
        let s = WakeSignal::new();
        s.register_current();
        assert!(!s.wait_timeout(Duration::from_millis(5)));
    }
}

//! Deterministic, seedable PRNG used by tests, data synthesis and the
//! property-testing harness. This is *not* the stochastic-computing entropy
//! source — the hardware-faithful RNGs (LFSR, Sobol) live in [`crate::sc::rng`].
//!
//! The generator is splitmix64 feeding xoshiro256**, the standard
//! recommendation for fast, high-quality, reproducible simulation streams.

/// The splitmix64 increment: `⌊2⁶⁴/φ⌋` rounded to odd (the 64-bit
/// "golden gamma" from Steele et al., *Fast Splittable Pseudorandom
/// Number Generators*). Every seed-expansion and seed-derivation site in
/// the crate references this single named constant — per-lane entropy
/// splits (`sc::rng`), fault-plan keying (`sc::fault`), wide-engine lane
/// seeding (`smurf::sim`/`sim_wide`), and PwMM stream striding
/// (`sc::pwmm_wide`) — so the seed-discipline lint (`xtask verify`) can
/// reject stray copies of the magic literal.
pub const GOLDEN_GAMMA: u64 = 0x9E3779B97F4A7C15;

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Pcg {
    s: [u64; 4],
}

impl Pcg {
    /// Create a generator from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into 256 bits of state.
        let mut x = seed.wrapping_add(GOLDEN_GAMMA);
        let mut next = || {
            x = x.wrapping_add(GOLDEN_GAMMA);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`. Panics if `n == 0`.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        // Multiply-shift rejection-free mapping (slight bias negligible
        // at simulation scale: 2^-64 * n).
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform `Duration` in `[lo, hi)`. The resilient client's backoff
    /// jitter draws from a seeded stream through this helper (no
    /// `thread_rng` anywhere), so retry schedules replay deterministically
    /// under a fixed seed. `hi <= lo` returns `lo`.
    pub fn range_duration(&mut self, lo: std::time::Duration, hi: std::time::Duration) -> std::time::Duration {
        if hi <= lo {
            return lo;
        }
        let span = (hi - lo).as_nanos().min(u64::MAX as u128) as u64;
        lo + std::time::Duration::from_nanos(self.below(span.max(1)))
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg::new(1);
        let mut b = Pcg::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg::new(3);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_duration_bounds_and_determinism() {
        use std::time::Duration;
        let (lo, hi) = (Duration::from_millis(2), Duration::from_millis(6));
        let mut a = Pcg::new(9);
        let mut b = Pcg::new(9);
        for _ in 0..200 {
            let d = a.range_duration(lo, hi);
            assert!(d >= lo && d < hi, "{d:?}");
            assert_eq!(d, b.range_duration(lo, hi), "same seed, same jitter schedule");
        }
        // Degenerate span collapses to lo instead of panicking.
        assert_eq!(a.range_duration(hi, lo), hi);
        assert_eq!(a.range_duration(lo, lo), lo);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 200_000;
        let (mut m1, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m1 += z;
            m2 += z * z;
        }
        m1 /= n as f64;
        m2 /= n as f64;
        assert!(m1.abs() < 0.02, "mean={m1}");
        assert!((m2 - 1.0).abs() < 0.05, "var={m2}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}

//! Minimal JSON reader/writer for artifact interchange (model weights,
//! synthesized coefficient tables, experiment records).
//!
//! serde is not vendored in this offline environment, so we own a small,
//! strict JSON implementation: full value model, recursive-descent parser
//! with depth limit, and a writer that round-trips f64 losslessly enough
//! for weight interchange (17 significant digits).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as `f64` (adequate for weight/metric
/// interchange; integers up to 2^53 are exact).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document. Returns an error message with byte offset on
    /// malformed input.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 && (*x != 0.0 || x.is_sign_positive()) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{:?}", x); // shortest round-trip repr
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (k, x) in xs.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    write_escaped(key, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Interpret as a flat numeric vector.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Build from a flat f64 slice.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Build from f32s (weights).
    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting depth > {MAX_DEPTH} at byte {}", self.i));
        }
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(format!("unexpected byte {:?} at {}", c as char, self.i)),
        }
    }

    fn peek(&self) -> Result<u8, String> {
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end of input".into())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {s:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        debug_assert_eq!(self.b[self.i], b'"');
        self.i += 1;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("truncated \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            // Surrogate pairs: accept and combine.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or("truncated surrogate")?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2).map_err(|_| "bad surrogate")?,
                                        16,
                                    )
                                    .map_err(|_| "bad surrogate")?;
                                    self.i += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                    .ok_or("bad surrogate pair")?
                                } else {
                                    return Err("lone high surrogate".into());
                                }
                            } else {
                                char::from_u32(cp).ok_or("bad codepoint")?
                            };
                            out.push(ch);
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i - 1)),
                    }
                }
                c if c < 0x20 => return Err(format!("raw control byte at {}", self.i - 1)),
                c => {
                    // Re-decode UTF-8: back up and take the full char.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let s = std::str::from_utf8(&self.b[self.i - 1..])
                            .map_err(|_| "invalid utf-8")?;
                        let ch = s.chars().next().unwrap();
                        out.push(ch);
                        self.i += ch.len_utf8() - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // '['
        self.depth += 1;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(format!("expected ',' or ']' got {:?} at {}", c as char, self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // '{'
        self.depth += 1;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            if self.peek()? != b'"' {
                return Err(format!("expected object key at byte {}", self.i));
            }
            let key = self.string()?;
            self.skip_ws();
            if self.peek()? != b':' {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.skip_ws();
            m.insert(key, self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(format!("expected ',' or '}}' got {:?} at {}", c as char, self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.25").unwrap(), Json::Num(3.25));
        assert_eq!(Json::parse("-1e3").unwrap(), Json::Num(-1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"w":[0.0,0.6083,0.0474,0.6911],"n":4,"name":"euclid"}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn roundtrip_floats_exact() {
        let xs = [0.1, 1.0 / 3.0, 1e-300, 123456789.123456789, -0.0];
        let j = Json::from_f64s(&xs);
        let back = Json::parse(&j.dump()).unwrap().as_f64_vec().unwrap();
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{'a':1}").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn depth_limit() {
        let deep = "[".repeat(10_000) + &"]".repeat(10_000);
        assert!(Json::parse(&deep).is_err());
    }
}

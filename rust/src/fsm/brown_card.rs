//! Brown–Card FSM nonlinear generators (paper §II-C, ref [14]).
//!
//! The 2001 scheme: an N-state chain whose *output* is a fixed 0/1 label
//! per state. With the right half labelled 1 the output mean approximates
//! `tanh(N/2 · x)` in bipolar encoding (paper Eq. 1 states the unipolar
//! equivalent). This is the univariate prior art SMURF generalizes: labels
//! here are binary and fixed, where SMURF's CPT-gate makes them
//! *continuous, synthesized* coefficients.

use super::chain::ChainFsm;
use super::steady::steady_state;
use crate::sc::rng::StreamRng;
use crate::sc::sng::ThetaGate;

/// A Brown–Card generator: chain FSM + per-state binary output label.
#[derive(Clone, Debug)]
pub struct BrownCardFsm {
    fsm: ChainFsm,
    labels: Vec<bool>,
}

impl BrownCardFsm {
    pub fn new(labels: Vec<bool>) -> Self {
        assert!(labels.len() >= 2);
        Self { fsm: ChainFsm::centered(labels.len()), labels }
    }

    /// The classic tanh configuration: states `N/2 …` output 1.
    pub fn tanh(n: usize) -> Self {
        assert!(n >= 2 && n % 2 == 0, "tanh config needs even N");
        Self::new((0..n).map(|i| i >= n / 2).collect())
    }

    /// The exp configuration from [14]: only the leftmost `n-1` states of
    /// the *complement* — output 1 unless in the rightmost state.
    pub fn exp(n: usize) -> Self {
        Self::new((0..n).map(|i| i < n - 1).collect())
    }

    pub fn num_states(&self) -> usize {
        self.labels.len()
    }

    /// One cycle: transition on the input bit, emit the new state's label.
    #[inline]
    pub fn step(&mut self, bit: bool) -> bool {
        let s = self.fsm.step(bit);
        self.labels[s]
    }

    /// Bit-level simulation: drive with a θ-gate encoding `p_x` for `len`
    /// cycles and return the output mean.
    pub fn run(&mut self, p_x: f64, len: usize, rng: &mut impl StreamRng) -> f64 {
        let gate = ThetaGate::new(p_x);
        let mut ones = 0u64;
        for _ in 0..len {
            let bit = gate.sample(rng.next_u16());
            ones += self.step(bit) as u64;
        }
        ones as f64 / len as f64
    }

    /// Analytic (infinite-stream) output: Σ_i π_i · label_i.
    pub fn analytic(&self, p_x: f64) -> f64 {
        steady_state(self.labels.len(), p_x)
            .iter()
            .zip(&self.labels)
            .map(|(pi, &l)| if l { *pi } else { 0.0 })
            .sum()
    }
}

/// The paper's Eq. 1 approximation target for the tanh configuration, in
/// the paper's own unipolar form:
/// `P_y ≈ (e^{N/2·Px} - e^{-N/2·Px}) / (e^{N/2·Px} + e^{-N/2·Px})`.
pub fn eq1_tanh_target(n: usize, p_x: f64) -> f64 {
    let a = n as f64 / 2.0 * p_x;
    a.tanh()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::XorShift64;

    #[test]
    fn tanh_labels() {
        let f = BrownCardFsm::tanh(4);
        assert_eq!(f.num_states(), 4);
        assert_eq!(f.labels, vec![false, false, true, true]);
    }

    #[test]
    #[should_panic]
    fn tanh_rejects_odd() {
        BrownCardFsm::tanh(5);
    }

    #[test]
    fn analytic_is_sigmoid_in_unipolar() {
        let f = BrownCardFsm::tanh(8);
        // Unipolar: at p=0 output 0; at p=1 output 1; at p=0.5 output 0.5.
        assert!(f.analytic(0.0) < 1e-9);
        assert!((f.analytic(1.0) - 1.0).abs() < 1e-9);
        assert!((f.analytic(0.5) - 0.5).abs() < 1e-9);
        // Monotone.
        let mut prev = -1.0;
        for k in 0..=10 {
            let y = f.analytic(k as f64 / 10.0);
            assert!(y >= prev);
            prev = y;
        }
    }

    #[test]
    fn bitlevel_matches_analytic() {
        let mut f = BrownCardFsm::tanh(4);
        let mut rng = XorShift64::new(1234);
        let p = 0.7;
        let y_hw = f.run(p, 200_000, &mut rng);
        let y_th = BrownCardFsm::tanh(4).analytic(p);
        assert!((y_hw - y_th).abs() < 0.01, "hw={y_hw} th={y_th}");
    }

    #[test]
    fn bipolar_tanh_tracks_eq1() {
        // In bipolar encoding (x = 2Px-1, y = 2Py-1) the N-state machine
        // approximates tanh(N/2 · x) — check at a few interior points.
        let n = 8;
        let f = BrownCardFsm::tanh(n);
        for &x in &[-0.4, -0.2, 0.0, 0.2, 0.4] {
            let px = (x + 1.0) / 2.0;
            let y = 2.0 * f.analytic(px) - 1.0;
            let target = (n as f64 / 2.0 * x).tanh();
            assert!(
                (y - target).abs() < 0.08,
                "x={x}: fsm={y} eq1={target}"
            );
        }
    }

    #[test]
    fn exp_config_shape() {
        let f = BrownCardFsm::exp(4);
        // At p=0 the chain sits at state 0 → label 1.
        assert!((f.analytic(0.0) - 1.0).abs() < 1e-9);
        // At p=1 it sits at the rightmost state → label 0.
        assert!(f.analytic(1.0) < 1e-9);
    }
}

//! MM-FSM baseline (paper ref [18], Feng/Hu/Han 2022): multi-driving,
//! multi-dimensional FSM for *univariate* nonlinear functions.
//!
//! Instead of a chain, the state space is an R×C grid; the row chain is
//! driven by the input bitstream and the column chain by an auxiliary
//! decorrelated copy of the same input. Each grid state carries a
//! synthesized coefficient (like SMURF's CPT bank). This is the immediate
//! precursor the paper generalizes: SMURF drives each dimension with a
//! *different variable*, making it multivariate.

use super::chain::ChainFsm;
use super::steady::steady_state;
use crate::sc::cpt::CptGate;
use crate::sc::rng::StreamRng;
use crate::sc::sng::ThetaGate;

/// An R×C grid FSM with per-state output coefficients.
#[derive(Clone, Debug)]
pub struct MmFsm {
    rows: ChainFsm,
    cols: ChainFsm,
    cpt: CptGate,
    r: usize,
    c: usize,
}

impl MmFsm {
    /// `ws` has `r*c` entries in row-major order.
    pub fn new(r: usize, c: usize, ws: &[f64]) -> Self {
        assert_eq!(ws.len(), r * c, "coefficient table shape mismatch");
        Self {
            rows: ChainFsm::centered(r),
            cols: ChainFsm::centered(c),
            cpt: CptGate::new(ws),
            r,
            c,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.r, self.c)
    }

    /// Analytic output for input probability `p` (both drives carry the
    /// same variable through independent SNGs, so the joint is a product).
    pub fn analytic(&self, p: f64) -> f64 {
        let pr = steady_state(self.r, p);
        let pc = steady_state(self.c, p);
        let mut y = 0.0;
        for i in 0..self.r {
            for j in 0..self.c {
                y += pr[i] * pc[j] * self.cpt.effective_w(i * self.c + j);
            }
        }
        y
    }

    /// Bit-level run: `len` cycles; three decorrelated entropy uses
    /// (row SNG, column SNG, CPT sampling).
    pub fn run(
        &mut self,
        p: f64,
        len: usize,
        rng_row: &mut impl StreamRng,
        rng_col: &mut impl StreamRng,
        rng_cpt: &mut impl StreamRng,
    ) -> f64 {
        let gate = ThetaGate::new(p);
        let mut ones = 0u64;
        for _ in 0..len {
            let rb = gate.sample(rng_row.next_u16());
            let cb = gate.sample(rng_col.next_u16());
            let i = self.rows.step(rb);
            let j = self.cols.step(cb);
            ones += self.cpt.sample(i * self.c + j, rng_cpt.next_u16()) as u64;
        }
        ones as f64 / len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::rng::XorShift64;

    #[test]
    fn shape_and_validation() {
        let f = MmFsm::new(2, 3, &[0.0; 6]);
        assert_eq!(f.shape(), (2, 3));
    }

    #[test]
    #[should_panic]
    fn rejects_bad_table() {
        MmFsm::new(2, 3, &[0.0; 5]);
    }

    #[test]
    fn constant_table_is_constant_function() {
        let f = MmFsm::new(3, 3, &[0.25; 9]);
        for p in [0.0, 0.3, 0.8, 1.0] {
            assert!((f.analytic(p) - 0.25).abs() < 1e-3);
        }
    }

    #[test]
    fn corner_table_reaches_corners() {
        // w = 1 only at the bottom-right grid state: at p=1 both chains
        // saturate there, so output → 1.
        let mut ws = vec![0.0; 16];
        ws[15] = 1.0;
        let f = MmFsm::new(4, 4, &ws);
        // 1e-4 tolerance: θ-gate thresholds are 16-bit quantized.
        assert!(f.analytic(1.0) > 1.0 - 1e-4);
        assert!(f.analytic(0.0) < 1e-4);
    }

    #[test]
    fn bitlevel_tracks_analytic() {
        let ws: Vec<f64> = (0..9).map(|i| i as f64 / 8.0).collect();
        let mut f = MmFsm::new(3, 3, &ws);
        let fa = f.clone();
        let mut r1 = XorShift64::new(1);
        let mut r2 = XorShift64::new(2);
        let mut r3 = XorShift64::new(3);
        let p = 0.6;
        let y = f.run(p, 100_000, &mut r1, &mut r2, &mut r3);
        assert!((y - fa.analytic(p)).abs() < 0.02, "y={y} vs {}", fa.analytic(p));
    }
}

//! Bit-sliced chained FSM: one independent saturating chain per plane
//! lane.
//!
//! The scalar [`crate::fsm::chain::ChainFsm`] walks one state per clock;
//! the wide SMURF engine needs `P::LANES` of them stepping together
//! (64 for the default `u64` plane, 256/512 for the SIMD planes — see
//! [`crate::sc::plane`]). State is held as `ceil(log2 N)` *bit planes*:
//! plane `b`, lane `l` is bit `b` of lane `l`'s state index. One clock is
//! then a masked ripple-carry increment (lanes whose input bit is 1) plus
//! a masked ripple-borrow decrement (lanes whose input bit is 0), with
//! the saturation masks computed first so lanes already at `0`/`N-1`
//! hold — branch-free plane ops instead of one data-dependent branch per
//! lane (the scalar simulator's main mispredict source).

use crate::sc::plane::BitPlane;

/// Up to `P::LANES` saturating chain FSMs over states `0 ..= n-1`, one
/// per bit lane.
#[derive(Clone, Debug)]
pub struct WideChainFsm<P: BitPlane = u64> {
    n: usize,
    nbits: usize,
    /// State planes; only `planes[..nbits]` are live.
    planes: [P; 8],
}

impl<P: BitPlane> WideChainFsm<P> {
    /// All lanes start at `initial` (the scalar reset convention).
    pub fn new(n: usize, initial: usize) -> Self {
        assert!(n >= 2, "chain FSM needs at least 2 states");
        assert!(n <= 256, "wide chain FSM supports radix <= 256");
        assert!(initial < n, "initial state out of range");
        let nbits = (usize::BITS - (n - 1).leading_zeros()) as usize;
        let mut planes = [P::zero(); 8];
        for (b, p) in planes.iter_mut().enumerate().take(nbits) {
            *p = P::splat((initial >> b) & 1 == 1);
        }
        Self { n, nbits, planes }
    }

    /// Start every lane in the middle state, like `ChainFsm::centered`.
    pub fn centered(n: usize) -> Self {
        Self::new(n, n / 2)
    }

    pub fn num_states(&self) -> usize {
        self.n
    }

    /// Lane mask of FSMs currently in state `s`.
    #[inline(always)]
    pub fn eq_const(&self, s: usize) -> P {
        debug_assert!(s < self.n);
        let mut m = P::ones();
        for b in 0..self.nbits {
            let p = self.planes[b];
            m = if (s >> b) & 1 == 1 { m.and(p) } else { m.and_not(p) };
        }
        m
    }

    /// One clock edge for all lanes: lane `l` of `up` high → lane `l`
    /// moves right (saturating at `N-1`), low → left (saturating at 0).
    /// Matches `ChainFsm::step` lane-for-lane.
    #[inline]
    pub fn step(&mut self, up: P) {
        let at_max = self.eq_const(self.n - 1);
        let at_min = self.eq_const(0);
        // Masked +1 over the state planes (ripple carry).
        let mut carry = up.and_not(at_max);
        for p in self.planes.iter_mut().take(self.nbits) {
            if carry.is_zero() {
                break;
            }
            let (sum, c) = p.half_add(carry);
            *p = sum;
            carry = c;
        }
        // Masked -1 (ripple borrow). Disjoint from the increment lanes.
        let mut borrow = up.not().and_not(at_min);
        for p in self.planes.iter_mut().take(self.nbits) {
            if borrow.is_zero() {
                break;
            }
            let (diff, b) = p.half_sub(borrow);
            *p = diff;
            borrow = b;
        }
    }

    /// Write the per-state lane masks (`out[s]` = lanes in state `s`) —
    /// the codeword digits the CPT MUX select consumes, in one-hot form.
    #[inline]
    pub fn digit_masks(&self, out: &mut [P]) {
        debug_assert_eq!(out.len(), self.n);
        for (s, o) in out.iter_mut().enumerate() {
            *o = self.eq_const(s);
        }
    }

    /// Fault-injection hook: let `f` rewrite the live state planes in
    /// place, then clamp every lane back into `0..n` — the wide analogue
    /// of `ChainFsm::inject`. When `n` is not a power of two a bit fault
    /// can leave a lane's pattern `>= n`; such lanes saturate at `n-1`
    /// (the hardware decoder convention), computed branch-free with an
    /// MSB-first `pattern > n-1` comparison over the planes.
    #[inline]
    pub fn inject(&mut self, f: impl FnOnce(&mut [P])) {
        f(&mut self.planes[..self.nbits]);
        if self.n.is_power_of_two() {
            return; // every nbits-wide pattern is a valid state
        }
        // gt = lanes whose pattern exceeds n-1, MSB-first compare.
        let max = self.n - 1;
        let mut gt = P::zero();
        let mut eq = P::ones();
        for b in (0..self.nbits).rev() {
            let p = self.planes[b];
            if (max >> b) & 1 == 1 {
                eq = eq.and(p);
            } else {
                gt = gt.or(eq.and(p));
                eq = eq.and_not(p);
            }
        }
        // Force the out-of-range lanes to n-1.
        for (b, p) in self.planes.iter_mut().enumerate().take(self.nbits) {
            *p = if (max >> b) & 1 == 1 { p.or(gt) } else { p.and_not(gt) };
        }
    }

    /// Lane `l`'s state index (test/debug path; the hot loop never needs it).
    pub fn state_of_lane(&self, l: usize) -> usize {
        let mut s = 0usize;
        for b in 0..self.nbits {
            s |= (self.planes[b].lane(l) as usize) << b;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fsm::chain::ChainFsm;
    use crate::util::prng::Pcg;

    /// Drive wide + `P::LANES` scalar FSMs with the same random bits;
    /// they must agree lane-for-lane at every clock.
    fn check_against_scalar<P: BitPlane>(n: usize, cycles: usize, seed: u64) {
        let mut wide = WideChainFsm::<P>::centered(n);
        let mut scalars: Vec<ChainFsm> =
            (0..P::LANES).map(|_| ChainFsm::centered(n)).collect();
        let mut rng = Pcg::new(seed);
        for cycle in 0..cycles {
            let mut up = P::zero();
            for l in 0..P::LANES {
                if rng.next_u64() & 1 == 1 {
                    up.set_lane(l);
                }
            }
            wide.step(up);
            for (l, f) in scalars.iter_mut().enumerate() {
                let expect = f.step(up.lane(l));
                assert_eq!(
                    wide.state_of_lane(l),
                    expect,
                    "n={n} cycle={cycle} lane={l}"
                );
            }
        }
    }

    fn check_all_radices<P: BitPlane>() {
        for n in [2usize, 3, 4, 5, 7, 8] {
            check_against_scalar::<P>(n, 200, 11 + (P::LANES + n) as u64);
        }
    }

    #[test]
    fn matches_scalar_all_widths() {
        crate::for_each_plane_width!(check_all_radices);
    }

    fn saturates_at_ends_generic<P: BitPlane>() {
        let mut w = WideChainFsm::<P>::new(4, 0);
        w.step(P::zero()); // all lanes down from 0 → stay 0
        assert_eq!(w.state_of_lane(0), 0);
        for _ in 0..10 {
            w.step(P::ones()); // all lanes up
        }
        for l in [0, P::LANES / 2 - 1, P::LANES - 1] {
            assert_eq!(w.state_of_lane(l), 3, "must saturate at N-1");
        }
    }

    #[test]
    fn saturates_at_ends() {
        crate::for_each_plane_width!(saturates_at_ends_generic);
    }

    fn digit_masks_partition_generic<P: BitPlane>() {
        let mut w = WideChainFsm::<P>::centered(5);
        let mut rng = Pcg::new(77);
        for _ in 0..200 {
            let mut up = P::zero();
            for l in 0..P::LANES {
                if rng.next_u64() & 1 == 1 {
                    up.set_lane(l);
                }
            }
            w.step(up);
        }
        let mut masks = vec![P::zero(); 5];
        w.digit_masks(&mut masks);
        let mut union = P::zero();
        for (s, &m) in masks.iter().enumerate() {
            assert!(union.and(m).is_zero(), "state {s} overlaps another");
            union = union.or(m);
        }
        assert_eq!(union, P::ones(), "every lane must be in exactly one state");
    }

    #[test]
    fn digit_masks_partition_lanes() {
        crate::for_each_plane_width!(digit_masks_partition_generic);
    }

    fn inject_identity_and_clamp_generic<P: BitPlane>() {
        for n in [2usize, 3, 4, 5, 7, 8] {
            // Identity injection must leave every lane untouched.
            let mut w = WideChainFsm::<P>::centered(n);
            let before: Vec<usize> =
                (0..P::LANES).map(|l| w.state_of_lane(l)).collect();
            w.inject(|_| {});
            for l in 0..P::LANES {
                assert_eq!(w.state_of_lane(l), before[l], "n={n} lane={l}");
            }
            // All-ones planes = pattern 2^nbits - 1; lanes must clamp
            // to n-1 exactly when that pattern is out of range.
            w.inject(|planes| {
                for p in planes.iter_mut() {
                    *p = P::ones();
                }
            });
            for l in [0, P::LANES - 1] {
                assert_eq!(w.state_of_lane(l), n - 1, "n={n} lane={l}");
            }
        }
    }

    #[test]
    fn inject_identity_and_clamp() {
        crate::for_each_plane_width!(inject_identity_and_clamp_generic);
    }

    /// Wide inject with a per-lane XOR pattern must agree with the
    /// scalar `ChainFsm::inject` applying the same per-lane flips.
    fn inject_matches_scalar_generic<P: BitPlane>() {
        for n in [3usize, 5, 6, 7] {
            let nbits = (usize::BITS - (n - 1).leading_zeros()) as usize;
            let mut wide = WideChainFsm::<P>::centered(n);
            let mut scalars: Vec<ChainFsm> =
                (0..P::LANES).map(|_| ChainFsm::centered(n)).collect();
            let mut rng = Pcg::new(0xFA17 + n as u64);
            for _ in 0..50 {
                // Random step, then a random per-lane bit-flip pattern.
                let mut up = P::zero();
                let mut flips = vec![0usize; P::LANES];
                for (l, fl) in flips.iter_mut().enumerate() {
                    let r = rng.next_u64();
                    if r & 1 == 1 {
                        up.set_lane(l);
                    }
                    *fl = ((r >> 1) as usize) & ((1 << nbits) - 1);
                }
                wide.step(up);
                wide.inject(|planes| {
                    for (b, p) in planes.iter_mut().enumerate() {
                        let mut m = P::zero();
                        for (l, fl) in flips.iter().enumerate() {
                            if (fl >> b) & 1 == 1 {
                                m.set_lane(l);
                            }
                        }
                        *p = p.xor(m);
                    }
                });
                for (l, f) in scalars.iter_mut().enumerate() {
                    f.step(up.lane(l));
                    let expect = f.inject(|s, _| s ^ flips[l]);
                    assert_eq!(wide.state_of_lane(l), expect, "n={n} lane={l}");
                }
            }
        }
    }

    #[test]
    fn inject_matches_scalar() {
        crate::for_each_plane_width!(inject_matches_scalar_generic);
    }

    #[test]
    fn centered_matches_scalar_reset() {
        for n in 2..=9 {
            let w = WideChainFsm::<u64>::centered(n);
            assert_eq!(w.state_of_lane(17), ChainFsm::centered(n).state());
            let w = WideChainFsm::<[u64; 4]>::centered(n);
            assert_eq!(w.state_of_lane(170), ChainFsm::centered(n).state());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_one_state() {
        WideChainFsm::<u64>::new(1, 0);
    }
}

//! Bit-sliced chained FSM: 64 independent saturating chains per word.
//!
//! The scalar [`crate::fsm::chain::ChainFsm`] walks one state per clock;
//! the wide SMURF engine needs 64 of them stepping together. State is held
//! as `ceil(log2 N)` *bit planes*: plane `b`, bit `l` is bit `b` of lane
//! `l`'s state index. One clock is then a masked ripple-carry increment
//! (lanes whose input bit is 1) plus a masked ripple-borrow decrement
//! (lanes whose input bit is 0), with the saturation masks computed first
//! so lanes already at `0`/`N-1` hold — branch-free word ops instead of 64
//! data-dependent branches (the scalar simulator's main mispredict source).

/// Up to 64 saturating chain FSMs over states `0 ..= n-1`, one per bit lane.
#[derive(Clone, Debug)]
pub struct WideChainFsm {
    n: usize,
    nbits: usize,
    /// State planes; only `planes[..nbits]` are live.
    planes: [u64; 8],
}

impl WideChainFsm {
    /// All 64 lanes start at `initial` (the scalar reset convention).
    pub fn new(n: usize, initial: usize) -> Self {
        assert!(n >= 2, "chain FSM needs at least 2 states");
        assert!(n <= 256, "wide chain FSM supports radix <= 256");
        assert!(initial < n, "initial state out of range");
        let nbits = (usize::BITS - (n - 1).leading_zeros()) as usize;
        let mut planes = [0u64; 8];
        for (b, p) in planes.iter_mut().enumerate().take(nbits) {
            *p = if (initial >> b) & 1 == 1 { !0 } else { 0 };
        }
        Self { n, nbits, planes }
    }

    /// Start every lane in the middle state, like `ChainFsm::centered`.
    pub fn centered(n: usize) -> Self {
        Self::new(n, n / 2)
    }

    pub fn num_states(&self) -> usize {
        self.n
    }

    /// Lane mask of FSMs currently in state `s`.
    #[inline(always)]
    pub fn eq_const(&self, s: usize) -> u64 {
        debug_assert!(s < self.n);
        let mut m = !0u64;
        for b in 0..self.nbits {
            let p = self.planes[b];
            m &= if (s >> b) & 1 == 1 { p } else { !p };
        }
        m
    }

    /// One clock edge for all lanes: bit `l` of `up` high → lane `l` moves
    /// right (saturating at `N-1`), low → left (saturating at 0). Matches
    /// `ChainFsm::step` lane-for-lane.
    #[inline]
    pub fn step(&mut self, up: u64) {
        let at_max = self.eq_const(self.n - 1);
        let at_min = self.eq_const(0);
        // Masked +1 over the state planes (ripple carry).
        let mut carry = up & !at_max;
        for p in self.planes.iter_mut().take(self.nbits) {
            if carry == 0 {
                break;
            }
            let t = *p;
            *p = t ^ carry;
            carry &= t;
        }
        // Masked -1 (ripple borrow). Disjoint from the increment lanes.
        let mut borrow = !up & !at_min;
        for p in self.planes.iter_mut().take(self.nbits) {
            if borrow == 0 {
                break;
            }
            let t = *p;
            *p = t ^ borrow;
            borrow &= !t;
        }
    }

    /// Write the per-state lane masks (`out[s]` = lanes in state `s`) —
    /// the codeword digits the CPT MUX select consumes, in one-hot form.
    #[inline]
    pub fn digit_masks(&self, out: &mut [u64]) {
        debug_assert_eq!(out.len(), self.n);
        for (s, o) in out.iter_mut().enumerate() {
            *o = self.eq_const(s);
        }
    }

    /// Lane `l`'s state index (test/debug path; the hot loop never needs it).
    pub fn state_of_lane(&self, l: usize) -> usize {
        let mut s = 0usize;
        for b in 0..self.nbits {
            s |= (((self.planes[b] >> l) & 1) as usize) << b;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fsm::chain::ChainFsm;
    use crate::util::prng::Pcg;

    /// Drive wide + 64 scalar FSMs with the same random bits; they must
    /// agree lane-for-lane at every clock.
    fn check_against_scalar(n: usize, cycles: usize, seed: u64) {
        let mut wide = WideChainFsm::centered(n);
        let mut scalars: Vec<ChainFsm> = (0..64).map(|_| ChainFsm::centered(n)).collect();
        let mut rng = Pcg::new(seed);
        for cycle in 0..cycles {
            let up = rng.next_u64();
            wide.step(up);
            for (l, f) in scalars.iter_mut().enumerate() {
                let expect = f.step((up >> l) & 1 == 1);
                assert_eq!(
                    wide.state_of_lane(l),
                    expect,
                    "n={n} cycle={cycle} lane={l}"
                );
            }
        }
    }

    #[test]
    fn matches_scalar_pow2_radix() {
        check_against_scalar(4, 500, 11);
        check_against_scalar(2, 500, 12);
        check_against_scalar(8, 500, 13);
    }

    #[test]
    fn matches_scalar_non_pow2_radix() {
        check_against_scalar(3, 500, 21);
        check_against_scalar(5, 500, 22);
        check_against_scalar(7, 500, 23);
    }

    #[test]
    fn saturates_at_ends() {
        let mut w = WideChainFsm::new(4, 0);
        w.step(0); // all lanes down from 0 → stay 0
        assert_eq!(w.state_of_lane(0), 0);
        for _ in 0..10 {
            w.step(!0); // all lanes up
        }
        for l in [0, 31, 63] {
            assert_eq!(w.state_of_lane(l), 3, "must saturate at N-1");
        }
    }

    #[test]
    fn digit_masks_partition_lanes() {
        let mut w = WideChainFsm::centered(5);
        let mut rng = Pcg::new(77);
        for _ in 0..200 {
            w.step(rng.next_u64());
        }
        let mut masks = vec![0u64; 5];
        w.digit_masks(&mut masks);
        let mut union = 0u64;
        for (s, &m) in masks.iter().enumerate() {
            assert_eq!(union & m, 0, "state {s} overlaps another");
            union |= m;
        }
        assert_eq!(union, !0u64, "every lane must be in exactly one state");
    }

    #[test]
    fn centered_matches_scalar_reset() {
        for n in 2..=9 {
            let w = WideChainFsm::centered(n);
            assert_eq!(w.state_of_lane(17), ChainFsm::centered(n).state());
        }
    }

    #[test]
    #[should_panic]
    fn rejects_one_state() {
        WideChainFsm::new(1, 0);
    }
}

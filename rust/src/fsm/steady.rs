//! Steady-state distribution of the chain FSM (paper Eq. 2–4, Fig. 5).
//!
//! At equilibrium the birth–death chain satisfies detailed balance
//! `π_{i+1} (1-p) = π_i p`, so `π_i ∝ t^i` with `t = p/(1-p)`.
//! The numerically-stable closed form used here multiplies through by
//! `(1-p)^{N-1}`:
//!
//! `π_i = p^i (1-p)^{N-1-i} / Σ_k p^k (1-p)^{N-1-k}`
//!
//! which is exact for the whole closed interval `p ∈ [0,1]` (no division
//! by zero at the endpoints).

/// Steady-state probabilities `π_0 … π_{n-1}` of an `n`-state chain FSM
/// driven by i.i.d. Bernoulli(`p`) input bits.
pub fn steady_state(n: usize, p: f64) -> Vec<f64> {
    let mut w = vec![0.0; n];
    steady_state_into(n, p, &mut w);
    w
}

/// Allocation-free variant of [`steady_state`] writing into `out`
/// (`out.len() == n`) — the serving hot path (§Perf).
pub fn steady_state_into(n: usize, p: f64, out: &mut [f64]) {
    assert!(n >= 1);
    assert_eq!(out.len(), n);
    let p = p.clamp(0.0, 1.0);
    let q = 1.0 - p;
    // Unnormalized weights p^i q^{n-1-i}, built by running products
    // (two multiplies per state instead of two `powi` calls).
    let mut fwd = 1.0; // p^i
    for o in out.iter_mut() {
        *o = fwd;
        fwd *= p;
    }
    let mut bwd = 1.0; // q^{n-1-i}
    for o in out.iter_mut().rev() {
        *o *= bwd;
        bwd *= q;
    }
    let z: f64 = out.iter().sum();
    if z == 0.0 {
        // Unreachable for p in [0,1] and n >= 1, but stay total.
        out.fill(1.0 / n as f64);
        return;
    }
    let inv = 1.0 / z;
    for wi in out.iter_mut() {
        *wi *= inv;
    }
}

/// Derivative `dπ_i/dp` by central difference — used by the L2 training
/// surrogate sanity tests (JAX computes this analytically by autodiff).
pub fn steady_state_grad(n: usize, p: f64, i: usize) -> f64 {
    let h = 1e-6;
    let lo = steady_state(n, (p - h).max(0.0));
    let hi = steady_state(n, (p + h).min(1.0));
    (hi[i] - lo[i]) / ((p + h).min(1.0) - (p - h).max(0.0))
}

/// The centre-of-mass of the steady state — the mean normalized state
/// index, a monotone sigmoid-like curve in `p` (the reason a chain FSM can
/// compute nonlinearities at all, §II-C).
pub fn mean_state(n: usize, p: f64) -> f64 {
    steady_state(n, p)
        .iter()
        .enumerate()
        .map(|(i, pi)| i as f64 * pi)
        .sum::<f64>()
        / (n - 1).max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, UnitF64};

    #[test]
    fn sums_to_one() {
        for n in [2, 3, 4, 5, 8] {
            for p in [0.0, 0.1, 0.5, 0.9, 1.0] {
                let pi = steady_state(n, p);
                let s: f64 = pi.iter().sum();
                assert!((s - 1.0).abs() < 1e-12, "n={n} p={p} sum={s}");
            }
        }
    }

    #[test]
    fn endpoint_degeneracy() {
        // p=0: all mass in state 0. p=1: all mass in state n-1.
        let pi0 = steady_state(4, 0.0);
        assert_eq!(pi0[0], 1.0);
        assert_eq!(pi0[3], 0.0);
        let pi1 = steady_state(4, 1.0);
        assert_eq!(pi1[3], 1.0);
    }

    #[test]
    fn two_state_is_linear() {
        // Paper §II-C: a 2-state FSM has completely linear steady-state
        // probabilities — π_1 = p exactly.
        for p in [0.0, 0.25, 0.5, 0.75, 1.0] {
            let pi = steady_state(2, p);
            assert!((pi[1] - p).abs() < 1e-12);
        }
    }

    #[test]
    fn symmetric_at_half() {
        // At p=1/2 all states are equally likely (t=1).
        let pi = steady_state(5, 0.5);
        for &x in &pi {
            assert!((x - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn matches_detailed_balance_ratio() {
        // π_{i+1}/π_i = t = p/(1-p) (Eq. 2).
        let p: f64 = 0.3;
        let t = p / (1.0 - p);
        let pi = steady_state(6, p);
        for i in 0..5 {
            assert!((pi[i + 1] / pi[i] - t).abs() < 1e-9);
        }
    }

    #[test]
    fn fig5_middle_states_hump_shape() {
        // Fig. 5: edge states are monotone (left decreasing, right
        // increasing); middle states are humps that vanish at both ends.
        let n = 4;
        for mid in 1..n - 1 {
            let at0 = steady_state(n, 0.0)[mid];
            let athalf = steady_state(n, 0.5)[mid];
            let at1 = steady_state(n, 1.0)[mid];
            assert_eq!(at0, 0.0);
            assert_eq!(at1, 0.0);
            assert!(athalf > 0.0);
        }
    }

    #[test]
    fn prop_edge_states_monotone() {
        check(31, 128, &UnitF64 { lo: 0.0, hi: 0.99 }, |&p| {
            let d = 0.01;
            let a = steady_state(4, p);
            let b = steady_state(4, p + d);
            // leftmost decreasing, rightmost increasing in p
            b[0] <= a[0] + 1e-12 && b[3] + 1e-12 >= a[3]
        });
    }

    #[test]
    fn mean_state_monotone_sigmoid() {
        let mut prev = -1.0;
        for k in 0..=20 {
            let p = k as f64 / 20.0;
            let m = mean_state(4, p);
            assert!(m >= prev - 1e-12, "not monotone at p={p}");
            assert!((0.0..=1.0).contains(&m));
            prev = m;
        }
        assert!((mean_state(4, 0.5) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn grad_positive_for_rightmost() {
        assert!(steady_state_grad(4, 0.4, 3) > 0.0);
        assert!(steady_state_grad(4, 0.4, 0) < 0.0);
    }
}

//! The chained N-state saturating Moore FSM (paper Fig. 4).
//!
//! On input bit 1 the state moves right (saturating at `N-1`), on 0 it
//! moves left (saturating at 0). One such chain per SMURF input variable;
//! its state index is one digit of the universal-radix codeword.

/// A saturating chain FSM over states `0 ..= n-1`.
#[derive(Clone, Debug)]
pub struct ChainFsm {
    n: usize,
    state: usize,
}

impl ChainFsm {
    /// `n >= 2` states, starting at `initial`.
    pub fn new(n: usize, initial: usize) -> Self {
        assert!(n >= 2, "chain FSM needs at least 2 states");
        assert!(initial < n, "initial state out of range");
        Self { n, state: initial }
    }

    /// Start in the middle state — the conventional reset for symmetric
    /// convergence from either side.
    pub fn centered(n: usize) -> Self {
        Self::new(n, n / 2)
    }

    pub fn num_states(&self) -> usize {
        self.n
    }

    pub fn state(&self) -> usize {
        self.state
    }

    /// One clock edge: input bit high → right, low → left (both saturating).
    #[inline(always)]
    pub fn step(&mut self, bit: bool) -> usize {
        if bit {
            if self.state + 1 < self.n {
                self.state += 1;
            }
        } else {
            self.state = self.state.saturating_sub(1);
        }
        self.state
    }

    /// Reset to a given state.
    pub fn reset(&mut self, state: usize) {
        assert!(state < self.n);
        self.state = state;
    }

    /// Fault-injection hook: let `f` rewrite the state register's raw
    /// bits (`f` receives the current state and the register width
    /// `ceil(log2(n))`), then clamp back into `0..n`. Hardware chains
    /// store the state one-hot or binary in `ceil(log2(n))` flip-flops;
    /// a bit fault can therefore produce a pattern `>= n` when `n` is
    /// not a power of two — real decoders saturate such patterns at the
    /// end of the chain, which is what the `min(n-1)` models. Returns
    /// the post-clamp state.
    #[inline]
    pub fn inject(&mut self, f: impl FnOnce(usize, u32) -> usize) -> usize {
        let nbits = usize::BITS - (self.n - 1).leading_zeros();
        let raw = f(self.state, nbits) & ((1usize << nbits) - 1);
        self.state = raw.min(self.n - 1);
        self.state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, RangeUsize};
    use crate::util::prng::Pcg;

    #[test]
    fn walks_and_saturates_right() {
        let mut f = ChainFsm::new(4, 0);
        assert_eq!(f.step(true), 1);
        assert_eq!(f.step(true), 2);
        assert_eq!(f.step(true), 3);
        assert_eq!(f.step(true), 3, "must saturate at N-1");
    }

    #[test]
    fn walks_and_saturates_left() {
        let mut f = ChainFsm::new(4, 2);
        assert_eq!(f.step(false), 1);
        assert_eq!(f.step(false), 0);
        assert_eq!(f.step(false), 0, "must saturate at 0");
    }

    #[test]
    fn centered_start() {
        assert_eq!(ChainFsm::centered(4).state(), 2);
        assert_eq!(ChainFsm::centered(5).state(), 2);
    }

    #[test]
    #[should_panic]
    fn rejects_one_state() {
        ChainFsm::new(1, 0);
    }

    #[test]
    fn empirical_occupancy_matches_steady_state() {
        // Drive with Bernoulli(p) bits and compare the long-run state
        // occupancy with the analytic distribution of steady.rs.
        let p = 0.3;
        let n = 4;
        let mut f = ChainFsm::centered(n);
        let mut rng = Pcg::new(42);
        let warmup = 1000;
        let cycles = 2_000_000;
        let mut occ = vec![0u64; n];
        for _ in 0..warmup {
            f.step(rng.uniform() < p);
        }
        for _ in 0..cycles {
            occ[f.step(rng.uniform() < p)] += 1;
        }
        let pi = crate::fsm::steady::steady_state(n, p);
        for (i, &cnt) in occ.iter().enumerate() {
            let emp = cnt as f64 / cycles as f64;
            assert!(
                (emp - pi[i]).abs() < 0.005,
                "state {i}: empirical {emp} vs analytic {}",
                pi[i]
            );
        }
    }

    #[test]
    fn inject_identity_keeps_state_and_clamps_out_of_range() {
        let mut f = ChainFsm::new(5, 3);
        // Identity injection must not move the state.
        assert_eq!(f.inject(|s, _| s), 3);
        assert_eq!(f.state(), 3);
        // nbits for n=5 is 3; an all-ones pattern (7) exceeds n-1 and
        // must saturate at the end of the chain.
        assert_eq!(f.inject(|_, nbits| (1usize << nbits) - 1), 4);
        // Bits above the register width are masked off before the clamp.
        assert_eq!(f.inject(|_, _| 0b1000), 0);
    }

    #[test]
    fn prop_inject_always_lands_in_range() {
        check(11, 128, &RangeUsize { lo: 2, hi: 9 }, |&n| {
            let mut f = ChainFsm::centered(n);
            let mut rng = Pcg::new(n as u64 ^ 0xFA17);
            (0..500).all(|_| {
                f.step(rng.uniform() < 0.5);
                let flip = (rng.uniform() * 256.0) as usize;
                f.inject(|s, _| s ^ flip) < n
            })
        });
    }

    #[test]
    fn prop_state_always_in_range() {
        check(7, 128, &RangeUsize { lo: 2, hi: 9 }, |&n| {
            let mut f = ChainFsm::centered(n);
            let mut rng = Pcg::new(n as u64);
            (0..1000).all(|_| f.step(rng.uniform() < 0.5) < n)
        });
    }
}

//! Chained finite-state machines and their steady-state theory
//! (paper §II-C, Fig. 4–5), plus the prior-art FSM baselines.

pub mod brown_card;
pub mod chain;
pub mod chain_wide;
pub mod mm_fsm;
pub mod steady;

pub use chain::ChainFsm;
pub use chain_wide::WideChainFsm;
pub use steady::steady_state;

//! Chained finite-state machines and their steady-state theory
//! (paper §II-C, Fig. 4–5), plus the prior-art FSM baselines.

pub mod brown_card;
pub mod chain;
pub mod mm_fsm;
pub mod steady;

pub use chain::ChainFsm;
pub use steady::steady_state;

//! Admission control: bounded per-engine in-flight depth, request
//! validation at the submit edge, and the load-shedding policy that
//! degrades `BitLevel` requests to the `Analytic` closed form before
//! resorting to rejection.
//!
//! Depth accounting is token-based: [`Admission::admit`] increments the
//! target engine's in-flight counter and attaches a [`DepthToken`] to the
//! request; the token decrements on `Drop`. Every path that consumes a
//! request — reply sent, batch dropped in a panicking worker, request
//! discarded at shutdown — releases its slot automatically, so queue
//! depth can never leak no matter how the request dies.
//!
//! Shedding uses hysteresis: it engages when the `BitLevel` in-flight
//! depth reaches `shed_high` and disengages only once the backlog drains
//! to `shed_low`, so the policy cannot flap around the watermark.
//! Degraded requests are accounted under their *new* engine (`Analytic`),
//! which is exactly what makes the policy stable: diverted traffic stops
//! feeding the watermark it tripped.
//!
//! Shedding is one of two sources of `degraded: true` responses: the
//! drift sentinel ([`super::sentinel`]) reroutes a *quarantined*
//! function's `BitLevel` traffic the same way, before admission runs, so
//! both paths depth-account the request under its final engine.

use super::metrics::Metrics;
use super::request::{Engine, EvalRequest, RejectReason};
use crate::util::sync::{Arc, AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Static admission policy. Limits bound *in-flight* requests per engine
/// (admitted but not yet answered), which covers the intake channel, the
/// batcher's pending groups, the worker channel, and execution itself.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// In-flight limit for the bit-level simulator (L-cycle expensive).
    pub bitlevel_limit: usize,
    /// In-flight limit for the analytic engine (cheap; also absorbs
    /// degraded BitLevel traffic, so it is the larger pool).
    pub analytic_limit: usize,
    /// In-flight limit for the XLA engine (serialized on one owner
    /// thread).
    pub xla_limit: usize,
    /// BitLevel in-flight depth at which shedding engages: new BitLevel
    /// requests are served from the analytic closed form (Eq. 21) and
    /// flagged `degraded` instead of queuing behind the backlog.
    pub shed_high: usize,
    /// Depth the BitLevel backlog must drain to before shedding
    /// disengages (hysteresis; must be < `shed_high`).
    pub shed_low: usize,
    /// Default deadline for `eval_sync` callers that did not pick one —
    /// conservative, but finite: a synchronous client never blocks
    /// forever.
    pub sync_timeout: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            bitlevel_limit: 1024,
            analytic_limit: 8192,
            xla_limit: 1024,
            shed_high: 256,
            shed_low: 64,
            sync_timeout: Duration::from_secs(30),
        }
    }
}

/// Runtime admission state shared between the server front door and the
/// metrics snapshot.
#[derive(Debug)]
pub struct Admission {
    cfg: AdmissionConfig,
    /// In-flight depth per [`Engine::index`].
    depth: [AtomicUsize; Engine::COUNT],
    /// Latched shedding state (hysteresis).
    shedding: AtomicBool,
    /// Test/bench hook: latch shedding on regardless of depth, so the
    /// degraded path can be driven deterministically.
    force_shed: AtomicBool,
    metrics: Arc<Metrics>,
}

/// RAII in-flight slot: releases the engine's depth counter when the
/// request it rides on is consumed (answered or dropped).
pub struct DepthToken {
    admission: Arc<Admission>,
    idx: usize,
}

impl std::fmt::Debug for DepthToken {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DepthToken").field("idx", &self.idx).finish()
    }
}

impl Drop for DepthToken {
    fn drop(&mut self) {
        self.admission.depth[self.idx].fetch_sub(1, Ordering::Relaxed);
    }
}

impl Admission {
    /// Build the admission state for one server. Panics if the hysteresis
    /// watermarks are inverted (a config bug, not a runtime condition).
    pub fn new(cfg: AdmissionConfig, metrics: Arc<Metrics>) -> Self {
        assert!(cfg.shed_low < cfg.shed_high, "hysteresis needs shed_low < shed_high");
        Self {
            cfg,
            depth: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            shedding: AtomicBool::new(false),
            force_shed: AtomicBool::new(false),
            metrics,
        }
    }

    /// The static policy this instance enforces.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Current in-flight depth for one engine.
    pub fn depth(&self, engine: Engine) -> usize {
        self.depth[engine.index()].load(Ordering::Relaxed)
    }

    /// Total in-flight depth across engines.
    pub fn total_depth(&self) -> usize {
        self.depth.iter().map(|d| d.load(Ordering::Relaxed)).sum()
    }

    /// Whether load shedding is currently engaged.
    pub fn is_shedding(&self) -> bool {
        self.force_shed.load(Ordering::Relaxed) || self.shedding.load(Ordering::Relaxed)
    }

    /// Test/bench hook: force the shedding latch on (or release it).
    pub fn force_shed(&self, on: bool) {
        self.force_shed.store(on, Ordering::Relaxed);
    }

    fn limit(&self, engine: Engine) -> usize {
        match engine {
            Engine::BitLevel => self.cfg.bitlevel_limit,
            Engine::Analytic => self.cfg.analytic_limit,
            Engine::Xla => self.cfg.xla_limit,
        }
    }

    /// Validate and admit a request: malformed traffic is refused at the
    /// edge, expired deadlines are refused before any queuing, shedding
    /// may rewrite `BitLevel` → `Analytic` (flagging the request
    /// `degraded`), and the target engine's depth limit is enforced. On
    /// success the request carries a [`DepthToken`]; on failure the typed
    /// [`RejectReason`] says why (`BadRequest`, `Deadline`, or
    /// `QueueFull`) and nothing was queued or accounted.
    ///
    /// `arity_of` resolves a function name to its input arity (`None` =
    /// unknown function). Associated fn (not a method): the token must
    /// hold the `Arc`, and `&Arc<Self>` receivers are not stable Rust.
    pub fn admit(
        this: &Arc<Self>,
        req: &mut EvalRequest,
        arity_of: impl Fn(&str) -> Option<usize>,
    ) -> Result<(), RejectReason> {
        // 1. Validation: refuse malformed traffic before it queues.
        let arity = arity_of(&req.function)
            .ok_or_else(|| RejectReason::BadRequest(format!("unknown function {:?}", req.function)))?;
        for (i, p) in req.points.iter().enumerate() {
            if p.len() != arity {
                return Err(RejectReason::BadRequest(format!(
                    "point {i} has arity {} but {:?} takes {arity} inputs",
                    p.len(),
                    req.function
                )));
            }
            if let Some(x) = p.iter().find(|x| !x.is_finite()) {
                return Err(RejectReason::BadRequest(format!(
                    "point {i} contains non-finite input {x}"
                )));
            }
        }
        if req.engine == Engine::BitLevel && req.stream_len == 0 {
            return Err(RejectReason::BadRequest(
                "stream_len must be > 0 for the BitLevel engine".into(),
            ));
        }

        // 2. Dead on arrival: an already-expired deadline is refused
        //    without queuing (BitLevel work is L-cycle expensive).
        if req.expired(Instant::now()) {
            return Err(RejectReason::Deadline);
        }

        // 3. Load shedding (BitLevel only): past the high watermark,
        //    serve from the analytic closed form at reduced fidelity
        //    instead of queuing; hysteresis keeps the latch stable.
        if req.engine == Engine::BitLevel && this.update_shed_latch() {
            req.engine = Engine::Analytic;
            req.degraded = true;
            this.metrics.record_degraded();
        }

        // 4. Depth limit on the (possibly rewritten) target engine:
        //    claim a slot with an explicit CAS loop (the open-coded form
        //    of `fetch_update`, which the loom models also compile — see
        //    rust/tests/loom_models.rs): the increment happens only if
        //    the observed depth is still below the limit, so concurrent
        //    admits can never overshoot it.
        let idx = req.engine.index();
        let limit = this.limit(req.engine);
        let mut depth = this.depth[idx].load(Ordering::Relaxed);
        loop {
            if depth >= limit {
                return Err(RejectReason::QueueFull);
            }
            match this.depth[idx].compare_exchange_weak(
                depth,
                depth + 1,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(observed) => depth = observed,
            }
        }
        req.admitted = Some(DepthToken { admission: Arc::clone(this), idx });
        this.metrics.note_queue_depth(this.total_depth() as u64);
        Ok(())
    }

    /// Advance the hysteresis latch from the current BitLevel depth and
    /// return whether shedding is engaged.
    fn update_shed_latch(&self) -> bool {
        if self.force_shed.load(Ordering::Relaxed) {
            return true;
        }
        let d = self.depth[Engine::BitLevel.index()].load(Ordering::Relaxed);
        if self.shedding.load(Ordering::Relaxed) {
            if d <= self.cfg.shed_low {
                self.shedding.store(false, Ordering::Relaxed);
                false
            } else {
                true
            }
        } else if d >= self.cfg.shed_high {
            self.shedding.store(true, Ordering::Relaxed);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn mk_admission(cfg: AdmissionConfig) -> Arc<Admission> {
        Arc::new(Admission::new(cfg, Arc::new(Metrics::new())))
    }

    fn mk_req(engine: Engine) -> EvalRequest {
        let (tx, _rx) = channel();
        EvalRequest::new("f", vec![vec![0.5, 0.5]], engine, 64, tx)
    }

    fn arity2(name: &str) -> Option<usize> {
        (name == "f").then_some(2)
    }

    #[test]
    fn validation_rejects_malformed_traffic() {
        let a = mk_admission(AdmissionConfig::default());
        let mut r = mk_req(Engine::Analytic);
        r.function = "nope".into();
        assert!(matches!(Admission::admit(&a, &mut r, arity2), Err(RejectReason::BadRequest(_))));

        let mut r = mk_req(Engine::Analytic);
        r.points = vec![vec![0.5]]; // arity 1 != 2
        assert!(matches!(Admission::admit(&a, &mut r, arity2), Err(RejectReason::BadRequest(_))));

        let mut r = mk_req(Engine::Analytic);
        r.points = vec![vec![0.5, f64::NAN]];
        assert!(matches!(Admission::admit(&a, &mut r, arity2), Err(RejectReason::BadRequest(_))));

        let mut r = mk_req(Engine::BitLevel);
        r.stream_len = 0;
        assert!(matches!(Admission::admit(&a, &mut r, arity2), Err(RejectReason::BadRequest(_))));

        // Valid traffic passes and is accounted.
        let mut r = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r, arity2).is_ok());
        assert_eq!(a.depth(Engine::BitLevel), 1);
    }

    #[test]
    fn expired_deadline_rejected_at_submit() {
        let a = mk_admission(AdmissionConfig::default());
        let mut r = mk_req(Engine::Analytic).with_deadline(Instant::now());
        std::thread::sleep(Duration::from_millis(1));
        assert_eq!(Admission::admit(&a, &mut r, arity2), Err(RejectReason::Deadline));
        assert_eq!(a.total_depth(), 0);
    }

    #[test]
    fn depth_limit_rejects_and_tokens_release() {
        let a = mk_admission(AdmissionConfig {
            analytic_limit: 2,
            ..AdmissionConfig::default()
        });
        let mut r1 = mk_req(Engine::Analytic);
        let mut r2 = mk_req(Engine::Analytic);
        let mut r3 = mk_req(Engine::Analytic);
        assert!(Admission::admit(&a, &mut r1, arity2).is_ok());
        assert!(Admission::admit(&a, &mut r2, arity2).is_ok());
        assert_eq!(Admission::admit(&a, &mut r3, arity2), Err(RejectReason::QueueFull));
        assert_eq!(a.depth(Engine::Analytic), 2);
        // Dropping an admitted request releases its slot (Drop-based, so
        // panic unwinds release too).
        drop(r1);
        assert_eq!(a.depth(Engine::Analytic), 1);
        let mut r4 = mk_req(Engine::Analytic);
        assert!(Admission::admit(&a, &mut r4, arity2).is_ok());
    }

    #[test]
    fn shedding_degrades_with_hysteresis() {
        let a = mk_admission(AdmissionConfig {
            shed_high: 2,
            shed_low: 1,
            ..AdmissionConfig::default()
        });
        // Fill BitLevel to the high watermark.
        let mut r1 = mk_req(Engine::BitLevel);
        let mut r2 = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r1, arity2).is_ok());
        assert!(Admission::admit(&a, &mut r2, arity2).is_ok());
        assert!(!r1.degraded && !r2.degraded);
        // Next BitLevel request trips the latch and degrades.
        let mut r3 = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r3, arity2).is_ok());
        assert!(r3.degraded);
        assert_eq!(r3.engine, Engine::Analytic);
        assert!(a.is_shedding());
        // Degraded traffic is accounted under Analytic, so the BitLevel
        // depth stays at the watermark until the backlog drains.
        assert_eq!(a.depth(Engine::BitLevel), 2);
        assert_eq!(a.depth(Engine::Analytic), 1);
        // Draining to shed_low = 1 releases the latch.
        drop(r2);
        let mut r4 = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r4, arity2).is_ok());
        assert!(!r4.degraded, "latch must release once depth <= shed_low");
        assert!(!a.is_shedding());
    }

    #[test]
    fn force_shed_hook_latches() {
        let a = mk_admission(AdmissionConfig::default());
        a.force_shed(true);
        let mut r = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r, arity2).is_ok());
        assert!(r.degraded);
        a.force_shed(false);
        let mut r = mk_req(Engine::BitLevel);
        assert!(Admission::admit(&a, &mut r, arity2).is_ok());
        assert!(!r.degraded);
    }
}

//! Service metrics: counters + latency histograms, merged across workers.

use crate::util::stats::LatencyHistogram;
use std::sync::Mutex;
use std::time::Instant;

/// Shared metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    points: u64,
    batches: u64,
    errors: u64,
    queue: Option<LatencyHistogram>,
    exec: Option<LatencyHistogram>,
    e2e: Option<LatencyHistogram>,
    started: Option<Instant>,
}

/// A point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub requests: u64,
    pub points: u64,
    pub batches: u64,
    pub errors: u64,
    pub mean_batch_size: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, queue_ns: u64, exec_ns: u64, e2e_ns: u64, points: u64, batch: bool) {
        let mut m = self.inner.lock().unwrap();
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
        m.requests += 1;
        m.points += points;
        if batch {
            m.batches += 1;
        }
        m.queue.get_or_insert_with(LatencyHistogram::new).record(queue_ns);
        m.exec.get_or_insert_with(LatencyHistogram::new).record(exec_ns);
        m.e2e.get_or_insert_with(LatencyHistogram::new).record(e2e_ns);
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let q = m.queue.clone().unwrap_or_default();
        let x = m.exec.clone().unwrap_or_default();
        let e = m.e2e.clone().unwrap_or_default();
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        Snapshot {
            requests: m.requests,
            points: m.points,
            batches: m.batches,
            errors: m.errors,
            mean_batch_size: if m.batches == 0 {
                0.0
            } else {
                m.requests as f64 / m.batches as f64
            },
            queue_p50_us: q.quantile_ns(0.5) as f64 / 1e3,
            queue_p99_us: q.quantile_ns(0.99) as f64 / 1e3,
            exec_p50_us: x.quantile_ns(0.5) as f64 / 1e3,
            exec_p99_us: x.quantile_ns(0.99) as f64 / 1e3,
            e2e_p50_us: e.quantile_ns(0.5) as f64 / 1e3,
            e2e_p99_us: e.quantile_ns(0.99) as f64 / 1e3,
            throughput_rps: if elapsed > 0.0 { m.requests as f64 / elapsed } else { 0.0 },
        }
    }
}

impl Snapshot {
    /// Render a human-readable report block.
    pub fn report(&self) -> String {
        format!(
            "requests={} points={} batches={} (mean batch {:.1}) errors={}\n\
             queue p50/p99: {:.1}/{:.1} us | exec p50/p99: {:.1}/{:.1} us | \
             e2e p50/p99: {:.1}/{:.1} us | throughput {:.0} req/s",
            self.requests,
            self.points,
            self.batches,
            self.mean_batch_size,
            self.errors,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.e2e_p50_us,
            self.e2e_p99_us,
            self.throughput_rps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record(1_000, 10_000, 12_000, 4, true);
        m.record(2_000, 20_000, 25_000, 4, false);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 8);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 1);
        assert!(s.exec_p99_us >= s.exec_p50_us);
        assert!(s.report().contains("requests=2"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.throughput_rps, 0.0);
    }
}

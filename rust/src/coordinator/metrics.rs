//! Service metrics: counters + latency histograms, merged across workers,
//! including the fault-tolerance counters (rejections by reason, client
//! timeouts, degraded evals, worker panics, respawns, shutdown-answered
//! requests, and the in-flight queue-depth high-water mark) and the
//! drift-sentinel counters (canary cross-checks, drift alarms, recovery
//! probes, drift-degraded requests, recoveries, and non-finite engine
//! outputs caught by the worker guard) and the resilient-client counters
//! (retries, budget-exhausted stops, hedges and hedge outcomes, and
//! per-function circuit-breaker rejections/opens/recloses — see
//! [`super::client`]). The `submitted` counter plus
//! [`Snapshot::check_conservation`] form the answered-exactly-once
//! ledger the chaos soak (`crate::testutil::soak`) audits every round.

use super::request::RejectReason;
use crate::util::stats::LatencyHistogram;
use crate::util::sync::{lock_unpoisoned, Mutex};
use std::time::Instant;

/// Shared metrics sink.
///
/// Every lock is taken through [`lock_unpoisoned`]: a worker panic
/// (isolated elsewhere) between two metric calls must not poison-cascade
/// into every later `record_*`. The counters are independent u64s, so
/// recovering the guard is always sound.
#[derive(Debug)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Default for Metrics {
    // Manual (not derived): the loom facade's `Mutex` does not promise a
    // `Default` impl, and construction must work under both cfgs.
    fn default() -> Self {
        Self { inner: Mutex::new(Inner::default()) }
    }
}

#[derive(Debug, Default)]
struct Inner {
    submitted: u64,
    requests: u64,
    points: u64,
    batches: u64,
    errors: u64,
    rejected_queue_full: u64,
    rejected_bad_request: u64,
    rejected_deadline: u64,
    client_timeouts: u64,
    degraded: u64,
    panics: u64,
    respawns: u64,
    shutdown_answered: u64,
    queue_depth_highwater: u64,
    canary_checks: u64,
    drift_alarms: u64,
    drift_probes: u64,
    drift_degraded: u64,
    drift_recoveries: u64,
    nonfinite_outputs: u64,
    client_retries: u64,
    client_retry_budget_exhausted: u64,
    client_hedges: u64,
    client_hedge_wins: u64,
    client_hedge_verified: u64,
    client_hedge_mismatches: u64,
    breaker_rejections: u64,
    breaker_opens: u64,
    breaker_recloses: u64,
    queue: Option<LatencyHistogram>,
    exec: Option<LatencyHistogram>,
    e2e: Option<LatencyHistogram>,
    started: Option<Instant>,
}

/// A point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Requests that entered [`super::server::EvalServer::submit`] —
    /// the left-hand side of the conservation ledger
    /// ([`Snapshot::check_conservation`]): once the queues drain, every
    /// submitted request must be accounted for by exactly one of
    /// `requests`, `errors`, `rejected_*`, or `shutdown_answered`.
    pub submitted: u64,
    pub requests: u64,
    pub points: u64,
    pub batches: u64,
    pub errors: u64,
    /// Admission refusals: target engine at its in-flight limit.
    pub rejected_queue_full: u64,
    /// Admission refusals: malformed requests caught at the edge.
    pub rejected_bad_request: u64,
    /// Requests whose deadline expired before execution (at submit,
    /// batch formation, or the worker).
    pub rejected_deadline: u64,
    /// `eval_sync` callers whose deadline fired while waiting.
    pub client_timeouts: u64,
    /// BitLevel requests served from the analytic closed form by load
    /// shedding.
    pub degraded: u64,
    /// Worker panics caught and isolated.
    pub panics: u64,
    /// Worker/batcher threads respawned by supervision.
    pub respawns: u64,
    /// Requests answered with a typed shutdown error instead of being
    /// silently dropped at close.
    pub shutdown_answered: u64,
    /// Highest total in-flight depth observed at admission.
    pub queue_depth_highwater: u64,
    /// BitLevel responses cross-checked against the analytic closed form
    /// by the drift sentinel (paced canaries + recovery probes).
    pub canary_checks: u64,
    /// Drift alarms raised (a function's canary-error EWMA crossed the
    /// quarantine threshold).
    pub drift_alarms: u64,
    /// Recovery probes routed through the real engine while quarantined.
    pub drift_probes: u64,
    /// BitLevel requests degraded to the analytic closed form because
    /// their function's engine was quarantined (also counted under
    /// `degraded`).
    pub drift_degraded: u64,
    /// Quarantined functions restored to healthy by successful probes.
    pub drift_recoveries: u64,
    /// Engine outputs caught non-finite by the worker guard and answered
    /// with a typed error instead of a poisoned float.
    pub nonfinite_outputs: u64,
    /// Resilient-client retry attempts after a retryable failure
    /// ([`super::client::ResilientClient`]).
    pub client_retries: u64,
    /// Retries the client *wanted* but the token-bucket budget refused —
    /// storm containment doing its job.
    pub client_retry_budget_exhausted: u64,
    /// Hedge attempts launched after the configured latency threshold.
    pub client_hedges: u64,
    /// Hedged requests won by the hedge attempt (the primary lost).
    pub client_hedge_wins: u64,
    /// Hedge losers that completed and matched the winner bit-for-bit —
    /// the idempotency dividend, audited.
    pub client_hedge_verified: u64,
    /// Hedge losers that completed and *diverged* from the winner. Must
    /// stay 0; anything else is a determinism bug.
    pub client_hedge_mismatches: u64,
    /// Calls refused fast by an open per-function circuit breaker.
    pub breaker_rejections: u64,
    /// Closed→Open breaker transitions (failure threshold crossed).
    pub breaker_opens: u64,
    /// HalfOpen→Closed breaker transitions (probe streak succeeded).
    pub breaker_recloses: u64,
    pub mean_batch_size: f64,
    pub queue_p50_us: f64,
    pub queue_p99_us: f64,
    pub exec_p50_us: f64,
    pub exec_p99_us: f64,
    pub e2e_p50_us: f64,
    pub e2e_p99_us: f64,
    pub throughput_rps: f64,
}

impl Metrics {
    /// A fresh, all-zero sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one answered request's timings and point count; `batch`
    /// marks the first request of its batch (for mean-batch-size).
    pub fn record(&self, queue_ns: u64, exec_ns: u64, e2e_ns: u64, points: u64, batch: bool) {
        let mut m = lock_unpoisoned(&self.inner);
        if m.started.is_none() {
            m.started = Some(Instant::now());
        }
        m.requests += 1;
        m.points += points;
        if batch {
            m.batches += 1;
        }
        m.queue.get_or_insert_with(LatencyHistogram::new).record(queue_ns);
        m.exec.get_or_insert_with(LatencyHistogram::new).record(exec_ns);
        m.e2e.get_or_insert_with(LatencyHistogram::new).record(e2e_ns);
    }

    /// Count a request entering `submit` (before routing, admission, or
    /// any outcome counter) — the conservation ledger's debit side.
    pub fn record_submitted(&self) {
        lock_unpoisoned(&self.inner).submitted += 1;
    }

    /// Count a request answered with a typed error.
    pub fn record_error(&self) {
        lock_unpoisoned(&self.inner).errors += 1;
    }

    /// Count an admission refusal under its typed reason.
    pub fn record_rejection(&self, reason: &RejectReason) {
        let mut m = lock_unpoisoned(&self.inner);
        match reason {
            RejectReason::QueueFull => m.rejected_queue_full += 1,
            RejectReason::BadRequest(_) => m.rejected_bad_request += 1,
            RejectReason::Deadline => m.rejected_deadline += 1,
        }
    }

    /// Count an `eval_sync` caller whose deadline fired while waiting.
    pub fn record_client_timeout(&self) {
        lock_unpoisoned(&self.inner).client_timeouts += 1;
    }

    /// Count a request served at reduced fidelity (shed or quarantined).
    pub fn record_degraded(&self) {
        lock_unpoisoned(&self.inner).degraded += 1;
    }

    /// Count a caught worker/batcher panic.
    pub fn record_panic(&self) {
        lock_unpoisoned(&self.inner).panics += 1;
    }

    /// Count a supervised thread respawn.
    pub fn record_respawn(&self) {
        lock_unpoisoned(&self.inner).respawns += 1;
    }

    /// Count a request answered with a typed shutdown error at close.
    pub fn record_shutdown_answered(&self) {
        lock_unpoisoned(&self.inner).shutdown_answered += 1;
    }

    /// Count a canary cross-check against the analytic reference.
    pub fn record_canary(&self) {
        lock_unpoisoned(&self.inner).canary_checks += 1;
    }

    /// Count a drift alarm (EWMA crossed the quarantine threshold).
    pub fn record_drift_alarm(&self) {
        lock_unpoisoned(&self.inner).drift_alarms += 1;
    }

    /// Count a recovery probe routed through the real engine.
    pub fn record_drift_probe(&self) {
        lock_unpoisoned(&self.inner).drift_probes += 1;
    }

    /// Count a request degraded because its function was quarantined.
    pub fn record_drift_degraded(&self) {
        lock_unpoisoned(&self.inner).drift_degraded += 1;
    }

    /// Count a quarantined function restored to healthy.
    pub fn record_drift_recovery(&self) {
        lock_unpoisoned(&self.inner).drift_recoveries += 1;
    }

    /// Count a non-finite engine output caught by the worker guard.
    pub fn record_nonfinite(&self) {
        lock_unpoisoned(&self.inner).nonfinite_outputs += 1;
    }

    /// Count a resilient-client retry attempt.
    pub fn record_client_retry(&self) {
        lock_unpoisoned(&self.inner).client_retries += 1;
    }

    /// Count a retry refused by an exhausted retry budget.
    pub fn record_retry_budget_exhausted(&self) {
        lock_unpoisoned(&self.inner).client_retry_budget_exhausted += 1;
    }

    /// Count a hedge attempt launched.
    pub fn record_client_hedge(&self) {
        lock_unpoisoned(&self.inner).client_hedges += 1;
    }

    /// Count a hedged request won by the hedge attempt.
    pub fn record_client_hedge_win(&self) {
        lock_unpoisoned(&self.inner).client_hedge_wins += 1;
    }

    /// Count a hedge loser audited bit-identical to the winner.
    pub fn record_client_hedge_verified(&self) {
        lock_unpoisoned(&self.inner).client_hedge_verified += 1;
    }

    /// Count a hedge loser that diverged from the winner (determinism bug).
    pub fn record_client_hedge_mismatch(&self) {
        lock_unpoisoned(&self.inner).client_hedge_mismatches += 1;
    }

    /// Count a call refused fast by an open circuit breaker.
    pub fn record_breaker_rejection(&self) {
        lock_unpoisoned(&self.inner).breaker_rejections += 1;
    }

    /// Count a Closed→Open breaker transition.
    pub fn record_breaker_open(&self) {
        lock_unpoisoned(&self.inner).breaker_opens += 1;
    }

    /// Count a HalfOpen→Closed breaker transition.
    pub fn record_breaker_reclose(&self) {
        lock_unpoisoned(&self.inner).breaker_recloses += 1;
    }

    /// Track the in-flight high-water mark (called at admission).
    pub fn note_queue_depth(&self, depth: u64) {
        let mut m = lock_unpoisoned(&self.inner);
        if depth > m.queue_depth_highwater {
            m.queue_depth_highwater = depth;
        }
    }

    /// A point-in-time copy of every counter and quantile.
    pub fn snapshot(&self) -> Snapshot {
        let m = lock_unpoisoned(&self.inner);
        let q = m.queue.clone().unwrap_or_default();
        let x = m.exec.clone().unwrap_or_default();
        let e = m.e2e.clone().unwrap_or_default();
        let elapsed = m.started.map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        Snapshot {
            submitted: m.submitted,
            requests: m.requests,
            points: m.points,
            batches: m.batches,
            errors: m.errors,
            rejected_queue_full: m.rejected_queue_full,
            rejected_bad_request: m.rejected_bad_request,
            rejected_deadline: m.rejected_deadline,
            client_timeouts: m.client_timeouts,
            degraded: m.degraded,
            panics: m.panics,
            respawns: m.respawns,
            shutdown_answered: m.shutdown_answered,
            queue_depth_highwater: m.queue_depth_highwater,
            canary_checks: m.canary_checks,
            drift_alarms: m.drift_alarms,
            drift_probes: m.drift_probes,
            drift_degraded: m.drift_degraded,
            drift_recoveries: m.drift_recoveries,
            nonfinite_outputs: m.nonfinite_outputs,
            client_retries: m.client_retries,
            client_retry_budget_exhausted: m.client_retry_budget_exhausted,
            client_hedges: m.client_hedges,
            client_hedge_wins: m.client_hedge_wins,
            client_hedge_verified: m.client_hedge_verified,
            client_hedge_mismatches: m.client_hedge_mismatches,
            breaker_rejections: m.breaker_rejections,
            breaker_opens: m.breaker_opens,
            breaker_recloses: m.breaker_recloses,
            mean_batch_size: if m.batches == 0 {
                0.0
            } else {
                m.requests as f64 / m.batches as f64
            },
            queue_p50_us: q.quantile_ns(0.5) as f64 / 1e3,
            queue_p99_us: q.quantile_ns(0.99) as f64 / 1e3,
            exec_p50_us: x.quantile_ns(0.5) as f64 / 1e3,
            exec_p99_us: x.quantile_ns(0.99) as f64 / 1e3,
            e2e_p50_us: e.quantile_ns(0.5) as f64 / 1e3,
            e2e_p99_us: e.quantile_ns(0.99) as f64 / 1e3,
            throughput_rps: if elapsed > 0.0 { m.requests as f64 / elapsed } else { 0.0 },
        }
    }
}

impl Snapshot {
    /// Conservation check over the answered-exactly-once ledger: every
    /// request that entered `submit` must appear in exactly one outcome
    /// bucket —
    ///
    /// ```text
    /// submitted == requests (ok)
    ///            + errors (typed EvalError at the worker)
    ///            + rejected_queue_full + rejected_bad_request + rejected_deadline
    ///            + shutdown_answered
    /// ```
    ///
    /// Only valid once the stack has drained (in-flight depth 0): a
    /// request still queued is submitted but not yet answered, so callers
    /// (the chaos soak, chaos-test teardowns) must wait for
    /// `Admission::total_depth() == 0` first. `client_timeouts` is
    /// deliberately absent: a timed-out caller's request is still
    /// answered (to a dropped receiver) and lands in a bucket. The one
    /// path outside the ledger is a *batcher* panic (its pending map is
    /// lost by design, clients see a disconnect); the soak never induces
    /// one, so a shortfall here under `panics > 0` with a healthy batcher
    /// is a real leak.
    pub fn check_conservation(&self) -> Result<(), String> {
        let answered = self.requests
            + self.errors
            + self.rejected_queue_full
            + self.rejected_bad_request
            + self.rejected_deadline
            + self.shutdown_answered;
        if self.submitted == answered {
            Ok(())
        } else {
            Err(format!(
                "metrics conservation violated: submitted={} != answered={} \
                 (ok={} + errors={} + rejected {}/{}/{} + shutdown_answered={})",
                self.submitted,
                answered,
                self.requests,
                self.errors,
                self.rejected_queue_full,
                self.rejected_bad_request,
                self.rejected_deadline,
                self.shutdown_answered,
            ))
        }
    }

    /// Render a human-readable report block.
    pub fn report(&self) -> String {
        format!(
            "submitted={} requests={} points={} batches={} (mean batch {:.1}) errors={}\n\
             rejected qfull/bad/deadline: {}/{}/{} | timeouts={} | degraded={} | \
             panics={} respawns={} shutdown-answered={} | queue hw={}\n\
             drift canary/alarm/probe/degraded/recovered: {}/{}/{}/{}/{} | \
             nonfinite={}\n\
             client retry/budget-stop/hedge/hedge-win/verified/mismatch: \
             {}/{}/{}/{}/{}/{} | breaker reject/open/reclose: {}/{}/{}\n\
             queue p50/p99: {:.1}/{:.1} us | exec p50/p99: {:.1}/{:.1} us | \
             e2e p50/p99: {:.1}/{:.1} us | throughput {:.0} req/s",
            self.submitted,
            self.requests,
            self.points,
            self.batches,
            self.mean_batch_size,
            self.errors,
            self.rejected_queue_full,
            self.rejected_bad_request,
            self.rejected_deadline,
            self.client_timeouts,
            self.degraded,
            self.panics,
            self.respawns,
            self.shutdown_answered,
            self.queue_depth_highwater,
            self.canary_checks,
            self.drift_alarms,
            self.drift_probes,
            self.drift_degraded,
            self.drift_recoveries,
            self.nonfinite_outputs,
            self.client_retries,
            self.client_retry_budget_exhausted,
            self.client_hedges,
            self.client_hedge_wins,
            self.client_hedge_verified,
            self.client_hedge_mismatches,
            self.breaker_rejections,
            self.breaker_opens,
            self.breaker_recloses,
            self.queue_p50_us,
            self.queue_p99_us,
            self.exec_p50_us,
            self.exec_p99_us,
            self.e2e_p50_us,
            self.e2e_p99_us,
            self.throughput_rps
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_submitted();
        m.record(1_000, 10_000, 12_000, 4, true);
        m.record(2_000, 20_000, 25_000, 4, false);
        m.record_error();
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.requests, 2);
        assert_eq!(s.points, 8);
        assert_eq!(s.batches, 1);
        assert_eq!(s.errors, 1);
        assert!(s.exec_p99_us >= s.exec_p50_us);
        assert!(s.report().contains("submitted=3 requests=2"));
    }

    #[test]
    fn conservation_balances_across_every_outcome_bucket() {
        let m = Metrics::new();
        // 7 submits: 2 ok, 1 typed error, 3 rejections (one per reason),
        // 1 answered at shutdown.
        for _ in 0..7 {
            m.record_submitted();
        }
        m.record(1_000, 10_000, 12_000, 1, true);
        m.record(1_000, 10_000, 12_000, 1, false);
        m.record_error();
        m.record_rejection(&RejectReason::QueueFull);
        m.record_rejection(&RejectReason::BadRequest("arity".into()));
        m.record_rejection(&RejectReason::Deadline);
        m.record_shutdown_answered();
        assert!(m.snapshot().check_conservation().is_ok());
        // Client-side counters never unbalance the ledger.
        m.record_client_timeout();
        m.record_breaker_rejection();
        assert!(m.snapshot().check_conservation().is_ok());
    }

    #[test]
    fn conservation_flags_an_unanswered_submit() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record(1_000, 10_000, 12_000, 1, true);
        let err = m.snapshot().check_conservation().unwrap_err();
        assert!(err.contains("submitted=2"), "got: {err}");
        assert!(err.contains("answered=1"), "got: {err}");
        // An over-answered ledger (an outcome recorded twice) also fails.
        m.record_error();
        m.record_error();
        assert!(m.snapshot().check_conservation().is_err());
    }

    #[test]
    fn fault_counters_record_and_report() {
        let m = Metrics::new();
        m.record_rejection(&RejectReason::QueueFull);
        m.record_rejection(&RejectReason::BadRequest("x".into()));
        m.record_rejection(&RejectReason::BadRequest("y".into()));
        m.record_rejection(&RejectReason::Deadline);
        m.record_client_timeout();
        m.record_degraded();
        m.record_panic();
        m.record_respawn();
        m.record_shutdown_answered();
        m.note_queue_depth(7);
        m.note_queue_depth(3); // high-water keeps the max
        m.record_canary();
        m.record_canary();
        m.record_drift_alarm();
        m.record_drift_probe();
        m.record_drift_degraded();
        m.record_drift_recovery();
        m.record_nonfinite();
        m.record_client_retry();
        m.record_client_retry();
        m.record_retry_budget_exhausted();
        m.record_client_hedge();
        m.record_client_hedge_win();
        m.record_client_hedge_verified();
        m.record_client_hedge_mismatch();
        m.record_breaker_rejection();
        m.record_breaker_rejection();
        m.record_breaker_rejection();
        m.record_breaker_open();
        m.record_breaker_reclose();
        let s = m.snapshot();
        assert_eq!(s.rejected_queue_full, 1);
        assert_eq!(s.rejected_bad_request, 2);
        assert_eq!(s.rejected_deadline, 1);
        assert_eq!(s.client_timeouts, 1);
        assert_eq!(s.degraded, 1);
        assert_eq!(s.panics, 1);
        assert_eq!(s.respawns, 1);
        assert_eq!(s.shutdown_answered, 1);
        assert_eq!(s.queue_depth_highwater, 7);
        assert_eq!(s.canary_checks, 2);
        assert_eq!(s.drift_alarms, 1);
        assert_eq!(s.drift_probes, 1);
        assert_eq!(s.drift_degraded, 1);
        assert_eq!(s.drift_recoveries, 1);
        assert_eq!(s.nonfinite_outputs, 1);
        assert_eq!(s.client_retries, 2);
        assert_eq!(s.client_retry_budget_exhausted, 1);
        assert_eq!(s.client_hedges, 1);
        assert_eq!(s.client_hedge_wins, 1);
        assert_eq!(s.client_hedge_verified, 1);
        assert_eq!(s.client_hedge_mismatches, 1);
        assert_eq!(s.breaker_rejections, 3);
        assert_eq!(s.breaker_opens, 1);
        assert_eq!(s.breaker_recloses, 1);
        assert!(s.report().contains("rejected qfull/bad/deadline: 1/2/1"));
        assert!(s.report().contains("queue hw=7"));
        assert!(s.report().contains("drift canary/alarm/probe/degraded/recovered: 2/1/1/1/1"));
        assert!(s.report().contains("nonfinite=1"));
        assert!(s
            .report()
            .contains("client retry/budget-stop/hedge/hedge-win/verified/mismatch: 2/1/1/1/1/1"));
        assert!(s.report().contains("breaker reject/open/reclose: 3/1/1"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.submitted, 0);
        assert_eq!(s.requests, 0);
        assert!(s.check_conservation().is_ok());
        assert_eq!(s.mean_batch_size, 0.0);
        assert_eq!(s.throughput_rps, 0.0);
        assert_eq!(s.panics, 0);
        assert_eq!(s.queue_depth_highwater, 0);
        assert_eq!(s.canary_checks, 0);
        assert_eq!(s.drift_alarms, 0);
        assert_eq!(s.nonfinite_outputs, 0);
        assert_eq!(s.client_retries, 0);
        assert_eq!(s.client_hedges, 0);
        assert_eq!(s.breaker_rejections, 0);
    }
}

//! Dynamic batcher: size + deadline triggered batch formation.
//!
//! Requests arrive on an MPSC channel; the batcher thread accumulates
//! them per (function, engine) key and flushes a batch when either
//! `max_batch` requests are waiting or the oldest request has waited
//! `max_wait`. This is the classic serving-router batching policy
//! (vLLM/Orca): bounded latency, amortized execution.
//!
//! Failure semantics (see the failure model in [`crate::coordinator`]):
//!
//! - **Per-request deadlines** are enforced at batch formation: an
//!   expired request is answered with `Rejected(Deadline)` and never
//!   dispatched (BitLevel work is L-cycle expensive; expired work is
//!   wasted work).
//! - **No starvation under continuous traffic**: expired groups are
//!   flushed on *every* loop iteration, including the arrival path — a
//!   quiet group's deadline cannot be held hostage by a busy neighbor
//!   key that keeps the receive loop in its arrival branch.
//! - **No silent drops**: if the worker channel is closed (shutdown or
//!   total worker loss), every request in the batch is answered with a
//!   typed [`EvalError::Shutdown`] and counted in metrics instead of
//!   being discarded.

use super::metrics::Metrics;
use super::request::{Engine, EvalError, EvalRequest, EvalResponse, RejectReason};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 300 µs deadline: §Perf found the 2 ms default dominated
        // end-to-end latency for synchronous clients (queue p50 ≈ max_wait)
        // while batches saturated at the client count anyway — the lower
        // deadline tripled closed-loop throughput at equal batch shapes.
        Self { max_batch: 64, max_wait: Duration::from_micros(300) }
    }
}

/// A formed batch ready for a worker.
pub struct Batch {
    pub key: (String, Engine),
    pub requests: Vec<EvalRequest>,
    pub formed_at: Instant,
}

/// Run the batching loop until the input channel closes. Formed batches
/// are sent to `out` (consumed by the worker pool). Borrows its channels
/// so the supervising wrapper in `server` can restart the loop after a
/// panic without losing either endpoint.
pub fn run_batcher(
    rx: &Receiver<EvalRequest>,
    out: &Sender<Batch>,
    policy: BatchPolicy,
    metrics: &Metrics,
) {
    let mut pending: HashMap<(String, Engine), Vec<EvalRequest>> = HashMap::new();
    let mut oldest: HashMap<(String, Engine), Instant> = HashMap::new();
    loop {
        // Compute the nearest deadline over all pending groups.
        let now = Instant::now();
        let next_deadline = oldest
            .values()
            .map(|&t| t + policy.max_wait)
            .min()
            .unwrap_or(now + Duration::from_millis(50));
        let timeout = next_deadline.saturating_duration_since(now);

        match rx.recv_timeout(timeout) {
            Ok(req) => {
                // xtask: hot-loop — steady-state arrival path: runs once per
                // request under continuous traffic. No fresh buffer
                // allocations here: group Vecs are reused through entry(),
                // and the String key clones are the only per-request heap
                // work (HashMap keying needs owned keys).
                let key = (req.function.clone(), req.engine);
                let group = pending.entry(key.clone()).or_default();
                oldest.entry(key.clone()).or_insert_with(Instant::now);
                group.push(req);
                if group.len() >= policy.max_batch {
                    flush(&mut pending, &mut oldest, &key, out, metrics);
                }
                // Starvation fix: a continuous arrival stream keeps this
                // branch hot (recv_timeout returns Ok whenever a message
                // is already queued), so group deadlines must also be
                // checked here, not only on the Timeout branch.
                flush_expired(&mut pending, &mut oldest, &policy, out, metrics);
                // xtask: hot-loop-end
            }
            Err(RecvTimeoutError::Timeout) => {
                flush_expired(&mut pending, &mut oldest, &policy, out, metrics);
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Intake closed: drain everything and exit.
                let keys: Vec<_> = pending.keys().cloned().collect();
                for key in keys {
                    flush(&mut pending, &mut oldest, &key, out, metrics);
                }
                return;
            }
        }
    }
}

/// Flush every group whose oldest member has waited `max_wait`.
fn flush_expired(
    pending: &mut HashMap<(String, Engine), Vec<EvalRequest>>,
    oldest: &mut HashMap<(String, Engine), Instant>,
    policy: &BatchPolicy,
    out: &Sender<Batch>,
    metrics: &Metrics,
) {
    let now = Instant::now();
    let expired: Vec<_> = oldest
        .iter()
        .filter(|(_, &t)| now >= t + policy.max_wait)
        .map(|(k, _)| k.clone())
        .collect();
    for key in expired {
        flush(pending, oldest, &key, out, metrics);
    }
}

fn flush(
    pending: &mut HashMap<(String, Engine), Vec<EvalRequest>>,
    oldest: &mut HashMap<(String, Engine), Instant>,
    key: &(String, Engine),
    out: &Sender<Batch>,
    metrics: &Metrics,
) {
    let Some(reqs) = pending.remove(key) else { return };
    oldest.remove(key);
    if reqs.is_empty() {
        return;
    }
    // Deadline enforcement at batch formation: expired requests are
    // answered, not evaluated.
    let now = Instant::now();
    let (expired, live): (Vec<_>, Vec<_>) = reqs.into_iter().partition(|r| r.expired(now));
    for r in expired {
        metrics.record_rejection(&RejectReason::Deadline);
        let _ = r
            .reply
            .send(EvalResponse::from_error(EvalError::Rejected(RejectReason::Deadline)));
    }
    if live.is_empty() {
        return;
    }
    if let Err(unsent) = out.send(Batch { key: key.clone(), requests: live, formed_at: now }) {
        // Worker channel closed (shutdown or total worker loss): answer
        // every request with a typed shutdown error instead of silently
        // discarding the batch.
        for r in unsent.0.requests {
            metrics.record_shutdown_answered();
            let _ = r.reply.send(EvalResponse::from_error(EvalError::Shutdown));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn mk_request(function: &str, reply: Sender<EvalResponse>) -> EvalRequest {
        EvalRequest::new(function, vec![vec![0.5, 0.5]], Engine::Analytic, 64, reply)
    }

    fn spawn_batcher(
        policy: BatchPolicy,
    ) -> (
        Sender<EvalRequest>,
        Receiver<Batch>,
        Arc<Metrics>,
        std::thread::JoinHandle<()>,
    ) {
        let (tx, rx) = channel();
        let (btx, brx) = channel();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let h = std::thread::spawn(move || run_batcher(&rx, &btx, policy, &m));
        (tx, brx, metrics, h)
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (rtx, _rrx) = channel();
        for _ in 0..4 {
            tx.send(mk_request("f", rtx.clone())).unwrap();
        }
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 4);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_trigger_flushes_partial_batch() {
        let policy = BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(5) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 2, "partial batch must flush on deadline");
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_function() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(200) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        tx.send(mk_request("g", rtx.clone())).unwrap();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        // "f" reaches max_batch=2 first.
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.key.0, "f");
        assert_eq!(batch.requests.len(), 2);
        drop(tx);
        // Remaining "g" flushes on drain.
        let batch2 = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch2.key.0, "g");
        h.join().unwrap();
    }

    #[test]
    fn shutdown_drains_pending() {
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(100) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        drop(tx); // close input
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        h.join().unwrap();
    }

    /// Regression (ISSUE 6): a group whose max_wait expires while another
    /// key's requests keep arriving must still flush on time. The old
    /// loop only checked deadlines on the recv *timeout* branch, which a
    /// continuous arrival stream never reaches.
    #[test]
    fn busy_neighbor_key_cannot_starve_a_quiet_group() {
        let policy = BatchPolicy { max_batch: 10_000, max_wait: Duration::from_millis(10) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (rtx, _rrx) = channel();
        // The quiet group: one request for "f".
        tx.send(mk_request("f", rtx.clone())).unwrap();
        let t0 = Instant::now();
        // The busy neighbor: hammer "g" continuously from another thread
        // so the batcher's arrival branch stays hot.
        let gtx = tx.clone();
        let grtx = rtx.clone();
        let hammer = std::thread::spawn(move || {
            while t0.elapsed() < Duration::from_millis(300) {
                if gtx.send(mk_request("g", grtx.clone())).is_err() {
                    return;
                }
                std::thread::yield_now();
            }
        });
        // "f" must flush at ~max_wait despite the traffic; allow generous
        // slack for CI schedulers, but far below the 300 ms hammer window.
        let f_batch = loop {
            let b = brx
                .recv_timeout(Duration::from_millis(250))
                .expect("quiet group starved: no flush while neighbor traffic continues");
            if b.key.0 == "f" {
                break b;
            }
        };
        assert!(
            t0.elapsed() < Duration::from_millis(250),
            "quiet group flushed only after {:?}",
            t0.elapsed()
        );
        assert_eq!(f_batch.requests.len(), 1);
        hammer.join().unwrap();
        drop(tx);
        // Drain remaining "g" batches so the batcher can exit.
        while brx.recv_timeout(Duration::from_millis(100)).is_ok() {}
        h.join().unwrap();
    }

    /// Deadline enforcement at batch formation: an expired request is
    /// answered with `Rejected(Deadline)` and never dispatched.
    #[test]
    fn expired_request_answered_not_dispatched() {
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(20) };
        let (tx, brx, metrics, h) = spawn_batcher(policy);
        let (rtx, rrx) = channel();
        let req = mk_request("f", rtx).with_deadline(Instant::now() + Duration::from_millis(1));
        tx.send(req).unwrap();
        // The flush fires at ~max_wait (20 ms) > deadline (1 ms).
        let resp = rrx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(resp.error, Some(EvalError::Rejected(RejectReason::Deadline)));
        assert!(
            brx.recv_timeout(Duration::from_millis(50)).is_err(),
            "expired request must not be dispatched to workers"
        );
        assert_eq!(metrics.snapshot().rejected_deadline, 1);
        drop(tx);
        h.join().unwrap();
    }

    /// A mixed group flushes its live members and answers only the
    /// expired ones.
    #[test]
    fn mixed_group_partitions_expired_from_live() {
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_millis(15) };
        let (tx, brx, _metrics, h) = spawn_batcher(policy);
        let (dead_tx, dead_rx) = channel();
        let (live_tx, _live_rx) = channel();
        tx.send(
            mk_request("f", dead_tx).with_deadline(Instant::now() + Duration::from_millis(1)),
        )
        .unwrap();
        tx.send(mk_request("f", live_tx)).unwrap();
        let resp = dead_rx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(resp.error, Some(EvalError::Rejected(RejectReason::Deadline)));
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1, "only the live request is dispatched");
        drop(tx);
        h.join().unwrap();
    }

    /// Regression (ISSUE 6): a closed worker channel answers every
    /// request with a typed shutdown error (the old code was
    /// `let _ = out.send(..)` — a silent drop).
    #[test]
    fn closed_worker_channel_answers_with_typed_shutdown() {
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(100) };
        let (tx, rx) = channel();
        let (btx, brx) = channel::<Batch>();
        drop(brx); // workers are gone
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let h = std::thread::spawn(move || run_batcher(&rx, &btx, policy, &m));
        let (rtx, rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        tx.send(mk_request("f", rtx.clone())).unwrap(); // size trigger
        for _ in 0..2 {
            let resp = rrx.recv_timeout(Duration::from_secs(1)).unwrap();
            assert_eq!(resp.error, Some(EvalError::Shutdown));
        }
        assert_eq!(metrics.snapshot().shutdown_answered, 2);
        drop(tx);
        h.join().unwrap();
    }
}

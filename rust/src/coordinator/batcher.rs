//! Dynamic batcher: size + deadline triggered batch formation.
//!
//! Requests arrive on an MPSC channel; the batcher thread accumulates
//! them per (function, engine) key and flushes a batch when either
//! `max_batch` requests are waiting or the oldest request has waited
//! `max_wait`. This is the classic serving-router batching policy
//! (vLLM/Orca): bounded latency, amortized execution.

use super::request::{Engine, EvalRequest};
use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        // 300 µs deadline: §Perf found the 2 ms default dominated
        // end-to-end latency for synchronous clients (queue p50 ≈ max_wait)
        // while batches saturated at the client count anyway — the lower
        // deadline tripled closed-loop throughput at equal batch shapes.
        Self { max_batch: 64, max_wait: Duration::from_micros(300) }
    }
}

/// A formed batch ready for a worker.
pub struct Batch {
    pub key: (String, Engine),
    pub requests: Vec<EvalRequest>,
    pub formed_at: Instant,
}

/// Run the batching loop until the input channel closes. Formed batches
/// are sent to `out` (consumed by the worker pool).
pub fn run_batcher(rx: Receiver<EvalRequest>, out: Sender<Batch>, policy: BatchPolicy) {
    let mut pending: HashMap<(String, Engine), Vec<EvalRequest>> = HashMap::new();
    let mut oldest: HashMap<(String, Engine), Instant> = HashMap::new();
    loop {
        // Compute the nearest deadline over all pending groups.
        let now = Instant::now();
        let next_deadline = oldest
            .values()
            .map(|&t| t + policy.max_wait)
            .min()
            .unwrap_or(now + Duration::from_millis(50));
        let timeout = next_deadline.saturating_duration_since(now);

        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let key = (req.function.clone(), req.engine);
                let group = pending.entry(key.clone()).or_default();
                oldest.entry(key.clone()).or_insert_with(Instant::now);
                group.push(req);
                if group.len() >= policy.max_batch {
                    flush(&mut pending, &mut oldest, &key, &out);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // Flush every group whose oldest member expired.
                let now = Instant::now();
                let expired: Vec<_> = oldest
                    .iter()
                    .filter(|(_, &t)| now >= t + policy.max_wait)
                    .map(|(k, _)| k.clone())
                    .collect();
                for key in expired {
                    flush(&mut pending, &mut oldest, &key, &out);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Drain everything and exit.
                let keys: Vec<_> = pending.keys().cloned().collect();
                for key in keys {
                    flush(&mut pending, &mut oldest, &key, &out);
                }
                return;
            }
        }
    }
}

fn flush(
    pending: &mut HashMap<(String, Engine), Vec<EvalRequest>>,
    oldest: &mut HashMap<(String, Engine), Instant>,
    key: &(String, Engine),
    out: &Sender<Batch>,
) {
    if let Some(reqs) = pending.remove(key) {
        oldest.remove(key);
        if !reqs.is_empty() {
            // Receiver loss means shutdown; drop silently.
            let _ = out.send(Batch {
                key: key.clone(),
                requests: reqs,
                formed_at: Instant::now(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn mk_request(function: &str, reply: Sender<super::super::request::EvalResponse>) -> EvalRequest {
        EvalRequest {
            function: function.into(),
            points: vec![vec![0.5, 0.5]],
            engine: Engine::Analytic,
            stream_len: 64,
            enqueued: Instant::now(),
            reply,
        }
    }

    #[test]
    fn size_trigger_forms_full_batch() {
        let (tx, rx) = channel();
        let (btx, brx) = channel();
        let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let (rtx, _rrx) = channel();
        for _ in 0..4 {
            tx.send(mk_request("f", rtx.clone())).unwrap();
        }
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 4);
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn deadline_trigger_flushes_partial_batch() {
        let (tx, rx) = channel();
        let (btx, brx) = channel();
        let policy = BatchPolicy { max_batch: 1000, max_wait: Duration::from_millis(5) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 2, "partial batch must flush on deadline");
        drop(tx);
        h.join().unwrap();
    }

    #[test]
    fn groups_by_function() {
        let (tx, rx) = channel();
        let (btx, brx) = channel();
        let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(200) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        tx.send(mk_request("g", rtx.clone())).unwrap();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        // "f" reaches max_batch=2 first.
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.key.0, "f");
        assert_eq!(batch.requests.len(), 2);
        drop(tx);
        // Remaining "g" flushes on drain.
        let batch2 = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch2.key.0, "g");
        h.join().unwrap();
    }

    #[test]
    fn shutdown_drains_pending() {
        let (tx, rx) = channel();
        let (btx, brx) = channel();
        let policy = BatchPolicy { max_batch: 100, max_wait: Duration::from_secs(100) };
        let h = std::thread::spawn(move || run_batcher(rx, btx, policy));
        let (rtx, _rrx) = channel();
        tx.send(mk_request("f", rtx.clone())).unwrap();
        drop(tx); // close input
        let batch = brx.recv_timeout(Duration::from_secs(1)).unwrap();
        assert_eq!(batch.requests.len(), 1);
        h.join().unwrap();
    }
}

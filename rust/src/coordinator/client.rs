//! Resilient client: the caller-side recovery ladder over
//! [`EvalServer`](super::server::EvalServer).
//!
//! The serving core *fails well* — typed errors, panic isolation, load
//! shedding, drift quarantine — but a bare `eval_sync` still surfaces
//! every `Timeout`/`QueueFull`/`WorkerPanic` straight to the caller.
//! [`ResilientClient`] wraps `submit`/`eval_sync_with_timeout` with four
//! independently configurable recovery stages, rung by rung:
//!
//! 1. **Deadline-carving retries** ([`RetryPolicy`]): every attempt gets
//!    a per-attempt timeout carved from the *overall* request deadline,
//!    and failed retryable attempts back off exponentially with
//!    equal-jitter drawn from a seeded [`Pcg`] stream — no `thread_rng`
//!    anywhere, so retry schedules replay exactly under a fixed seed.
//! 2. **Retry budgets** ([`BudgetConfig`]): a token bucket (earn a
//!    fraction per success, spend one per retry) bounds how much extra
//!    load retries can add, so a correlated failure can never amplify
//!    into a retry storm. Classification is
//!    [`EvalError::is_retryable`]: terminal errors never burn budget.
//! 3. **Hedged requests** ([`HedgeConfig`]): once an attempt outlives a
//!    latency threshold (fixed, or a live quantile of past attempt
//!    latencies), a second identical request is launched and the first
//!    answer wins. Because served outputs are deterministic per request
//!    (seeds derive from `DEFAULT_STREAM_SEED ^ point_index`), the
//!    losing attempt is *audited* for bit-identity with the winner when
//!    it eventually lands — the idempotency dividend, checked on every
//!    hedge rather than assumed.
//! 4. **Per-function circuit breakers** ([`BreakerConfig`]):
//!    Closed→Open→HalfOpen keyed on function name, reusing the drift
//!    sentinel's count-based probe-and-recover idiom (no wall-clock
//!    cooldowns — deterministic in tests). While Open, calls fail fast
//!    with [`EvalError::CircuitOpen`] without touching the server; every
//!    `probe_interval`-th arrival is let through as a probe, and a
//!    streak of good probes recloses the breaker.
//!
//! With every stage disabled ([`ClientConfig::default`]) the client is a
//! strict passthrough: `eval_with_timeout` delegates directly to
//! [`EvalServer::eval_sync_with_timeout`](super::server::EvalServer::eval_sync_with_timeout),
//! byte-for-byte identical behavior (pinned by the chaos suite).
//!
//! All shared state lives behind [`crate::util::sync`] primitives so the
//! module stays loom-modelable alongside the rest of the coordinator.

use super::metrics::Metrics;
use super::request::{Engine, EvalError, EvalRequest, EvalResponse};
use super::server::EvalServer;
use crate::util::prng::Pcg;
use crate::util::stats::LatencyHistogram;
use crate::util::sync::{lock_unpoisoned, Arc, AtomicU64, Mutex, Ordering};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

/// Poll tick while racing a primary attempt against its hedge: mpsc
/// receivers cannot be `select`ed, so after the hedge launches the
/// client alternates `try_recv` on both channels at this cadence. Far
/// below every serving latency floor we gate on, and only ever paid on
/// the (rare, already-slow) hedged path.
const HEDGE_POLL: Duration = Duration::from_micros(100);

/// Cap on parked hedge audits awaiting their losing reply; beyond it the
/// oldest audit is dropped (the loser's receiver closes harmlessly).
const MAX_PENDING_AUDITS: usize = 32;

/// Fixed-point scale for the retry budget: tokens are stored in
/// milli-tokens so fractional earn rates (e.g. 0.1 per success) work on
/// an integer atomic.
const BUDGET_MILLI: u64 = 1_000;

/// Retry stage configuration (ladder rung 1).
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Max retries after the first attempt (0 = first attempt only).
    pub max_retries: u32,
    /// Per-attempt timeout carved from the overall deadline; `None`
    /// gives every attempt the full remaining deadline.
    pub attempt_timeout: Option<Duration>,
    /// Backoff before retry `k` is drawn from
    /// `[min(base·2^k, max)/2, min(base·2^k, max))` — "equal jitter".
    pub backoff_base: Duration,
    /// Upper clamp on the exponential backoff.
    pub backoff_max: Duration,
    /// Seed for the jitter stream ([`Pcg`]); fixed seed ⇒ identical
    /// retry schedule on every run.
    pub jitter_seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_retries: 3,
            attempt_timeout: None,
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(100),
            jitter_seed: 0xB0FF,
        }
    }
}

/// Retry-budget configuration (ladder rung 2): a token bucket that
/// starts at `initial` tokens, earns `earn_per_success` per successful
/// attempt (clamped to `max`), and spends exactly 1 token per retry.
/// Budget-refused retries surface the last attempt's typed error and
/// bump `client_retry_budget_exhausted`.
#[derive(Clone, Copy, Debug)]
pub struct BudgetConfig {
    /// Tokens available at construction.
    pub initial: f64,
    /// Bucket capacity.
    pub max: f64,
    /// Tokens earned per successful attempt.
    pub earn_per_success: f64,
}

impl Default for BudgetConfig {
    fn default() -> Self {
        Self { initial: 10.0, max: 10.0, earn_per_success: 0.1 }
    }
}

/// When to launch the hedge attempt (ladder rung 3).
#[derive(Clone, Copy, Debug)]
pub enum HedgeDelay {
    /// Hedge after a fixed wait.
    Fixed(Duration),
    /// Hedge after the `q`-quantile of observed successful-attempt
    /// latencies, once at least `min_samples` have been recorded
    /// (`fallback` until then), never below `floor`.
    Quantile { q: f64, min_samples: u64, floor: Duration, fallback: Duration },
}

/// Hedged-request configuration.
#[derive(Clone, Copy, Debug)]
pub struct HedgeConfig {
    /// Latency threshold after which the second attempt launches.
    pub delay: HedgeDelay,
}

/// Per-function circuit-breaker configuration (ladder rung 4). All
/// cadences are *count-based* (arrivals, not wall-clock), mirroring the
/// drift sentinel's probe idiom, so breaker tests are deterministic.
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive failed calls (while Closed) that trip the breaker.
    pub failure_threshold: u32,
    /// While Open, every `probe_interval`-th arrival is admitted as a
    /// HalfOpen probe; the rest fail fast.
    pub probe_interval: u32,
    /// Consecutive successful probes required to reclose.
    pub probe_successes: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self { failure_threshold: 5, probe_interval: 4, probe_successes: 2 }
    }
}

/// Full client configuration. The default disables every stage, making
/// the client a strict passthrough to the server.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientConfig {
    /// Overall deadline for [`ResilientClient::eval`]; attempts, backoff
    /// and hedges are all carved from this one window. `None` uses the
    /// server's configured `sync_timeout`.
    pub total_timeout: Option<Duration>,
    /// Ladder rung 1; `None` = single attempt.
    pub retry: Option<RetryPolicy>,
    /// Ladder rung 2; `None` = unlimited retries (bounded only by
    /// `max_retries` and the deadline).
    pub budget: Option<BudgetConfig>,
    /// Ladder rung 3; `None` = never hedge.
    pub hedge: Option<HedgeConfig>,
    /// Ladder rung 4; `None` = no breaker.
    pub breaker: Option<BreakerConfig>,
}

/// Public breaker lifecycle state for one function.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: calls pass through; failures are counted.
    Closed,
    /// Tripped: calls fail fast; periodic arrivals become probes.
    Open,
    /// A probe is in flight; other arrivals still fail fast.
    HalfOpen,
}

/// Outcome tallies from [`ResilientClient::drain_hedge_audits`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HedgeAudit {
    /// Losers that completed bit-identical to their winner.
    pub verified: u64,
    /// Losers that completed but diverged (determinism bug — must be 0).
    pub mismatched: u64,
    /// Losers still unanswered when the drain wait expired (dropped).
    pub unresolved: u64,
}

// ---------------------------------------------------------------------
// Retry budget: fixed-point token bucket on a single atomic.
// ---------------------------------------------------------------------

#[derive(Debug)]
struct RetryBudget {
    milli: AtomicU64,
    max_milli: u64,
    earn_milli: u64,
}

impl RetryBudget {
    fn new(cfg: &BudgetConfig) -> Self {
        let to_milli = |x: f64| (x * BUDGET_MILLI as f64).round().max(0.0) as u64;
        let max_milli = to_milli(cfg.max);
        Self {
            milli: AtomicU64::new(to_milli(cfg.initial).min(max_milli)),
            max_milli,
            earn_milli: to_milli(cfg.earn_per_success),
        }
    }

    /// Spend one whole token; `false` (and no change) if fewer remain.
    fn try_spend(&self) -> bool {
        let mut cur = self.milli.load(Ordering::Relaxed);
        loop {
            if cur < BUDGET_MILLI {
                return false;
            }
            match self.milli.compare_exchange_weak(
                cur,
                cur - BUDGET_MILLI,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Earn the per-success increment, clamped to capacity.
    fn earn(&self) {
        let mut cur = self.milli.load(Ordering::Relaxed);
        loop {
            let next = cur.saturating_add(self.earn_milli).min(self.max_milli);
            if next == cur {
                return;
            }
            match self
                .milli
                .compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    fn tokens(&self) -> f64 {
        self.milli.load(Ordering::Relaxed) as f64 / BUDGET_MILLI as f64
    }
}

// ---------------------------------------------------------------------
// Per-function circuit breaker.
// ---------------------------------------------------------------------

#[derive(Debug)]
enum BreakerRoute {
    Pass,
    Probe,
    Reject,
}

#[derive(Clone, Copy, Debug)]
enum AttemptOutcome {
    /// The attempt succeeded.
    Good,
    /// The attempt failed with a *retryable* error — evidence the
    /// function's serving path is unhealthy.
    Faulty,
    /// The attempt failed terminally (bad request, shutdown, expired
    /// deadline): says nothing about the function's health, so it
    /// neither trips nor heals the breaker.
    Neutral,
}

#[derive(Debug, PartialEq, Eq)]
enum BreakerEvent {
    Opened,
    Reclosed,
}

#[derive(Debug)]
struct FnBreaker {
    stage: BreakerState,
    failures: u32,
    open_arrivals: u32,
    probe_streak: u32,
}

impl Default for FnBreaker {
    fn default() -> Self {
        Self { stage: BreakerState::Closed, failures: 0, open_arrivals: 0, probe_streak: 0 }
    }
}

#[derive(Debug)]
struct Breaker {
    cfg: BreakerConfig,
    map: Mutex<HashMap<String, FnBreaker>>,
}

impl Breaker {
    fn new(cfg: BreakerConfig) -> Self {
        Self { cfg, map: Mutex::new(HashMap::new()) }
    }

    /// Admission decision for one arrival at `function`'s breaker.
    fn route(&self, function: &str) -> BreakerRoute {
        let mut map = lock_unpoisoned(&self.map);
        let fb = map.entry(function.to_string()).or_default();
        match fb.stage {
            BreakerState::Closed => BreakerRoute::Pass,
            // A probe is already in flight; don't stampede it.
            BreakerState::HalfOpen => BreakerRoute::Reject,
            BreakerState::Open => {
                fb.open_arrivals += 1;
                if fb.open_arrivals % self.cfg.probe_interval == 0 {
                    fb.stage = BreakerState::HalfOpen;
                    BreakerRoute::Probe
                } else {
                    BreakerRoute::Reject
                }
            }
        }
    }

    /// Fold one attempt's outcome into the state machine; returns the
    /// lifecycle transition (if any) so the caller can count it.
    fn observe(
        &self,
        function: &str,
        was_probe: bool,
        outcome: AttemptOutcome,
    ) -> Option<BreakerEvent> {
        let mut map = lock_unpoisoned(&self.map);
        let fb = map.entry(function.to_string()).or_default();
        match (outcome, was_probe) {
            (AttemptOutcome::Good, true) => {
                fb.probe_streak += 1;
                if fb.probe_streak >= self.cfg.probe_successes {
                    *fb = FnBreaker::default();
                    return Some(BreakerEvent::Reclosed);
                }
                // Streak continues at the next probe slot.
                fb.stage = BreakerState::Open;
                None
            }
            (AttemptOutcome::Good, false) => {
                if fb.stage == BreakerState::Closed {
                    fb.failures = 0;
                }
                None
            }
            (AttemptOutcome::Faulty, true) => {
                fb.probe_streak = 0;
                fb.stage = BreakerState::Open;
                None
            }
            (AttemptOutcome::Faulty, false) => {
                if fb.stage == BreakerState::Closed {
                    fb.failures += 1;
                    if fb.failures >= self.cfg.failure_threshold {
                        fb.stage = BreakerState::Open;
                        fb.open_arrivals = 0;
                        fb.probe_streak = 0;
                        return Some(BreakerEvent::Opened);
                    }
                }
                None
            }
            // A terminal error during a probe neither confirms recovery
            // nor indicts the function: give the slot back.
            (AttemptOutcome::Neutral, true) => {
                fb.stage = BreakerState::Open;
                None
            }
            (AttemptOutcome::Neutral, false) => None,
        }
    }

    fn state(&self, function: &str) -> BreakerState {
        lock_unpoisoned(&self.map)
            .get(function)
            .map(|fb| fb.stage)
            .unwrap_or(BreakerState::Closed)
    }
}

// ---------------------------------------------------------------------
// Hedge audits.
// ---------------------------------------------------------------------

#[derive(Debug)]
struct PendingAudit {
    function: String,
    winner: Vec<f64>,
    winner_degraded: bool,
    loser: Receiver<EvalResponse>,
}

#[derive(Debug, PartialEq, Eq)]
enum AuditOutcome {
    Verified,
    Mismatched,
    /// The loser errored or was served at a different fidelity
    /// (degraded vs full): nothing comparable, silently resolved.
    Skipped,
}

// ---------------------------------------------------------------------
// The client.
// ---------------------------------------------------------------------

/// Caller-side recovery ladder over an [`EvalServer`]; see the module
/// docs for the four stages. Cheap to construct; borrow one per server.
/// All methods take `&self` and the client is `Sync`, so one instance
/// can serve many threads.
#[derive(Debug)]
pub struct ResilientClient<'a> {
    server: &'a EvalServer,
    cfg: ClientConfig,
    metrics: Arc<Metrics>,
    budget: Option<RetryBudget>,
    breaker: Option<Breaker>,
    jitter: Mutex<Pcg>,
    attempt_latency: Mutex<LatencyHistogram>,
    audits: Mutex<Vec<PendingAudit>>,
}

impl<'a> ResilientClient<'a> {
    /// Wrap `server` with the given recovery ladder. Panics (via
    /// `assert!`) on nonsensical configs: zero breaker cadences,
    /// negative budget rates, a hedge quantile outside `[0, 1]`, or
    /// `backoff_base > backoff_max`.
    pub fn new(server: &'a EvalServer, cfg: ClientConfig) -> Self {
        if let Some(r) = &cfg.retry {
            assert!(r.backoff_base <= r.backoff_max, "backoff_base must be <= backoff_max");
        }
        if let Some(b) = &cfg.budget {
            assert!(
                b.initial >= 0.0 && b.max >= b.initial && b.earn_per_success >= 0.0,
                "budget must satisfy 0 <= initial <= max, earn >= 0"
            );
        }
        if let Some(br) = &cfg.breaker {
            assert!(
                br.failure_threshold >= 1 && br.probe_interval >= 1 && br.probe_successes >= 1,
                "breaker cadences must be >= 1"
            );
        }
        if let Some(h) = &cfg.hedge {
            if let HedgeDelay::Quantile { q, .. } = h.delay {
                assert!((0.0..=1.0).contains(&q), "hedge quantile must be in [0, 1]");
            }
        }
        let metrics = server.metrics_handle();
        let budget = cfg.budget.as_ref().map(RetryBudget::new);
        let breaker = cfg.breaker.map(Breaker::new);
        let jitter_seed = cfg.retry.as_ref().map(|r| r.jitter_seed).unwrap_or(0);
        Self {
            server,
            cfg,
            metrics,
            budget,
            breaker,
            jitter: Mutex::new(Pcg::new(jitter_seed)),
            attempt_latency: Mutex::new(LatencyHistogram::new()),
            audits: Mutex::new(Vec::new()),
        }
    }

    /// Evaluate with the configured overall deadline
    /// ([`ClientConfig::total_timeout`], else the server's
    /// `sync_timeout`). Failures arrive as a typed [`EvalError`] on the
    /// response, exactly like the bare server path.
    pub fn eval(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
    ) -> EvalResponse {
        let timeout = self
            .cfg
            .total_timeout
            .unwrap_or_else(|| self.server.admission().config().sync_timeout);
        self.eval_with_timeout(function, points, engine, stream_len, timeout)
    }

    /// Evaluate with an explicit overall deadline; retries, backoff and
    /// hedges are all carved from this single window. The response's
    /// typed [`EvalError`] (if any) is the *last attempt's* error — or
    /// [`EvalError::CircuitOpen`] when the breaker refused without an
    /// attempt, or [`EvalError::Timeout`] when the window closed.
    pub fn eval_with_timeout(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
        timeout: Duration,
    ) -> EvalResponse {
        self.sweep_audits();
        if self.is_passthrough() {
            // Acceptance contract: default config == calling the server
            // directly, byte for byte.
            return self
                .server
                .eval_sync_with_timeout(function, points, engine, stream_len, timeout);
        }
        let overall = Instant::now() + timeout;
        let max_retries = self.cfg.retry.as_ref().map(|r| r.max_retries).unwrap_or(0);
        let mut attempt: u32 = 0;
        loop {
            let was_probe = match self.breaker.as_ref().map(|b| b.route(function)) {
                Some(BreakerRoute::Reject) => {
                    self.metrics.record_breaker_rejection();
                    return EvalResponse::from_error(EvalError::CircuitOpen);
                }
                Some(BreakerRoute::Probe) => true,
                Some(BreakerRoute::Pass) | None => false,
            };
            let now = Instant::now();
            if now >= overall {
                self.metrics.record_client_timeout();
                return EvalResponse::from_error(EvalError::Timeout);
            }
            let attempt_deadline = match self.cfg.retry.as_ref().and_then(|r| r.attempt_timeout)
            {
                Some(t) => overall.min(now + t),
                None => overall,
            };
            let started = now;
            let resp = self.run_attempt(function, &points, engine, stream_len, attempt_deadline);
            let Some(err) = resp.error.clone() else {
                if let Some(b) = &self.budget {
                    b.earn();
                }
                if let Some(br) = &self.breaker {
                    if br.observe(function, was_probe, AttemptOutcome::Good)
                        == Some(BreakerEvent::Reclosed)
                    {
                        self.metrics.record_breaker_reclose();
                    }
                }
                if self.cfg.hedge.is_some() {
                    let ns = started.elapsed().as_nanos().min(u64::MAX as u128) as u64;
                    lock_unpoisoned(&self.attempt_latency).record(ns);
                }
                return resp;
            };
            let retryable = err.is_retryable();
            if let Some(br) = &self.breaker {
                let outcome =
                    if retryable { AttemptOutcome::Faulty } else { AttemptOutcome::Neutral };
                if br.observe(function, was_probe, outcome) == Some(BreakerEvent::Opened) {
                    self.metrics.record_breaker_open();
                }
            }
            if !retryable || attempt >= max_retries {
                return resp;
            }
            if let Some(b) = &self.budget {
                if !b.try_spend() {
                    self.metrics.record_retry_budget_exhausted();
                    return resp;
                }
            }
            if let Some(r) = &self.cfg.retry {
                let backoff = self.backoff_for(r, attempt);
                // Carve check: a retry that cannot start (let alone
                // finish) before the overall deadline is pointless.
                if Instant::now() + backoff >= overall {
                    return resp;
                }
                if !backoff.is_zero() {
                    std::thread::sleep(backoff);
                }
            }
            self.metrics.record_client_retry();
            attempt += 1;
        }
    }

    /// Current breaker state for `function` (`Closed` when no breaker
    /// is configured or the function has never been seen).
    pub fn breaker_state(&self, function: &str) -> BreakerState {
        self.breaker.as_ref().map(|b| b.state(function)).unwrap_or(BreakerState::Closed)
    }

    /// Remaining retry-budget tokens (`None` when no budget is
    /// configured — i.e. unlimited).
    pub fn retry_budget_tokens(&self) -> Option<f64> {
        self.budget.as_ref().map(|b| b.tokens())
    }

    /// Resolve parked hedge audits, waiting up to `wait` total for
    /// losing replies still in flight. Verified/mismatched counts are
    /// also mirrored into the metrics sink as they resolve; losers
    /// still pending at the end of the wait are dropped and counted
    /// `unresolved`. Tests call this before asserting the bit-identity
    /// invariant; it is safe to call at any time.
    pub fn drain_hedge_audits(&self, wait: Duration) -> HedgeAudit {
        let deadline = Instant::now() + wait;
        let pending: Vec<PendingAudit> =
            lock_unpoisoned(&self.audits).drain(..).collect();
        let mut out = HedgeAudit::default();
        for a in pending {
            let left = deadline.saturating_duration_since(Instant::now());
            match a.loser.recv_timeout(left) {
                Ok(resp) => match self.resolve_audit(&a, &resp) {
                    AuditOutcome::Verified => out.verified += 1,
                    AuditOutcome::Mismatched => out.mismatched += 1,
                    AuditOutcome::Skipped => {}
                },
                Err(RecvTimeoutError::Timeout) => out.unresolved += 1,
                // Loser dropped without answering (shutdown race): the
                // answer-exactly-once contract was kept by the winner.
                Err(RecvTimeoutError::Disconnected) => {}
            }
        }
        out
    }

    fn is_passthrough(&self) -> bool {
        self.cfg.retry.is_none()
            && self.cfg.budget.is_none()
            && self.cfg.hedge.is_none()
            && self.cfg.breaker.is_none()
    }

    /// Equal-jitter exponential backoff before retry number `attempt`.
    fn backoff_for(&self, r: &RetryPolicy, attempt: u32) -> Duration {
        let exp = r.backoff_base.saturating_mul(2u32.saturating_pow(attempt));
        let full = exp.min(r.backoff_max);
        let half = full / 2;
        lock_unpoisoned(&self.jitter).range_duration(half, full)
    }

    /// Latency threshold after which this attempt hedges.
    fn hedge_delay(&self, cfg: &HedgeConfig) -> Duration {
        match cfg.delay {
            HedgeDelay::Fixed(d) => d,
            HedgeDelay::Quantile { q, min_samples, floor, fallback } => {
                let hist = lock_unpoisoned(&self.attempt_latency);
                if hist.count() >= min_samples {
                    floor.max(Duration::from_nanos(hist.quantile_ns(q)))
                } else {
                    fallback
                }
            }
        }
    }

    /// One attempt: submit, wait; if a hedge is configured and the
    /// primary outlives the hedge threshold, launch a second identical
    /// request and take the first answer, parking the loser for a
    /// bit-identity audit.
    fn run_attempt(
        &self,
        function: &str,
        points: &[Vec<f64>],
        engine: Engine,
        stream_len: usize,
        deadline: Instant,
    ) -> EvalResponse {
        let (tx, rx) = channel();
        let req = EvalRequest::new(function, points.to_vec(), engine, stream_len, tx)
            .with_deadline(deadline);
        if let Err(e) = self.server.submit(req) {
            return EvalResponse::from_error(e);
        }
        let hedge_at = self.cfg.hedge.as_ref().map(|h| self.hedge_delay(h));
        let until_deadline = deadline.saturating_duration_since(Instant::now());
        let first_wait = match hedge_at {
            Some(d) => d.min(until_deadline),
            None => until_deadline,
        };
        match rx.recv_timeout(first_wait) {
            // Primary answered before the hedge threshold: done. A
            // *failed* primary is not hedged either — the retry rungs
            // own failure recovery; hedging only targets latency.
            Ok(resp) => return resp,
            Err(RecvTimeoutError::Disconnected) => {
                return EvalResponse::from_error(EvalError::Shutdown)
            }
            Err(RecvTimeoutError::Timeout) => {
                if hedge_at.is_none() || Instant::now() >= deadline {
                    self.metrics.record_client_timeout();
                    return EvalResponse::from_error(EvalError::Timeout);
                }
            }
        }
        // The primary is slow: launch the hedge on its own channel.
        let (htx, hrx) = channel();
        let hedge_req = EvalRequest::new(function, points.to_vec(), engine, stream_len, htx)
            .with_deadline(deadline);
        match self.server.submit(hedge_req) {
            Ok(()) => self.metrics.record_client_hedge(),
            // Hedge refused (queue full, shedding, …): keep waiting on
            // the primary alone — hedging is best-effort by design.
            Err(_) => {
                let left = deadline.saturating_duration_since(Instant::now());
                return match rx.recv_timeout(left) {
                    Ok(resp) => resp,
                    Err(RecvTimeoutError::Timeout) => {
                        self.metrics.record_client_timeout();
                        EvalResponse::from_error(EvalError::Timeout)
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        EvalResponse::from_error(EvalError::Shutdown)
                    }
                };
            }
        }
        self.race_hedge(function, rx, hrx, deadline)
    }

    /// Race the primary and hedge receivers to the first *successful*
    /// answer; the still-pending loser is parked for a bit-identity
    /// audit. If one arm fails, keep the other until the deadline and
    /// surface the first failure only if both fail.
    fn race_hedge(
        &self,
        function: &str,
        primary: Receiver<EvalResponse>,
        hedge: Receiver<EvalResponse>,
        deadline: Instant,
    ) -> EvalResponse {
        let mut primary = Some(primary);
        let mut hedge = Some(hedge);
        let mut first_err: Option<EvalResponse> = None;
        loop {
            if let Some(rx) = primary.as_ref() {
                match rx.try_recv() {
                    Ok(resp) if resp.is_ok() => {
                        if let Some(loser) = hedge.take() {
                            self.park_audit(function, &resp, loser);
                        }
                        return resp;
                    }
                    Ok(resp) => {
                        primary = None;
                        first_err.get_or_insert(resp);
                    }
                    Err(TryRecvError::Empty) => {}
                    Err(TryRecvError::Disconnected) => {
                        primary = None;
                        first_err
                            .get_or_insert(EvalResponse::from_error(EvalError::Shutdown));
                    }
                }
            }
            if let Some(rx) = hedge.as_ref() {
                match rx.try_recv() {
                    Ok(resp) if resp.is_ok() => {
                        self.metrics.record_client_hedge_win();
                        if let Some(loser) = primary.take() {
                            self.park_audit(function, &resp, loser);
                        }
                        return resp;
                    }
                    Ok(resp) => {
                        hedge = None;
                        first_err.get_or_insert(resp);
                    }
                    Err(TryRecvError::Empty) => {}
                    Err(TryRecvError::Disconnected) => {
                        hedge = None;
                        first_err
                            .get_or_insert(EvalResponse::from_error(EvalError::Shutdown));
                    }
                }
            }
            if primary.is_none() && hedge.is_none() {
                // Both arms failed: surface the first typed error.
                return first_err
                    .unwrap_or_else(|| EvalResponse::from_error(EvalError::Shutdown));
            }
            if Instant::now() >= deadline {
                self.metrics.record_client_timeout();
                return EvalResponse::from_error(EvalError::Timeout);
            }
            std::thread::sleep(HEDGE_POLL);
        }
    }

    /// Park a hedge loser for later bit-identity verification; capped
    /// at [`MAX_PENDING_AUDITS`] (oldest dropped).
    fn park_audit(
        &self,
        function: &str,
        winner: &EvalResponse,
        loser: Receiver<EvalResponse>,
    ) {
        let mut audits = lock_unpoisoned(&self.audits);
        if audits.len() >= MAX_PENDING_AUDITS {
            audits.remove(0);
        }
        audits.push(PendingAudit {
            function: function.to_string(),
            winner: winner.outputs.clone(),
            winner_degraded: winner.degraded,
            loser,
        });
    }

    /// Non-blocking pass over parked audits at the top of every eval.
    fn sweep_audits(&self) {
        let mut audits = lock_unpoisoned(&self.audits);
        let mut i = 0;
        while i < audits.len() {
            match audits[i].loser.try_recv() {
                Ok(resp) => {
                    let a = audits.remove(i);
                    self.resolve_audit(&a, &resp);
                }
                Err(TryRecvError::Empty) => i += 1,
                Err(TryRecvError::Disconnected) => {
                    audits.remove(i);
                }
            }
        }
    }

    /// Compare a completed loser against its winner. Served outputs are
    /// deterministic per request (seed = `DEFAULT_STREAM_SEED ^ i`), so
    /// same-fidelity replays must match to the bit.
    fn resolve_audit(&self, audit: &PendingAudit, loser: &EvalResponse) -> AuditOutcome {
        if !loser.is_ok() || loser.degraded != audit.winner_degraded {
            // Errored loser, or the two attempts were served at
            // different fidelities (one degraded to analytic): outputs
            // are legitimately incomparable.
            return AuditOutcome::Skipped;
        }
        let identical = loser.outputs.len() == audit.winner.len()
            && loser
                .outputs
                .iter()
                .zip(&audit.winner)
                .all(|(a, b)| a.to_bits() == b.to_bits());
        if identical {
            self.metrics.record_client_hedge_verified();
            AuditOutcome::Verified
        } else {
            self.metrics.record_client_hedge_mismatch();
            debug_assert!(
                false,
                "hedge loser diverged from winner for `{}` — served-output determinism broke",
                audit.function
            );
            AuditOutcome::Mismatched
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_spends_and_earns_with_fixed_point_precision() {
        let b = RetryBudget::new(&BudgetConfig { initial: 2.0, max: 3.0, earn_per_success: 0.1 });
        assert!((b.tokens() - 2.0).abs() < 1e-9);
        assert!(b.try_spend());
        assert!(b.try_spend());
        assert!(!b.try_spend(), "2 tokens buy exactly 2 retries");
        assert!((b.tokens() - 0.0).abs() < 1e-9);
        // 10 successes earn exactly one token back (0.1 each, no float drift).
        for _ in 0..10 {
            b.earn();
        }
        assert!((b.tokens() - 1.0).abs() < 1e-9);
        assert!(b.try_spend());
        assert!(!b.try_spend());
        // Earning clamps at capacity.
        for _ in 0..1000 {
            b.earn();
        }
        assert!((b.tokens() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn zero_budget_never_allows_a_retry() {
        let b = RetryBudget::new(&BudgetConfig { initial: 0.0, max: 5.0, earn_per_success: 0.0 });
        assert!(!b.try_spend());
        b.earn(); // earn rate 0: still empty
        assert!(!b.try_spend());
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let br = Breaker::new(BreakerConfig {
            failure_threshold: 3,
            probe_interval: 2,
            probe_successes: 2,
        });
        let f = "fn";
        // Closed: passes; failures accumulate.
        for i in 0..3 {
            assert!(matches!(br.route(f), BreakerRoute::Pass));
            let ev = br.observe(f, false, AttemptOutcome::Faulty);
            if i < 2 {
                assert_eq!(ev, None);
                assert_eq!(br.state(f), BreakerState::Closed);
            } else {
                assert_eq!(ev, Some(BreakerEvent::Opened));
            }
        }
        assert_eq!(br.state(f), BreakerState::Open);
        // Open: arrival 1 rejected, arrival 2 is the probe.
        assert!(matches!(br.route(f), BreakerRoute::Reject));
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.state(f), BreakerState::HalfOpen);
        // While the probe is in flight, everyone else is rejected.
        assert!(matches!(br.route(f), BreakerRoute::Reject));
        // First good probe: streak 1 of 2 — back to Open, wait for next slot.
        assert_eq!(br.observe(f, true, AttemptOutcome::Good), None);
        assert_eq!(br.state(f), BreakerState::Open);
        assert!(matches!(br.route(f), BreakerRoute::Reject));
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        // Second good probe recloses.
        assert_eq!(br.observe(f, true, AttemptOutcome::Good), Some(BreakerEvent::Reclosed));
        assert_eq!(br.state(f), BreakerState::Closed);
        // A success after reclose keeps it closed and resets failures.
        assert!(matches!(br.route(f), BreakerRoute::Pass));
        assert_eq!(br.observe(f, false, AttemptOutcome::Good), None);
        assert_eq!(br.state(f), BreakerState::Closed);
    }

    #[test]
    fn failed_probe_reopens_and_resets_the_streak() {
        let br = Breaker::new(BreakerConfig {
            failure_threshold: 1,
            probe_interval: 1,
            probe_successes: 2,
        });
        let f = "g";
        assert!(matches!(br.route(f), BreakerRoute::Pass));
        assert_eq!(br.observe(f, false, AttemptOutcome::Faulty), Some(BreakerEvent::Opened));
        // probe_interval 1: every Open arrival probes.
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.observe(f, true, AttemptOutcome::Good), None); // streak 1/2
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.observe(f, true, AttemptOutcome::Faulty), None); // streak reset
        assert_eq!(br.state(f), BreakerState::Open);
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.observe(f, true, AttemptOutcome::Good), None); // streak 1/2 again
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.observe(f, true, AttemptOutcome::Good), Some(BreakerEvent::Reclosed));
    }

    #[test]
    fn terminal_errors_are_neutral_to_the_breaker() {
        let br = Breaker::new(BreakerConfig {
            failure_threshold: 1,
            probe_interval: 1,
            probe_successes: 1,
        });
        let f = "h";
        // Terminal failures while Closed never trip it.
        for _ in 0..10 {
            assert!(matches!(br.route(f), BreakerRoute::Pass));
            assert_eq!(br.observe(f, false, AttemptOutcome::Neutral), None);
        }
        assert_eq!(br.state(f), BreakerState::Closed);
        // Trip it, then a terminal error on the probe gives the slot back
        // without reclosing.
        br.observe(f, false, AttemptOutcome::Faulty);
        assert!(matches!(br.route(f), BreakerRoute::Probe));
        assert_eq!(br.observe(f, true, AttemptOutcome::Neutral), None);
        assert_eq!(br.state(f), BreakerState::Open);
    }

    #[test]
    fn breakers_are_keyed_per_function() {
        let br = Breaker::new(BreakerConfig {
            failure_threshold: 1,
            probe_interval: 1,
            probe_successes: 1,
        });
        br.observe("a", false, AttemptOutcome::Faulty);
        assert_eq!(br.state("a"), BreakerState::Open);
        assert_eq!(br.state("b"), BreakerState::Closed);
        assert!(matches!(br.route("b"), BreakerRoute::Pass));
    }

    #[test]
    fn jitter_schedule_is_deterministic_and_equal_jitter_bounded() {
        // Replays of the same seed produce the same backoff schedule,
        // and every draw lands in [full/2, full) with full = min(base·2^k, max).
        let base = Duration::from_millis(4);
        let max = Duration::from_millis(20);
        let draws = |seed: u64| -> Vec<Duration> {
            let mut rng = Pcg::new(seed);
            (0..6)
                .map(|k| {
                    let full = base.saturating_mul(2u32.saturating_pow(k)).min(max);
                    rng.range_duration(full / 2, full)
                })
                .collect()
        };
        let a = draws(0xB0FF);
        let b = draws(0xB0FF);
        assert_eq!(a, b, "same seed, same schedule");
        for (k, d) in a.iter().enumerate() {
            let full = base.saturating_mul(2u32.saturating_pow(k as u32)).min(max);
            assert!(*d >= full / 2 && *d < full.max(full / 2 + Duration::from_nanos(1)),
                "draw {k} = {d:?} outside [{:?}, {:?})", full / 2, full);
        }
        // The clamp binds: k >= 3 draws stay under max.
        assert!(a[5] < max);
    }

    #[test]
    fn default_config_is_passthrough() {
        let cfg = ClientConfig::default();
        assert!(cfg.retry.is_none());
        assert!(cfg.budget.is_none());
        assert!(cfg.hedge.is_none());
        assert!(cfg.breaker.is_none());
        assert!(cfg.total_timeout.is_none());
    }

    #[test]
    fn deadline_carving_math() {
        // attempt_deadline = min(now + attempt_timeout, overall): the
        // last sliver of the window produces a shorter attempt, never a
        // longer one.
        let now = Instant::now();
        let overall = now + Duration::from_millis(100);
        let carve = |now: Instant, attempt_timeout: Option<Duration>| match attempt_timeout {
            Some(t) => overall.min(now + t),
            None => overall,
        };
        assert_eq!(carve(now, None), overall);
        assert_eq!(carve(now, Some(Duration::from_millis(30))), now + Duration::from_millis(30));
        let late = now + Duration::from_millis(90);
        assert_eq!(carve(late, Some(Duration::from_millis(30))), overall);
    }
}

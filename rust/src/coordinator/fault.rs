//! Fault injection for the serving stack.
//!
//! A [`FaultInjector`] is shared between the tests/bench driving a server
//! and the workers executing batches; the chaos suite uses it to inject
//! the failure modes the fault-tolerant core must absorb:
//!
//! - **panic-on-Nth-batch** — a worker panics mid-batch (exercises
//!   `catch_unwind` isolation, typed `WorkerPanic` replies, and the
//!   supervisor's respawn path);
//! - **artificial slowness** — every batch stalls for a configured
//!   duration (exercises deadline expiry, client timeouts, queue
//!   buildup, and load shedding);
//! - **output drift** — a constant bias added to every `BitLevel` batch
//!   output (exercises the drift sentinel's canary cross-checks and the
//!   quarantine lifecycle: the bias is healable, so clearing it lets
//!   recovery probes succeed);
//! - **NaN poisoning** — every `BitLevel` output becomes NaN (exercises
//!   the worker's non-finite output guard: clients must see a typed
//!   engine error, never a poisoned float);
//! - reply-receiver drops are driven from the client side (drop the
//!   receiver before the reply arrives) — no hook needed here.
//!
//! The default injector is inert: two relaxed atomic loads per *batch*
//! (not per cycle), so production builds keep it compiled in and the
//! chaos suite runs against the exact shipping code path.

use crate::util::sync::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

/// Shared, thread-safe fault plan. All hooks are disabled by default.
#[derive(Debug)]
pub struct FaultInjector {
    /// 1-based batch ordinal to panic on (0 = disabled). One-shot: the
    /// trigger clears itself so the respawned worker recovers.
    panic_on_batch: AtomicU64,
    /// Batches executed so far (across all workers).
    batches_seen: AtomicU64,
    /// Artificial stall before each batch, in nanoseconds (0 = none).
    slow_batch_ns: AtomicU64,
    /// Constant bias added to every BitLevel batch output, stored as
    /// `f64::to_bits` (0 = the bit pattern of +0.0 = disabled).
    output_bias: AtomicU64,
    /// Replace every BitLevel output with NaN.
    poison_nan: AtomicBool,
}

impl Default for FaultInjector {
    // Manual (not derived): the loom facade's atomics do not promise
    // `Default` impls, and construction must work under both cfgs.
    fn default() -> Self {
        Self {
            panic_on_batch: AtomicU64::new(0),
            batches_seen: AtomicU64::new(0),
            slow_batch_ns: AtomicU64::new(0),
            output_bias: AtomicU64::new(0),
            poison_nan: AtomicBool::new(false),
        }
    }
}

impl FaultInjector {
    /// A fully inert injector (every hook disabled).
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm a one-shot panic on the `n`th batch executed from now
    /// (1 = the very next batch). Resets the batch counter.
    pub fn arm_panic_on_batch(&self, n: u64) {
        assert!(n > 0, "batch ordinals are 1-based");
        self.batches_seen.store(0, Ordering::SeqCst);
        self.panic_on_batch.store(n, Ordering::SeqCst);
    }

    /// Stall every subsequent batch by `d` (Duration::ZERO disables).
    pub fn set_slow_batch(&self, d: Duration) {
        self.slow_batch_ns.store(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Bias every subsequent BitLevel batch output by `bias` (0.0
    /// disables). Models a drifting engine — stuck counter bits,
    /// mis-calibrated decode — in a *healable* way: clearing the bias
    /// lets the sentinel's recovery probes succeed.
    pub fn set_output_bias(&self, bias: f64) {
        self.output_bias.store(bias.to_bits(), Ordering::SeqCst);
    }

    /// Replace every subsequent BitLevel output with NaN (off by
    /// default). Drives the worker's non-finite output guard.
    pub fn set_poison_nan(&self, on: bool) {
        self.poison_nan.store(on, Ordering::SeqCst);
    }

    /// Worker-side hook applied to a BitLevel batch's outputs after the
    /// engine runs and before results scatter to clients. Inert by
    /// default: one relaxed bool + one relaxed u64 load per batch.
    pub fn corrupt_outputs(&self, outputs: &mut [f64]) {
        if self.poison_nan.load(Ordering::Relaxed) {
            for y in outputs.iter_mut() {
                *y = f64::NAN;
            }
            return;
        }
        let bits = self.output_bias.load(Ordering::Relaxed);
        if bits != 0 {
            let bias = f64::from_bits(bits);
            for y in outputs.iter_mut() {
                *y += bias;
            }
        }
    }

    /// Worker-side hook, called once per batch before execution. May
    /// panic (isolated by the worker's `catch_unwind`) or sleep.
    pub fn before_batch(&self) {
        let seen = self.batches_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let target = self.panic_on_batch.load(Ordering::SeqCst);
        if target != 0 && seen == target {
            self.panic_on_batch.store(0, Ordering::SeqCst);
            // xtask: allow(no-panic) justification: panicking is this hook's entire
            // purpose — it injects the worker-panic fault the chaos suite isolates.
            panic!("fault injection: worker panic on batch {seen}");
        }
        let ns = self.slow_batch_ns.load(Ordering::Relaxed);
        if ns > 0 {
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_by_default() {
        let f = FaultInjector::new();
        for _ in 0..100 {
            f.before_batch(); // no panic, no stall
        }
    }

    #[test]
    fn panic_on_nth_batch_is_one_shot() {
        let f = FaultInjector::new();
        f.arm_panic_on_batch(3);
        f.before_batch();
        f.before_batch();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_batch()));
        assert!(err.is_err(), "third batch must panic");
        // Trigger cleared: later batches run clean.
        f.before_batch();
        f.before_batch();
    }

    #[test]
    fn output_corruption_hooks() {
        let f = FaultInjector::new();
        let mut out = [0.25, 0.5];
        // Inert by default: outputs pass through untouched.
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.25, 0.5]);
        // Bias shifts every output; clearing it restores pass-through.
        f.set_output_bias(0.5);
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.75, 1.0]);
        f.set_output_bias(0.0);
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.75, 1.0]);
        // NaN poisoning wins over bias and is reversible.
        f.set_output_bias(0.5);
        f.set_poison_nan(true);
        f.corrupt_outputs(&mut out);
        assert!(out.iter().all(|y| y.is_nan()));
        f.set_poison_nan(false);
        f.set_output_bias(0.0);
        let mut out = [0.1];
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.1]);
    }

    #[test]
    fn slow_batch_stalls() {
        let f = FaultInjector::new();
        f.set_slow_batch(Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        f.before_batch();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        f.set_slow_batch(Duration::ZERO);
        f.before_batch();
    }
}

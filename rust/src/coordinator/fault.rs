//! Fault injection for the serving stack.
//!
//! A [`FaultInjector`] is shared between the tests/bench driving a server
//! and the workers executing batches; the chaos suite uses it to inject
//! the failure modes the fault-tolerant core must absorb:
//!
//! - **panic-on-Nth-batch** — a worker panics mid-batch (exercises
//!   `catch_unwind` isolation, typed `WorkerPanic` replies, and the
//!   supervisor's respawn path);
//! - **artificial slowness** — every batch stalls for a configured
//!   duration (exercises deadline expiry, client timeouts, queue
//!   buildup, and load shedding);
//! - reply-receiver drops are driven from the client side (drop the
//!   receiver before the reply arrives) — no hook needed here.
//!
//! The default injector is inert: two relaxed atomic loads per *batch*
//! (not per cycle), so production builds keep it compiled in and the
//! chaos suite runs against the exact shipping code path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Shared, thread-safe fault plan. All hooks are disabled by default.
#[derive(Debug, Default)]
pub struct FaultInjector {
    /// 1-based batch ordinal to panic on (0 = disabled). One-shot: the
    /// trigger clears itself so the respawned worker recovers.
    panic_on_batch: AtomicU64,
    /// Batches executed so far (across all workers).
    batches_seen: AtomicU64,
    /// Artificial stall before each batch, in nanoseconds (0 = none).
    slow_batch_ns: AtomicU64,
}

impl FaultInjector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm a one-shot panic on the `n`th batch executed from now
    /// (1 = the very next batch). Resets the batch counter.
    pub fn arm_panic_on_batch(&self, n: u64) {
        assert!(n > 0, "batch ordinals are 1-based");
        self.batches_seen.store(0, Ordering::SeqCst);
        self.panic_on_batch.store(n, Ordering::SeqCst);
    }

    /// Stall every subsequent batch by `d` (Duration::ZERO disables).
    pub fn set_slow_batch(&self, d: Duration) {
        self.slow_batch_ns.store(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Worker-side hook, called once per batch before execution. May
    /// panic (isolated by the worker's `catch_unwind`) or sleep.
    pub fn before_batch(&self) {
        let seen = self.batches_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let target = self.panic_on_batch.load(Ordering::SeqCst);
        if target != 0 && seen == target {
            self.panic_on_batch.store(0, Ordering::SeqCst);
            panic!("fault injection: worker panic on batch {seen}");
        }
        let ns = self.slow_batch_ns.load(Ordering::Relaxed);
        if ns > 0 {
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_by_default() {
        let f = FaultInjector::new();
        for _ in 0..100 {
            f.before_batch(); // no panic, no stall
        }
    }

    #[test]
    fn panic_on_nth_batch_is_one_shot() {
        let f = FaultInjector::new();
        f.arm_panic_on_batch(3);
        f.before_batch();
        f.before_batch();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_batch()));
        assert!(err.is_err(), "third batch must panic");
        // Trigger cleared: later batches run clean.
        f.before_batch();
        f.before_batch();
    }

    #[test]
    fn slow_batch_stalls() {
        let f = FaultInjector::new();
        f.set_slow_batch(Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        f.before_batch();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        f.set_slow_batch(Duration::ZERO);
        f.before_batch();
    }
}

//! Fault injection for the serving stack.
//!
//! A [`FaultInjector`] is shared between the tests/bench driving a server
//! and the workers executing batches; the chaos suite uses it to inject
//! the failure modes the fault-tolerant core must absorb:
//!
//! - **panic-on-Nth-batch** — a worker panics mid-batch (exercises
//!   `catch_unwind` isolation, typed `WorkerPanic` replies, and the
//!   supervisor's respawn path);
//! - **artificial slowness** — every batch stalls for a configured
//!   duration (exercises deadline expiry, client timeouts, queue
//!   buildup, and load shedding), or a *single* numbered batch stalls
//!   once (exercises hedged requests: the primary attempt wedges, the
//!   hedge lands on a healthy worker);
//! - **bounded flaky windows** — for the next `batches` batches, each
//!   batch independently panics or stalls with seeded Bernoulli
//!   probabilities ([`FlakyWindow`]); the draws come from a
//!   [`Pcg`](crate::util::prng::Pcg) stream, so a fixed seed replays the
//!   exact fault schedule (this is what the resilient client's
//!   retry/budget chaos tests drive);
//! - **output drift** — a constant bias added to every `BitLevel` batch
//!   output (exercises the drift sentinel's canary cross-checks and the
//!   quarantine lifecycle: the bias is healable, so clearing it lets
//!   recovery probes succeed);
//! - **NaN poisoning** — every `BitLevel` output becomes NaN (exercises
//!   the worker's non-finite output guard: clients must see a typed
//!   engine error, never a poisoned float);
//! - reply-receiver drops are driven from the client side (drop the
//!   receiver before the reply arrives) — no hook needed here.
//!
//! The default injector is inert: two relaxed atomic loads per *batch*
//! (not per cycle), so production builds keep it compiled in and the
//! chaos suite runs against the exact shipping code path.

use crate::util::prng::Pcg;
use crate::util::sync::{lock_unpoisoned, AtomicBool, AtomicU64, Mutex, Ordering};
use std::time::Duration;

/// A bounded window of seeded intermittent faults: for the next
/// `batches` batches, each batch independently panics with probability
/// `panic_prob`, else stalls for `stall` with probability `stall_prob`.
/// Draws come from a [`Pcg`] stream seeded with `seed`, so the exact
/// fault schedule replays deterministically (the property the resilient
/// client's retry chaos tests stand on). After the window the injector
/// returns to inert on its own.
#[derive(Clone, Copy, Debug)]
pub struct FlakyWindow {
    /// Seed for the per-batch Bernoulli draws.
    pub seed: u64,
    /// Probability that a batch in the window panics before execution.
    pub panic_prob: f64,
    /// Probability that a (non-panicking) batch stalls for `stall`.
    pub stall_prob: f64,
    /// Stall applied to stalled batches.
    pub stall: Duration,
    /// Number of batches the window covers.
    pub batches: u64,
}

/// Live state of an armed [`FlakyWindow`].
#[derive(Debug)]
struct FlakyState {
    rng: Pcg,
    window: FlakyWindow,
    remaining: u64,
}

/// What a flaky draw decided for one batch (resolved under the lock,
/// acted on after it is released so a panic cannot poison the state).
enum FlakyAction {
    None,
    Panic(u64),
    Stall(Duration),
}

/// Shared, thread-safe fault plan. All hooks are disabled by default.
#[derive(Debug)]
pub struct FaultInjector {
    /// 1-based batch ordinal to panic on (0 = disabled). One-shot: the
    /// trigger clears itself so the respawned worker recovers.
    panic_on_batch: AtomicU64,
    /// 1-based batch ordinal to stall once (0 = disabled, one-shot).
    stall_on_batch: AtomicU64,
    /// Duration of the one-shot stall, in nanoseconds.
    stall_once_ns: AtomicU64,
    /// Batches executed so far (across all workers).
    batches_seen: AtomicU64,
    /// Artificial stall before each batch, in nanoseconds (0 = none).
    slow_batch_ns: AtomicU64,
    /// Constant bias added to every BitLevel batch output, stored as
    /// `f64::to_bits` (0 = the bit pattern of +0.0 = disabled).
    output_bias: AtomicU64,
    /// Replace every BitLevel output with NaN.
    poison_nan: AtomicBool,
    /// Fast gate for the flaky window: the per-batch cost of a disarmed
    /// injector stays a handful of relaxed loads, never a lock.
    flaky_armed: AtomicBool,
    /// Armed flaky window, if any (locked only while armed).
    flaky: Mutex<Option<FlakyState>>,
}

impl Default for FaultInjector {
    // Manual (not derived): the loom facade's atomics do not promise
    // `Default` impls, and construction must work under both cfgs.
    fn default() -> Self {
        Self {
            panic_on_batch: AtomicU64::new(0),
            stall_on_batch: AtomicU64::new(0),
            stall_once_ns: AtomicU64::new(0),
            batches_seen: AtomicU64::new(0),
            slow_batch_ns: AtomicU64::new(0),
            output_bias: AtomicU64::new(0),
            poison_nan: AtomicBool::new(false),
            flaky_armed: AtomicBool::new(false),
            flaky: Mutex::new(None),
        }
    }
}

impl FaultInjector {
    /// A fully inert injector (every hook disabled).
    pub fn new() -> Self {
        Self::default()
    }

    /// Arm a one-shot panic on the `n`th batch executed from now
    /// (1 = the very next batch). Resets the batch counter.
    pub fn arm_panic_on_batch(&self, n: u64) {
        assert!(n > 0, "batch ordinals are 1-based");
        self.batches_seen.store(0, Ordering::SeqCst);
        self.panic_on_batch.store(n, Ordering::SeqCst);
    }

    /// Stall every subsequent batch by `d` (Duration::ZERO disables).
    pub fn set_slow_batch(&self, d: Duration) {
        self.slow_batch_ns.store(d.as_nanos() as u64, Ordering::SeqCst);
    }

    /// Arm a one-shot stall of `d` on the `n`th batch executed from now
    /// (1 = the very next batch). Resets the batch counter. This is the
    /// hedged-request fault: exactly one attempt wedges, every other
    /// batch — including the hedge — runs at full speed.
    pub fn arm_stall_on_batch(&self, n: u64, d: Duration) {
        assert!(n > 0, "batch ordinals are 1-based");
        self.batches_seen.store(0, Ordering::SeqCst);
        self.stall_once_ns.store(d.as_nanos() as u64, Ordering::SeqCst);
        self.stall_on_batch.store(n, Ordering::SeqCst);
    }

    /// Arm a bounded [`FlakyWindow`]: the next `window.batches` batches
    /// draw panic/stall faults from a Bernoulli stream seeded with
    /// `window.seed`, then the injector disarms itself. Replaces any
    /// window already armed.
    pub fn arm_flaky_window(&self, window: FlakyWindow) {
        assert!(
            (0.0..=1.0).contains(&window.panic_prob) && (0.0..=1.0).contains(&window.stall_prob),
            "fault probabilities must lie in [0, 1]"
        );
        let state = FlakyState { rng: Pcg::new(window.seed), window, remaining: window.batches };
        *lock_unpoisoned(&self.flaky) = (window.batches > 0).then_some(state);
        self.flaky_armed.store(window.batches > 0, Ordering::SeqCst);
    }

    /// Disarm any flaky window before its batch budget runs out.
    pub fn clear_flaky_window(&self) {
        self.flaky_armed.store(false, Ordering::SeqCst);
        *lock_unpoisoned(&self.flaky) = None;
    }

    /// Bias every subsequent BitLevel batch output by `bias` (0.0
    /// disables). Models a drifting engine — stuck counter bits,
    /// mis-calibrated decode — in a *healable* way: clearing the bias
    /// lets the sentinel's recovery probes succeed.
    pub fn set_output_bias(&self, bias: f64) {
        self.output_bias.store(bias.to_bits(), Ordering::SeqCst);
    }

    /// Replace every subsequent BitLevel output with NaN (off by
    /// default). Drives the worker's non-finite output guard.
    pub fn set_poison_nan(&self, on: bool) {
        self.poison_nan.store(on, Ordering::SeqCst);
    }

    /// Worker-side hook applied to a BitLevel batch's outputs after the
    /// engine runs and before results scatter to clients. Inert by
    /// default: one relaxed bool + one relaxed u64 load per batch.
    pub fn corrupt_outputs(&self, outputs: &mut [f64]) {
        if self.poison_nan.load(Ordering::Relaxed) {
            for y in outputs.iter_mut() {
                *y = f64::NAN;
            }
            return;
        }
        let bits = self.output_bias.load(Ordering::Relaxed);
        if bits != 0 {
            let bias = f64::from_bits(bits);
            for y in outputs.iter_mut() {
                *y += bias;
            }
        }
    }

    /// Worker-side hook, called once per batch before execution. May
    /// panic (isolated by the worker's `catch_unwind`) or sleep.
    pub fn before_batch(&self) {
        let seen = self.batches_seen.fetch_add(1, Ordering::SeqCst) + 1;
        let target = self.panic_on_batch.load(Ordering::SeqCst);
        if target != 0 && seen == target {
            self.panic_on_batch.store(0, Ordering::SeqCst);
            // xtask: allow(no-panic) justification: panicking is this hook's entire
            // purpose — it injects the worker-panic fault the chaos suite isolates.
            panic!("fault injection: worker panic on batch {seen}");
        }
        let stall_target = self.stall_on_batch.load(Ordering::SeqCst);
        if stall_target != 0 && seen == stall_target {
            self.stall_on_batch.store(0, Ordering::SeqCst);
            let ns = self.stall_once_ns.swap(0, Ordering::SeqCst);
            if ns > 0 {
                std::thread::sleep(Duration::from_nanos(ns));
            }
        }
        if self.flaky_armed.load(Ordering::Relaxed) {
            match self.flaky_draw(seen) {
                FlakyAction::None => {}
                FlakyAction::Panic(batch) => {
                    // xtask: allow(no-panic) justification: the flaky window's whole
                    // purpose is injecting intermittent worker panics (isolated by
                    // catch_unwind) for the resilient-client chaos tests.
                    panic!("fault injection: flaky panic on batch {batch}");
                }
                FlakyAction::Stall(d) => std::thread::sleep(d),
            }
        }
        let ns = self.slow_batch_ns.load(Ordering::Relaxed);
        if ns > 0 {
            std::thread::sleep(Duration::from_nanos(ns));
        }
    }

    /// Resolve one batch's fate under the armed flaky window. The draw
    /// (and the window bookkeeping) happens under the lock; the panic or
    /// stall itself is performed by the caller *after* the guard drops,
    /// so an injected panic cannot wedge the injector's own state.
    fn flaky_draw(&self, seen: u64) -> FlakyAction {
        let mut guard = lock_unpoisoned(&self.flaky);
        let Some(state) = guard.as_mut() else {
            return FlakyAction::None;
        };
        state.remaining -= 1;
        let u = state.rng.uniform();
        let action = if u < state.window.panic_prob {
            FlakyAction::Panic(seen)
        } else if u < state.window.panic_prob + state.window.stall_prob {
            FlakyAction::Stall(state.window.stall)
        } else {
            FlakyAction::None
        };
        if state.remaining == 0 {
            *guard = None;
            self.flaky_armed.store(false, Ordering::SeqCst);
        }
        action
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inert_by_default() {
        let f = FaultInjector::new();
        for _ in 0..100 {
            f.before_batch(); // no panic, no stall
        }
    }

    #[test]
    fn panic_on_nth_batch_is_one_shot() {
        let f = FaultInjector::new();
        f.arm_panic_on_batch(3);
        f.before_batch();
        f.before_batch();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_batch()));
        assert!(err.is_err(), "third batch must panic");
        // Trigger cleared: later batches run clean.
        f.before_batch();
        f.before_batch();
    }

    #[test]
    fn output_corruption_hooks() {
        let f = FaultInjector::new();
        let mut out = [0.25, 0.5];
        // Inert by default: outputs pass through untouched.
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.25, 0.5]);
        // Bias shifts every output; clearing it restores pass-through.
        f.set_output_bias(0.5);
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.75, 1.0]);
        f.set_output_bias(0.0);
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.75, 1.0]);
        // NaN poisoning wins over bias and is reversible.
        f.set_output_bias(0.5);
        f.set_poison_nan(true);
        f.corrupt_outputs(&mut out);
        assert!(out.iter().all(|y| y.is_nan()));
        f.set_poison_nan(false);
        f.set_output_bias(0.0);
        let mut out = [0.1];
        f.corrupt_outputs(&mut out);
        assert_eq!(out, [0.1]);
    }

    #[test]
    fn one_shot_stall_hits_exactly_one_batch() {
        let f = FaultInjector::new();
        f.arm_stall_on_batch(2, Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        f.before_batch(); // batch 1: untouched
        assert!(t0.elapsed() < Duration::from_millis(5));
        let t1 = std::time::Instant::now();
        f.before_batch(); // batch 2: stalls once
        assert!(t1.elapsed() >= Duration::from_millis(5));
        let t2 = std::time::Instant::now();
        f.before_batch(); // batch 3: trigger cleared
        assert!(t2.elapsed() < Duration::from_millis(5));
    }

    #[test]
    fn flaky_window_panics_deterministically_and_disarms() {
        // p=1 panics every batch in the window, then the injector is
        // inert again without any explicit clear.
        let f = FaultInjector::new();
        f.arm_flaky_window(FlakyWindow {
            seed: 7,
            panic_prob: 1.0,
            stall_prob: 0.0,
            stall: Duration::ZERO,
            batches: 2,
        });
        for _ in 0..2 {
            let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_batch()));
            assert!(err.is_err(), "every batch in a p=1 window must panic");
        }
        for _ in 0..5 {
            f.before_batch(); // window exhausted: clean
        }
    }

    #[test]
    fn flaky_window_replays_the_seeded_bernoulli_schedule() {
        // The injector's panic/no-panic sequence must equal an
        // independent replay of the same Pcg stream — fault schedules
        // are part of the deterministic test contract, not noise.
        let window = FlakyWindow {
            seed: 42,
            panic_prob: 0.5,
            stall_prob: 0.0,
            stall: Duration::ZERO,
            batches: 32,
        };
        let f = FaultInjector::new();
        f.arm_flaky_window(window);
        let mut rng = Pcg::new(window.seed);
        for i in 0..window.batches {
            let expect_panic = rng.uniform() < window.panic_prob;
            let got =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f.before_batch()));
            assert_eq!(got.is_err(), expect_panic, "batch {i} diverged from the seeded schedule");
        }
        f.before_batch(); // window over: inert
    }

    #[test]
    fn flaky_window_can_stall_and_be_cleared_early() {
        let f = FaultInjector::new();
        f.arm_flaky_window(FlakyWindow {
            seed: 3,
            panic_prob: 0.0,
            stall_prob: 1.0,
            stall: Duration::from_millis(5),
            batches: 100,
        });
        let t0 = std::time::Instant::now();
        f.before_batch();
        assert!(t0.elapsed() >= Duration::from_millis(5), "p=1 stall window must stall");
        f.clear_flaky_window();
        let t1 = std::time::Instant::now();
        f.before_batch();
        assert!(t1.elapsed() < Duration::from_millis(5), "cleared window must be inert");
    }

    #[test]
    fn slow_batch_stalls() {
        let f = FaultInjector::new();
        f.set_slow_batch(Duration::from_millis(5));
        let t0 = std::time::Instant::now();
        f.before_batch();
        assert!(t0.elapsed() >= Duration::from_millis(5));
        f.set_slow_batch(Duration::ZERO);
        f.before_batch();
    }
}

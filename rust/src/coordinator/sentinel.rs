//! Drift sentinel: canary cross-checks and engine quarantine.
//!
//! The bit-level engine is a stochastic simulator of real hardware; the
//! analytic evaluator (Eq. 21) is its infinite-stream limit and — unlike
//! the engine — cannot suffer bit-level faults (it never touches the
//! stochastic pipeline, see [`crate::sc::fault`]). That asymmetry makes
//! the analytic path a *fault-free reference*: by re-evaluating a small
//! fraction of `BitLevel` responses analytically and tracking the error
//! per function, the service can detect a drifting engine (stuck RNG
//! bits, corrupted FSM state, radiation-style upsets in silicon) while it
//! is still serving, and reroute traffic before clients see garbage.
//!
//! Per function the sentinel runs a three-state quarantine machine:
//!
//! ```text
//!            EWMA > threshold                probe failed
//!  Healthy ───────────────────► Quarantined ◄──────────── Probing
//!     ▲       (DriftAlarm)        │      ▲                  │
//!     │                           │      └── probe ok but ──┘
//!     │                           │          more needed
//!     │              every probe_interval-th request
//!     │                           ▼
//!     └──── probe_successes ── Probing
//!           consecutive good
//! ```
//!
//! - **Healthy** — requests serve on the real engine; a deterministic
//!   [Bresenham accumulator](DriftSentinel::route) canaries
//!   `canary_fraction` of them (no RNG: the k-th request of a function is
//!   canaried or not identically across runs). Canary errors feed an
//!   EWMA; once it exceeds `quarantine_threshold` (after `min_samples`
//!   observations) the function trips to Quarantined and a typed
//!   [`DriftAlarm`] is raised.
//! - **Quarantined** — `BitLevel` traffic degrades to the analytic
//!   closed form (`degraded: true`, exactly the load-shedding response
//!   shape), except that every `probe_interval`-th request is sent
//!   through the *real* engine as a forced-canary probe.
//! - **Probing** — one probe in flight; further traffic keeps degrading.
//!   A probe error at or below `recovery_threshold` counts toward
//!   recovery; `probe_successes` consecutive good probes re-enter
//!   Healthy with a reset EWMA. A bad probe clears the progress.
//!
//! With `canary_fraction == 0.0` the sentinel is fully disarmed: every
//! route is a plain serve, no canary is ever taken, no state machine can
//! trip — the serving path is behaviorally identical to a build without
//! the sentinel.

use crate::util::sync::{lock_unpoisoned, Mutex};
use std::collections::HashMap;

/// Sentinel policy knobs. The defaults are conservative: one request in
/// sixteen pays one extra analytic evaluation, and quarantine requires a
/// sustained EWMA excursion, not one noisy short stream.
#[derive(Clone, Debug)]
pub struct SentinelConfig {
    /// Fraction of healthy `BitLevel` requests cross-checked against the
    /// analytic closed form (deterministically paced). `0.0` disarms the
    /// sentinel entirely.
    pub canary_fraction: f64,
    /// EWMA smoothing factor for the per-function canary error
    /// (`ewma ← α·err + (1-α)·ewma`).
    pub ewma_alpha: f64,
    /// EWMA of mean |bitlevel − analytic| that trips quarantine.
    pub quarantine_threshold: f64,
    /// Canary observations required before the EWMA may trip (guards
    /// against a single noisy short stream quarantining a healthy
    /// engine).
    pub min_samples: u64,
    /// While quarantined, every `probe_interval`-th arriving request is
    /// served on the real engine as a probe; the rest degrade.
    pub probe_interval: u64,
    /// Consecutive successful probes required to re-enter Healthy.
    pub probe_successes: u64,
    /// Probe error at or below this counts as a success. Kept stricter
    /// than `quarantine_threshold` so recovery cannot flap around the
    /// trip point.
    pub recovery_threshold: f64,
}

impl Default for SentinelConfig {
    fn default() -> Self {
        Self {
            canary_fraction: 1.0 / 16.0,
            ewma_alpha: 0.2,
            quarantine_threshold: 0.15,
            min_samples: 4,
            probe_interval: 4,
            probe_successes: 2,
            recovery_threshold: 0.075,
        }
    }
}

impl SentinelConfig {
    /// A fully disarmed sentinel: never canaries, never quarantines.
    pub fn disabled() -> Self {
        Self { canary_fraction: 0.0, ..Self::default() }
    }
}

/// Per-function engine health as seen by the sentinel.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EngineHealth {
    /// Serving on the real engine; canaried at the configured pace.
    #[default]
    Healthy,
    /// Drift detected; traffic degrades, periodic probes test recovery.
    Quarantined,
    /// A probe is in flight on the real engine.
    Probing,
}

/// Typed drift notification, raised when a function's canary-error EWMA
/// crosses the quarantine threshold.
#[derive(Clone, Debug, PartialEq)]
pub struct DriftAlarm {
    /// The drifting function.
    pub function: String,
    /// EWMA of mean |bitlevel − analytic| at trip time.
    pub ewma: f64,
    /// The configured threshold it crossed.
    pub threshold: f64,
    /// Canary observations folded into the EWMA so far.
    pub samples: u64,
}

/// Routing verdict for one arriving `BitLevel` request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Serve on the real engine; `canary` marks it for cross-checking.
    Serve { canary: bool },
    /// Serve on the real engine as a forced-canary recovery probe.
    Probe,
    /// Reroute to the analytic closed form, flagged `degraded`.
    Degrade,
}

/// What one canary observation did to the state machine.
#[derive(Clone, Debug, PartialEq)]
pub enum Observation {
    /// Folded into the EWMA (or ignored); no transition.
    Noted,
    /// The EWMA crossed the threshold: the function is now quarantined.
    Alarm(DriftAlarm),
    /// Enough good probes: the function returned to Healthy.
    Recovered,
}

/// Canary pacing resolution: `canary_fraction` is quantized to units of
/// 1/65536 (the same grid as the θ-gate thresholds), so any nonzero
/// fraction ≥ 2⁻¹⁶ actually canaries.
const PACE_SCALE: u64 = 1 << 16;

#[derive(Debug, Default)]
struct FnState {
    health: EngineHealth,
    /// EWMA of the canary error while Healthy.
    ewma: f64,
    /// Canary observations folded into `ewma`.
    samples: u64,
    /// Bresenham accumulator for canary pacing.
    pace: u64,
    /// Requests seen while Quarantined (probe cadence counter).
    quarantined_seen: u64,
    /// Consecutive successful probes.
    probe_good: u64,
}

/// Per-function drift tracking shared between the submit edge (routing)
/// and the workers (canary observations). One mutex, touched once per
/// `BitLevel` request — negligible next to an L-cycle evaluation.
#[derive(Debug)]
pub struct DriftSentinel {
    cfg: SentinelConfig,
    /// `canary_fraction` quantized to `PACE_SCALE` units.
    pace_step: u64,
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    functions: HashMap<String, FnState>,
    /// Alarms raised and not yet drained by [`DriftSentinel::take_alarms`].
    alarms: Vec<DriftAlarm>,
}

impl DriftSentinel {
    /// Build a sentinel from a policy. Panics on malformed knobs
    /// (fractions outside [0, 1], zero cadences) — config bugs, not
    /// runtime conditions.
    pub fn new(cfg: SentinelConfig) -> Self {
        assert!(
            (0.0..=1.0).contains(&cfg.canary_fraction),
            "canary_fraction must be in [0, 1]"
        );
        assert!((0.0..=1.0).contains(&cfg.ewma_alpha) && cfg.ewma_alpha > 0.0);
        assert!(cfg.quarantine_threshold > 0.0);
        assert!(cfg.recovery_threshold > 0.0);
        assert!(cfg.probe_interval > 0, "probe cadence must be positive");
        assert!(cfg.probe_successes > 0);
        let pace_step = (cfg.canary_fraction * PACE_SCALE as f64).round() as u64;
        Self { cfg, pace_step, inner: Mutex::new(Inner::default()) }
    }

    /// The policy this sentinel runs.
    pub fn config(&self) -> &SentinelConfig {
        &self.cfg
    }

    /// Route one arriving `BitLevel` request for `function`. Mutates the
    /// pacing/probe counters, so call exactly once per request.
    pub fn route(&self, function: &str) -> Route {
        if self.pace_step == 0 {
            // Disarmed: nothing here can ever have left Healthy.
            return Route::Serve { canary: false };
        }
        let mut inner = lock_unpoisoned(&self.inner);
        let st = inner.functions.entry(function.to_string()).or_default();
        match st.health {
            EngineHealth::Healthy => {
                // Bresenham pacing: deterministic, evenly spread, exact
                // long-run fraction.
                st.pace += self.pace_step;
                let canary = st.pace >= PACE_SCALE;
                if canary {
                    st.pace -= PACE_SCALE;
                }
                Route::Serve { canary }
            }
            EngineHealth::Quarantined => {
                st.quarantined_seen += 1;
                if st.quarantined_seen % self.cfg.probe_interval == 0 {
                    st.health = EngineHealth::Probing;
                    Route::Probe
                } else {
                    Route::Degrade
                }
            }
            // One probe in flight at a time; the rest keep degrading.
            EngineHealth::Probing => Route::Degrade,
        }
    }

    /// Fold one canary observation (`err` = mean |bitlevel − analytic|
    /// over the request's points) into `function`'s state machine.
    pub fn observe(&self, function: &str, err: f64) -> Observation {
        // A non-finite error would poison the EWMA forever; clamp it to
        // a huge finite value so it trips (or fails a probe) instead.
        let err = if err.is_finite() { err.abs() } else { f64::MAX / 4.0 };
        let mut inner = lock_unpoisoned(&self.inner);
        let st = inner.functions.entry(function.to_string()).or_default();
        match st.health {
            EngineHealth::Healthy => {
                st.ewma = if st.samples == 0 {
                    err
                } else {
                    self.cfg.ewma_alpha * err + (1.0 - self.cfg.ewma_alpha) * st.ewma
                };
                st.samples += 1;
                if st.samples >= self.cfg.min_samples && st.ewma > self.cfg.quarantine_threshold
                {
                    st.health = EngineHealth::Quarantined;
                    st.quarantined_seen = 0;
                    st.probe_good = 0;
                    let alarm = DriftAlarm {
                        function: function.to_string(),
                        ewma: st.ewma,
                        threshold: self.cfg.quarantine_threshold,
                        samples: st.samples,
                    };
                    inner.alarms.push(alarm.clone());
                    Observation::Alarm(alarm)
                } else {
                    Observation::Noted
                }
            }
            EngineHealth::Probing => {
                if err <= self.cfg.recovery_threshold {
                    st.probe_good += 1;
                    if st.probe_good >= self.cfg.probe_successes {
                        *st = FnState::default(); // Healthy, EWMA reset
                        Observation::Recovered
                    } else {
                        // Good, but recovery needs more evidence: back to
                        // Quarantined so the cadence schedules the next
                        // probe; the success streak is kept.
                        st.health = EngineHealth::Quarantined;
                        Observation::Noted
                    }
                } else {
                    st.probe_good = 0;
                    st.health = EngineHealth::Quarantined;
                    Observation::Noted
                }
            }
            // Degraded traffic is analytic-served and never canaried;
            // a stray observation here has nothing to update.
            EngineHealth::Quarantined => Observation::Noted,
        }
    }

    /// Current health of a function (`Healthy` if never seen).
    pub fn health(&self, function: &str) -> EngineHealth {
        let inner = lock_unpoisoned(&self.inner);
        inner.functions.get(function).map(|s| s.health).unwrap_or_default()
    }

    /// The canary-error EWMA and sample count for a function, if any
    /// observation has been folded in (introspection/test hook).
    pub fn ewma(&self, function: &str) -> Option<(f64, u64)> {
        let inner = lock_unpoisoned(&self.inner);
        inner
            .functions
            .get(function)
            .filter(|s| s.samples > 0)
            .map(|s| (s.ewma, s.samples))
    }

    /// Drain the alarms raised since the last call.
    pub fn take_alarms(&self) -> Vec<DriftAlarm> {
        let mut inner = lock_unpoisoned(&self.inner);
        std::mem::take(&mut inner.alarms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn trippy() -> SentinelConfig {
        SentinelConfig {
            canary_fraction: 1.0,
            min_samples: 3,
            probe_interval: 4,
            probe_successes: 2,
            ..SentinelConfig::default()
        }
    }

    /// Drive routes until one comes back as a probe (bounded).
    fn route_until_probe(s: &DriftSentinel, f: &str, max: usize) -> usize {
        for i in 0..max {
            match s.route(f) {
                Route::Probe => return i + 1,
                Route::Degrade => continue,
                r => panic!("unexpected route while quarantined: {r:?}"),
            }
        }
        panic!("no probe within {max} requests");
    }

    /// Feed healthy-state errors until the alarm trips (bounded).
    fn observe_until_alarm(s: &DriftSentinel, f: &str, err: f64, max: usize) -> DriftAlarm {
        for _ in 0..max {
            if let Observation::Alarm(a) = s.observe(f, err) {
                return a;
            }
        }
        panic!("no alarm within {max} observations at err={err}");
    }

    #[test]
    fn unknown_function_is_healthy_and_serves() {
        let s = DriftSentinel::new(SentinelConfig::default());
        assert_eq!(s.health("f"), EngineHealth::Healthy);
        assert!(matches!(s.route("f"), Route::Serve { .. }));
        assert!(s.ewma("f").is_none());
    }

    #[test]
    fn disarmed_sentinel_never_canaries_or_trips() {
        let s = DriftSentinel::new(SentinelConfig::disabled());
        for _ in 0..100 {
            assert_eq!(s.route("f"), Route::Serve { canary: false });
        }
        // Even direct huge observations cannot quarantine a function the
        // router will consult, because routing short-circuits first.
        assert_eq!(s.route("f"), Route::Serve { canary: false });
    }

    #[test]
    fn bresenham_pacing_is_exact_and_deterministic() {
        let cfg = SentinelConfig { canary_fraction: 0.25, ..SentinelConfig::default() };
        let pattern = |s: &DriftSentinel| -> Vec<bool> {
            (0..100)
                .map(|_| matches!(s.route("f"), Route::Serve { canary: true }))
                .collect()
        };
        let a = pattern(&DriftSentinel::new(cfg.clone()));
        let b = pattern(&DriftSentinel::new(cfg));
        assert_eq!(a, b, "pacing must be deterministic");
        assert_eq!(a.iter().filter(|&&c| c).count(), 25, "exactly 1 in 4");
        // Evenly spread, not front-loaded: every window of 4 has one.
        for w in a.chunks(4) {
            assert_eq!(w.iter().filter(|&&c| c).count(), 1);
        }
    }

    #[test]
    fn full_fraction_canaries_every_request() {
        let s = DriftSentinel::new(trippy());
        for _ in 0..10 {
            assert_eq!(s.route("f"), Route::Serve { canary: true });
        }
    }

    #[test]
    fn drift_trips_after_min_samples_and_raises_alarm() {
        let s = DriftSentinel::new(trippy());
        assert_eq!(s.observe("f", 0.5), Observation::Noted);
        assert_eq!(s.observe("f", 0.5), Observation::Noted);
        let a = match s.observe("f", 0.5) {
            Observation::Alarm(a) => a,
            other => panic!("expected alarm on the 3rd sample, got {other:?}"),
        };
        assert_eq!(a.function, "f");
        assert_eq!(a.samples, 3);
        assert!(a.ewma > a.threshold, "ewma {} vs {}", a.ewma, a.threshold);
        assert_eq!(s.health("f"), EngineHealth::Quarantined);
        // The alarm is also queued for draining, exactly once.
        assert_eq!(s.take_alarms().len(), 1);
        assert!(s.take_alarms().is_empty());
    }

    #[test]
    fn small_errors_never_trip() {
        let s = DriftSentinel::new(trippy());
        for _ in 0..200 {
            assert_eq!(s.observe("f", 0.01), Observation::Noted);
        }
        assert_eq!(s.health("f"), EngineHealth::Healthy);
        let (ewma, n) = s.ewma("f").unwrap();
        assert!(ewma < 0.02);
        assert_eq!(n, 200);
    }

    #[test]
    fn quarantine_degrades_and_probes_on_cadence() {
        let s = DriftSentinel::new(trippy());
        observe_until_alarm(&s, "f", 0.5, 10);
        // probe_interval = 4: three degrades, then a probe.
        assert_eq!(s.route("f"), Route::Degrade);
        assert_eq!(s.route("f"), Route::Degrade);
        assert_eq!(s.route("f"), Route::Degrade);
        assert_eq!(s.route("f"), Route::Probe);
        assert_eq!(s.health("f"), EngineHealth::Probing);
        // While the probe is in flight, traffic keeps degrading.
        assert_eq!(s.route("f"), Route::Degrade);
        assert_eq!(s.route("f"), Route::Degrade);
    }

    #[test]
    fn probe_recovery_lifecycle() {
        let s = DriftSentinel::new(trippy());
        observe_until_alarm(&s, "f", 0.5, 10);
        // First good probe: progress, but still quarantined.
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Noted);
        assert_eq!(s.health("f"), EngineHealth::Quarantined);
        // Second good probe completes recovery (probe_successes = 2).
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Recovered);
        assert_eq!(s.health("f"), EngineHealth::Healthy);
        // EWMA reset: recovery starts from a clean slate and serves.
        assert!(s.ewma("f").is_none());
        assert!(matches!(s.route("f"), Route::Serve { .. }));
    }

    #[test]
    fn failed_probe_clears_the_success_streak() {
        let s = DriftSentinel::new(trippy());
        observe_until_alarm(&s, "f", 0.5, 10);
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Noted); // good: streak 1
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.9), Observation::Noted); // bad: streak 0
        assert_eq!(s.health("f"), EngineHealth::Quarantined);
        // Recovery now needs two fresh successes again.
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Noted);
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Recovered);
    }

    #[test]
    fn nonfinite_observation_is_clamped_not_poisonous() {
        let s = DriftSentinel::new(trippy());
        observe_until_alarm(&s, "f", f64::NAN, 10);
        assert_eq!(s.health("f"), EngineHealth::Quarantined);
        // Recovery still works: the EWMA was never set to NaN/Inf.
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Noted);
        route_until_probe(&s, "f", 8);
        assert_eq!(s.observe("f", 0.0), Observation::Recovered);
    }

    #[test]
    fn functions_are_tracked_independently() {
        let s = DriftSentinel::new(trippy());
        observe_until_alarm(&s, "bad", 0.5, 10);
        for _ in 0..50 {
            s.observe("good", 0.01);
        }
        assert_eq!(s.health("bad"), EngineHealth::Quarantined);
        assert_eq!(s.health("good"), EngineHealth::Healthy);
        assert!(matches!(s.route("good"), Route::Serve { .. }));
        assert_eq!(s.route("bad"), Route::Degrade);
    }

    #[test]
    #[should_panic]
    fn rejects_out_of_range_fraction() {
        DriftSentinel::new(SentinelConfig { canary_fraction: 1.5, ..SentinelConfig::default() });
    }
}

//! Request/response types for the evaluation service.

use std::sync::mpsc::Sender;
use std::time::Instant;

/// Which evaluation engine executes a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Cycle-accurate bit-level simulator (hardware-faithful).
    BitLevel,
    /// Closed-form Eq. 21 evaluation (infinite-stream limit).
    Analytic,
    /// AOT-compiled XLA executable (L1 Pallas kernel through PJRT).
    Xla,
}

/// One evaluation request: a point (or batch of points) for a named,
/// already-synthesized function.
#[derive(Debug)]
pub struct EvalRequest {
    /// Registered function name (e.g. "euclidean2").
    pub function: String,
    /// Input probability vectors, each of the function's arity.
    pub points: Vec<Vec<f64>>,
    pub engine: Engine,
    /// Bitstream length for the bit-level engine.
    pub stream_len: usize,
    /// Enqueue timestamp (set by the server).
    pub enqueued: Instant,
    /// Completion channel.
    pub reply: Sender<EvalResponse>,
}

/// Response with outputs and timing.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    pub outputs: Vec<f64>,
    /// Queue wait before the batch formed.
    pub queue_ns: u64,
    /// Execution time inside the worker.
    pub exec_ns: u64,
    /// Batch size this request was served in.
    pub batch_size: usize,
    /// Error message if evaluation failed.
    pub error: Option<String>,
}

impl EvalResponse {
    pub fn failed(msg: impl Into<String>) -> Self {
        Self { outputs: Vec::new(), queue_ns: 0, exec_ns: 0, batch_size: 0, error: Some(msg.into()) }
    }

    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failed_response() {
        let r = EvalResponse::failed("nope");
        assert!(!r.is_ok());
        assert_eq!(r.error.as_deref(), Some("nope"));
    }

    #[test]
    fn engine_is_hashable() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(Engine::BitLevel);
        s.insert(Engine::Analytic);
        s.insert(Engine::Xla);
        assert_eq!(s.len(), 3);
    }
}

//! Request/response types for the evaluation service, including the
//! typed failure model (see the failure-model section in
//! [`crate::coordinator`]): a request is either **rejected** at the
//! admission edge ([`RejectReason`]), **degraded** to a cheaper engine
//! under load (flagged in [`EvalResponse::degraded`]), or answered with a
//! typed [`EvalError`] — a client holding a reply channel is always
//! answered, never silently dropped.

use super::admission::DepthToken;
use std::fmt;
use std::sync::mpsc::Sender;
use std::time::Instant;

/// Base seed for serving-side bitstreams: point `i` of a request runs at
/// seed `DEFAULT_STREAM_SEED ^ i` (the *within-request* index, never the
/// batch slot), which is what makes served results deterministic per
/// request and independent of batch composition — see
/// `server::eval_bitlevel_batch`. The literal value is part of the
/// served-output contract (pinned by tests/chaos fixtures), so every
/// non-test reference goes through this named constant (enforced by
/// `xtask verify`'s seed-discipline rule).
pub const DEFAULT_STREAM_SEED: u64 = 0x5EED;

/// Which evaluation engine executes a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Engine {
    /// Cycle-accurate bit-level simulator (hardware-faithful).
    BitLevel,
    /// Closed-form Eq. 21 evaluation (infinite-stream limit).
    Analytic,
    /// AOT-compiled XLA executable (L1 Pallas kernel through PJRT).
    Xla,
}

impl Engine {
    /// Number of engines (per-engine admission tables are indexed by
    /// [`Engine::index`]).
    pub const COUNT: usize = 3;

    /// Dense index for per-engine accounting.
    pub fn index(self) -> usize {
        match self {
            Engine::BitLevel => 0,
            Engine::Analytic => 1,
            Engine::Xla => 2,
        }
    }
}

/// Why admission control refused a request (the typed `Rejected{…}`
/// family: nothing here ever reaches an engine).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RejectReason {
    /// The target engine's in-flight depth limit is reached and load
    /// shedding could not absorb the request either.
    QueueFull,
    /// The request is malformed (unknown function, arity mismatch,
    /// non-finite input, zero stream length) — refused at the edge
    /// instead of panicking deep inside an engine.
    BadRequest(String),
    /// The request's deadline had already passed before execution
    /// (at submit, at batch formation, or at the worker).
    Deadline,
}

impl fmt::Display for RejectReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RejectReason::QueueFull => write!(f, "queue full"),
            RejectReason::BadRequest(why) => write!(f, "bad request: {why}"),
            RejectReason::Deadline => write!(f, "deadline expired before execution"),
        }
    }
}

/// Typed failure attached to an [`EvalResponse`].
#[derive(Clone, Debug, PartialEq)]
pub enum EvalError {
    /// Refused by admission control; the request was never evaluated.
    Rejected(RejectReason),
    /// The synchronous client gave up waiting (its deadline fired while
    /// the request was still in flight). The server may still finish the
    /// evaluation; the reply is discarded.
    Timeout,
    /// A worker panicked while executing the batch this request rode in.
    /// The payload is the panic message; the supervisor respawns the
    /// worker, so later requests are unaffected.
    WorkerPanic(String),
    /// The serving stack closed (or crashed) before the request could be
    /// evaluated; it was answered rather than silently dropped.
    Shutdown,
    /// The engine itself failed (unknown function at execution time,
    /// unavailable XLA runtime, …).
    Engine(String),
    /// Refused by the *client-side* circuit breaker
    /// ([`crate::coordinator::client`]): recent attempts against this
    /// function kept failing, so the client fails fast without loading
    /// the server. Never produced by the server itself.
    CircuitOpen,
}

impl EvalError {
    /// Whether a fresh, identical attempt could plausibly succeed — the
    /// classification the resilient client's retry/hedge ladder keys on.
    ///
    /// Retryable: [`Timeout`](EvalError::Timeout) (the reply may simply
    /// have been slow), `Rejected(QueueFull)` (load is transient),
    /// [`WorkerPanic`](EvalError::WorkerPanic) (the supervisor respawns
    /// the worker), and [`Engine`](EvalError::Engine) (covers injected
    /// intermittent faults; a deterministic engine bug fails again and
    /// burns one retry, which the budget bounds).
    ///
    /// Terminal: `Rejected(BadRequest)` (same input, same refusal),
    /// `Rejected(Deadline)` (the deadline stays expired),
    /// [`Shutdown`](EvalError::Shutdown) (the server is gone), and
    /// [`CircuitOpen`](EvalError::CircuitOpen) (retrying immediately
    /// would defeat the breaker).
    ///
    /// Resubmission is *safe* in every case because served outputs are
    /// deterministic per request: seeds derive from
    /// [`DEFAULT_STREAM_SEED`] `^` the within-request point index, never
    /// from batch composition or worker identity.
    pub fn is_retryable(&self) -> bool {
        match self {
            EvalError::Timeout | EvalError::WorkerPanic(_) | EvalError::Engine(_) => true,
            EvalError::Rejected(RejectReason::QueueFull) => true,
            EvalError::Rejected(RejectReason::BadRequest(_))
            | EvalError::Rejected(RejectReason::Deadline)
            | EvalError::Shutdown
            | EvalError::CircuitOpen => false,
        }
    }
}

impl fmt::Display for EvalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EvalError::Rejected(r) => write!(f, "rejected: {r}"),
            EvalError::Timeout => write!(f, "client deadline fired while waiting for the reply"),
            EvalError::WorkerPanic(msg) => write!(f, "worker panicked: {msg}"),
            EvalError::Shutdown => write!(f, "server shut down before the request was evaluated"),
            EvalError::Engine(msg) => write!(f, "engine error: {msg}"),
            EvalError::CircuitOpen => {
                write!(f, "circuit breaker open: failing fast without contacting the server")
            }
        }
    }
}

// `std::error::Error` so `?`-interop and `Box<dyn Error>` callers can
// consume the typed failure surface directly. Both enums are leaves of
// the failure model: an `EvalError::Rejected` *carries* its
// `RejectReason` as data (matched on by the retry ladder), so neither
// impl forwards a `source()` — the default `None` is the contract.
impl std::error::Error for RejectReason {}

impl std::error::Error for EvalError {}

/// One evaluation request: a point (or batch of points) for a named,
/// already-synthesized function.
#[derive(Debug)]
pub struct EvalRequest {
    /// Registered function name (e.g. "euclidean2").
    pub function: String,
    /// Input probability vectors, each of the function's arity.
    pub points: Vec<Vec<f64>>,
    pub engine: Engine,
    /// Bitstream length for the bit-level engine.
    pub stream_len: usize,
    /// Enqueue timestamp (set by the server).
    pub enqueued: Instant,
    /// Optional deadline: once passed, the request is answered with
    /// `Rejected(Deadline)` instead of being evaluated (checked at
    /// submit, at batch formation, and again at the worker — BitLevel
    /// work is L-cycle expensive, so expired work is never started).
    pub deadline: Option<Instant>,
    /// Set by load shedding when the request was downgraded from
    /// `BitLevel` to `Analytic`; echoed on the response.
    pub degraded: bool,
    /// Set by the drift sentinel at submit: this `BitLevel` request's
    /// outputs are cross-checked against the analytic closed form after
    /// execution (either a paced canary or a quarantine-recovery probe).
    /// Does not change the outputs the client receives.
    pub canary: bool,
    /// Completion channel.
    pub reply: Sender<EvalResponse>,
    /// In-flight depth accounting token, held from admission until the
    /// request is answered (or dropped — the token releases on `Drop`,
    /// so panics and drops can never leak queue depth).
    pub(crate) admitted: Option<DepthToken>,
}

impl EvalRequest {
    /// Build a request with no deadline. `submit` stamps `enqueued` and
    /// attaches the admission token.
    pub fn new(
        function: impl Into<String>,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
        reply: Sender<EvalResponse>,
    ) -> Self {
        Self {
            function: function.into(),
            points,
            engine,
            stream_len,
            enqueued: Instant::now(),
            deadline: None,
            degraded: false,
            canary: false,
            reply,
            admitted: None,
        }
    }

    /// Attach an absolute deadline.
    pub fn with_deadline(mut self, deadline: Instant) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// True once `deadline` has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Response with outputs and timing.
#[derive(Clone, Debug)]
pub struct EvalResponse {
    pub outputs: Vec<f64>,
    /// Queue wait before the batch formed.
    pub queue_ns: u64,
    /// Execution time inside the worker.
    pub exec_ns: u64,
    /// Batch size this request was served in.
    pub batch_size: usize,
    /// True when load shedding served this `BitLevel` request from the
    /// `Analytic` closed form instead (reduced fidelity, same function).
    pub degraded: bool,
    /// Typed error if the request was not successfully evaluated.
    pub error: Option<EvalError>,
}

impl EvalResponse {
    /// An empty response carrying a typed error.
    pub fn from_error(error: EvalError) -> Self {
        Self {
            outputs: Vec::new(),
            queue_ns: 0,
            exec_ns: 0,
            batch_size: 0,
            degraded: false,
            error: Some(error),
        }
    }

    /// True when the request was evaluated (no typed error attached).
    pub fn is_ok(&self) -> bool {
        self.error.is_none()
    }

    /// The error rendered for humans, if any.
    pub fn error_message(&self) -> Option<String> {
        self.error.as_ref().map(|e| e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failed_response() {
        let r = EvalResponse::from_error(EvalError::Engine("nope".into()));
        assert!(!r.is_ok());
        assert_eq!(r.error, Some(EvalError::Engine("nope".into())));
        assert_eq!(r.error_message().as_deref(), Some("engine error: nope"));
    }

    #[test]
    fn retryable_classification_matches_the_ladder_contract() {
        // Retryable: transient by construction — a fresh attempt can win.
        assert!(EvalError::Timeout.is_retryable());
        assert!(EvalError::Rejected(RejectReason::QueueFull).is_retryable());
        assert!(EvalError::WorkerPanic("boom".into()).is_retryable());
        assert!(EvalError::Engine("flaky".into()).is_retryable());
        // Terminal: deterministic refusals and gone-forever states.
        assert!(!EvalError::Rejected(RejectReason::BadRequest("arity".into())).is_retryable());
        assert!(!EvalError::Rejected(RejectReason::Deadline).is_retryable());
        assert!(!EvalError::Shutdown.is_retryable());
        assert!(!EvalError::CircuitOpen.is_retryable());
        // The client-side variant renders for humans like the rest.
        let r = EvalResponse::from_error(EvalError::CircuitOpen);
        assert!(r.error_message().unwrap().contains("circuit breaker open"));
    }

    #[test]
    fn typed_rejections_render() {
        let r = EvalResponse::from_error(EvalError::Rejected(RejectReason::QueueFull));
        assert!(!r.is_ok());
        assert!(r.error_message().unwrap().contains("queue full"));
        let r = EvalResponse::from_error(EvalError::Rejected(RejectReason::BadRequest(
            "arity 3 != 2".into(),
        )));
        assert!(r.error_message().unwrap().contains("arity 3 != 2"));
        let r = EvalResponse::from_error(EvalError::WorkerPanic("boom".into()));
        assert!(matches!(r.error, Some(EvalError::WorkerPanic(ref m)) if m == "boom"));
    }

    #[test]
    fn typed_errors_box_into_dyn_error() {
        // `?`-interop: both failure enums erase into `Box<dyn Error>`.
        fn fails_rejected() -> Result<(), Box<dyn std::error::Error>> {
            Err(RejectReason::QueueFull)?
        }
        fn fails_eval() -> Result<(), Box<dyn std::error::Error>> {
            Err(EvalError::Timeout)?
        }
        let e = fails_rejected().unwrap_err();
        assert_eq!(e.to_string(), "queue full");
        assert!(e.source().is_none(), "leaf error: source() is None by contract");
        let e = fails_eval().unwrap_err();
        assert!(e.to_string().contains("deadline fired"));
        assert!(e.source().is_none());
        // Rejected carries its reason as matched data, not as a source.
        let e: Box<dyn std::error::Error> =
            Box::new(EvalError::Rejected(RejectReason::Deadline));
        assert!(e.source().is_none());
    }

    #[test]
    fn engine_is_hashable() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(Engine::BitLevel);
        s.insert(Engine::Analytic);
        s.insert(Engine::Xla);
        assert_eq!(s.len(), 3);
        assert_eq!(Engine::COUNT, 3);
        assert_eq!(Engine::BitLevel.index(), 0);
    }

    #[test]
    fn request_constructor_defaults() {
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = EvalRequest::new("f", vec![vec![0.5]], Engine::Analytic, 64, tx);
        assert!(req.deadline.is_none());
        assert!(!req.degraded);
        assert!(!req.canary);
        assert!(!req.expired(Instant::now()));
        let now = Instant::now();
        let req = req.with_deadline(now);
        assert!(req.expired(now + std::time::Duration::from_micros(1)));
    }
}

//! The evaluation server: function registry + admission control +
//! batcher + supervised worker pool.
//!
//! Architecture (std threads + channels; Python never on this path):
//!
//! ```text
//! clients → submit() → admission → [mpsc] → batcher thread → [mpsc] → N workers
//!              │  (validate, shed,            │ (deadlines,            │ (catch_unwind,
//!              │   depth limits)              │  typed drains)         │  typed panics)
//!              └────────── rejected ──────────┴──────── metrics ───────┴── supervisor
//! ```
//!
//! Workers execute a whole batch on one engine: the bit-level simulator,
//! the analytic evaluator, or — when `artifacts/smurf_eval.hlo.txt`
//! exists — the AOT-compiled XLA kernel for supported configurations.
//! Every batch runs under `catch_unwind`; a panicking worker answers its
//! in-flight requests with a typed `WorkerPanic` error and exits, and
//! the supervisor respawns it (fresh thread ⇒ fresh thread-local engine
//! scratch), so the pool never silently shrinks. The batcher is wrapped
//! in its own restart loop with the same guarantee.

use super::admission::{Admission, AdmissionConfig};
use super::batcher::{run_batcher, Batch, BatchPolicy};
use super::fault::FaultInjector;
use super::metrics::Metrics;
use super::request::{Engine, EvalError, EvalRequest, EvalResponse, RejectReason};
use super::request::DEFAULT_STREAM_SEED;
use super::sentinel::{DriftSentinel, Observation, Route, SentinelConfig};
use crate::runtime::Runtime;
use crate::smurf::approximator::SmurfApproximator;
use crate::util::sync::{lock_unpoisoned, Arc, AtomicBool, Mutex, Ordering, WakeSignal};
use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Server configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    pub workers: usize,
    pub policy: BatchPolicy,
    /// Artifact name of the XLA smurf_eval kernel (batch-N, M=2, N=4).
    pub xla_artifact: String,
    /// Admission policy: validation, depth limits, shedding watermarks.
    pub admission: AdmissionConfig,
    /// Fault-injection hooks (inert by default; shared with chaos tests).
    pub faults: Arc<FaultInjector>,
    /// Drift-sentinel policy: canary pacing + quarantine thresholds
    /// (see [`SentinelConfig`]; `SentinelConfig::disabled()` disarms).
    pub sentinel: SentinelConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
            policy: BatchPolicy::default(),
            xla_artifact: "smurf_eval.hlo.txt".into(),
            admission: AdmissionConfig::default(),
            faults: Arc::new(FaultInjector::new()),
            sentinel: SentinelConfig::default(),
        }
    }
}

/// A job for the dedicated XLA thread (the PJRT client is not `Send` in
/// the `xla` crate, so a single owner thread serializes device access —
/// the same single-queue model a real accelerator backend uses).
struct XlaJob {
    /// Row-major (batch, 2) f32 inputs, padded to the kernel batch.
    xs: Vec<f32>,
    /// 4×4 coefficient table.
    w: Vec<f32>,
    reply: Sender<Result<Vec<f32>, String>>,
}

/// Shared state between workers.
struct Shared {
    functions: HashMap<String, Arc<SmurfApproximator>>,
    metrics: Arc<Metrics>,
    admission: Arc<Admission>,
    faults: Arc<FaultInjector>,
    sentinel: Arc<DriftSentinel>,
    /// Level-triggered supervisor wakeup: the worker panic path and
    /// `shutdown()` notify it instead of waiting out the backoff
    /// timeout. A [`WakeSignal`] rather than a raw thread handle —
    /// regression for a loom-found lost wakeup: workers spawn *before*
    /// the supervisor thread exists, so a worker that panicked in that
    /// window used to find no handle registered and skip the unpark
    /// entirely (the supervisor then slept out its full backoff). The
    /// signal's pending flag persists across the registration window.
    supervisor_wake: WakeSignal,
    xla_tx: Option<Sender<XlaJob>>,
}

/// Owner loop for the PJRT runtime: creates the client *inside* the
/// thread (the `xla` crate's handles are not `Send`), compiles the
/// artifact once, then serves jobs until the channel closes.
fn xla_owner_loop(artifacts_dir: std::path::PathBuf, artifact: String, rx: Receiver<XlaJob>) {
    let exe = Runtime::cpu(&artifacts_dir)
        .map_err(|e| e.to_string())
        .and_then(|runtime| {
            if runtime.has_artifact(&artifact) {
                runtime.load(&artifact).map_err(|e| e.to_string())
            } else {
                Err(format!("artifact {artifact} missing (run `make artifacts`)"))
            }
        });
    while let Ok(job) = rx.recv() {
        let result = match &exe {
            Ok(exe) => exe
                .run_f32(&[(&[KERNEL_BATCH, 2], &job.xs), (&[4, 4], &job.w)])
                .map(|mut out| out.remove(0))
                .map_err(|e| e.to_string()),
            Err(e) => Err(e.clone()),
        };
        let _ = job.reply.send(result);
    }
}

/// Batch size the AOT kernel was lowered with (see python/compile/aot.py).
const KERNEL_BATCH: usize = 1024;

/// Supervisor wait right after a respawn (a crash storm wants fast
/// replacement); doubles while the pool stays healthy.
const SUPERVISE_MIN: Duration = Duration::from_millis(1);

/// Backoff cap for the supervisor's parked wait. Reaction latency is not
/// bounded by this: worker panic paths unpark the supervisor directly,
/// so the timeout only covers silent thread exits.
const SUPERVISE_MAX: Duration = Duration::from_millis(50);

/// The running evaluation service.
pub struct EvalServer {
    tx: Option<Sender<EvalRequest>>,
    shared: Arc<Shared>,
    batcher: Option<std::thread::JoinHandle<()>>,
    /// Worker handles, shared with the supervisor (which swaps respawned
    /// threads in place).
    workers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    supervisor: Option<std::thread::JoinHandle<()>>,
    /// Set before intake closes so the supervisor stops respawning.
    stop: Arc<AtomicBool>,
}

impl EvalServer {
    /// Start the service with a set of synthesized functions.
    /// `artifacts_dir` is optional: without it (or without artifacts) the
    /// XLA engine reports an error response instead of failing at startup.
    pub fn start(
        functions: Vec<SmurfApproximator>,
        artifacts_dir: Option<std::path::PathBuf>,
        cfg: ServerConfig,
    ) -> Self {
        // Dedicated XLA owner thread (PJRT client is not Send).
        let xla_tx = artifacts_dir.map(|dir| {
            let (jtx, jrx) = channel::<XlaJob>();
            let artifact = cfg.xla_artifact.clone();
            std::thread::Builder::new()
                .name("smurf-xla".into())
                .spawn(move || xla_owner_loop(dir, artifact, jrx))
                // xtask: allow(no-panic) justification: thread spawn fails only on
                // resource exhaustion at startup; there is no service to degrade yet.
                .expect("spawn xla owner");
            jtx
        });
        let metrics = Arc::new(Metrics::new());
        let admission = Arc::new(Admission::new(cfg.admission.clone(), metrics.clone()));
        let shared = Arc::new(Shared {
            functions: functions
                .into_iter()
                .map(|f| (f.name().to_string(), Arc::new(f)))
                .collect(),
            metrics: metrics.clone(),
            admission,
            faults: cfg.faults.clone(),
            sentinel: Arc::new(DriftSentinel::new(cfg.sentinel.clone())),
            supervisor_wake: WakeSignal::new(),
            xla_tx,
        });
        let (tx, rx) = channel::<EvalRequest>();
        let (btx, brx) = channel::<Batch>();
        let policy = cfg.policy;
        // Batcher with a self-restart loop: the wrapper owns both channel
        // endpoints, so a panicking batcher is restarted with its intake
        // and worker channels intact (requests still buffered in the
        // intake channel are re-received by the fresh loop; only the
        // panicking iteration's pending map is lost, and those clients
        // see a disconnect rather than a hang).
        let batcher_metrics = metrics.clone();
        let batcher = std::thread::Builder::new()
            .name("smurf-batcher".into())
            .spawn(move || loop {
                let r = catch_unwind(AssertUnwindSafe(|| {
                    run_batcher(&rx, &btx, policy, &batcher_metrics)
                }));
                match r {
                    Ok(()) => return, // intake closed: normal exit
                    Err(_) => {
                        batcher_metrics.record_panic();
                        batcher_metrics.record_respawn();
                    }
                }
            })
            // xtask: allow(no-panic) justification: thread spawn fails only on
            // resource exhaustion at startup; there is no service to degrade yet.
            .expect("spawn batcher");
        // Work-stealing via a shared locked receiver.
        let brx = Arc::new(Mutex::new(brx));
        let stop = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::new();
        for i in 0..cfg.workers.max(1) {
            handles.push(spawn_worker(i, shared.clone(), brx.clone()));
        }
        let workers = Arc::new(Mutex::new(handles));
        // Supervisor: respawn any worker whose thread has died (panic
        // isolation answers the in-flight batch, then exits the thread so
        // the replacement starts with fresh thread-local scratch).
        let supervisor = {
            let shared = shared.clone();
            let workers = workers.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("smurf-supervisor".into())
                .spawn(move || supervise(shared, brx, workers, stop))
                // xtask: allow(no-panic) justification: thread spawn fails only on
                // resource exhaustion at startup; there is no service to degrade yet.
                .expect("spawn supervisor")
        };
        // No registration step here: the supervisor registers itself with
        // `shared.supervisor_wake` at loop entry, and any notify that
        // lands earlier (a worker panicking during startup) is preserved
        // by the signal's pending flag — see [`WakeSignal`].
        Self {
            tx: Some(tx),
            shared,
            batcher: Some(batcher),
            workers,
            supervisor: Some(supervisor),
            stop,
        }
    }

    /// Submit a request. The drift sentinel routes first (a quarantined
    /// function's `BitLevel` traffic is rewritten to `Analytic` with
    /// `degraded: true`, exactly like load shedding; healthy traffic may
    /// be marked for a canary cross-check), then admission control:
    /// malformed traffic, expired deadlines, and over-limit queues are
    /// refused with a typed [`EvalError::Rejected`] (carrying the
    /// [`RejectReason`]) before anything is enqueued, and a closed intake
    /// returns [`EvalError::Shutdown`]; under shedding a `BitLevel`
    /// request may be rewritten to `Analytic`.
    pub fn submit(&self, mut req: EvalRequest) -> Result<(), EvalError> {
        req.enqueued = Instant::now();
        // Conservation ledger debit: recorded before routing or admission
        // so that *every* outcome below (rejection, shutdown, worker
        // answer) balances it — see `metrics::Snapshot::check_conservation`.
        self.shared.metrics.record_submitted();
        let functions = &self.shared.functions;
        // Sentinel routing runs before admission so rerouted traffic is
        // validated and depth-accounted under its *final* engine (the
        // same invariant the shedding path keeps). Gated on a known
        // function name so junk traffic cannot grow the sentinel's
        // per-function table.
        if req.engine == Engine::BitLevel && functions.contains_key(&req.function) {
            match self.shared.sentinel.route(&req.function) {
                Route::Serve { canary } => req.canary = canary,
                Route::Probe => {
                    req.canary = true;
                    self.shared.metrics.record_drift_probe();
                }
                Route::Degrade => {
                    req.engine = Engine::Analytic;
                    req.degraded = true;
                    self.shared.metrics.record_degraded();
                    self.shared.metrics.record_drift_degraded();
                }
            }
        }
        let arity_of = |name: &str| functions.get(name).map(|f| f.config().num_vars());
        Admission::admit(&self.shared.admission, &mut req, arity_of).map_err(|reason| {
            self.shared.metrics.record_rejection(&reason);
            EvalError::Rejected(reason)
        })?;
        let Some(tx) = self.tx.as_ref() else {
            // Closed intake: the typed `Shutdown` result *is* the answer,
            // so it is counted like the batcher's drain path to keep the
            // conservation ledger balanced.
            self.shared.metrics.record_shutdown_answered();
            return Err(EvalError::Shutdown);
        };
        // On failure the request (and its depth token) is dropped here.
        tx.send(req).map_err(|_| {
            self.shared.metrics.record_shutdown_answered();
            EvalError::Shutdown
        })
    }

    /// Convenience: synchronous single-request evaluation with the
    /// configured default timeout ([`AdmissionConfig::sync_timeout`]) —
    /// never blocks forever.
    pub fn eval_sync(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
    ) -> EvalResponse {
        let timeout = self.shared.admission.config().sync_timeout;
        self.eval_sync_with_timeout(function, points, engine, stream_len, timeout)
    }

    /// Synchronous evaluation with an explicit deadline: the request
    /// carries it end to end (admission, batch formation, worker), and
    /// the wait itself gives up with a typed `Timeout` once it fires.
    pub fn eval_sync_with_timeout(
        &self,
        function: &str,
        points: Vec<Vec<f64>>,
        engine: Engine,
        stream_len: usize,
        timeout: Duration,
    ) -> EvalResponse {
        let deadline = Instant::now() + timeout;
        let (rtx, rrx) = channel();
        let req = EvalRequest::new(function, points, engine, stream_len, rtx)
            .with_deadline(deadline);
        if let Err(e) = self.submit(req) {
            return EvalResponse::from_error(e);
        }
        match rrx.recv_timeout(deadline.saturating_duration_since(Instant::now())) {
            Ok(resp) => resp,
            Err(RecvTimeoutError::Timeout) => {
                self.shared.metrics.record_client_timeout();
                EvalResponse::from_error(EvalError::Timeout)
            }
            // The reply sender vanished without an answer (crashed
            // batcher iteration or shutdown race): typed, not a hang.
            Err(RecvTimeoutError::Disconnected) => EvalResponse::from_error(EvalError::Shutdown),
        }
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::metrics::Snapshot {
        self.shared.metrics.snapshot()
    }

    /// Shared metrics sink handle (crate-internal): the resilient client
    /// ([`super::client`]) records its retry/hedge/breaker counters into
    /// the same sink the server reports from, so one snapshot covers the
    /// whole serving path.
    pub(crate) fn metrics_handle(&self) -> Arc<Metrics> {
        self.shared.metrics.clone()
    }

    /// Admission state (depths, shedding latch; `force_shed` for tests
    /// and benches).
    pub fn admission(&self) -> &Admission {
        &self.shared.admission
    }

    /// Drift-sentinel state (per-function health, EWMAs, alarm drain).
    pub fn sentinel(&self) -> &DriftSentinel {
        &self.shared.sentinel
    }

    /// Number of worker threads currently alive (the supervisor returns
    /// this to the configured size after crashes).
    pub fn live_workers(&self) -> usize {
        lock_unpoisoned(&self.workers).iter().filter(|h| !h.is_finished()).count()
    }

    /// Registered function names.
    pub fn functions(&self) -> Vec<String> {
        let mut v: Vec<String> = self.shared.functions.keys().cloned().collect();
        v.sort();
        v
    }

    /// Graceful shutdown: stop supervision, close intake, join batcher
    /// and workers. Requests still queued at close are either evaluated
    /// by the draining workers or answered with a typed shutdown error —
    /// never silently dropped. Returns the final metrics snapshot, taken
    /// after every thread has joined, so callers can audit the
    /// conservation ledger ([`super::metrics::Snapshot::check_conservation`])
    /// over the server's complete lifetime — the chaos suite and the
    /// soak (`crate::testutil::soak`) do exactly that at teardown.
    ///
    /// Join-order audit (ISSUE 8, cross-checked against the loom wakeup
    /// model): `stop` must be set and the supervisor notified *before*
    /// intake closes, else a worker dying in the drain window could be
    /// respawned into a closing pool; the batcher joins before the
    /// supervisor (it feeds the worker channel, and joining it first
    /// bounds how much drain work the workers can still receive); workers
    /// join last, after the supervisor is guaranteed to never swap fresh
    /// handles into `self.workers` again. The one ordering bug the model
    /// did find was upstream of this function — the supervisor
    /// registration window, fixed by [`WakeSignal`].
    pub fn shutdown(mut self) -> super::metrics::Snapshot {
        // Order matters: the supervisor must stop respawning before the
        // workers see the closed channel and exit.
        self.stop.store(true, Ordering::SeqCst);
        // Wake the supervisor out of its parked wait so shutdown does
        // not serialize behind the backoff timeout.
        self.shared.supervisor_wake.notify();
        self.tx.take(); // closes intake; batcher drains and exits
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
        let mut ws = lock_unpoisoned(&self.workers);
        for w in ws.drain(..) {
            let _ = w.join();
        }
        drop(ws);
        self.shared.metrics.snapshot()
    }
}

fn spawn_worker(
    i: usize,
    shared: Arc<Shared>,
    brx: Arc<Mutex<Receiver<Batch>>>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("smurf-worker-{i}"))
        .spawn(move || worker_loop(shared, brx))
        // xtask: allow(no-panic) justification: respawn-path spawn failure means
        // the process is out of threads; the supervisor retrying is the recovery.
        .expect("spawn worker")
}

/// Supervision loop: respawn any dead worker until the server begins
/// shutdown.
///
/// Waits on the shared [`WakeSignal`] rather than busy-polling: the
/// worker panic path and `shutdown()` notify it, so the common cases
/// react in microseconds while a healthy pool costs one wakeup per
/// [`SUPERVISE_MAX`]. The timeout (doubling from [`SUPERVISE_MIN`] after
/// a respawn up to the cap) is the fallback for worker threads that die
/// without reaching their panic handler. Notifies that fired before this
/// loop starts (a worker panicking during server startup) are preserved
/// by the signal's level-triggered flag and consumed by the first wait.
fn supervise(
    shared: Arc<Shared>,
    brx: Arc<Mutex<Receiver<Batch>>>,
    workers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
) {
    shared.supervisor_wake.register_current();
    let mut wait = SUPERVISE_MIN;
    while !stop.load(Ordering::SeqCst) {
        shared.supervisor_wake.wait_timeout(wait);
        if stop.load(Ordering::SeqCst) {
            return;
        }
        let mut respawned = false;
        {
            let mut ws = lock_unpoisoned(&workers);
            for (i, slot) in ws.iter_mut().enumerate() {
                if slot.is_finished() && !stop.load(Ordering::SeqCst) {
                    let fresh = spawn_worker(i, shared.clone(), brx.clone());
                    let dead = std::mem::replace(slot, fresh);
                    let _ = dead.join();
                    shared.metrics.record_respawn();
                    respawned = true;
                }
            }
        }
        // Stay hot through a crash storm; back off while healthy.
        wait = if respawned { SUPERVISE_MIN } else { (wait * 2).min(SUPERVISE_MAX) };
    }
}

fn worker_loop(shared: Arc<Shared>, brx: Arc<Mutex<Receiver<Batch>>>) {
    loop {
        let batch = {
            let guard = lock_unpoisoned(&brx);
            guard.recv()
        };
        let Ok(batch) = batch else { return };
        // Panic isolation: clone the reply channels first so a panicking
        // engine (or injected fault) can never strand its clients.
        let replies: Vec<Sender<EvalResponse>> =
            batch.requests.iter().map(|r| r.reply.clone()).collect();
        let result = catch_unwind(AssertUnwindSafe(|| execute_batch(&shared, batch)));
        if let Err(payload) = result {
            let msg = panic_text(payload.as_ref());
            shared.metrics.record_panic();
            for tx in replies {
                shared.metrics.record_error();
                let _ = tx.send(EvalResponse::from_error(EvalError::WorkerPanic(msg.clone())));
            }
            // Exit the thread: the engines keep per-thread scratch, and a
            // panicking evaluation may have left it mid-update. Notify
            // the supervisor so the replacement (with clean
            // thread-locals) spawns immediately instead of after the
            // backoff timeout. Level-triggered: this is never lost, even
            // if the supervisor has not started waiting (or registering)
            // yet.
            shared.supervisor_wake.notify();
            return;
        }
    }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "worker panicked".to_string()
    }
}

fn execute_batch(shared: &Shared, batch: Batch) {
    let (ref fname, engine) = batch.key;
    // Fault-injection hook (inert in production): may panic or stall.
    shared.faults.before_batch();
    // Final deadline check: the batch may have waited in the worker
    // channel; expired requests are answered, not evaluated.
    let now = Instant::now();
    let (expired, requests): (Vec<_>, Vec<_>) =
        batch.requests.into_iter().partition(|r| r.expired(now));
    for req in expired {
        shared.metrics.record_rejection(&RejectReason::Deadline);
        let _ = req
            .reply
            .send(EvalResponse::from_error(EvalError::Rejected(RejectReason::Deadline)));
    }
    if requests.is_empty() {
        return;
    }
    let batch_size = requests.len();
    let Some(func) = shared.functions.get(fname).cloned() else {
        // Unreachable through submit() (admission validates the name);
        // kept as defense for directly-injected batches.
        for req in requests {
            shared.metrics.record_error();
            let _ = req.reply.send(EvalResponse::from_error(EvalError::Engine(format!(
                "unknown function {fname}"
            ))));
        }
        return;
    };

    // Execute the whole batch once, then scatter results per request.
    // (The BitLevel engine works on the request structure directly —
    // stream lengths and seeds are per-request — so only the engines
    // that are length-agnostic flatten the points.)
    let spans: Vec<usize> = requests.iter().map(|r| r.points.len()).collect();
    let exec_start = Instant::now();
    let result: Result<Vec<f64>, String> = match engine {
        Engine::Analytic => Ok(requests
            .iter()
            .flat_map(|r| r.points.iter())
            .map(|p| func.eval_analytic(p))
            .collect()),
        Engine::BitLevel => Ok(eval_bitlevel_batch(&func, &requests)),
        Engine::Xla => {
            let all_points: Vec<&[f64]> = requests
                .iter()
                .flat_map(|r| r.points.iter().map(|p| p.as_slice()))
                .collect();
            execute_xla(shared, &func, &all_points)
        }
    };
    let exec_ns = exec_start.elapsed().as_nanos() as u64;

    match result {
        Ok(mut outputs) => {
            if engine == Engine::BitLevel {
                // Chaos hook (inert in production): simulated engine
                // drift / NaN poisoning, applied to the raw engine
                // outputs so the sentinel and the non-finite guard see
                // exactly what a faulty engine would produce.
                shared.faults.corrupt_outputs(&mut outputs);
            }
            let mut off = 0;
            let mut batch_counted = false;
            for (req, span) in requests.into_iter().zip(spans) {
                let span_out = &outputs[off..off + span];
                off += span;
                // Non-finite guard: a NaN/Inf engine result becomes a
                // typed error, never a poisoned float in `outputs`.
                if let Some(bad) = span_out.iter().find(|y| !y.is_finite()) {
                    shared.metrics.record_nonfinite();
                    shared.metrics.record_error();
                    let _ = req.reply.send(EvalResponse::from_error(EvalError::Engine(format!(
                        "engine produced non-finite output {bad}"
                    ))));
                    continue;
                }
                // Canary/probe cross-check: feed the mean error vs the
                // analytic closed form (the fault-free reference) into
                // the drift sentinel. Outputs are unchanged.
                if req.canary && engine == Engine::BitLevel {
                    shared.metrics.record_canary();
                    let err = span_out
                        .iter()
                        .zip(&req.points)
                        .map(|(y, p)| (y - func.eval_analytic(p)).abs())
                        .sum::<f64>()
                        / span.max(1) as f64;
                    match shared.sentinel.observe(fname, err) {
                        Observation::Alarm(_) => shared.metrics.record_drift_alarm(),
                        Observation::Recovered => shared.metrics.record_drift_recovery(),
                        Observation::Noted => {}
                    }
                }
                let queue_ns = batch
                    .formed_at
                    .saturating_duration_since(req.enqueued)
                    .as_nanos() as u64;
                let e2e_ns = req.enqueued.elapsed().as_nanos() as u64;
                shared.metrics.record(queue_ns, exec_ns, e2e_ns, span as u64, !batch_counted);
                batch_counted = true;
                let _ = req.reply.send(EvalResponse {
                    outputs: span_out.to_vec(),
                    queue_ns,
                    exec_ns,
                    batch_size,
                    degraded: req.degraded,
                    error: None,
                });
            }
        }
        Err(e) => {
            for req in requests {
                shared.metrics.record_error();
                let _ = req.reply.send(EvalResponse::from_error(EvalError::Engine(e.clone())));
            }
        }
    }
}

/// Points per wide pass: one trial per lane of the widest bit plane
/// compiled into the build (256, or 512 with the `wide512` feature).
const WIDE_LANES: usize = crate::smurf::sim_wide::MAX_LANES;

/// Batch size at which the bit-level engine switches from per-point scalar
/// simulation to the bit-sliced wide engine; below this the fixed lane
/// word cost is not amortized (same threshold as the estimator routing).
const WIDE_BATCH_MIN: usize = crate::smurf::sim::WIDE_TRIALS_MIN;

/// Bit-level engine over a batch of requests, flattened in request order.
///
/// Two batching guarantees the previous flattened-slice implementation
/// broke, both load-bearing for a deterministic service:
///
/// - **Per-request stream lengths.** Points are grouped by `stream_len`
///   before chunking, so a mixed-L batch evaluates every request at *its
///   own* L instead of the first request's (and the groups run
///   independently — no serialization on the first request's length).
/// - **Batch-independent streams.** Seeds derive from the point's index
///   *within its request* ([`DEFAULT_STREAM_SEED`]` ^ i`), not its slot
///   in the flattened batch, so a client observes the same bitstream for
///   the same request regardless of what it was batched with.
///
/// Points run through [`SmurfApproximator::eval_bitstream_points_into`]
/// — [`WIDE_LANES`] lanes per wide pass (the widest plane in the build),
/// points from different requests sharing passes, on the calling worker's
/// persistent thread-local
/// [`WideRunState`](crate::smurf::sim_wide::WideRunState) scratch.
/// The dominant uniform-L batch streams lanes directly and allocates only
/// the output vector; a mixed-L batch additionally builds small
/// per-length index lists so each group chunks independently. Per-point
/// outputs stay bit-exact equal to the scalar
/// `eval_bitstream(p, len, DEFAULT_STREAM_SEED ^ i)` at every plane
/// width.
fn eval_bitlevel_batch(func: &SmurfApproximator, requests: &[EvalRequest]) -> Vec<f64> {
    let total: usize = requests.iter().map(|r| r.points.len()).sum();
    let mut outputs = vec![0.0f64; total];

    // Fast path: every request shares one stream length (the common case
    // — the batcher keys on function+engine, and clients of one function
    // typically agree on L). Slots are then contiguous in flattened
    // order, so lanes stream straight into the output vector with no
    // grouping structures at all.
    let uniform_len = {
        let mut lens = requests.iter().map(|r| r.stream_len.max(1));
        let first = lens.next();
        first.filter(|&l| lens.all(|x| x == l))
    };
    if let Some(len) = uniform_len {
        if total < WIDE_BATCH_MIN {
            // Below this the fixed plane-word cost is not amortized
            // (small wide-eligible batches route to the 64-lane engine
            // inside eval_bitstream_points_into).
            let mut slot = 0usize;
            for r in requests {
                for (i, p) in r.points.iter().enumerate() {
                    outputs[slot] = func.eval_bitstream(p, len, DEFAULT_STREAM_SEED ^ i as u64);
                    slot += 1;
                }
            }
            return outputs;
        }
        let mut pts: [&[f64]; WIDE_LANES] = [&[]; WIDE_LANES];
        let mut seeds = [0u64; WIDE_LANES];
        let mut lane_out = [0.0f64; WIDE_LANES];
        let mut fill = 0usize;
        let mut flushed = 0usize;
        for r in requests {
            for (i, p) in r.points.iter().enumerate() {
                pts[fill] = p.as_slice();
                seeds[fill] = DEFAULT_STREAM_SEED ^ i as u64;
                fill += 1;
                if fill == WIDE_LANES {
                    func.eval_bitstream_points_into(&pts, len, &seeds, &mut lane_out);
                    outputs[flushed..flushed + WIDE_LANES].copy_from_slice(&lane_out);
                    flushed += WIDE_LANES;
                    fill = 0;
                }
            }
        }
        if fill > 0 {
            func.eval_bitstream_points_into(
                &pts[..fill],
                len,
                &seeds[..fill],
                &mut lane_out[..fill],
            );
            outputs[flushed..flushed + fill].copy_from_slice(&lane_out[..fill]);
        }
        return outputs;
    }

    // Mixed-L batch: group (flattened output slot, seed, point) by stream
    // length so every request evaluates at its own L.
    let mut groups: std::collections::BTreeMap<usize, Vec<(usize, u64, &[f64])>> =
        std::collections::BTreeMap::new();
    let mut off = 0usize;
    for r in requests {
        let len = r.stream_len.max(1);
        let group = groups.entry(len).or_default();
        for (i, p) in r.points.iter().enumerate() {
            group.push((off + i, DEFAULT_STREAM_SEED ^ i as u64, p.as_slice()));
        }
        off += r.points.len();
    }
    for (len, entries) in &groups {
        if entries.len() < WIDE_BATCH_MIN {
            for &(slot, seed, p) in entries {
                outputs[slot] = func.eval_bitstream(p, *len, seed);
            }
            continue;
        }
        // The group is already heap-materialized, so hand the whole thing
        // to the approximator (which owns the 64-lane chunking) and
        // scatter the results to their flattened slots.
        let gpts: Vec<&[f64]> = entries.iter().map(|&(_, _, p)| p).collect();
        let gseeds: Vec<u64> = entries.iter().map(|&(_, s, _)| s).collect();
        let gout = func.eval_bitstream_points(&gpts, *len, &gseeds);
        for (&(slot, _, _), y) in entries.iter().zip(gout) {
            outputs[slot] = y;
        }
    }
    outputs
}

/// Execute a batch on the AOT XLA kernel via the owner thread. The
/// shipped kernel is specialized to M=2/N=4 with a runtime coefficient
/// table and a fixed batch of 1024 (padded).
fn execute_xla(
    shared: &Shared,
    func: &SmurfApproximator,
    points: &[&[f64]],
) -> Result<Vec<f64>, String> {
    let jtx = shared.xla_tx.as_ref().ok_or("XLA runtime not configured")?;
    if func.config().num_vars() != 2 || func.config().radices() != [4, 4] {
        return Err("XLA kernel is compiled for bivariate N=4 functions".into());
    }
    let w: Vec<f32> = func.coefficients().iter().map(|&x| x as f32).collect();
    let mut outputs = Vec::with_capacity(points.len());
    for chunk in points.chunks(KERNEL_BATCH) {
        let mut xs = vec![0.0f32; KERNEL_BATCH * 2];
        for (i, p) in chunk.iter().enumerate() {
            xs[i * 2] = p[0] as f32;
            xs[i * 2 + 1] = p[1] as f32;
        }
        let (rtx, rrx) = channel();
        jtx.send(XlaJob { xs, w: w.clone(), reply: rtx })
            .map_err(|_| "xla owner thread gone".to_string())?;
        let out = rrx.recv().map_err(|_| "xla owner dropped reply".to_string())??;
        outputs.extend(out[..chunk.len()].iter().map(|&y| y as f64));
    }
    Ok(outputs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::smurf::config::SmurfConfig;
    use crate::synth::functions;

    fn test_server(workers: usize) -> EvalServer {
        let cfg = SmurfConfig::uniform(2, 4);
        let funcs = vec![
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64),
            SmurfApproximator::synthesize(&cfg, &functions::product2(), 64),
        ];
        EvalServer::start(
            funcs,
            None,
            ServerConfig {
                workers,
                policy: BatchPolicy {
                    max_batch: 8,
                    max_wait: std::time::Duration::from_millis(1),
                },
                ..ServerConfig::default()
            },
        )
    }

    #[test]
    fn serves_analytic_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::Analytic, 64);
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!(!resp.degraded);
        assert!((resp.outputs[0] - 0.5).abs() < 0.05, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn serves_bitlevel_requests() {
        let server = test_server(2);
        let resp = server.eval_sync("product2", vec![vec![0.5, 0.5]], Engine::BitLevel, 256);
        assert!(resp.is_ok());
        assert!((resp.outputs[0] - 0.25).abs() < 0.2, "y={}", resp.outputs[0]);
        server.shutdown();
    }

    #[test]
    fn bitlevel_batch_matches_scalar_per_point() {
        // The wide batch path must reproduce the per-point scalar streams
        // bit-exactly (same 0x5EED ^ i seeds), across the u64-word mark
        // at 64, the auto-width chunk boundary at WIDE_LANES, and the
        // scalar fallback below 8 points.
        let server = test_server(1);
        let cfg = SmurfConfig::uniform(2, 4);
        let reference =
            SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        for n in [3usize, 8, 64, 70, WIDE_LANES, WIDE_LANES + 6] {
            let points: Vec<Vec<f64>> = (0..n)
                .map(|i| vec![(i % 9) as f64 / 8.0, (i % 7) as f64 / 6.0])
                .collect();
            let resp = server.eval_sync("euclidean2", points.clone(), Engine::BitLevel, 128);
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert_eq!(resp.outputs.len(), n);
            for (i, p) in points.iter().enumerate() {
                let expect = reference.eval_bitstream(p, 128, 0x5EED ^ i as u64);
                assert_eq!(resp.outputs[i], expect, "n={n} point {i}");
            }
        }
        server.shutdown();
    }

    #[test]
    fn mixed_stream_lengths_evaluate_at_their_own_length() {
        // A batch mixing stream lengths must evaluate every request at
        // its own L (the old flattened path ran everything at the first
        // request's L), with seeds from the within-request point index.
        // Group shapes: len=32 gets 10 + (WIDE_LANES + 20) points — the
        // cross-request lane packing fills one whole plane word and
        // spills a tail past the auto-width chunk boundary — while
        // len=128 gets 3 (scalar fallback).
        let cfg = SmurfConfig::uniform(2, 4);
        let func = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        let mk = |n: usize, len: usize, salt: usize| -> EvalRequest {
            let (rtx, _rrx) = channel();
            EvalRequest::new(
                "euclidean2",
                (0..n)
                    .map(|i| vec![((i + salt) % 10) as f64 / 9.0, (i % 7) as f64 / 6.0])
                    .collect(),
                Engine::BitLevel,
                len,
                rtx,
            )
        };
        let reqs = vec![mk(10, 32, 1), mk(3, 128, 2), mk(WIDE_LANES + 20, 32, 3)];
        let out = eval_bitlevel_batch(&func, &reqs);
        assert_eq!(out.len(), WIDE_LANES + 33);
        let mut off = 0;
        for (ri, r) in reqs.iter().enumerate() {
            for (i, p) in r.points.iter().enumerate() {
                let want = func.eval_bitstream(p, r.stream_len, 0x5EED ^ i as u64);
                assert_eq!(out[off + i], want, "request {ri} point {i}");
            }
            off += r.points.len();
        }
    }

    #[test]
    fn uniform_length_multi_request_batch_streams_lanes() {
        // The uniform-L fast path: 50 + (WIDE_LANES - 30) + 1 points from
        // three requests stream through shared WIDE_LANES-wide passes
        // (one full flush + a 21-lane tail), each point still seeded by
        // its within-request index.
        let cfg = SmurfConfig::uniform(2, 4);
        let func = SmurfApproximator::synthesize(&cfg, &functions::product2(), 64);
        let mk = |n: usize, salt: usize| -> EvalRequest {
            let (rtx, _rrx) = channel();
            EvalRequest::new(
                "product2",
                (0..n)
                    .map(|i| vec![((i + salt) % 8) as f64 / 7.0, (i % 5) as f64 / 4.0])
                    .collect(),
                Engine::BitLevel,
                64,
                rtx,
            )
        };
        let reqs = vec![mk(50, 0), mk(WIDE_LANES - 30, 5), mk(1, 9)];
        let out = eval_bitlevel_batch(&func, &reqs);
        assert_eq!(out.len(), WIDE_LANES + 21);
        let mut off = 0;
        for (ri, r) in reqs.iter().enumerate() {
            for (i, p) in r.points.iter().enumerate() {
                let want = func.eval_bitstream(p, 64, 0x5EED ^ i as u64);
                assert_eq!(out[off + i], want, "request {ri} point {i}");
            }
            off += r.points.len();
        }
    }

    #[test]
    fn unknown_function_rejected_at_the_edge() {
        let server = test_server(1);
        let resp = server.eval_sync("nope", vec![vec![0.1, 0.1]], Engine::Analytic, 64);
        assert!(!resp.is_ok());
        assert!(
            matches!(resp.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))),
            "{:?}",
            resp.error
        );
        assert_eq!(server.metrics().rejected_bad_request, 1);
        server.shutdown();
    }

    #[test]
    fn malformed_points_rejected_at_the_edge() {
        let server = test_server(1);
        // Wrong arity.
        let r = server.eval_sync("euclidean2", vec![vec![0.1]], Engine::Analytic, 64);
        assert!(matches!(r.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))));
        // Non-finite input.
        let r = server.eval_sync("euclidean2", vec![vec![0.1, f64::INFINITY]], Engine::Analytic, 64);
        assert!(matches!(r.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))));
        // Zero stream length on the bit-level engine.
        let r = server.eval_sync("euclidean2", vec![vec![0.1, 0.2]], Engine::BitLevel, 0);
        assert!(matches!(r.error, Some(EvalError::Rejected(RejectReason::BadRequest(_)))));
        assert_eq!(server.metrics().rejected_bad_request, 3);
        server.shutdown();
    }

    #[test]
    fn degraded_request_served_from_analytic_and_flagged() {
        let server = test_server(1);
        server.admission().force_shed(true);
        let points = vec![vec![0.3, 0.4], vec![0.6, 0.2]];
        let resp = server.eval_sync("euclidean2", points.clone(), Engine::BitLevel, 256);
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!(resp.degraded, "shedding must flag the response");
        let cfg = SmurfConfig::uniform(2, 4);
        let reference = SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64);
        for (got, p) in resp.outputs.iter().zip(&points) {
            assert_eq!(*got, reference.eval_analytic(p), "degraded == analytic closed form");
        }
        assert!(server.metrics().degraded >= 1);
        server.admission().force_shed(false);
        let resp = server.eval_sync("euclidean2", points, Engine::BitLevel, 256);
        assert!(resp.is_ok() && !resp.degraded);
        server.shutdown();
    }

    #[test]
    fn nonfinite_outputs_are_typed_errors() {
        let cfg = SmurfConfig::uniform(2, 4);
        let funcs = vec![SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64)];
        let faults = Arc::new(FaultInjector::new());
        let server = EvalServer::start(
            funcs,
            None,
            ServerConfig {
                workers: 1,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                faults: faults.clone(),
                ..ServerConfig::default()
            },
        );
        faults.set_poison_nan(true);
        let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::BitLevel, 64);
        assert!(!resp.is_ok());
        assert!(
            matches!(resp.error, Some(EvalError::Engine(ref m)) if m.contains("non-finite")),
            "{:?}",
            resp.error
        );
        assert!(resp.outputs.is_empty(), "no poisoned float may reach a client");
        assert!(server.metrics().nonfinite_outputs >= 1);
        // Clearing the fault restores normal service.
        faults.set_poison_nan(false);
        let resp = server.eval_sync("euclidean2", vec![vec![0.3, 0.4]], Engine::BitLevel, 64);
        assert!(resp.is_ok(), "{:?}", resp.error);
        assert!(resp.outputs[0].is_finite());
        server.shutdown();
    }

    #[test]
    fn canaries_cross_check_without_disturbing_healthy_service() {
        use crate::coordinator::sentinel::EngineHealth;
        let cfg = SmurfConfig::uniform(2, 4);
        let funcs = vec![SmurfApproximator::synthesize(&cfg, &functions::euclidean2(), 64)];
        let server = EvalServer::start(
            funcs,
            None,
            ServerConfig {
                workers: 1,
                policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) },
                sentinel: SentinelConfig { canary_fraction: 1.0, ..SentinelConfig::default() },
                ..ServerConfig::default()
            },
        );
        // A healthy engine under full canary coverage: every response is
        // cross-checked, none degrade, no alarm trips.
        for i in 0..6 {
            let x = (i + 1) as f64 / 8.0;
            let resp = server.eval_sync("euclidean2", vec![vec![x, 0.5]], Engine::BitLevel, 2048);
            assert!(resp.is_ok(), "{:?}", resp.error);
            assert!(!resp.degraded);
        }
        let snap = server.metrics();
        assert!(snap.canary_checks >= 6, "canary_checks={}", snap.canary_checks);
        assert_eq!(snap.drift_alarms, 0);
        assert_eq!(snap.drift_degraded, 0);
        assert_eq!(server.sentinel().health("euclidean2"), EngineHealth::Healthy);
        let (ewma, n) = server.sentinel().ewma("euclidean2").expect("canaries observed");
        assert!(n >= 6);
        assert!(ewma < server.sentinel().config().quarantine_threshold, "ewma={ewma}");
        server.shutdown();
    }

    #[test]
    fn xla_without_runtime_errors_cleanly() {
        let server = test_server(1);
        let resp = server.eval_sync("euclidean2", vec![vec![0.1, 0.1]], Engine::Xla, 64);
        assert!(!resp.is_ok());
        assert!(matches!(resp.error, Some(EvalError::Engine(_))));
        server.shutdown();
    }

    #[test]
    fn concurrent_load_is_batched() {
        let server = Arc::new(test_server(4));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..25 {
                    let x = (t as f64 * 25.0 + i as f64) / 200.0;
                    let r = s.eval_sync("euclidean2", vec![vec![x, x]], Engine::Analytic, 64);
                    assert!(r.is_ok());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let snap = server.metrics().clone();
        assert_eq!(snap.requests, 200);
        assert!(snap.mean_batch_size >= 1.0);
        assert_eq!(snap.errors, 0);
        assert!(snap.queue_depth_highwater >= 1);
        if let Ok(s) = Arc::try_unwrap(server) {
            s.shutdown();
        }
    }

    #[test]
    fn functions_listing() {
        let server = test_server(1);
        assert_eq!(server.functions(), vec!["euclidean2", "product2"]);
        server.shutdown();
    }

    #[test]
    fn live_workers_reports_pool_size() {
        let server = test_server(3);
        assert_eq!(server.live_workers(), 3);
        server.shutdown();
    }
}
